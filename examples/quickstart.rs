//! Quickstart: the public API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a deterministic MSM workload on both paper curves, runs every MSM
//! algorithm, cross-checks results, shows the measured op counts next to
//! the paper's Tables II/III accounting, and times the modeled FPGA.

use ifzkp::ec::{points, Bls12381G1, Bn254G1, CurveParams};
use ifzkp::fpga::{CurveId, SabConfig, SabModel};
use ifzkp::msm::{self, MsmConfig, Reduction};
use ifzkp::util::{human_count, human_secs, Stopwatch};

fn demo<C: CurveParams>(label: &str, m: usize) {
    println!("--- {label}, m = {} ---", human_count(m as u64));
    let w = points::workload::<C>(m, 2024);

    // 1. naive double-and-add (Algorithm 1 per point)
    let sw = Stopwatch::start();
    let (naive, naive_ops) =
        ifzkp::ff::opcount::measure(|| msm::naive::msm(&w.points, &w.scalars));
    println!(
        "naive double-and-add: {:>10} modmuls  ({})",
        naive_ops.modmuls(),
        human_secs(sw.secs())
    );

    // 2. bucket method (Algorithm 2), the paper's hardware window k=12
    // (signed-digit buckets by default: half the buckets, half the serial
    // reduce chain)
    let cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 });
    let sw = Stopwatch::start();
    let (bucket, bucket_ops) =
        ifzkp::ff::opcount::measure(|| msm::msm_pippenger(&w.points, &w.scalars, &cfg));
    println!(
        "bucket method (k=12): {:>10} modmuls  ({}) — {:.1}x fewer",
        bucket_ops.modmuls(),
        human_secs(sw.secs()),
        naive_ops.modmuls() as f64 / bucket_ops.modmuls() as f64
    );
    assert!(naive.eq_point(&bucket), "algorithms must agree");

    // 3. multi-threaded
    let threads = msm::parallel::default_threads();
    let sw = Stopwatch::start();
    let par = msm::parallel::msm(&w.points, &w.scalars, &cfg, threads);
    println!("parallel ({threads} threads): {}", human_secs(sw.secs()));
    assert!(par.eq_point(&bucket));
    println!("all MSM variants agree\n");
}

fn main() {
    println!("if-ZKP quickstart — MSM on BN254 & BLS12-381 (Weierstrass, Jacobian)\n");
    demo::<Bn254G1>("BN128 (BN254) G1", 4096);
    demo::<Bls12381G1>("BLS12-381 G1", 4096);

    // 4. the modeled Agilex accelerator (the paper's Table IX machine)
    println!("--- modeled if-ZKP accelerator (BLS12-381, UDA-Standard, S=2) ---");
    let model = SabModel::new(SabConfig::paper(CurveId::Bls12381, 2));
    for m in [10_000u64, 1_000_000, 64_000_000] {
        let t = model.time_msm(m);
        println!(
            "m = {:>4}: {:>8}  ({:.2} M points/s){}",
            human_count(m),
            human_secs(t.total_s()),
            t.m_msm_pps(m),
            if t.stream_bound { "  [DDR-stream bound]" } else { "" }
        );
    }
    println!("\nnext: examples/prover_e2e.rs (full prover), examples/serving.rs (coordinator)");
}
