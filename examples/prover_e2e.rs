//! End-to-end driver: the full system on a real (small) workload.
//!
//! ```bash
//! cargo run --release --example prover_e2e [n_constraints] [--engine]
//! ```
//!
//! Pipeline: synthetic circuit → R1CS witness → QAP (NTT stack) →
//! Groth16-shaped prover whose FOUR G1 MSMs and ONE G2 MSM run through the
//! coordinator (sim-FPGA device + CPU device), with the QAP identity
//! self-check as the correctness seal. With `--engine` (and artifacts
//! built), the A-query MSM is additionally recomputed through the PJRT UDA
//! engine and compared bit-exactly — proving L1/L2/L3 compose.
//!
//! This is the EXPERIMENTS.md §E2E run.

use ifzkp::coordinator::{Coordinator, CoordinatorConfig, DeviceDesc, PointSetRegistry};
use ifzkp::ec::{Bn254G1, Bn254G2};
use ifzkp::ff::params::Bn254FrParams;
use ifzkp::ff::{Field, Fp};
use ifzkp::fpga::{CurveId, SabConfig};
use ifzkp::msm::{self, MsmConfig};
use ifzkp::snark::{circuits, qap, setup::CrsBn254};
use ifzkp::util::rng::Rng;
use ifzkp::util::{human_secs, Stopwatch};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.iter().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4096);
    let use_engine = args.iter().any(|a| a == "--engine");
    println!("=== if-ZKP end-to-end prover run: {n} constraints (BN254) ===\n");

    // 1. circuit + witness
    let sw = Stopwatch::start();
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, 7);
    assert!(cs.is_satisfied(), "witness must satisfy the circuit");
    println!(
        "[1] circuit: {} constraints, {} variables ({})",
        cs.num_constraints(),
        cs.num_variables(),
        human_secs(sw.secs())
    );

    // 2. QAP reduction (NTT stack)
    let sw = Stopwatch::start();
    let (a_ev, b_ev, c_ev) = cs.constraint_evals();
    let qapw = qap::compute_h(&a_ev, &b_ev, &c_ev).expect("within 2-adicity");
    let mut rng = Rng::new(99);
    assert!(
        qap::check_identity(&a_ev, &b_ev, &c_ev, &qapw, &mut rng),
        "QAP identity must hold"
    );
    println!(
        "[2] QAP: domain 2^{}, h degree bound ok, identity verified at a random point ({})",
        qapw.domain.n.trailing_zeros(),
        human_secs(sw.secs())
    );

    // 3. CRS + coordinator with a sim-FPGA and a CPU device
    let sw = Stopwatch::start();
    let crs = CrsBn254::synthesize(cs.num_variables(), qapw.domain.n, 8);
    let mut registry = PointSetRegistry::<Bn254G1>::new();
    let ps_a = registry.register(crs.a_query.clone());
    let ps_b1 = registry.register(crs.b1_query.clone());
    let ps_l = registry.register(crs.l_query.clone());
    let ps_h = registry.register(crs.h_query.clone());
    let devices = vec![
        DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 34),
        DeviceDesc::<Bn254G1>::native(2),
    ];
    let coord = Coordinator::start(CoordinatorConfig::default(), devices, registry);
    println!("[3] coordinator up: 2 devices, 4 point sets resident-on-demand ({})",
        human_secs(sw.secs()));

    // 4. prover MSMs through the coordinator
    let sw = Stopwatch::start();
    let witness_scalars: Arc<Vec<[u64; 4]>> =
        Arc::new(cs.witness.iter().map(|w| w.to_canonical()).collect());
    // h has degree ≤ n−2: its top coefficient is zero and the CRS H-query
    // holds n−1 points, so truncate to the query length.
    let h_scalars: Arc<Vec<[u64; 4]>> = Arc::new(
        qapw.h_coeffs[..crs.h_query.len()].iter().map(Fp::to_canonical).collect(),
    );

    let (_, rx_a) = coord.submit(ps_a, witness_scalars.clone())?;
    let (_, rx_b1) = coord.submit(ps_b1, witness_scalars.clone())?;
    let (_, rx_l) = coord.submit(ps_l, witness_scalars.clone())?;
    let (_, rx_h) = coord.submit(ps_h, h_scalars.clone())?;
    let res_a = rx_a.recv()?;
    let res_b1 = rx_b1.recv()?;
    let res_l = rx_l.recv()?;
    let res_h = rx_h.recv()?;
    // a delivered-but-failed result carries the identity point — refuse to
    // assemble a proof from it
    for (name, res) in
        [("A", &res_a), ("B1", &res_b1), ("L", &res_l), ("H", &res_h)]
    {
        if let Some(err) = &res.error {
            anyhow::bail!("{name} MSM failed on device {}: {err}", res.device);
        }
    }
    println!(
        "[4] 4x G1 MSM served ({}): device times {:.4}/{:.4}/{:.4}/{:.4} s (modeled FPGA)",
        human_secs(sw.secs()),
        res_a.device_s,
        res_b1.device_s,
        res_l.device_s,
        res_h.device_s
    );

    // G2 MSM natively (the paper also keeps G2 off-device — future work)
    let sw = Stopwatch::start();
    let b2 = msm::msm(&crs.b2_query[..cs.num_variables()], &witness_scalars);
    println!("[5] G2 MSM (native, Fp2): {} — proof B component ready", human_secs(sw.secs()));

    // 5. cross-check coordinator results against direct computation
    let direct_a = msm::msm(&crs.a_query[..cs.num_variables()], &witness_scalars);
    assert!(res_a.output.eq_point(&direct_a), "coordinator result mismatch");
    let proof_c = res_l.output.add(&res_h.output);
    println!(
        "[6] proof assembled: A={}.., B={}.., C={}..",
        &format!("{:?}", res_a.output.to_affine())[..24.min(60)],
        &format!("{:?}", b2.to_affine().infinity)[..5],
        &format!("{:?}", proof_c.to_affine())[..24.min(60)]
    );

    // 6. optional: replay the A MSM through the PJRT UDA engine
    if use_engine {
        let dir = ifzkp::runtime::artifact::default_dir();
        if dir.join("manifest.json").exists() && ifzkp::runtime::PjrtContext::available() {
            println!("[7] engine replay: loading AOT artifact + compiling on PJRT…");
            let ctx = ifzkp::runtime::PjrtContext::cpu()?;
            let manifest = ifzkp::runtime::ArtifactManifest::load(&dir)?;
            let sw = Stopwatch::start();
            let engine = ifzkp::runtime::UdaEngine::<Bn254G1>::load(&ctx, &manifest)?;
            println!("    compiled in {}", human_secs(sw.secs()));
            let cfg = MsmConfig::new(8, Default::default());
            let take = 512.min(cs.num_variables());
            let sw = Stopwatch::start();
            let (eng_out, stats) = ifzkp::runtime::msm_engine::msm_engine(
                &engine,
                &crs.a_query[..take],
                &witness_scalars[..take],
                &cfg,
            )?;
            let want = msm::msm_pippenger(&crs.a_query[..take], &witness_scalars[..take], &cfg);
            assert!(eng_out.eq_point(&want), "engine disagrees with native");
            println!(
                "    engine MSM over {take} points: {} — {} ops in {} batches, {:.0}% of point-ops on engine — MATCHES native",
                human_secs(sw.secs()),
                stats.engine_ops,
                stats.engine_batches,
                100.0 * stats.engine_ops as f64 / (stats.engine_ops + stats.native_ops) as f64
            );
        } else {
            println!("[7] engine replay skipped: run `make artifacts` first");
        }
    } else {
        println!("[7] engine replay skipped (pass --engine to enable)");
    }

    let snap = coord.counters.snapshot();
    println!(
        "\ncoordinator stats: {} submitted, {} completed, affinity hit-rate {:.0}%, mean latency {}",
        snap.submitted,
        snap.completed,
        100.0 * snap.hit_rate(),
        human_secs(coord.latency.mean_secs())
    );
    coord.shutdown();
    println!("=== e2e complete: all layers agree ===");
    Ok(())
}
