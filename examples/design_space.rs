//! Design-space exploration over the modeled Agilex accelerator.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```
//!
//! Sweeps the paper's architecture knobs — number form, unified vs PA+PD,
//! scaling S, reduction strategy, IS-RBAM k₂ — and prints resources, fmax,
//! fit, throughput and efficiency for each point, reproducing the §IV
//! design narrative (PAPD-Mont → UDA-Mont → UDA-Standard) as one table.

use ifzkp::fpga::rbam::ReductionKind;
use ifzkp::fpga::{
    device::IA840F, power, CurveId, DesignVariant, NumberForm, ResourceModel, SabConfig, SabModel,
};
use ifzkp::report::ascii_table;

fn main() {
    let rm = ResourceModel;
    let m = 16_000_000u64;

    // ---- 1. the §IV evolution: architecture × number form ----------------
    let mut rows = Vec::new();
    for (curve, bits) in [(CurveId::Bn254, 254u32), (CurveId::Bls12381, 381)] {
        for (unified, form) in [
            (false, NumberForm::Montgomery),
            (true, NumberForm::Montgomery),
            (true, NumberForm::Standard),
        ] {
            let v = DesignVariant { bits, form, unified };
            for s in [1u32, 2] {
                let r = rm.system(v, s);
                let fits = IA840F.fits(&r);
                let cfg = SabConfig {
                    variant: v,
                    reduction: ReductionKind::Recursive { k2: 6 },
                    ..SabConfig::paper(curve, s)
                };
                let t = SabModel::new(cfg).time_msm(m);
                let p = power::estimate(v, s);
                rows.push(vec![
                    format!("{} {}", curve.name(), v.label()),
                    format!("S={s}"),
                    format!("{:.0}k", r.alms / 1e3),
                    format!("{:.0}", r.dsps),
                    format!("{:.0}", r.m20ks),
                    if fits { "yes".into() } else { "NO".into() },
                    format!("{:.2}", t.m_msm_pps(m)),
                    format!("{:.4}", t.m_msm_pps(m) / p.active_w),
                ]);
            }
        }
    }
    println!(
        "{}",
        ascii_table(
            &format!("Design space: architecture x form x scaling (throughput @ {}M points)", m / 1_000_000),
            &["design", "S", "ALM", "DSP", "M20K", "fits?", "M-PPS", "M-PPS/W"],
            &rows,
        )
    );

    // ---- 2. IS-RBAM k2 sweep (the reduction knob) -------------------------
    let mut rows = Vec::new();
    for k2 in 1..=12u32 {
        let cfg = SabConfig {
            reduction: ReductionKind::Recursive { k2 },
            ..SabConfig::paper(CurveId::Bls12381, 2)
        };
        let small = SabModel::new(cfg).time_msm(10_000).total_s();
        let large = SabModel::new(cfg).time_msm(m).total_s();
        rows.push(vec![
            format!("k2={k2}"),
            format!("{:.4}", small),
            format!("{:.3}", large),
        ]);
    }
    {
        let cfg = SabConfig {
            reduction: ReductionKind::RunningSum,
            ..SabConfig::paper(CurveId::Bls12381, 2)
        };
        rows.push(vec![
            "running-sum".into(),
            format!("{:.4}", SabModel::new(cfg).time_msm(10_000).total_s()),
            format!("{:.3}", SabModel::new(cfg).time_msm(m).total_s()),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            "IS-RBAM sub-window sweep (BLS12-381 S=2; seconds per MSM)",
            &["reduction", "t(10K)", "t(16M)"],
            &rows,
        )
    );

    // ---- 2b. signed-digit buckets (the slicing knob) ---------------------
    let mut rows = Vec::new();
    for (label, cfg) in [
        ("unsigned (paper)", SabConfig::paper(CurveId::Bls12381, 2)),
        ("signed", SabConfig::paper_signed(CurveId::Bls12381, 2)),
        (
            "unsigned run-sum",
            SabConfig {
                reduction: ReductionKind::RunningSum,
                ..SabConfig::paper(CurveId::Bls12381, 2)
            },
        ),
        (
            "signed run-sum",
            SabConfig {
                reduction: ReductionKind::RunningSum,
                ..SabConfig::paper_signed(CurveId::Bls12381, 2)
            },
        ),
        ("signed + GLV", SabConfig::paper_glv(CurveId::Bls12381, 2)),
        (
            "signed + GLV run-sum",
            SabConfig {
                reduction: ReductionKind::RunningSum,
                ..SabConfig::paper_glv(CurveId::Bls12381, 2)
            },
        ),
    ] {
        let plan = cfg.plan();
        rows.push(vec![
            label.into(),
            format!("{}", plan.live_buckets()),
            format!("{}", plan.windows),
            format!("{:.4}", SabModel::new(cfg).time_msm(100_000).total_s()),
            format!("{:.3}", SabModel::new(cfg).time_msm(m).total_s()),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            "Signed digits + GLV (BLS12-381 S=2): buckets halve, then window passes halve",
            &["decomposition", "buckets/window", "windows", "t(100K)", "t(16M)"],
            &rows,
        )
    );

    // ---- 3. hypothetical larger device: where does scaling stop? ---------
    let mut rows = Vec::new();
    for s in 1..=4u32 {
        let v = DesignVariant { bits: 381, form: NumberForm::Standard, unified: true };
        let r = rm.system(v, s);
        let cfg = SabConfig { scaling: s, ..SabConfig::paper(CurveId::Bls12381, s) };
        let t = SabModel::new(cfg).time_msm(64_000_000);
        rows.push(vec![
            format!("S={s}"),
            format!("{:.0}%", 100.0 * r.alms / IA840F.alms as f64),
            if IA840F.fits(&r) { "fits".into() } else { "exceeds IA-840f".into() },
            format!("{:.2}", t.m_msm_pps(64_000_000)),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            "Scaling beyond the paper (64M BLS12-381) — the paper's future-work projection",
            &["S", "ALM util", "fit", "M-PPS"],
            &rows,
        )
    );
    println!("max feasible scaling on IA-840f (model): S={}",
        IA840F.max_scaling(&rm, DesignVariant { bits: 381, form: NumberForm::Standard, unified: true }));
}
