//! Serving workload: the coordinator under a proving-farm request mix.
//!
//! ```bash
//! cargo run --release --example serving [jobs] [msm_size]
//! ```
//!
//! Three circuits' point sets compete for two devices (one sim-FPGA, one
//! CPU); a skewed request mix (one hot circuit) exercises affinity routing,
//! batching, the LRU point cache and backpressure. Reports throughput,
//! latency quantiles and hit rates — the serving-side evaluation the paper
//! implies but doesn't publish.

use ifzkp::coordinator::{Coordinator, CoordinatorConfig, DeviceDesc, PointSetRegistry};
use ifzkp::ec::{points, Bn254G1};
use ifzkp::fpga::{CurveId, SabConfig};
use ifzkp::util::rng::Rng;
use ifzkp::util::{human_secs, Stopwatch};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let jobs: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let m: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2048);
    println!("=== if-ZKP serving demo: {jobs} jobs over 3 circuits, m = {m} ===\n");

    // three circuits (point sets), one of them hot
    let mut registry = PointSetRegistry::<Bn254G1>::new();
    let sets: Vec<_> = (0..3)
        .map(|i| registry.register(points::generate_points_walk::<Bn254G1>(m, 100 + i)))
        .collect();

    let devices = vec![
        DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 30),
        DeviceDesc::<Bn254G1>::native(2),
    ];
    let coord = Coordinator::start(CoordinatorConfig::default(), devices, registry);

    // skewed workload: 70% hot set, 20% warm, 10% cold
    let mut rng = Rng::new(42);
    let mut receivers = Vec::new();
    let sw = Stopwatch::start();
    let mut rejected = 0usize;
    for _ in 0..jobs {
        let r = rng.f64();
        let ps = if r < 0.7 {
            sets[0]
        } else if r < 0.9 {
            sets[1]
        } else {
            sets[2]
        };
        let scalars = Arc::new(points::generate_scalars(m, 254, rng.next_u64()));
        match coord.submit(ps, scalars) {
            Ok((_, rx)) => receivers.push(rx),
            Err(_) => {
                rejected += 1; // backpressure: a real client would retry
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
    }
    let mut device_hist = [0usize; 8];
    let mut sum_device_s = 0.0;
    let mut failed = 0usize;
    for rx in receivers {
        let res = rx.recv()?;
        if !res.is_ok() {
            failed += 1; // delivered device failure (distinct from shutdown)
            continue;
        }
        device_hist[res.device.min(7)] += 1;
        sum_device_s += res.device_s;
    }
    if failed > 0 {
        println!("WARNING: {failed} jobs returned device failures");
    }
    let wall = sw.secs();

    let snap = coord.counters.snapshot();
    println!("completed {} / {} submitted ({} rejected by backpressure)", snap.completed, snap.submitted, rejected);
    println!("wall time          : {}", human_secs(wall));
    println!("throughput         : {:.1} MSM jobs/s  ({:.2} M points/s aggregate)",
        snap.completed as f64 / wall,
        snap.completed as f64 * m as f64 / wall / 1e6);
    println!("device split       : fpga={} cpu={}", device_hist[0], device_hist[1]);
    println!("affinity hit rate  : {:.0}%", 100.0 * snap.hit_rate());
    println!("uploaded           : {} MB (point-set DDR moves)", snap.uploads_bytes / 1_000_000);
    println!("latency mean/p50/p99: {} / {} / {}",
        human_secs(coord.latency.mean_secs()),
        human_secs(coord.latency.quantile_secs(0.5)),
        human_secs(coord.latency.quantile_secs(0.99)));
    println!("modeled device-seconds consumed: {}", human_secs(sum_device_s));

    coord.shutdown();
    println!("\n=== serving demo complete ===");
    Ok(())
}
