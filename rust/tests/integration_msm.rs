//! Integration: MSM algorithms against each other and against the paper's
//! accounting, at sizes above the unit-test range.

use ifzkp::ec::{points, scalar, Bls12381G1, Bn254G1, Jacobian};
use ifzkp::ff::Field;
use ifzkp::msm::{self, Backend, MsmConfig, Reduction, Slicing};

#[test]
fn all_algorithms_agree_bn254_2k() {
    let w = points::workload::<Bn254G1>(2048, 9001);
    let naive = msm::naive::msm(&w.points, &w.scalars);
    for k in [8u32, 12, 16] {
        for red in [Reduction::RunningSum, Reduction::Recursive { k2: 6 }] {
            for slicing in [Slicing::Unsigned, Slicing::Signed] {
                let cfg =
                    MsmConfig { window_bits: k, reduction: red, slicing, ..Default::default() };
                let serial = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
                let par = msm::parallel::msm(&w.points, &w.scalars, &cfg, 4);
                assert!(serial.eq_point(&naive), "serial k={k} {red:?} {slicing:?}");
                assert!(par.eq_point(&naive), "parallel k={k} {red:?} {slicing:?}");
            }
        }
    }
}

#[test]
fn backend_dispatch_agrees_at_2k() {
    let w = points::workload::<Bn254G1>(2048, 9010);
    let naive = msm::naive::msm(&w.points, &w.scalars);
    let cfg = MsmConfig::auto(2048);
    for backend in [
        Backend::Pippenger,
        Backend::Parallel { threads: 4 },
        Backend::BatchAffine,
        Backend::BatchAffineParallel { threads: 4 },
        Backend::Chunked { threads: 4 },
        Backend::Chunked { threads: 48 },
    ] {
        let got = msm::execute(backend, &w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&naive), "{backend:?}");
    }
}

#[test]
fn all_algorithms_agree_bls_1k() {
    let w = points::workload::<Bls12381G1>(1024, 9002);
    let naive = msm::naive::msm(&w.points, &w.scalars);
    let got = msm::msm(&w.points, &w.scalars);
    assert!(got.eq_point(&naive));
}

#[test]
fn msm_with_duplicated_points_and_scalars() {
    // duplicates stress the bucket same-point (PD-check) paths
    let base = points::generate_points_walk::<Bn254G1>(16, 9003);
    let mut pts = Vec::new();
    let mut scalars = Vec::new();
    for rep in 0..64 {
        for (i, p) in base.iter().enumerate() {
            pts.push(*p);
            scalars.push([((rep * 16 + i) % 7 + 1) as u64, 0, 0, 0]);
        }
    }
    let naive = msm::naive::msm(&pts, &scalars);
    let fast = msm::msm(&pts, &scalars);
    assert!(fast.eq_point(&naive));
}

#[test]
fn msm_with_adversarial_scalars() {
    // all-zero, one, maximal scalar, single bit at each window edge
    let m = 128;
    let pts = points::generate_points_walk::<Bn254G1>(m, 9004);
    let mut scalars = vec![[0u64; 4]; m];
    scalars[1] = [1, 0, 0, 0];
    scalars[2] = [u64::MAX, u64::MAX, u64::MAX, u64::MAX >> 2]; // 254-bit max
    for (i, s) in scalars.iter_mut().enumerate().skip(3) {
        let bit = (i * 11) % 254;
        s[bit / 64] = 1u64 << (bit % 64);
    }
    let naive = msm::naive::msm(&pts, &scalars);
    for k in [4u32, 12] {
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            let cfg = MsmConfig {
                window_bits: k,
                reduction: Reduction::Recursive { k2: 4 },
                slicing,
                ..Default::default()
            };
            assert!(
                msm::msm_pippenger(&pts, &scalars, &cfg).eq_point(&naive),
                "k={k} {slicing:?}"
            );
            // adversarial scalars through the GLV split as well
            assert!(
                msm::msm_pippenger(&pts, &scalars, &cfg.glv()).eq_point(&naive),
                "glv k={k} {slicing:?}"
            );
        }
    }
}

#[test]
fn msm_linearity_over_point_sets() {
    // MSM(s, P ∪ Q) = MSM(s_P, P) + MSM(s_Q, Q)
    let w1 = points::workload::<Bn254G1>(300, 9005);
    let w2 = points::workload::<Bn254G1>(200, 9006);
    let combined_pts: Vec<_> = w1.points.iter().chain(&w2.points).copied().collect();
    let combined_scalars: Vec<_> = w1.scalars.iter().chain(&w2.scalars).copied().collect();
    let whole = msm::msm(&combined_pts, &combined_scalars);
    let split = msm::msm(&w1.points, &w1.scalars).add(&msm::msm(&w2.points, &w2.scalars));
    assert!(whole.eq_point(&split));
}

#[test]
fn msm_of_generator_multiples_matches_field_sum() {
    // P_i = i·G with scalar s_i ⇒ MSM = (Σ i·s_i)·G — an independent
    // ground truth through scalar-field arithmetic.
    type Fr = ifzkp::ff::FrBn254;
    let g = Jacobian::<Bn254G1>::generator();
    let m = 50u64;
    let mut pts = Vec::new();
    let mut scalars = Vec::new();
    let mut expect = Fr::zero();
    for i in 1..=m {
        pts.push(scalar::mul::<Bn254G1>(&g, &[i, 0, 0, 0]).to_affine());
        let s = 3 * i + 1;
        scalars.push([s, 0, 0, 0]);
        expect = expect.add(&Fr::from_u64(i).mul(&Fr::from_u64(s)));
    }
    let got = msm::msm(&pts, &scalars);
    let want = scalar::mul::<Bn254G1>(&g, &expect.to_canonical());
    assert!(got.eq_point(&want));
}

#[test]
fn glv_dispatch_agrees_at_2k_both_curves() {
    // the end-to-end GLV acceptance at integration size: every backend,
    // GLV on, equals naive — on both curves
    let w = points::workload::<Bn254G1>(2048, 9020);
    let naive = msm::naive::msm(&w.points, &w.scalars);
    let cfg = MsmConfig::auto(2048).glv();
    for backend in [
        Backend::Pippenger,
        Backend::Parallel { threads: 4 },
        Backend::BatchAffine,
        Backend::BatchAffineParallel { threads: 4 },
        Backend::Chunked { threads: 4 },
        Backend::Chunked { threads: 48 },
    ] {
        let got = msm::execute(backend, &w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&naive), "{backend:?}");
    }
    let w = points::workload::<Bls12381G1>(1024, 9021);
    let naive = msm::naive::msm(&w.points, &w.scalars);
    let backend = Backend::BatchAffineParallel { threads: 4 };
    let got = msm::execute(backend, &w.points, &w.scalars, &cfg);
    assert!(got.eq_point(&naive), "bls glv");
}

#[test]
fn glv_sharded_pool_matches_unsharded() {
    // ShardPool (the in-process multi-device executor) under a GLV
    // config: both policies, merged output equal to the plain path
    use ifzkp::coordinator::shard::{ShardPolicy, ShardPool};
    let w = points::workload::<Bn254G1>(600, 9022);
    let cfg = MsmConfig::default().glv();
    let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &MsmConfig::default());
    for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
        let pool = ShardPool::<Bn254G1>::native(3, 1).with_policy(policy);
        let got = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
        assert!(got.eq_point(&want), "{policy:?}");
    }
}

#[test]
fn window_fill_accounting_matches_paper() {
    // Table III: at k=12 the hardware runs 22 (BN) / 32 (BLS) window
    // passes; measured fill ops per point ≈ occupied windows (zero slices
    // skip — scalars are 254/255-bit).
    let m = 512;
    let w = points::workload::<Bn254G1>(m, 9007);
    // unsigned buckets: the Table III accounting the paper publishes
    let cfg = MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 });
    let (_, cost) = msm::pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
    let per_point = cost.fill_ops as f64 / m as f64;
    assert!(
        (20.0..=22.0).contains(&per_point),
        "BN254 fill ops/point {per_point} (expect ≈21.99)"
    );
    assert_eq!(ifzkp::fpga::CurveId::Bn254.hw_windows(), 22);
    assert_eq!(ifzkp::fpga::CurveId::Bls12381.hw_windows(), 32);
}
