//! CI perf smoke: pinned operation-count bounds for the paper configs.
//!
//! Wall-clock is too noisy to gate on shared CI runners, so this gate
//! pins **op counts** instead: the serially dependent point-op chain
//! (`MsmPlan::serial_reduce_ops`) and the measured fill/reduce/combine
//! point ops of real MSM executions are exact and deterministic, so a
//! kernel-layer regression — an extra window pass, a longer running-sum
//! chain, a de-specialized squaring, runaway merge cost in the chunked
//! backend — fails here as count drift long before it would show up as
//! seconds anywhere else. CI runs this with `--release` right after the
//! quick bench.

use ifzkp::ec::{points, Bn254G1};
use ifzkp::ff::params::{Bls12381FpParams, Bn254FpParams, Bn254FrParams};
use ifzkp::ff::{opcount, Field, FpBls12381, FpBn254, FpLanes, FrBn254, LANES};
use ifzkp::msm::{self, pippenger, Backend, MsmConfig, MsmPlan, Reduction};
use ifzkp::ntt::{self, parallel, NttPlan};
use ifzkp::util::rng::Rng;

/// Large enough that every paper window has dense buckets at k ≤ 8 and
/// the fill phase dominates, small enough for the debug-mode tier-1 run.
const M: usize = 1 << 11;
const SEED: u64 = 0x5EED;

#[test]
fn paper_plan_serial_chains_stay_pinned() {
    // model widths (the Table III shapes)
    let unsigned_rs = MsmConfig::unsigned(12, Reduction::RunningSum);
    let p = MsmPlan::new(254, &unsigned_rs);
    assert_eq!(p.windows, 22);
    assert_eq!(p.serial_reduce_ops_per_window(), 2 * 4095);
    assert_eq!(p.serial_reduce_ops(), 2 * 4095 * 22);
    assert_eq!(MsmPlan::new(381, &unsigned_rs).windows, 32);
    // IS-RBAM at the paper's k2 = 6: (12/6) short sums + 12 doublings
    let rbam = MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 });
    assert_eq!(MsmPlan::new(254, &rbam).serial_reduce_ops_per_window(), 2 * 2 * 63 + 12);
    // signed digits halve the running-sum chain at the hardware window
    let signed_rs = MsmConfig::new(12, Reduction::RunningSum);
    assert_eq!(MsmPlan::new(254, &signed_rs).serial_reduce_ops_per_window(), 2 * 2048);
    // the GLV split halves the window passes on the real curve
    let glv = MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv();
    let gp = MsmPlan::for_curve::<Bn254G1>(&glv);
    assert_eq!(gp.windows, 11);
    assert_eq!(gp.serial_reduce_ops(), (2 * 2 * 63 + 12) * 11);
}

#[test]
fn measured_serial_point_ops_within_pinned_bounds() {
    let w = points::workload::<Bn254G1>(M, SEED);
    let mut reference = None;
    for (label, cfg) in [
        ("unsigned run-sum", MsmConfig::unsigned(12, Reduction::RunningSum)),
        ("unsigned IS-RBAM", MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 })),
        ("signed IS-RBAM", MsmConfig::new(12, Reduction::Recursive { k2: 6 })),
        ("glv signed IS-RBAM", MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv()),
    ] {
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let (out, cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
        // all four paper configs answer the same point
        let want = *reference.get_or_insert(out);
        assert!(out.eq_point(&want), "{label}: result drifted");
        // the measured reduce chain can never exceed the plan's bound
        assert!(
            cost.reduce_ops <= plan.serial_reduce_ops(),
            "{label}: reduce ops {} > pinned bound {}",
            cost.reduce_ops,
            plan.serial_reduce_ops()
        );
        // combine: k doublings + 1 add per window, exactly
        let combine_bound = plan.windows as u64 * (plan.window_bits as u64 + 1);
        assert!(
            cost.combine_ops <= combine_bound,
            "{label}: combine ops {} > pinned bound {combine_bound}",
            cost.combine_ops
        );
        // fill issues one op per nonzero digit: ≤ (expanded) m × windows
        let fill_bound = plan.decomposition.expansion_factor() * M as u64 * plan.windows as u64;
        assert!(
            cost.fill_ops <= fill_bound,
            "{label}: fill ops {} > pinned bound {fill_bound}",
            cost.fill_ops
        );
        // and the fill is never degenerate (digits all zero would mean a
        // broken recode, not a fast one)
        assert!(cost.fill_ops > fill_bound / 2, "{label}: fill ops suspiciously low");
    }
}

#[test]
fn table_fed_fill_has_no_doubling_chain_and_pinned_build_cost() {
    // the fixed-base table contract, pinned exactly (satellite of the
    // point-cache PR): the per-window doubling/shift chain moves out of
    // the per-call hot path and into the one-time build.
    // * build: the column shift chain is the ONLY point work —
    //   expanded_m · (windows − 1) · k doublings, zero additions (batch
    //   normalization is field-only);
    // * per-call fill: one batched mixed add per nonzero digit, ZERO
    //   doublings;
    // * per-call combine: a plain (windows − 1)-add chain, ZERO doublings
    //   — the Horner ladder is pre-paid in the tables. (Reduce keeps its
    //   recursive doublings; that phase is unchanged by tables.)
    use ifzkp::ec::counters;
    let w = points::workload::<Bn254G1>(M, SEED);
    for (label, cfg) in [
        ("signed IS-RBAM", MsmConfig::new(12, Reduction::Recursive { k2: 6 })),
        ("glv signed IS-RBAM", MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv()),
    ] {
        let (table, build) =
            counters::measure(|| msm::PrecompTable::<Bn254G1>::build(&w.points, &cfg));
        let plan = table.plan();
        let windows = table.windows() as u64;
        let em = table.expanded_len() as u64;
        // one-time build cost, exact: the shift chain and nothing else
        assert_eq!(
            build.double,
            em * (windows - 1) * plan.window_bits as u64,
            "{label}: build doubling count drifted"
        );
        assert_eq!(build.add + build.mixed, 0, "{label}: build issued point additions");
        // per-call budget: table slot → bucket, no doubles anywhere in
        // fill or combine
        let (out, cost) = table.msm_with_cost(&w.scalars);
        let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        assert!(out.eq_point(&want), "{label}: table-fed result drifted");
        assert_eq!(cost.fill.double, 0, "{label}: fill issued doublings");
        assert_eq!(cost.combine.double, 0, "{label}: combine issued doublings");
        assert_eq!(
            cost.combine.total(),
            windows - 1,
            "{label}: combine is not the plain add chain"
        );
        // fill issues at most one op per nonzero digit of the (endo-
        // expanded, half-width) plan — and is never degenerate
        let budget = plan.decomposition.expansion_factor() * M as u64 * windows;
        assert!(
            cost.issued <= budget,
            "{label}: fill issued {} > budget {budget}",
            cost.issued
        );
        assert!(cost.issued > budget / 2, "{label}: fill suspiciously sparse");
    }
}

#[test]
fn sos_squaring_stays_cheaper_than_mul_and_counted() {
    // word-mul budgets, pinned exactly (the symmetric-cross-term saving)
    assert_eq!(FpBn254::MUL_WORD_MULS, 36);
    assert_eq!(FpBn254::SQUARE_WORD_MULS, 30);
    assert_eq!(FpBls12381::MUL_WORD_MULS, 78);
    assert_eq!(FpBls12381::SQUARE_WORD_MULS, 63);
    assert!(FpBn254::SQUARE_WORD_MULS < FpBn254::MUL_WORD_MULS);
    assert!(FpBls12381::SQUARE_WORD_MULS < FpBls12381::MUL_WORD_MULS);
    // and the dedicated path still feeds the square opcount lane
    let (_, ops) = opcount::measure(|| {
        let mut x = FpBn254::from_u64(3);
        for _ in 0..16 {
            x = x.square();
        }
        x
    });
    assert_eq!(ops.square, 16);
    assert_eq!(ops.mul, 0);
}

#[test]
fn lane_core_word_mul_budgets_stay_pinned() {
    // the 4-lane core must cost exactly four scalar budgets in word
    // muls — a lane carrying hidden normalization or cross-lane work
    // shows up here as a constant drift
    assert_eq!(FpLanes::<Bn254FpParams, 4>::MUL4_WORD_MULS, 4 * FpBn254::MUL_WORD_MULS);
    assert_eq!(FpLanes::<Bn254FpParams, 4>::SQUARE4_WORD_MULS, 4 * FpBn254::SQUARE_WORD_MULS);
    assert_eq!(FpLanes::<Bls12381FpParams, 6>::MUL4_WORD_MULS, 4 * FpBls12381::MUL_WORD_MULS);
    assert_eq!(
        FpLanes::<Bls12381FpParams, 6>::SQUARE4_WORD_MULS,
        4 * FpBls12381::SQUARE_WORD_MULS
    );
    // and the counted-op discipline: one lane op == four scalar ops, on
    // the same counter lanes the NTT/MSM/QAP pins read
    let mut rng = Rng::new(SEED);
    let a: [FpBn254; LANES] = std::array::from_fn(|_| FpBn254::random(&mut rng));
    let b: [FpBn254; LANES] = std::array::from_fn(|_| FpBn254::random(&mut rng));
    let (_, ops) = opcount::measure(|| Field::mul4(&a, &b));
    assert_eq!((ops.mul, ops.square, ops.add), (4, 0, 0), "mul4 op charge drifted");
    let (_, ops) = opcount::measure(|| Field::square4(&a));
    assert_eq!((ops.mul, ops.square, ops.add), (0, 4, 0), "square4 op charge drifted");
    let (_, ops) = opcount::measure(|| {
        Field::add4(&a, &b);
        Field::sub4(&a, &b);
        Field::double4(&a)
    });
    assert_eq!((ops.mul, ops.square, ops.add), (0, 0, 12), "additive op charge drifted");
}

#[test]
fn lane_batch_invert_op_parity_stays_pinned() {
    // the lane-fed inversion batches: the classic 3n muls + 1 inversion,
    // plus exactly 9 bookkeeping muls (3 folding the lane totals, 6
    // peeling the per-lane seeds) once the lane path engages at
    // n ≥ 2·LANES — and bit-identical inverses either way
    let mut rng = Rng::new(SEED ^ 0x1a);
    // the Fermat ladder inside inv() counts its own muls/squares; its
    // cost is exponent-only, so one reference measurement subtracts out
    let probe = FpBn254::random(&mut rng);
    let (_, inv_ops) = opcount::measure(|| probe.inv());
    for n in [3usize, 7, 8, 9, 11, 64, 257] {
        let xs: Vec<FpBn254> = (0..n).map(|_| FpBn254::random(&mut rng)).collect();
        let (invs, ops) = opcount::measure(|| msm::batch_invert(&xs).expect("nonzero inputs"));
        assert_eq!(ops.inv, 1, "n={n}: more than one real inversion");
        assert_eq!(ops.square, inv_ops.square, "n={n}: squares outside the Fermat ladder");
        let overhead = if n < 2 * LANES { 0 } else { 9 };
        assert_eq!(
            ops.mul - inv_ops.mul,
            3 * n as u64 + overhead,
            "n={n}: batch_invert mul overhead drifted"
        );
        for (x, inv) in xs.iter().zip(&invs) {
            assert_eq!(x.inv(), Some(*inv), "n={n}: lane inverse diverged");
        }
    }
}

#[test]
fn ntt_fieldmul_budgets_stay_pinned() {
    // The plan's cached twiddle tables make a transform's mul count
    // *exact*: n/2·log₂ n butterfly muls, plus one n-mul pointwise pass
    // for the inverse scale or the coset shift (never both — the
    // inverse-coset ladder folds n⁻¹ in). threads == 1 runs inline, so
    // the thread-local opcount lane sees every mul — the same convention
    // the chunked-MSM pins rely on.
    let n = 1usize << 10;
    let plan = NttPlan::<Bn254FrParams, 4>::new(n).unwrap();
    let nb = (n as u64 / 2) * 10;
    assert_eq!(plan.mul_budget(false, false), nb);
    assert_eq!(plan.mul_budget(true, false), nb + n as u64);
    assert_eq!(plan.mul_budget(false, true), nb + n as u64);
    assert_eq!(plan.mul_budget(true, true), nb + n as u64);

    let mut rng = Rng::new(0x5EED_17);
    let orig: Vec<FrBn254> = (0..n).map(|_| FrBn254::random(&mut rng)).collect();
    let mut total = opcount::OpCounts::default();

    let mut v = orig.clone();
    let (_, ops) = opcount::measure(|| plan.ntt(&mut v, 1));
    assert_eq!(ops.mul, plan.mul_budget(false, false), "forward muls drifted");
    assert_eq!(ops.square, 0, "butterflies never square");
    total += ops;

    let (_, ops) = opcount::measure(|| plan.intt(&mut v, 1));
    assert_eq!(ops.mul, plan.mul_budget(true, false), "inverse muls drifted");
    assert_eq!(v, orig, "roundtrip broke");
    total += ops;

    let (_, ops) = opcount::measure(|| plan.coset_ntt(&mut v, 1));
    assert_eq!(ops.mul, plan.mul_budget(false, true), "coset forward muls drifted");
    total += ops;
    let (_, ops) = opcount::measure(|| plan.coset_intt(&mut v, 1));
    assert_eq!(ops.mul, plan.mul_budget(true, true), "coset inverse muls drifted");
    assert_eq!(v, orig, "coset roundtrip broke");
    total += ops;

    // the whole 4-transform sequence aggregates exactly: 4 butterflies
    // passes + 3 pointwise passes, zero squares anywhere
    assert_eq!(total.mul, 4 * nb + 3 * n as u64, "sequence total drifted");
    assert_eq!(total.square, 0);

    // the serial reference pays the per-butterfly twiddle walk on top:
    // ≥ 2 muls per butterfly (the cached tables halve the transform)
    let mut w = orig.clone();
    let (_, ref_ops) = opcount::measure(|| ntt::ntt_in_place(&mut w, &plan.omega));
    assert!(
        ref_ops.mul >= 2 * nb,
        "reference lost its twiddle walk? {} vs {}",
        ref_ops.mul,
        2 * nb
    );
}

#[test]
fn four_step_mul_overhead_stays_bounded() {
    // the transpose decomposition covers the same n/2·log n butterflies
    // through its row/column sub-transforms; on top, the on-the-fly
    // twiddle pass (step 3) costs ~2 muls per touched element: the lane
    // ladder spends 2 lane muls (8 counted) per 4-element group — apply
    // plus the stride step w ← w·wj⁴ — with a 1-mul/2-square row setup,
    // for the (n1−1)(n2−1) touched entries, plus O(√n·log n) sub-table
    // and small-pow muls. Bound: budget + 9n/4, well under the 2x budget
    // a per-transform stage-twiddle re-derivation would cost. (At
    // n = 2^10: 5120 butterflies + 1860 twiddle + ~154 table/pow muls
    // ≈ 7134, bound 7424.)
    let n = 1usize << 10;
    let plan = NttPlan::<Bn254FrParams, 4>::new(n).unwrap();
    let mut rng = Rng::new(0x5EED_18);
    let orig: Vec<FrBn254> = (0..n).map(|_| FrBn254::random(&mut rng)).collect();
    let mut want = orig.clone();
    plan.ntt(&mut want, 1);
    let mut v = orig.clone();
    let (_, ops) = opcount::measure(|| parallel::ntt_four_step(&plan, &mut v, 1));
    assert_eq!(v, want);
    let bound = plan.mul_budget(false, false) + 2 * n as u64 + n as u64 / 4;
    assert!(ops.mul <= bound, "four-step muls {} > bound {bound}", ops.mul);
    // and it covers at least the butterfly work — no degenerate shortcut
    assert!(ops.mul >= plan.mul_budget(false, false), "too few muls: {}", ops.mul);
}

#[test]
fn qap_reduction_reuses_one_cached_plan() {
    // compute_h runs 7 transforms of size n; through one cached plan the
    // total stays near 7·(n/2·log n) + 7n. Re-deriving twiddles per
    // transform (the pre-plan behaviour) costs ~2x the butterfly muls
    // and blows this bound. Budget: 7 transforms + plan build (~3n) +
    // pointwise h (2n) + Z⁻¹/ω⁻¹/n⁻¹ inversions and pows (~3k modmuls).
    let cs = ifzkp::snark::circuits::mul_chain::<Bn254FrParams, 4>(600, 0x5EED);
    let (a, b, c) = cs.constraint_evals();
    let n = 1024u64;
    let nb = n / 2 * 10;
    let ((qapw, _phases), ops) = opcount::measure(|| {
        ifzkp::snark::qap::compute_h_with(&a, &b, &c, 1).expect("domain fits")
    });
    assert_eq!(qapw.domain.n as u64, n);
    let bound = 8 * nb + 12 * n + 6_000;
    assert!(
        ops.modmuls() <= bound,
        "QAP reduction modmuls {} > pinned bound {bound} — cached plan not reused?",
        ops.modmuls()
    );
    // and it did real transform work, not a degenerate shortcut
    assert!(ops.modmuls() > 7 * nb, "suspiciously few muls: {}", ops.modmuls());
}

#[test]
fn streaming_budget_high_water_pinned_exactly() {
    // The streaming prover's memory contract, pinned exactly:
    // * per-element bytes are 96 (G1 affine + scalar) and 160 (G2) — the
    //   constants every budget→chunk computation divides by;
    // * a budget that is a common multiple of both admits whole chunks in
    //   both lanes, so the accounted high-water EQUALS the budget;
    // * the fixed lane is exactly (witness + h_coeffs) · 32 bytes — the
    //   scalar vectors the prover keeps resident while points stream;
    // * the budget is an order of magnitude below the resident Θ(m)
    //   working set, and a budget below one element is a typed error.
    use ifzkp::coordinator::request::JobError;
    use ifzkp::ec::{Bn254G2, CurveParams};
    use ifzkp::snark::{circuits, prove_streaming, qap, ProverConfig, StreamingSrs};
    use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
    let per_g1 = Bn254G1::AFFINE_BYTES + SCALAR_BYTES;
    let per_g2 = Bn254G2::AFFINE_BYTES + SCALAR_BYTES;
    assert_eq!(per_g1, 96, "G1 streamed element size drifted");
    assert_eq!(per_g2, 160, "G2 streamed element size drifted");
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(900, SEED);
    let dn = cs.num_constraints().max(2).next_power_of_two();
    let nv = cs.num_variables();
    let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, dn, 3);
    // lcm(96, 160) = 480: both lanes fill whole chunks with zero slack
    let budget_bytes = 480 * 8;
    assert!(nv >= budget_bytes as usize / 96, "circuit too small for a full-chunk pin");
    let budget = MemoryBudget::bytes(budget_bytes);
    let (_, report) =
        prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert_eq!(report.chunk_points_g1, 40, "budget→G1 chunk sizing drifted");
    assert_eq!(report.chunk_points_g2, 24, "budget→G2 chunk sizing drifted");
    // the accounted high-water is the budget, exactly — never above
    assert_eq!(report.peak_chunk_bytes, budget_bytes, "high-water != budget");
    // fixed lane: the resident scalar vectors, exactly
    let (a, b, c) = cs.constraint_evals();
    let (qapw, _) = qap::compute_h_with(&a, &b, &c, 1).expect("domain fits");
    let want_fixed = (cs.witness.len() + qapw.h_coeffs.len()) as u64 * SCALAR_BYTES;
    assert_eq!(report.fixed_bytes, want_fixed, "fixed-lane accounting drifted");
    // streaming runs where the resident prover is Θ(m): the G1 queries
    // alone are an order of magnitude above the whole chunk budget
    assert!(
        nv as u64 * per_g1 >= 8 * budget_bytes,
        "test lost its point: resident set {} vs budget {budget_bytes}",
        nv as u64 * per_g1
    );
    // a budget below one element is refused with a typed error, up front
    let err = prove_streaming(&cs, &srs, MemoryBudget::bytes(per_g2 - 1), &Default::default())
        .expect_err("sub-element budget must be refused");
    assert!(matches!(err, JobError::StreamFailed(_)), "{err:?}");
    assert!(err.to_string().contains("budget"), "{err}");
}

#[test]
fn chunked_backend_modmul_overhead_stays_bounded() {
    // Single-thread chunked runs inline, so the thread-local counters see
    // every op. The fused all-window batch-affine fill must not cost more
    // modmuls than the window-by-window batch-affine backend (bigger
    // inversion batches can only help), modulo round-boundary noise.
    let w = points::workload::<Bn254G1>(M, SEED);
    let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 4 });
    let (want, base) =
        opcount::measure(|| msm::execute(Backend::BatchAffine, &w.points, &w.scalars, &cfg));
    let chunked = Backend::Chunked { threads: 1 };
    let (got, chunk) =
        opcount::measure(|| msm::execute(chunked, &w.points, &w.scalars, &cfg));
    assert!(got.eq_point(&want));
    assert!(
        (chunk.modmuls() as f64) < 1.05 * base.modmuls() as f64,
        "chunked(1) modmuls {} vs batch-affine {}",
        chunk.modmuls(),
        base.modmuls()
    );
    // multi-thread runs stay bit-identical (op totals live on the worker
    // threads, so only the result is asserted here)
    for threads in [4usize, 16] {
        let got = msm::execute(Backend::Chunked { threads }, &w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&want), "threads={threads}");
    }
}
