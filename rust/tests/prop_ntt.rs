//! Property matrix for the parallel NTT runtime: every executor
//! (stage-parallel radix-2, four-step transpose, coset variants) ×
//! thread counts {1, 2, 4, 32} × sizes × both scalar fields, held
//! bit-identical against the serial reference (`ntt_in_place` /
//! `intt_in_place` and the pre-plan serial coset walk). Field arithmetic
//! is exact, so "bit-identical" is literal: `Vec<Fp>` equality on the
//! canonical Montgomery limbs.

use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
use ifzkp::ff::{Field, FieldParams, Fp};
use ifzkp::ntt::{self, parallel, NttPlan};
use ifzkp::util::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 32];
const SIZES: [usize; 6] = [2, 8, 64, 512, 1024, 4096];

fn rand_vec<P: FieldParams<4>>(n: usize, seed: u64) -> Vec<Fp<P, 4>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| Fp::random(&mut rng)).collect()
}

/// The pre-plan coset reference: serial gⁱ walk, then the serial NTT.
fn coset_ntt_reference<P: FieldParams<4>>(plan: &NttPlan<P, 4>, values: &mut [Fp<P, 4>]) {
    let mut scale = Fp::<P, 4>::one();
    for v in values.iter_mut() {
        *v = v.mul(&scale);
        scale = scale.mul(&plan.coset_gen);
    }
    ntt::ntt_in_place(values, &plan.omega);
}

fn forward_matrix<P: FieldParams<4>>(seed: u64) {
    for n in SIZES {
        let plan = NttPlan::<P, 4>::new(n).unwrap();
        let orig = rand_vec::<P>(n, seed + n as u64);
        let mut want = orig.clone();
        ntt::ntt_in_place(&mut want, &plan.omega);
        for threads in THREADS {
            let mut got = orig.clone();
            plan.ntt(&mut got, threads);
            assert_eq!(got, want, "ntt n={n} threads={threads}");
            plan.intt(&mut got, threads);
            assert_eq!(got, orig, "roundtrip n={n} threads={threads}");
        }
    }
}

fn inverse_matrix<P: FieldParams<4>>(seed: u64) {
    for n in SIZES {
        let plan = NttPlan::<P, 4>::new(n).unwrap();
        let orig = rand_vec::<P>(n, seed + n as u64);
        let mut want = orig.clone();
        ntt::intt_in_place(&mut want, &plan.omega);
        for threads in THREADS {
            let mut got = orig.clone();
            plan.intt(&mut got, threads);
            assert_eq!(got, want, "intt n={n} threads={threads}");
        }
    }
}

fn coset_matrix<P: FieldParams<4>>(seed: u64) {
    for n in SIZES {
        let plan = NttPlan::<P, 4>::new(n).unwrap();
        let orig = rand_vec::<P>(n, seed + n as u64);
        let mut want = orig.clone();
        coset_ntt_reference(&plan, &mut want);
        for threads in THREADS {
            let mut got = orig.clone();
            plan.coset_ntt(&mut got, threads);
            assert_eq!(got, want, "coset ntt n={n} threads={threads}");
            plan.coset_intt(&mut got, threads);
            assert_eq!(got, orig, "coset roundtrip n={n} threads={threads}");
        }
    }
}

#[test]
fn bn254_forward_matrix_matches_serial_reference() {
    forward_matrix::<Bn254FrParams>(0x1001);
}

#[test]
fn bn254_inverse_matrix_matches_serial_reference() {
    inverse_matrix::<Bn254FrParams>(0x1002);
}

#[test]
fn bn254_coset_matrix_matches_pre_plan_reference() {
    coset_matrix::<Bn254FrParams>(0x1003);
}

#[test]
fn bls12381_fr_matrix_matches_serial_reference() {
    forward_matrix::<Bls12381FrParams>(0x2001);
    inverse_matrix::<Bls12381FrParams>(0x2002);
    coset_matrix::<Bls12381FrParams>(0x2003);
}

#[test]
fn four_step_matches_reference_at_every_shape() {
    // the forced four-step path (the auto executor only takes it at
    // n ≥ FOUR_STEP_MIN): square and rectangular n1×n2 splits, odd and
    // even log n, both directions
    for n in [4usize, 16, 32, 256, 2048, 4096] {
        let plan = NttPlan::<Bn254FrParams, 4>::new(n).unwrap();
        let orig = rand_vec::<Bn254FrParams>(n, 0x3000 + n as u64);
        let mut want = orig.clone();
        ntt::ntt_in_place(&mut want, &plan.omega);
        for threads in THREADS {
            let mut got = orig.clone();
            parallel::ntt_four_step(&plan, &mut got, threads);
            assert_eq!(got, want, "four-step n={n} threads={threads}");
            parallel::intt_four_step(&plan, &mut got, threads);
            assert_eq!(got, orig, "four-step inverse n={n} threads={threads}");
        }
    }
}

#[test]
fn stage_parallel_and_four_step_agree_with_each_other() {
    // the two parallel schedules are interchangeable executors of the
    // same plan — outputs identical, not just "both correct"
    let n = 1 << 12;
    let plan = NttPlan::<Bn254FrParams, 4>::new(n).unwrap();
    let orig = rand_vec::<Bn254FrParams>(n, 0x4001);
    let mut a = orig.clone();
    parallel::ntt_stage_parallel(&plan, &mut a, 8);
    let mut b = orig.clone();
    parallel::ntt_four_step(&plan, &mut b, 8);
    assert_eq!(a, b);
}

#[test]
fn convolution_through_the_parallel_runtime() {
    // the NTT's defining property survives the parallel path: pointwise
    // products on the transform side are polynomial products
    let mut rng = Rng::new(0x5001);
    let (da, db) = (25usize, 40usize);
    let a: Vec<Fp<Bn254FrParams, 4>> = (0..da).map(|_| Fp::random(&mut rng)).collect();
    let b: Vec<Fp<Bn254FrParams, 4>> = (0..db).map(|_| Fp::random(&mut rng)).collect();
    let want = ntt::poly_mul_schoolbook(&a, &b);
    let n = (da + db - 1).next_power_of_two();
    let plan = NttPlan::<Bn254FrParams, 4>::new(n).unwrap();
    let mut fa = a.clone();
    fa.resize(n, Fp::zero());
    let mut fb = b.clone();
    fb.resize(n, Fp::zero());
    plan.ntt(&mut fa, 4);
    plan.ntt(&mut fb, 4);
    for (x, y) in fa.iter_mut().zip(&fb) {
        *x = x.mul(y);
    }
    plan.intt(&mut fa, 4);
    assert_eq!(&fa[..want.len()], &want[..]);
    assert!(fa[want.len()..].iter().all(|x| x.is_zero()));
}
