//! Property tests: field axioms, backend agreement, encoding roundtrips.

use ifzkp::ff::{barrett, bigint, limbs16, Field, Fp2Bn254, FpBls12381, FpBn254, FrBls12381};
use ifzkp::util::prop::{check, check_with, Config};
use ifzkp::{prop_assert, prop_assert_eq};

fn axioms<F: Field>(name: &'static str) {
    check(&format!("{name}: ring axioms"), |rng| {
        let a = F::random(rng);
        let b = F::random(rng);
        let c = F::random(rng);
        prop_assert_eq!(a.add(&b), b.add(&a));
        prop_assert_eq!(a.mul(&b), b.mul(&a));
        prop_assert_eq!(a.add(&b).add(&c), a.add(&b.add(&c)));
        prop_assert_eq!(a.mul(&b).mul(&c), a.mul(&b.mul(&c)));
        prop_assert_eq!(a.mul(&b.add(&c)), a.mul(&b).add(&a.mul(&c)));
        prop_assert_eq!(a.add(&F::zero()), a);
        prop_assert_eq!(a.mul(&F::one()), a);
        prop_assert_eq!(a.mul(&F::zero()), F::zero());
        prop_assert_eq!(a.sub(&a), F::zero());
        prop_assert_eq!(a.square(), a.mul(&a));
        prop_assert_eq!(a.neg().neg(), a);
        Ok(())
    });
    check(&format!("{name}: inverses"), |rng| {
        let a = F::random(rng);
        if !a.is_zero() {
            let inv = a.inv().ok_or("inverse must exist")?;
            prop_assert_eq!(a.mul(&inv), F::one());
        }
        Ok(())
    });
    check(&format!("{name}: pow laws"), |rng| {
        let a = F::random(rng);
        let e1 = rng.below(1 << 20);
        let e2 = rng.below(1 << 20);
        prop_assert_eq!(a.pow_u64(e1).mul(&a.pow_u64(e2)), a.pow_u64(e1 + e2));
        Ok(())
    });
}

#[test]
fn fp_bn254_axioms() {
    axioms::<FpBn254>("FpBn254");
}

#[test]
fn fp_bls_axioms() {
    axioms::<FpBls12381>("FpBls12381");
}

#[test]
fn fr_bls_axioms() {
    axioms::<FrBls12381>("FrBls12381");
}

#[test]
fn fp2_axioms() {
    axioms::<Fp2Bn254>("Fp2Bn254");
}

#[test]
fn montgomery_and_barrett_backends_agree() {
    check("mont == barrett (bn254 + bls)", |rng| {
        let a = FpBn254::random(rng);
        let b = FpBn254::random(rng);
        let mut want = a.mul(&b).to_canonical().to_vec();
        bigint::normalize(&mut want);
        let got = barrett::BN254_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
        prop_assert_eq!(got, want);

        let a = FpBls12381::random(rng);
        let b = FpBls12381::random(rng);
        let mut want = a.mul(&b).to_canonical().to_vec();
        bigint::normalize(&mut want);
        let got = barrett::BLS12_381_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
        prop_assert_eq!(got, want);
        Ok(())
    });
}

#[test]
fn limb16_roundtrip_prop() {
    check("u64 <-> u16 limbs roundtrip", |rng| {
        let n = 1 + rng.below(8) as usize;
        let limbs = rng.words(n);
        let u16s = limbs16::u64_to_u16_limbs(&limbs);
        prop_assert_eq!(limbs16::u16_limbs_to_u64(&u16s)?, limbs);
        Ok(())
    });
}

#[test]
fn canonical_roundtrip_prop() {
    check_with(Config { cases: 128, seed: 7 }, "to/from canonical", |rng| {
        let a = FpBls12381::random(rng);
        let c = a.to_canonical();
        let back = FpBls12381::from_canonical(c).ok_or("canonical must be < p")?;
        prop_assert_eq!(back, a);
        // hex roundtrip too
        prop_assert_eq!(FpBls12381::from_hex(&a.to_hex())?, a);
        Ok(())
    });
}

#[test]
fn sqrt_of_square_roundtrips_prop() {
    check_with(Config { cases: 16, seed: 8 }, "sqrt(a^2) = +-a", |rng| {
        let a = FpBn254::random(rng);
        let sq = a.square();
        let r = ifzkp::ff::sqrt::sqrt(&sq).ok_or("square must have root")?;
        prop_assert!(r == a || r == a.neg(), "root mismatch");
        Ok(())
    });
}

#[test]
fn frobenius_fixes_base_field_prop() {
    // a^p = a for a ∈ Fp (Frobenius is identity on the prime field) —
    // exercises pow_limbs against the modulus itself.
    use ifzkp::ff::fp::FieldParams;
    check_with(Config { cases: 8, seed: 9 }, "frobenius", |rng| {
        let a = FpBn254::random(rng);
        let p = ifzkp::ff::params::Bn254FpParams::MODULUS;
        prop_assert_eq!(a.pow_limbs(&p), a);
        Ok(())
    });
}
