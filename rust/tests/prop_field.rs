//! Property tests: field axioms, backend agreement, encoding roundtrips,
//! and the 4-lane vectorized core against the scalar reference.

use ifzkp::ff::fp::FieldParams;
use ifzkp::ff::{barrett, bigint, limbs16, Field, Fp, Fp2Bn254, FpBls12381, FpBn254, FrBls12381};
use ifzkp::ff::{FpLanes, LANES};
use ifzkp::util::prop::{check, check_with, Config};
use ifzkp::util::rng::Rng;
use ifzkp::{prop_assert, prop_assert_eq};

fn axioms<F: Field>(name: &'static str) {
    check(&format!("{name}: ring axioms"), |rng| {
        let a = F::random(rng);
        let b = F::random(rng);
        let c = F::random(rng);
        prop_assert_eq!(a.add(&b), b.add(&a));
        prop_assert_eq!(a.mul(&b), b.mul(&a));
        prop_assert_eq!(a.add(&b).add(&c), a.add(&b.add(&c)));
        prop_assert_eq!(a.mul(&b).mul(&c), a.mul(&b.mul(&c)));
        prop_assert_eq!(a.mul(&b.add(&c)), a.mul(&b).add(&a.mul(&c)));
        prop_assert_eq!(a.add(&F::zero()), a);
        prop_assert_eq!(a.mul(&F::one()), a);
        prop_assert_eq!(a.mul(&F::zero()), F::zero());
        prop_assert_eq!(a.sub(&a), F::zero());
        prop_assert_eq!(a.square(), a.mul(&a));
        prop_assert_eq!(a.neg().neg(), a);
        Ok(())
    });
    check(&format!("{name}: inverses"), |rng| {
        let a = F::random(rng);
        if !a.is_zero() {
            let inv = a.inv().ok_or("inverse must exist")?;
            prop_assert_eq!(a.mul(&inv), F::one());
        }
        Ok(())
    });
    check(&format!("{name}: pow laws"), |rng| {
        let a = F::random(rng);
        let e1 = rng.below(1 << 20);
        let e2 = rng.below(1 << 20);
        prop_assert_eq!(a.pow_u64(e1).mul(&a.pow_u64(e2)), a.pow_u64(e1 + e2));
        Ok(())
    });
}

#[test]
fn fp_bn254_axioms() {
    axioms::<FpBn254>("FpBn254");
}

#[test]
fn fp_bls_axioms() {
    axioms::<FpBls12381>("FpBls12381");
}

#[test]
fn fr_bls_axioms() {
    axioms::<FrBls12381>("FrBls12381");
}

#[test]
fn fp2_axioms() {
    axioms::<Fp2Bn254>("Fp2Bn254");
}

#[test]
fn montgomery_and_barrett_backends_agree() {
    check("mont == barrett (bn254 + bls)", |rng| {
        let a = FpBn254::random(rng);
        let b = FpBn254::random(rng);
        let mut want = a.mul(&b).to_canonical().to_vec();
        bigint::normalize(&mut want);
        let got = barrett::BN254_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
        prop_assert_eq!(got, want);

        let a = FpBls12381::random(rng);
        let b = FpBls12381::random(rng);
        let mut want = a.mul(&b).to_canonical().to_vec();
        bigint::normalize(&mut want);
        let got = barrett::BLS12_381_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
        prop_assert_eq!(got, want);
        Ok(())
    });
}

#[test]
fn limb16_roundtrip_prop() {
    check("u64 <-> u16 limbs roundtrip", |rng| {
        let n = 1 + rng.below(8) as usize;
        let limbs = rng.words(n);
        let u16s = limbs16::u64_to_u16_limbs(&limbs);
        prop_assert_eq!(limbs16::u16_limbs_to_u64(&u16s)?, limbs);
        Ok(())
    });
}

#[test]
fn canonical_roundtrip_prop() {
    check_with(Config { cases: 128, seed: 7 }, "to/from canonical", |rng| {
        let a = FpBls12381::random(rng);
        let c = a.to_canonical();
        let back = FpBls12381::from_canonical(c).ok_or("canonical must be < p")?;
        prop_assert_eq!(back, a);
        // hex roundtrip too
        prop_assert_eq!(FpBls12381::from_hex(&a.to_hex())?, a);
        Ok(())
    });
}

#[test]
fn sqrt_of_square_roundtrips_prop() {
    check_with(Config { cases: 16, seed: 8 }, "sqrt(a^2) = +-a", |rng| {
        let a = FpBn254::random(rng);
        let sq = a.square();
        let r = ifzkp::ff::sqrt::sqrt(&sq).ok_or("square must have root")?;
        prop_assert!(r == a || r == a.neg(), "root mismatch");
        Ok(())
    });
}

/// Lane-sensitive edge values: 0, 1, p−1 (largest canonical residue) and
/// R−1 (one below the Montgomery radix residue — every limb of its
/// representation is in play).
fn lane_edges<P: FieldParams<N>, const N: usize>() -> [Fp<P, N>; 4] {
    let one = Fp::<P, N>::one();
    let r = Fp::<P, N>::from_u64(2).pow_u64(64 * N as u64);
    [Fp::<P, N>::zero(), one, one.neg(), r.sub(&one)]
}

/// The full lane matrix for one field: every 4-lane op against four
/// independent scalar ops, lanes drawn from edge values and random
/// elements alike, plus the trait-level hooks the consumers call.
fn lane_matrix<P: FieldParams<N>, const N: usize>(name: &str) {
    check(&format!("{name}: 4-lane ops == scalar ops"), |rng| {
        let edges = lane_edges::<P, N>();
        let mut draw = |rng: &mut Rng| {
            let k = rng.below(8) as usize;
            if k < edges.len() {
                edges[k]
            } else {
                Fp::<P, N>::random(rng)
            }
        };
        let a: [Fp<P, N>; LANES] = std::array::from_fn(|_| draw(rng));
        let b: [Fp<P, N>; LANES] = std::array::from_fn(|_| draw(rng));
        let la = FpLanes::from_elems(&a);
        let lb = FpLanes::from_elems(&b);
        let want_mul: [Fp<P, N>; LANES] = std::array::from_fn(|l| a[l].mul(&b[l]));
        let want_sqr: [Fp<P, N>; LANES] = std::array::from_fn(|l| a[l].square());
        let want_add: [Fp<P, N>; LANES] = std::array::from_fn(|l| a[l].add(&b[l]));
        let want_sub: [Fp<P, N>; LANES] = std::array::from_fn(|l| a[l].sub(&b[l]));
        let want_dbl: [Fp<P, N>; LANES] = std::array::from_fn(|l| a[l].double());
        prop_assert_eq!(la.mul4(&lb).to_elems(), want_mul);
        prop_assert_eq!(la.square4().to_elems(), want_sqr);
        prop_assert_eq!(la.add4(&lb).to_elems(), want_add);
        prop_assert_eq!(la.sub4(&lb).to_elems(), want_sub);
        prop_assert_eq!(la.double4().to_elems(), want_dbl);
        // the trait hooks the NTT/MSM/QAP consumers actually call
        prop_assert_eq!(Field::mul4(&a, &b), want_mul);
        prop_assert_eq!(Field::square4(&a), want_sqr);
        prop_assert_eq!(Field::add4(&a, &b), want_add);
        prop_assert_eq!(Field::sub4(&a, &b), want_sub);
        prop_assert_eq!(Field::double4(&a), want_dbl);
        Ok(())
    });
    check(&format!("{name}: interleave roundtrips"), |rng| {
        let xs: [Fp<P, N>; LANES] = std::array::from_fn(|_| Fp::<P, N>::random(rng));
        prop_assert_eq!(FpLanes::from_elems(&xs).to_elems(), xs);
        let mut out = [Fp::<P, N>::zero(); LANES];
        FpLanes::load(&xs).store(&mut out);
        prop_assert_eq!(out, xs);
        let k = Fp::<P, N>::random(rng);
        prop_assert_eq!(FpLanes::splat(&k).to_elems(), [k; LANES]);
        Ok(())
    });
    // ragged tails 1–3 past the lane groups, through the public
    // lane-fed batch inversion (8 = 2·LANES is the lane threshold)
    let cfg = Config { cases: 16, seed: 21 };
    check_with(cfg, &format!("{name}: batch_invert ragged tails"), |rng| {
        for len in [8usize, 9, 10, 11] {
            let xs: Vec<Fp<P, N>> = (0..len)
                .map(|_| loop {
                    let x = Fp::<P, N>::random(rng);
                    if !x.is_zero() {
                        break x;
                    }
                })
                .collect();
            let invs = ifzkp::msm::batch_invert(&xs).map_err(|e| e.to_string())?;
            for (x, inv) in xs.iter().zip(&invs) {
                prop_assert_eq!(Some(*inv), x.inv());
            }
        }
        Ok(())
    });
}

#[test]
fn lanes_match_scalar_fp_bn254() {
    lane_matrix::<ifzkp::ff::params::Bn254FpParams, 4>("FpBn254");
}

#[test]
fn lanes_match_scalar_fr_bn254() {
    lane_matrix::<ifzkp::ff::params::Bn254FrParams, 4>("FrBn254");
}

#[test]
fn lanes_match_scalar_fp_bls12381() {
    lane_matrix::<ifzkp::ff::params::Bls12381FpParams, 6>("FpBls12381");
}

#[test]
fn lanes_match_scalar_fr_bls12381() {
    lane_matrix::<ifzkp::ff::params::Bls12381FrParams, 4>("FrBls12381");
}

#[test]
fn frobenius_fixes_base_field_prop() {
    // a^p = a for a ∈ Fp (Frobenius is identity on the prime field) —
    // exercises pow_limbs against the modulus itself.
    use ifzkp::ff::fp::FieldParams;
    check_with(Config { cases: 8, seed: 9 }, "frobenius", |rng| {
        let a = FpBn254::random(rng);
        let p = ifzkp::ff::params::Bn254FpParams::MODULUS;
        prop_assert_eq!(a.pow_limbs(&p), a);
        Ok(())
    });
}
