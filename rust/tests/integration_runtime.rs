//! Integration: AOT artifacts → PJRT engine → bit-exact agreement with the
//! native elliptic-curve path. This is the cross-language correctness seal:
//! the python/int oracle validated the kernels, the rust tests validated
//! the native path, and this file proves the compiled artifact and the
//! native path agree on the same inputs.
//!
//! Requires `make artifacts` (skips with a notice when absent, so plain
//! `cargo test` works in a fresh checkout).

use ifzkp::ec::{points, Affine, Bls12381G1, Bn254G1, Jacobian};
use ifzkp::msm::{self, MsmConfig, Reduction};
use ifzkp::runtime::{msm_engine, ArtifactManifest, EngineCurve, PjrtContext, UdaEngine};
use ifzkp::util::rng::Rng;

fn manifest_or_skip() -> Option<(PjrtContext, ArtifactManifest)> {
    if !PjrtContext::available() {
        eprintln!("SKIP: PJRT backend is the offline xla stub");
        return None;
    }
    let dir = ifzkp::runtime::artifact::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let ctx = PjrtContext::cpu().expect("pjrt client");
    let m = ArtifactManifest::load(&dir).expect("manifest");
    Some((ctx, m))
}

/// XLA compilation of a UDA artifact takes minutes (the "bitstream load" of
/// this reproduction — see EXPERIMENTS.md §Perf/L2). One bn254 smoke test
/// stays unconditional; the wider engine matrix runs with
/// `IFZKP_ENGINE_TESTS=1 cargo test`.
fn engine_matrix_enabled() -> bool {
    if std::env::var("IFZKP_ENGINE_TESTS").is_ok() {
        return true;
    }
    eprintln!("SKIP: set IFZKP_ENGINE_TESTS=1 for the full engine matrix (minutes of XLA compile per artifact)");
    false
}

fn engine_matches_native<C: EngineCurve>(ctx: &PjrtContext, m: &ArtifactManifest, seed: u64) {
    let engine = UdaEngine::<C>::load(ctx, m).expect("engine loads");
    let b = engine.batch();
    let pts = points::generate_points_walk::<C>(2 * b, seed);

    // generic adds: random pairs
    let pairs: Vec<(Jacobian<C>, Jacobian<C>)> = (0..b)
        .map(|i| (pts[i].to_jacobian(), pts[i + b].to_jacobian()))
        .collect();
    let out = engine.uda_batch(&pairs).expect("engine executes");
    for (i, ((p, q), r)) in pairs.iter().zip(&out).enumerate() {
        let want = p.add(q);
        assert!(r.eq_point(&want), "lane {i}: engine add != native add");
        assert!(r.is_on_curve());
    }

    // UDA semantics lanes: double, cancellation, identities — all in one batch
    let p = pts[0].to_jacobian();
    let special = vec![
        (p, p),                                  // -> 2P (PD check)
        (p, p.neg()),                            // -> O
        (Jacobian::<C>::infinity(), p),          // -> P
        (p, Jacobian::<C>::infinity()),          // -> P
        (Jacobian::<C>::infinity(), Jacobian::<C>::infinity()), // -> O
    ];
    let out = engine.uda_batch(&special).expect("special lanes execute");
    assert!(out[0].eq_point(&p.double()), "PD lane");
    assert!(out[1].is_infinity(), "cancellation lane");
    assert!(out[2].eq_point(&p), "left identity");
    assert!(out[3].eq_point(&p), "right identity");
    assert!(out[4].is_infinity(), "O + O");
}

/// One artifact compile (bn254), then the full per-lane semantics + MSM +
/// error-path checks against that engine. Gated: XLA compiles the 2 MB UDA
/// module for ≈10–15 minutes on this CPU (the reproduction's "bitstream
/// load"); the recorded run lives in EXPERIMENTS.md §E2E. The same
/// numerics are oracle-checked per commit by the fast pytest suite.
#[test]
fn engine_bn254_smoke_suite() {
    if !engine_matrix_enabled() {
        return;
    }
    let Some((ctx, m)) = manifest_or_skip() else { return };
    engine_matches_native::<Bn254G1>(&ctx, &m, 1001);

    // (reuse would be ideal, but engine_matches_native owns its engine;
    // compile once more here and run the remaining checks against it)
    let engine = UdaEngine::<Bn254G1>::load(&ctx, &m).expect("engine");

    // --- MSM through the engine ------------------------------------------
    let w = points::workload::<Bn254G1>(300, 1003);
    let cfg = MsmConfig::new(8, Reduction::default());
    let (got, stats) =
        msm_engine::msm_engine(&engine, &w.points, &w.scalars, &cfg).expect("engine msm");
    let want = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
    assert!(got.eq_point(&want), "engine MSM != native MSM");
    assert!(stats.engine_ops > 0 && stats.engine_batches > 0);
    let frac = stats.engine_ops as f64 / (stats.engine_ops + stats.native_ops) as f64;
    eprintln!(
        "engine ops {} native {} occupancy {:.2} ({} batches) engine share {:.1}%",
        stats.engine_ops,
        stats.native_ops,
        stats.mean_occupancy,
        stats.engine_batches,
        100.0 * frac
    );
    assert!(frac > 0.85, "engine should carry ≥85% of point-ops (paper: ≥90%)");

    // --- error paths -------------------------------------------------------
    let p = Jacobian::<Bn254G1>::generator();
    let too_many = vec![(p, p); engine.batch() + 1];
    assert!(engine.uda_batch(&too_many).is_err());
    assert!(engine.uda_batch(&[]).is_err());

    // --- determinism --------------------------------------------------------
    let mut rng = Rng::new(1005);
    let k = rng.range(2, 1 << 20);
    let p = ifzkp::ec::scalar::mul::<Bn254G1>(&Jacobian::generator(), &[k, 0, 0, 0]);
    let q = Jacobian::<Bn254G1>::generator();
    let a = engine.uda_batch(&[(p, q)]).unwrap();
    let b = engine.uda_batch(&[(p, q)]).unwrap();
    assert_eq!(a[0].x, b[0].x);
    assert_eq!(a[0].y, b[0].y);
    assert_eq!(a[0].z, b[0].z);
}

/// Gated: the BLS12-381 engine (a second multi-minute XLA compile).
#[test]
fn engine_bls12_381_matches_native() {
    if !engine_matrix_enabled() {
        return;
    }
    let Some((ctx, m)) = manifest_or_skip() else { return };
    engine_matches_native::<Bls12381G1>(&ctx, &m, 1002);

    // partial-batch padding on the same compiled engine
    let engine = UdaEngine::<Bls12381G1>::load(&ctx, &m).expect("engine");
    let pts = points::generate_points_walk::<Bls12381G1>(6, 1004);
    let pairs: Vec<_> =
        (0..3).map(|i| (pts[i].to_jacobian(), pts[i + 3].to_jacobian())).collect();
    let out = engine.uda_batch(&pairs).expect("partial batch");
    assert_eq!(out.len(), 3);
    for ((p, q), r) in pairs.iter().zip(&out) {
        assert!(r.eq_point(&p.add(q)));
    }
}

#[test]
fn affine_roundtrip_through_engine_packing() {
    // Pack→unpack identity for coordinates (no engine needed, but placed
    // here as it exercises the EngineCurve impls).
    let pts = points::generate_points_walk::<Bls12381G1>(4, 1006);
    for p in &pts {
        let mut buf = Vec::new();
        Bls12381G1::pack_coord(&p.x, &mut buf);
        let back = Bls12381G1::unpack_coord(&buf).unwrap();
        assert_eq!(back, p.x);
    }
    let inf = Affine::<Bls12381G1>::infinity();
    let mut buf = Vec::new();
    Bls12381G1::pack_coord(&inf.x, &mut buf);
    assert!(buf.iter().all(|&v| v == 0));
}
