//! Integration: the Groth16-shaped prover pipeline end-to-end on both curve
//! families, including the Table I shape assertions at a non-trivial size.

use ifzkp::ec::{Bls12381G1, Bls12381G2, Bn254G1, Bn254G2};
use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
use ifzkp::snark::{circuits, prover::Prover, qap, setup::Crs};
use ifzkp::util::rng::Rng;

#[test]
fn full_pipeline_bn254() {
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(1000, 31337);
    assert!(cs.is_satisfied());
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), n, 1);
    let (proof, prof) = Prover::new(crs).prove(&cs);
    assert!(!proof.a.is_infinity() && !proof.b.is_infinity() && !proof.c.is_infinity());
    assert!(proof.a.is_on_curve() && proof.b.is_on_curve() && proof.c.is_on_curve());
    // Table I shape: MSM dominates; G2 share substantial
    assert!(prof.msm_g1_pct + prof.msm_g2_pct > 65.0, "{prof:?}");
    assert!(prof.msm_g2_pct > 15.0, "{prof:?}");
    assert!(prof.ntt_pct < 30.0, "{prof:?}");
}

#[test]
fn full_pipeline_bls12_381() {
    let cs = circuits::square_chain::<Bls12381FrParams, 4>(800, 31338);
    assert!(cs.is_satisfied());
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bls12381G1, Bls12381G2>::synthesize(cs.num_variables(), n, 2);
    let (proof, prof) = Prover::new(crs).prove(&cs);
    assert!(!proof.a.is_infinity());
    assert!(prof.msm_g1_pct + prof.msm_g2_pct > 60.0, "{prof:?}");
}

#[test]
fn qap_identity_is_the_correctness_seal() {
    // satisfied circuit ⇒ identity holds at random points;
    // corrupt one witness value ⇒ identity breaks.
    let mut cs = circuits::mul_chain::<Bn254FrParams, 4>(500, 31339);
    let (a, b, c) = cs.constraint_evals();
    let qapw = qap::compute_h(&a, &b, &c).unwrap();
    let mut rng = Rng::new(55);
    for _ in 0..5 {
        assert!(qap::check_identity(&a, &b, &c, &qapw, &mut rng));
    }

    // corrupt
    use ifzkp::ff::Field;
    let idx = cs.witness.len() / 2;
    cs.witness[idx] = cs.witness[idx].add(&ifzkp::ff::FrBn254::one());
    assert!(!cs.is_satisfied());
    let (a2, b2, c2) = cs.constraint_evals();
    let qapw2 = qap::compute_h(&a2, &b2, &c2).unwrap();
    assert!(!qap::check_identity(&a2, &b2, &c2, &qapw2, &mut rng));
}

#[test]
fn qap_divisibility_regression_at_2_12_constraints() {
    // the parallel-NTT acceptance size: a 2^12-point domain runs all
    // seven transforms through one cached plan, multi-threaded — the
    // quotient must still divide exactly (Schwartz–Zippel check), with
    // h bit-identical to the single-threaded reduction
    use ifzkp::ff::Field;
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(4090, 20260729);
    assert!(cs.is_satisfied());
    let (a, b, c) = cs.constraint_evals();
    let (qapw, phases) = qap::compute_h_with(&a, &b, &c, 4).expect("within 2-adicity");
    assert_eq!(qapw.domain.n, 1 << 12);
    assert!(phases.total_s() > 0.0, "{phases:?}");
    let mut rng = Rng::new(20260730);
    for _ in 0..3 {
        assert!(qap::check_identity(&a, &b, &c, &qapw, &mut rng));
    }
    // h degree ≤ n − 2 ⇒ the top coefficient vanishes
    assert!(qapw.h_coeffs.last().unwrap().is_zero());
    // thread budget is invisible in the coefficients
    let (qapw1, _) = qap::compute_h_with(&a, &b, &c, 1).unwrap();
    assert_eq!(qapw.h_coeffs, qapw1.h_coeffs);
}

#[test]
fn streaming_prover_matches_resident_both_curves() {
    // the streaming-vs-resident proof matrix: generator-backed SRS chunks
    // under a budget far below Θ(m), both curves, proofs bit-identical
    // (eq_point on a, b, c) to the resident prover
    use ifzkp::ec::CurveParams;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
    {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(700, 31350);
        let dn = cs.num_constraints().next_power_of_two();
        let nv = cs.num_variables();
        let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 5);
        let (want, _) = Prover::new(crs).prove(&cs);
        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, dn, 5);
        let budget = MemoryBudget::bytes(24 * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES));
        let (got, report) =
            prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
        assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    }
    {
        let cs = circuits::square_chain::<Bls12381FrParams, 4>(500, 31351);
        let dn = cs.num_constraints().next_power_of_two();
        let nv = cs.num_variables();
        let crs = Crs::<Bls12381G1, Bls12381G2>::synthesize(nv, dn, 6);
        let (want, _) = Prover::new(crs).prove(&cs);
        let srs = StreamingSrs::<Bls12381G1, Bls12381G2>::generated(nv, dn, 6);
        let budget = MemoryBudget::bytes(24 * (Bls12381G2::AFFINE_BYTES + SCALAR_BYTES));
        let (got, report) =
            prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
        assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    }
}

#[test]
fn streaming_prover_disk_fault_surfaces_and_retry_succeeds() {
    // a disk-backed SRS whose chunk file is truncated mid-stream must
    // surface a typed JobError::StreamFailed — not a wrong proof, hang, or
    // partial state — and a rewritten SRS retries to the bit-identical
    // proof
    use ifzkp::coordinator::request::JobError;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::MemoryBudget;
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(400, 31352);
    let dn = cs.num_constraints().next_power_of_two();
    let nv = cs.num_variables();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 7);
    let (want, _) = Prover::new(crs).prove(&cs);
    let dir = std::env::temp_dir().join("ifzkp_srs_fault_test");
    let srs =
        StreamingSrs::<Bn254G1, Bn254G2>::write_to_dir(&dir, nv, dn, 7, 64).unwrap();
    let budget = MemoryBudget::mib(1);
    // healthy disk SRS first: proves and matches
    let (got, _) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    // truncate the B1 query mid-points: the header stays valid, the read
    // fails partway through the stream
    let b1 = dir.join("b1_query.pts");
    let bytes = std::fs::read(&b1).unwrap();
    std::fs::write(&b1, &bytes[..bytes.len() / 2]).unwrap();
    let err = prove_streaming(&cs, &srs, budget, &ProverConfig::default())
        .expect_err("truncated SRS must fail");
    assert!(matches!(err, JobError::StreamFailed(_)), "{err:?}");
    assert!(err.to_string().contains("streaming chunk source failed"), "{err}");
    // a rewritten SRS retries from a fresh stream, bit-identically
    let srs =
        StreamingSrs::<Bn254G1, Bn254G2>::write_to_dir(&dir, nv, dn, 7, 64).unwrap();
    let (got, _) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance size: 2^18 constraints with `IFZKP_HEAVY_TESTS=1` (CI runs
/// this in release mode), a debug-friendly 2^11 otherwise — assertions
/// identical: the streamed proof completes under a budget orders of
/// magnitude below the resident working set and matches it bit for bit.
#[test]
fn streaming_prover_heavy() {
    use ifzkp::ec::CurveParams;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
    let n: usize =
        if std::env::var("IFZKP_HEAVY_TESTS").is_ok() { 1 << 18 } else { 1 << 11 };
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, 31353);
    let dn = cs.num_constraints().next_power_of_two();
    let nv = cs.num_variables();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 8);
    let (want, _) = Prover::new(crs).prove(&cs);
    // the full working set is Θ(m); stream under a budget of 2^12 G2
    // elements regardless of n — at 2^18 that is ~64x smaller than the
    // G2 query alone
    let budget = MemoryBudget::bytes((1 << 12) * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES));
    let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, dn, 8);
    let (got, report) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    println!(
        "streaming_prover_heavy: n={n} budget={} peak_chunk={} fixed={} wall={:.2}s",
        report.budget_bytes, report.peak_chunk_bytes, report.fixed_bytes, report.total_s
    );
}

#[test]
fn profile_split_stable_across_runs() {
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(600, 31340);
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), n, 3);
    let prover = Prover::new(crs);
    let (_, p1) = prover.prove(&cs);
    let (_, p2) = prover.prove(&cs);
    // percentages shouldn't swing wildly between identical runs
    assert!((p1.msm_g2_pct - p2.msm_g2_pct).abs() < 15.0, "{p1:?} vs {p2:?}");
}

#[test]
fn g2_share_grows_with_circuit_size() {
    // Table I's G2 dominance emerges with scale (fixed costs wash out).
    let share = |n: usize| {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, 31341);
        let dn = cs.num_constraints().next_power_of_two();
        let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), dn, 4);
        let (_, prof) = Prover::new(crs).prove(&cs);
        prof.msm_g2_pct
    };
    let small = share(200);
    let large = share(2000);
    assert!(
        large > small - 8.0,
        "G2 share should not collapse with size: {small} -> {large}"
    );
}
