//! Integration: the Groth16-shaped prover pipeline end-to-end on both curve
//! families, including the Table I shape assertions at a non-trivial size.

use ifzkp::ec::{Bls12381G1, Bls12381G2, Bn254G1, Bn254G2};
use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
use ifzkp::snark::{circuits, prover::Prover, qap, setup::Crs};
use ifzkp::util::rng::Rng;

#[test]
fn full_pipeline_bn254() {
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(1000, 31337);
    assert!(cs.is_satisfied());
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), n, 1);
    let (proof, prof) = Prover::new(crs).prove(&cs);
    assert!(!proof.a.is_infinity() && !proof.b.is_infinity() && !proof.c.is_infinity());
    assert!(proof.a.is_on_curve() && proof.b.is_on_curve() && proof.c.is_on_curve());
    // Table I shape: MSM dominates; G2 share substantial
    assert!(prof.msm_g1_pct + prof.msm_g2_pct > 65.0, "{prof:?}");
    assert!(prof.msm_g2_pct > 15.0, "{prof:?}");
    assert!(prof.ntt_pct < 30.0, "{prof:?}");
}

#[test]
fn full_pipeline_bls12_381() {
    let cs = circuits::square_chain::<Bls12381FrParams, 4>(800, 31338);
    assert!(cs.is_satisfied());
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bls12381G1, Bls12381G2>::synthesize(cs.num_variables(), n, 2);
    let (proof, prof) = Prover::new(crs).prove(&cs);
    assert!(!proof.a.is_infinity());
    assert!(prof.msm_g1_pct + prof.msm_g2_pct > 60.0, "{prof:?}");
}

#[test]
fn qap_identity_is_the_correctness_seal() {
    // satisfied circuit ⇒ identity holds at random points;
    // corrupt one witness value ⇒ identity breaks.
    let mut cs = circuits::mul_chain::<Bn254FrParams, 4>(500, 31339);
    let (a, b, c) = cs.constraint_evals();
    let qapw = qap::compute_h(&a, &b, &c).unwrap();
    let mut rng = Rng::new(55);
    for _ in 0..5 {
        assert!(qap::check_identity(&a, &b, &c, &qapw, &mut rng));
    }

    // corrupt
    use ifzkp::ff::Field;
    let idx = cs.witness.len() / 2;
    cs.witness[idx] = cs.witness[idx].add(&ifzkp::ff::FrBn254::one());
    assert!(!cs.is_satisfied());
    let (a2, b2, c2) = cs.constraint_evals();
    let qapw2 = qap::compute_h(&a2, &b2, &c2).unwrap();
    assert!(!qap::check_identity(&a2, &b2, &c2, &qapw2, &mut rng));
}

#[test]
fn qap_divisibility_regression_at_2_12_constraints() {
    // the parallel-NTT acceptance size: a 2^12-point domain runs all
    // seven transforms through one cached plan, multi-threaded — the
    // quotient must still divide exactly (Schwartz–Zippel check), with
    // h bit-identical to the single-threaded reduction
    use ifzkp::ff::Field;
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(4090, 20260729);
    assert!(cs.is_satisfied());
    let (a, b, c) = cs.constraint_evals();
    let (qapw, phases) = qap::compute_h_with(&a, &b, &c, 4).expect("within 2-adicity");
    assert_eq!(qapw.domain.n, 1 << 12);
    assert!(phases.total_s() > 0.0, "{phases:?}");
    let mut rng = Rng::new(20260730);
    for _ in 0..3 {
        assert!(qap::check_identity(&a, &b, &c, &qapw, &mut rng));
    }
    // h degree ≤ n − 2 ⇒ the top coefficient vanishes
    assert!(qapw.h_coeffs.last().unwrap().is_zero());
    // thread budget is invisible in the coefficients
    let (qapw1, _) = qap::compute_h_with(&a, &b, &c, 1).unwrap();
    assert_eq!(qapw.h_coeffs, qapw1.h_coeffs);
}

#[test]
fn streaming_prover_matches_resident_both_curves() {
    // the streaming-vs-resident proof matrix: generator-backed SRS chunks
    // under a budget far below Θ(m), both curves, proofs bit-identical
    // (eq_point on a, b, c) to the resident prover
    use ifzkp::ec::CurveParams;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
    {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(700, 31350);
        let dn = cs.num_constraints().next_power_of_two();
        let nv = cs.num_variables();
        let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 5);
        let (want, _) = Prover::new(crs).prove(&cs);
        let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, dn, 5);
        let budget = MemoryBudget::bytes(24 * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES));
        let (got, report) =
            prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
        assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    }
    {
        let cs = circuits::square_chain::<Bls12381FrParams, 4>(500, 31351);
        let dn = cs.num_constraints().next_power_of_two();
        let nv = cs.num_variables();
        let crs = Crs::<Bls12381G1, Bls12381G2>::synthesize(nv, dn, 6);
        let (want, _) = Prover::new(crs).prove(&cs);
        let srs = StreamingSrs::<Bls12381G1, Bls12381G2>::generated(nv, dn, 6);
        let budget = MemoryBudget::bytes(24 * (Bls12381G2::AFFINE_BYTES + SCALAR_BYTES));
        let (got, report) =
            prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
        assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
        assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    }
}

#[test]
fn streaming_prover_disk_fault_surfaces_and_retry_succeeds() {
    // a disk-backed SRS whose chunk file is truncated mid-stream must
    // surface a typed JobError::StreamFailed — not a wrong proof, hang, or
    // partial state — and a rewritten SRS retries to the bit-identical
    // proof
    use ifzkp::coordinator::request::JobError;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::MemoryBudget;
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(400, 31352);
    let dn = cs.num_constraints().next_power_of_two();
    let nv = cs.num_variables();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 7);
    let (want, _) = Prover::new(crs).prove(&cs);
    let dir = std::env::temp_dir().join("ifzkp_srs_fault_test");
    let srs =
        StreamingSrs::<Bn254G1, Bn254G2>::write_to_dir(&dir, nv, dn, 7, 64).unwrap();
    let budget = MemoryBudget::mib(1);
    // healthy disk SRS first: proves and matches
    let (got, _) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    // truncate the B1 query mid-points: the header stays valid, the read
    // fails partway through the stream
    let b1 = dir.join("b1_query.pts");
    let bytes = std::fs::read(&b1).unwrap();
    std::fs::write(&b1, &bytes[..bytes.len() / 2]).unwrap();
    let err = prove_streaming(&cs, &srs, budget, &ProverConfig::default())
        .expect_err("truncated SRS must fail");
    assert!(matches!(err, JobError::StreamFailed(_)), "{err:?}");
    assert!(err.to_string().contains("streaming chunk source failed"), "{err}");
    // a rewritten SRS retries from a fresh stream, bit-identically
    let srs =
        StreamingSrs::<Bn254G1, Bn254G2>::write_to_dir(&dir, nv, dn, 7, 64).unwrap();
    let (got, _) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance size: 2^18 constraints with `IFZKP_HEAVY_TESTS=1` (CI runs
/// this in release mode), a debug-friendly 2^11 otherwise — assertions
/// identical: the streamed proof completes under a budget orders of
/// magnitude below the resident working set and matches it bit for bit.
#[test]
fn streaming_prover_heavy() {
    use ifzkp::ec::CurveParams;
    use ifzkp::snark::{prove_streaming, ProverConfig, StreamingSrs};
    use ifzkp::util::mem::{MemoryBudget, SCALAR_BYTES};
    let n: usize =
        if std::env::var("IFZKP_HEAVY_TESTS").is_ok() { 1 << 18 } else { 1 << 11 };
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, 31353);
    let dn = cs.num_constraints().next_power_of_two();
    let nv = cs.num_variables();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, dn, 8);
    let (want, _) = Prover::new(crs).prove(&cs);
    // the full working set is Θ(m); stream under a budget of 2^12 G2
    // elements regardless of n — at 2^18 that is ~64x smaller than the
    // G2 query alone
    let budget = MemoryBudget::bytes((1 << 12) * (Bn254G2::AFFINE_BYTES + SCALAR_BYTES));
    let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, dn, 8);
    let (got, report) = prove_streaming(&cs, &srs, budget, &ProverConfig::default()).unwrap();
    assert!(got.a.eq_point(&want.a) && got.b.eq_point(&want.b) && got.c.eq_point(&want.c));
    assert!(report.peak_chunk_bytes <= report.budget_bytes, "{report:?}");
    println!(
        "streaming_prover_heavy: n={n} budget={} peak_chunk={} fixed={} wall={:.2}s",
        report.budget_bytes, report.peak_chunk_bytes, report.fixed_bytes, report.total_s
    );
}

// ---------------------------------------------------------------------------
// Soundness suite: every scenario, both curves. Each negative path must be
// rejected at the layer that owns it — witness tampering by `is_satisfied`,
// proof bit-flips by the curve checks, wrong publics by the π commitment.
// CI runs these in release mode (`cargo test --release soundness_`).
// ---------------------------------------------------------------------------

fn soundness_negative_paths<G1, G2, P>()
where
    G1: ifzkp::ec::CurveParams,
    G2: ifzkp::ec::CurveParams,
    P: ifzkp::ff::FieldParams<4>,
{
    use ifzkp::ff::{Field, Fp};
    use ifzkp::snark::{verify, Scenario, VerifyError, VerifyingKey};
    for sc in Scenario::ALL {
        let inst = sc.build::<P, 4>(260, 77);
        assert!(inst.cs.is_satisfied(), "{}", sc.name());

        // tampered witness: adding 1 to a mid-witness private wire must
        // break satisfaction (every allocated wire is constrained)
        let mut tampered = inst.cs.clone();
        let idx = tampered.witness.len() / 2;
        tampered.witness[idx] = tampered.witness[idx].add(&Fp::<P, 4>::one());
        assert!(!tampered.is_satisfied(), "{}: tamper survived", sc.name());

        let domain_n = inst.cs.num_constraints().max(2).next_power_of_two();
        let crs = Crs::<G1, G2>::synthesize(inst.cs.num_variables(), domain_n, 9);
        let vk = VerifyingKey::from_crs(&crs, inst.cs.num_public);
        let (proof, _) = Prover::new(crs).prove(&inst.cs);
        assert_eq!(verify(&vk, &proof, &inst.public_inputs), Ok(()), "{}", sc.name());

        // wrong public input
        let mut wrong = inst.public_inputs.clone();
        wrong[0] = wrong[0].add(&Fp::<P, 4>::one());
        assert_eq!(
            verify(&vk, &proof, &wrong),
            Err(VerifyError::PublicInputMismatch),
            "{}",
            sc.name()
        );

        // bit-flipped proof element lands off-curve
        let mut flipped = ifzkp::snark::Proof { a: proof.a, b: proof.b, c: proof.c, pi: proof.pi };
        flipped.a.y = flipped.a.y.add(&Field::one());
        assert_eq!(
            verify(&vk, &flipped, &inst.public_inputs),
            Err(VerifyError::OffCurve("a")),
            "{}",
            sc.name()
        );

        // substituted-but-valid π must hit the commitment check
        let mut swapped = ifzkp::snark::Proof { a: proof.a, b: proof.b, c: proof.c, pi: proof.pi };
        swapped.pi = swapped.pi.add(&ifzkp::ec::Jacobian::generator());
        assert_eq!(
            verify(&vk, &swapped, &inst.public_inputs),
            Err(VerifyError::PublicInputMismatch),
            "{}",
            sc.name()
        );
    }
}

#[test]
fn soundness_negative_paths_bn254() {
    soundness_negative_paths::<Bn254G1, Bn254G2, Bn254FrParams>();
}

#[test]
fn soundness_negative_paths_bls12_381() {
    soundness_negative_paths::<Bls12381G1, Bls12381G2, Bls12381FrParams>();
}

#[test]
fn soundness_forged_merkle_sibling_rejected() {
    // constraint-level rejection: swap one sibling witness after synthesis
    // and the recomputed root no longer meets the public root
    use ifzkp::ff::Field;
    use ifzkp::snark::circuits::merkle::{alloc_path, fold_path, root_gadget};
    use ifzkp::snark::circuits::poseidon2::Poseidon2;
    use ifzkp::snark::LinearCombination;
    use ifzkp::util::rng::Rng;
    type Fr = ifzkp::ff::FrBn254;
    let hasher = Poseidon2::<Bn254FrParams, 4>::standard();
    let mut rng = Rng::new(88);
    let leaf = Fr::random(&mut rng);
    let index = 5usize;
    let sibs: Vec<Fr> = (0..4).map(|_| Fr::random(&mut rng)).collect();
    let root = fold_path(&hasher, leaf, index, &sibs);
    let mut cs = ifzkp::snark::ConstraintSystem::<Bn254FrParams, 4>::new();
    let w_root = cs.alloc_public(root);
    let leaf_lc = LinearCombination::var(cs.alloc(leaf));
    let path = alloc_path(&mut cs, index, &sibs);
    let got = root_gadget(&hasher, &mut cs, &leaf_lc, &path);
    cs.enforce_eq(&got, &LinearCombination::var(w_root));
    assert!(cs.is_satisfied());
    // forge sibling at level 2
    cs.witness[path.siblings[2]] = cs.witness[path.siblings[2]].add(&Fr::one());
    assert!(!cs.is_satisfied(), "forged sibling must be rejected");
}

#[test]
fn soundness_overflowed_range_value_rejected() {
    // constraint-level rejection: a value at exactly 2^k cannot satisfy
    // the k-bit decomposition, nor can the −1 wrap-around candidate
    use ifzkp::ff::Field;
    use ifzkp::snark::circuits::range::range_gadget;
    use ifzkp::snark::LinearCombination;
    type Fr = ifzkp::ff::FrBn254;
    for value in [Fr::from_u64(1u64 << 16), Fr::zero().sub(&Fr::one())] {
        let mut cs = ifzkp::snark::ConstraintSystem::<Bn254FrParams, 4>::new();
        let w = cs.alloc_public(value);
        range_gadget(&mut cs, &LinearCombination::var(w), 16);
        assert!(!cs.is_satisfied());
    }
}

// ---------------------------------------------------------------------------
// Cross-runtime differential matrix: every scenario must prove bit-
// identically across {resident, streaming} × {full-width, GLV} ×
// {Pippenger, Chunked, auto} — and verify. One baseline proof per
// scenario anchors the comparison.
// ---------------------------------------------------------------------------

fn differential_matrix<G1, G2, P>(seed: u64)
where
    G1: ifzkp::ec::CurveParams,
    G2: ifzkp::ec::CurveParams,
    P: ifzkp::ff::FieldParams<4>,
    G1::Base: ifzkp::ff::WordCodec,
    G2::Base: ifzkp::ff::WordCodec,
{
    use ifzkp::msm::Backend;
    use ifzkp::snark::{
        prove_streaming, verify, ProverConfig, Scenario, StreamingSrs, VerifyingKey,
    };
    use ifzkp::util::MemoryBudget;
    for sc in Scenario::ALL {
        let inst = sc.build::<P, 4>(240, seed);
        let nv = inst.cs.num_variables();
        let domain_n = inst.cs.num_constraints().max(2).next_power_of_two();
        let crs_seed = seed ^ 0xd1f;
        let crs = Crs::<G1, G2>::synthesize(nv, domain_n, crs_seed);
        let vk = VerifyingKey::from_crs(&crs, inst.cs.num_public);
        let (want, _) = Prover::new(crs).prove(&inst.cs);
        assert_eq!(verify(&vk, &want, &inst.public_inputs), Ok(()), "{}", sc.name());

        let configs = |glv: bool| {
            let base = if glv {
                ProverConfig::<G1, G2>::default().glv()
            } else {
                ProverConfig::<G1, G2>::default()
            };
            [
                base.clone().backend(Backend::Pippenger),
                base.clone().backend(Backend::Chunked { threads: 2 }),
                base.auto_backend(),
            ]
        };
        for glv in [false, true] {
            for (ci, cfg) in configs(glv).into_iter().enumerate() {
                let label = format!("{} glv={glv} cfg={ci}", sc.name());
                // resident
                let crs = Crs::<G1, G2>::synthesize(nv, domain_n, crs_seed);
                let (got, _) = Prover::with_config(crs, cfg.clone()).prove(&inst.cs);
                assert!(
                    got.a.eq_point(&want.a)
                        && got.b.eq_point(&want.b)
                        && got.c.eq_point(&want.c)
                        && got.pi.eq_point(&want.pi),
                    "resident diverged: {label}"
                );
                assert_eq!(verify(&vk, &got, &inst.public_inputs), Ok(()), "{label}");
                // streaming, same config, chunk-identical SRS
                let srs = StreamingSrs::<G1, G2>::generated(nv, domain_n, crs_seed);
                let (got, report) =
                    prove_streaming(&inst.cs, &srs, MemoryBudget::mib(1), &cfg).unwrap();
                assert!(
                    got.a.eq_point(&want.a)
                        && got.b.eq_point(&want.b)
                        && got.c.eq_point(&want.c)
                        && got.pi.eq_point(&want.pi),
                    "streaming diverged: {label}"
                );
                assert!(report.peak_chunk_bytes <= report.budget_bytes, "{label}");
                assert_eq!(verify(&vk, &got, &inst.public_inputs), Ok(()), "{label}");
            }
        }
    }
}

#[test]
fn scenario_differential_matrix_bn254() {
    differential_matrix::<Bn254G1, Bn254G2, Bn254FrParams>(101);
}

#[test]
fn scenario_differential_matrix_bls12_381() {
    differential_matrix::<Bls12381G1, Bls12381G2, Bls12381FrParams>(102);
}

/// The repeated-SRS serving case: one prover with fixed-base tables over
/// the CRS queries serves two same-shape instances, each bit-identical to
/// an untabled prover. `IFZKP_HEAVY_TESTS=1` runs the 2^14 acceptance
/// size; the default stays debug-friendly.
#[test]
fn scenario_point_cache_repeated_srs() {
    use ifzkp::snark::{verify, ProverConfig, Scenario, VerifyingKey};
    let size: usize =
        if std::env::var("IFZKP_HEAVY_TESTS").is_ok() { 1 << 14 } else { 600 };
    let a = Scenario::Poseidon2.build::<Bn254FrParams, 4>(size, 301);
    let b = Scenario::Poseidon2.build::<Bn254FrParams, 4>(size, 302);
    assert_eq!(a.cs.num_variables(), b.cs.num_variables(), "same shape required");
    let nv = a.cs.num_variables();
    let domain_n = a.cs.num_constraints().max(2).next_power_of_two();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(nv, domain_n, 303);
    let vk = VerifyingKey::from_crs(&crs, a.cs.num_public);
    let cached = Prover::with_config(crs, ProverConfig::default().point_cache());
    for inst in [&a, &b] {
        let (got, _) = cached.prove(&inst.cs);
        let plain = Prover::new(Crs::<Bn254G1, Bn254G2>::synthesize(nv, domain_n, 303));
        let (want, _) = plain.prove(&inst.cs);
        assert!(
            got.a.eq_point(&want.a)
                && got.b.eq_point(&want.b)
                && got.c.eq_point(&want.c)
                && got.pi.eq_point(&want.pi),
            "table-fed proof diverged"
        );
        assert_eq!(verify(&vk, &got, &inst.public_inputs), Ok(()));
    }
}

#[test]
fn profile_split_stable_across_runs() {
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(600, 31340);
    let n = cs.num_constraints().next_power_of_two();
    let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), n, 3);
    let prover = Prover::new(crs);
    let (_, p1) = prover.prove(&cs);
    let (_, p2) = prover.prove(&cs);
    // percentages shouldn't swing wildly between identical runs
    assert!((p1.msm_g2_pct - p2.msm_g2_pct).abs() < 15.0, "{p1:?} vs {p2:?}");
}

#[test]
fn g2_share_grows_with_circuit_size() {
    // Table I's G2 dominance emerges with scale (fixed costs wash out).
    let share = |n: usize| {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, 31341);
        let dn = cs.num_constraints().next_power_of_two();
        let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), dn, 4);
        let (_, prof) = Prover::new(crs).prove(&cs);
        prof.msm_g2_pct
    };
    let small = share(200);
    let large = share(2000);
    assert!(
        large > small - 8.0,
        "G2 share should not collapse with size: {small} -> {large}"
    );
}
