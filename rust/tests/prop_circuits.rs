//! Property tests for the circuit library: every gadget must agree with
//! its out-of-circuit reference, and the Poseidon2 permutation is pinned
//! to known-answer vectors (independently recomputable from the frozen
//! constant-derivation spec in the module docs) so the constants can
//! never drift silently between releases or between the two Fr fields.

use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
use ifzkp::ff::{Field, FieldParams, Fp};
use ifzkp::snark::circuits::merkle::{self, MerkleTree};
use ifzkp::snark::circuits::poseidon2::Poseidon2;
use ifzkp::snark::circuits::{range, rollup};
use ifzkp::snark::{ConstraintSystem, LinearCombination};
use ifzkp::util::hex::limbs_to_hex;
use ifzkp::util::rng::Rng;

type FrBn = ifzkp::ff::FrBn254;
type FrBls = ifzkp::ff::FrBls12381;

fn hex<P: FieldParams<4>>(x: &Fp<P, 4>) -> String {
    limbs_to_hex(&x.to_canonical())
}

// ---------------------------------------------------------------- poseidon2

/// Known-answer vectors for the standard (RF=8, RP=56) instance. The
/// values were produced by an independent straight-line implementation
/// of the frozen spec (seeded xoshiro256** constant schedule,
/// circ(2,1,1) external / diag-adjusted internal matrices, x^5 S-box) —
/// not by running this crate against itself.
#[test]
fn poseidon2_known_answer_vectors_bn254() {
    let h = Poseidon2::<Bn254FrParams, 4>::standard();
    let out = h.permute([FrBn::from_u64(1), FrBn::from_u64(2), FrBn::from_u64(3)]);
    assert_eq!(hex(&out[0]), "0x38e58fe8f38b7b6f26de4c901ee41ef2f5b79a3d5770e1b3d15526bcaa7f4de");
    assert_eq!(hex(&out[1]), "0x26e40a9fb27677d156ef8d438d8e0a48b8a58746bd7db77e543d4b1e7194897d");
    assert_eq!(hex(&out[2]), "0x2a3d6e743d02401d672db7fbf5a6bd25b527ebb9326073266e60e79be7d7077b");
    let zero = h.permute([FrBn::zero(), FrBn::zero(), FrBn::zero()]);
    assert_eq!(hex(&zero[0]), "0x11fb026d4c481827576c6e02da5b0bf1e12a2374e2a4145c6ef1403a0bb3fe6");
    let c = h.compress(&FrBn::from_u64(5), &FrBn::from_u64(7));
    assert_eq!(hex(&c), "0x60241aa667fd8fe3a2c0c7d8eceb17d3eb7d280a47116e21018caba5465a9c");
}

#[test]
fn poseidon2_known_answer_vectors_bls12_381() {
    let h = Poseidon2::<Bls12381FrParams, 4>::standard();
    let out = h.permute([FrBls::from_u64(1), FrBls::from_u64(2), FrBls::from_u64(3)]);
    assert_eq!(hex(&out[0]), "0x73c24bbd85c1beced4e8a5154673bb6499069bf17543e5d20ce348d765881e46");
    assert_eq!(hex(&out[1]), "0x423051132b9308ecd109a5cc725fdc57d663dbbc871801c961f238ed2c4032cd");
    assert_eq!(hex(&out[2]), "0x13753c1ed8b4d38024f2b3a6b14c3c99895681934a62160b15bb10d806cf416d");
    let zero = h.permute([FrBls::zero(), FrBls::zero(), FrBls::zero()]);
    assert_eq!(hex(&zero[0]), "0x305af2616964f5ff39de09dd2f6c1c05ab61e45b2a9dd5cf4927dc629da9763c");
    let c = h.compress(&FrBls::from_u64(5), &FrBls::from_u64(7));
    assert_eq!(hex(&c), "0x15a89c483d254a44a942c9bde81d3c58dfd34ce24f27efe0f786559c0415bffe");
}

/// The two fields must disagree: identical hex outputs would mean the
/// domain-separated constant schedule collapsed to one field.
#[test]
fn poseidon2_fields_are_domain_separated() {
    let bn = Poseidon2::<Bn254FrParams, 4>::standard()
        .permute([FrBn::from_u64(1), FrBn::from_u64(2), FrBn::from_u64(3)]);
    let bls = Poseidon2::<Bls12381FrParams, 4>::standard()
        .permute([FrBls::from_u64(1), FrBls::from_u64(2), FrBls::from_u64(3)]);
    assert_ne!(hex(&bn[0]), hex(&bls[0]));
}

fn permute_gadget_matches<P: FieldParams<4>>(seed: u64, iters: usize) {
    let h = Poseidon2::<P, 4>::standard();
    let mut rng = Rng::new(seed);
    for _ in 0..iters {
        let input = [
            Fp::<P, 4>::random(&mut rng),
            Fp::<P, 4>::random(&mut rng),
            Fp::<P, 4>::random(&mut rng),
        ];
        let want = h.permute(input);
        let mut cs = ConstraintSystem::<P, 4>::new();
        let wires = input.map(|v| cs.alloc(v));
        let lcs = wires.map(LinearCombination::var);
        let out = h.permute_gadget(&mut cs, &lcs);
        assert!(cs.is_satisfied());
        assert_eq!(cs.num_constraints(), h.constraints_per_permutation());
        for (lane, (got, want)) in out.iter().zip(&want).enumerate() {
            assert_eq!(cs.eval_comb(got), *want, "lane {lane} diverged");
        }
    }
}

#[test]
fn poseidon2_gadget_matches_reference_on_random_inputs() {
    permute_gadget_matches::<Bn254FrParams>(701, 4);
    permute_gadget_matches::<Bls12381FrParams>(702, 4);
}

// ------------------------------------------------------------------- merkle

/// In-circuit path verification equals the out-of-circuit fold at every
/// required depth, over every leaf position of a real tree (shallow
/// depths) and over synthetic paths (depth 16, where materializing the
/// 2^16-leaf reference tree would dominate the test).
#[test]
fn merkle_gadget_matches_reference_across_depths() {
    for depth in [1usize, 4] {
        let hasher = Poseidon2::<Bn254FrParams, 4>::standard();
        let mut rng = Rng::new(800 + depth as u64);
        let leaves: Vec<FrBn> =
            (0..1usize << depth).map(|_| FrBn::random(&mut rng)).collect();
        let tree = MerkleTree::new(hasher.clone(), leaves);
        for index in 0..1usize << depth {
            let sibs = tree.path(index);
            let folded = merkle::fold_path(&hasher, tree.leaf(index), index, &sibs);
            assert_eq!(folded, tree.root());
            let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
            let leaf = LinearCombination::var(cs.alloc(tree.leaf(index)));
            let path = merkle::alloc_path(&mut cs, index, &sibs);
            let got = merkle::root_gadget(&hasher, &mut cs, &leaf, &path);
            assert!(cs.is_satisfied());
            assert_eq!(cs.eval_comb(&got), tree.root(), "depth {depth} index {index}");
        }
    }
    // depth 16: synthetic random path, gadget vs fold_path
    let depth = 16;
    let hasher = Poseidon2::<Bn254FrParams, 4>::standard();
    let mut rng = Rng::new(816);
    let leaf = FrBn::random(&mut rng);
    let index = rng.below(1u64 << depth) as usize;
    let sibs: Vec<FrBn> = (0..depth).map(|_| FrBn::random(&mut rng)).collect();
    let want = merkle::fold_path(&hasher, leaf, index, &sibs);
    let mut cs = ConstraintSystem::<Bn254FrParams, 4>::new();
    let leaf_lc = LinearCombination::var(cs.alloc(leaf));
    let path = merkle::alloc_path(&mut cs, index, &sibs);
    let got = merkle::root_gadget(&hasher, &mut cs, &leaf_lc, &path);
    assert!(cs.is_satisfied());
    assert_eq!(cs.eval_comb(&got), want);
}

#[test]
fn merkle_update_then_path_still_folds() {
    let hasher = Poseidon2::<Bls12381FrParams, 4>::standard();
    let mut rng = Rng::new(821);
    let leaves: Vec<FrBls> = (0..8).map(|_| FrBls::random(&mut rng)).collect();
    let mut tree = MerkleTree::new(hasher.clone(), leaves);
    tree.update(5, FrBls::from_u64(9999));
    for index in 0..8 {
        let folded =
            merkle::fold_path(&hasher, tree.leaf(index), index, &tree.path(index));
        assert_eq!(folded, tree.root());
    }
}

// -------------------------------------------------------------------- range

fn range_ok<P: FieldParams<4>>(value: Fp<P, 4>, k: usize) -> bool {
    let mut cs = ConstraintSystem::<P, 4>::new();
    let w = cs.alloc_public(value);
    range::range_gadget(&mut cs, &LinearCombination::var(w), k);
    cs.is_satisfied()
}

/// k = 6 is small enough to enumerate: the gadget accepts *exactly*
/// [0, 64) and rejects the next 32 values above the boundary.
#[test]
fn range_accepts_exactly_the_k_bit_interval() {
    for v in 0u64..64 {
        assert!(range_ok::<Bn254FrParams>(FrBn::from_u64(v), 6), "{v} must pass k=6");
        assert!(range_ok::<Bls12381FrParams>(FrBls::from_u64(v), 6), "{v} bls");
    }
    for v in 64u64..96 {
        assert!(!range_ok::<Bn254FrParams>(FrBn::from_u64(v), 6), "{v} must fail k=6");
    }
}

#[test]
fn range_k32_boundary_is_exact() {
    let max = (1u64 << 32) - 1;
    assert!(range_ok::<Bn254FrParams>(FrBn::from_u64(max), 32));
    assert!(!range_ok::<Bn254FrParams>(FrBn::from_u64(1u64 << 32), 32));
    assert!(!range_ok::<Bn254FrParams>(FrBn::from_u64((1u64 << 32) + 1), 32));
    // the additive wrap-around candidate: p − 1 ≡ −1 must not pass as
    // a "small" value at any k
    let minus_one = FrBn::zero().sub(&FrBn::one());
    assert!(!range_ok::<Bn254FrParams>(minus_one, 32));
}

// ------------------------------------------------------------------- rollup

/// Conservation under random transfer batches: the circuit is satisfied,
/// the public new root equals an independent replay on the reference
/// tree, and total supply is preserved leaf-by-leaf.
#[test]
fn rollup_conserves_supply_under_random_batches() {
    for seed in [901u64, 902, 903] {
        let mut rng = Rng::new(seed);
        let depth = 2usize;
        let n_accounts = 1usize << depth;
        let amount_bits = 20usize;
        let initial: Vec<u64> =
            (0..n_accounts).map(|_| rng.below(1 << (amount_bits - depth - 1))).collect();
        let mut bal = initial.clone();
        let transfers: Vec<rollup::Transfer> = (0..3)
            .map(|_| {
                let from = rng.below(n_accounts as u64) as usize;
                let mut to = rng.below(n_accounts as u64) as usize;
                while to == from {
                    to = rng.below(n_accounts as u64) as usize;
                }
                let amount = rng.below(bal[from] + 1);
                bal[from] -= amount;
                bal[to] += amount;
                rollup::Transfer { from, to, amount }
            })
            .collect();
        // supply conserved in the u64 replay
        assert_eq!(initial.iter().sum::<u64>(), bal.iter().sum::<u64>(), "seed {seed}");

        let (cs, publics) = rollup::batch_transfer_circuit::<Bn254FrParams, 4>(
            &initial, &transfers, amount_bits,
        );
        assert!(cs.is_satisfied(), "seed {seed}");

        // the public new root must match a tree built from the replayed
        // final balances directly
        let hasher = Poseidon2::<Bn254FrParams, 4>::standard();
        let final_tree = MerkleTree::new(
            hasher,
            bal.iter().map(|b| FrBn::from_u64(*b)).collect(),
        );
        assert_eq!(publics[1], final_tree.root(), "seed {seed}");
    }
}
