//! Property tests: MSM algorithm equivalence (every backend × slicing ×
//! reduction against naive), signed-digit decomposition round-trips, and
//! coordinator invariants under randomized workloads.

use ifzkp::coordinator::pointcache::{Admission, DeviceDdr};
use ifzkp::coordinator::request::PointSetId;
use ifzkp::coordinator::router;
use ifzkp::ec::{points, Bn254G1};
use ifzkp::msm::partial::{self, PartialMsm};
use ifzkp::msm::{self, signed, Backend, MsmConfig, MsmPlan, Reduction, Slicing};
use ifzkp::prop_assert;
use ifzkp::util::prop::{check_with, Config};

#[test]
fn pippenger_equals_naive_random_sizes() {
    check_with(Config { cases: 12, seed: 0xA11CE }, "pippenger == naive", |rng| {
        let m = 1 + rng.below(200) as usize;
        let k = 2 + rng.below(13) as u32;
        let k2 = 1 + rng.below(k as u64) as u32;
        let red = if rng.bool() {
            Reduction::RunningSum
        } else {
            Reduction::Recursive { k2 }
        };
        let slicing = if rng.bool() { Slicing::Signed } else { Slicing::Unsigned };
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let naive = msm::naive::msm(&w.points, &w.scalars);
        let fast = msm::msm_pippenger(
            &w.points,
            &w.scalars,
            &MsmConfig { window_bits: k, reduction: red, slicing, ..Default::default() },
        );
        prop_assert!(fast.eq_point(&naive), "m={m} k={k} {red:?} {slicing:?}");
        Ok(())
    });
}

#[test]
fn all_backends_slicings_reductions_equal_naive() {
    // the acceptance matrix: backends × {unsigned, signed} × {RunningSum,
    // Recursive} all bit-exact against naive
    check_with(Config { cases: 4, seed: 0xFAB }, "backend matrix == naive", |rng| {
        let m = 8 + rng.below(120) as usize;
        let k = 4 + rng.below(10) as u32;
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let naive = msm::naive::msm(&w.points, &w.scalars);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 1 + (k / 2) }] {
                let cfg =
                    MsmConfig { window_bits: k, reduction: red, slicing, ..Default::default() };
                for backend in [
                    Backend::Pippenger,
                    Backend::Parallel { threads: 1 + rng.below(5) as usize },
                    Backend::BatchAffine,
                    Backend::BatchAffineParallel { threads: 2 },
                ] {
                    let got = msm::execute(backend, &w.points, &w.scalars, &cfg);
                    prop_assert!(
                        got.eq_point(&naive),
                        "m={m} k={k} {red:?} {slicing:?} {backend:?}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn signed_digits_roundtrip_to_scalar() {
    // Σ dᵢ·2^(k·i) == scalar, checked in exact 320-bit integer arithmetic
    check_with(Config { cases: 64, seed: 0x51D }, "signed digit round-trip", |rng| {
        let k = 2 + rng.below(15) as u32;
        let bits = 1 + rng.below(255) as u32;
        let mut s = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()];
        // mask to `bits`
        for (i, limb) in s.iter_mut().enumerate() {
            let lo = 64 * i as u32;
            if lo >= bits {
                *limb = 0;
            } else if bits - lo < 64 {
                *limb &= (1u64 << (bits - lo)) - 1;
            }
        }
        let windows = signed::signed_window_count(bits, k);
        let digits = signed::signed_digits(&s, k, windows);
        let half = 1i64 << (k - 1);
        for &d in &digits {
            prop_assert!((-half..half).contains(&d), "digit {d} out of range k={k}");
        }
        // exact 320-bit reconstruction (shared checker in msm::signed)
        let diff = match signed::reconstruct(&digits, k) {
            Some(v) => v,
            None => return Err(format!("negative/overflowing sum k={k} bits={bits}")),
        };
        prop_assert!(diff[4] == 0, "overflow limb nonzero");
        prop_assert!(&diff[..4] == &s[..], "k={k} bits={bits}: {diff:?} != {s:?}");
        Ok(())
    });
}

#[test]
fn plan_digits_agree_with_bucket_ops() {
    check_with(Config { cases: 24, seed: 0xB0C4 }, "plan digit consistency", |rng| {
        let k = 2 + rng.below(15) as u32;
        let slicing = if k >= 2 && rng.bool() { Slicing::Signed } else { Slicing::Unsigned };
        let cfg = MsmConfig {
            window_bits: k,
            reduction: Reduction::RunningSum,
            slicing,
            ..Default::default()
        };
        let plan = MsmPlan::new(254, &cfg);
        let s = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 2];
        let digits = plan.digits(&s);
        prop_assert!(digits.len() == plan.windows as usize, "digit count");
        for (j, &d) in digits.iter().enumerate() {
            prop_assert!(plan.digit(&s, j as u32) == d, "digit mismatch at {j}");
            match plan.bucket_op(&s, j as u32) {
                None => prop_assert!(d == 0, "zero digit maps to no op"),
                Some((b, negate)) => {
                    prop_assert!(b as u64 == d.unsigned_abs(), "bucket index");
                    prop_assert!(negate == (d < 0), "negate flag");
                    prop_assert!(b < plan.bucket_slots(), "bucket in range");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_merges_equal_unsharded_execute() {
    // the sharding acceptance matrix: chunk- and window-sharded merges are
    // bit-exact against the unsharded msm::execute result across a
    // backend × shard-count grid, with shuffled arrival order
    check_with(Config { cases: 5, seed: 0x5A4D }, "shard merge == execute", |rng| {
        let m = 16 + rng.below(180) as usize;
        let k = 4 + rng.below(9) as u32;
        let slicing = if rng.bool() { Slicing::Signed } else { Slicing::Unsigned };
        let cfg = MsmConfig {
            window_bits: k,
            reduction: Reduction::Recursive { k2: 3 },
            slicing,
            ..Default::default()
        };
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        for backend in [
            Backend::Pippenger,
            Backend::Parallel { threads: 1 + rng.below(4) as usize },
            Backend::BatchAffine,
        ] {
            let want = msm::execute(backend, &w.points, &w.scalars, &cfg);
            for shards in [1usize, 2, 3, 5] {
                for specs in
                    [partial::chunk_specs(m, shards), partial::window_specs(windows, shards)]
                {
                    let mut parts: Vec<PartialMsm<Bn254G1>> = specs
                        .iter()
                        .enumerate()
                        .map(|(i, s)| PartialMsm {
                            index: i,
                            spec: *s,
                            output: partial::execute_shard(
                                backend, &w.points, &w.scalars, &cfg, s,
                            ),
                        })
                        .collect();
                    parts.reverse(); // completion order must not matter
                    let got = partial::merge(&mut parts);
                    prop_assert!(
                        got.eq_point(&want),
                        "m={m} k={k} {slicing:?} {backend:?} shards={shards} {specs:?}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn glv_decomposition_roundtrips_mod_r() {
    use ifzkp::ec::CurveParams;
    use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
    use ifzkp::ff::{bigint, Field, FieldParams, Fp};
    use ifzkp::util::rng::Rng;

    fn check<C: CurveParams, P: FieldParams<4>>(rng: &mut Rng, bits: u32) -> Result<(), String> {
        let p = C::glv().ok_or_else(|| format!("{}: GLV params missing", C::NAME))?;
        // pinned: both halves are genuinely half-width (the lattice bound
        // sits just above bits/2 for a balanced basis)
        prop_assert!(p.half_bits <= 130, "{}: half_bits {}", C::NAME, p.half_bits);
        let lambda = Fp::<P, 4>::from_canonical(p.lambda).ok_or("lambda not canonical")?;
        // λ² + λ + 1 ≡ 0 (mod r): the cube-root minimal polynomial
        prop_assert!(
            lambda.square().add(&lambda).add(&Fp::<P, 4>::one()).is_zero(),
            "{}: lambda not a primitive cube root",
            C::NAME
        );
        for _ in 0..12 {
            let mut k = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()];
            for (i, limb) in k.iter_mut().enumerate() {
                let lo = 64 * i as u32;
                if lo >= bits {
                    *limb = 0;
                } else if bits - lo < 64 {
                    *limb &= (1u64 << (bits - lo)) - 1;
                }
            }
            let split = p.decompose(&k);
            for (label, mag) in [("k1", &split.k1), ("k2", &split.k2)] {
                let w = bigint::msb(mag).map_or(0, |b| b as u32 + 1);
                prop_assert!(
                    w <= p.half_bits,
                    "{}: {label} is {w} bits > bound {}",
                    C::NAME,
                    p.half_bits
                );
            }
            // exact congruence: k1 + k2·λ ≡ k (mod r)
            let signed = |neg: bool, mag: &[u64; 4]| {
                let v = Fp::<P, 4>::from_limbs_reduce(*mag);
                if neg {
                    v.neg()
                } else {
                    v
                }
            };
            let lhs = signed(split.k1_neg, &split.k1)
                .add(&signed(split.k2_neg, &split.k2).mul(&lambda));
            let rhs = Fp::<P, 4>::from_limbs_reduce(k);
            prop_assert!(lhs == rhs, "{}: congruence failed for {k:?}", C::NAME);
        }
        Ok(())
    }

    check_with(Config { cases: 6, seed: 0x61F }, "glv round-trip", |rng| {
        check::<Bn254G1, Bn254FrParams>(rng, 254)?;
        check::<ifzkp::ec::Bls12381G1, Bls12381FrParams>(rng, 255)?;
        Ok(())
    });
}

#[test]
fn glv_matches_full_across_backends_slicings_and_shards() {
    // the GLV acceptance matrix: backend × slicing × shard shape, all
    // bit-identical to the non-GLV result
    check_with(Config { cases: 3, seed: 0x61F2 }, "glv == full", |rng| {
        let m = 16 + rng.below(140) as usize;
        let k = 6 + rng.below(7) as u32;
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let full_cfg = MsmConfig::new(k, Reduction::Recursive { k2: 3 });
        let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &full_cfg);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            let glv_cfg = MsmConfig { slicing, ..full_cfg.glv() };
            for backend in [
                Backend::Pippenger,
                Backend::Parallel { threads: 1 + rng.below(4) as usize },
                Backend::BatchAffine,
                Backend::BatchAffineParallel { threads: 2 },
            ] {
                let got = msm::execute(backend, &w.points, &w.scalars, &glv_cfg);
                prop_assert!(got.eq_point(&want), "m={m} k={k} {slicing:?} {backend:?}");
            }
            // both shard shapes, shuffled arrival: merged GLV partials
            // must equal the unsharded result (shards decompose
            // consistently — per point, deterministically)
            let windows = MsmPlan::for_curve::<Bn254G1>(&glv_cfg).windows;
            for shards in [2usize, 3] {
                for specs in
                    [partial::chunk_specs(m, shards), partial::window_specs(windows, shards)]
                {
                    let mut parts: Vec<PartialMsm<Bn254G1>> = specs
                        .iter()
                        .enumerate()
                        .map(|(i, s)| PartialMsm {
                            index: i,
                            spec: *s,
                            output: partial::execute_shard(
                                Backend::Pippenger,
                                &w.points,
                                &w.scalars,
                                &glv_cfg,
                                s,
                            ),
                        })
                        .collect();
                    parts.reverse();
                    prop_assert!(
                        partial::merge(&mut parts).eq_point(&want),
                        "m={m} k={k} {slicing:?} shards={shards} {specs:?}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn chunked_matches_pippenger_full_matrix() {
    // the chunk-parallel acceptance matrix: {1, 2, 4, 32} threads ×
    // {Full, Glv} × {Unsigned, Signed} × both curves, every cell
    // eq_point-identical to msm::execute(Backend::Pippenger, …)
    fn case<C: ifzkp::ec::CurveParams>(rng: &mut ifzkp::util::rng::Rng) -> Result<(), String> {
        let m = 8 + rng.below(140) as usize;
        let k = 4 + rng.below(9) as u32;
        let w = points::workload::<C>(m, rng.next_u64());
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for glv in [false, true] {
                let mut cfg = MsmConfig {
                    window_bits: k,
                    reduction: Reduction::Recursive { k2: 3 },
                    slicing,
                    ..Default::default()
                };
                if glv {
                    cfg = cfg.glv();
                }
                let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
                for threads in [1usize, 2, 4, 32] {
                    let got = msm::execute(
                        Backend::Chunked { threads },
                        &w.points,
                        &w.scalars,
                        &cfg,
                    );
                    prop_assert!(
                        got.eq_point(&want),
                        "{} m={m} k={k} {slicing:?} glv={glv} threads={threads}",
                        C::NAME
                    );
                }
            }
        }
        Ok(())
    }
    check_with(Config { cases: 3, seed: 0xC44C }, "chunked == pippenger", |rng| {
        case::<Bn254G1>(rng)?;
        case::<ifzkp::ec::Bls12381G1>(rng)?;
        Ok(())
    });
}

#[test]
fn precomputed_matches_pippenger_full_matrix() {
    // the fixed-base acceptance matrix: table-fed MSM × {Full, Glv} ×
    // {Unsigned, Signed} × both curves × both shard policies, every cell
    // eq_point-identical to the live Pippenger reference — plus random
    // sub-ranges through the table and the multi-threaded backends at
    // {1, 2, 32} threads against the same table output
    fn case<C: ifzkp::ec::CurveParams>(rng: &mut ifzkp::util::rng::Rng) -> Result<(), String> {
        let m = 8 + rng.below(140) as usize;
        let k = 4 + rng.below(9) as u32;
        let w = points::workload::<C>(m, rng.next_u64());
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for glv in [false, true] {
                let mut cfg = MsmConfig {
                    window_bits: k,
                    reduction: Reduction::Recursive { k2: 3 },
                    slicing,
                    ..Default::default()
                };
                if glv {
                    cfg = cfg.glv();
                }
                let tag = format!("{} m={m} k={k} {slicing:?} glv={glv}", C::NAME);
                let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
                // the dispatch arm (one-shot table build inside execute)
                let got = msm::execute(Backend::Precomputed, &w.points, &w.scalars, &cfg);
                prop_assert!(got.eq_point(&want), "dispatch {tag}");
                // an explicit table serves the whole set and random ranges
                let table = msm::PrecompTable::<C>::build(&w.points, &cfg);
                prop_assert!(table.msm(&w.scalars).eq_point(&want), "table {tag}");
                let lo = rng.below(m as u64 + 1) as usize;
                let hi = lo + rng.below((m - lo) as u64 + 1) as usize;
                let sub = msm::execute(
                    Backend::Pippenger,
                    &w.points[lo..hi],
                    &w.scalars[lo..hi],
                    &cfg,
                );
                prop_assert!(
                    table.msm_range(lo, &w.scalars[lo..hi]).eq_point(&sub),
                    "range {lo}..{hi} {tag}"
                );
                // the multi-threaded live backends agree with the table at
                // every thread count — the mid-run fallback contract
                for threads in [1usize, 2, 32] {
                    let live = msm::execute(
                        Backend::Chunked { threads },
                        &w.points,
                        &w.scalars,
                        &cfg,
                    );
                    prop_assert!(live.eq_point(&got), "threads={threads} {tag}");
                }
                // both shard shapes, shuffled arrival, with the table-fed
                // backend executing the point-chunk shards
                let windows = MsmPlan::for_curve::<C>(&cfg).windows;
                for shards in [2usize, 3] {
                    for specs in
                        [partial::chunk_specs(m, shards), partial::window_specs(windows, shards)]
                    {
                        let mut parts: Vec<PartialMsm<C>> = specs
                            .iter()
                            .enumerate()
                            .map(|(i, s)| PartialMsm {
                                index: i,
                                spec: *s,
                                output: partial::execute_shard(
                                    Backend::Precomputed,
                                    &w.points,
                                    &w.scalars,
                                    &cfg,
                                    s,
                                ),
                            })
                            .collect();
                        parts.reverse(); // completion order must not matter
                        prop_assert!(
                            partial::merge(&mut parts).eq_point(&want),
                            "shards={shards} {specs:?} {tag}"
                        );
                    }
                }
            }
        }
        Ok(())
    }
    check_with(Config { cases: 3, seed: 0x9CAC }, "precomputed == pippenger", |rng| {
        case::<Bn254G1>(rng)?;
        case::<ifzkp::ec::Bls12381G1>(rng)?;
        Ok(())
    });
}

#[test]
fn shard_pool_through_chunked_backend_matches_direct() {
    // ShardPool's native devices execute shards on the chunked backend;
    // the pool's deterministic merge must stay invisible next to the
    // direct (unsharded) dispatch, for both shard shapes and with more
    // threads per device than the plan has windows
    use ifzkp::coordinator::shard::ShardPool;
    check_with(Config { cases: 4, seed: 0x5CCD }, "pool(chunked) == execute", |rng| {
        let m = 32 + rng.below(200) as usize;
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let cfg = if rng.bool() { MsmConfig::default() } else { MsmConfig::default().glv() };
        let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        for policy in [partial::ShardPolicy::ChunkPoints, partial::ShardPolicy::WindowRange] {
            let pool = ShardPool::<Bn254G1>::native(3, 32).with_policy(policy);
            let got = pool
                .execute(&w.points, &w.scalars, &cfg)
                .map_err(|e| format!("pool failed: {e:#}"))?;
            prop_assert!(got.eq_point(&want), "m={m} {policy:?}");
        }
        Ok(())
    });
}

#[test]
fn parallel_equals_serial_random_threads() {
    check_with(Config { cases: 8, seed: 0xB0B }, "parallel == serial", |rng| {
        let m = 16 + rng.below(150) as usize;
        let threads = 1 + rng.below(9) as usize;
        let w = points::workload::<Bn254G1>(m, rng.next_u64());
        let cfg = MsmConfig::default();
        let a = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let b = msm::parallel::msm(&w.points, &w.scalars, &cfg, threads);
        prop_assert!(a.eq_point(&b), "threads={threads}");
        Ok(())
    });
}

#[test]
fn ddr_cache_invariants() {
    check_with(Config { cases: 64, seed: 0xCACE }, "DDR cache invariants", |rng| {
        let cap = 1000 + rng.below(9000);
        let mut ddr = DeviceDdr::new(cap);
        let mut resident_model: std::collections::HashSet<u64> = Default::default();
        for _ in 0..50 {
            let id = rng.below(12);
            let bytes = 100 + rng.below(cap);
            match ddr.admit(PointSetId(id), bytes) {
                Admission::Hit => {
                    prop_assert!(resident_model.contains(&id), "hit on non-resident {id}");
                }
                Admission::Miss { upload_bytes, .. } => {
                    // a re-admission at a grown size uploads only the
                    // delta; a fresh admission uploads the whole set
                    prop_assert!(
                        upload_bytes >= 1 && upload_bytes <= bytes,
                        "upload bytes {upload_bytes} outside (0, {bytes}]"
                    );
                    resident_model.insert(id);
                }
                Admission::TooLarge => {
                    prop_assert!(bytes > cap, "TooLarge but fits: {bytes} <= {cap}");
                    continue;
                }
            }
            prop_assert!(ddr.used_bytes() <= cap, "over capacity");
            // the model over-approximates (evictions happen underneath);
            // prune it to the truth and check agreement
            resident_model.retain(|&x| ddr.is_resident(PointSetId(x)));
            prop_assert!(
                resident_model.len() == ddr.resident_count(),
                "residency divergence"
            );
        }
        Ok(())
    });
}

#[test]
fn router_routes_and_places_correctly() {
    check_with(Config { cases: 64, seed: 0x40FE }, "router placement", |rng| {
        let ndev = 1 + rng.below(4) as usize;
        let caps: Vec<u64> = (0..ndev).map(|_| 1000 + rng.below(5000)).collect();
        let mut ddrs: Vec<DeviceDdr> = caps.iter().map(|&c| DeviceDdr::new(c)).collect();
        let loads: Vec<usize> = (0..ndev).map(|_| rng.below(100) as usize).collect();
        for _ in 0..20 {
            let ps = PointSetId(rng.below(6));
            let bytes = 1 + rng.below(7000);
            let feasible = caps.iter().any(|&c| bytes <= c);
            match router::route(&mut ddrs, &loads, ps, bytes) {
                None => prop_assert!(!feasible, "router refused feasible {bytes}"),
                Some(r) => {
                    prop_assert!(r.device < ndev, "device index out of range");
                    prop_assert!(
                        ddrs[r.device].is_resident(ps),
                        "routed device must hold the set afterwards"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn router_affinity_is_sticky() {
    check_with(Config { cases: 32, seed: 0x57CC }, "affinity stickiness", |rng| {
        let mut ddrs: Vec<DeviceDdr> = (0..3).map(|_| DeviceDdr::new(10_000)).collect();
        let loads =
            vec![rng.below(10) as usize, rng.below(10) as usize, rng.below(10) as usize];
        let ps = PointSetId(1);
        let first = router::route(&mut ddrs, &loads, ps, 500).ok_or("must route")?;
        for _ in 0..5 {
            let again = router::route(&mut ddrs, &loads, ps, 500).ok_or("must route")?;
            prop_assert!(again.admission == Admission::Hit, "expected hit");
            prop_assert!(again.device == first.device, "affinity moved");
        }
        Ok(())
    });
}

#[test]
fn streamed_msm_matches_resident_matrix() {
    // the streaming acceptance matrix: chunk sizes {1, 7, 2^10, m} ×
    // both curves × {Full, Glv} × chunked {1, 4} threads, every cell
    // bit-identical to the resident execute; chunk=1 runs on a small m
    // (per-point chunks at 2^10+ points would dominate the suite), the
    // ragged-tail chunks on m > 2^10. Both shard shapes cross-check the
    // same reference, so streamed folds and sharded merges agree too.
    use ifzkp::msm::stream::{msm_stream, SlicePoints, SliceScalars};
    use ifzkp::util::mem::MemLedger;
    fn case<C: ifzkp::ec::CurveParams>(
        rng: &mut ifzkp::util::rng::Rng,
        m: usize,
        chunks: &[usize],
    ) -> Result<(), String> {
        let w = points::workload::<C>(m, rng.next_u64());
        for glv in [false, true] {
            let mut cfg = MsmConfig::new(8, Reduction::Recursive { k2: 3 });
            if glv {
                cfg = cfg.glv();
            }
            let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
            for threads in [1usize, 4] {
                for &chunk in chunks {
                    let chunk = chunk.min(m).max(1);
                    let ledger = MemLedger::unlimited();
                    let mut ps = SlicePoints::new(&w.points);
                    let mut ss = SliceScalars::new(&w.scalars);
                    let got = msm_stream(
                        &mut ps,
                        &mut ss,
                        Backend::Chunked { threads },
                        &cfg,
                        chunk,
                        &ledger,
                    )
                    .map_err(|e| format!("stream failed: {e}"))?;
                    prop_assert!(
                        got.eq_point(&want),
                        "{} m={m} glv={glv} threads={threads} chunk={chunk}",
                        C::NAME
                    );
                    prop_assert!(
                        ledger.live_bytes() == 0,
                        "{} chunk={chunk}: charges leaked",
                        C::NAME
                    );
                }
            }
            // both shard shapes merge to the same reference the streamed
            // folds just matched
            let windows = MsmPlan::for_curve::<C>(&cfg).windows;
            for specs in [partial::chunk_specs(m, 3), partial::window_specs(windows, 3)] {
                let mut parts: Vec<PartialMsm<C>> = specs
                    .iter()
                    .enumerate()
                    .map(|(i, s)| PartialMsm {
                        index: i,
                        spec: *s,
                        output: partial::execute_shard(
                            Backend::Pippenger,
                            &w.points,
                            &w.scalars,
                            &cfg,
                            s,
                        ),
                    })
                    .collect();
                parts.reverse();
                prop_assert!(
                    partial::merge(&mut parts).eq_point(&want),
                    "{} m={m} glv={glv} {specs:?}",
                    C::NAME
                );
            }
        }
        Ok(())
    }
    check_with(Config { cases: 2, seed: 0x57E4 }, "streamed == resident", |rng| {
        // small m: per-point (chunk=1) and tiny chunks
        let small = 24 + rng.below(40) as usize;
        case::<Bn254G1>(rng, small, &[1, 7, usize::MAX])?;
        case::<ifzkp::ec::Bls12381G1>(rng, small, &[1, 7, usize::MAX])?;
        // m > 2^10: the 2^10 chunk leaves a ragged tail, plus one-shot m
        let big = 1025 + rng.below(120) as usize;
        case::<Bn254G1>(rng, big, &[7, 1 << 10, usize::MAX])?;
        case::<ifzkp::ec::Bls12381G1>(rng, big, &[7, 1 << 10, usize::MAX])?;
        Ok(())
    });
}

#[test]
fn stream_faults_surface_typed_errors_and_retry_identically() {
    // fault injection: a reader failing at chunk k (and one silently
    // under-delivering) must surface a typed StreamError — never a wrong
    // result, hang, or leaked ledger charge — and a fresh stream retries
    // to the bit-identical answer
    use ifzkp::msm::stream::{
        msm_stream, FailingPoints, ShortPoints, SlicePoints, SliceScalars, StreamError,
    };
    use ifzkp::util::mem::MemLedger;
    let m = 100usize;
    let chunk = 16usize;
    let w = points::workload::<Bn254G1>(m, 42);
    let cfg = MsmConfig::auto(m);
    let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
    for fail_at in [0usize, 2, 6] {
        let ledger = MemLedger::unlimited();
        let mut ps = FailingPoints::new(SlicePoints::new(&w.points), fail_at);
        let mut ss = SliceScalars::new(&w.scalars);
        let err = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, chunk, &ledger)
            .expect_err("injected failure must surface");
        assert!(matches!(err, StreamError::Read { .. }), "fail_at={fail_at}: {err:?}");
        assert!(err.to_string().contains(&format!("chunk {fail_at}")), "{err}");
        assert_eq!(ledger.live_bytes(), 0, "failed stream leaked its charge");
        let mut ps = SlicePoints::new(&w.points);
        let mut ss = SliceScalars::new(&w.scalars);
        let got = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, chunk, &ledger)
            .expect("fresh stream retries cleanly");
        assert!(got.eq_point(&want), "retry diverged after fail_at={fail_at}");
    }
    for short_at in [0usize, 3] {
        let ledger = MemLedger::unlimited();
        let mut ps = ShortPoints::new(SlicePoints::new(&w.points), short_at);
        let mut ss = SliceScalars::new(&w.scalars);
        let err = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, chunk, &ledger)
            .expect_err("short chunk must surface");
        match err {
            StreamError::ShortChunk { chunk: c, expected, got } => {
                assert_eq!(c, short_at);
                assert_eq!(expected, 16);
                assert_eq!(got, 15);
            }
            other => panic!("expected ShortChunk, got {other:?}"),
        }
        assert_eq!(ledger.live_bytes(), 0, "short stream leaked its charge");
    }
}

#[test]
fn ragged_tail_ranges_regression_m_prime() {
    // audit regression for the chunk-offset math (`msm_range` /
    // window-range shards): m prime (2053) with a 2^10 chunk leaves a
    // 5-point tail, so every boundary is a non-multiple-of-chunk offset.
    // Each range must equal its direct sub-MSM, the folded ranges and the
    // streamed fold must equal the resident reference, and window-range
    // shard merges must agree at shard counts that do not divide the plan.
    use ifzkp::ec::Jacobian;
    use ifzkp::msm::stream::{msm_stream, SlicePoints, SliceScalars};
    use ifzkp::util::mem::MemLedger;
    let m = 2053usize; // prime — not a multiple of any chunk size
    let chunk = 1usize << 10;
    let w = points::workload::<Bn254G1>(m, 7);
    let cfg = MsmConfig::new(12, Reduction::Recursive { k2: 6 }).glv();
    let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
    let table = msm::PrecompTable::<Bn254G1>::build(&w.points, &cfg);
    let mut acc = Jacobian::<Bn254G1>::infinity();
    let mut lo = 0usize;
    while lo < m {
        let hi = (lo + chunk).min(m);
        let part = table.msm_range(lo, &w.scalars[lo..hi]);
        let direct =
            msm::execute(Backend::Pippenger, &w.points[lo..hi], &w.scalars[lo..hi], &cfg);
        assert!(part.eq_point(&direct), "msm_range {lo}..{hi} != direct sub-MSM");
        acc = acc.add(&part);
        lo = hi;
    }
    assert!(acc.eq_point(&want), "folded table ranges != resident reference");
    let ledger = MemLedger::unlimited();
    let mut ps = SlicePoints::new(&w.points);
    let mut ss = SliceScalars::new(&w.scalars);
    let streamed = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, chunk, &ledger)
        .expect("streamed fold");
    assert!(streamed.eq_point(&want), "streamed fold != resident reference");
    let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
    for shards in [2usize, 3, 5] {
        for specs in [partial::chunk_specs(m, shards), partial::window_specs(windows, shards)] {
            let mut parts: Vec<PartialMsm<Bn254G1>> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| PartialMsm {
                    index: i,
                    spec: *s,
                    output: partial::execute_shard(
                        Backend::Pippenger,
                        &w.points,
                        &w.scalars,
                        &cfg,
                        s,
                    ),
                })
                .collect();
            parts.reverse();
            assert!(
                partial::merge(&mut parts).eq_point(&want),
                "shards={shards} {specs:?} != resident reference"
            );
        }
    }
}

#[test]
fn reduction_strategies_equivalent_on_random_buckets() {
    use ifzkp::ec::Jacobian;
    check_with(Config { cases: 10, seed: 0xBCE7 }, "reduce equivalence", |rng| {
        let k = 3 + rng.below(7) as u32;
        let nb = 1usize << k;
        let g = Jacobian::<Bn254G1>::generator();
        let mut buckets = vec![Jacobian::<Bn254G1>::infinity(); nb];
        for b in buckets.iter_mut() {
            if rng.f64() < 0.4 {
                let mult = 1 + rng.below(1 << 20);
                *b = ifzkp::ec::scalar::mul::<Bn254G1>(&g, &[mult, 0, 0, 0]);
            }
        }
        let want = msm::pippenger::reduce_running_sum(&buckets);
        let k2 = 1 + rng.below(k as u64) as u32;
        let got = msm::pippenger::reduce_recursive(&buckets, k, k2);
        prop_assert!(got.eq_point(&want), "k={k} k2={k2}");
        Ok(())
    });
}
