//! Integration: the coordinator end-to-end — correctness of served results,
//! affinity behaviour, backpressure, batching, sharded multi-device
//! execution (merge determinism, retry, atomic group failure), shutdown,
//! and the admission tier (typed quota rejections, atomic shed of shard
//! groups, counter reconciliation under concurrency, derived bounds).

use ifzkp::coordinator::devices::{DeviceBackend, EngineHolder};
use ifzkp::coordinator::{
    Coordinator, CoordinatorConfig, DeviceDesc, JobError, Lane, PointSetRegistry, Quota,
    RejectReason, TenantId,
};
use ifzkp::coordinator::batcher::{BatchPolicy, Batcher};
use ifzkp::coordinator::request::ShardAssignment;
use ifzkp::ec::{points, Affine, Bn254G1, Jacobian, ScalarLimbs};
use ifzkp::fpga::{CurveId, SabConfig};
use ifzkp::msm::{self, Backend, MsmConfig, ShardPolicy};
use std::sync::Arc;

fn registry_with_sets(
    sizes: &[usize],
) -> (PointSetRegistry<Bn254G1>, Vec<ifzkp::coordinator::PointSetId>, Vec<Vec<ifzkp::ec::Affine<Bn254G1>>>)
{
    let mut reg = PointSetRegistry::new();
    let mut ids = Vec::new();
    let mut raw = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let pts = points::generate_points_walk::<Bn254G1>(n, 5000 + i as u64);
        ids.push(reg.register(pts.clone()));
        raw.push(pts);
    }
    (reg, ids, raw)
}

#[test]
fn served_results_match_direct_computation() {
    let (reg, ids, raw) = registry_with_sets(&[256, 256]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![
            DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 30),
            DeviceDesc::<Bn254G1>::native(2),
        ],
        reg,
    );
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for (i, &ps) in ids.iter().cycle().take(8).enumerate() {
        let scalars = Arc::new(points::generate_scalars(256, 254, 100 + i as u64));
        expected.push(msm::msm(&raw[if i % 2 == 0 { 0 } else { 1 }], &scalars));
        rxs.push(coord.submit(ps, scalars).expect("submit ok").1);
    }
    let mut pairs = Vec::new();
    for (rx, want) in rxs.into_iter().zip(expected) {
        let res = rx.recv().expect("job completes");
        assert!(res.is_ok(), "unexpected device failure: {:?}", res.error);
        assert!(res.service_s >= 0.0 && res.device_s > 0.0);
        pairs.push((res.output, want));
    }
    // one RLC fold audits all eight served results at once
    assert!(msm::batch_eq(&pairs, 0xC0DE), "served results mismatch");
    let snap = coord.counters.snapshot();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.submitted, 8);
    coord.shutdown();
}

#[test]
fn affinity_hits_accumulate_for_hot_set() {
    let (reg, ids, _) = registry_with_sets(&[128]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            // batches of 1 so every submit is routed individually
            batch: BatchPolicy { max_batch: 1, max_wait: std::time::Duration::from_micros(100) },
            ..Default::default()
        },
        vec![DeviceDesc::<Bn254G1>::native(1), DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..10 {
        let scalars = Arc::new(points::generate_scalars(128, 254, i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.counters.snapshot();
    // first route uploads, the rest should hit
    assert_eq!(snap.affinity_misses, 1, "exactly one upload: {snap:?}");
    assert_eq!(snap.affinity_hits, 9, "{snap:?}");
    assert!(snap.hit_rate() > 0.85);
    coord.shutdown();
}

#[test]
fn unknown_point_set_rejected() {
    let (reg, _, _) = registry_with_sets(&[16]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let scalars = Arc::new(points::generate_scalars(16, 254, 1));
    assert!(coord.submit(ifzkp::coordinator::PointSetId(999), scalars).is_err());
    coord.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let (reg, ids, _) = registry_with_sets(&[512]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            queue_capacity: 2,
            batch: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_millis(50) },
            ..Default::default()
        },
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    // flood much faster than one slow device drains
    let mut accepted = 0;
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..200 {
        let scalars = Arc::new(points::generate_scalars(512, 254, i));
        match coord.submit(ids[0], scalars) {
            Ok((_, rx)) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected backpressure rejections (accepted={accepted})");
    for rx in rxs {
        let _ = rx.recv();
    }
    coord.shutdown();
}

#[test]
fn batching_groups_same_point_set() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(20) },
            ..Default::default()
        },
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..8 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 300 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.counters.snapshot();
    // 8 jobs in batches of ≤4 → at least 2 route decisions, at most 8;
    // affinity ⇒ exactly 1 miss
    assert_eq!(snap.affinity_misses, 1);
    assert!(snap.affinity_hits >= 1);
    coord.shutdown();
}

#[test]
fn shutdown_drains_pending_work() {
    let (reg, ids, _) = registry_with_sets(&[128]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..4 {
        let scalars = Arc::new(points::generate_scalars(128, 254, 400 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    coord.shutdown(); // must drain, not drop
    let mut done = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            done += 1;
        }
    }
    assert_eq!(done, 4, "shutdown must drain all accepted jobs");
}

/// An engine that always errors — injected through the public Engine
/// factory to exercise the device-failure path.
struct FailingEngine;

impl EngineHolder<Bn254G1> for FailingEngine {
    fn msm(
        &self,
        _points: &[Affine<Bn254G1>],
        _scalars: &[ScalarLimbs],
        _cfg: &MsmConfig,
    ) -> anyhow::Result<Jacobian<Bn254G1>> {
        Err(anyhow::anyhow!("injected device fault"))
    }
}

#[test]
fn device_failure_is_delivered_and_counted() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let failing = DeviceDesc {
        name: "failing-engine".into(),
        backend: DeviceBackend::Engine {
            factory: Box::new(|| Ok(Box::new(FailingEngine) as Box<dyn EngineHolder<Bn254G1>>)),
        },
        ddr_capacity: u64::MAX,
        msm_cfg: MsmConfig::default(),
    };
    let coord = Coordinator::start(CoordinatorConfig::default(), vec![failing], reg);
    let mut rxs = Vec::new();
    for i in 0..3 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 600 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        // the error is *delivered* (recv succeeds) — a dropped channel
        // would be indistinguishable from shutdown
        let res = rx.recv().expect("failure result must be delivered, not dropped");
        assert!(!res.is_ok(), "expected a failed result");
        assert!(res.error_message().unwrap().contains("injected device fault"));
        assert!(res.output.is_infinity());
    }
    let snap = coord.counters.snapshot();
    assert_eq!(snap.failed, 3, "{snap:?}");
    assert_eq!(snap.completed, 0, "{snap:?}");
    assert_eq!(snap.submitted, 3, "{snap:?}");
    coord.shutdown();
}

#[test]
fn successful_results_report_ok() {
    let (reg, ids, _) = registry_with_sets(&[32]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let scalars = Arc::new(points::generate_scalars(32, 254, 700));
    let (_, rx) = coord.submit(ids[0], scalars).unwrap();
    let res = rx.recv().unwrap();
    assert!(res.is_ok());
    assert!(res.error.is_none());
    assert_eq!(coord.counters.snapshot().failed, 0);
    coord.shutdown();
}

/// Acceptance size: 2^16 with `IFZKP_HEAVY_TESTS=1` (CI runs this in
/// release mode), a debug-friendly 2^11 otherwise — assertions identical.
fn sharded_msm_size() -> usize {
    if std::env::var("IFZKP_HEAVY_TESTS").is_ok() {
        1 << 16
    } else {
        1 << 11
    }
}

#[test]
fn sharded_msm_matches_single_device_execute_both_policies() {
    let m = sharded_msm_size();
    let (reg, ids, raw) = registry_with_sets(&[m]);
    // 4 simulated FPGA devices — the acceptance configuration
    let devices: Vec<DeviceDesc<Bn254G1>> = (0..4)
        .map(|_| DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 34))
        .collect();
    let cfg = CoordinatorConfig::default();
    let shard_cfg = cfg.shard_cfg;
    let coord = Coordinator::start(cfg, devices, reg);
    let scalars = Arc::new(points::generate_scalars(m, 254, 9001));
    // the single-device reference: plain msm::execute under the same plan
    let want = msm::execute(Backend::Parallel { threads: 2 }, &raw[0], &scalars, &shard_cfg);

    let mut audit = Vec::new();
    for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
        let (_, rx) = coord.submit_sharded(ids[0], scalars.clone(), policy).unwrap();
        let res = rx.recv().expect("sharded job completes");
        assert!(res.is_ok(), "{policy:?}: {:?}", res.error);
        assert!(res.device_s > 0.0, "{policy:?}: group makespan missing");
        audit.push((res.output, want));
    }
    // shard-merge audit: one RLC fold covers both policies' merges
    assert!(
        msm::batch_eq(&audit, 9001),
        "sharded results must be bit-identical to msm::execute"
    );
    let snap = coord.counters.snapshot();
    assert_eq!(snap.shard_groups, 2, "{snap:?}");
    assert_eq!(snap.completed, 2, "{snap:?}");
    assert_eq!(snap.shard_group_failures, 0, "{snap:?}");
    // the fan-out really spread: every device lane executed shards
    let shards_per_dev: Vec<u64> = coord
        .device_metrics
        .lanes()
        .iter()
        .map(|l| l.shards.load(std::sync::atomic::Ordering::Relaxed))
        .collect();
    assert_eq!(shards_per_dev.iter().sum::<u64>(), 8, "{shards_per_dev:?}");
    assert!(
        shards_per_dev.iter().all(|&s| s > 0),
        "shards must spread across all 4 devices: {shards_per_dev:?}"
    );
    coord.shutdown();
}

#[test]
fn sharded_submit_single_device_falls_back() {
    let (reg, ids, raw) = registry_with_sets(&[256]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let scalars = Arc::new(points::generate_scalars(256, 254, 9100));
    let (_, rx) = coord.submit_sharded(ids[0], scalars.clone(), ShardPolicy::ChunkPoints).unwrap();
    let res = rx.recv().unwrap();
    assert!(res.is_ok());
    assert!(res.output.eq_point(&msm::msm(&raw[0], &scalars)));
    // degraded to the plain path: no shard group was formed
    assert_eq!(coord.counters.snapshot().shard_groups, 0);
    coord.shutdown();
}

#[test]
fn sharded_group_retries_failed_shard_on_healthy_device() {
    let (reg, ids, raw) = registry_with_sets(&[512]);
    // device 0 always fails; device 1 is healthy — the shard landing on 0
    // must be retried on 1 and the merged result still be exact
    let failing = DeviceDesc {
        name: "failing-engine".into(),
        backend: DeviceBackend::Engine {
            factory: Box::new(|| Ok(Box::new(FailingEngine) as Box<dyn EngineHolder<Bn254G1>>)),
        },
        ddr_capacity: u64::MAX,
        msm_cfg: MsmConfig::default(),
    };
    let cfg = CoordinatorConfig::default();
    let shard_cfg = cfg.shard_cfg;
    let coord =
        Coordinator::start(cfg, vec![failing, DeviceDesc::<Bn254G1>::native(2)], reg);
    let scalars = Arc::new(points::generate_scalars(512, 254, 9200));
    let want = msm::execute(Backend::Pippenger, &raw[0], &scalars, &shard_cfg);
    let (_, rx) = coord.submit_sharded(ids[0], scalars, ShardPolicy::ChunkPoints).unwrap();
    let res = rx.recv().expect("retried group completes");
    assert!(res.is_ok(), "group must survive one failing device: {:?}", res.error);
    assert!(res.output.eq_point(&want));
    let snap = coord.counters.snapshot();
    assert!(snap.shard_retries >= 1, "{snap:?}");
    assert_eq!(snap.shard_group_failures, 0, "{snap:?}");
    assert_eq!(snap.completed, 1, "{snap:?}");
    coord.shutdown();
}

#[test]
fn sharded_group_fails_atomically_when_every_device_fails() {
    let (reg, ids, _) = registry_with_sets(&[128]);
    let mk_failing = || DeviceDesc {
        name: "failing-engine".into(),
        backend: DeviceBackend::Engine {
            factory: Box::new(|| Ok(Box::new(FailingEngine) as Box<dyn EngineHolder<Bn254G1>>)),
        },
        ddr_capacity: u64::MAX,
        msm_cfg: MsmConfig::default(),
    };
    let coord =
        Coordinator::start(CoordinatorConfig::default(), vec![mk_failing(), mk_failing()], reg);
    let scalars = Arc::new(points::generate_scalars(128, 254, 9300));
    let (_, rx) = coord.submit_sharded(ids[0], scalars, ShardPolicy::ChunkPoints).unwrap();
    // atomic failure is *delivered* through JobResult::error, not a
    // dropped channel
    let res = rx.recv().expect("atomic failure must be delivered");
    assert!(!res.is_ok());
    assert!(res.error_message().unwrap().contains("atomically"), "{:?}", res.error);
    assert!(res.output.is_infinity());
    let snap = coord.counters.snapshot();
    assert_eq!(snap.shard_group_failures, 1, "{snap:?}");
    assert_eq!(snap.completed, 0, "{snap:?}");
    coord.shutdown();
}

#[test]
fn sharded_metrics_report_utilization_and_skew() {
    let (reg, ids, _) = registry_with_sets(&[1024]);
    let devices: Vec<DeviceDesc<Bn254G1>> =
        (0..3).map(|_| DeviceDesc::<Bn254G1>::native(1)).collect();
    let coord = Coordinator::start(CoordinatorConfig::default(), devices, reg);
    for i in 0..3 {
        let scalars = Arc::new(points::generate_scalars(1024, 254, 9400 + i));
        let (_, rx) = coord.submit_sharded(ids[0], scalars, ShardPolicy::ChunkPoints).unwrap();
        assert!(rx.recv().unwrap().is_ok());
    }
    let snap = coord.counters.snapshot();
    assert_eq!(snap.shard_groups, 3);
    // skew was sampled once per group and stays a valid ratio
    assert!(snap.mean_shard_skew() >= 0.0 && snap.mean_shard_skew() <= 1.0);
    let util = coord.device_metrics.utilization();
    assert_eq!(util.len(), 3);
    assert!(util.iter().any(|&u| u > 0.0), "some device must show busy time: {util:?}");
    coord.shutdown();
}

/// Regression (batcher flush ordering): a shard group must come out of the
/// batcher in exactly one flush — `max_batch` must not cut it mid-group,
/// and `expired`/`drain` must never emit a partial group.
#[test]
fn batcher_never_splits_a_shard_group_across_flushes() {
    let policy = BatchPolicy { max_batch: 2, max_wait: std::time::Duration::from_millis(1) };
    let mut b = Batcher::new(policy);
    let job = |id: u64, shard: Option<ShardAssignment>| ifzkp::coordinator::MsmJob {
        id: ifzkp::coordinator::JobId(id),
        point_set: ifzkp::coordinator::PointSetId(1),
        scalars: Arc::new(vec![[id, 0, 0, 0]]),
        submitted_at: std::time::Instant::now(),
        shard,
    };
    // interleave plain jobs with a 5-shard group under max_batch = 2
    assert!(b.push(job(1, None)).is_none());
    let mut flushes: Vec<Vec<ifzkp::coordinator::MsmJob>> = Vec::new();
    for index in 0..4u32 {
        let pushed = b.push(job(10 + index as u64, Some(ShardAssignment {
            group: 7,
            index,
            total: 5,
        })));
        assert!(pushed.is_none(), "group must not flush before member 5 (at {index})");
        // expiry in between must hold the incomplete group back
        let late = std::time::Instant::now() + std::time::Duration::from_secs(1);
        for (_, jobs) in b.expired(late) {
            assert!(jobs.iter().all(|j| j.shard.is_none()), "expired() split the group");
            flushes.push(jobs);
        }
    }
    let (_, group_flush) = b
        .push(job(14, Some(ShardAssignment { group: 7, index: 4, total: 5 })))
        .expect("complete group flushes");
    assert_eq!(group_flush.len(), 5, "the whole group in one flush");
    assert!(group_flush.iter().all(|j| j.shard.map(|s| s.group) == Some(7)));
    for jobs in b.drain() {
        assert!(jobs.1.iter().all(|j| j.shard.is_none()), "no group remnants after its flush");
    }
}

// ---------------------------------------------------------------- admission

#[test]
fn quota_exhaustion_rejects_typed_instead_of_deadlocking() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let tenant = TenantId(42);
    // rate 0: the bucket never refills, so exactly `burst` jobs admit
    coord.set_tenant_quota(tenant, Quota { rate_per_s: 0.0, burst: 2.0 });
    let mut admitted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..6 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 8000 + i));
        match coord.submit_admitted(tenant, Lane::Interactive, None, ids[0], scalars) {
            Ok(job) => admitted.push(job),
            Err(e) => {
                rejected += 1;
                assert_eq!(
                    e,
                    JobError::Rejected {
                        lane: Lane::Interactive,
                        reason: RejectReason::QuotaExhausted,
                    }
                );
            }
        }
    }
    assert_eq!(admitted.len(), 2, "burst of 2 admits exactly 2");
    assert_eq!(rejected, 4);
    // the admitted jobs still complete — rejection is a clean refusal at
    // the front door, never a wedge of the serving path behind it
    for job in admitted {
        let res = job.recv().expect("admitted jobs complete");
        assert!(res.is_ok(), "{:?}", res.error);
    }
    let snap = coord.admission_snapshot();
    assert_eq!(snap.admitted_total(), 2, "{snap:?}");
    assert_eq!(snap.shed_by_reason[RejectReason::QuotaExhausted.index()], 4, "{snap:?}");
    assert_eq!(snap.completed_total(), 2, "{snap:?}");
    coord.shutdown();
}

#[test]
fn admission_shed_never_splits_a_shard_group() {
    let (reg, ids, raw) = registry_with_sets(&[512]);
    let cfg = CoordinatorConfig::default();
    let shard_cfg = cfg.shard_cfg;
    let coord = Coordinator::start(
        cfg,
        vec![DeviceDesc::<Bn254G1>::native(1), DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let tenant = TenantId(7);
    coord.set_tenant_quota(tenant, Quota { rate_per_s: 0.0, burst: 1.0 });
    let scalars = Arc::new(points::generate_scalars(512, 254, 8100));
    let want = msm::execute(Backend::Pippenger, &raw[0], &scalars, &shard_cfg);
    // the first group takes the one token and is admitted whole
    let job = coord
        .submit_sharded_admitted(
            tenant,
            Lane::Batch,
            None,
            ids[0],
            scalars.clone(),
            ShardPolicy::ChunkPoints,
        )
        .expect("first group admits");
    // the second group is ONE admission unit: shed whole, zero shards
    let err = coord
        .submit_sharded_admitted(
            tenant,
            Lane::Batch,
            None,
            ids[0],
            scalars.clone(),
            ShardPolicy::ChunkPoints,
        )
        .expect_err("second group must be shed");
    assert!(
        matches!(err, JobError::Rejected { reason: RejectReason::QuotaExhausted, .. }),
        "{err:?}"
    );
    let res = job.recv().expect("admitted group completes");
    assert!(res.is_ok(), "{:?}", res.error);
    assert!(res.output.eq_point(&want), "merged group result must stay bit-exact");
    let snap = coord.counters.snapshot();
    // exactly one group ever reached the dispatcher; the shed one left
    // no partial shards and no atomic-failure record behind
    assert_eq!(snap.shard_groups, 1, "{snap:?}");
    assert_eq!(snap.shard_group_failures, 0, "{snap:?}");
    let a = coord.admission_snapshot();
    assert_eq!(a.shed[Lane::Batch.index()], 1, "{a:?}");
    assert_eq!(a.completed_total(), 1, "{a:?}");
    coord.shutdown();
}

/// Every offer lands in exactly one of {admitted, shed}, and every
/// admitted job in exactly one of {completed, failed} — under concurrent
/// submitters on mixed lanes with a quota-capped tenant in the mix.
/// `IFZKP_HEAVY_TESTS=1` widens the thread/job counts.
#[test]
fn admission_counters_reconcile_under_concurrent_load() {
    let heavy = std::env::var("IFZKP_HEAVY_TESTS").is_ok();
    let (n_threads, per_thread) = if heavy { (8u64, 64u64) } else { (4u64, 16u64) };
    let (reg, ids, _) = registry_with_sets(&[128]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1), DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    // tenant 0 is tightly capped so the shed path is exercised too
    coord.set_tenant_quota(TenantId(0), Quota { rate_per_s: 0.0, burst: 4.0 });
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let coord = &coord;
            let ps = ids[0];
            s.spawn(move || {
                let lane = Lane::ALL[(t % 3) as usize];
                for i in 0..per_thread {
                    let scalars =
                        Arc::new(points::generate_scalars(128, 254, 8200 + t * 1000 + i));
                    if let Ok(job) = coord.submit_admitted(TenantId(t), lane, None, ps, scalars)
                    {
                        let res = job.recv().expect("admitted job completes");
                        assert!(res.is_ok(), "{:?}", res.error);
                    }
                }
            });
        }
    });
    let snap = coord.admission_snapshot();
    assert_eq!(snap.offered_total(), n_threads * per_thread, "{snap:?}");
    assert_eq!(snap.offered_total(), snap.admitted_total() + snap.shed_total(), "{snap:?}");
    assert_eq!(snap.admitted_total(), snap.completed_total() + snap.failed_total(), "{snap:?}");
    assert_eq!(snap.failed_total(), 0, "{snap:?}");
    assert!(snap.shed_total() > 0, "the capped tenant must have shed: {snap:?}");
    coord.shutdown();
}

#[test]
fn queue_capacity_derives_from_device_count() {
    // regression: the default used to be a fleet-blind 256 — a 1-device
    // pool admitted 256 queued jobs unbounded by any lane
    let (reg, _, _) = registry_with_sets(&[16]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    assert_eq!(coord.queue_capacity(), 32, "1 device → 32, not 256");
    assert_eq!(coord.lane_capacity(Lane::Interactive), 8, "lanes derive as devices × 8");
    coord.shutdown();
    // an explicit override still wins, and wider fleets scale up
    let (reg2, _, _) = registry_with_sets(&[16]);
    let coord2 = Coordinator::start(
        CoordinatorConfig { queue_capacity: 7, ..Default::default() },
        (0..3).map(|_| DeviceDesc::<Bn254G1>::native(1)).collect(),
        reg2,
    );
    assert_eq!(coord2.queue_capacity(), 7, "explicit override respected");
    assert_eq!(coord2.lane_capacity(Lane::BestEffort), 24);
    coord2.shutdown();
}

#[test]
fn latency_histogram_populated() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(2)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..6 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 500 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert_eq!(coord.latency.count(), 6);
    assert!(coord.latency.mean_secs() > 0.0);
    assert!(coord.latency.quantile_secs(0.99) >= coord.latency.quantile_secs(0.5));
    coord.shutdown();
}
