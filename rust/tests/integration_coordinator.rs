//! Integration: the coordinator end-to-end — correctness of served results,
//! affinity behaviour, backpressure, batching, shutdown.

use ifzkp::coordinator::devices::{DeviceBackend, EngineHolder};
use ifzkp::coordinator::{Coordinator, CoordinatorConfig, DeviceDesc, PointSetRegistry};
use ifzkp::coordinator::batcher::BatchPolicy;
use ifzkp::ec::{points, Affine, Bn254G1, Jacobian, ScalarLimbs};
use ifzkp::fpga::{CurveId, SabConfig};
use ifzkp::msm::{self, MsmConfig};
use std::sync::Arc;

fn registry_with_sets(
    sizes: &[usize],
) -> (PointSetRegistry<Bn254G1>, Vec<ifzkp::coordinator::PointSetId>, Vec<Vec<ifzkp::ec::Affine<Bn254G1>>>)
{
    let mut reg = PointSetRegistry::new();
    let mut ids = Vec::new();
    let mut raw = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let pts = points::generate_points_walk::<Bn254G1>(n, 5000 + i as u64);
        ids.push(reg.register(pts.clone()));
        raw.push(pts);
    }
    (reg, ids, raw)
}

#[test]
fn served_results_match_direct_computation() {
    let (reg, ids, raw) = registry_with_sets(&[256, 256]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![
            DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 30),
            DeviceDesc::<Bn254G1>::native(2),
        ],
        reg,
    );
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for (i, &ps) in ids.iter().cycle().take(8).enumerate() {
        let scalars = Arc::new(points::generate_scalars(256, 254, 100 + i as u64));
        expected.push(msm::msm(&raw[if i % 2 == 0 { 0 } else { 1 }], &scalars));
        rxs.push(coord.submit(ps, scalars).expect("submit ok").1);
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let res = rx.recv().expect("job completes");
        assert!(res.is_ok(), "unexpected device failure: {:?}", res.error);
        assert!(res.output.eq_point(&want), "served result mismatch");
        assert!(res.service_s >= 0.0 && res.device_s > 0.0);
    }
    let snap = coord.counters.snapshot();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.submitted, 8);
    coord.shutdown();
}

#[test]
fn affinity_hits_accumulate_for_hot_set() {
    let (reg, ids, _) = registry_with_sets(&[128]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            // batches of 1 so every submit is routed individually
            batch: BatchPolicy { max_batch: 1, max_wait: std::time::Duration::from_micros(100) },
            ..Default::default()
        },
        vec![DeviceDesc::<Bn254G1>::native(1), DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..10 {
        let scalars = Arc::new(points::generate_scalars(128, 254, i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.counters.snapshot();
    // first route uploads, the rest should hit
    assert_eq!(snap.affinity_misses, 1, "exactly one upload: {snap:?}");
    assert_eq!(snap.affinity_hits, 9, "{snap:?}");
    assert!(snap.hit_rate() > 0.85);
    coord.shutdown();
}

#[test]
fn unknown_point_set_rejected() {
    let (reg, _, _) = registry_with_sets(&[16]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let scalars = Arc::new(points::generate_scalars(16, 254, 1));
    assert!(coord.submit(ifzkp::coordinator::PointSetId(999), scalars).is_err());
    coord.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let (reg, ids, _) = registry_with_sets(&[512]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            queue_capacity: 2,
            batch: BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_millis(50) },
        },
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    // flood much faster than one slow device drains
    let mut accepted = 0;
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..200 {
        let scalars = Arc::new(points::generate_scalars(512, 254, i));
        match coord.submit(ids[0], scalars) {
            Ok((_, rx)) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected backpressure rejections (accepted={accepted})");
    for rx in rxs {
        let _ = rx.recv();
    }
    coord.shutdown();
}

#[test]
fn batching_groups_same_point_set() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let coord = Coordinator::start(
        CoordinatorConfig {
            batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(20) },
            ..Default::default()
        },
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..8 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 300 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.counters.snapshot();
    // 8 jobs in batches of ≤4 → at least 2 route decisions, at most 8;
    // affinity ⇒ exactly 1 miss
    assert_eq!(snap.affinity_misses, 1);
    assert!(snap.affinity_hits >= 1);
    coord.shutdown();
}

#[test]
fn shutdown_drains_pending_work() {
    let (reg, ids, _) = registry_with_sets(&[128]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..4 {
        let scalars = Arc::new(points::generate_scalars(128, 254, 400 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    coord.shutdown(); // must drain, not drop
    let mut done = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            done += 1;
        }
    }
    assert_eq!(done, 4, "shutdown must drain all accepted jobs");
}

/// An engine that always errors — injected through the public Engine
/// factory to exercise the device-failure path.
struct FailingEngine;

impl EngineHolder<Bn254G1> for FailingEngine {
    fn msm(
        &self,
        _points: &[Affine<Bn254G1>],
        _scalars: &[ScalarLimbs],
        _cfg: &MsmConfig,
    ) -> anyhow::Result<Jacobian<Bn254G1>> {
        Err(anyhow::anyhow!("injected device fault"))
    }
}

#[test]
fn device_failure_is_delivered_and_counted() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let failing = DeviceDesc {
        name: "failing-engine".into(),
        backend: DeviceBackend::Engine {
            factory: Box::new(|| Ok(Box::new(FailingEngine) as Box<dyn EngineHolder<Bn254G1>>)),
        },
        ddr_capacity: u64::MAX,
        msm_cfg: MsmConfig::default(),
    };
    let coord = Coordinator::start(CoordinatorConfig::default(), vec![failing], reg);
    let mut rxs = Vec::new();
    for i in 0..3 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 600 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        // the error is *delivered* (recv succeeds) — a dropped channel
        // would be indistinguishable from shutdown
        let res = rx.recv().expect("failure result must be delivered, not dropped");
        assert!(!res.is_ok(), "expected a failed result");
        assert!(res.error.as_deref().unwrap().contains("injected device fault"));
        assert!(res.output.is_infinity());
    }
    let snap = coord.counters.snapshot();
    assert_eq!(snap.failed, 3, "{snap:?}");
    assert_eq!(snap.completed, 0, "{snap:?}");
    assert_eq!(snap.submitted, 3, "{snap:?}");
    coord.shutdown();
}

#[test]
fn successful_results_report_ok() {
    let (reg, ids, _) = registry_with_sets(&[32]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(1)],
        reg,
    );
    let scalars = Arc::new(points::generate_scalars(32, 254, 700));
    let (_, rx) = coord.submit(ids[0], scalars).unwrap();
    let res = rx.recv().unwrap();
    assert!(res.is_ok());
    assert!(res.error.is_none());
    assert_eq!(coord.counters.snapshot().failed, 0);
    coord.shutdown();
}

#[test]
fn latency_histogram_populated() {
    let (reg, ids, _) = registry_with_sets(&[64]);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        vec![DeviceDesc::<Bn254G1>::native(2)],
        reg,
    );
    let mut rxs = Vec::new();
    for i in 0..6 {
        let scalars = Arc::new(points::generate_scalars(64, 254, 500 + i));
        rxs.push(coord.submit(ids[0], scalars).unwrap().1);
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert_eq!(coord.latency.count(), 6);
    assert!(coord.latency.mean_secs() > 0.0);
    assert!(coord.latency.quantile_secs(0.99) >= coord.latency.quantile_secs(0.5));
    coord.shutdown();
}
