//! CPU baseline: measured (this crate) + libsnark-calibrated model.
//!
//! The paper profiles libsnark (single-thread, Fig. 4) and an OpenMP
//! multi-core build (Table IX). Their published operating points:
//!
//! * Fig. 4 plateau: ≈0.06 M-MSM-PPS (BN128), ≈0.04 M-MSM-PPS (BLS12-381),
//!   single thread, flat in m for large m;
//! * Table IX (multi-core BLS12-381): 64M points in 1658.88 s
//!   ⇒ ≈0.0386 M-MSM-PPS — i.e. their OpenMP build bought little on this
//!   workload (memory-bound bucket updates).
//!
//! [`CpuBaseline::model_seconds`] reproduces those numbers; the
//! `measure_*` functions time this crate's own Pippenger on the local
//! host — both are reported side by side in the benches.

use crate::coordinator::shard::{ShardPolicy, ShardPool};
use crate::ec::{points, CurveParams};
use crate::ff::{Field, FieldParams, Fp};
use crate::fpga::CurveId;
use crate::msm::{self, Backend, MsmConfig};
use crate::ntt::NttPlan;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

/// Published libsnark operating points (M-MSM-PPS plateaus).
#[derive(Clone, Copy, Debug)]
pub struct CpuBaseline {
    /// Plateau throughput, single-threaded libsnark (Fig. 4).
    pub single_thread_mpps: f64,
    /// Table IX effective throughput (their OpenMP build).
    pub multi_core_mpps: f64,
    /// Small-size throughput rises toward this at m→1k (Fig. 4 shows the
    /// highest throughput at the smallest sizes — cache residency).
    pub small_size_boost: f64,
}

impl CpuBaseline {
    /// The published operating points of a curve's libsnark baseline.
    pub fn for_curve(curve: CurveId) -> CpuBaseline {
        match curve {
            CurveId::Bn254 => CpuBaseline {
                single_thread_mpps: 0.060,
                multi_core_mpps: 0.0570, // Table X: 64M in 1123 s
                small_size_boost: 1.6,
            },
            CurveId::Bls12381 => CpuBaseline {
                single_thread_mpps: 0.040,
                multi_core_mpps: 0.0386, // Table IX: 64M in 1658.88 s
                small_size_boost: 1.55,
            },
        }
    }

    /// Modeled seconds for an m-point MSM (multi-core column of Table IX).
    /// Size dependence follows Fig. 4: slightly faster per point at small
    /// m (everything cache-resident), flattening by m ≈ 10⁶.
    pub fn model_seconds(&self, m: u64) -> f64 {
        let mpps = self.throughput_mpps(m, false);
        m as f64 / (mpps * 1e6)
    }

    /// Modeled throughput (M-MSM-PPS); `single_thread` picks the Fig. 4
    /// curve, otherwise the Table IX multi-core one.
    pub fn throughput_mpps(&self, m: u64, single_thread: bool) -> f64 {
        let plateau = if single_thread { self.single_thread_mpps } else { self.multi_core_mpps };
        // smooth interpolation: boost at 1e3, gone by 1e6
        let lg = (m.max(1) as f64).log10();
        let t = ((lg - 3.0) / 3.0).clamp(0.0, 1.0);
        let boost = self.small_size_boost + (1.0 - self.small_size_boost) * t;
        plateau * boost.max(1.0)
    }
}

/// A timed local measurement.
#[derive(Clone, Copy, Debug)]
pub struct CpuMeasurement {
    /// MSM size measured.
    pub m: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Millions of points per second.
    pub mpps: f64,
}

/// Measure one MSM backend under an explicit plan config (the GLV
/// ablations pass `MsmConfig::default().glv()` here; everything else goes
/// through [`measure_backend`]).
pub fn measure_backend_with<C: CurveParams>(
    m: usize,
    seed: u64,
    backend: Backend,
    cfg: &MsmConfig,
) -> CpuMeasurement {
    let w = points::workload::<C>(m, seed);
    let sw = Stopwatch::start();
    let out = msm::execute(backend, &w.points, &w.scalars, cfg);
    let seconds = sw.secs();
    std::hint::black_box(out);
    CpuMeasurement { m: m as u64, seconds, mpps: m as f64 / seconds / 1e6 }
}

/// Measure one MSM backend on the local host with the default config.
pub fn measure_backend<C: CurveParams>(m: usize, seed: u64, backend: Backend) -> CpuMeasurement {
    measure_backend_with::<C>(m, seed, backend, &MsmConfig::default())
}

/// Measure this crate's serial Pippenger on the local host.
pub fn measure_serial<C: CurveParams>(m: usize, seed: u64) -> CpuMeasurement {
    measure_backend::<C>(m, seed, Backend::Pippenger)
}

/// Measure the multi-threaded Pippenger (`threads == 0` ⇒ single thread).
pub fn measure_parallel<C: CurveParams>(m: usize, seed: u64, threads: usize) -> CpuMeasurement {
    measure_backend::<C>(m, seed, Backend::Parallel { threads: threads.max(1) })
}

/// Measure the chunk-parallel backend (point-level parallelism — thread
/// count is not capped by the plan's window count).
pub fn measure_chunked<C: CurveParams>(m: usize, seed: u64, threads: usize) -> CpuMeasurement {
    measure_backend::<C>(m, seed, Backend::Chunked { threads: threads.max(1) })
}

/// Measure under the automatic, curve-exact backend choice
/// ([`Backend::auto_for`]): on hosts whose thread budget exceeds the
/// plan's window count this resolves to the chunk-parallel backend —
/// which is what makes this the credible CPU reference column for the
/// FPGA model's speedup tables.
pub fn measure_auto<C: CurveParams>(m: usize, seed: u64) -> CpuMeasurement {
    let cfg = MsmConfig::auto(m);
    measure_backend_with::<C>(m, seed, Backend::auto_for::<C>(m, &cfg), &cfg)
}

/// Measure the table-fed fixed-base path ([`msm::PrecompTable`]): the
/// table is built **outside** the timed region — it belongs to the SRS
/// and amortizes across proofs, so the steady-state per-call cost is the
/// honest number (the same convention [`measure_ntt`] uses for twiddle
/// tables). Compare against [`measure_backend_with`] on the same `cfg` to
/// get the pointcache ablation's measured speedup column.
pub fn measure_precomputed_with<C: CurveParams>(
    m: usize,
    seed: u64,
    cfg: &MsmConfig,
) -> CpuMeasurement {
    let w = points::workload::<C>(m, seed);
    let table = msm::PrecompTable::<C>::build(&w.points, cfg);
    let sw = Stopwatch::start();
    let out = table.msm(&w.scalars);
    let seconds = sw.secs();
    std::hint::black_box(out);
    CpuMeasurement { m: m as u64, seconds, mpps: m as f64 / seconds / 1e6 }
}

/// Measure one n-point forward NTT over the scalar field `P` on the
/// local host, through a cached [`NttPlan`] (built outside the timed
/// region — the tables amortize across the prover's transforms, so the
/// steady-state cost is what matters). `threads == 1` is the serial
/// baseline; larger budgets run the stage/chunk-parallel (or four-step)
/// executor. In the returned [`CpuMeasurement`], `m` is the element
/// count and `mpps` is millions of field **elements** per second.
pub fn measure_ntt<P: FieldParams<4>>(n: usize, seed: u64, threads: usize) -> CpuMeasurement {
    let plan = NttPlan::<P, 4>::new(n).expect("size within the field's 2-adicity");
    let mut rng = Rng::new(seed);
    let mut v: Vec<Fp<P, 4>> = (0..n).map(|_| Fp::random(&mut rng)).collect();
    let sw = Stopwatch::start();
    plan.ntt(&mut v, threads.max(1));
    let seconds = sw.secs();
    std::hint::black_box(&v);
    CpuMeasurement { m: n as u64, seconds, mpps: n as f64 / seconds / 1e6 }
}

/// Measure an MSM submitted through the sharded multi-device path: the
/// job splits across `devices` simulated native devices under `policy`
/// and the partials merge deterministically (single device ⇒ the direct
/// path, same as [`measure_parallel`] with one thread per device).
pub fn measure_sharded<C: CurveParams>(
    m: usize,
    seed: u64,
    devices: usize,
    policy: ShardPolicy,
) -> CpuMeasurement {
    let w = points::workload::<C>(m, seed);
    let pool = ShardPool::<C>::native(devices.max(1), 1).with_policy(policy);
    let cfg = MsmConfig::default();
    let sw = Stopwatch::start();
    let out = pool.execute(&w.points, &w.scalars, &cfg).expect("native devices do not fail");
    let seconds = sw.secs();
    std::hint::black_box(out);
    CpuMeasurement { m: m as u64, seconds, mpps: m as f64 / seconds / 1e6 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_matches_table_ix_anchors() {
        let bls = CpuBaseline::for_curve(CurveId::Bls12381);
        // Table IX CPU column (OpenMP libsnark), BLS12-381
        let anchors = [
            (1_000_000u64, 29.92f64),
            (8_000_000, 228.61),
            (64_000_000, 1658.88),
        ];
        for (m, want) in anchors {
            let got = bls.model_seconds(m);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.15, "m={m}: {got} vs {want}");
        }
    }

    #[test]
    fn model_matches_table_x_bn() {
        let bn = CpuBaseline::for_curve(CurveId::Bn254);
        let got = bn.model_seconds(64_000_000);
        assert!((got - 1123.0).abs() / 1123.0 < 0.1, "{got}");
    }

    #[test]
    fn fig4_shape_flat_with_small_boost() {
        // Fig. 4: highest throughput at small sizes, flattening later
        let bn = CpuBaseline::for_curve(CurveId::Bn254);
        let t1k = bn.throughput_mpps(1_000, true);
        let t1m = bn.throughput_mpps(1_000_000, true);
        let t64m = bn.throughput_mpps(64_000_000, true);
        assert!(t1k > t1m, "{t1k} > {t1m}");
        assert!((t1m - t64m).abs() / t64m < 0.02, "flat tail");
        assert!((t64m - 0.06).abs() < 0.005);
    }

    #[test]
    fn measured_msm_runs_and_reports() {
        let m = measure_serial::<crate::ec::Bn254G1>(2_000, 99);
        assert_eq!(m.m, 2_000);
        assert!(m.seconds > 0.0 && m.mpps > 0.0);
    }

    #[test]
    fn glv_measurement_runs() {
        let cfg = MsmConfig::default().glv();
        let m = measure_backend_with::<crate::ec::Bn254G1>(1_000, 99, Backend::Pippenger, &cfg);
        assert_eq!(m.m, 1_000);
        assert!(m.seconds > 0.0 && m.mpps > 0.0);
    }

    #[test]
    fn chunked_and_auto_measurements_run() {
        let m = measure_chunked::<crate::ec::Bn254G1>(1_500, 99, 4);
        assert_eq!(m.m, 1_500);
        assert!(m.seconds > 0.0 && m.mpps > 0.0);
        let a = measure_auto::<crate::ec::Bn254G1>(1_500, 99);
        assert_eq!(a.m, 1_500);
        assert!(a.seconds > 0.0 && a.mpps > 0.0);
    }

    #[test]
    fn precomputed_measurement_runs_and_matches() {
        let cfg = MsmConfig::default().glv();
        let m = measure_precomputed_with::<crate::ec::Bn254G1>(1_000, 99, &cfg);
        assert_eq!(m.m, 1_000);
        assert!(m.seconds > 0.0 && m.mpps > 0.0);
    }

    #[test]
    fn ntt_measurement_runs_serial_and_parallel() {
        use crate::ff::params::{Bls12381FrParams, Bn254FrParams};
        let s = measure_ntt::<Bn254FrParams>(1 << 10, 99, 1);
        assert_eq!(s.m, 1 << 10);
        assert!(s.seconds > 0.0 && s.mpps > 0.0);
        let p = measure_ntt::<Bn254FrParams>(1 << 10, 99, 4);
        assert!(p.seconds > 0.0 && p.mpps > 0.0);
        let bls = measure_ntt::<Bls12381FrParams>(1 << 9, 99, 2);
        assert_eq!(bls.m, 1 << 9);
        assert!(bls.mpps > 0.0);
    }

    #[test]
    fn sharded_measurement_runs_both_policies() {
        for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
            let m = measure_sharded::<crate::ec::Bn254G1>(512, 99, 3, policy);
            assert_eq!(m.m, 512);
            assert!(m.seconds > 0.0 && m.mpps > 0.0, "{policy:?}");
        }
    }
}
