//! GPU baseline model: Bellperson BLS12-381 MSM on an NVIDIA T4
//! (AWS g4dn.16xlarge) — §V-A/§V-C4.
//!
//! No GPU exists in this environment, so per the substitution rule the GPU
//! series is the paper's own published Table IX column, log-log
//! interpolated between anchors (and extended by the asymptotic
//! points-per-second rate beyond them):
//!
//! ```text
//! m:      1e3   1e4   1e5    1e6   2e6   4e6   8e6   16e6  32e6  64e6
//! t(s):   0.01  0.02  0.09   0.36  0.68  1.21  2.21  4.28  8.63  17.10
//! ```
//!
//! Power: 70 W board power under load (Table X).

use crate::fpga::CurveId;

/// Published (m, seconds) anchor points (Table IX GPU column).
const T4_ANCHORS: [(f64, f64); 10] = [
    (1e3, 0.01),
    (1e4, 0.02),
    (1e5, 0.09),
    (1e6, 0.36),
    (2e6, 0.68),
    (4e6, 1.21),
    (8e6, 2.21),
    (16e6, 4.28),
    (32e6, 8.63),
    (64e6, 17.10),
];

/// T4/Bellperson model.
#[derive(Clone, Debug)]
pub struct GpuModel {
    anchors: &'static [(f64, f64)],
    /// Board power under load (W), Table X.
    pub power_w: f64,
}

impl GpuModel {
    /// The paper's benchmarked configuration (BLS12-381 only — bellperson
    /// is a Filecoin library; no BN128 GPU column exists in the paper,
    /// Table X marks it NA).
    pub fn t4_bellperson(curve: CurveId) -> Option<GpuModel> {
        match curve {
            CurveId::Bls12381 => Some(GpuModel { anchors: &T4_ANCHORS, power_w: 70.0 }),
            CurveId::Bn254 => None,
        }
    }

    /// Seconds for an m-point MSM: log-log interpolation between the
    /// published anchors; constant-rate extrapolation outside them.
    pub fn seconds(&self, m: u64) -> f64 {
        let m = m as f64;
        let a = self.anchors;
        if m <= a[0].0 {
            // below the smallest anchor: launch overhead dominates
            return a[0].1;
        }
        let last = a[a.len() - 1];
        if m >= last.0 {
            // beyond the table: asymptotic per-point rate of the last span
            let prev = a[a.len() - 2];
            let rate = (last.1 - prev.1) / (last.0 - prev.0);
            return last.1 + (m - last.0) * rate;
        }
        let i = a.partition_point(|&(am, _)| am < m);
        let (m0, t0) = a[i - 1];
        let (m1, t1) = a[i];
        let f = (m.ln() - m0.ln()) / (m1.ln() - m0.ln());
        (t0.ln() + f * (t1.ln() - t0.ln())).exp()
    }

    /// Millions of MSM points per second at size m.
    pub fn throughput_mpps(&self, m: u64) -> f64 {
        m as f64 / self.seconds(m) / 1e6
    }

    /// Power-normalized throughput (M-PPS per watt, the Fig. 8 axis).
    pub fn throughput_per_watt(&self, m: u64) -> f64 {
        self.throughput_mpps(m) / self.power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table_ix_gpu_column_exactly_at_anchors() {
        let g = GpuModel::t4_bellperson(CurveId::Bls12381).unwrap();
        for &(m, want) in &T4_ANCHORS {
            let got = g.seconds(m as u64);
            assert!((got - want).abs() < 1e-9, "m={m}: {got} vs {want}");
        }
    }

    #[test]
    fn interpolation_monotone_between_anchors() {
        let g = GpuModel::t4_bellperson(CurveId::Bls12381).unwrap();
        let mut last = 0.0;
        for m in [1_500u64, 50_000, 500_000, 3_000_000, 48_000_000] {
            let t = g.seconds(m);
            assert!(t > last, "monotone at {m}");
            last = t;
        }
    }

    #[test]
    fn extrapolates_sanely() {
        let g = GpuModel::t4_bellperson(CurveId::Bls12381).unwrap();
        assert_eq!(g.seconds(10), 0.01); // overhead floor
        let t128m = g.seconds(128_000_000);
        assert!((t128m - 34.0).abs() < 2.0, "{t128m}"); // ~2× the 64M time
    }

    #[test]
    fn no_bn128_gpu_baseline() {
        assert!(GpuModel::t4_bellperson(CurveId::Bn254).is_none());
    }

    #[test]
    fn throughput_saturates_near_3_75_mpps() {
        let g = GpuModel::t4_bellperson(CurveId::Bls12381).unwrap();
        let t64m = g.throughput_mpps(64_000_000);
        assert!((t64m - 3.74).abs() < 0.1, "{t64m}");
        assert!(g.throughput_mpps(1_000) < 0.2);
    }
}
