//! CPU and GPU baselines (§V-B, §V-C4).
//!
//! * [`cpu`] — two forms: **measured** (this crate's own MSM, timed on the
//!   actual host — the honest baseline for our Table IX) and
//!   **libsnark-calibrated** (a throughput model pinned to the paper's
//!   published libsnark/Clearmatics numbers, so the paper's speedup
//!   factors can be reproduced at sizes impractical to execute here);
//! * [`gpu`] — a throughput model of Bellperson on the NVIDIA T4
//!   (g4dn.16xlarge), calibrated to Table IX's GPU column — the paper
//!   itself used a cloud instance it didn't control; our substitution is
//!   one step further removed but preserves the published curve.

pub mod cpu;
pub mod gpu;

pub use cpu::{CpuBaseline, CpuMeasurement};
pub use gpu::GpuModel;
