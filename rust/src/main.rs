//! `ifzkp` — launcher CLI (hand-rolled arg parsing; clap is not in the
//! offline dependency set).
//!
//! ```text
//! ifzkp msm     --curve bn254|bls12_381 --size N [--backend native|sim|engine] [--threads T] [--glv]
//! ifzkp prove   --constraints N [--stream [--budget MIB] [--verify]]
//! ifzkp prove   --scenario mul-chain|square-chain|poseidon2|merkle|range|rollup [--curve C] [--size N]
//! ifzkp serve   [--config serve.toml] [--jobs N] [--size N] [--devices N] [--sharded chunk|window]
//! ifzkp serve   --load [--size N] [--devices N] [--duration S] [--json PATH]  # open-loop serving bench
//! ifzkp sim     --curve ... [--size N] [--scaling S]
//! ifzkp tables  [--id 1|2|4|7|8|9|10|ablation|glv|pointcache|whatif|ntt|all] [--cpu-measure N]
//! ifzkp tables  --id scenarios [--size N] [--json PATH]   # circuit-library profiles
//! ifzkp figures [--id 4|5|6|7|8|all]
//! ifzkp info
//! ```

use ifzkp::baseline::cpu;
use ifzkp::ec::{points, Bls12381G1, Bn254G1, CurveParams};
use ifzkp::fpga::{CurveId, SabConfig, SabModel};
use ifzkp::msm::{self, MsmConfig};
use ifzkp::report::{figures, tables};
use ifzkp::util::{human_count, human_secs, Stopwatch};

struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(name) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn curve_id(name: &str) -> CurveId {
    match name {
        "bn254" | "bn128" => CurveId::Bn254,
        "bls12_381" | "bls12-381" | "bls" => CurveId::Bls12381,
        other => {
            eprintln!("unknown curve {other:?} (use bn254 | bls12_381)");
            std::process::exit(2);
        }
    }
}

fn cmd_msm(args: &Args) -> anyhow::Result<()> {
    let curve = curve_id(&args.get("curve", "bn254"));
    let size = args.get_usize("size", 1 << 14);
    let backend = args.get("backend", "native");
    let threads = args.get_usize("threads", msm::parallel::default_threads());
    // --glv switches the plan to the endomorphism split (half the window
    // passes over the doubled (P, phi(P)) set); results are identical.
    let glv = args.get("glv", "") == "true";
    let base_cfg = if glv { MsmConfig::default().glv() } else { MsmConfig::default() };
    println!(
        "MSM: curve={} size={} backend={backend}{}",
        curve.name(),
        human_count(size as u64),
        if glv { " [glv]" } else { "" }
    );

    fn run_native<C: CurveParams>(size: usize, threads: usize, cfg: &MsmConfig) -> f64 {
        let w = points::workload::<C>(size, 1);
        let sw = Stopwatch::start();
        let out = msm::parallel::msm(&w.points, &w.scalars, cfg, threads);
        let t = sw.secs();
        std::hint::black_box(out);
        t
    }

    match backend.as_str() {
        "native" => {
            let t = match curve {
                CurveId::Bn254 => run_native::<Bn254G1>(size, threads, &base_cfg),
                CurveId::Bls12381 => run_native::<Bls12381G1>(size, threads, &base_cfg),
            };
            println!(
                "native ({threads} threads): {} ({:.3} M points/s)",
                human_secs(t),
                size as f64 / t / 1e6
            );
        }
        "sim" => {
            let s = args.get_usize("scaling", 2) as u32;
            let cfg =
                if glv { SabConfig::paper_glv(curve, s) } else { SabConfig::paper(curve, s) };
            let model = SabModel::new(cfg);
            let timing = model.time_msm(size as u64);
            println!(
                "modeled FPGA (S={s}): {} ({:.3} M points/s){}",
                human_secs(timing.total_s()),
                timing.m_msm_pps(size as u64),
                if timing.stream_bound { " [stream-bound]" } else { "" }
            );
            println!(
                "  transfer {:.4}s fill {:.4}s stream {:.4}s reduce {:.4}s combine {:.5}s",
                timing.transfer_s, timing.fill_s, timing.stream_s, timing.reduce_s,
                timing.combine_s
            );
        }
        "engine" => {
            if curve != CurveId::Bn254 {
                anyhow::bail!("engine CLI path is wired for bn254 (see examples for bls)");
            }
            let ctx = ifzkp::runtime::PjrtContext::cpu()?;
            let manifest =
                ifzkp::runtime::ArtifactManifest::load(&ifzkp::runtime::artifact::default_dir())?;
            let sw = Stopwatch::start();
            let engine = ifzkp::runtime::UdaEngine::<Bn254G1>::load(&ctx, &manifest)?;
            println!("engine compiled in {}", human_secs(sw.secs()));
            let w = points::workload::<Bn254G1>(size, 1);
            let mut cfg = MsmConfig::new(8, Default::default());
            if glv {
                cfg = cfg.glv();
            }
            let sw = Stopwatch::start();
            let (out, stats) =
                ifzkp::runtime::msm_engine::msm_engine(&engine, &w.points, &w.scalars, &cfg)?;
            let t = sw.secs();
            let want = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
            anyhow::ensure!(out.eq_point(&want), "engine result mismatch!");
            println!(
                "engine MSM: {} — verified vs native; {} engine ops in {} batches (occ {:.2})",
                human_secs(t),
                stats.engine_ops,
                stats.engine_batches,
                stats.mean_occupancy
            );
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.get("load", "") == "true" {
        return cmd_serve_load(args);
    }
    let jobs = args.get_usize("jobs", 32);
    let size = args.get_usize("size", 2048);
    let cfg_path = args.get("config", "");
    // 0 = auto: the coordinator derives the ingress bound from the
    // device count (devices × 32) instead of a fleet-blind constant.
    let mut queue_capacity = 0usize;
    if !cfg_path.is_empty() {
        let cfg = ifzkp::config::load(std::path::Path::new(&cfg_path))
            .map_err(|e| anyhow::anyhow!(e))?;
        queue_capacity = cfg.get_int("serve", "queue_capacity", 0) as usize;
    }
    // --sharded chunk|window splits every job across the device set;
    // --devices N controls the simulated fleet size (default 2).
    let policy = match args.get("sharded", "").as_str() {
        "" => None,
        "chunk" => Some(ifzkp::msm::ShardPolicy::ChunkPoints),
        "window" => Some(ifzkp::msm::ShardPolicy::WindowRange),
        other => anyhow::bail!("unknown shard policy {other} (use chunk | window)"),
    };
    let n_devices = args.get_usize("devices", 2);
    use ifzkp::coordinator::{Coordinator, CoordinatorConfig, DeviceDesc, PointSetRegistry};
    use std::sync::Arc;
    let mut registry = PointSetRegistry::<Bn254G1>::new();
    let ps = registry.register(points::generate_points_walk::<Bn254G1>(size, 11));
    let mut devices =
        vec![DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 30)];
    while devices.len() < n_devices.max(1) {
        devices.push(DeviceDesc::<Bn254G1>::native(2));
    }
    let coord = Coordinator::start(
        CoordinatorConfig { queue_capacity, ..Default::default() },
        devices,
        registry,
    );
    let sw = Stopwatch::start();
    let mut rxs = Vec::new();
    for i in 0..jobs {
        let scalars = Arc::new(points::generate_scalars(size, 254, 1000 + i as u64));
        rxs.push(match policy {
            Some(p) => coord.submit_sharded(ps, scalars, p)?.1,
            None => coord.submit(ps, scalars)?.1,
        });
    }
    let mut failed = 0usize;
    for rx in rxs {
        if rx.recv()?.error.is_some() {
            failed += 1;
        }
    }
    let wall = sw.secs();
    let snap = coord.counters.snapshot();
    println!(
        "{} jobs in {} — {:.1} jobs/s, hit rate {:.0}%, p99 {}",
        snap.completed,
        human_secs(wall),
        snap.completed as f64 / wall,
        100.0 * snap.hit_rate(),
        human_secs(coord.latency.quantile_secs(0.99))
    );
    if failed > 0 {
        println!("WARNING: {failed} jobs returned device failures");
    }
    if policy.is_some() {
        println!(
            "shard groups {} (retries {}, atomic failures {}), mean shard skew {:.1}%",
            snap.shard_groups,
            snap.shard_retries,
            snap.shard_group_failures,
            100.0 * snap.mean_shard_skew()
        );
        let util = coord.device_metrics.utilization();
        for (i, lane) in coord.device_metrics.lanes().iter().enumerate() {
            println!(
                "device {i}: {} shards, {} jobs, busy {} (util {:.2})",
                lane.shards.load(std::sync::atomic::Ordering::Relaxed),
                lane.jobs.load(std::sync::atomic::Ordering::Relaxed),
                human_secs(lane.busy_secs()),
                util[i]
            );
        }
    }
    coord.shutdown();
    Ok(())
}

/// `serve --load`: the open-loop serving benchmark. Sweeps the built-in
/// tenant mixes across offered-load multipliers and writes the
/// `BENCH_serving.json` artifact (schema in BENCHMARKS.md).
/// `IFZKP_BENCH_QUICK=1` shrinks the sweep to CI-smoke scale.
fn cmd_serve_load(args: &Args) -> anyhow::Result<()> {
    use ifzkp::coordinator::loadgen::{self, LoadgenConfig};
    let quick = std::env::var("IFZKP_BENCH_QUICK").is_ok();
    let defaults = LoadgenConfig::default();
    let cfg = LoadgenConfig {
        msm_size: args.get_usize("size", if quick { 256 } else { defaults.msm_size }),
        devices: args.get_usize("devices", defaults.devices),
        duration_s: args
            .get("duration", "")
            .parse()
            .unwrap_or(if quick { 0.3 } else { defaults.duration_s }),
        multipliers: if quick { vec![0.5, 3.0] } else { defaults.multipliers.clone() },
        ..defaults
    };
    let json_path = args.get("json", "BENCH_serving.json");
    println!(
        "serving bench: {} points/job, {} devices, {:.2}s window, multipliers {:?}",
        human_count(cfg.msm_size as u64),
        cfg.devices,
        cfg.duration_s,
        cfg.multipliers
    );
    let report = loadgen::run(&cfg, &loadgen::default_mixes());
    println!(
        "calibrated {}/job — fleet capacity {:.0} jobs/s",
        human_secs(report.calibrated_job_s),
        report.capacity_jobs_per_s
    );
    for mix in &report.mixes {
        println!("mix {}:", mix.mix);
        for run in &mix.runs {
            println!(
                "  x{:<4} offered {:>6.0}/s  achieved {:>6.0}/s  shed {:>3.0}%",
                run.multiplier,
                run.offered_jobs_per_s,
                run.achieved_jobs_per_s,
                100.0 * run.shed_rate
            );
            for lane in &run.lanes {
                if lane.offered == 0 {
                    continue;
                }
                println!(
                    "    {:<12} p50 {:>9}  p95 {:>9}  p99 {:>9}  shed {:>3.0}%",
                    lane.lane.name(),
                    human_secs(lane.p50_s),
                    human_secs(lane.p95_s),
                    human_secs(lane.p99_s),
                    100.0 * lane.shed_rate
                );
            }
        }
    }
    std::fs::write(&json_path, report.to_json().to_string())
        .map_err(|e| anyhow::anyhow!("writing {json_path}: {e}"))?;
    println!("wrote {json_path}");
    Ok(())
}

/// `prove --stream`: run the bounded-memory streaming prover on a
/// synthetic circuit and print its memory report. `--budget` caps the
/// chunk lane in MiB (default 4); `--verify` cross-checks the streamed
/// proof bit-for-bit against the resident prover (costs a full resident
/// prove — skip it at large `--constraints`).
fn cmd_prove_stream(args: &Args) -> anyhow::Result<()> {
    use ifzkp::ec::Bn254G2;
    use ifzkp::ff::params::Bn254FrParams;
    use ifzkp::snark::{circuits, prove_streaming, Prover, ProverConfig, StreamingSrs};
    use ifzkp::util::MemoryBudget;
    let n = args.get_usize("constraints", 1 << 12);
    let budget_mib = args.get_usize("budget", 4) as u64;
    let seed = 20240710u64;
    let cs = circuits::mul_chain::<Bn254FrParams, 4>(n, seed);
    let domain_n = cs.num_constraints().max(2).next_power_of_two();
    let nv = cs.num_variables();
    let srs = StreamingSrs::<Bn254G1, Bn254G2>::generated(nv, domain_n, seed);
    let budget = MemoryBudget::mib(budget_mib);
    println!(
        "streaming prove: {} constraints ({} vars, domain {}), budget {budget_mib} MiB",
        human_count(n as u64),
        human_count(nv as u64),
        human_count(domain_n as u64)
    );
    let (proof, report) = prove_streaming(&cs, &srs, budget, &ProverConfig::default())
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "proved in {} — chunk peak {} B of {} B budget, fixed lane {} B",
        human_secs(report.total_s),
        report.peak_chunk_bytes,
        report.budget_bytes,
        report.fixed_bytes
    );
    println!(
        "chunk sizes: {} G1 points / {} G2 points per read",
        human_count(report.chunk_points_g1 as u64),
        human_count(report.chunk_points_g2 as u64)
    );
    if args.get("verify", "") == "true" {
        let crs = ifzkp::snark::setup::CrsBn254::synthesize(nv, domain_n, seed);
        let prover = Prover::<_, _, Bn254FrParams>::new(crs);
        let (want, _) = prover.prove(&cs);
        // one RLC fold per group instead of per-element eq_point checks
        anyhow::ensure!(
            msm::batch_eq(&[(proof.a, want.a), (proof.c, want.c)], seed)
                && msm::batch_eq(&[(proof.b, want.b)], seed),
            "streamed proof diverged from the resident prover!"
        );
        println!("verified: bit-identical to the resident prover");
    }
    Ok(())
}

/// `prove --scenario NAME`: build one circuit-library workload, prove it
/// on the default Table-I rig, check the transcript with the verifier,
/// and print the phase profile.
fn cmd_prove_scenario(args: &Args, scenario: &str) -> anyhow::Result<()> {
    use ifzkp::ec::{Bls12381G2, Bn254G2};
    use ifzkp::ff::params::{Bls12381FrParams, Bn254FrParams};
    use ifzkp::ff::FieldParams;
    use ifzkp::snark::{setup::Crs, verify, Prover, Scenario, VerifyingKey};
    let sc = Scenario::parse(scenario).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario {scenario:?} (use {})",
            Scenario::ALL.map(|s| s.name()).join(" | ")
        )
    })?;

    fn run<G1, G2, P>(sc: Scenario, size: usize, seed: u64, curve: &str) -> anyhow::Result<()>
    where
        G1: CurveParams,
        G2: CurveParams,
        P: FieldParams<4>,
    {
        let inst = sc.build::<P, 4>(size, seed);
        let cs = &inst.cs;
        let domain_n = cs.num_constraints().max(2).next_power_of_two();
        let crs = Crs::<G1, G2>::synthesize(cs.num_variables(), domain_n, seed ^ 1);
        let vk = VerifyingKey::from_crs(&crs, cs.num_public);
        let (proof, prof) = Prover::<G1, G2, P>::new(crs).prove(cs);
        verify(&vk, &proof, &inst.public_inputs)
            .map_err(|e| anyhow::anyhow!("transcript verify failed: {e}"))?;
        println!(
            "{curve} {} ({}): {} constraints, {} vars, {} public",
            sc.name(),
            inst.shape,
            human_count(cs.num_constraints() as u64),
            human_count(cs.num_variables() as u64),
            cs.num_public
        );
        println!(
            "proved in {} — MSM-G1 {:.1}% MSM-G2 {:.1}% NTT {:.1}% other {:.1}% — verified",
            human_secs(prof.total_s),
            prof.msm_g1_pct,
            prof.msm_g2_pct,
            prof.ntt_pct,
            prof.other_pct
        );
        Ok(())
    }

    let size = args.get_usize("size", 1 << 12);
    let seed = 20240710u64;
    match curve_id(&args.get("curve", "bn254")) {
        CurveId::Bn254 => run::<Bn254G1, Bn254G2, Bn254FrParams>(sc, size, seed, "BN254"),
        CurveId::Bls12381 => {
            run::<Bls12381G1, Bls12381G2, Bls12381FrParams>(sc, size, seed, "BLS12-381")
        }
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let curve = curve_id(&args.get("curve", "bls12_381"));
    let s = args.get_usize("scaling", 2) as u32;
    let model = SabModel::new(SabConfig::paper(curve, s));
    println!("SAB model: {} S={s} fmax={:.0}MHz", curve.name(), model.fmax_hz / 1e6);
    let size = args.get_usize("size", 0);
    let sizes: Vec<u64> = if size > 0 {
        vec![size as u64]
    } else {
        vec![1_000, 10_000, 100_000, 1_000_000, 8_000_000, 64_000_000]
    };
    for m in sizes {
        let t = model.time_msm(m);
        println!(
            "m={:>6}: total {:>10} throughput {:>7.3} M-PPS{}",
            human_count(m),
            human_secs(t.total_s()),
            t.m_msm_pps(m),
            if t.stream_bound { " [stream]" } else { " [compute]" }
        );
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let id = args.get("id", "all");
    let all = id == "all";
    if all || id == "1" {
        println!("{}", tables::table1(1 << 12, 20240710));
    }
    if all || id == "2" || id == "3" {
        println!("{}", tables::table2_3(512, 20240710));
    }
    if all || id == "4" || id == "5" {
        println!("{}", tables::table4_5());
    }
    if all || id == "7" {
        println!("{}", tables::table7());
    }
    if all || id == "8" {
        println!("{}", tables::table8());
    }
    if all || id == "9" {
        println!("{}", tables::table9(args.get_usize("cpu-measure", 1 << 16)));
    }
    if all || id == "10" {
        println!("{}", tables::table10());
    }
    if all || id == "ablation" {
        println!("{}", tables::ablation_reduction());
        println!("{}", tables::ablation_signed(2048, 20240710));
        println!("{}", tables::ablation_glv(2048, 20240710));
    }
    if id == "glv" {
        println!("{}", tables::ablation_glv(args.get_usize("size", 2048), 20240710));
    }
    // the fixed-base precompute-table ablation: measured + modeled speedup
    // vs table size as the window width sweeps (--size caps the MSM)
    if all || id == "pointcache" {
        println!("{}", tables::ablation_pointcache(args.get_usize("size", 4096), 20240710));
    }
    if all || id == "whatif" {
        println!("{}", tables::whatif_multi_kernel(args.get_usize("size", 16_000_000) as u64));
    }
    // the FPGA-NTT what-if (paper future work): CPU NTT measured locally
    // up to --cpu-measure elements, modeled device + Amdahl prover columns
    if all || id == "ntt" {
        println!("{}", tables::whatif_ntt(args.get_usize("cpu-measure", 1 << 16)));
    }
    // circuit-library profiles (not in `all`: proves 12 circuit/curve
    // combinations twice — resident + streaming); --json writes the
    // BENCH_scenarios.json artifact, IFZKP_BENCH_QUICK shrinks the build
    if id == "scenarios" {
        let quick = std::env::var("IFZKP_BENCH_QUICK").is_ok();
        let size = args.get_usize("size", if quick { 400 } else { 2000 });
        let (table, json) = tables::table_scenarios(size, 20240710);
        println!("{table}");
        let json_path = args.get("json", "");
        if !json_path.is_empty() {
            std::fs::write(&json_path, json.to_string())
                .map_err(|e| anyhow::anyhow!("writing {json_path}: {e}"))?;
            println!("wrote {json_path}");
        }
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let id = args.get("id", "all");
    let all = id == "all";
    if all || id == "4" {
        println!("{}", figures::fig4_cpu_throughput());
    }
    if all || id == "5" {
        println!("{}", figures::fig5_7_power_normalized(CurveId::Bn254));
    }
    if all || id == "6" {
        println!("{}", figures::fig6_fpga_throughput());
    }
    if all || id == "7" {
        println!("{}", figures::fig5_7_power_normalized(CurveId::Bls12381));
    }
    if all || id == "8" {
        println!("{}", figures::fig8_fpga_vs_gpu());
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("ifzkp — reproduction of 'if-ZKP: Intel FPGA-Based Acceleration of Zero Knowledge Proofs'");
    println!("curves   : BN254 (BN128), BLS12-381 — Weierstrass, Jacobian coordinates");
    println!("device   : {} (modeled)", ifzkp::fpga::device::IA840F.name);
    let dir = ifzkp::runtime::artifact::default_dir();
    match ifzkp::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} (batch={})", dir.display(), m.batch);
            for e in &m.entries {
                println!("  - {} ({}, {} limbs)", e.file, e.curve, e.nlimb16);
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    let meas = cpu::measure_serial::<Bn254G1>(4096, 1);
    println!("host MSM : {:.3} M points/s (BN254, serial, m=4096)", meas.mpps);
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: ifzkp <msm|prove|serve|sim|tables|figures|info> [flags]\n\
         see rust/src/main.rs header for per-command flags"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    if let Some(pos) = argv.iter().position(|a| a == "--log-level") {
        if let Some(l) = argv.get(pos + 1).and_then(|v| ifzkp::util::log::parse_level(v)) {
            ifzkp::util::log::set_level(l);
        }
    }
    let args = Args::parse(&argv[1..]);
    match argv[0].as_str() {
        "msm" => cmd_msm(&args),
        "prove" => {
            if args.get("stream", "") == "true" {
                return cmd_prove_stream(&args);
            }
            let scenario = args.get("scenario", "");
            if !scenario.is_empty() {
                return cmd_prove_scenario(&args, &scenario);
            }
            let n = args.get_usize("constraints", 1 << 12);
            println!("{}", tables::table1(n, 20240710));
            Ok(())
        }
        "serve" => cmd_serve(&args),
        "sim" => cmd_sim(&args),
        "tables" => cmd_tables(&args),
        "figures" => cmd_figures(&args),
        "info" => cmd_info(),
        _ => usage(),
    }
}
