//! Multi-scalar multiplication: `R = Σ sᵢ·Pᵢ` (§II-E).
//!
//! The paper's subject. The subsystem is layered as **one kernel, many
//! executors** (see `rust/DESIGN.md` §MsmKernel):
//!
//! * [`plan`] — the shared `MsmPlan`: window slicing, digit encoding
//!   (unsigned or **signed**, which halves bucket memory and the serial
//!   reduce chain), scalar decomposition (full-width or the **GLV**
//!   endomorphism split, which halves the window passes on top — see
//!   [`crate::ec::endo`]), bucket indexing, reduction strategy, and the
//!   serial op accounting the FPGA model consumes. Signed digit re-coding
//!   itself lives in [`signed`]; the raw slice primitives at
//!   [`crate::ec::scalar`].
//! * Backends, all consuming the same plan (and its one-pass
//!   [`DigitMatrix`] recode) and bit-exact against [`naive`]:
//!   [`pippenger`] (serial fills, Algorithm 2 + IS-RBAM reduction),
//!   [`parallel`] (windows fan out across threads — the software analogue
//!   of replicated BAM units), [`batch_affine`] (bucket fills with shared
//!   batch inversion, ≈6M per add — the §Perf/L3 optimization),
//!   [`chunked`] (the chunk-parallel runtime: **points** partition across
//!   threads, so parallelism is not capped by the window count — the
//!   SZKP/ZK-Flex point-level scheduling, on CPU), and
//!   `runtime::msm_engine` (the PJRT UDA engine, conflict-free batches).
//! * [`partial`] — shard specs (point chunks, window ranges), window-range
//!   execution and the deterministic merge: the kernel half of the
//!   multi-device sharding layer (`coordinator::shard` owns the device
//!   half).
//! * [`Backend`]/[`execute`] — the dispatch surface callers
//!   (`snark::prover`, `baseline::cpu`, `coordinator::devices`) use
//!   instead of hand-picking implementations; [`msm`] auto-selects both
//!   backend and config.
//! * [`stream`] — the bounded-memory driver: point/scalar chunk sources
//!   (slice-, generator- and disk-backed) folded through any resident
//!   backend under an enforced [`crate::util::mem::MemoryBudget`] — the
//!   host-side analogue of the paper's DDR→SAB chunk streaming.
//! * [`audit`] — the random-linear-combination batched point-equality
//!   checker: one RLC fold verifies N (got, want) pairs with a single
//!   comparison instead of N.
//!
//! Property tests in `rust/tests/prop_msm.rs` enforce bit-exactness of
//! every backend × slicing × reduction combination against [`naive`],
//! including the streamed chunk matrix.

pub mod plan;
pub mod signed;
pub mod naive;
pub mod pippenger;
pub mod parallel;
pub mod batch_affine;
pub mod chunked;
pub mod partial;
pub mod precomp;
pub mod stream;
pub mod audit;

use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};

pub use audit::batch_eq;
pub use batch_affine::{batch_invert, ZeroDenominator};
pub use chunked::ChunkedPhases;
pub use partial::{PartialMsm, ShardPolicy, ShardSpec};
pub use pippenger::msm as msm_pippenger;
pub use plan::{Decomposition, DigitMatrix, MsmConfig, MsmInput, MsmPlan, Reduction, Slicing};
pub use precomp::{PrecompCost, PrecompTable};
pub use stream::{msm_stream, PointStream, ScalarStream, StreamError};

/// Heuristic window width: balances m/window bucket fills against 2^k
/// reduction work. The usual c ≈ log2(m) − 3 rule, clamped to the paper's
/// hardware point k = 12 (larger windows trade reduce work the hardware
/// cannot hide for bucket memory it does not have).
pub fn auto_window(m: usize) -> u32 {
    let lg = (usize::BITS - m.leading_zeros()).max(1);
    (lg.saturating_sub(3)).clamp(2, 12)
}

/// Which executor carries the bucket fills.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Per-point double-and-add (the Table II baseline; ignores the
    /// window/reduction config).
    Naive,
    /// Serial Pippenger through the shared plan.
    Pippenger,
    /// Window-parallel Pippenger over OS threads.
    Parallel {
        /// OS threads the windows fan out across.
        threads: usize,
    },
    /// Batch-affine bucket fills (shared batch inversion), serial.
    BatchAffine,
    /// Batch-affine fills, window-parallel.
    BatchAffineParallel {
        /// OS threads the windows fan out across.
        threads: usize,
    },
    /// Chunk-parallel runtime ([`chunked`]): **points** partition across
    /// threads; each thread fills a private all-window bucket array from
    /// the one-pass digit matrix with batch-affine adds, then arrays
    /// merge pairwise and reduce once. The only backend whose thread
    /// count is not capped by the plan's window count.
    Chunked {
        /// OS threads the point chunks fan out across.
        threads: usize,
    },
    /// Fixed-base table-fed fills ([`precomp`]): per-window shifted
    /// multiples are precomputed, so the fill loop reads table columns
    /// straight into the batch-affine buckets and the combine collapses
    /// to a plain add chain — no doubling/shift chain anywhere outside
    /// the planned reduction. Through [`execute`] the table is built
    /// inline (one-shot, pays the build); amortized callers hold a
    /// [`PrecompTable`] (or a `coordinator` registry entry) and call it
    /// directly.
    Precomputed,
}

impl Backend {
    /// The shared selection rule, as a pure function of the exact inputs
    /// (the unit the threshold tests pin): tiny inputs skip bucket setup
    /// entirely; mid sizes run serial fills; large inputs go
    /// point-chunked once the thread budget exceeds the plan's window
    /// count (window-parallel backends idle past that ceiling — 22
    /// windows for BN254 at k = 12, only 11 under GLV), else
    /// window-parallel batch-affine fills.
    pub fn pick(m: usize, plan_windows: u32, threads: usize) -> Backend {
        if m < 32 {
            Backend::Naive
        } else if m < 1024 {
            Backend::Pippenger
        } else if threads > plan_windows as usize {
            Backend::Chunked { threads }
        } else {
            Backend::BatchAffineParallel { threads }
        }
    }

    /// Pick an executor for an m-point MSM with [`Self::pick`], sizing
    /// the window count at the model width (254-bit scalars — the BN254
    /// paper shape). Curve-exact callers should prefer
    /// [`Self::auto_for`], which also sees GLV's halved window count.
    pub fn auto(m: usize) -> Backend {
        let windows = MsmPlan::new(254, &MsmConfig::auto(m)).windows;
        Backend::pick(m, windows, parallel::default_threads())
    }

    /// Curve- and config-exact selection: resolves the plan's real
    /// window count (a GLV config halves it, moving the chunked
    /// threshold down to ~11 threads on BN254) against
    /// [`parallel::default_threads`].
    pub fn auto_for<C: CurveParams>(m: usize, cfg: &MsmConfig) -> Backend {
        Backend::pick(m, MsmPlan::for_curve::<C>(cfg).windows, parallel::default_threads())
    }

    /// [`Self::pick`] extended with table residency: when the caller's
    /// registry holds compatible fixed-base tables for the input set
    /// (`coordinator::devices::PointSetRegistry::tables_for`), the
    /// table-fed backend wins at every size past the naive tier — its
    /// fill does strictly less work than any live-point fill and its
    /// combine drops the Horner chain entirely. Without resident tables
    /// (or below the bucket-setup threshold) the standard rule applies
    /// unchanged, so eviction between selection and execution only ever
    /// falls back to a bit-identical backend.
    pub fn pick_with_tables(
        m: usize,
        plan_windows: u32,
        threads: usize,
        tables_resident: bool,
    ) -> Backend {
        if tables_resident && m >= 32 {
            Backend::Precomputed
        } else {
            Backend::pick(m, plan_windows, threads)
        }
    }

    /// Curve- and config-exact [`Self::pick_with_tables`] (the residency
    ///-aware sibling of [`Self::auto_for`]).
    pub fn auto_for_cached<C: CurveParams>(
        m: usize,
        cfg: &MsmConfig,
        tables_resident: bool,
    ) -> Backend {
        Backend::pick_with_tables(
            m,
            MsmPlan::for_curve::<C>(cfg).windows,
            parallel::default_threads(),
            tables_resident,
        )
    }
}

/// Run an MSM on the chosen backend. Every backend routes through the same
/// [`MsmPlan`], so results are bit-exact across backends for any config —
/// including the GLV fast path ([`MsmConfig::glv`]).
///
/// # Examples
///
/// ```
/// use ifzkp::ec::{points, Bn254G1};
/// use ifzkp::msm::{self, Backend, MsmConfig};
///
/// let w = points::workload::<Bn254G1>(64, 7);
/// let cfg = MsmConfig::default();
/// let a = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
/// let b = msm::execute(Backend::BatchAffine, &w.points, &w.scalars, &cfg);
/// // the GLV endomorphism split changes the execution plan, not the sum
/// let c = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg.glv());
/// assert!(a.eq_point(&b));
/// assert!(a.eq_point(&c));
/// ```
pub fn execute<C: CurveParams>(
    backend: Backend,
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    match backend {
        Backend::Naive => naive::msm(points, scalars),
        Backend::Pippenger => pippenger::msm(points, scalars, cfg),
        Backend::Parallel { threads } => parallel::msm(points, scalars, cfg, threads),
        Backend::BatchAffine => batch_affine::msm(points, scalars, cfg),
        Backend::BatchAffineParallel { threads } => {
            batch_affine::msm_parallel(points, scalars, cfg, threads)
        }
        Backend::Chunked { threads } => chunked::msm(points, scalars, cfg, threads),
        Backend::Precomputed => precomp::msm(points, scalars, cfg),
    }
}

/// Top-level convenience: auto backend + auto config (signed digits and
/// the paper's recursive reduction once the window is wide enough; the
/// chunk-parallel backend once the host has more threads than the plan
/// has windows).
pub fn msm<C: CurveParams>(points: &[Affine<C>], scalars: &[ScalarLimbs]) -> Jacobian<C> {
    let m = points.len();
    let cfg = MsmConfig::auto(m);
    execute(Backend::auto_for::<C>(m, &cfg), points, scalars, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bn254G1};

    #[test]
    fn auto_window_monotone() {
        assert!(auto_window(1 << 10) <= auto_window(1 << 20));
        assert_eq!(auto_window(1), 2);
        assert!(auto_window(usize::MAX / 2) <= 12);
    }

    #[test]
    fn auto_window_clamps_at_hardware_k() {
        // the documented clamp: never exceed the paper's hardware point
        // k = 12, reached at m = 2^15 and held from there on
        assert_eq!(auto_window(1 << 15), 12);
        assert_eq!(auto_window(1 << 20), 12);
        assert_eq!(auto_window(usize::MAX), 12);
        // below the clamp the log rule is live
        assert_eq!(auto_window(1 << 10), 8);
        assert_eq!(auto_window(1 << 14), 12);
        assert_eq!(auto_window(1 << 13), 11);
    }

    #[test]
    fn msm_toplevel_matches_naive() {
        let w = points::workload::<Bn254G1>(100, 17);
        let a = msm(&w.points, &w.scalars);
        let b = naive::msm(&w.points, &w.scalars);
        assert!(a.eq_point(&b));
    }

    #[test]
    fn auto_backend_tiers() {
        assert_eq!(Backend::auto(8), Backend::Naive);
        assert_eq!(Backend::auto(100), Backend::Pippenger);
        // large inputs go wide; which wide backend depends on the host's
        // thread count vs the plan's window count
        assert!(matches!(
            Backend::auto(1 << 20),
            Backend::BatchAffineParallel { .. } | Backend::Chunked { .. }
        ));
    }

    #[test]
    fn pick_prefers_chunked_past_the_window_ceiling() {
        // the exact decision rule, pinned (auto/auto_for are thin shims
        // over this with host-dependent thread counts)
        assert_eq!(Backend::pick(1 << 20, 22, 8), Backend::BatchAffineParallel { threads: 8 });
        assert_eq!(Backend::pick(1 << 20, 22, 22), Backend::BatchAffineParallel { threads: 22 });
        assert_eq!(Backend::pick(1 << 20, 22, 23), Backend::Chunked { threads: 23 });
        assert_eq!(Backend::pick(1 << 20, 11, 12), Backend::Chunked { threads: 12 });
        assert_eq!(Backend::pick(8, 22, 64), Backend::Naive);
        assert_eq!(Backend::pick(100, 22, 64), Backend::Pippenger);
    }

    #[test]
    fn auto_picks_chunked_at_threads_beyond_glv_windows() {
        // satellite regression: threads ≫ windows on a GLV plan must
        // resolve to the chunked backend — the GLV split leaves only 11
        // windows on BN254, so window-parallel backends idle 21 of 32
        // threads there
        let cfg = MsmConfig::new(12, Reduction::default()).glv();
        let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        assert_eq!(windows, 11);
        let picked = Backend::pick(1 << 14, windows, 32);
        assert_eq!(picked, Backend::Chunked { threads: 32 });
        // and the selected backend is bit-identical at that operating
        // point (threads ≫ windows, GLV decomposition)
        let w = points::workload::<Bn254G1>(1 << 11, 4242);
        let got = execute(picked, &w.points, &w.scalars, &cfg);
        let want = execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn all_backends_agree_through_execute() {
        let w = points::workload::<Bn254G1>(160, 18);
        let cfg = MsmConfig::auto(160);
        let want = naive::msm(&w.points, &w.scalars);
        for backend in [
            Backend::Naive,
            Backend::Pippenger,
            Backend::Parallel { threads: 3 },
            Backend::BatchAffine,
            Backend::BatchAffineParallel { threads: 3 },
            Backend::Chunked { threads: 3 },
            Backend::Precomputed,
        ] {
            let got = execute(backend, &w.points, &w.scalars, &cfg);
            assert!(got.eq_point(&want), "{backend:?}");
        }
    }

    #[test]
    fn pick_with_tables_beats_chunked_when_resident() {
        // satellite regression: with resident tables the precomputed
        // backend wins exactly where any bucket backend would run —
        // including the operating point where chunked would otherwise win
        // (threads past the GLV window ceiling)
        assert_eq!(Backend::pick_with_tables(1 << 20, 11, 23, true), Backend::Precomputed);
        assert_eq!(Backend::pick_with_tables(1 << 20, 22, 8, true), Backend::Precomputed);
        assert_eq!(Backend::pick_with_tables(100, 22, 64, true), Backend::Precomputed);
        // without residency the pinned standard rule applies verbatim
        assert_eq!(
            Backend::pick_with_tables(1 << 20, 11, 23, false),
            Backend::Chunked { threads: 23 }
        );
        assert_eq!(
            Backend::pick_with_tables(1 << 20, 22, 8, false),
            Backend::BatchAffineParallel { threads: 8 }
        );
        // tiny inputs skip bucket setup either way
        assert_eq!(Backend::pick_with_tables(8, 22, 64, true), Backend::Naive);
        assert_eq!(Backend::pick_with_tables(8, 22, 64, false), Backend::Naive);
    }

    #[test]
    fn precomputed_and_fallback_are_bit_identical() {
        // the two backends an eviction mid-run switches between must
        // agree bit-for-bit at the switch point
        let w = points::workload::<Bn254G1>(1 << 9, 19);
        let cfg = MsmConfig::new(8, Reduction::default()).glv();
        let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        let with_tables = Backend::pick_with_tables(w.points.len(), windows, 32, true);
        let evicted = Backend::pick_with_tables(w.points.len(), windows, 32, false);
        assert_eq!(with_tables, Backend::Precomputed);
        assert_ne!(evicted, Backend::Precomputed);
        let a = execute(with_tables, &w.points, &w.scalars, &cfg);
        let b = execute(evicted, &w.points, &w.scalars, &cfg);
        assert!(a.eq_point(&b));
    }
}
