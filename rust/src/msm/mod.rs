//! Multi-scalar multiplication: `R = Σ sᵢ·Pᵢ` (§II-E).
//!
//! The paper's subject. Implementations, in increasing sophistication:
//!
//! * [`naive`] — per-point double-and-add then accumulate: the Table II
//!   baseline, O(m·N) point-ops;
//! * [`pippenger`] — the Bucket Algorithm (Algorithm 2 / Pippenger [21])
//!   over k-bit scalar slices, with **two bucket-reduction strategies**:
//!   the classic serial running sum, and the paper's novel **recursive
//!   bucket reduction (IS-RBAM, §IV-A)** which converts the latency-bound
//!   running sum into pipeline-friendly bucket fills — identical results,
//!   different op/latency profile (the FPGA model exploits the
//!   difference);
//! * [`parallel`] — multi-threaded Pippenger (windows fan out across
//!   threads; the software analogue of replicated BAM units);
//! * [`batch_affine`] — bucket fills with shared batch inversion (≈6M per
//!   add instead of 11M): the §Perf/L3 optimization, also the software
//!   echo of the BAM's one-op-per-bucket-per-round conflict rule.
//!
//! All variants are bit-exact against each other; property tests in
//! `rust/tests/prop_msm.rs` enforce it.

pub mod naive;
pub mod pippenger;
pub mod parallel;
pub mod batch_affine;

use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};

pub use pippenger::{msm as msm_pippenger, MsmConfig, Reduction};

/// Heuristic window width: balances m/window bucket fills against 2^k
/// reduction work. Matches the usual c ≈ log2(m) − 3 rule, clamped to the
/// paper's hardware point k = 12.
pub fn auto_window(m: usize) -> u32 {
    let lg = (usize::BITS - m.leading_zeros()).max(1);
    (lg.saturating_sub(3)).clamp(2, 16)
}

/// Top-level convenience: Pippenger with auto window and recursive
/// reduction (the paper's configuration).
pub fn msm<C: CurveParams>(points: &[Affine<C>], scalars: &[ScalarLimbs]) -> Jacobian<C> {
    pippenger::msm(
        points,
        scalars,
        &MsmConfig { window_bits: auto_window(points.len()), reduction: Reduction::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bn254G1};

    #[test]
    fn auto_window_monotone() {
        assert!(auto_window(1 << 10) <= auto_window(1 << 20));
        assert_eq!(auto_window(1), 2);
        assert!(auto_window(usize::MAX / 2) <= 16);
    }

    #[test]
    fn msm_toplevel_matches_naive() {
        let w = points::workload::<Bn254G1>(100, 17);
        let a = msm(&w.points, &w.scalars);
        let b = naive::msm(&w.points, &w.scalars);
        assert!(a.eq_point(&b));
    }
}
