//! Partial MSMs: shard specs, window-range execution, and the
//! deterministic merge — the kernel-level half of the multi-device
//! sharding layer (`coordinator::shard` owns the device-level half).
//!
//! The paper replicates BAM units *inside* one accelerator (scaling factor
//! S); SZKP shards the same work across many PEs. This module generalizes
//! both: one m-point MSM splits into independent shards that any
//! [`super::Backend`] (or any device) can execute, and the partials merge
//! back with plain point additions in a fixed order, so the final point is
//! identical no matter which shard finishes first.
//!
//! Two shard shapes exist, mirroring the two ways the sum
//! `R = Σⱼ 2^(k·j) · Σᵢ dᵢⱼ·Pᵢ` factorizes:
//!
//! * [`ShardSpec::PointChunk`] — a contiguous slice of the point/scalar
//!   stream, all windows. The MSM is linear in its inputs, so
//!   `msm(P, s) = msm(P[..c], s[..c]) + msm(P[c..], s[c..])`.
//! * [`ShardSpec::WindowRange`] — all points, a contiguous range of k-bit
//!   windows, pre-shifted to its global Horner position by
//!   [`msm_window_range`], so partials still merge by addition alone.

use super::plan::{MsmConfig, MsmPlan};
use super::Backend;
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};

/// How a multi-device MSM is split (one spec per shard is derived via
/// [`ShardPolicy::plan`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    /// Contiguous chunks of the point/scalar stream, one per device: each
    /// device streams only its chunk (scalars split across devices), runs
    /// every window, and the partials add up. Scales both fills and DDR
    /// streaming — the default.
    #[default]
    ChunkPoints,
    /// Contiguous k-bit window ranges, one per device: every device sees
    /// all m scalars (broadcast) but fills/reduces only its windows.
    /// Requires every shard to run the *same* [`MsmConfig`] or the window
    /// boundaries disagree.
    WindowRange,
}

impl ShardPolicy {
    /// Shard an m-point MSM under `cfg` into at most `shards` specs
    /// (fewer when there is not enough work to split).
    pub fn plan<C: CurveParams>(&self, m: usize, cfg: &MsmConfig, shards: usize) -> Vec<ShardSpec> {
        match self {
            ShardPolicy::ChunkPoints => chunk_specs(m, shards),
            ShardPolicy::WindowRange => {
                window_specs(MsmPlan::for_curve::<C>(cfg).windows, shards)
            }
        }
    }
}

/// The slice of one MSM a single shard computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSpec {
    /// Full windows over `points[lo..hi]` (scalars sliced identically).
    PointChunk {
        /// First point index (inclusive).
        lo: usize,
        /// Last point index (exclusive).
        hi: usize,
    },
    /// Windows `[lo, hi)` over all points, pre-shifted to global position.
    WindowRange {
        /// First window index (inclusive).
        lo: u32,
        /// Last window index (exclusive).
        hi: u32,
    },
}

impl ShardSpec {
    /// Number of points the shard streams (its device-load proxy).
    pub fn points(&self, m: usize) -> usize {
        match *self {
            ShardSpec::PointChunk { lo, hi } => hi - lo,
            ShardSpec::WindowRange { .. } => m,
        }
    }

    /// Human-readable form for logs and error messages.
    pub fn describe(&self) -> String {
        match *self {
            ShardSpec::PointChunk { lo, hi } => format!("points[{lo}..{hi}]"),
            ShardSpec::WindowRange { lo, hi } => format!("windows[{lo}..{hi})"),
        }
    }
}

/// Split an m-point MSM into at most `shards` contiguous point chunks.
/// Chunk sizes differ by at most one point; empty chunks are never
/// emitted (so `shards > m` yields `m` one-point chunks).
pub fn chunk_specs(m: usize, shards: usize) -> Vec<ShardSpec> {
    let shards = shards.clamp(1, m.max(1));
    let base = m / shards;
    let extra = m % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for i in 0..shards {
        let len = base + usize::from(i < extra);
        out.push(ShardSpec::PointChunk { lo, hi: lo + len });
        lo += len;
    }
    out
}

/// Split a plan's `windows` k-bit windows into at most `shards` contiguous
/// ranges (sizes differ by at most one window; never empty).
pub fn window_specs(windows: u32, shards: usize) -> Vec<ShardSpec> {
    let shards = (shards.max(1) as u32).min(windows.max(1));
    let base = windows / shards;
    let extra = windows % shards;
    let mut out = Vec::with_capacity(shards as usize);
    let mut lo = 0u32;
    for i in 0..shards {
        let len = base + u32::from(i < extra);
        out.push(ShardSpec::WindowRange { lo, hi: lo + len });
        lo += len;
    }
    out
}

/// Execute windows `[lo, hi)` of the plan over all points, returning the
/// partial already shifted to its global Horner position
/// (`Σ_{j∈[lo,hi)} 2^(k·j)·Wⱼ`), so window-range partials merge by plain
/// point addition.
pub fn msm_window_range<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    lo: u32,
    hi: u32,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let plan = MsmPlan::for_curve::<C>(cfg);
    assert!(lo <= hi && hi <= plan.windows, "window range [{lo}, {hi}) outside plan");
    // Per-point GLV expansion is deterministic, so every device expanding
    // the full set for its window range produces identical inputs — the
    // merge invariant below survives the decomposition. Each shard
    // expands independently (O(m) integer work, duplicated per device):
    // mandatory across real distributed devices, and accepted in the
    // in-process pool too, where it is noise next to the O(m·windows)
    // point operations a shard performs and buys one shared code path.
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = super::plan::DigitMatrix::build(&plan, input.scalars());
    let mut acc = Jacobian::<C>::infinity();
    for j in (lo..hi).rev() {
        let w = plan.reduce(&plan.fill_window_from(&matrix, points, j));
        acc = acc.double_n(plan.window_bits).add(&w);
    }
    // shift the range result to its global position: k·lo doublings
    acc.double_n(plan.window_bits * lo)
}

/// [`msm_window_range`] with the range's windows fanned out across OS
/// threads (the same window-level parallelism `super::parallel` uses for
/// whole MSMs). Identical output to the serial form — the Horner combine
/// runs in window order either way.
pub fn msm_window_range_threaded<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    lo: u32,
    hi: u32,
    threads: usize,
) -> Jacobian<C> {
    let threads = threads.max(1);
    let count = hi.saturating_sub(lo) as usize;
    if threads == 1 || count <= 1 {
        return msm_window_range(points, scalars, cfg, lo, hi);
    }
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let plan = MsmPlan::for_curve::<C>(cfg);
    assert!(hi <= plan.windows, "window range [{lo}, {hi}) outside plan");
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = super::plan::DigitMatrix::build_parallel(&plan, input.scalars(), threads);
    let mut window_results = vec![Jacobian::<C>::infinity(); count];
    std::thread::scope(|scope| {
        let per = count.div_ceil(threads);
        for (t, chunk) in window_results.chunks_mut(per).enumerate() {
            let first = lo + (t * per) as u32;
            let (plan, matrix) = (&plan, &matrix);
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let j = first + i as u32;
                    *slot = plan.reduce(&plan.fill_window_from(matrix, points, j));
                }
            });
        }
    });
    let mut acc = Jacobian::<C>::infinity();
    for wj in window_results.iter().rev() {
        acc = acc.double_n(plan.window_bits).add(wj);
    }
    acc.double_n(plan.window_bits * lo)
}

/// Execute one shard. Point chunks run through the full backend dispatch;
/// window ranges run the shared plan directly — serially, or window-
/// parallel when the backend is a threaded one (every backend agrees with
/// the plan bit-exactly, so the merge stays backend-independent).
pub fn execute_shard<C: CurveParams>(
    backend: Backend,
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    spec: &ShardSpec,
) -> Jacobian<C> {
    match *spec {
        ShardSpec::PointChunk { lo, hi } => {
            super::execute(backend, &points[lo..hi], &scalars[lo..hi], cfg)
        }
        ShardSpec::WindowRange { lo, hi } => {
            let threads = match backend {
                Backend::Parallel { threads }
                | Backend::BatchAffineParallel { threads }
                | Backend::Chunked { threads } => threads,
                _ => 1,
            };
            msm_window_range_threaded(points, scalars, cfg, lo, hi, threads)
        }
    }
}

/// One shard's output, tagged for the deterministic merge.
#[derive(Clone, Copy, Debug)]
pub struct PartialMsm<C: CurveParams> {
    /// Position in the shard plan (the merge orders by this).
    pub index: usize,
    /// The shard this partial answers.
    pub spec: ShardSpec,
    /// The shard's (pre-shifted, addition-ready) partial sum.
    pub output: Jacobian<C>,
}

/// Deterministic reduce: partials are summed in shard-index order, so the
/// merged point — coordinates included, not just the projective class —
/// never depends on which device finished first.
pub fn merge<C: CurveParams>(partials: &mut [PartialMsm<C>]) -> Jacobian<C> {
    partials.sort_by_key(|p| p.index);
    let mut acc = Jacobian::<C>::infinity();
    for p in partials.iter() {
        acc = acc.add(&p.output);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bn254G1};
    use crate::msm::{self, Reduction};

    #[test]
    fn chunk_specs_cover_exactly() {
        for (m, n) in [(10usize, 3usize), (7, 7), (5, 9), (64, 4), (1, 1)] {
            let specs = chunk_specs(m, n);
            assert!(specs.len() <= n && !specs.is_empty());
            let mut next = 0usize;
            for s in &specs {
                match *s {
                    ShardSpec::PointChunk { lo, hi } => {
                        assert_eq!(lo, next);
                        assert!(hi > lo, "empty chunk in {specs:?}");
                        next = hi;
                    }
                    _ => panic!("chunk plan emitted a window spec"),
                }
            }
            assert_eq!(next, m);
        }
    }

    #[test]
    fn window_specs_cover_exactly() {
        for (w, n) in [(22u32, 4usize), (22, 30), (1, 3), (8, 8)] {
            let specs = window_specs(w, n);
            let mut next = 0u32;
            for s in &specs {
                match *s {
                    ShardSpec::WindowRange { lo, hi } => {
                        assert_eq!(lo, next);
                        assert!(hi > lo);
                        next = hi;
                    }
                    _ => panic!("window plan emitted a chunk spec"),
                }
            }
            assert_eq!(next, w);
        }
    }

    #[test]
    fn full_window_range_equals_pippenger() {
        let w = points::workload::<Bn254G1>(90, 901);
        let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 3 });
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let want = msm::msm_pippenger(&w.points, &w.scalars, &cfg);
        let got = msm_window_range(&w.points, &w.scalars, &cfg, 0, plan.windows);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn threaded_window_range_matches_serial() {
        let w = points::workload::<Bn254G1>(80, 903);
        let cfg = MsmConfig::new(7, Reduction::RunningSum);
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let (lo, hi) = (1, plan.windows - 1);
        let serial = msm_window_range(&w.points, &w.scalars, &cfg, lo, hi);
        for threads in [2usize, 4, 9] {
            let par = msm_window_range_threaded(&w.points, &w.scalars, &cfg, lo, hi, threads);
            assert!(par.eq_point(&serial), "threads={threads}");
        }
    }

    #[test]
    fn merged_shards_equal_whole_msm_both_shapes() {
        let w = points::workload::<Bn254G1>(70, 902);
        let cfg = MsmConfig::default();
        let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        for specs in [chunk_specs(70, 3), window_specs(windows, 3)] {
            let mut parts: Vec<PartialMsm<Bn254G1>> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| PartialMsm {
                    index: i,
                    spec: *s,
                    output: execute_shard(Backend::Pippenger, &w.points, &w.scalars, &cfg, s),
                })
                .collect();
            parts.reverse(); // arrival order must not matter
            assert!(merge(&mut parts).eq_point(&want), "{specs:?}");
        }
    }

    #[test]
    fn policy_plans_respect_device_count() {
        let cfg = MsmConfig::default();
        let chunk = ShardPolicy::ChunkPoints.plan::<Bn254G1>(1000, &cfg, 4);
        assert_eq!(chunk.len(), 4);
        let win = ShardPolicy::WindowRange.plan::<Bn254G1>(1000, &cfg, 4);
        assert_eq!(win.len(), 4);
        // more devices than windows: clamp, never emit empty shards
        let win = ShardPolicy::WindowRange.plan::<Bn254G1>(1000, &cfg, 64);
        let windows = MsmPlan::for_curve::<Bn254G1>(&cfg).windows as usize;
        assert_eq!(win.len(), windows.min(64));
    }
}
