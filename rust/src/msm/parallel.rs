//! Multi-threaded Pippenger: windows fan out across OS threads — the
//! software analogue of the paper's replicated BAM units (scaling knob S,
//! §IV-A), and the engine behind the multi-core CPU baseline column of
//! Table IX (the paper's CPU reference uses OpenMP libsnark).

use super::pippenger::{self, MsmConfig};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};

/// Parallel MSM over `threads` OS threads (window-level parallelism: each
/// thread owns a disjoint set of k-bit windows; the final Horner combine is
/// serial and cheap).
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let threads = threads.max(1);
    let k = cfg.window_bits;
    let windows = pippenger::window_count(C::SCALAR_BITS.min(256), k);
    if threads == 1 || windows == 1 {
        return pippenger::msm(points, scalars, cfg);
    }

    // Window results, computed in parallel.
    let mut window_results = vec![Jacobian::<C>::infinity(); windows as usize];
    std::thread::scope(|scope| {
        let chunks: Vec<&mut [Jacobian<C>]> = {
            // round-robin would interleave; contiguous chunks keep it simple
            let per = windows.div_ceil(threads as u32) as usize;
            window_results.chunks_mut(per).collect()
        };
        for (t, chunk) in chunks.into_iter().enumerate() {
            let per = windows.div_ceil(threads as u32) as usize;
            let first = t * per;
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let j = (first + i) as u32;
                    *slot = window_msm::<C>(points, scalars, j * k, k, cfg);
                }
            });
        }
    });

    // DNA combine.
    let mut result = Jacobian::<C>::infinity();
    for wj in window_results.iter().rev() {
        for _ in 0..k {
            result = result.double();
        }
        result = result.add(wj);
    }
    result
}

/// One window's bucket MSM (fill + reduce).
fn window_msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    lo: u32,
    k: u32,
    cfg: &MsmConfig,
) -> Jacobian<C> {
    let mut buckets = vec![Jacobian::<C>::infinity(); 1 << k];
    for (p, s) in points.iter().zip(scalars) {
        let b = pippenger::slice_bits(s, lo, k) as usize;
        if b != 0 {
            buckets[b] = buckets[b].add_mixed(p);
        }
    }
    match cfg.reduction {
        super::Reduction::RunningSum => pippenger::reduce_running_sum(&buckets),
        super::Reduction::Recursive { k2 } => {
            pippenger::reduce_recursive(&buckets, k, k2.min(k))
        }
    }
}

/// Default thread count: physical parallelism minus one for the OS, at
/// least one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::naive;

    #[test]
    fn parallel_matches_serial() {
        let w = points::workload::<Bn254G1>(128, 81);
        let want = naive::msm(&w.points, &w.scalars);
        for threads in [1usize, 2, 4, 32] {
            let got = msm(&w.points, &w.scalars, &MsmConfig::default(), threads);
            assert!(got.eq_point(&want), "threads={threads}");
        }
    }

    #[test]
    fn parallel_bls_matches() {
        let w = points::workload::<Bls12381G1>(64, 82);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default(), 4);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn more_threads_than_windows_is_fine() {
        let w = points::workload::<Bn254G1>(16, 83);
        let cfg = MsmConfig { window_bits: 16, reduction: Default::default() };
        // 16 windows, 64 threads
        let got = msm(&w.points, &w.scalars, &cfg, 64);
        assert!(got.eq_point(&naive::msm(&w.points, &w.scalars)));
    }

    #[test]
    fn default_threads_nonzero() {
        assert!(default_threads() >= 1);
    }
}
