//! Multi-threaded Pippenger: windows fan out across OS threads — the
//! software analogue of the paper's replicated BAM units (scaling knob S,
//! §IV-A), and the engine behind the multi-core CPU baseline column of
//! Table IX (the paper's CPU reference uses OpenMP libsnark).
//!
//! All window slicing / bucket indexing / reduction comes from the shared
//! [`MsmPlan`]; this file only owns the thread fan-out.

use super::plan::{MsmConfig, MsmPlan};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};

/// Parallel MSM over `threads` OS threads (window-level parallelism: each
/// thread owns a disjoint set of k-bit windows; the final Horner combine is
/// serial and cheap).
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let threads = threads.max(1);
    let plan = MsmPlan::for_curve::<C>(cfg);
    let windows = plan.windows;
    if threads == 1 || windows == 1 {
        return super::pippenger::msm(points, scalars, cfg);
    }
    // Decomposition (GLV expansion when configured) and the one-pass
    // digit recode happen once, up front, so every window thread reads
    // the same prepared view and the same matrix.
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = super::plan::DigitMatrix::build_parallel(&plan, input.scalars(), threads);

    // Window results, computed in parallel.
    let mut window_results = vec![Jacobian::<C>::infinity(); windows as usize];
    std::thread::scope(|scope| {
        let per = windows.div_ceil(threads as u32) as usize;
        for (t, chunk) in window_results.chunks_mut(per).enumerate() {
            let first = t * per;
            let (plan, matrix) = (&plan, &matrix);
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let j = (first + i) as u32;
                    *slot = plan.reduce(&plan.fill_window_from(matrix, points, j));
                }
            });
        }
    });

    // DNA combine.
    plan.combine(&window_results)
}

/// Default thread count: physical parallelism minus one for the OS, at
/// least one.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::naive;
    use crate::msm::plan::{Reduction, Slicing};

    #[test]
    fn parallel_matches_serial() {
        let w = points::workload::<Bn254G1>(128, 81);
        let want = naive::msm(&w.points, &w.scalars);
        for threads in [1usize, 2, 4, 32] {
            let got = msm(&w.points, &w.scalars, &MsmConfig::default(), threads);
            assert!(got.eq_point(&want), "threads={threads}");
        }
    }

    #[test]
    fn parallel_matches_in_both_slicing_modes() {
        let w = points::workload::<Bn254G1>(96, 84);
        let want = naive::msm(&w.points, &w.scalars);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            let cfg = MsmConfig {
                window_bits: 9,
                reduction: Reduction::RunningSum,
                slicing,
                ..Default::default()
            };
            let got = msm(&w.points, &w.scalars, &cfg, 3);
            assert!(got.eq_point(&want), "{slicing:?}");
        }
    }

    #[test]
    fn parallel_bls_matches() {
        let w = points::workload::<Bls12381G1>(64, 82);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default(), 4);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn more_threads_than_windows_is_fine() {
        let w = points::workload::<Bn254G1>(16, 83);
        let cfg = MsmConfig::new(16, Default::default());
        // 16 windows, 64 threads
        let got = msm(&w.points, &w.scalars, &cfg, 64);
        assert!(got.eq_point(&naive::msm(&w.points, &w.scalars)));
    }

    #[test]
    fn default_threads_nonzero() {
        assert!(default_threads() >= 1);
    }
}
