//! Chunk-parallel MSM runtime: **points** partition across threads, not
//! windows.
//!
//! The window-parallel backends ([`super::parallel`],
//! [`super::batch_affine`]) cap their useful thread count at the plan's
//! window count — 22 for BN254 at the hardware k = 12, and only 11 under
//! the GLV split. SZKP and ZK-Flex scale their accelerators by
//! partitioning the *point stream* instead; this backend is the CPU
//! analogue, so `baseline::cpu` throughput keeps scaling with cores:
//!
//! 1. **Recode** — one pass over the (GLV-prepared) scalars builds the
//!    row-major [`DigitMatrix`]; no scalar is ever re-sliced per window.
//! 2. **Fill** — each thread owns a contiguous point chunk and fills a
//!    *private* bucket array covering **all** windows at once (flat index
//!    `window · slots + |digit|`), through the shared batch-affine
//!    batched-inversion accumulator — one round's inversion serves every
//!    window's lanes. Private arrays mean no locks and no conflict
//!    stalls between threads; the cost is memory:
//!    `threads × windows × bucket_slots` Jacobian points.
//! 3. **Merge** — per-thread arrays combine bucketwise in a pairwise
//!    tree over *thread index* (round 1 pairs (0,1), (2,3), …). Bucket
//!    accumulation is a commutative group sum, and the pairing is fixed,
//!    so the merged buckets — and therefore the reduce/combine output —
//!    never depend on thread completion order and stay `eq_point`-equal
//!    to every other backend.
//! 4. **Reduce + combine** — the merged buckets reduce once per window
//!    (window-parallel, the only phase where window count bounds
//!    threads) and the usual Horner shift chain (`double_n`) combines.
//!
//! [`msm_with_phases`] reports wall-clock per phase; the hotpath bench
//! emits that breakdown into the `BENCH_hotpath.json` artifact.

use super::batch_affine;
use super::plan::{DigitMatrix, MsmConfig, MsmPlan};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::util::Stopwatch;

/// One thread's private bucket array (flat `windows × slots` layout).
type Buckets<C> = Vec<Jacobian<C>>;

/// Minimum points per chunk worth a dedicated thread: below this the
/// thread's private bucket array (`windows × slots` Jacobian points) and
/// its share of the merge dwarf the fill work it contributes, so the
/// thread count is clamped to `⌈m / MIN_CHUNK⌉`. Large MSMs are
/// unaffected (at m = 2¹⁶ the clamp sits at 4096 threads).
const MIN_CHUNK: usize = 16;

/// Wall-clock seconds per phase of one chunk-parallel MSM (the
/// recode/fill/merge/reduce split the hotpath bench records).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkedPhases {
    /// Building the one-pass digit matrix.
    pub recode_s: f64,
    /// Per-thread private bucket fills (batch-affine accumulation).
    pub fill_s: f64,
    /// Pairwise bucket-array merge.
    pub merge_s: f64,
    /// Window reductions plus the final Horner combine.
    pub reduce_s: f64,
}

impl ChunkedPhases {
    /// Total across the four phases.
    pub fn total_s(&self) -> f64 {
        self.recode_s + self.fill_s + self.merge_s + self.reduce_s
    }
}

/// Chunk-parallel MSM over `threads` OS threads (point-level
/// parallelism; see the module docs for the phase pipeline).
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> Jacobian<C> {
    msm_with_phases(points, scalars, cfg, threads).0
}

/// [`msm`] with the wall-clock phase breakdown.
pub fn msm_with_phases<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> (Jacobian<C>, ChunkedPhases) {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let mut phases = ChunkedPhases::default();
    if points.is_empty() {
        return (Jacobian::infinity(), phases);
    }
    let plan = MsmPlan::for_curve::<C>(cfg);
    let input = plan.prepare::<C>(points, scalars);
    let (points, scalars) = (input.points(), input.scalars());
    let m = points.len();
    let threads = threads.clamp(1, m.div_ceil(MIN_CHUNK));
    let windows = plan.windows as usize;
    let slots = plan.bucket_slots();

    // -- recode: one pass over the scalars ------------------------------
    let sw = Stopwatch::start();
    let matrix = DigitMatrix::build_parallel(&plan, scalars, threads);
    phases.recode_s = sw.secs();

    // -- fill: private all-window buckets per point chunk ----------------
    // (threads == 1 runs inline so the thread-local op counters keep
    // seeing the work — the perf-smoke pins measure through this path)
    let sw = Stopwatch::start();
    let chunk = m.div_ceil(threads);
    // `points.chunks` is the source of truth for the partition (ceil
    // division arithmetic can overshoot m on the last band); every band
    // is non-empty, so every array is full-sized for the merge.
    let mut arrays: Vec<Buckets<C>> = if threads == 1 {
        vec![fill_chunk(&plan, &matrix, points, 0)]
    } else {
        let mut arrays: Vec<Buckets<C>> = vec![Vec::new(); m.div_ceil(chunk)];
        std::thread::scope(|scope| {
            for (t, (slot, band)) in arrays.iter_mut().zip(points.chunks(chunk)).enumerate() {
                let lo = t * chunk;
                let (plan, matrix) = (&plan, &matrix);
                scope.spawn(move || {
                    *slot = fill_chunk(plan, matrix, band, lo);
                });
            }
        });
        arrays
    };
    phases.fill_s = sw.secs();

    // -- merge: pairwise tree over thread index --------------------------
    let sw = Stopwatch::start();
    while arrays.len() > 1 {
        // an odd trailing array passes through and keeps its position
        let tail = if arrays.len() % 2 == 1 { arrays.pop() } else { None };
        let pairs: Vec<(Buckets<C>, Buckets<C>)> = {
            let mut drained = std::mem::take(&mut arrays).into_iter();
            let mut pairs = Vec::new();
            while let (Some(a), Some(b)) = (drained.next(), drained.next()) {
                pairs.push((a, b));
            }
            pairs
        };
        std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .into_iter()
                .map(|(mut a, b)| {
                    scope.spawn(move || {
                        for (x, y) in a.iter_mut().zip(&b) {
                            *x = x.add(y);
                        }
                        a
                    })
                })
                .collect();
            // join in spawn order: the next round's pairing stays fixed
            for h in handles {
                arrays.push(h.join().expect("merge thread panicked"));
            }
        });
        if let Some(t) = tail {
            arrays.push(t);
        }
    }
    let buckets = arrays.pop().expect("at least one bucket array");
    phases.merge_s = sw.secs();

    // -- reduce (window-parallel) + Horner combine -----------------------
    let sw = Stopwatch::start();
    let mut window_results = vec![Jacobian::<C>::infinity(); windows];
    if threads == 1 {
        for (j, slot) in window_results.iter_mut().enumerate() {
            *slot = plan.reduce(&buckets[j * slots..(j + 1) * slots]);
        }
    } else {
        std::thread::scope(|scope| {
            let per = windows.div_ceil(threads);
            for (t, out) in window_results.chunks_mut(per).enumerate() {
                let first = t * per;
                let (plan, buckets) = (&plan, &buckets[..]);
                scope.spawn(move || {
                    for (i, slot) in out.iter_mut().enumerate() {
                        let j = first + i;
                        *slot = plan.reduce(&buckets[j * slots..(j + 1) * slots]);
                    }
                });
            }
        });
    }
    let result = plan.combine(&window_results);
    phases.reduce_s = sw.secs();
    (result, phases)
}

/// Fill one point band's private all-window buckets (`band` starts at
/// global point index `lo`): every (point, window) op lands at flat
/// index `window · slots + |digit|`, so a single batch-affine round
/// batches inversion lanes across *all* windows at once.
fn fill_chunk<C: CurveParams>(
    plan: &MsmPlan,
    matrix: &DigitMatrix,
    band: &[Affine<C>],
    lo: usize,
) -> Buckets<C> {
    let slots = plan.bucket_slots();
    let windows = plan.windows;
    let ops = band.iter().enumerate().flat_map(move |(off, p)| {
        let row = lo + off;
        (0..windows).filter_map(move |j| {
            if p.infinity {
                return None;
            }
            matrix
                .bucket_op(row, j)
                .map(|(b, negate)| (j as usize * slots + b, if negate { p.neg() } else { *p }))
        })
    });
    batch_affine::fill_batch_affine(windows as usize * slots, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::{naive, Reduction, Slicing};

    #[test]
    fn matches_naive_across_thread_counts() {
        let w = points::workload::<Bn254G1>(130, 951);
        let want = naive::msm(&w.points, &w.scalars);
        for threads in [1usize, 2, 4, 32, 200] {
            let got = msm(&w.points, &w.scalars, &MsmConfig::default(), threads);
            assert!(got.eq_point(&want), "threads={threads}");
        }
    }

    #[test]
    fn ragged_partition_uses_fewer_bands_than_threads() {
        // m = 305, threads = 19: chunk = ⌈305/19⌉ = 17, but only
        // ⌈305/17⌉ = 18 bands exist — the partition must follow the
        // slice, not the ceil arithmetic (which would index past m)
        let w = points::workload::<Bn254G1>(305, 957);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default(), 19);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn matches_naive_both_slicings_and_reductions() {
        let w = points::workload::<Bn254G1>(90, 952);
        let want = naive::msm(&w.points, &w.scalars);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 3 }] {
                let cfg =
                    MsmConfig { window_bits: 8, reduction: red, slicing, ..Default::default() };
                let got = msm(&w.points, &w.scalars, &cfg, 3);
                assert!(got.eq_point(&want), "{slicing:?} {red:?}");
            }
        }
    }

    #[test]
    fn matches_naive_glv_bls() {
        let w = points::workload::<Bls12381G1>(64, 953);
        let want = naive::msm(&w.points, &w.scalars);
        let cfg = MsmConfig::default().glv();
        let got = msm(&w.points, &w.scalars, &cfg, 5);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let (r, phases) = msm_with_phases::<Bn254G1>(&[], &[], &MsmConfig::default(), 4);
        assert!(r.is_infinity());
        assert_eq!(phases.total_s(), 0.0);
        // one point, many threads: the MIN_CHUNK clamp collapses to one
        let w = points::workload::<Bn254G1>(1, 954);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default(), 16);
        assert!(got.eq_point(&naive::msm(&w.points, &w.scalars)));
    }

    #[test]
    fn deterministic_across_runs() {
        // the pairwise merge must make the output coordinates (not just
        // the projective class) independent of thread scheduling
        let w = points::workload::<Bn254G1>(150, 955);
        let cfg = MsmConfig::new(7, Reduction::RunningSum);
        let a = msm(&w.points, &w.scalars, &cfg, 4);
        for _ in 0..3 {
            let b = msm(&w.points, &w.scalars, &cfg, 4);
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
            assert_eq!(a.z, b.z);
        }
    }

    #[test]
    fn phases_are_recorded() {
        let w = points::workload::<Bn254G1>(600, 956);
        let (out, phases) = msm_with_phases(&w.points, &w.scalars, &MsmConfig::default(), 2);
        assert!(out.eq_point(&naive::msm(&w.points, &w.scalars)));
        assert!(phases.recode_s >= 0.0 && phases.fill_s > 0.0);
        assert!(phases.reduce_s > 0.0);
        assert!(phases.total_s() >= phases.fill_s);
    }
}
