//! Naive MSM: m independent double-and-add scalar multiplications followed
//! by a sum — the cost baseline of the paper's Table II
//! (`m × (2 × N × 16)` modular multiplications).

use crate::ec::{scalar, Affine, CurveParams, Jacobian, ScalarLimbs};

/// Σ sᵢ·Pᵢ by Algorithm 1 per point.
pub fn msm<C: CurveParams>(points: &[Affine<C>], scalars: &[ScalarLimbs]) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let mut acc = Jacobian::<C>::infinity();
    for (p, s) in points.iter().zip(scalars) {
        let term = scalar::mul::<C>(&p.to_jacobian(), s);
        acc = acc.add(&term);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, scalar, Bls12381G1, Bn254G1};

    #[test]
    fn empty_msm_is_infinity() {
        let out = msm::<Bn254G1>(&[], &[]);
        assert!(out.is_infinity());
    }

    #[test]
    fn single_point_matches_scalar_mul() {
        let w = points::workload::<Bls12381G1>(1, 23);
        let out = msm(&w.points, &w.scalars);
        let want = scalar::mul::<Bls12381G1>(&w.points[0].to_jacobian(), &w.scalars[0]);
        assert!(out.eq_point(&want));
    }

    #[test]
    fn linear_in_scalars() {
        // MSM(s, P) + MSM(t, P) == MSM(s+t, P) for small carry-free scalars
        let pts = points::generate_points_walk::<Bn254G1>(10, 31);
        let s: Vec<_> = (0..10u64).map(|i| [i + 1, 0, 0, 0]).collect();
        let t: Vec<_> = (0..10u64).map(|i| [100 - i, 0, 0, 0]).collect();
        let st: Vec<_> = (0..10u64).map(|i| [101, 0, 0, 0].map(|x| x + 0 * i)).collect();
        let lhs = msm(&pts, &s).add(&msm(&pts, &t));
        let rhs = msm(&pts, &st);
        assert!(lhs.eq_point(&rhs));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn rejects_mismatched_lengths() {
        let pts = points::generate_points_walk::<Bn254G1>(3, 1);
        let _ = msm(&pts, &[[1, 0, 0, 0]]);
    }
}
