//! Random-linear-combination batched point-equality auditing.
//!
//! Cross-checks like "streamed proof == resident proof" and "sharded
//! merge == unsharded result" compare N (got, want) point pairs. Checking
//! them one by one costs N full Jacobian comparisons (each a handful of
//! field muls to cross-normalize Z); the RLC fold here verifies all N
//! with **one** comparison: draw independent random coefficients rᵢ and
//! test
//!
//! ```text
//!   Σ rᵢ·(gotᵢ − wantᵢ)  ==  ∞
//! ```
//!
//! If every pair matches, the sum is the identity for any choice of rᵢ.
//! If some pair differs, the sum is a fixed nonzero point scaled by a
//! random 128-bit coefficient plus independent terms — by
//! Schwartz–Zippel it lands on the identity with probability ≤ 2⁻¹²⁸ per
//! differing pair. This is the serving-side seed of the paper's batched
//! verification story: a coordinator auditing many device results pays
//! one fold, not N comparisons.
//!
//! Determinism: the caller supplies the seed, so audits are reproducible
//! run-to-run (the repo-wide invariant); soundness needs the seed to be
//! outside the prover's control, which holds for self-audits.

use crate::ec::{scalar, CurveParams, Jacobian, ScalarLimbs};
use crate::util::rng::Rng;

/// Domain-separation constant folded into the caller's seed so an audit
/// stream never reuses the point-generation stream of the same seed.
const AUDIT_STREAM: u64 = 0xBA7C4_E0_0553;

/// Verify N `(got, want)` Jacobian pairs with one random-linear-
/// combination fold and a single infinity test.
///
/// Returns `true` iff every pair is (projectively) equal — up to the
/// ≤ N·2⁻¹²⁸ Schwartz–Zippel false-accept bound; `false` never
/// mis-fires on equal inputs. Single-pair calls short-circuit to an
/// exact [`Jacobian::eq_point`], and an empty batch is vacuously true.
pub fn batch_eq<C: CurveParams>(pairs: &[(Jacobian<C>, Jacobian<C>)], seed: u64) -> bool {
    match pairs {
        [] => return true,
        [(got, want)] => return got.eq_point(want),
        _ => {}
    }
    let mut rng = Rng::new(seed ^ AUDIT_STREAM);
    let mut acc = Jacobian::<C>::infinity();
    for (got, want) in pairs {
        // 128 random bits per coefficient: two limbs, forced odd so a
        // zero draw can never silently drop its pair from the fold
        let r: ScalarLimbs = [rng.next_u64() | 1, rng.next_u64(), 0, 0];
        let diff = got.add(&want.neg());
        acc = acc.add(&scalar::mul::<C>(&diff, &r));
    }
    acc.is_infinity()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};

    fn pairs_of<C: CurveParams>(n: usize, seed: u64) -> Vec<(Jacobian<C>, Jacobian<C>)> {
        points::generate_points_walk::<C>(n, seed)
            .into_iter()
            .map(|p| (p.to_jacobian(), p.to_jacobian()))
            .collect()
    }

    #[test]
    fn accepts_equal_pairs() {
        assert!(batch_eq::<Bn254G1>(&[], 1));
        assert!(batch_eq(&pairs_of::<Bn254G1>(1, 10), 2));
        assert!(batch_eq(&pairs_of::<Bn254G1>(8, 11), 3));
        assert!(batch_eq(&pairs_of::<Bls12381G1>(8, 12), 4));
    }

    #[test]
    fn accepts_projectively_equal_representations() {
        // got and want may carry different Z coordinates for the same
        // point — the fold must see through the representation
        let pts = points::generate_points_walk::<Bn254G1>(6, 13);
        let pairs: Vec<_> = pts
            .iter()
            .map(|p| {
                let j = p.to_jacobian();
                (j.add(&j).add(&j.neg()), j) // same point, scrambled Z
            })
            .collect();
        assert!(batch_eq(&pairs, 5));
    }

    #[test]
    fn rejects_any_corrupted_pair() {
        let g = Jacobian::<Bn254G1>::generator();
        for corrupt_at in [0usize, 3, 7] {
            let mut pairs = pairs_of::<Bn254G1>(8, 14);
            pairs[corrupt_at].0 = pairs[corrupt_at].0.add(&g);
            // a few seeds: rejection must not depend on a lucky draw
            for seed in [0u64, 1, 99] {
                assert!(!batch_eq(&pairs, seed), "corrupt_at={corrupt_at} seed={seed}");
            }
        }
    }

    #[test]
    fn single_pair_is_exact() {
        let g = Jacobian::<Bn254G1>::generator();
        assert!(batch_eq(&[(g, g)], 0));
        assert!(!batch_eq(&[(g, g.double())], 0));
    }
}
