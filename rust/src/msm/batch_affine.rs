//! Batch-affine bucket accumulation — the §Perf/L3 optimization.
//!
//! The bucket-fill phase is mixed Jacobian+affine addition (7M+4S each).
//! Keeping the buckets **affine** and batching one add per bucket per round
//! lets all the slope inversions share a single Montgomery-trick batch
//! inversion: amortized cost ≈ 6M per add (λ = Δy/Δx via shared inversion,
//! then 1S+2M to finish) instead of 11M — the same trick production MSM
//! libraries (gnark, arkworks, bellman) use, and a faithful software
//! echo of the paper's BAM conflict rule: one in-flight op per bucket per
//! round, conflicts replay next round.
//!
//! Edge lanes (doubling: same x same y; cancellation: same x opposite y;
//! first touch: empty bucket) are resolved in the same round without
//! inversions.

use super::plan::{DigitMatrix, MsmConfig, MsmPlan};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::ff::Field;

/// One window's buckets, affine with explicit emptiness.
struct AffineBuckets<C: CurveParams> {
    slots: Vec<Option<Affine<C>>>,
}

impl<C: CurveParams> AffineBuckets<C> {
    fn new(n: usize) -> Self {
        AffineBuckets { slots: (0..n).map(|_| None).collect() }
    }

    /// Fold all buckets into Jacobian form for the reduction phase.
    fn into_jacobian(self) -> Vec<Jacobian<C>> {
        self.slots
            .into_iter()
            .map(|s| s.map(|a| a.to_jacobian()).unwrap_or_else(Jacobian::infinity))
            .collect()
    }
}

/// Affine addition state for one batched lane.
enum Lane<C: CurveParams> {
    /// generic add: needs λ = (y2−y1)/(x2−x1)
    Add { bucket: usize, p: Affine<C>, q: Affine<C> },
    /// doubling: needs λ = 3x²/(2y)
    Double { bucket: usize, p: Affine<C> },
}

/// Below this many lanes a round's shared Fermat inversion (≈380 modmuls)
/// costs more than it saves — finish such tails on the Jacobian path.
/// (Degenerate example: the top scalar window has only a couple of bits ⇒
/// 3 buckets ⇒ thousands of single-lane rounds without this fallback.)
const MIN_BATCH: usize = 48;

/// Fill a bucket array with batch-affine adds, returning Jacobian
/// buckets ready for reduction. Bucket indices are opaque: the window
/// backends pass one window's slots, the chunk-parallel backend
/// (`super::chunked`) a fused `windows × slots` space so one round's
/// batch inversion serves every window at once.
///
/// `ops` yields (bucket, point). Rounds: at most one op per bucket; all
/// inversions in a round share one batch inversion. Once a round falls
/// under [`MIN_BATCH`] lanes, the remaining (conflict-tail) ops finish as
/// ordinary mixed-Jacobian adds.
pub(super) fn fill_batch_affine<C: CurveParams>(
    nbuckets: usize,
    ops: impl Iterator<Item = (usize, Affine<C>)>,
) -> Vec<Jacobian<C>> {
    let mut buckets = AffineBuckets::<C>::new(nbuckets);
    let mut pending: Vec<(usize, Affine<C>)> = ops.collect();
    let mut deferred: Vec<(usize, Affine<C>)> = Vec::new();
    let mut in_round = vec![false; nbuckets];

    while !pending.is_empty() {
        let mut lanes: Vec<Lane<C>> = Vec::new();
        for (b, p) in pending.drain(..) {
            if in_round[b] {
                deferred.push((b, p)); // BAM conflict FIFO
                continue;
            }
            match buckets.slots[b] {
                None => {
                    // first touch: free
                    buckets.slots[b] = Some(p);
                }
                Some(q) => {
                    in_round[b] = true;
                    if q.x == p.x {
                        if q.y == p.y {
                            lanes.push(Lane::Double { bucket: b, p });
                        } else {
                            // cancellation: bucket empties, no arithmetic
                            buckets.slots[b] = None;
                            in_round[b] = false;
                        }
                    } else {
                        lanes.push(Lane::Add { bucket: b, p: q, q: p });
                    }
                }
            }
        }

        if !lanes.is_empty() && lanes.len() < MIN_BATCH {
            // Tail regime: finish everything on the Jacobian path.
            let mut jac = buckets.into_jacobian();
            for lane in lanes {
                match lane {
                    Lane::Add { bucket, q, .. } => {
                        // `q` is the incoming point; the bucket value is
                        // already inside jac[bucket].
                        jac[bucket] = jac[bucket].add_mixed(&q);
                    }
                    Lane::Double { bucket, .. } => {
                        jac[bucket] = jac[bucket].double();
                    }
                }
            }
            for (b, p) in deferred.drain(..).chain(pending.drain(..)) {
                jac[b] = jac[b].add_mixed(&p);
            }
            return jac;
        }

        if !lanes.is_empty() {
            // batch inversion over every lane's denominator
            let denoms: Vec<C::Base> = lanes
                .iter()
                .map(|l| match l {
                    Lane::Add { p, q, .. } => q.x.sub(&p.x),
                    Lane::Double { p, .. } => p.y.double(),
                })
                .collect();
            let invs = batch_invert(&denoms);
            for (lane, dinv) in lanes.into_iter().zip(invs) {
                match lane {
                    Lane::Add { bucket, p, q } => {
                        let lambda = q.y.sub(&p.y).mul(&dinv);
                        let x3 = lambda.square().sub(&p.x).sub(&q.x);
                        let y3 = lambda.mul(&p.x.sub(&x3)).sub(&p.y);
                        buckets.slots[bucket] = Some(Affine::new(x3, y3));
                        in_round[bucket] = false;
                    }
                    Lane::Double { bucket, p } => {
                        // λ = 3x² / 2y (a = 0)
                        let xx = p.x.square();
                        let lambda = xx.double().add(&xx).mul(&dinv);
                        let x3 = lambda.square().sub(&p.x.double());
                        let y3 = lambda.mul(&p.x.sub(&x3)).sub(&p.y);
                        buckets.slots[bucket] = Some(Affine::new(x3, y3));
                        in_round[bucket] = false;
                    }
                }
            }
        }
        std::mem::swap(&mut pending, &mut deferred);
    }
    buckets.into_jacobian()
}

/// Montgomery-trick batch inversion (3 muls per element + 1 inversion).
/// All inputs must be nonzero (guaranteed by lane construction).
fn batch_invert<F: Field>(xs: &[F]) -> Vec<F> {
    if xs.is_empty() {
        return Vec::new();
    }
    let mut prefix = Vec::with_capacity(xs.len());
    let mut acc = F::one();
    for x in xs {
        prefix.push(acc);
        acc = acc.mul(x);
    }
    let mut inv = acc.inv().expect("nonzero denominators");
    let mut out = vec![F::zero(); xs.len()];
    for i in (0..xs.len()).rev() {
        out[i] = inv.mul(&prefix[i]);
        inv = inv.mul(&xs[i]);
    }
    out
}

/// The (bucket, signed point) op stream for one window, read from the
/// pre-recoded digit matrix: negative digits contribute the negated
/// point (free: y ↦ −y), per the shared plan's bucket contract.
fn window_ops<'a, C: CurveParams>(
    matrix: &'a DigitMatrix,
    points: &'a [Affine<C>],
    j: u32,
) -> impl Iterator<Item = (usize, Affine<C>)> + 'a {
    points.iter().enumerate().filter_map(move |(i, p)| {
        if p.infinity {
            return None;
        }
        matrix.bucket_op(i, j)
            .map(|(b, negate)| (b, if negate { p.neg() } else { *p }))
    })
}

/// Pippenger MSM with batch-affine bucket accumulation.
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let plan = MsmPlan::for_curve::<C>(cfg);
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = DigitMatrix::build(&plan, input.scalars());
    let per_window: Vec<Jacobian<C>> = (0..plan.windows)
        .map(|j| {
            let buckets =
                fill_batch_affine(plan.bucket_slots(), window_ops(&matrix, points, j));
            plan.reduce(&buckets)
        })
        .collect();
    plan.combine(&per_window)
}

/// Multi-threaded batch-affine MSM (window-parallel like
/// [`super::parallel`]).
pub fn msm_parallel<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len());
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let threads = threads.max(1);
    let plan = MsmPlan::for_curve::<C>(cfg);
    let windows = plan.windows;
    if threads == 1 || windows == 1 {
        return msm(points, scalars, cfg);
    }
    // One shared prepared view (GLV expansion when configured) and one
    // shared digit matrix for every window thread.
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = DigitMatrix::build_parallel(&plan, input.scalars(), threads);
    let mut window_results = vec![Jacobian::<C>::infinity(); windows as usize];
    std::thread::scope(|scope| {
        let per = windows.div_ceil(threads as u32) as usize;
        for (t, chunk) in window_results.chunks_mut(per).enumerate() {
            let first = t * per;
            let (plan, matrix) = (&plan, &matrix);
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let j = (first + i) as u32;
                    let buckets = fill_batch_affine(
                        plan.bucket_slots(),
                        window_ops(matrix, points, j),
                    );
                    *slot = plan.reduce(&buckets);
                }
            });
        }
    });
    plan.combine(&window_results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, scalar, Bls12381G1, Bn254G1};
    use crate::msm::naive;
    use crate::msm::plan::{Reduction, Slicing};
    use crate::msm::pippenger;

    #[test]
    fn batch_invert_matches_individual() {
        use crate::ff::FpBn254;
        let mut rng = crate::util::rng::Rng::new(77);
        let xs: Vec<FpBn254> = (0..17).map(|_| {
            loop {
                let x = FpBn254::random(&mut rng);
                if !x.is_zero() {
                    break x;
                }
            }
        }).collect();
        let invs = batch_invert(&xs);
        for (x, i) in xs.iter().zip(&invs) {
            assert_eq!(x.mul(i), FpBn254::one());
        }
        assert!(batch_invert::<FpBn254>(&[]).is_empty());
    }

    #[test]
    fn matches_naive_small() {
        let w = points::workload::<Bn254G1>(100, 881);
        let want = naive::msm(&w.points, &w.scalars);
        for k in [4u32, 8, 12] {
            for slicing in [Slicing::Unsigned, Slicing::Signed] {
                let cfg = MsmConfig {
                    window_bits: k,
                    reduction: Reduction::Recursive { k2: 4 },
                    slicing,
                    ..Default::default()
                };
                let got = msm(&w.points, &w.scalars, &cfg);
                assert!(got.eq_point(&want), "k={k} {slicing:?}");
            }
        }
    }

    #[test]
    fn matches_naive_bls() {
        let w = points::workload::<Bls12381G1>(64, 882);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default());
        assert!(got.eq_point(&want));
    }

    #[test]
    fn handles_duplicates_doubling_lanes() {
        // many identical points in the same bucket force Double lanes
        let g = crate::ec::Jacobian::<Bn254G1>::generator().to_affine();
        let pts = vec![g; 40];
        let scalars = vec![[5u64, 0, 0, 0]; 40]; // all in bucket 5
        let want = naive::msm(&pts, &scalars);
        let cfg = MsmConfig::new(4, Reduction::RunningSum);
        let got = msm(&pts, &scalars, &cfg);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn handles_cancellation_lanes() {
        // P and −P with the same scalar cancel inside a bucket
        let g = scalar::mul::<Bn254G1>(&crate::ec::Jacobian::generator(), &[9, 0, 0, 0])
            .to_affine();
        let pts = vec![g, g.neg(), g, g.neg(), g];
        let scalars = vec![[3u64, 0, 0, 0]; 5];
        let want = naive::msm(&pts, &scalars);
        let got = msm(&pts, &scalars, &MsmConfig::new(4, Reduction::RunningSum));
        assert!(got.eq_point(&want));
        // net = 1·(3·G)
        let check = scalar::mul::<Bn254G1>(&g.to_jacobian(), &[3, 0, 0, 0]);
        assert!(got.eq_point(&check));
    }

    #[test]
    fn parallel_matches_serial() {
        let w = points::workload::<Bn254G1>(256, 883);
        let want = msm(&w.points, &w.scalars, &MsmConfig::default());
        for t in [2usize, 4] {
            let got = msm_parallel(&w.points, &w.scalars, &MsmConfig::default(), t);
            assert!(got.eq_point(&want), "threads={t}");
        }
    }

    #[test]
    fn uses_fewer_modmuls_than_jacobian_fill() {
        // The win shows in the fill-dominated regime (m ≫ 2^k): ≈6M per
        // add incl. the amortized batch inversion vs 11M+4S mixed-
        // Jacobian. With m ≈ 2^k the bucket *reduction* dominates both
        // variants equally and the ratio drifts toward 1 — that crossover
        // is by design (measured in the hotpath bench).
        let w = points::workload::<Bn254G1>(8192, 884);
        let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 6 });
        let (_, jac_ops) =
            crate::ff::opcount::measure(|| pippenger::msm(&w.points, &w.scalars, &cfg));
        let (_, aff_ops) = crate::ff::opcount::measure(|| msm(&w.points, &w.scalars, &cfg));
        assert!(
            (aff_ops.modmuls() as f64) < 0.8 * jac_ops.modmuls() as f64,
            "batch-affine {} vs jacobian {} modmuls",
            aff_ops.modmuls(),
            jac_ops.modmuls()
        );
    }
}
