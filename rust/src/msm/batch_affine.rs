//! Batch-affine bucket accumulation — the §Perf/L3 optimization.
//!
//! The bucket-fill phase is mixed Jacobian+affine addition (7M+4S each).
//! Keeping the buckets **affine** and batching one add per bucket per round
//! lets all the slope inversions share a single Montgomery-trick batch
//! inversion: amortized cost ≈ 6M per add (λ = Δy/Δx via shared inversion,
//! then 1S+2M to finish) instead of 11M — the same trick production MSM
//! libraries (gnark, arkworks, bellman) use, and a faithful software
//! echo of the paper's BAM conflict rule: one in-flight op per bucket per
//! round, conflicts replay next round.
//!
//! Edge lanes (doubling: same x same y; cancellation: same x opposite y;
//! first touch: empty bucket) are resolved in the same round without
//! inversions.

use super::plan::{DigitMatrix, MsmConfig, MsmPlan};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::ff::lanes::LANES;
use crate::ff::Field;
use std::fmt;

/// One window's buckets, affine with explicit emptiness.
struct AffineBuckets<C: CurveParams> {
    slots: Vec<Option<Affine<C>>>,
}

impl<C: CurveParams> AffineBuckets<C> {
    fn new(n: usize) -> Self {
        AffineBuckets { slots: (0..n).map(|_| None).collect() }
    }

    /// Fold all buckets into Jacobian form for the reduction phase.
    fn into_jacobian(self) -> Vec<Jacobian<C>> {
        self.slots
            .into_iter()
            .map(|s| s.map(|a| a.to_jacobian()).unwrap_or_else(Jacobian::infinity))
            .collect()
    }
}

/// Below this many lanes a round's shared Fermat inversion (≈380 modmuls)
/// costs more than it saves — finish such tails on the Jacobian path.
/// (Degenerate example: the top scalar window has only a couple of bits ⇒
/// 3 buckets ⇒ thousands of single-lane rounds without this fallback.)
const MIN_BATCH: usize = 48;

/// Fill a bucket array with batch-affine adds, returning Jacobian
/// buckets ready for reduction. Bucket indices are opaque: the window
/// backends pass one window's slots, the chunk-parallel backend
/// (`super::chunked`) a fused `windows × slots` space so one round's
/// batch inversion serves every window at once.
///
/// `ops` yields (bucket, point). Rounds: at most one op per bucket; all
/// inversions in a round share one batch inversion. Once a round falls
/// under [`MIN_BATCH`] lanes, the remaining (conflict-tail) ops finish as
/// ordinary mixed-Jacobian adds.
pub(super) fn fill_batch_affine<C: CurveParams>(
    nbuckets: usize,
    ops: impl Iterator<Item = (usize, Affine<C>)>,
) -> Vec<Jacobian<C>> {
    let mut buckets = AffineBuckets::<C>::new(nbuckets);
    let mut pending: Vec<(usize, Affine<C>)> = ops.collect();
    let mut deferred: Vec<(usize, Affine<C>)> = Vec::new();
    let mut in_round = vec![false; nbuckets];

    while !pending.is_empty() {
        // (bucket, accumulated, incoming): needs λ = (y2−y1)/(x2−x1)
        let mut adds: Vec<(usize, Affine<C>, Affine<C>)> = Vec::new();
        // (bucket, accumulated): needs λ = 3x²/(2y)
        let mut doubles: Vec<(usize, Affine<C>)> = Vec::new();
        for (b, p) in pending.drain(..) {
            if in_round[b] {
                deferred.push((b, p)); // BAM conflict FIFO
                continue;
            }
            match buckets.slots[b] {
                None => {
                    // first touch: free
                    buckets.slots[b] = Some(p);
                }
                Some(q) => {
                    if q.x == p.x {
                        if q.y != p.y {
                            // cancellation: bucket empties, no arithmetic
                            buckets.slots[b] = None;
                        } else if p.y.is_zero() {
                            // 2-torsion: 2P = ∞ — resolved here so the
                            // doubling denominator 2y is never zero
                            buckets.slots[b] = None;
                        } else {
                            in_round[b] = true;
                            doubles.push((b, p));
                        }
                    } else {
                        in_round[b] = true;
                        adds.push((b, q, p));
                    }
                }
            }
        }

        let nlanes = adds.len() + doubles.len();
        if nlanes > 0 && nlanes < MIN_BATCH {
            // Tail regime: finish everything on the Jacobian path.
            let mut jac = buckets.into_jacobian();
            for (bucket, _, q) in adds {
                // `q` is the incoming point; the accumulated value is
                // already inside jac[bucket].
                jac[bucket] = jac[bucket].add_mixed(&q);
            }
            for (bucket, _) in doubles {
                jac[bucket] = jac[bucket].double();
            }
            for (b, p) in deferred.drain(..).chain(pending.drain(..)) {
                jac[b] = jac[b].add_mixed(&p);
            }
            return jac;
        }

        if nlanes > 0 {
            // Batch inversion over every lane's denominator — adds first,
            // then doublings, so the 4-wide apply groups stay contiguous.
            let invs = loop {
                let denoms: Vec<C::Base> = adds
                    .iter()
                    .map(|(_, p, q)| q.x.sub(&p.x))
                    .chain(doubles.iter().map(|(_, p)| p.y.double()))
                    .collect();
                match batch_invert(&denoms) {
                    Ok(v) => break v,
                    Err(e) => {
                        // Defense in depth: lane construction filters every
                        // zero denominator (x2 ≠ x1 for adds, y ≠ 0 for
                        // doublings), but if one slips through, resolve
                        // that single op on the Jacobian path and retry
                        // the rest instead of aborting the whole MSM.
                        let (b, jac) = if e.index < adds.len() {
                            let (b, p, q) = adds.swap_remove(e.index);
                            (b, p.to_jacobian().add_mixed(&q))
                        } else {
                            let (b, p) = doubles.swap_remove(e.index - adds.len());
                            (b, p.to_jacobian().double())
                        };
                        buckets.slots[b] =
                            if jac.is_infinity() { None } else { Some(jac.to_affine()) };
                        in_round[b] = false;
                    }
                }
            };
            let (add_invs, dbl_invs) = invs.split_at(adds.len());
            apply_adds(&mut buckets, &mut in_round, &adds, add_invs);
            apply_doubles(&mut buckets, &mut in_round, &doubles, dbl_invs);
        }
        std::mem::swap(&mut pending, &mut deferred);
    }
    buckets.into_jacobian()
}

/// Apply the batched-affine addition λ/x3/y3 arithmetic 4 lanes at a time
/// through the [`Field::mul4`]-family hooks (the limb-interleaved core
/// for prime base fields, scalar loops for Fp²), with a scalar tail.
/// Op-for-op identical to the scalar formulas — results and op counts
/// match exactly.
fn apply_adds<C: CurveParams>(
    buckets: &mut AffineBuckets<C>,
    in_round: &mut [bool],
    adds: &[(usize, Affine<C>, Affine<C>)],
    invs: &[C::Base],
) {
    let mut i = 0;
    while i + LANES <= adds.len() {
        let px: [C::Base; LANES] = std::array::from_fn(|l| adds[i + l].1.x);
        let py: [C::Base; LANES] = std::array::from_fn(|l| adds[i + l].1.y);
        let qx: [C::Base; LANES] = std::array::from_fn(|l| adds[i + l].2.x);
        let qy: [C::Base; LANES] = std::array::from_fn(|l| adds[i + l].2.y);
        let dinv: &[C::Base; LANES] = invs[i..i + LANES].try_into().expect("lane group");
        let lambda = Field::mul4(&Field::sub4(&qy, &py), dinv);
        let x3 = Field::sub4(&Field::sub4(&Field::square4(&lambda), &px), &qx);
        let y3 = Field::sub4(&Field::mul4(&lambda, &Field::sub4(&px, &x3)), &py);
        for l in 0..LANES {
            let bucket = adds[i + l].0;
            buckets.slots[bucket] = Some(Affine::new(x3[l], y3[l]));
            in_round[bucket] = false;
        }
        i += LANES;
    }
    for ((bucket, p, q), dinv) in adds[i..].iter().zip(&invs[i..]) {
        let lambda = q.y.sub(&p.y).mul(dinv);
        let x3 = lambda.square().sub(&p.x).sub(&q.x);
        let y3 = lambda.mul(&p.x.sub(&x3)).sub(&p.y);
        buckets.slots[*bucket] = Some(Affine::new(x3, y3));
        in_round[*bucket] = false;
    }
}

/// Batched-affine doubling, 4 lanes at a time (see [`apply_adds`]).
fn apply_doubles<C: CurveParams>(
    buckets: &mut AffineBuckets<C>,
    in_round: &mut [bool],
    doubles: &[(usize, Affine<C>)],
    invs: &[C::Base],
) {
    let mut i = 0;
    while i + LANES <= doubles.len() {
        let px: [C::Base; LANES] = std::array::from_fn(|l| doubles[i + l].1.x);
        let py: [C::Base; LANES] = std::array::from_fn(|l| doubles[i + l].1.y);
        let dinv: &[C::Base; LANES] = invs[i..i + LANES].try_into().expect("lane group");
        // λ = 3x² / 2y (a = 0)
        let xx = Field::square4(&px);
        let lambda = Field::mul4(&Field::add4(&Field::double4(&xx), &xx), dinv);
        let x3 = Field::sub4(&Field::square4(&lambda), &Field::double4(&px));
        let y3 = Field::sub4(&Field::mul4(&lambda, &Field::sub4(&px, &x3)), &py);
        for l in 0..LANES {
            let bucket = doubles[i + l].0;
            buckets.slots[bucket] = Some(Affine::new(x3[l], y3[l]));
            in_round[bucket] = false;
        }
        i += LANES;
    }
    for ((bucket, p), dinv) in doubles[i..].iter().zip(&invs[i..]) {
        let xx = p.x.square();
        let lambda = xx.double().add(&xx).mul(dinv);
        let x3 = lambda.square().sub(&p.x.double());
        let y3 = lambda.mul(&p.x.sub(&x3)).sub(&p.y);
        buckets.slots[*bucket] = Some(Affine::new(x3, y3));
        in_round[*bucket] = false;
    }
}

/// Error from [`batch_invert`]: an input was zero, hence not invertible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZeroDenominator {
    /// Index of the first zero input.
    pub index: usize,
}

impl fmt::Display for ZeroDenominator {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "batch inversion input {} is zero", self.index)
    }
}

impl std::error::Error for ZeroDenominator {}

/// Montgomery-trick batch inversion (3 muls per element + 1 inversion).
///
/// Large batches run the prefix/suffix product chains **4 lanes wide**
/// through [`Field::mul4`]: four interleaved chains absorb the elements,
/// the 4 chain totals fold into a single Fermat inversion, and the
/// backward pass re-derives each chain's running inverse — a flat 9
/// extra muls next to the serial 3n, with bit-identical outputs (each
/// inverse is the unique canonical representative, independent of which
/// chain its element rode).
///
/// Returns `Err` carrying the index of the first zero input instead of
/// panicking, so callers can resolve the offending op out of band.
pub fn batch_invert<F: Field>(xs: &[F]) -> Result<Vec<F>, ZeroDenominator> {
    if xs.len() < 2 * LANES {
        return batch_invert_serial(xs);
    }
    let q = xs.len() - xs.len() % LANES;
    // forward: 4 interleaved product chains, one mul4 per group
    let mut prefix: Vec<F> = Vec::with_capacity(q);
    let mut acc = [F::one(); LANES];
    for group in xs[..q].chunks_exact(LANES) {
        prefix.extend_from_slice(&acc);
        let g: &[F; LANES] = group.try_into().expect("exact group");
        acc = F::mul4(&acc, g);
    }
    // fold the 4 chain totals, then chain the ragged tail on serially
    let mut lane_prod = acc;
    for l in 1..LANES {
        lane_prod[l] = lane_prod[l - 1].mul(&acc[l]);
    }
    let mut tail_prefix: Vec<F> = Vec::with_capacity(xs.len() - q);
    let mut total = lane_prod[LANES - 1];
    for x in &xs[q..] {
        tail_prefix.push(total);
        total = total.mul(x);
    }
    let Some(mut inv) = total.inv() else {
        let index = xs.iter().position(F::is_zero).unwrap_or(0);
        return Err(ZeroDenominator { index });
    };
    let mut out = vec![F::zero(); xs.len()];
    // scalar tail backward
    for i in (q..xs.len()).rev() {
        out[i] = inv.mul(&tail_prefix[i - q]);
        inv = inv.mul(&xs[i]);
    }
    // per-chain inverse seeds, peeled off the folded chain totals
    let mut seed = [F::zero(); LANES];
    for l in (1..LANES).rev() {
        seed[l] = inv.mul(&lane_prod[l - 1]);
        inv = inv.mul(&acc[l]);
    }
    seed[0] = inv;
    // lane backward: each group holds one element of every chain
    for (gi, group) in xs[..q].chunks_exact(LANES).enumerate().rev() {
        let g: &[F; LANES] = group.try_into().expect("exact group");
        let pf: &[F; LANES] =
            prefix[gi * LANES..(gi + 1) * LANES].try_into().expect("exact group");
        out[gi * LANES..(gi + 1) * LANES].copy_from_slice(&F::mul4(&seed, pf));
        seed = F::mul4(&seed, g);
    }
    Ok(out)
}

/// Scalar single-chain fallback for batches too small to amortize the
/// lane seed/fold overhead.
fn batch_invert_serial<F: Field>(xs: &[F]) -> Result<Vec<F>, ZeroDenominator> {
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    let mut prefix = Vec::with_capacity(xs.len());
    let mut acc = F::one();
    for x in xs {
        prefix.push(acc);
        acc = acc.mul(x);
    }
    let Some(mut inv) = acc.inv() else {
        let index = xs.iter().position(F::is_zero).unwrap_or(0);
        return Err(ZeroDenominator { index });
    };
    let mut out = vec![F::zero(); xs.len()];
    for i in (0..xs.len()).rev() {
        out[i] = inv.mul(&prefix[i]);
        inv = inv.mul(&xs[i]);
    }
    Ok(out)
}

/// The (bucket, signed point) op stream for one window, read from the
/// pre-recoded digit matrix: negative digits contribute the negated
/// point (free: y ↦ −y), per the shared plan's bucket contract.
fn window_ops<'a, C: CurveParams>(
    matrix: &'a DigitMatrix,
    points: &'a [Affine<C>],
    j: u32,
) -> impl Iterator<Item = (usize, Affine<C>)> + 'a {
    points.iter().enumerate().filter_map(move |(i, p)| {
        if p.infinity {
            return None;
        }
        matrix.bucket_op(i, j)
            .map(|(b, negate)| (b, if negate { p.neg() } else { *p }))
    })
}

/// Pippenger MSM with batch-affine bucket accumulation.
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let plan = MsmPlan::for_curve::<C>(cfg);
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = DigitMatrix::build(&plan, input.scalars());
    let per_window: Vec<Jacobian<C>> = (0..plan.windows)
        .map(|j| {
            let buckets =
                fill_batch_affine(plan.bucket_slots(), window_ops(&matrix, points, j));
            plan.reduce(&buckets)
        })
        .collect();
    plan.combine(&per_window)
}

/// Multi-threaded batch-affine MSM (window-parallel like
/// [`super::parallel`]).
pub fn msm_parallel<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
    threads: usize,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len());
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let threads = threads.max(1);
    let plan = MsmPlan::for_curve::<C>(cfg);
    let windows = plan.windows;
    if threads == 1 || windows == 1 {
        return msm(points, scalars, cfg);
    }
    // One shared prepared view (GLV expansion when configured) and one
    // shared digit matrix for every window thread.
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = DigitMatrix::build_parallel(&plan, input.scalars(), threads);
    let mut window_results = vec![Jacobian::<C>::infinity(); windows as usize];
    std::thread::scope(|scope| {
        let per = windows.div_ceil(threads as u32) as usize;
        for (t, chunk) in window_results.chunks_mut(per).enumerate() {
            let first = t * per;
            let (plan, matrix) = (&plan, &matrix);
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let j = (first + i) as u32;
                    let buckets = fill_batch_affine(
                        plan.bucket_slots(),
                        window_ops(matrix, points, j),
                    );
                    *slot = plan.reduce(&buckets);
                }
            });
        }
    });
    plan.combine(&window_results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, scalar, Bls12381G1, Bn254G1};
    use crate::msm::naive;
    use crate::msm::plan::{Reduction, Slicing};
    use crate::msm::pippenger;

    fn nonzero(rng: &mut crate::util::rng::Rng) -> crate::ff::FpBn254 {
        use crate::ff::FpBn254;
        loop {
            let x = FpBn254::random(rng);
            if !x.is_zero() {
                break x;
            }
        }
    }

    #[test]
    fn batch_invert_matches_individual() {
        use crate::ff::FpBn254;
        let mut rng = crate::util::rng::Rng::new(77);
        // lengths straddle the serial/lane threshold (2·LANES) and every
        // ragged-tail residue of the 4-wide interleaved chains
        for len in [1usize, 5, 7, 8, 9, 10, 11, 12, 17, 64] {
            let xs: Vec<FpBn254> = (0..len).map(|_| nonzero(&mut rng)).collect();
            let invs = batch_invert(&xs).unwrap();
            for (i, (x, v)) in xs.iter().zip(&invs).enumerate() {
                assert_eq!(x.mul(v), FpBn254::one(), "len={len} idx={i}");
            }
            // the lane-interleaved chains must also match the serial
            // reference bit-for-bit (canonical inverses)
            assert_eq!(invs, batch_invert_serial(&xs).unwrap(), "len={len}");
        }
        assert!(batch_invert::<FpBn254>(&[]).unwrap().is_empty());
    }

    #[test]
    fn batch_invert_reports_zero_index() {
        use crate::ff::FpBn254;
        let mut rng = crate::util::rng::Rng::new(78);
        // both the serial fallback (len < 8) and the lane path, with the
        // zero in the lane body, lane boundary, and ragged tail
        for len in [3usize, 8, 9, 21] {
            for at in [0usize, len / 2, len - 1] {
                let mut xs: Vec<FpBn254> = (0..len).map(|_| nonzero(&mut rng)).collect();
                xs[at] = FpBn254::zero();
                assert_eq!(
                    batch_invert(&xs),
                    Err(ZeroDenominator { index: at }),
                    "len={len} at={at}"
                );
            }
        }
    }

    #[test]
    fn two_torsion_doubling_collapses_without_panic() {
        use crate::ff::FpBn254;
        // A crafted y = 0 point: doubling it is the point at infinity, and
        // its batched denominator 2y would be zero. Lane construction must
        // filter it structurally (bucket empties, no lane) while enough
        // real doubling lanes keep the round on the batched path.
        let torsion = Affine::<Bn254G1>::new(FpBn254::from_u64(5), FpBn254::zero());
        let real = points::generate_points_walk::<Bn254G1>(MIN_BATCH + 8, 4242);
        let ops: Vec<(usize, Affine<Bn254G1>)> = std::iter::repeat((0usize, torsion))
            .take(2)
            .chain(real.iter().enumerate().flat_map(|(i, p)| [(i + 1, *p), (i + 1, *p)]))
            .collect();
        let out = fill_batch_affine(real.len() + 1, ops.into_iter());
        assert!(out[0].is_infinity(), "2-torsion double must collapse to infinity");
        for (i, p) in real.iter().enumerate() {
            assert!(out[i + 1].eq_point(&p.to_jacobian().double()), "bucket {}", i + 1);
        }
    }

    #[test]
    fn matches_naive_small() {
        let w = points::workload::<Bn254G1>(100, 881);
        let want = naive::msm(&w.points, &w.scalars);
        for k in [4u32, 8, 12] {
            for slicing in [Slicing::Unsigned, Slicing::Signed] {
                let cfg = MsmConfig {
                    window_bits: k,
                    reduction: Reduction::Recursive { k2: 4 },
                    slicing,
                    ..Default::default()
                };
                let got = msm(&w.points, &w.scalars, &cfg);
                assert!(got.eq_point(&want), "k={k} {slicing:?}");
            }
        }
    }

    #[test]
    fn matches_naive_bls() {
        let w = points::workload::<Bls12381G1>(64, 882);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default());
        assert!(got.eq_point(&want));
    }

    #[test]
    fn handles_duplicates_doubling_lanes() {
        // many identical points in the same bucket force Double lanes
        let g = crate::ec::Jacobian::<Bn254G1>::generator().to_affine();
        let pts = vec![g; 40];
        let scalars = vec![[5u64, 0, 0, 0]; 40]; // all in bucket 5
        let want = naive::msm(&pts, &scalars);
        let cfg = MsmConfig::new(4, Reduction::RunningSum);
        let got = msm(&pts, &scalars, &cfg);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn handles_cancellation_lanes() {
        // P and −P with the same scalar cancel inside a bucket
        let g = scalar::mul::<Bn254G1>(&crate::ec::Jacobian::generator(), &[9, 0, 0, 0])
            .to_affine();
        let pts = vec![g, g.neg(), g, g.neg(), g];
        let scalars = vec![[3u64, 0, 0, 0]; 5];
        let want = naive::msm(&pts, &scalars);
        let got = msm(&pts, &scalars, &MsmConfig::new(4, Reduction::RunningSum));
        assert!(got.eq_point(&want));
        // net = 1·(3·G)
        let check = scalar::mul::<Bn254G1>(&g.to_jacobian(), &[3, 0, 0, 0]);
        assert!(got.eq_point(&check));
    }

    #[test]
    fn parallel_matches_serial() {
        let w = points::workload::<Bn254G1>(256, 883);
        let want = msm(&w.points, &w.scalars, &MsmConfig::default());
        for t in [2usize, 4] {
            let got = msm_parallel(&w.points, &w.scalars, &MsmConfig::default(), t);
            assert!(got.eq_point(&want), "threads={t}");
        }
    }

    #[test]
    fn uses_fewer_modmuls_than_jacobian_fill() {
        // The win shows in the fill-dominated regime (m ≫ 2^k): ≈6M per
        // add incl. the amortized batch inversion vs 11M+4S mixed-
        // Jacobian. With m ≈ 2^k the bucket *reduction* dominates both
        // variants equally and the ratio drifts toward 1 — that crossover
        // is by design (measured in the hotpath bench).
        let w = points::workload::<Bn254G1>(8192, 884);
        let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 6 });
        let (_, jac_ops) =
            crate::ff::opcount::measure(|| pippenger::msm(&w.points, &w.scalars, &cfg));
        let (_, aff_ops) = crate::ff::opcount::measure(|| msm(&w.points, &w.scalars, &cfg));
        assert!(
            (aff_ops.modmuls() as f64) < 0.8 * jac_ops.modmuls() as f64,
            "batch-affine {} vs jacobian {} modmuls",
            aff_ops.modmuls(),
            jac_ops.modmuls()
        );
    }
}
