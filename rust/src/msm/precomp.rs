//! Fixed-base precomputed-table MSM: trade DDR/host memory for the
//! per-window doubling chain (ROADMAP open item 3 — the SRS point-cache
//! fast path).
//!
//! The prover's MSM bases are the *same SRS points* on every proof, so a
//! deployment serving many proofs can precompute, once per base set, the
//! shifted multiples `2^(j·k)·B` for every window `j` — and then run
//! every subsequent MSM without a single point doubling outside the
//! planned bucket reduction:
//!
//! * **fill** reads the window-`j` table column instead of shifting the
//!   live point, and feeds the shared batch-affine accumulator
//!   ([`super::batch_affine`]) exactly like the other backends — same
//!   bucket indexing, same conflict rule, same batched inversions;
//! * **combine** collapses from the DNA Horner chain (`k` doublings per
//!   window) to a plain (windows − 1)-add sum, because the `2^(j·k)`
//!   window weight is already baked into each table entry.
//!
//! Tables compose with the whole plan stack. Under
//! [`Decomposition::Glv`] the basis is the *endo-expanded* pair set
//! `(Pᵢ, φ(Pᵢ))` — built with [`endo::endo_affine`], which is
//! scalar-independent, unlike `endo::expand`, which folds per-scalar
//! split signs into the points — and each scalar's split signs are folded
//! into the table reads at fill time instead
//! (`negate = digit_sign XOR split_sign`; negation is free on
//! Weierstrass points). Signed-digit slicing needs nothing extra: buckets
//! are indexed by digit magnitude exactly as everywhere else.
//!
//! Layout is **window-major**: `entries[j·expanded_m + e] = 2^(j·k)·B_e`,
//! so one window's fill streams one contiguous column. The footprint is
//! exactly `base_bytes × windows` (`expansion_factor × m × windows`
//! affine points) — the same number `coordinator::pointcache::
//! table_resident_bytes` books against device DDR and the FPGA what-if
//! (`fpga::sab`) charges for resident tables.
//!
//! Determinism: the table path runs the same [`DigitMatrix`] recode, the
//! same bucket fills, the same planned reduction, and a combine that adds
//! the same window results in the same order — so results are
//! bit-identical (`eq_point`) to every live-point backend for any config.
//! Evicting tables mid-run therefore falls back to any other backend
//! without changing a single proof byte.

use super::plan::{Decomposition, DigitMatrix, MsmConfig, MsmPlan};
use crate::ec::counters::{self, PointOps};
use crate::ec::{endo, Affine, CurveParams, Jacobian, ScalarLimbs};

/// Per-phase measured cost of one table-fed MSM — the instrumentation the
/// perf pins assert the structural claims on, phase by phase (the
/// whole-MSM counter view cannot: IS-RBAM's sub-window Horner pass issues
/// doublings inside *reduce*, which must not be confused with the
/// fill/combine chains the tables eliminate).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrecompCost {
    /// Bucket ops issued by the fill phase — one table read per nonzero
    /// digit (the same accounting as `pippenger::MsmCost::fill_ops`).
    pub issued: u64,
    /// Point ops the fill phase executed. Batch-affine lanes run in the
    /// field layer, so only the Jacobian conflict-tail is visible here —
    /// and **zero doublings** outside duplicate-point tails.
    pub fill: PointOps,
    /// Point ops of the planned bucket reduction (running sum or
    /// IS-RBAM — the latter's sub-window doublings land here).
    pub reduce: PointOps,
    /// Point ops of the plain-add combine: windows − 1 additions, **zero
    /// doublings** (the Horner shift chain is pre-paid in the table).
    pub combine: PointOps,
}

impl PrecompCost {
    /// Total measured point ops across all three phases.
    pub fn total_point_ops(&self) -> u64 {
        self.fill.total() + self.reduce.total() + self.combine.total()
    }
}

fn accum(into: &mut PointOps, ops: PointOps) {
    into.add += ops.add;
    into.double += ops.double;
    into.mixed += ops.mixed;
}

/// A fixed-base table: per-window shifted multiples of one point set
/// under one [`MsmConfig`], ready to feed [`Self::msm`] /
/// [`Self::msm_range`] any number of times.
pub struct PrecompTable<C: CurveParams> {
    /// The resolved plan the table was sized for (GLV configs degrade to
    /// full-width here exactly as in [`MsmPlan::for_curve`]).
    plan: MsmPlan,
    /// The config the table was built under (the compatibility key).
    cfg: MsmConfig,
    /// Caller-visible base points (pre-expansion).
    base_m: usize,
    /// Basis length after decomposition expansion (2·m under GLV).
    expanded_m: usize,
    /// Window-major multiples: `entries[j·expanded_m + e] = 2^(j·k)·B_e`.
    entries: Vec<Affine<C>>,
}

impl<C: CurveParams> PrecompTable<C> {
    /// Precompute the table for `points` under `cfg`. One-time cost:
    /// `expanded_m · (windows − 1) · window_bits` point doublings (each
    /// column is the previous one shifted by `double_n(k)`) plus one
    /// batch-affine normalization per column — amortized away after a
    /// handful of MSMs over the same set.
    pub fn build(points: &[Affine<C>], cfg: &MsmConfig) -> PrecompTable<C> {
        let plan = MsmPlan::for_curve::<C>(cfg);
        let basis: Vec<Affine<C>> = match plan.decomposition {
            Decomposition::Full => points.to_vec(),
            Decomposition::Glv => {
                let p = C::glv().expect("for_curve keeps Glv only when endo params exist");
                let mut b = Vec::with_capacity(2 * points.len());
                for pt in points {
                    b.push(*pt);
                    b.push(endo::endo_affine(p, pt));
                }
                b
            }
        };
        let expanded_m = basis.len();
        let windows = plan.windows as usize;
        let mut entries = Vec::with_capacity(windows.saturating_mul(expanded_m));
        let mut column: Vec<Jacobian<C>> = basis.iter().map(Affine::to_jacobian).collect();
        entries.extend_from_slice(&basis);
        for _ in 1..windows {
            for p in column.iter_mut() {
                *p = p.double_n(plan.window_bits);
            }
            entries.extend(Jacobian::batch_to_affine(&column));
        }
        PrecompTable { plan, cfg: *cfg, base_m: points.len(), expanded_m, entries }
    }

    /// The resolved plan the table executes under.
    pub fn plan(&self) -> &MsmPlan {
        &self.plan
    }

    /// Window count = table columns.
    pub fn windows(&self) -> u32 {
        self.plan.windows
    }

    /// Number of caller-visible base points the table covers.
    pub fn base_len(&self) -> usize {
        self.base_m
    }

    /// Basis length after decomposition expansion (2·base under GLV).
    pub fn expanded_len(&self) -> usize {
        self.expanded_m
    }

    /// True when the table covers no points.
    pub fn is_empty(&self) -> bool {
        self.base_m == 0
    }

    /// Exact table footprint: `expanded_m × windows` affine points — the
    /// number DDR residency accounting books (`base_bytes × expansion ×
    /// windows`, see `coordinator::pointcache::table_resident_bytes`).
    pub fn bytes(&self) -> u64 {
        (self.entries.len() as u64).saturating_mul(C::AFFINE_BYTES)
    }

    /// Whether this table can serve MSMs under `cfg`: the window width,
    /// slicing, reduction, and decomposition must all match the build
    /// config (a mismatched plan would read the wrong columns or reduce
    /// differently — callers get `None` from the registry and fall back
    /// to a live-point backend instead).
    pub fn compatible_with(&self, cfg: &MsmConfig) -> bool {
        self.cfg.window_bits == cfg.window_bits
            && self.cfg.slicing == cfg.slicing
            && self.cfg.reduction == cfg.reduction
            && self.cfg.decomposition == cfg.decomposition
    }

    /// Table-fed MSM over a sub-range of the base set: `scalars[i]`
    /// multiplies base point `offset + i`. Prefix slices of an SRS vector
    /// (the prover's `a_query[..nv]` pattern) use `offset = 0`; the
    /// L-query slice starts mid-vector. Panics if the range leaves the
    /// table.
    pub fn msm_range(&self, offset: usize, scalars: &[ScalarLimbs]) -> Jacobian<C> {
        self.msm_range_with_cost(offset, scalars).0
    }

    /// Table-fed MSM over the whole base set prefix of length
    /// `scalars.len()`.
    pub fn msm(&self, scalars: &[ScalarLimbs]) -> Jacobian<C> {
        self.msm_range(0, scalars)
    }

    /// [`Self::msm`] with the per-phase instrumented cost.
    pub fn msm_with_cost(&self, scalars: &[ScalarLimbs]) -> (Jacobian<C>, PrecompCost) {
        self.msm_range_with_cost(0, scalars)
    }

    /// [`Self::msm_range`] with the per-phase instrumented cost (see
    /// [`PrecompCost`] for what lands where).
    pub fn msm_range_with_cost(
        &self,
        offset: usize,
        scalars: &[ScalarLimbs],
    ) -> (Jacobian<C>, PrecompCost) {
        assert!(
            offset.checked_add(scalars.len()).is_some_and(|end| end <= self.base_m),
            "table range out of bounds: {offset}+{} > {}",
            scalars.len(),
            self.base_m
        );
        let mut cost = PrecompCost::default();
        if scalars.is_empty() {
            return (Jacobian::infinity(), cost);
        }
        let (magnitudes, signs) = self.split_scalars(scalars);
        let matrix = DigitMatrix::build(&self.plan, &magnitudes);
        let row0 = offset * self.plan.decomposition.expansion_factor() as usize;
        let mut window_results = Vec::with_capacity(self.plan.windows as usize);
        for j in 0..self.plan.windows {
            cost.issued += matrix.nonzero_in_window(j);
            let column = &self.entries[j as usize * self.expanded_m..][..self.expanded_m];
            let (buckets, fill) = counters::measure(|| {
                super::batch_affine::fill_batch_affine(
                    self.plan.bucket_slots(),
                    (0..matrix.rows()).filter_map(|i| {
                        matrix.bucket_op(i, j).and_then(|(b, digit_neg)| {
                            let e = &column[row0 + i];
                            if e.infinity {
                                return None;
                            }
                            Some((b, if digit_neg != signs[i] { e.neg() } else { *e }))
                        })
                    }),
                )
            });
            let (wj, reduce) = counters::measure(|| self.plan.reduce(&buckets));
            accum(&mut cost.fill, fill);
            accum(&mut cost.reduce, reduce);
            window_results.push(wj);
        }
        // Combine: window weights are baked into the tables, so the Horner
        // shift chain disappears — windows − 1 plain additions.
        let (result, combine) = counters::measure(|| {
            let mut acc = Jacobian::<C>::infinity();
            for wj in &window_results {
                acc = acc.add(wj);
            }
            acc
        });
        cost.combine = combine;
        (result, cost)
    }

    /// Resolve scalars to the digit-matrix input: their GLV split
    /// magnitudes plus the per-row split signs (folded into the table
    /// reads at fill time), or the scalars as-is under a full-width plan.
    fn split_scalars(&self, scalars: &[ScalarLimbs]) -> (Vec<ScalarLimbs>, Vec<bool>) {
        match self.plan.decomposition {
            Decomposition::Full => (scalars.to_vec(), vec![false; scalars.len()]),
            Decomposition::Glv => {
                let p = C::glv().expect("GLV table requires endo params");
                let mut mags = Vec::with_capacity(2 * scalars.len());
                let mut signs = Vec::with_capacity(2 * scalars.len());
                for s in scalars {
                    let split = p.decompose(s);
                    mags.push(split.k1);
                    signs.push(split.k1_neg);
                    mags.push(split.k2);
                    signs.push(split.k2_neg);
                }
                (mags, signs)
            }
        }
    }
}

/// One-shot table-fed MSM: build the table inline, then run — the
/// [`super::Backend::Precomputed`] dispatch arm. Correct for any input,
/// but the build pays the full doubling chain; callers that reuse a base
/// set should build once ([`PrecompTable::build`]) or register the set
/// with `coordinator::devices::PointSetRegistry` and amortize.
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    PrecompTable::build(points, cfg).msm(scalars)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::{self, Backend, Reduction, Slicing};

    #[test]
    fn table_msm_matches_pippenger_full_and_glv() {
        let w = points::workload::<Bn254G1>(120, 611);
        for cfg in [
            MsmConfig::new(8, Reduction::RunningSum),
            MsmConfig::new(8, Reduction::Recursive { k2: 3 }),
            MsmConfig::new(10, Reduction::Recursive { k2: 4 }).glv(),
            MsmConfig::unsigned(7, Reduction::RunningSum),
        ] {
            let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
            let table = PrecompTable::build(&w.points, &cfg);
            let got = table.msm(&w.scalars);
            assert!(got.eq_point(&want), "{cfg:?}");
        }
    }

    #[test]
    fn table_msm_matches_on_bls() {
        let w = points::workload::<Bls12381G1>(48, 612);
        for cfg in [MsmConfig::default(), MsmConfig::default().glv()] {
            let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
            let got = PrecompTable::build(&w.points, &cfg).msm(&w.scalars);
            assert!(got.eq_point(&want), "{cfg:?}");
        }
    }

    #[test]
    fn range_offsets_match_the_sub_msm() {
        let w = points::workload::<Bn254G1>(40, 613);
        for cfg in [MsmConfig::new(6, Reduction::RunningSum), MsmConfig::default().glv()] {
            let table = PrecompTable::build(&w.points, &cfg);
            for (lo, hi) in [(0usize, 40usize), (7, 29), (39, 40), (12, 12)] {
                let want = msm::naive::msm(&w.points[lo..hi], &w.scalars[lo..hi]);
                let got = table.msm_range(lo, &w.scalars[lo..hi]);
                assert!(got.eq_point(&want), "{cfg:?} range {lo}..{hi}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "table range out of bounds")]
    fn range_past_the_table_panics() {
        let w = points::workload::<Bn254G1>(8, 614);
        let table = PrecompTable::build(&w.points, &MsmConfig::new(4, Reduction::RunningSum));
        table.msm_range(4, &w.scalars[0..5]);
    }

    #[test]
    fn footprint_is_base_times_windows() {
        let w = points::workload::<Bn254G1>(20, 615);
        let cfg = MsmConfig::new(9, Reduction::RunningSum);
        let table = PrecompTable::build(&w.points, &cfg);
        assert_eq!(table.base_len(), 20);
        assert_eq!(table.expanded_len(), 20);
        let expect = 20 * table.windows() as u64 * Bn254G1::AFFINE_BYTES;
        assert_eq!(table.bytes(), expect);
        // GLV doubles the basis and halves the windows — the product is
        // what the DDR accounting books
        let glv = PrecompTable::build(&w.points, &cfg.glv());
        assert_eq!(glv.expanded_len(), 40);
        assert_eq!(glv.bytes(), 40 * glv.windows() as u64 * Bn254G1::AFFINE_BYTES);
    }

    #[test]
    fn compatibility_requires_the_exact_plan_knobs() {
        let w = points::workload::<Bn254G1>(10, 616);
        let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 3 });
        let table = PrecompTable::build(&w.points, &cfg);
        assert!(table.compatible_with(&cfg));
        assert!(!table.compatible_with(&MsmConfig::new(9, Reduction::Recursive { k2: 3 })));
        assert!(!table.compatible_with(&MsmConfig::new(8, Reduction::RunningSum)));
        assert!(!table.compatible_with(&cfg.glv()));
        assert!(!table.compatible_with(&MsmConfig {
            slicing: Slicing::Unsigned,
            ..cfg
        }));
    }

    #[test]
    fn fill_and_combine_issue_zero_doublings() {
        let w = points::workload::<Bn254G1>(300, 617);
        for cfg in
            [MsmConfig::new(8, Reduction::Recursive { k2: 4 }), MsmConfig::default().glv()]
        {
            let table = PrecompTable::build(&w.points, &cfg);
            let (got, cost) = table.msm_with_cost(&w.scalars);
            let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
            assert!(got.eq_point(&want));
            // the structural claim: the tables pre-pay every shift chain
            assert_eq!(cost.fill.double, 0, "{cfg:?} fill doubles");
            assert_eq!(cost.combine.double, 0, "{cfg:?} combine doubles");
            assert_eq!(
                cost.combine.total(),
                table.windows() as u64 - 1,
                "{cfg:?} combine is a plain add chain"
            );
            assert!(cost.issued > 0);
        }
    }

    #[test]
    fn empty_scalars_yield_infinity() {
        let w = points::workload::<Bn254G1>(6, 618);
        let table = PrecompTable::build(&w.points, &MsmConfig::new(4, Reduction::RunningSum));
        assert!(table.msm(&[]).is_infinity());
        assert!(!table.is_empty());
        let none = PrecompTable::<Bn254G1>::build(&[], &MsmConfig::new(4, Reduction::RunningSum));
        assert!(none.is_empty());
        assert!(none.msm(&[]).is_infinity());
    }

    #[test]
    fn build_cost_is_the_column_shift_chain() {
        let w = points::workload::<Bn254G1>(16, 619);
        let cfg = MsmConfig::new(8, Reduction::RunningSum);
        let (table, ops) = counters::measure(|| PrecompTable::build(&w.points, &cfg));
        // one double_n(k) per basis point per column past the first; the
        // batch normalization is field-only
        let expect = table.expanded_len() as u64
            * u64::from(table.windows() - 1)
            * u64::from(table.plan().window_bits);
        assert_eq!(ops.double, expect);
        assert_eq!(ops.add + ops.mixed, 0);
    }
}
