//! Signed-digit scalar decomposition (the SZKP/CycloneMSM bucket-halving
//! trick, applied on top of the paper's §II-F window slicing).
//!
//! A k-bit unsigned slice d ∈ [0, 2^k) indexes one of 2^k − 1 live buckets.
//! Re-coding the slices with carry propagation,
//!
//! ```text
//!   v = slice + carry_in;   if v ≥ 2^(k−1) { d = v − 2^k; carry_out = 1 }
//!                           else           { d = v;       carry_out = 0 }
//! ```
//!
//! yields digits d ∈ [−2^(k−1), 2^(k−1)−1] with Σ dⱼ·2^(k·j) equal to the
//! original scalar. When the top window's slice is wide enough to carry
//! (≥ k−1 live bits), one extra window absorbs the final carry (its digit
//! is 0 or 1); for narrower top slices — including both paper curves at
//! the hardware k = 12 — no extra window is needed at all. Because
//! negating a Weierstrass point is free
//! (y ↦ −y), a negative digit becomes an add of −P into bucket |d| — so
//! only 2^(k−1) live buckets are needed: **half the bucket memory and half
//! the serial running-sum chain** the reduction phase walks. The MSM plan
//! ([`super::plan`]) threads these digits through every backend and into
//! the FPGA model's bucket counts.
//!
//! Requires k ≥ 2 (with k = 1 the digit set {−1, 0} cannot absorb a carry).

use crate::ec::scalar::slice_bits;
use crate::ec::ScalarLimbs;

/// Windows needed to cover an N-bit scalar with signed k-bit digits: the
/// unsigned count, plus one carry-absorbing top window **only when the
/// top slice can actually carry**. The top window holds
/// `r = N − (windows−1)·k` live bits, so its value v ≤ (2^r − 1) + 1;
/// a carry out (v ≥ 2^(k−1)) is possible iff r ≥ k − 1. Both paper
/// curves at the hardware k = 12 (254: r = 2; 381: r = 9) never carry —
/// signed mode there costs no extra window or stream pass.
pub fn signed_window_count(scalar_bits: u32, k: u32) -> u32 {
    let base = crate::ec::scalar::window_count(scalar_bits, k);
    let top_bits = scalar_bits - (base - 1) * k;
    base + (top_bits >= k - 1) as u32
}

/// The signed digit of `scalar` at window `j` (k-bit windows, k ∈ [2, 16]).
///
/// Recomputes the carry chain from window 0 — O(j) slice reads, which is
/// noise next to the ≥1 point operation each nonzero digit triggers. Use
/// [`signed_digits`] when all windows of one scalar are needed at once.
pub fn signed_digit(scalar: &ScalarLimbs, j: u32, k: u32) -> i64 {
    debug_assert!((2..=16).contains(&k), "signed slicing needs 2 <= k <= 16");
    let half = 1u64 << (k - 1);
    let mut carry = 0u64;
    for t in 0..j {
        let v = slice_bits(scalar, t * k, k) + carry;
        carry = (v >= half) as u64;
    }
    let v = slice_bits(scalar, j * k, k) + carry;
    if v >= half {
        v as i64 - (1i64 << k)
    } else {
        v as i64
    }
}

/// All signed digits of one scalar written into `out` (length = window
/// count), LSB window first, in a single carry pass — the recode core the
/// one-pass `DigitMatrix` builds rows with. Digits fit `i32` for every
/// supported window (|d| ≤ 2^15 at k = 16).
pub fn signed_digits_into(scalar: &ScalarLimbs, k: u32, out: &mut [i32]) {
    debug_assert!((2..=16).contains(&k), "signed slicing needs 2 <= k <= 16");
    let half = 1u64 << (k - 1);
    let mut carry = 0u64;
    for (j, slot) in out.iter_mut().enumerate() {
        let v = slice_bits(scalar, j as u32 * k, k) + carry;
        if v >= half {
            *slot = v as i32 - (1i32 << k);
            carry = 1;
        } else {
            *slot = v as i32;
            carry = 0;
        }
    }
    debug_assert_eq!(carry, 0, "carry must be absorbed by the top window");
}

/// All signed digits of one scalar, LSB window first, in a single carry
/// pass. `windows` should be [`signed_window_count`] of the scalar width.
pub fn signed_digits(scalar: &ScalarLimbs, k: u32, windows: u32) -> Vec<i64> {
    let mut buf = vec![0i32; windows as usize];
    signed_digits_into(scalar, k, &mut buf);
    buf.into_iter().map(i64::from).collect()
}

/// Exact inverse of the decomposition: Σ dⱼ·2^(k·j) computed in 320-bit
/// integer arithmetic (positive and negative magnitudes accumulated
/// separately, then subtracted). Returns `None` if the sum is negative or
/// overflows 320 bits — both impossible for digits produced by
/// [`signed_digits`], so the round-trip tests treat `None` as failure.
/// The low 4 limbs of the result must equal the original scalar and the
/// 5th must be zero.
pub fn reconstruct(digits: &[i64], k: u32) -> Option<[u64; 5]> {
    let mut pos = [0u64; 5];
    let mut neg = [0u64; 5];
    for (j, &d) in digits.iter().enumerate() {
        let acc = if d >= 0 { &mut pos } else { &mut neg };
        let shift = j as u32 * k;
        let limb = (shift / 64) as usize;
        let off = shift % 64;
        let wide = (d.unsigned_abs() as u128) << off;
        let mut carry = 0u128;
        for (t, part) in [wide as u64, (wide >> 64) as u64].iter().enumerate() {
            if limb + t >= 5 {
                if *part != 0 {
                    return None; // contribution past 320 bits
                }
                continue;
            }
            let sum = acc[limb + t] as u128 + *part as u128 + carry;
            acc[limb + t] = sum as u64;
            carry = sum >> 64;
        }
        let mut i = limb + 2;
        while carry > 0 {
            if i >= 5 {
                return None;
            }
            let sum = acc[i] as u128 + carry;
            acc[i] = sum as u64;
            carry = sum >> 64;
            i += 1;
        }
    }
    let mut out = [0u64; 5];
    let mut borrow = 0i128;
    for i in 0..5 {
        let d = pos[i] as i128 - neg[i] as i128 - borrow;
        if d < 0 {
            out[i] = (d + (1i128 << 64)) as u64;
            borrow = 1;
        } else {
            out[i] = d as u64;
            borrow = 0;
        }
    }
    if borrow != 0 {
        return None; // negative sum
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Exact check: Σ dⱼ·2^(k·j) == scalar, via [`reconstruct`].
    fn assert_roundtrip(scalar: &ScalarLimbs, k: u32, bits: u32) {
        let windows = signed_window_count(bits, k);
        let digits = signed_digits(scalar, k, windows);
        let half = 1i64 << (k - 1);
        for &d in &digits {
            assert!((-half..half).contains(&d), "digit {d} out of range (k={k})");
        }
        let got = reconstruct(&digits, k).expect("non-negative, in-range sum");
        assert_eq!(&got[..4], &scalar[..], "k={k}");
        assert_eq!(got[4], 0, "k={k}");
    }

    #[test]
    fn reconstruct_rejects_bad_digit_vectors() {
        // net-negative sum
        assert_eq!(reconstruct(&[-1], 4), None);
        // overflow past 320 bits: a digit at window 21 of k=16 lands at
        // bit 336
        let mut digits = vec![0i64; 22];
        digits[21] = 1;
        assert_eq!(reconstruct(&digits, 16), None);
        // plain positive value survives
        assert_eq!(reconstruct(&[5, 1], 4), Some([21, 0, 0, 0, 0]));
    }

    #[test]
    fn roundtrip_small_known_values() {
        for k in 2u32..=8 {
            for v in [0u64, 1, 2, 7, 8, 255, 256, 1000, u32::MAX as u64] {
                assert_roundtrip(&[v, 0, 0, 0], k, 64);
            }
        }
    }

    #[test]
    fn roundtrip_full_width_random() {
        let mut rng = Rng::new(0x519D);
        for k in [2u32, 3, 4, 7, 12, 13, 16] {
            for _ in 0..20 {
                let s = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 1];
                assert_roundtrip(&s, k, 255);
            }
        }
    }

    #[test]
    fn roundtrip_adversarial_patterns() {
        // all-ones (maximal carry chains), alternating, single high bit
        let patterns: [ScalarLimbs; 4] = [
            [u64::MAX, u64::MAX, u64::MAX, u64::MAX >> 2],
            [0xAAAA_AAAA_AAAA_AAAA; 4],
            [0, 0, 0, 1 << 61],
            [1, 0, 0, u64::MAX >> 3],
        ];
        for s in &patterns {
            for k in [2u32, 5, 12, 16] {
                assert_roundtrip(s, k, 254.max(256 - s[3].leading_zeros()));
            }
        }
    }

    #[test]
    fn digit_matches_digits_vector() {
        let mut rng = Rng::new(0xD161);
        for _ in 0..10 {
            let s = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 2];
            for k in [2u32, 6, 12] {
                let windows = signed_window_count(254, k);
                let all = signed_digits(&s, k, windows);
                for j in 0..windows {
                    assert_eq!(signed_digit(&s, j, k), all[j as usize], "j={j} k={k}");
                }
            }
        }
    }

    #[test]
    fn max_magnitude_is_half_window() {
        // the digit that triggers the carry: slice exactly 2^(k−1)
        let k = 8u32;
        let s: ScalarLimbs = [0x80, 0, 0, 0];
        let d = signed_digits(&s, k, signed_window_count(16, k));
        assert_eq!(d[0], -128);
        assert_eq!(d[1], 1);
        assert_eq!(d[2], 0);
    }
}
