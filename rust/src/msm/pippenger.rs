//! The Bucket Algorithm (Pippenger) — Algorithm 2 of the paper — executed
//! through the shared [`MsmPlan`] kernel layer.
//!
//! The scalar is sliced into windows of k bits (§II-F; the plan decides
//! unsigned or signed digits). Per window:
//!
//! 1. **Fill** (the BAM's job): `bucket[|d|] += ±Pᵢ` — one mixed add per
//!    point with a nonzero digit; fully pipelineable, II=1 in hardware.
//! 2. **Reduce**: combine buckets into `MSM_j = Σ_b b·bucket[b]` with the
//!    planned strategy ([`Reduction::RunningSum`] — Algorithm 2's serial
//!    loop — or [`Reduction::Recursive`], the paper's IS-RBAM).
//! 3. **Combine** (the DNA unit): Horner over windows.
//!
//! This file owns the instrumented variant ([`msm_with_cost`]) that feeds
//! Tables II/III and the FPGA model's op accounting; the slicing/bucket
//! logic itself lives in [`super::plan`] and [`super::signed`], shared with
//! every other backend.

use super::plan::MsmPlan;
use crate::ec::{counters, Affine, CurveParams, Jacobian, ScalarLimbs};

// Compatibility re-exports: the config/strategy types live in the plan
// layer, the slicing primitives at the field-ops layer.
pub use super::plan::{reduce_recursive, reduce_running_sum, MsmConfig, Reduction, Slicing};
pub use crate::ec::scalar::{slice_bits, window_count};

/// Full Pippenger MSM through the shared plan.
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let plan = MsmPlan::for_curve::<C>(cfg);
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    // one-pass recode: the fill loops below never re-slice a scalar
    let matrix = super::plan::DigitMatrix::build(&plan, input.scalars());
    let per_window: Vec<Jacobian<C>> = (0..plan.windows)
        .map(|j| plan.reduce(&plan.fill_window_from(&matrix, points, j)))
        .collect();
    plan.combine(&per_window)
}

/// Measured cost breakdown of one MSM configuration (drives Tables II/III
/// and the FPGA timing model's op feed).
#[derive(Clone, Copy, Debug, Default)]
pub struct MsmCost {
    /// Point ops spent filling buckets (BAM phase, pipeline friendly).
    pub fill_ops: u64,
    /// Point ops spent reducing buckets (serial-chain heavy).
    pub reduce_ops: u64,
    /// Point ops spent in the window combine (DNA phase).
    pub combine_ops: u64,
    /// Total modular multiplications measured in the field layer.
    pub modmuls: u64,
}

impl MsmCost {
    /// All point operations across the three phases.
    pub fn total_point_ops(&self) -> u64 {
        self.fill_ops + self.reduce_ops + self.combine_ops
    }
}

/// Run an MSM while measuring the per-phase point-op split.
pub fn msm_with_cost<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> (Jacobian<C>, MsmCost) {
    assert_eq!(points.len(), scalars.len());
    let plan = MsmPlan::for_curve::<C>(cfg);
    let input = plan.prepare::<C>(points, scalars);
    let points = input.points();
    let matrix = super::plan::DigitMatrix::build(&plan, input.scalars());
    let mm0 = crate::ff::opcount::snapshot();

    let mut cost = MsmCost::default();
    let mut result = Jacobian::<C>::infinity();
    for j in (0..plan.windows).rev() {
        let (r2, combine) = counters::measure(|| result.double_n(plan.window_bits));
        let buckets = plan.fill_window_from(&matrix, points, j);
        // Fill ops are counted as *issued* UDA operations (one per nonzero
        // digit), matching the hardware: a first touch of an empty bucket
        // still flows through the pipeline even though the software
        // shortcut skips the arithmetic.
        let issued: u64 = matrix.nonzero_in_window(j);
        let (wj, reduce) = counters::measure(|| plan.reduce(&buckets));
        let (r3, combine2) = counters::measure(|| r2.add(&wj));
        result = r3;
        cost.fill_ops += issued;
        cost.reduce_ops += reduce.total();
        cost.combine_ops += combine.total() + combine2.total();
    }
    cost.modmuls = (crate::ff::opcount::snapshot() - mm0).modmuls();
    (result, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::naive;

    #[test]
    fn matches_naive_small_all_modes() {
        let w = points::workload::<Bn254G1>(50, 71);
        let want = naive::msm(&w.points, &w.scalars);
        for k in [4u32, 8, 12] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 3 }] {
                for slicing in [Slicing::Unsigned, Slicing::Signed] {
                    let cfg =
                        MsmConfig { window_bits: k, reduction: red, slicing, ..Default::default() };
                    let got = msm(&w.points, &w.scalars, &cfg);
                    assert!(got.eq_point(&want), "k={k} red={red:?} {slicing:?}");
                }
            }
        }
    }

    #[test]
    fn matches_naive_bls() {
        let w = points::workload::<Bls12381G1>(40, 72);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default());
        assert!(got.eq_point(&want));
    }

    #[test]
    fn reduction_strategies_agree() {
        let w = points::workload::<Bn254G1>(200, 73);
        let a = msm(&w.points, &w.scalars, &MsmConfig::new(10, Reduction::RunningSum));
        for k2 in [1u32, 2, 5, 10] {
            let b = msm(
                &w.points,
                &w.scalars,
                &MsmConfig::new(10, Reduction::Recursive { k2 }),
            );
            assert!(a.eq_point(&b), "k2={k2}");
        }
    }

    #[test]
    fn recursive_reduction_standalone() {
        // buckets with known contents: Σ b·B[b] over a handful of filled slots
        let g = Jacobian::<Bn254G1>::generator();
        let k = 6u32;
        let mut buckets = vec![Jacobian::<Bn254G1>::infinity(); 1 << k];
        for (b, mult) in [(3usize, 5u64), (17, 2), (63, 1)] {
            buckets[b] = crate::ec::scalar::mul::<Bn254G1>(&g, &[mult, 0, 0, 0]);
        }
        let want = reduce_running_sum(&buckets);
        for k2 in 1..=k {
            let got = reduce_recursive(&buckets, k, k2);
            assert!(got.eq_point(&want), "k2={k2}");
        }
        // sanity: expected scalar = 3*5 + 17*2 + 63 = 112
        let check = crate::ec::scalar::mul::<Bn254G1>(&g, &[112, 0, 0, 0]);
        assert!(want.eq_point(&check));
    }

    #[test]
    fn recursive_reduction_on_signed_sized_buckets() {
        // a signed plan's bucket array (2^(k−1) + 1 slots) reduces with the
        // same functions: index_bits stays k
        let g = Jacobian::<Bn254G1>::generator();
        let k = 6u32;
        let slots = (1usize << (k - 1)) + 1;
        let mut buckets = vec![Jacobian::<Bn254G1>::infinity(); slots];
        for (b, mult) in [(1usize, 3u64), (19, 7), (32, 2)] {
            buckets[b] = crate::ec::scalar::mul::<Bn254G1>(&g, &[mult, 0, 0, 0]);
        }
        let want = reduce_running_sum(&buckets);
        for k2 in 1..=k {
            assert!(reduce_recursive(&buckets, k, k2).eq_point(&want), "k2={k2}");
        }
        // 1·3 + 19·7 + 32·2 = 200
        let check = crate::ec::scalar::mul::<Bn254G1>(&g, &[200, 0, 0, 0]);
        assert!(want.eq_point(&check));
    }

    #[test]
    fn zero_scalars_give_infinity() {
        let pts = points::generate_points_walk::<Bn254G1>(10, 74);
        let zeros = vec![[0u64; 4]; 10];
        assert!(msm(&pts, &zeros, &MsmConfig::default()).is_infinity());
    }

    #[test]
    fn cost_split_sums_to_total() {
        let w = points::workload::<Bn254G1>(64, 75);
        let cfg = MsmConfig::unsigned(8, Reduction::RunningSum);
        let (r, cost) = msm_with_cost(&w.points, &w.scalars, &cfg);
        let want = naive::msm(&w.points, &w.scalars);
        assert!(r.eq_point(&want));
        assert!(cost.fill_ops > 0 && cost.reduce_ops > 0 && cost.combine_ops > 0);
        assert!(cost.modmuls > cost.total_point_ops()); // each op ≥ several modmuls
    }

    #[test]
    fn cost_agrees_with_naive_in_signed_mode() {
        let w = points::workload::<Bn254G1>(64, 78);
        let cfg = MsmConfig::new(8, Reduction::Recursive { k2: 4 });
        assert_eq!(cfg.slicing, Slicing::Signed);
        let (r, cost) = msm_with_cost(&w.points, &w.scalars, &cfg);
        assert!(r.eq_point(&naive::msm(&w.points, &w.scalars)));
        assert!(cost.fill_ops > 0);
    }

    #[test]
    fn signed_halves_measured_reduce_chain_when_dense() {
        // With m ≫ buckets every bucket is occupied, so the measured
        // running-sum reduce ops land at the analytic chain length: the
        // signed plan's chain is half the unsigned one at equal k.
        let k = 6u32;
        let w = points::workload::<Bn254G1>(2048, 79);
        let (ru, cu) = msm_with_cost(
            &w.points,
            &w.scalars,
            &MsmConfig::unsigned(k, Reduction::RunningSum),
        );
        let (rs, cs) = msm_with_cost(
            &w.points,
            &w.scalars,
            &MsmConfig {
                window_bits: k,
                reduction: Reduction::RunningSum,
                slicing: Slicing::Signed,
                ..Default::default()
            },
        );
        assert!(ru.eq_point(&rs));
        // compare per-window reduce ops (window counts can differ when the
        // signed plan needs a carry window)
        let pu = MsmPlan::for_curve::<Bn254G1>(&MsmConfig::unsigned(k, Reduction::RunningSum));
        let ps = MsmPlan::for_curve::<Bn254G1>(&MsmConfig {
            window_bits: k,
            reduction: Reduction::RunningSum,
            slicing: Slicing::Signed,
            ..Default::default()
        });
        let per_u = cu.reduce_ops as f64 / pu.windows as f64;
        let per_s = cs.reduce_ops as f64 / ps.windows as f64;
        let ratio = per_u / per_s;
        assert!(
            ratio > 1.7 && ratio < 2.3,
            "per-window reduce ops: unsigned {per_u:.0} signed {per_s:.0} ratio {ratio:.2}"
        );
    }

    #[test]
    fn recursive_shrinks_serial_reduce_ops_fraction() {
        // Running-sum reduce ops per window are bounded by 2·live_buckets;
        // adds with an infinity operand short-circuit (not counted), so
        // with only 32 points most buckets are empty ⇒ counted ops ≪ bound.
        let w = points::workload::<Bn254G1>(32, 76);
        let cfg = MsmConfig::unsigned(8, Reduction::RunningSum);
        let (_, cost) = msm_with_cost(&w.points, &w.scalars, &cfg);
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        assert!(cost.reduce_ops <= plan.serial_reduce_ops());
    }
}
