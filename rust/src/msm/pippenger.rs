//! The Bucket Algorithm (Pippenger) — Algorithm 2 of the paper — plus the
//! paper's recursive bucket reduction (IS-RBAM).
//!
//! The scalar is sliced into ⌈N/k⌉ windows of k bits (§II-F). Per window:
//!
//! 1. **Fill** (the BAM's job): `bucket[slice] += Pᵢ` — one mixed add per
//!    point with a nonzero slice; fully pipelineable, II=1 in hardware.
//! 2. **Reduce**: combine buckets into `MSM_j = Σ_b b·bucket[b]`.
//!    * [`Reduction::RunningSum`] — Algorithm 2's second loop
//!      (`A += E; E += B[i-1]`): 2·(2^k − 1) *serially dependent* adds —
//!      each one stalls a 270-cycle hardware pipeline.
//!    * [`Reduction::Recursive`] — IS-RBAM: treat the bucket index b as a
//!      scalar and compute `Σ b·bucket[b]` as a second, much smaller bucket
//!      MSM with window k₂ | k. The fills are independent (pipeline
//!      friendly); only the tiny 2^k₂ running sums remain serial.
//! 3. **Combine** (the DNA unit): Horner over windows —
//!    `R = Σ_j 2^(k·j) MSM_j` via k doublings per window plus one add.

use crate::ec::{counters, Affine, CurveParams, Jacobian, ScalarLimbs};

/// Bucket-reduction strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    /// Classic serial running sum (Algorithm 2).
    RunningSum,
    /// The paper's IS-RBAM recursive bucket reduction with sub-window k₂.
    Recursive { k2: u32 },
}

impl Default for Reduction {
    fn default() -> Self {
        // k₂ = 6 halves the serial chain at negligible extra fills for the
        // k ∈ [10, 16] range the hardware uses.
        Reduction::Recursive { k2: 6 }
    }
}

/// MSM configuration.
#[derive(Clone, Copy, Debug)]
pub struct MsmConfig {
    /// Window (slice) width k in bits. The paper's hardware uses k = 12
    /// (Table III: ⌈254/12⌉ = 22 and ⌈381/12⌉ = 32 windows).
    pub window_bits: u32,
    pub reduction: Reduction,
}

impl Default for MsmConfig {
    fn default() -> Self {
        MsmConfig { window_bits: 12, reduction: Reduction::default() }
    }
}

/// Extract the k-bit slice of `scalar` starting at bit `lo`.
#[inline]
pub fn slice_bits(scalar: &ScalarLimbs, lo: u32, k: u32) -> u64 {
    debug_assert!(k <= 32);
    let limb = (lo / 64) as usize;
    let shift = lo % 64;
    if limb >= 4 {
        return 0;
    }
    let mut v = scalar[limb] >> shift;
    if shift + k > 64 && limb + 1 < 4 {
        v |= scalar[limb + 1] << (64 - shift);
    }
    v & ((1u64 << k) - 1)
}

/// Number of k-bit windows covering an N-bit scalar.
pub fn window_count(scalar_bits: u32, k: u32) -> u32 {
    scalar_bits.div_ceil(k)
}

/// One window's bucket fill: `buckets[slice − 1] += Pᵢ` (bucket 0 unused —
/// index shifted so bucket b holds coefficient b+1... here we keep the
/// natural indexing with a dummy slot 0 for clarity; slice 0 contributes
/// nothing).
fn fill_window<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    lo: u32,
    k: u32,
) -> Vec<Jacobian<C>> {
    let mut buckets = vec![Jacobian::<C>::infinity(); 1 << k];
    for (p, s) in points.iter().zip(scalars) {
        let b = slice_bits(s, lo, k) as usize;
        if b != 0 {
            buckets[b] = buckets[b].add_mixed(p);
        }
    }
    buckets
}

/// Algorithm 2's reconstruction loop: Σ b·B[b] via the running sum.
/// 2·(2^k − 1) point adds, all serially dependent.
pub fn reduce_running_sum<C: CurveParams>(buckets: &[Jacobian<C>]) -> Jacobian<C> {
    let mut acc = Jacobian::<C>::infinity(); // E: running suffix sum
    let mut sum = Jacobian::<C>::infinity(); // A: accumulated answer
    for b in buckets.iter().skip(1).rev() {
        acc = acc.add(b);
        sum = sum.add(&acc);
    }
    sum
}

/// IS-RBAM: Σ b·B[b] as a second-level bucket MSM over k₂-bit sub-slices
/// of the bucket index. Identical output; the serial chain shrinks from
/// 2·2^k to (k/k₂)·2·2^k₂ (plus k doublings), everything else is
/// independent fills.
pub fn reduce_recursive<C: CurveParams>(
    buckets: &[Jacobian<C>],
    k: u32,
    k2: u32,
) -> Jacobian<C> {
    assert!(k2 >= 1 && k2 <= k, "invalid sub-window");
    let sub_windows = k.div_ceil(k2);
    let mut l2: Vec<Vec<Jacobian<C>>> =
        vec![vec![Jacobian::<C>::infinity(); 1 << k2]; sub_windows as usize];
    for (b, point) in buckets.iter().enumerate().skip(1) {
        if point.is_infinity() {
            continue;
        }
        let mut idx = b as u64;
        for t in 0..sub_windows {
            let sub = (idx & ((1 << k2) - 1)) as usize;
            if sub != 0 {
                l2[t as usize][sub] = l2[t as usize][sub].add(point);
            }
            idx >>= k2;
        }
    }
    // Each sub-window reduces with the (short) running sum, then Horner.
    let mut result = Jacobian::<C>::infinity();
    for t in (0..sub_windows).rev() {
        for _ in 0..k2 {
            result = result.double();
        }
        let w = reduce_running_sum(&l2[t as usize]);
        result = result.add(&w);
    }
    result
}

/// Full Pippenger MSM.
pub fn msm<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Jacobian<C> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    if points.is_empty() {
        return Jacobian::infinity();
    }
    let k = cfg.window_bits;
    assert!((1..=16).contains(&k), "window bits out of range");
    let windows = window_count(C::SCALAR_BITS.min(256), k);

    // DNA combine (Horner), MSB window first.
    let mut result = Jacobian::<C>::infinity();
    for j in (0..windows).rev() {
        for _ in 0..k {
            result = result.double();
        }
        let buckets = fill_window(points, scalars, j * k, k);
        let wj = match cfg.reduction {
            Reduction::RunningSum => reduce_running_sum(&buckets),
            Reduction::Recursive { k2 } => reduce_recursive(&buckets, k, k2.min(k)),
        };
        result = result.add(&wj);
    }
    result
}

/// Measured cost breakdown of one MSM configuration (drives Tables II/III
/// and the FPGA timing model's op feed).
#[derive(Clone, Copy, Debug, Default)]
pub struct MsmCost {
    /// Point ops spent filling buckets (BAM phase, pipeline friendly).
    pub fill_ops: u64,
    /// Point ops spent reducing buckets (serial-chain heavy).
    pub reduce_ops: u64,
    /// Point ops spent in the window combine (DNA phase).
    pub combine_ops: u64,
    /// Total modular multiplications measured in the field layer.
    pub modmuls: u64,
}

impl MsmCost {
    pub fn total_point_ops(&self) -> u64 {
        self.fill_ops + self.reduce_ops + self.combine_ops
    }
}

/// Run an MSM while measuring the per-phase point-op split.
pub fn msm_with_cost<C: CurveParams>(
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> (Jacobian<C>, MsmCost) {
    assert_eq!(points.len(), scalars.len());
    let k = cfg.window_bits;
    let windows = window_count(C::SCALAR_BITS.min(256), k);
    let mm0 = crate::ff::opcount::snapshot();

    let mut cost = MsmCost::default();
    let mut result = Jacobian::<C>::infinity();
    for j in (0..windows).rev() {
        let (r2, combine) = counters::measure(|| {
            let mut r = result;
            for _ in 0..k {
                r = r.double();
            }
            r
        });
        let buckets = fill_window(points, scalars, j * k, k);
        // Fill ops are counted as *issued* UDA operations (one per nonzero
        // slice), matching the hardware: a first touch of an empty bucket
        // still flows through the pipeline even though the software
        // shortcut skips the arithmetic.
        let issued: u64 =
            scalars.iter().filter(|s| slice_bits(s, j * k, k) != 0).count() as u64;
        let (wj, reduce) = counters::measure(|| match cfg.reduction {
            Reduction::RunningSum => reduce_running_sum(&buckets),
            Reduction::Recursive { k2 } => reduce_recursive(&buckets, k, k2.min(k)),
        });
        let (r3, combine2) = counters::measure(|| r2.add(&wj));
        result = r3;
        cost.fill_ops += issued;
        cost.reduce_ops += reduce.total();
        cost.combine_ops += combine.total() + combine2.total();
    }
    cost.modmuls = (crate::ff::opcount::snapshot() - mm0).modmuls();
    (result, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};
    use crate::msm::naive;

    #[test]
    fn slice_bits_extracts_correctly() {
        let s: ScalarLimbs = [0xABCD_EF01_2345_6789, 0x1122_3344_5566_7788, 0, 0];
        assert_eq!(slice_bits(&s, 0, 8), 0x89);
        assert_eq!(slice_bits(&s, 4, 8), 0x78);
        // straddles the limb boundary: bits 60..72 = low 4 of limb1 (0x8) ++ top nibble of limb0 (0xA)
        assert_eq!(slice_bits(&s, 60, 12), 0x88A);
        assert_eq!(slice_bits(&s, 192, 16), 0);
    }

    #[test]
    fn window_count_matches_paper_table_iii() {
        // k=12: BN254 → 22 windows, BLS12-381 → 32 windows (Table III's
        // m×22 / m×32 point-op accounting).
        assert_eq!(window_count(254, 12), 22);
        assert_eq!(window_count(381, 12), 32);
    }

    #[test]
    fn matches_naive_small() {
        let w = points::workload::<Bn254G1>(50, 71);
        let want = naive::msm(&w.points, &w.scalars);
        for k in [4u32, 8, 12] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 3 }] {
                let got = msm(&w.points, &w.scalars, &MsmConfig { window_bits: k, reduction: red });
                assert!(got.eq_point(&want), "k={k} red={red:?}");
            }
        }
    }

    #[test]
    fn matches_naive_bls() {
        let w = points::workload::<Bls12381G1>(40, 72);
        let want = naive::msm(&w.points, &w.scalars);
        let got = msm(&w.points, &w.scalars, &MsmConfig::default());
        assert!(got.eq_point(&want));
    }

    #[test]
    fn reduction_strategies_agree() {
        let w = points::workload::<Bn254G1>(200, 73);
        let a = msm(
            &w.points,
            &w.scalars,
            &MsmConfig { window_bits: 10, reduction: Reduction::RunningSum },
        );
        for k2 in [1u32, 2, 5, 10] {
            let b = msm(
                &w.points,
                &w.scalars,
                &MsmConfig { window_bits: 10, reduction: Reduction::Recursive { k2 } },
            );
            assert!(a.eq_point(&b), "k2={k2}");
        }
    }

    #[test]
    fn recursive_reduction_standalone() {
        // buckets with known contents: Σ b·B[b] over a handful of filled slots
        let g = Jacobian::<Bn254G1>::generator();
        let k = 6u32;
        let mut buckets = vec![Jacobian::<Bn254G1>::infinity(); 1 << k];
        for (b, mult) in [(3usize, 5u64), (17, 2), (63, 1)] {
            buckets[b] = crate::ec::scalar::mul::<Bn254G1>(&g, &[mult, 0, 0, 0]);
        }
        let want = reduce_running_sum(&buckets);
        for k2 in 1..=k {
            let got = reduce_recursive(&buckets, k, k2);
            assert!(got.eq_point(&want), "k2={k2}");
        }
        // sanity: expected scalar = 3*5 + 17*2 + 63 = 112
        let check = crate::ec::scalar::mul::<Bn254G1>(&g, &[112, 0, 0, 0]);
        assert!(want.eq_point(&check));
    }

    #[test]
    fn zero_scalars_give_infinity() {
        let pts = points::generate_points_walk::<Bn254G1>(10, 74);
        let zeros = vec![[0u64; 4]; 10];
        assert!(msm(&pts, &zeros, &MsmConfig::default()).is_infinity());
    }

    #[test]
    fn cost_split_sums_to_total() {
        let w = points::workload::<Bn254G1>(64, 75);
        let cfg = MsmConfig { window_bits: 8, reduction: Reduction::RunningSum };
        let (r, cost) = msm_with_cost(&w.points, &w.scalars, &cfg);
        let want = naive::msm(&w.points, &w.scalars);
        assert!(r.eq_point(&want));
        assert!(cost.fill_ops > 0 && cost.reduce_ops > 0 && cost.combine_ops > 0);
        assert!(cost.modmuls > cost.total_point_ops()); // each op ≥ several modmuls
    }

    #[test]
    fn recursive_shrinks_serial_reduce_ops_fraction() {
        // IS-RBAM trades serial reduce adds for parallel fills; measured
        // reduce-phase ops should exceed running-sum? No: total ops shift.
        // What the hardware cares about: the serial-chain length, which the
        // FPGA model derives from the reduction kind. Here we simply check
        // both have the documented op counts: running sum ≈ 2·(2^k−1) per
        // window.
        let w = points::workload::<Bn254G1>(32, 76);
        let k = 8u32;
        let cfg = MsmConfig { window_bits: k, reduction: Reduction::RunningSum };
        let (_, cost) = msm_with_cost(&w.points, &w.scalars, &cfg);
        let windows = window_count(254, k) as u64;
        // Each window's running sum performs 2·(2^k −1) adds, but adds with
        // an infinity operand short-circuit (not counted). With only 32
        // points most buckets are empty ⇒ counted ops ≪ bound.
        assert!(cost.reduce_ops <= windows * 2 * ((1 << k) - 1));
    }
}
