//! The shared MSM kernel plan: one place that decides window slicing,
//! digit encoding (unsigned vs signed), bucket indexing, and the
//! bucket-reduction strategy — consumed by **every** backend
//! ([`super::pippenger`], [`super::parallel`], [`super::batch_affine`],
//! `runtime::msm_engine`) and by the FPGA timing model
//! (`fpga::sab`/`fpga::rbam`), so software and hardware model can never
//! disagree on bucket counts or window counts again.
//!
//! A plan answers, for a fixed curve + [`MsmConfig`]:
//!
//! * how many k-bit windows cover the scalar ([`MsmPlan::windows`] —
//!   signed mode adds a carry window only when the top slice can carry);
//! * how many bucket slots a window needs ([`MsmPlan::bucket_slots`],
//!   [`MsmPlan::live_buckets`] — **halved** by signed digits);
//! * which bucket a (scalar, window) pair touches and with which point
//!   sign ([`MsmPlan::bucket_op`]);
//! * how a filled window reduces ([`MsmPlan::reduce`]) and how window
//!   results combine ([`MsmPlan::combine`], the DNA Horner pass);
//! * the length of the serial reduce chain the hardware pays latency for
//!   ([`MsmPlan::serial_reduce_ops`] — the quantity IS-RBAM and signed
//!   digits each attack).
//!
//! Buckets use natural indexing: slot `b` holds the points whose digit has
//! magnitude `b`; slot 0 is a dummy (digit 0 contributes nothing).
//!
//! Digit extraction is a **one-pass recode**: [`DigitMatrix`] turns every
//! (point, window) digit into a flat row-major matrix up front, so the
//! fill loops never re-slice a scalar (and never re-walk the signed carry
//! chain) once per window. Every backend builds the matrix once per MSM.
//!
//! On top of the digit encoding sits the scalar **decomposition**
//! ([`Decomposition`]): the GLV fast path rewrites each full-width term
//! `k·P` as two half-width terms `k1·P + k2·φ(P)` using the curve's
//! cube-root endomorphism (`ec::endo`), halving the window passes against
//! a doubled point set. Backends stay decomposition-agnostic: they call
//! [`MsmPlan::prepare`] once and run their usual fill/reduce/combine over
//! whatever point/scalar view it returns.

use super::signed;
use crate::ec::{endo, scalar, Affine, CurveParams, Jacobian, ScalarLimbs};

/// Digit encoding for scalar slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Slicing {
    /// Classic Pippenger: digits in [0, 2^k), 2^k − 1 live buckets.
    Unsigned,
    /// Signed digits in [−2^(k−1), 2^(k−1)): negative digits add −P, so
    /// only 2^(k−1) live buckets — half the memory, half the running-sum
    /// chain. Needs k ≥ 2. The crate default: the default window (k = 12)
    /// is well past the k ≥ 4 threshold of [`Slicing::auto`].
    #[default]
    Signed,
}

impl Slicing {
    /// Default policy: signed for k ≥ 4 (at tiny windows the saved chain
    /// is a handful of adds while the extra carry window costs a full
    /// fill pass).
    pub fn auto(window_bits: u32) -> Slicing {
        if window_bits >= 4 {
            Slicing::Signed
        } else {
            Slicing::Unsigned
        }
    }
}

/// Scalar decomposition applied before window slicing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Decomposition {
    /// Scalars enter the window slicer at their full width (the paper's
    /// hardware pipeline).
    #[default]
    Full,
    /// GLV endomorphism split (`ec::endo`): `k ≡ k1 + k2·λ (mod r)` with
    /// half-width `k1`, `k2`, run against the doubled point set
    /// `(P, φ(P))`. Halves the window passes — and with them the serial
    /// reduce chain and the DNA combine — at unchanged total fill work.
    /// Curves without endomorphism parameters ([`CurveParams::glv`] is
    /// `None`) silently fall back to [`Decomposition::Full`].
    Glv,
}

impl Decomposition {
    /// How many entries the prepared point set holds per input point —
    /// the single source of the "GLV doubles the working set" rule that
    /// both DDR residency accounting (`coordinator::pointcache`) and the
    /// FPGA model's streamed/resident point counts (`fpga::sab`) consume.
    pub fn expansion_factor(&self) -> u64 {
        match self {
            Decomposition::Full => 1,
            Decomposition::Glv => 2,
        }
    }
}

/// Bucket-reduction strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    /// Classic serial running sum (Algorithm 2).
    RunningSum,
    /// The paper's IS-RBAM recursive bucket reduction with sub-window k₂.
    Recursive {
        /// Sub-window width of the second-level bucket MSM.
        k2: u32,
    },
}

impl Default for Reduction {
    fn default() -> Self {
        // k₂ = 6 halves the serial chain at negligible extra fills for the
        // k ∈ [10, 16] range the hardware uses.
        Reduction::Recursive { k2: 6 }
    }
}

/// MSM configuration (the user-facing knobs; [`MsmPlan`] derives the rest).
#[derive(Clone, Copy, Debug)]
pub struct MsmConfig {
    /// Window (slice) width k in bits. The paper's hardware uses k = 12
    /// (Table III: ⌈254/12⌉ = 22 and ⌈381/12⌉ = 32 windows).
    pub window_bits: u32,
    /// Bucket-reduction strategy (running sum vs the paper's IS-RBAM).
    pub reduction: Reduction,
    /// Digit encoding (unsigned vs signed buckets).
    pub slicing: Slicing,
    /// Scalar decomposition (full-width vs the GLV endomorphism split).
    pub decomposition: Decomposition,
}

impl Default for MsmConfig {
    fn default() -> Self {
        MsmConfig {
            window_bits: 12,
            reduction: Reduction::default(),
            slicing: Slicing::auto(12),
            decomposition: Decomposition::Full,
        }
    }
}

impl MsmConfig {
    /// Config with the default slicing policy for the window width.
    pub fn new(window_bits: u32, reduction: Reduction) -> MsmConfig {
        MsmConfig {
            window_bits,
            reduction,
            slicing: Slicing::auto(window_bits),
            decomposition: Decomposition::Full,
        }
    }

    /// Config pinned to unsigned (paper-faithful) buckets.
    pub fn unsigned(window_bits: u32, reduction: Reduction) -> MsmConfig {
        MsmConfig {
            window_bits,
            reduction,
            slicing: Slicing::Unsigned,
            decomposition: Decomposition::Full,
        }
    }

    /// Auto-tuned config for an m-point MSM (window via the c ≈ log2 m − 3
    /// rule clamped to the hardware point, default reduction + slicing).
    pub fn auto(m: usize) -> MsmConfig {
        MsmConfig::new(super::auto_window(m), Reduction::default())
    }

    /// The same config with the GLV endomorphism fast path switched on.
    pub fn glv(mut self) -> MsmConfig {
        self.decomposition = Decomposition::Glv;
        self
    }
}

/// A fully resolved execution plan for one MSM shape.
///
/// # Examples
///
/// ```
/// use ifzkp::msm::{MsmConfig, MsmPlan, Reduction};
///
/// // the paper's hardware point: unsigned 12-bit windows, 254-bit scalars
/// let plan = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::RunningSum));
/// assert_eq!(plan.windows, 22); // Table III: ceil(254 / 12)
/// assert_eq!(plan.live_buckets(), 4095); // 2^12 - 1
///
/// // signed digits halve the live buckets at the same window width
/// let signed = MsmPlan::new(254, &MsmConfig::new(12, Reduction::RunningSum));
/// assert_eq!(signed.live_buckets(), 2048); // 2^11
///
/// // the GLV split halves the window passes (half-width scalars)
/// let glv = MsmPlan::new(254, &MsmConfig::new(12, Reduction::RunningSum).glv());
/// assert!(glv.windows <= plan.windows / 2);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct MsmPlan {
    /// Window (slice) width k in bits.
    pub window_bits: u32,
    /// Digit encoding the windows use.
    pub slicing: Slicing,
    /// Bucket-reduction strategy.
    pub reduction: Reduction,
    /// Scalar bit width the windows must cover. Under [`Decomposition::Glv`]
    /// this is the *half*-width of the split scalars, not the curve width.
    pub scalar_bits: u32,
    /// Window count (signed mode adds a carry window only when the top
    /// slice is wide enough to carry — see `signed::signed_window_count`).
    pub windows: u32,
    /// The decomposition this plan is sized for. When `Glv`, backends must
    /// run over the expanded `(P, φ(P))` inputs from [`MsmPlan::prepare`].
    pub decomposition: Decomposition,
}

impl MsmPlan {
    /// Build a plan for `scalar_bits`-wide scalars under `cfg`. Without a
    /// curve in hand, a GLV config is sized at the generic half width
    /// (`⌈bits/2⌉ + 1` — the FPGA model's what-if view);
    /// [`MsmPlan::for_curve`] uses the exact per-curve lattice bound
    /// instead.
    pub fn new(scalar_bits: u32, cfg: &MsmConfig) -> MsmPlan {
        match cfg.decomposition {
            Decomposition::Full => MsmPlan::with_bits(scalar_bits, cfg, Decomposition::Full),
            Decomposition::Glv => {
                MsmPlan::with_bits(scalar_bits.div_ceil(2) + 1, cfg, Decomposition::Glv)
            }
        }
    }

    /// The shared constructor: windows cover `scalar_bits` under the
    /// config's slicing; the decomposition is recorded as given.
    fn with_bits(scalar_bits: u32, cfg: &MsmConfig, decomposition: Decomposition) -> MsmPlan {
        let k = cfg.window_bits;
        assert!((1..=16).contains(&k), "window bits out of range");
        if cfg.slicing == Slicing::Signed {
            assert!(k >= 2, "signed slicing needs k >= 2");
        }
        let windows = match cfg.slicing {
            Slicing::Unsigned => scalar::window_count(scalar_bits, k),
            Slicing::Signed => signed::signed_window_count(scalar_bits, k),
        };
        MsmPlan {
            window_bits: k,
            slicing: cfg.slicing,
            reduction: cfg.reduction,
            scalar_bits,
            windows,
            decomposition,
        }
    }

    /// Plan for a curve's scalars (the width every backend uses). A GLV
    /// config resolves against the curve's exact lattice bound
    /// (`GlvParams::half_bits`); curves without endomorphism parameters
    /// fall back to the full-width plan, so the config is always safe to
    /// pass for any curve.
    pub fn for_curve<C: CurveParams>(cfg: &MsmConfig) -> MsmPlan {
        let full_bits = C::SCALAR_BITS.min(256);
        match cfg.decomposition {
            Decomposition::Full => MsmPlan::with_bits(full_bits, cfg, Decomposition::Full),
            Decomposition::Glv => match C::glv() {
                Some(p) => MsmPlan::with_bits(p.half_bits, cfg, Decomposition::Glv),
                None => MsmPlan::with_bits(full_bits, cfg, Decomposition::Full),
            },
        }
    }

    /// Resolve the backend-facing input view for this plan: full-width
    /// plans borrow the caller's slices untouched; GLV plans expand every
    /// `(P, k)` into `(±P, |k1|), (±φ(P), |k2|)` (see `ec::endo::expand`).
    /// Every backend calls this exactly once, so all executors agree on
    /// the decomposition — which is what keeps shard merges bit-identical.
    ///
    /// Panics if the plan was sized for GLV but the curve carries no
    /// endomorphism parameters; [`MsmPlan::for_curve`] never produces that
    /// combination.
    pub fn prepare<'a, C: CurveParams>(
        &self,
        points: &'a [Affine<C>],
        scalars: &'a [ScalarLimbs],
    ) -> MsmInput<'a, C> {
        assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
        match self.decomposition {
            Decomposition::Full => MsmInput::Borrowed { points, scalars },
            Decomposition::Glv => {
                let p = C::glv().expect(
                    "GLV plan prepared for a curve without endomorphism parameters \
                     (build plans with MsmPlan::for_curve)",
                );
                let (points, scalars) = endo::expand(p, points, scalars);
                MsmInput::Expanded { points, scalars }
            }
        }
    }

    /// Bucket-array length per window, **including** the dummy slot 0.
    pub fn bucket_slots(&self) -> usize {
        match self.slicing {
            Slicing::Unsigned => 1usize << self.window_bits,
            Slicing::Signed => (1usize << (self.window_bits - 1)) + 1,
        }
    }

    /// Live (coefficient-carrying) buckets per window: 2^k − 1 unsigned,
    /// 2^(k−1) signed. This is what sizes hardware bucket memory and the
    /// running-sum serial chain.
    pub fn live_buckets(&self) -> u64 {
        self.bucket_slots() as u64 - 1
    }

    /// Digit of `scalar` at window `j`: [0, 2^k) unsigned,
    /// [−2^(k−1), 2^(k−1)) signed.
    #[inline]
    pub fn digit(&self, scalar: &ScalarLimbs, j: u32) -> i64 {
        match self.slicing {
            Slicing::Unsigned => {
                scalar::slice_bits(scalar, j * self.window_bits, self.window_bits) as i64
            }
            Slicing::Signed => signed::signed_digit(scalar, j, self.window_bits),
        }
    }

    /// All digits of one scalar, LSB window first (length [`Self::windows`]).
    pub fn digits(&self, scalar: &ScalarLimbs) -> Vec<i64> {
        let mut buf = vec![0i32; self.windows as usize];
        self.digits_into(scalar, &mut buf);
        buf.into_iter().map(i64::from).collect()
    }

    /// Write all digits of one scalar into `out` (length
    /// [`Self::windows`]) in a single pass — one carry sweep for signed
    /// slicing instead of the O(windows) re-walk [`Self::digit`] pays per
    /// window. This is the row recode of [`DigitMatrix`]; digits fit
    /// `i32` for every supported window width (|d| < 2^16).
    pub fn digits_into(&self, scalar: &ScalarLimbs, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.windows as usize, "row length != window count");
        match self.slicing {
            Slicing::Unsigned => {
                let k = self.window_bits;
                for (j, slot) in out.iter_mut().enumerate() {
                    *slot = scalar::slice_bits(scalar, j as u32 * k, k) as i32;
                }
            }
            Slicing::Signed => signed::signed_digits_into(scalar, self.window_bits, out),
        }
    }

    /// The bucket operation for (scalar, window): `None` when the digit is
    /// zero, else `(bucket_index, negate_point)`. The index is the digit's
    /// magnitude (natural indexing), never 0, and < [`Self::bucket_slots`].
    #[inline]
    pub fn bucket_op(&self, scalar: &ScalarLimbs, j: u32) -> Option<(usize, bool)> {
        let d = self.digit(scalar, j);
        match d.cmp(&0) {
            std::cmp::Ordering::Equal => None,
            std::cmp::Ordering::Greater => Some((d as usize, false)),
            std::cmp::Ordering::Less => Some((d.unsigned_abs() as usize, true)),
        }
    }

    /// Fill one window's Jacobian buckets (mixed adds, sign-aware). The
    /// shared fill loop of the serial and window-parallel backends; the
    /// batch-affine and engine backends drive [`Self::bucket_op`] through
    /// their own batched executors.
    pub fn fill_window<C: CurveParams>(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        j: u32,
    ) -> Vec<Jacobian<C>> {
        let mut buckets = vec![Jacobian::<C>::infinity(); self.bucket_slots()];
        for (p, s) in points.iter().zip(scalars) {
            if let Some((b, negate)) = self.bucket_op(s, j) {
                if negate {
                    buckets[b] = buckets[b].add_mixed(&p.neg());
                } else {
                    buckets[b] = buckets[b].add_mixed(p);
                }
            }
        }
        buckets
    }

    /// [`Self::fill_window`] reading pre-recoded digits from a
    /// [`DigitMatrix`] row instead of re-slicing every scalar — what the
    /// backends run after their single recode pass.
    pub fn fill_window_from<C: CurveParams>(
        &self,
        matrix: &DigitMatrix,
        points: &[Affine<C>],
        j: u32,
    ) -> Vec<Jacobian<C>> {
        let mut buckets = vec![Jacobian::<C>::infinity(); self.bucket_slots()];
        for (i, p) in points.iter().enumerate() {
            if let Some((b, negate)) = matrix.bucket_op(i, j) {
                if negate {
                    buckets[b] = buckets[b].add_mixed(&p.neg());
                } else {
                    buckets[b] = buckets[b].add_mixed(p);
                }
            }
        }
        buckets
    }

    /// Reduce one window's (natural-indexed) buckets to Σ b·B[b] with the
    /// planned strategy.
    pub fn reduce<C: CurveParams>(&self, buckets: &[Jacobian<C>]) -> Jacobian<C> {
        match self.reduction {
            Reduction::RunningSum => reduce_running_sum(buckets),
            Reduction::Recursive { k2 } => {
                reduce_recursive(buckets, self.window_bits, k2.clamp(1, self.window_bits))
            }
        }
    }

    /// DNA combine: Horner over window results (index j = window j, LSB
    /// first), k doublings per window (one `double_n` shift-chain call)
    /// plus one add.
    pub fn combine<C: CurveParams>(&self, window_results: &[Jacobian<C>]) -> Jacobian<C> {
        let mut result = Jacobian::<C>::infinity();
        for wj in window_results.iter().rev() {
            result = result.double_n(self.window_bits).add(wj);
        }
        result
    }

    /// Length of the *serially dependent* point-op chain in one window's
    /// reduction — each of these stalls a full pipeline latency in
    /// hardware. Running sum: 2·live_buckets (signed mode halves it);
    /// IS-RBAM: (k/k₂) short sums of 2^k₂ buckets plus k Horner doublings.
    pub fn serial_reduce_ops_per_window(&self) -> u64 {
        match self.reduction {
            Reduction::RunningSum => 2 * self.live_buckets(),
            Reduction::Recursive { k2 } => {
                let k2 = k2.clamp(1, self.window_bits);
                let sub = self.window_bits.div_ceil(k2) as u64;
                sub * 2 * ((1u64 << k2) - 1) + self.window_bits as u64
            }
        }
    }

    /// Serial reduce chain across all windows.
    pub fn serial_reduce_ops(&self) -> u64 {
        self.serial_reduce_ops_per_window() * self.windows as u64
    }
}

/// The input view a plan hands its backends (see [`MsmPlan::prepare`]):
/// either the caller's slices as-is, or the owned GLV-expanded point and
/// scalar vectors (2m entries, half-width magnitudes, signs folded into
/// the points).
pub enum MsmInput<'a, C: CurveParams> {
    /// Full-width plan: the caller's slices pass through untouched.
    Borrowed {
        /// The caller's points.
        points: &'a [Affine<C>],
        /// The caller's scalars.
        scalars: &'a [ScalarLimbs],
    },
    /// GLV plan: the expanded `(±P, |k1|), (±φ(P), |k2|)` pairs.
    Expanded {
        /// Expanded points, signs folded in.
        points: Vec<Affine<C>>,
        /// Half-width scalar magnitudes.
        scalars: Vec<ScalarLimbs>,
    },
}

impl<C: CurveParams> MsmInput<'_, C> {
    /// The points the backend should fill buckets from.
    pub fn points(&self) -> &[Affine<C>] {
        match self {
            MsmInput::Borrowed { points, .. } => points,
            MsmInput::Expanded { points, .. } => points,
        }
    }

    /// The scalars the backend should slice.
    pub fn scalars(&self) -> &[ScalarLimbs] {
        match self {
            MsmInput::Borrowed { scalars, .. } => scalars,
            MsmInput::Expanded { scalars, .. } => scalars,
        }
    }
}

/// The one-pass digit matrix: every (point, window) digit recoded up
/// front into a flat **row-major** array — row `i` holds all
/// [`MsmPlan::windows`] digits of scalar `i`, LSB window first.
///
/// One build pass replaces the per-window re-extraction the fill loops
/// used to pay: under signed slicing, [`MsmPlan::digit`] re-walks the
/// carry chain from window 0 on every call, so filling all windows
/// point-by-window cost O(windows²) slice reads per scalar; a row recode
/// is one carry sweep, O(windows). The row-major layout also makes the
/// matrix trivially chunkable by *points* — the chunk-parallel backend
/// (`super::chunked`) hands each thread a contiguous band of rows.
///
/// Memory: 4 bytes per (point, window) — `m × windows × i32` (GLV plans
/// double the rows but halve the windows, so the footprint is unchanged).
pub struct DigitMatrix {
    /// Row length (digits per scalar).
    windows: usize,
    /// Row-major digits: entry (i, j) at `i * windows + j`.
    digits: Vec<i32>,
}

impl DigitMatrix {
    /// Recode every scalar in one serial pass.
    pub fn build(plan: &MsmPlan, scalars: &[ScalarLimbs]) -> DigitMatrix {
        let windows = plan.windows as usize;
        let mut digits = vec![0i32; scalars.len() * windows];
        for (row, s) in digits.chunks_mut(windows).zip(scalars) {
            plan.digits_into(s, row);
        }
        DigitMatrix { windows, digits }
    }

    /// Recode with the rows split across `threads` scoped threads (the
    /// recode is integer-only, but at 2²⁰ points it is still worth
    /// spreading). Identical output to [`Self::build`].
    pub fn build_parallel(plan: &MsmPlan, scalars: &[ScalarLimbs], threads: usize) -> DigitMatrix {
        let threads = threads.clamp(1, scalars.len().max(1));
        if threads <= 1 {
            return DigitMatrix::build(plan, scalars);
        }
        let windows = plan.windows as usize;
        let mut digits = vec![0i32; scalars.len() * windows];
        let chunk = scalars.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for (rows, band) in digits.chunks_mut(chunk * windows).zip(scalars.chunks(chunk)) {
                scope.spawn(move || {
                    for (row, s) in rows.chunks_mut(windows).zip(band) {
                        plan.digits_into(s, row);
                    }
                });
            }
        });
        DigitMatrix { windows, digits }
    }

    /// Digits per row (= the plan's window count).
    pub fn windows(&self) -> u32 {
        self.windows as u32
    }

    /// Number of rows (scalars recoded).
    pub fn rows(&self) -> usize {
        if self.windows == 0 {
            0
        } else {
            self.digits.len() / self.windows
        }
    }

    /// All digits of scalar `i`, LSB window first.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.digits[i * self.windows..(i + 1) * self.windows]
    }

    /// The digit of scalar `i` at window `j`.
    #[inline]
    pub fn digit(&self, i: usize, j: u32) -> i32 {
        self.digits[i * self.windows + j as usize]
    }

    /// The bucket operation for (scalar `i`, window `j`) — same contract
    /// as [`MsmPlan::bucket_op`], read from the matrix.
    #[inline]
    pub fn bucket_op(&self, i: usize, j: u32) -> Option<(usize, bool)> {
        let d = self.digit(i, j);
        match d.cmp(&0) {
            std::cmp::Ordering::Equal => None,
            std::cmp::Ordering::Greater => Some((d as usize, false)),
            std::cmp::Ordering::Less => Some((d.unsigned_abs() as usize, true)),
        }
    }

    /// How many rows carry a nonzero digit in window `j` (the issued-op
    /// count of the instrumented cost path).
    pub fn nonzero_in_window(&self, j: u32) -> u64 {
        (0..self.rows()).filter(|&i| self.digit(i, j) != 0).count() as u64
    }
}

/// Algorithm 2's reconstruction loop: Σ b·B[b] via the running sum.
/// 2·(len − 1) point adds, all serially dependent.
pub fn reduce_running_sum<C: CurveParams>(buckets: &[Jacobian<C>]) -> Jacobian<C> {
    let mut acc = Jacobian::<C>::infinity(); // E: running suffix sum
    let mut sum = Jacobian::<C>::infinity(); // A: accumulated answer
    for b in buckets.iter().skip(1).rev() {
        acc = acc.add(b);
        sum = sum.add(&acc);
    }
    sum
}

/// IS-RBAM: Σ b·B[b] as a second-level bucket MSM over k₂-bit sub-slices
/// of the bucket index. `index_bits` is the bit width of the largest
/// bucket index (= k for both unsigned [max 2^k − 1] and signed
/// [max 2^(k−1)] plans). Identical output to the running sum; the serial
/// chain shrinks from 2·live to (k/k₂)·2·2^k₂ (plus k doublings) — the
/// rest is independent, pipeline-friendly fills.
pub fn reduce_recursive<C: CurveParams>(
    buckets: &[Jacobian<C>],
    index_bits: u32,
    k2: u32,
) -> Jacobian<C> {
    assert!(k2 >= 1 && k2 <= index_bits, "invalid sub-window");
    let sub_windows = index_bits.div_ceil(k2);
    let mut l2: Vec<Vec<Jacobian<C>>> =
        vec![vec![Jacobian::<C>::infinity(); 1 << k2]; sub_windows as usize];
    for (b, point) in buckets.iter().enumerate().skip(1) {
        if point.is_infinity() {
            continue;
        }
        let mut idx = b as u64;
        for t in 0..sub_windows {
            let sub = (idx & ((1 << k2) - 1)) as usize;
            if sub != 0 {
                l2[t as usize][sub] = l2[t as usize][sub].add(point);
            }
            idx >>= k2;
        }
    }
    // Each sub-window reduces with the (short) running sum, then Horner.
    let mut result = Jacobian::<C>::infinity();
    for t in (0..sub_windows).rev() {
        let w = reduce_running_sum(&l2[t as usize]);
        result = result.double_n(k2).add(&w);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};

    #[test]
    fn plan_window_counts() {
        let unsigned = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::RunningSum));
        assert_eq!(unsigned.windows, 22); // Table III
        // 254-bit scalars at k=12: the top window has only 2 live bits —
        // it can never carry, so signed mode needs no extra window
        let signed = MsmPlan::new(254, &MsmConfig::new(12, Reduction::RunningSum));
        assert_eq!(signed.slicing, Slicing::Signed);
        assert_eq!(signed.windows, 22);
        // a full-width top window (24 = 2·12 bits) can carry: +1
        let carrying = MsmPlan::new(24, &MsmConfig::new(12, Reduction::RunningSum));
        assert_eq!(carrying.windows, 3);
        assert_eq!(MsmPlan::new(24, &MsmConfig::unsigned(12, Reduction::RunningSum)).windows, 2);
    }

    #[test]
    fn signed_halves_buckets() {
        for k in [4u32, 8, 12, 16] {
            let u = MsmPlan::new(254, &MsmConfig::unsigned(k, Reduction::RunningSum));
            let s = MsmPlan::new(254, &MsmConfig::new(k, Reduction::RunningSum));
            assert_eq!(u.live_buckets(), (1 << k) - 1);
            assert_eq!(s.live_buckets(), 1 << (k - 1));
            assert_eq!(u.bucket_slots(), 1 << k);
            assert_eq!(s.bucket_slots(), (1 << (k - 1)) + 1);
            // the halving the reduce chain inherits: (2^k − 1)/2^(k−1),
            // i.e. 1.875 at k = 4 and → 2 as k grows
            let ratio = u.serial_reduce_ops_per_window() as f64
                / s.serial_reduce_ops_per_window() as f64;
            assert!(ratio > 1.8 && ratio <= 2.0, "k={k} ratio={ratio}");
        }
    }

    #[test]
    fn slicing_auto_threshold() {
        assert_eq!(Slicing::auto(2), Slicing::Unsigned);
        assert_eq!(Slicing::auto(3), Slicing::Unsigned);
        assert_eq!(Slicing::auto(4), Slicing::Signed);
        assert_eq!(Slicing::auto(12), Slicing::Signed);
        // the crate default is the paper window, so signed mode is on
        assert_eq!(MsmConfig::default().slicing, Slicing::Signed);
    }

    #[test]
    fn digits_match_digit_and_stay_in_range() {
        let w = points::workload::<Bn254G1>(6, 411);
        for cfg in [
            MsmConfig::unsigned(8, Reduction::RunningSum),
            MsmConfig::new(8, Reduction::RunningSum),
            MsmConfig::new(13, Reduction::RunningSum),
        ] {
            let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
            for s in &w.scalars {
                let all = plan.digits(s);
                assert_eq!(all.len(), plan.windows as usize);
                for (j, &d) in all.iter().enumerate() {
                    assert_eq!(plan.digit(s, j as u32), d);
                    assert!(d.unsigned_abs() <= plan.live_buckets(), "digit {d}");
                    match plan.bucket_op(s, j as u32) {
                        None => assert_eq!(d, 0),
                        Some((b, neg)) => {
                            assert_eq!(b as u64, d.unsigned_abs());
                            assert_eq!(neg, d < 0);
                            assert!(b < plan.bucket_slots());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fill_reduce_combine_matches_naive_both_modes() {
        let w = points::workload::<Bn254G1>(60, 412);
        let want = crate::msm::naive::msm(&w.points, &w.scalars);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 3 }] {
                let cfg =
                    MsmConfig { window_bits: 7, reduction: red, slicing, ..Default::default() };
                let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
                let per_window: Vec<_> = (0..plan.windows)
                    .map(|j| plan.reduce(&plan.fill_window(&w.points, &w.scalars, j)))
                    .collect();
                let got = plan.combine(&per_window);
                assert!(got.eq_point(&want), "{slicing:?} {red:?}");
            }
        }
    }

    #[test]
    fn bls_signed_matches_naive() {
        let w = points::workload::<Bls12381G1>(40, 413);
        let want = crate::msm::naive::msm(&w.points, &w.scalars);
        let plan = MsmPlan::for_curve::<Bls12381G1>(&MsmConfig::default());
        let per_window: Vec<_> = (0..plan.windows)
            .map(|j| plan.reduce(&plan.fill_window(&w.points, &w.scalars, j)))
            .collect();
        assert!(plan.combine(&per_window).eq_point(&want));
    }

    #[test]
    fn serial_ops_accounting() {
        // running sum, unsigned, k=12: 2·(2^12 − 1) per window × 22 windows
        let p = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::RunningSum));
        assert_eq!(p.serial_reduce_ops_per_window(), 2 * 4095);
        assert_eq!(p.serial_reduce_ops(), 2 * 4095 * 22);
        // recursive: (12/6) sub-sums of 2·63 plus 12 doublings
        let r = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 }));
        assert_eq!(r.serial_reduce_ops_per_window(), 2 * 2 * 63 + 12);
    }

    #[test]
    #[should_panic(expected = "window bits out of range")]
    fn rejects_zero_window() {
        MsmPlan::new(254, &MsmConfig::unsigned(0, Reduction::RunningSum));
    }

    #[test]
    fn digit_matrix_agrees_with_per_window_extraction() {
        let w = points::workload::<Bn254G1>(40, 418);
        for cfg in [
            MsmConfig::unsigned(9, Reduction::RunningSum),
            MsmConfig::new(9, Reduction::RunningSum),
            MsmConfig::new(13, Reduction::RunningSum),
            MsmConfig::new(12, Reduction::RunningSum).glv(),
        ] {
            let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
            let input = plan.prepare::<Bn254G1>(&w.points, &w.scalars);
            let scalars = input.scalars();
            let matrix = DigitMatrix::build(&plan, scalars);
            assert_eq!(matrix.windows(), plan.windows);
            assert_eq!(matrix.rows(), scalars.len());
            for (i, s) in scalars.iter().enumerate() {
                assert_eq!(matrix.row(i).len(), plan.windows as usize);
                for j in 0..plan.windows {
                    assert_eq!(i64::from(matrix.digit(i, j)), plan.digit(s, j), "i={i} j={j}");
                    assert_eq!(matrix.bucket_op(i, j), plan.bucket_op(s, j), "i={i} j={j}");
                }
            }
            // the threaded recode is bit-identical to the serial one
            for threads in [2usize, 3, 64] {
                let par = DigitMatrix::build_parallel(&plan, scalars, threads);
                assert_eq!(par.digits, matrix.digits, "threads={threads}");
            }
            // and the matrix-fed fill produces the same buckets
            for j in 0..plan.windows {
                let a = plan.fill_window(input.points(), scalars, j);
                let b = plan.fill_window_from(&matrix, input.points(), j);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert!(x.eq_point(y), "window {j}");
                }
            }
        }
    }

    #[test]
    fn digit_matrix_empty_input() {
        let plan = MsmPlan::for_curve::<Bn254G1>(&MsmConfig::default());
        let matrix = DigitMatrix::build(&plan, &[]);
        assert_eq!(matrix.rows(), 0);
        assert_eq!(matrix.nonzero_in_window(0), 0);
    }

    #[test]
    fn glv_plan_halves_window_passes() {
        let cfg = MsmConfig::new(12, Reduction::RunningSum);
        let full = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let glv = MsmPlan::for_curve::<Bn254G1>(&cfg.glv());
        assert_eq!(glv.decomposition, Decomposition::Glv);
        assert_eq!(full.windows, 22);
        // the exact lattice bound sits just above 128 bits → 11 windows
        assert!(glv.windows <= full.windows / 2, "{} vs {}", glv.windows, full.windows);
        assert!(glv.windows >= 9);
        // bucket memory is a per-window quantity — unchanged
        assert_eq!(glv.bucket_slots(), full.bucket_slots());
        // so the total serial reduce chain halves with the window count
        assert!(glv.serial_reduce_ops() <= full.serial_reduce_ops() / 2);
        // the curve-less (model) view agrees on the window count at k=12
        assert_eq!(MsmPlan::new(254, &cfg.glv()).windows, 11);
    }

    #[test]
    fn glv_prepare_expands_and_matches_naive() {
        let w = points::workload::<Bn254G1>(40, 415);
        let cfg = MsmConfig::new(10, Reduction::RunningSum).glv();
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let input = plan.prepare::<Bn254G1>(&w.points, &w.scalars);
        assert_eq!(input.points().len(), 80);
        assert_eq!(input.scalars().len(), 80);
        // every expanded magnitude fits the plan's half width
        for s in input.scalars() {
            let bits = crate::ff::bigint::msb(s).map_or(0, |b| b as u32 + 1);
            assert!(bits <= plan.scalar_bits, "magnitude {bits} > {}", plan.scalar_bits);
        }
        // fill/reduce/combine over the expanded set equals the plain MSM
        let per_window: Vec<_> = (0..plan.windows)
            .map(|j| plan.reduce(&plan.fill_window(input.points(), input.scalars(), j)))
            .collect();
        let got = plan.combine(&per_window);
        assert!(got.eq_point(&crate::msm::naive::msm(&w.points, &w.scalars)));
    }

    #[test]
    fn full_prepare_borrows_untouched() {
        let w = points::workload::<Bn254G1>(5, 416);
        let plan = MsmPlan::for_curve::<Bn254G1>(&MsmConfig::default());
        let input = plan.prepare::<Bn254G1>(&w.points, &w.scalars);
        assert_eq!(input.points().len(), 5);
        assert!(std::ptr::eq(input.points().as_ptr(), w.points.as_ptr()));
        assert!(std::ptr::eq(input.scalars().as_ptr(), w.scalars.as_ptr()));
    }

    /// A Bn254-shaped curve that deliberately carries no GLV parameters —
    /// pins the fallback: a GLV config must degrade to the full-width plan
    /// instead of silently dropping scalar bits.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    struct NoEndoCurve;

    impl CurveParams for NoEndoCurve {
        type Base = crate::ff::FpBn254;

        fn b() -> Self::Base {
            use crate::ff::Field;
            Self::Base::from_u64(3)
        }

        fn generator_xy() -> (Self::Base, Self::Base) {
            use crate::ff::Field;
            (Self::Base::from_u64(1), Self::Base::from_u64(2))
        }

        const SCALAR_BITS: u32 = 254;
        const MSM_SCALAR_BITS: u32 = 254;
        const NAME: &'static str = "test_no_endo";
        const AFFINE_BYTES: u64 = 64;
    }

    #[test]
    fn glv_config_falls_back_without_endo_params() {
        let cfg = MsmConfig::new(12, Reduction::RunningSum).glv();
        let plan = MsmPlan::for_curve::<NoEndoCurve>(&cfg);
        assert_eq!(plan.decomposition, Decomposition::Full);
        assert_eq!(plan.windows, 22); // full width, no silent truncation
        // and the whole pipeline still matches naive under the GLV config
        let w = points::workload::<NoEndoCurve>(20, 417);
        let per_window: Vec<_> = (0..plan.windows)
            .map(|j| plan.reduce(&plan.fill_window(&w.points, &w.scalars, j)))
            .collect();
        let got = plan.combine(&per_window);
        assert!(got.eq_point(&crate::msm::naive::msm(&w.points, &w.scalars)));
    }
}
