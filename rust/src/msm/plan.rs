//! The shared MSM kernel plan: one place that decides window slicing,
//! digit encoding (unsigned vs signed), bucket indexing, and the
//! bucket-reduction strategy — consumed by **every** backend
//! ([`super::pippenger`], [`super::parallel`], [`super::batch_affine`],
//! `runtime::msm_engine`) and by the FPGA timing model
//! (`fpga::sab`/`fpga::rbam`), so software and hardware model can never
//! disagree on bucket counts or window counts again.
//!
//! A plan answers, for a fixed curve + [`MsmConfig`]:
//!
//! * how many k-bit windows cover the scalar ([`MsmPlan::windows`] —
//!   signed mode adds a carry window only when the top slice can carry);
//! * how many bucket slots a window needs ([`MsmPlan::bucket_slots`],
//!   [`MsmPlan::live_buckets`] — **halved** by signed digits);
//! * which bucket a (scalar, window) pair touches and with which point
//!   sign ([`MsmPlan::bucket_op`]);
//! * how a filled window reduces ([`MsmPlan::reduce`]) and how window
//!   results combine ([`MsmPlan::combine`], the DNA Horner pass);
//! * the length of the serial reduce chain the hardware pays latency for
//!   ([`MsmPlan::serial_reduce_ops`] — the quantity IS-RBAM and signed
//!   digits each attack).
//!
//! Buckets use natural indexing: slot `b` holds the points whose digit has
//! magnitude `b`; slot 0 is a dummy (digit 0 contributes nothing).

use super::signed;
use crate::ec::{scalar, Affine, CurveParams, Jacobian, ScalarLimbs};

/// Digit encoding for scalar slices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Slicing {
    /// Classic Pippenger: digits in [0, 2^k), 2^k − 1 live buckets.
    Unsigned,
    /// Signed digits in [−2^(k−1), 2^(k−1)): negative digits add −P, so
    /// only 2^(k−1) live buckets — half the memory, half the running-sum
    /// chain. Needs k ≥ 2. The crate default: the default window (k = 12)
    /// is well past the k ≥ 4 threshold of [`Slicing::auto`].
    #[default]
    Signed,
}

impl Slicing {
    /// Default policy: signed for k ≥ 4 (at tiny windows the saved chain
    /// is a handful of adds while the extra carry window costs a full
    /// fill pass).
    pub fn auto(window_bits: u32) -> Slicing {
        if window_bits >= 4 {
            Slicing::Signed
        } else {
            Slicing::Unsigned
        }
    }
}

/// Bucket-reduction strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    /// Classic serial running sum (Algorithm 2).
    RunningSum,
    /// The paper's IS-RBAM recursive bucket reduction with sub-window k₂.
    Recursive { k2: u32 },
}

impl Default for Reduction {
    fn default() -> Self {
        // k₂ = 6 halves the serial chain at negligible extra fills for the
        // k ∈ [10, 16] range the hardware uses.
        Reduction::Recursive { k2: 6 }
    }
}

/// MSM configuration (the user-facing knobs; [`MsmPlan`] derives the rest).
#[derive(Clone, Copy, Debug)]
pub struct MsmConfig {
    /// Window (slice) width k in bits. The paper's hardware uses k = 12
    /// (Table III: ⌈254/12⌉ = 22 and ⌈381/12⌉ = 32 windows).
    pub window_bits: u32,
    pub reduction: Reduction,
    pub slicing: Slicing,
}

impl Default for MsmConfig {
    fn default() -> Self {
        MsmConfig {
            window_bits: 12,
            reduction: Reduction::default(),
            slicing: Slicing::auto(12),
        }
    }
}

impl MsmConfig {
    /// Config with the default slicing policy for the window width.
    pub fn new(window_bits: u32, reduction: Reduction) -> MsmConfig {
        MsmConfig { window_bits, reduction, slicing: Slicing::auto(window_bits) }
    }

    /// Config pinned to unsigned (paper-faithful) buckets.
    pub fn unsigned(window_bits: u32, reduction: Reduction) -> MsmConfig {
        MsmConfig { window_bits, reduction, slicing: Slicing::Unsigned }
    }

    /// Auto-tuned config for an m-point MSM (window via the c ≈ log2 m − 3
    /// rule clamped to the hardware point, default reduction + slicing).
    pub fn auto(m: usize) -> MsmConfig {
        MsmConfig::new(super::auto_window(m), Reduction::default())
    }
}

/// A fully resolved execution plan for one MSM shape.
#[derive(Clone, Copy, Debug)]
pub struct MsmPlan {
    pub window_bits: u32,
    pub slicing: Slicing,
    pub reduction: Reduction,
    /// Scalar bit width the windows must cover.
    pub scalar_bits: u32,
    /// Window count (signed mode adds a carry window only when the top
    /// slice is wide enough to carry — see `signed::signed_window_count`).
    pub windows: u32,
}

impl MsmPlan {
    /// Build a plan for `scalar_bits`-wide scalars under `cfg`.
    pub fn new(scalar_bits: u32, cfg: &MsmConfig) -> MsmPlan {
        let k = cfg.window_bits;
        assert!((1..=16).contains(&k), "window bits out of range");
        if cfg.slicing == Slicing::Signed {
            assert!(k >= 2, "signed slicing needs k >= 2");
        }
        let windows = match cfg.slicing {
            Slicing::Unsigned => scalar::window_count(scalar_bits, k),
            Slicing::Signed => signed::signed_window_count(scalar_bits, k),
        };
        MsmPlan {
            window_bits: k,
            slicing: cfg.slicing,
            reduction: cfg.reduction,
            scalar_bits,
            windows,
        }
    }

    /// Plan for a curve's scalars (the width every backend uses).
    pub fn for_curve<C: CurveParams>(cfg: &MsmConfig) -> MsmPlan {
        MsmPlan::new(C::SCALAR_BITS.min(256), cfg)
    }

    /// Bucket-array length per window, **including** the dummy slot 0.
    pub fn bucket_slots(&self) -> usize {
        match self.slicing {
            Slicing::Unsigned => 1usize << self.window_bits,
            Slicing::Signed => (1usize << (self.window_bits - 1)) + 1,
        }
    }

    /// Live (coefficient-carrying) buckets per window: 2^k − 1 unsigned,
    /// 2^(k−1) signed. This is what sizes hardware bucket memory and the
    /// running-sum serial chain.
    pub fn live_buckets(&self) -> u64 {
        self.bucket_slots() as u64 - 1
    }

    /// Digit of `scalar` at window `j`: [0, 2^k) unsigned,
    /// [−2^(k−1), 2^(k−1)) signed.
    #[inline]
    pub fn digit(&self, scalar: &ScalarLimbs, j: u32) -> i64 {
        match self.slicing {
            Slicing::Unsigned => {
                scalar::slice_bits(scalar, j * self.window_bits, self.window_bits) as i64
            }
            Slicing::Signed => signed::signed_digit(scalar, j, self.window_bits),
        }
    }

    /// All digits of one scalar, LSB window first (length [`Self::windows`]).
    pub fn digits(&self, scalar: &ScalarLimbs) -> Vec<i64> {
        match self.slicing {
            Slicing::Unsigned => (0..self.windows)
                .map(|j| {
                    scalar::slice_bits(scalar, j * self.window_bits, self.window_bits) as i64
                })
                .collect(),
            Slicing::Signed => {
                signed::signed_digits(scalar, self.window_bits, self.windows)
            }
        }
    }

    /// The bucket operation for (scalar, window): `None` when the digit is
    /// zero, else `(bucket_index, negate_point)`. The index is the digit's
    /// magnitude (natural indexing), never 0, and < [`Self::bucket_slots`].
    #[inline]
    pub fn bucket_op(&self, scalar: &ScalarLimbs, j: u32) -> Option<(usize, bool)> {
        let d = self.digit(scalar, j);
        match d.cmp(&0) {
            std::cmp::Ordering::Equal => None,
            std::cmp::Ordering::Greater => Some((d as usize, false)),
            std::cmp::Ordering::Less => Some((d.unsigned_abs() as usize, true)),
        }
    }

    /// Fill one window's Jacobian buckets (mixed adds, sign-aware). The
    /// shared fill loop of the serial and window-parallel backends; the
    /// batch-affine and engine backends drive [`Self::bucket_op`] through
    /// their own batched executors.
    pub fn fill_window<C: CurveParams>(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        j: u32,
    ) -> Vec<Jacobian<C>> {
        let mut buckets = vec![Jacobian::<C>::infinity(); self.bucket_slots()];
        for (p, s) in points.iter().zip(scalars) {
            if let Some((b, negate)) = self.bucket_op(s, j) {
                if negate {
                    buckets[b] = buckets[b].add_mixed(&p.neg());
                } else {
                    buckets[b] = buckets[b].add_mixed(p);
                }
            }
        }
        buckets
    }

    /// Reduce one window's (natural-indexed) buckets to Σ b·B[b] with the
    /// planned strategy.
    pub fn reduce<C: CurveParams>(&self, buckets: &[Jacobian<C>]) -> Jacobian<C> {
        match self.reduction {
            Reduction::RunningSum => reduce_running_sum(buckets),
            Reduction::Recursive { k2 } => {
                reduce_recursive(buckets, self.window_bits, k2.clamp(1, self.window_bits))
            }
        }
    }

    /// DNA combine: Horner over window results (index j = window j, LSB
    /// first), k doublings per window plus one add.
    pub fn combine<C: CurveParams>(&self, window_results: &[Jacobian<C>]) -> Jacobian<C> {
        let mut result = Jacobian::<C>::infinity();
        for wj in window_results.iter().rev() {
            for _ in 0..self.window_bits {
                result = result.double();
            }
            result = result.add(wj);
        }
        result
    }

    /// Length of the *serially dependent* point-op chain in one window's
    /// reduction — each of these stalls a full pipeline latency in
    /// hardware. Running sum: 2·live_buckets (signed mode halves it);
    /// IS-RBAM: (k/k₂) short sums of 2^k₂ buckets plus k Horner doublings.
    pub fn serial_reduce_ops_per_window(&self) -> u64 {
        match self.reduction {
            Reduction::RunningSum => 2 * self.live_buckets(),
            Reduction::Recursive { k2 } => {
                let k2 = k2.clamp(1, self.window_bits);
                let sub = self.window_bits.div_ceil(k2) as u64;
                sub * 2 * ((1u64 << k2) - 1) + self.window_bits as u64
            }
        }
    }

    /// Serial reduce chain across all windows.
    pub fn serial_reduce_ops(&self) -> u64 {
        self.serial_reduce_ops_per_window() * self.windows as u64
    }
}

/// Algorithm 2's reconstruction loop: Σ b·B[b] via the running sum.
/// 2·(len − 1) point adds, all serially dependent.
pub fn reduce_running_sum<C: CurveParams>(buckets: &[Jacobian<C>]) -> Jacobian<C> {
    let mut acc = Jacobian::<C>::infinity(); // E: running suffix sum
    let mut sum = Jacobian::<C>::infinity(); // A: accumulated answer
    for b in buckets.iter().skip(1).rev() {
        acc = acc.add(b);
        sum = sum.add(&acc);
    }
    sum
}

/// IS-RBAM: Σ b·B[b] as a second-level bucket MSM over k₂-bit sub-slices
/// of the bucket index. `index_bits` is the bit width of the largest
/// bucket index (= k for both unsigned [max 2^k − 1] and signed
/// [max 2^(k−1)] plans). Identical output to the running sum; the serial
/// chain shrinks from 2·live to (k/k₂)·2·2^k₂ (plus k doublings) — the
/// rest is independent, pipeline-friendly fills.
pub fn reduce_recursive<C: CurveParams>(
    buckets: &[Jacobian<C>],
    index_bits: u32,
    k2: u32,
) -> Jacobian<C> {
    assert!(k2 >= 1 && k2 <= index_bits, "invalid sub-window");
    let sub_windows = index_bits.div_ceil(k2);
    let mut l2: Vec<Vec<Jacobian<C>>> =
        vec![vec![Jacobian::<C>::infinity(); 1 << k2]; sub_windows as usize];
    for (b, point) in buckets.iter().enumerate().skip(1) {
        if point.is_infinity() {
            continue;
        }
        let mut idx = b as u64;
        for t in 0..sub_windows {
            let sub = (idx & ((1 << k2) - 1)) as usize;
            if sub != 0 {
                l2[t as usize][sub] = l2[t as usize][sub].add(point);
            }
            idx >>= k2;
        }
    }
    // Each sub-window reduces with the (short) running sum, then Horner.
    let mut result = Jacobian::<C>::infinity();
    for t in (0..sub_windows).rev() {
        for _ in 0..k2 {
            result = result.double();
        }
        let w = reduce_running_sum(&l2[t as usize]);
        result = result.add(&w);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bls12381G1, Bn254G1};

    #[test]
    fn plan_window_counts() {
        let unsigned = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::RunningSum));
        assert_eq!(unsigned.windows, 22); // Table III
        // 254-bit scalars at k=12: the top window has only 2 live bits —
        // it can never carry, so signed mode needs no extra window
        let signed = MsmPlan::new(254, &MsmConfig::new(12, Reduction::RunningSum));
        assert_eq!(signed.slicing, Slicing::Signed);
        assert_eq!(signed.windows, 22);
        // a full-width top window (24 = 2·12 bits) can carry: +1
        let carrying = MsmPlan::new(24, &MsmConfig::new(12, Reduction::RunningSum));
        assert_eq!(carrying.windows, 3);
        assert_eq!(MsmPlan::new(24, &MsmConfig::unsigned(12, Reduction::RunningSum)).windows, 2);
    }

    #[test]
    fn signed_halves_buckets() {
        for k in [4u32, 8, 12, 16] {
            let u = MsmPlan::new(254, &MsmConfig::unsigned(k, Reduction::RunningSum));
            let s = MsmPlan::new(254, &MsmConfig::new(k, Reduction::RunningSum));
            assert_eq!(u.live_buckets(), (1 << k) - 1);
            assert_eq!(s.live_buckets(), 1 << (k - 1));
            assert_eq!(u.bucket_slots(), 1 << k);
            assert_eq!(s.bucket_slots(), (1 << (k - 1)) + 1);
            // the halving the reduce chain inherits: (2^k − 1)/2^(k−1),
            // i.e. 1.875 at k = 4 and → 2 as k grows
            let ratio = u.serial_reduce_ops_per_window() as f64
                / s.serial_reduce_ops_per_window() as f64;
            assert!(ratio > 1.8 && ratio <= 2.0, "k={k} ratio={ratio}");
        }
    }

    #[test]
    fn slicing_auto_threshold() {
        assert_eq!(Slicing::auto(2), Slicing::Unsigned);
        assert_eq!(Slicing::auto(3), Slicing::Unsigned);
        assert_eq!(Slicing::auto(4), Slicing::Signed);
        assert_eq!(Slicing::auto(12), Slicing::Signed);
        // the crate default is the paper window, so signed mode is on
        assert_eq!(MsmConfig::default().slicing, Slicing::Signed);
    }

    #[test]
    fn digits_match_digit_and_stay_in_range() {
        let w = points::workload::<Bn254G1>(6, 411);
        for cfg in [
            MsmConfig::unsigned(8, Reduction::RunningSum),
            MsmConfig::new(8, Reduction::RunningSum),
            MsmConfig::new(13, Reduction::RunningSum),
        ] {
            let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
            for s in &w.scalars {
                let all = plan.digits(s);
                assert_eq!(all.len(), plan.windows as usize);
                for (j, &d) in all.iter().enumerate() {
                    assert_eq!(plan.digit(s, j as u32), d);
                    assert!(d.unsigned_abs() <= plan.live_buckets(), "digit {d}");
                    match plan.bucket_op(s, j as u32) {
                        None => assert_eq!(d, 0),
                        Some((b, neg)) => {
                            assert_eq!(b as u64, d.unsigned_abs());
                            assert_eq!(neg, d < 0);
                            assert!(b < plan.bucket_slots());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fill_reduce_combine_matches_naive_both_modes() {
        let w = points::workload::<Bn254G1>(60, 412);
        let want = crate::msm::naive::msm(&w.points, &w.scalars);
        for slicing in [Slicing::Unsigned, Slicing::Signed] {
            for red in [Reduction::RunningSum, Reduction::Recursive { k2: 3 }] {
                let cfg = MsmConfig { window_bits: 7, reduction: red, slicing };
                let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
                let per_window: Vec<_> = (0..plan.windows)
                    .map(|j| plan.reduce(&plan.fill_window(&w.points, &w.scalars, j)))
                    .collect();
                let got = plan.combine(&per_window);
                assert!(got.eq_point(&want), "{slicing:?} {red:?}");
            }
        }
    }

    #[test]
    fn bls_signed_matches_naive() {
        let w = points::workload::<Bls12381G1>(40, 413);
        let want = crate::msm::naive::msm(&w.points, &w.scalars);
        let plan = MsmPlan::for_curve::<Bls12381G1>(&MsmConfig::default());
        let per_window: Vec<_> = (0..plan.windows)
            .map(|j| plan.reduce(&plan.fill_window(&w.points, &w.scalars, j)))
            .collect();
        assert!(plan.combine(&per_window).eq_point(&want));
    }

    #[test]
    fn serial_ops_accounting() {
        // running sum, unsigned, k=12: 2·(2^12 − 1) per window × 22 windows
        let p = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::RunningSum));
        assert_eq!(p.serial_reduce_ops_per_window(), 2 * 4095);
        assert_eq!(p.serial_reduce_ops(), 2 * 4095 * 22);
        // recursive: (12/6) sub-sums of 2·63 plus 12 doublings
        let r = MsmPlan::new(254, &MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 }));
        assert_eq!(r.serial_reduce_ops_per_window(), 2 * 2 * 63 + 12);
    }

    #[test]
    #[should_panic(expected = "window bits out of range")]
    fn rejects_zero_window() {
        MsmPlan::new(254, &MsmConfig::unsigned(0, Reduction::RunningSum));
    }
}
