//! Chunked MSM over streamed point/scalar sources under a memory budget.
//!
//! The paper's accelerator never holds the full point set: the host DMAs
//! fixed-size chunks from DDR through the SAB and the kernel accumulates
//! partial sums (§IV). This module is the host-side analogue — the last
//! in-RAM scalability wall for giant circuits (ROADMAP item 1):
//!
//! * [`PointStream`]/[`ScalarStream`] — pull-based chunk sources. Provided
//!   impls: borrowed slices ([`SlicePoints`]/[`SliceScalars`]), the
//!   deterministic generator walk ([`WalkPoints`] — what
//!   `snark::stream::StreamingSrs` synthesizes queries from), a disk-backed
//!   reader over the chunk-file format ([`FilePoints`]), and the
//!   fault injectors ([`FailingPoints`], [`ShortPoints`]) the
//!   fault-injection tests use.
//! * [`msm_stream`] — the bounded-memory driver: for each chunk it charges
//!   the payload bytes to a [`MemLedger`] *before* reading (so the budget is
//!   enforced, not observed), executes the chunk through any resident
//!   [`Backend`], folds `acc = acc + partial`, and credits the bytes when
//!   the chunk drops.
//!
//! **Determinism.** The fold visits chunks in ascending point order and
//! each partial is produced by the same plan/backend machinery as the
//! resident path, so the result is bit-identical (projective `eq_point`)
//! to the one-shot MSM for every chunk size — the same argument as
//! `partial::merge`'s sorted plain-add chain, of which this is the
//! contiguous special case. `tests/prop_msm.rs` pins the full
//! chunk × curve × decomposition × backend matrix.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::marker::PhantomData;
use std::path::Path;

use super::{Backend, MsmConfig};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::ff::WordCodec;
use crate::util::mem::{BudgetExceeded, MemLedger, SCALAR_BYTES};

/// Magic number heading every point chunk file (`"ifZKPpts"` as LE bytes).
pub const POINT_FILE_MAGIC: u64 = u64::from_le_bytes(*b"ifZKPpts");
/// Version of the point chunk-file format.
pub const POINT_FILE_VERSION: u64 = 1;

/// Typed failure of a chunk source or the streaming driver.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamError {
    /// The underlying source failed to produce a chunk.
    Read {
        /// What went wrong (I/O detail, injected-fault marker, …).
        detail: String,
    },
    /// A source delivered fewer items than the driver requested.
    ShortChunk {
        /// Zero-based index of the offending chunk.
        chunk: usize,
        /// Items the driver asked for.
        expected: usize,
        /// Items actually delivered.
        got: usize,
    },
    /// Point and scalar sources disagree on the MSM length.
    LengthMismatch {
        /// Remaining points.
        points: usize,
        /// Remaining scalars.
        scalars: usize,
    },
    /// A chunk file's header is malformed or of the wrong curve/format.
    Header {
        /// What was malformed.
        detail: String,
    },
    /// The ledger refused the chunk's bytes (budget would be exceeded).
    Budget(BudgetExceeded),
    /// The budget cannot hold even a single point + scalar.
    BudgetTooSmall {
        /// Bytes one streamed element needs.
        needed: u64,
        /// The configured budget in bytes.
        budget: u64,
    },
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Read { detail } => write!(f, "chunk source read failed: {detail}"),
            StreamError::ShortChunk { chunk, expected, got } => {
                write!(f, "short chunk {chunk}: expected {expected} items, got {got}")
            }
            StreamError::LengthMismatch { points, scalars } => {
                write!(f, "stream length mismatch: {points} points vs {scalars} scalars")
            }
            StreamError::Header { detail } => write!(f, "bad point-file header: {detail}"),
            StreamError::Budget(e) => write!(f, "{e}"),
            StreamError::BudgetTooSmall { needed, budget } => {
                write!(
                    f,
                    "memory budget too small to stream: one element needs {needed} bytes, \
                     budget is {budget}"
                )
            }
        }
    }
}

impl std::error::Error for StreamError {}

impl From<BudgetExceeded> for StreamError {
    fn from(e: BudgetExceeded) -> Self {
        StreamError::Budget(e)
    }
}

/// Pull-based source of affine points for [`msm_stream`]. `len` is the
/// number of points *remaining*; `next_chunk` returns up to `max` of them
/// in order.
pub trait PointStream<C: CurveParams> {
    /// Points remaining in the stream.
    fn len(&self) -> usize;

    /// True when the stream is exhausted.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Produce the next `min(max, len)` points.
    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError>;

    /// Advance past `n` points without handing them to the caller.
    fn skip(&mut self, n: usize) -> Result<(), StreamError> {
        let mut left = n;
        while left > 0 && !self.is_empty() {
            let got = self.next_chunk(left.min(1 << 12))?;
            if got.is_empty() {
                break;
            }
            left -= got.len();
        }
        Ok(())
    }
}

/// Pull-based source of canonical scalar limbs for [`msm_stream`].
pub trait ScalarStream {
    /// Scalars remaining in the stream.
    fn len(&self) -> usize;

    /// True when the stream is exhausted.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Produce the next `min(max, len)` scalars.
    fn next_chunk(&mut self, max: usize) -> Result<Vec<ScalarLimbs>, StreamError>;
}

/// [`PointStream`] over a borrowed resident slice (the bridge the resident
/// prover uses to run its in-RAM CRS through the streaming driver).
pub struct SlicePoints<'a, C: CurveParams> {
    points: &'a [Affine<C>],
    cursor: usize,
}

impl<'a, C: CurveParams> SlicePoints<'a, C> {
    /// Stream over `points`, front to back.
    pub fn new(points: &'a [Affine<C>]) -> Self {
        SlicePoints { points, cursor: 0 }
    }
}

impl<C: CurveParams> PointStream<C> for SlicePoints<'_, C> {
    fn len(&self) -> usize {
        self.points.len() - self.cursor
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError> {
        let take = max.min(self.len());
        let out = self.points[self.cursor..self.cursor + take].to_vec();
        self.cursor += take;
        Ok(out)
    }

    fn skip(&mut self, n: usize) -> Result<(), StreamError> {
        self.cursor += n.min(self.len());
        Ok(())
    }
}

/// [`ScalarStream`] over a borrowed resident slice.
pub struct SliceScalars<'a> {
    scalars: &'a [ScalarLimbs],
    cursor: usize,
}

impl<'a> SliceScalars<'a> {
    /// Stream over `scalars`, front to back.
    pub fn new(scalars: &'a [ScalarLimbs]) -> Self {
        SliceScalars { scalars, cursor: 0 }
    }
}

impl ScalarStream for SliceScalars<'_> {
    fn len(&self) -> usize {
        self.scalars.len() - self.cursor
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<ScalarLimbs>, StreamError> {
        let take = max.min(self.len());
        let out = self.scalars[self.cursor..self.cursor + take].to_vec();
        self.cursor += take;
        Ok(out)
    }
}

/// Generator-backed [`PointStream`]: emits `len` points of the
/// deterministic additive walk (`ec::points::PointWalk`) for `seed`,
/// chunk by chunk, bit-identical to `generate_points_walk(len, seed)`.
/// Skipping costs one point-add per point (no affine normalization).
pub struct WalkPoints<C: CurveParams> {
    walk: crate::ec::points::PointWalk<C>,
    remaining: usize,
}

impl<C: CurveParams> WalkPoints<C> {
    /// A walk stream of `len` points for `seed`, starting at index 0.
    pub fn new(seed: u64, len: usize) -> Self {
        WalkPoints { walk: crate::ec::points::PointWalk::new(seed), remaining: len }
    }
}

impl<C: CurveParams> PointStream<C> for WalkPoints<C> {
    fn len(&self) -> usize {
        self.remaining
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError> {
        let take = max.min(self.remaining);
        self.remaining -= take;
        Ok(self.walk.next_chunk(take))
    }

    fn skip(&mut self, n: usize) -> Result<(), StreamError> {
        let take = n.min(self.remaining);
        self.walk.skip(take);
        self.remaining -= take;
        Ok(())
    }
}

fn io_read(e: io::Error) -> StreamError {
    StreamError::Read { detail: e.to_string() }
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Disk-backed [`PointStream`] over the chunk-file format written by
/// [`write_points_file`]: a 4-word header (magic, version, count,
/// words-per-point) followed by each point's canonical `x`/`y` words
/// (little-endian `u64`s; the point at infinity is all-zero words, which
/// is unambiguous because `(0, 0)` is off-curve for every supported group
/// — b ≠ 0). Decoding validates canonicity *and* curve membership, so a
/// corrupted file surfaces as a typed [`StreamError`], never as a wrong
/// point.
pub struct FilePoints<C: CurveParams> {
    reader: BufReader<File>,
    remaining: usize,
    next_index: usize,
    _c: PhantomData<C>,
}

impl<C: CurveParams> FilePoints<C>
where
    C::Base: WordCodec,
{
    /// Open `path`, validating the header against this curve's coordinate
    /// width.
    pub fn open(path: &Path) -> Result<Self, StreamError> {
        let bad = |detail: String| StreamError::Header { detail };
        let file = File::open(path)
            .map_err(|e| bad(format!("{}: {e}", path.display())))?;
        let mut reader = BufReader::new(file);
        let magic = read_u64(&mut reader).map_err(|e| bad(e.to_string()))?;
        if magic != POINT_FILE_MAGIC {
            return Err(bad(format!("{}: wrong magic {magic:#x}", path.display())));
        }
        let version = read_u64(&mut reader).map_err(|e| bad(e.to_string()))?;
        if version != POINT_FILE_VERSION {
            return Err(bad(format!("{}: unsupported version {version}", path.display())));
        }
        let count = read_u64(&mut reader).map_err(|e| bad(e.to_string()))?;
        let words = read_u64(&mut reader).map_err(|e| bad(e.to_string()))?;
        let expect_words = 2 * C::Base::WORDS as u64;
        if words != expect_words {
            return Err(bad(format!(
                "{}: {words} words per point, curve {} needs {expect_words}",
                path.display(),
                C::NAME
            )));
        }
        Ok(FilePoints { reader, remaining: count as usize, next_index: 0, _c: PhantomData })
    }

    /// Cap the stream at the next `n` points (for query vectors shorter
    /// than the stored file).
    pub fn take(mut self, n: usize) -> Self {
        self.remaining = self.remaining.min(n);
        self
    }
}

impl<C: CurveParams> PointStream<C> for FilePoints<C>
where
    C::Base: WordCodec,
{
    fn len(&self) -> usize {
        self.remaining
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError> {
        let take = max.min(self.remaining);
        let words_per = 2 * C::Base::WORDS;
        let mut words = vec![0u64; words_per];
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            for w in words.iter_mut() {
                *w = read_u64(&mut self.reader).map_err(io_read)?;
            }
            if words.iter().all(|&w| w == 0) {
                out.push(Affine::infinity());
            } else {
                let decode_err = || StreamError::Read {
                    detail: format!("non-canonical coordinate at point {}", self.next_index),
                };
                let x = C::Base::read_words(&words[..C::Base::WORDS]).ok_or_else(decode_err)?;
                let y = C::Base::read_words(&words[C::Base::WORDS..]).ok_or_else(decode_err)?;
                let p = Affine::new(x, y);
                if !p.is_on_curve() {
                    return Err(StreamError::Read {
                        detail: format!("off-curve point at index {}", self.next_index),
                    });
                }
                out.push(p);
            }
            self.next_index += 1;
            self.remaining -= 1;
        }
        Ok(out)
    }

    fn skip(&mut self, n: usize) -> Result<(), StreamError> {
        let take = n.min(self.remaining);
        let bytes = (take * 2 * C::Base::WORDS * 8) as i64;
        self.reader.seek_relative(bytes).map_err(io_read)?;
        self.next_index += take;
        self.remaining -= take;
        Ok(())
    }
}

/// Drain `source` into the chunk-file format at `path`, `chunk` points at
/// a time (the writer never holds more than one chunk). Returns the
/// number of points written.
pub fn write_points_file<C: CurveParams>(
    path: &Path,
    source: &mut dyn PointStream<C>,
    chunk: usize,
) -> Result<u64, StreamError>
where
    C::Base: WordCodec,
{
    assert!(chunk > 0, "write_points_file needs a positive chunk size");
    let file = File::create(path)
        .map_err(|e| StreamError::Read { detail: format!("{}: {e}", path.display()) })?;
    let mut writer = BufWriter::new(file);
    let count = source.len() as u64;
    let header = [POINT_FILE_MAGIC, POINT_FILE_VERSION, count, 2 * C::Base::WORDS as u64];
    for w in header {
        writer.write_all(&w.to_le_bytes()).map_err(io_read)?;
    }
    let mut words: Vec<u64> = Vec::with_capacity(2 * C::Base::WORDS);
    while !source.is_empty() {
        for p in source.next_chunk(chunk)? {
            words.clear();
            if p.infinity {
                words.resize(2 * C::Base::WORDS, 0);
            } else {
                p.x.write_words(&mut words);
                p.y.write_words(&mut words);
            }
            for w in &words {
                writer.write_all(&w.to_le_bytes()).map_err(io_read)?;
            }
        }
    }
    writer.flush().map_err(io_read)?;
    Ok(count)
}

/// Fault injector: delegates to `inner` but fails (typed
/// [`StreamError::Read`]) on the `fail_at`-th `next_chunk` call.
pub struct FailingPoints<S> {
    inner: S,
    fail_at: usize,
    calls: usize,
}

impl<S> FailingPoints<S> {
    /// Fail on the zero-based `fail_at`-th chunk read.
    pub fn new(inner: S, fail_at: usize) -> Self {
        FailingPoints { inner, fail_at, calls: 0 }
    }
}

impl<C: CurveParams, S: PointStream<C>> PointStream<C> for FailingPoints<S> {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError> {
        if self.calls == self.fail_at {
            return Err(StreamError::Read {
                detail: format!("injected read failure at chunk {}", self.fail_at),
            });
        }
        self.calls += 1;
        self.inner.next_chunk(max)
    }
}

/// Fault injector: delegates to `inner` but drops one item from the
/// `short_at`-th chunk (a source that silently under-delivers).
pub struct ShortPoints<S> {
    inner: S,
    short_at: usize,
    calls: usize,
}

impl<S> ShortPoints<S> {
    /// Under-deliver on the zero-based `short_at`-th chunk read.
    pub fn new(inner: S, short_at: usize) -> Self {
        ShortPoints { inner, short_at, calls: 0 }
    }
}

impl<C: CurveParams, S: PointStream<C>> PointStream<C> for ShortPoints<S> {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn next_chunk(&mut self, max: usize) -> Result<Vec<Affine<C>>, StreamError> {
        let call = self.calls;
        self.calls += 1;
        let mut out = self.inner.next_chunk(max)?;
        if call == self.short_at {
            out.pop();
        }
        Ok(out)
    }
}

/// Bytes one streamed chunk of `n` points + scalars occupies on the
/// ledger (affine coordinates + canonical scalar limbs).
pub fn chunk_bytes<C: CurveParams>(n: usize) -> u64 {
    n as u64 * (C::AFFINE_BYTES + SCALAR_BYTES)
}

/// Largest chunk (in points) a budget of `budget_bytes` admits for this
/// curve; 0 when the budget cannot hold even one element.
pub fn chunk_for_budget<C: CurveParams>(budget_bytes: u64) -> usize {
    let per = C::AFFINE_BYTES + SCALAR_BYTES;
    (budget_bytes / per).min(usize::MAX as u64) as usize
}

/// Bounded-memory MSM: fold `chunk`-sized partial MSMs over the streamed
/// sources, charging each chunk's payload bytes to `ledger` before it is
/// read. Bit-identical (`eq_point`) to the resident
/// [`execute`](super::execute) on the same data for every chunk size and
/// backend; see the module docs for the determinism argument.
pub fn msm_stream<C: CurveParams>(
    points: &mut dyn PointStream<C>,
    scalars: &mut dyn ScalarStream,
    backend: Backend,
    cfg: &MsmConfig,
    chunk: usize,
    ledger: &MemLedger,
) -> Result<Jacobian<C>, StreamError> {
    assert!(chunk > 0, "msm_stream needs a positive chunk size");
    if points.len() != scalars.len() {
        return Err(StreamError::LengthMismatch {
            points: points.len(),
            scalars: scalars.len(),
        });
    }
    let mut acc = Jacobian::infinity();
    let mut index = 0usize;
    while !points.is_empty() {
        let want = chunk.min(points.len());
        let charge = ledger.charge(chunk_bytes::<C>(want))?;
        let pts = points.next_chunk(want)?;
        if pts.len() != want {
            return Err(StreamError::ShortChunk { chunk: index, expected: want, got: pts.len() });
        }
        let scs = scalars.next_chunk(want)?;
        if scs.len() != want {
            return Err(StreamError::ShortChunk { chunk: index, expected: want, got: scs.len() });
        }
        let partial = super::execute(backend, &pts, &scs, cfg);
        acc = acc.add(&partial);
        drop(charge);
        index += 1;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::points::{generate_points_walk, workload};
    use crate::ec::{Bls12381G1, Bn254G1, Bn254G2};
    use crate::util::mem::MemoryBudget;

    #[test]
    fn slice_streams_match_resident_execute() {
        let w = workload::<Bn254G1>(200, 11);
        let cfg = MsmConfig::auto(200);
        let want = super::super::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        for chunk in [1usize, 7, 64, 200, 500] {
            let ledger = MemLedger::unlimited();
            let mut ps = SlicePoints::new(&w.points);
            let mut ss = SliceScalars::new(&w.scalars);
            let got =
                msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, chunk, &ledger).unwrap();
            assert!(got.eq_point(&want), "chunk={chunk}");
            assert_eq!(ledger.live_bytes(), 0, "all charges credited back");
        }
    }

    #[test]
    fn walk_stream_matches_one_shot_generation() {
        let mut ws = WalkPoints::<Bn254G1>::new(99, 50);
        let mut got = Vec::new();
        got.extend(ws.next_chunk(17).unwrap());
        got.extend(ws.next_chunk(40).unwrap());
        assert!(ws.is_empty());
        let want = generate_points_walk::<Bn254G1>(50, 99);
        for (p, q) in got.iter().zip(&want) {
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
    }

    #[test]
    fn file_roundtrip_preserves_points_and_infinity() {
        let dir = std::env::temp_dir().join("ifzkp_stream_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts.bin");
        let mut pts = generate_points_walk::<Bn254G1>(33, 5);
        pts[7] = Affine::infinity();
        let n = write_points_file::<Bn254G1>(&path, &mut SlicePoints::new(&pts), 10).unwrap();
        assert_eq!(n, 33);
        let mut fp = FilePoints::<Bn254G1>::open(&path).unwrap();
        assert_eq!(PointStream::<Bn254G1>::len(&fp), 33);
        let back = fp.next_chunk(33).unwrap();
        assert!(fp.is_empty());
        for (p, q) in back.iter().zip(&pts) {
            assert_eq!(p.infinity, q.infinity);
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
        // skip + take work against the same file
        let mut fp = FilePoints::<Bn254G1>::open(&path).unwrap().take(20);
        PointStream::<Bn254G1>::skip(&mut fp, 3).unwrap();
        let tail = fp.next_chunk(100).unwrap();
        assert_eq!(tail.len(), 17);
        assert_eq!(tail[0].x, pts[3].x);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_roundtrip_g2() {
        let dir = std::env::temp_dir().join("ifzkp_stream_g2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts_g2.bin");
        let pts = generate_points_walk::<Bn254G2>(9, 6);
        write_points_file::<Bn254G2>(&path, &mut SlicePoints::new(&pts), 4).unwrap();
        let mut fp = FilePoints::<Bn254G2>::open(&path).unwrap();
        let back = fp.next_chunk(9).unwrap();
        for (p, q) in back.iter().zip(&pts) {
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_curve_file_is_rejected_at_open() {
        let dir = std::env::temp_dir().join("ifzkp_stream_wrongcurve");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts_bn.bin");
        let pts = generate_points_walk::<Bn254G1>(4, 8);
        write_points_file::<Bn254G1>(&path, &mut SlicePoints::new(&pts), 4).unwrap();
        // a BLS reader expects 12-word points, the file has 8-word points
        let err = FilePoints::<Bls12381G1>::open(&path).unwrap_err();
        assert!(matches!(err, StreamError::Header { .. }), "{err:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_surfaces_read_error_not_garbage() {
        let dir = std::env::temp_dir().join("ifzkp_stream_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts_trunc.bin");
        let pts = generate_points_walk::<Bn254G1>(8, 9);
        write_points_file::<Bn254G1>(&path, &mut SlicePoints::new(&pts), 8).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 16]).unwrap();
        let mut fp = FilePoints::<Bn254G1>::open(&path).unwrap();
        let err = fp.next_chunk(8).unwrap_err();
        assert!(matches!(err, StreamError::Read { .. }), "{err:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn budget_enforced_by_driver() {
        let w = workload::<Bn254G1>(64, 12);
        let cfg = MsmConfig::auto(64);
        // 16-point chunks need 16 × 96 bytes; one byte less must refuse
        let per = chunk_bytes::<Bn254G1>(16);
        let ledger = MemLedger::new(MemoryBudget::bytes(per - 1));
        let mut ps = SlicePoints::new(&w.points);
        let mut ss = SliceScalars::new(&w.scalars);
        let err = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, 16, &ledger).unwrap_err();
        assert!(matches!(err, StreamError::Budget(_)), "{err:?}");
        // with exactly the needed budget it runs, and the peak is pinned
        let ledger = MemLedger::new(MemoryBudget::bytes(per));
        let mut ps = SlicePoints::new(&w.points);
        let mut ss = SliceScalars::new(&w.scalars);
        let got = msm_stream(&mut ps, &mut ss, Backend::Pippenger, &cfg, 16, &ledger).unwrap();
        let want = super::super::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&want));
        assert_eq!(ledger.peak_bytes(), per);
    }

    #[test]
    fn length_mismatch_is_typed() {
        let w = workload::<Bn254G1>(10, 13);
        let cfg = MsmConfig::auto(10);
        let ledger = MemLedger::unlimited();
        let mut ps = SlicePoints::new(&w.points);
        let mut ss = SliceScalars::new(&w.scalars[..9]);
        let err = msm_stream(&mut ps, &mut ss, Backend::Naive, &cfg, 4, &ledger).unwrap_err();
        assert_eq!(err, StreamError::LengthMismatch { points: 10, scalars: 9 });
    }

    #[test]
    fn chunk_sizing_helpers() {
        // BN254 G1: 64-byte points + 32-byte scalars
        assert_eq!(chunk_bytes::<Bn254G1>(10), 960);
        assert_eq!(chunk_for_budget::<Bn254G1>(960), 10);
        assert_eq!(chunk_for_budget::<Bn254G1>(959), 9);
        assert_eq!(chunk_for_budget::<Bn254G1>(95), 0);
    }
}
