//! # ifzkp — if-ZKP reproduction
//!
//! Full-system reproduction of *"if-ZKP: Intel FPGA-Based Acceleration of
//! Zero Knowledge Proofs"* (Butt et al., Intel, 2024): FPGA acceleration of
//! the multi-scalar multiplication (MSM) at the heart of zk-SNARK provers,
//! for the BN254 ("BN128") and BLS12-381 curves in Jacobian coordinates.
//!
//! The crate is organised in three layers (see `DESIGN.md`):
//!
//! * **substrates** — finite fields ([`ff`]), elliptic curves ([`ec`]),
//!   MSM algorithms ([`msm`]: one shared `MsmKernel` plan — window slicing,
//!   signed-digit buckets, reduction strategy — consumed by every backend
//!   behind the [`msm::Backend`] dispatch), the NTT runtime ([`ntt`]: a
//!   cached twiddle plan with stage-parallel and four-step executors,
//!   mirroring the MSM plan/executor split) and a Groth16-shaped prover
//!   ([`snark`]) — everything the paper's evaluation depends on, built
//!   from scratch;
//! * **device models** — a cycle-level model of the paper's SAB/UDA Agilex
//!   design ([`fpga`]) plus the CPU/GPU baselines ([`baseline`]);
//! * **runtime + coordinator** — a PJRT-backed batched point-operation
//!   engine ([`runtime`]) that executes the AOT-compiled JAX/Pallas UDA
//!   datapath, orchestrated by a serving-style coordinator
//!   ([`coordinator`]).
//!
//! The [`report`] module regenerates every table and figure of the paper's
//! evaluation section; `rust/benches/` contains one harness per table and
//! figure.
//!
//! Start with the repository-level `README.md` for the architecture map
//! and a CLI tour; `rust/DESIGN.md` holds the full design notes.

// Every public item must carry rustdoc: CI runs `cargo doc --no-deps`
// with `RUSTDOCFLAGS="-D warnings"`, so a missing doc fails the build
// there rather than rotting silently.
#![warn(missing_docs)]

pub mod util;
pub mod config;
pub mod ff;
pub mod ec;
pub mod msm;
pub mod ntt;
pub mod snark;
pub mod fpga;
pub mod baseline;
pub mod runtime;
pub mod coordinator;
pub mod report;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
