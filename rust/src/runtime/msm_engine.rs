//! MSM executed through the UDA engine — the full paper dataflow in
//! software: the host (SPS role) streams (bucket, point) pairs, batches are
//! formed **conflict-free** (no two ops in a batch target the same bucket —
//! the BAM's hazard rule, §IV-A), the engine (UDA role) executes them, and
//! the reduction/combination phases (IS-RBAM/DNA roles) drain the remaining
//! serial work.
//!
//! Window slicing, digit signs, and bucket indexing all come from the
//! shared [`MsmPlan`] — the engine is just one more executor of the same
//! kernel, so signed-digit mode (negated operand, half the buckets, and
//! with it half the BAM conflict surface) works here unchanged.
//!
//! The engine performs the bucket-fill phase, which is ≥90% of all point
//! operations at realistic sizes — matching the paper's claim that the BAM
//! "may account for generating 90% or more" of the point ops. The short
//! serial tails run on the native path (they are latency- not
//! throughput-bound, exactly like the hardware's DNA stage).

use super::engine::{EngineCurve, UdaEngine};
use crate::ec::{Affine, Jacobian, ScalarLimbs};
use crate::msm::plan::{MsmConfig, MsmPlan};
use anyhow::Result;

/// Outcome stats of an engine MSM.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineMsmStats {
    /// Point-ops executed on the engine (bucket fills).
    pub engine_ops: u64,
    /// Engine batches dispatched.
    pub engine_batches: u64,
    /// Mean batch occupancy (filled lanes / batch width).
    pub mean_occupancy: f64,
    /// Point-ops executed natively (reduction + combine tails).
    pub native_ops: u64,
}

/// MSM with engine-offloaded bucket accumulation.
pub fn msm_engine<C: EngineCurve>(
    engine: &UdaEngine<C>,
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
    cfg: &MsmConfig,
) -> Result<(Jacobian<C>, EngineMsmStats)> {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let mut stats = EngineMsmStats::default();
    if points.is_empty() {
        return Ok((Jacobian::infinity(), stats));
    }
    let plan = MsmPlan::for_curve::<C>(cfg);
    // The engine is one more executor of the shared kernel: GLV expansion
    // (when configured) happens in the same plan.prepare step as the
    // native backends, so engine results stay bit-exact against them.
    let input = plan.prepare::<C>(points, scalars);
    let (points, scalars) = (input.points(), input.scalars());
    let nbuckets = plan.bucket_slots();
    let bsz = engine.batch();

    let native0 = crate::ec::counters::snapshot();
    let mut window_results = Vec::with_capacity(plan.windows as usize);
    for j in 0..plan.windows {
        // ---- fill phase on the engine, conflict-free batches ------------
        let mut buckets = vec![Jacobian::<C>::infinity(); nbuckets];
        // op queue: (bucket, point index, negate); simple two-pass
        // scheduling — take ops whose bucket is not yet used in the current
        // batch, defer conflicts to the next round (the BAM's replay FIFO).
        let mut queue: Vec<(usize, usize, bool)> = Vec::with_capacity(points.len());
        for (i, s) in scalars.iter().enumerate() {
            if let Some((b, negate)) = plan.bucket_op(s, j) {
                queue.push((b, i, negate));
            }
        }
        let mut in_batch = vec![false; nbuckets];
        while !queue.is_empty() {
            let mut batch_ops: Vec<(usize, usize, bool)> = Vec::with_capacity(bsz);
            let mut deferred: Vec<(usize, usize, bool)> = Vec::new();
            for (b, i, negate) in queue.drain(..) {
                if batch_ops.len() < bsz && !in_batch[b] {
                    in_batch[b] = true;
                    batch_ops.push((b, i, negate));
                } else {
                    deferred.push((b, i, negate));
                }
            }
            let pairs: Vec<(Jacobian<C>, Jacobian<C>)> = batch_ops
                .iter()
                .map(|&(b, i, negate)| {
                    let p = if negate { points[i].neg() } else { points[i] };
                    (buckets[b], p.to_jacobian())
                })
                .collect();
            let outs = engine.uda_batch(&pairs)?;
            for (&(b, _, _), out) in batch_ops.iter().zip(outs) {
                buckets[b] = out;
                in_batch[b] = false;
            }
            stats.engine_ops += pairs.len() as u64;
            stats.engine_batches += 1;
            stats.mean_occupancy += pairs.len() as f64 / bsz as f64;
            queue = deferred;
        }

        // ---- reduce tail natively (IS-RBAM role) ------------------------
        window_results.push(plan.reduce(&buckets));
    }
    // ---- DNA combine -----------------------------------------------------
    let result = plan.combine(&window_results);
    stats.native_ops = (crate::ec::counters::snapshot() - native0).total();
    if stats.engine_batches > 0 {
        stats.mean_occupancy /= stats.engine_batches as f64;
    }
    Ok((result, stats))
}
