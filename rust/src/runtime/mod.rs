//! PJRT runtime: the "FPGA board" of this reproduction.
//!
//! The paper's host sees the FPGA as an offload engine reached through a
//! load/execute interface (§V-C); here the rust host loads AOT-compiled XLA
//! executables (`artifacts/*.hlo.txt`, produced once by
//! `python/compile/aot.py`) onto a PJRT CPU client and drives them from the
//! request path. The analogy is kept deliberately tight:
//!
//! | paper                      | this repo                     |
//! |----------------------------|-------------------------------|
//! | bitstream on Agilex        | HLO text compiled on PJRT     |
//! | oneAPI BSP shell           | [`context::PjrtContext`]      |
//! | UDA pipelined point unit   | [`engine::UdaEngine`] batch   |
//! | DDR-resident point banks   | host-side packed limb buffers |
//!
//! Python never runs at request time; the HLO artifacts are the only thing
//! that crosses the language boundary.

pub mod artifact;
pub mod context;
pub mod engine;
pub mod msm_engine;

pub use artifact::{ArtifactManifest, ArtifactMeta};
pub use context::PjrtContext;
pub use engine::{EngineCurve, UdaEngine};
