//! PJRT client bootstrap (the oneAPI-BSP analogue: one per process, owns
//! the device).

use anyhow::{Context as _, Result};

/// Owns the PJRT CPU client. Compilation of each artifact happens once; the
/// resulting executables are cheap to share per-thread afterwards.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Whether a real PJRT backend is linked in. `false` with the vendored
    /// offline `xla` stub — callers should fall back to native backends.
    pub fn available() -> bool {
        xla::available()
    }

    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtContext { client })
    }

    /// Platform string (e.g. "cpu") — surfaced in metrics/logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Devices visible to the client.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        if !PjrtContext::available() {
            // vendored xla stub: construction must fail loudly, not hang
            assert!(PjrtContext::cpu().is_err());
            return;
        }
        let ctx = PjrtContext::cpu().expect("PJRT cpu client");
        assert!(ctx.device_count() >= 1);
        assert_eq!(ctx.platform().to_lowercase(), "cpu");
    }

    #[test]
    fn missing_artifact_is_error() {
        if !PjrtContext::available() {
            return;
        }
        let ctx = PjrtContext::cpu().unwrap();
        assert!(ctx.compile_hlo_text(std::path::Path::new("/nonexistent.hlo.txt")).is_err());
    }
}
