//! The UDA engine: a compiled batched point processor.
//!
//! One `execute` call performs `batch` independent unified double-adds —
//! the vector-engine re-expression of the paper's 1-op/cycle pipelined UDA
//! (see DESIGN.md §Hardware-Adaptation). Operands cross the boundary as
//! packed 16-bit Montgomery limbs; because the engine's radix equals the
//! host's (R = 2^(64·N) = 2^(16·4N)), packing is pure bit-splitting — no
//! arithmetic on the hot path.

use super::artifact::{ArtifactManifest, ArtifactMeta};
use super::context::PjrtContext;
use crate::ec::{Bls12381G1, Bn254G1, CurveParams, Jacobian};
use crate::ff::{limbs16, Field, Fp};
use anyhow::{anyhow, Context, Result};

/// Curves the engine can serve: those whose base field is a prime field
/// with a 16-bit-limb artifact (G1 of both paper curves; G2 is the paper's
/// future work and stays on the native path).
pub trait EngineCurve: CurveParams {
    /// Manifest key ("bn254" / "bls12_381").
    const MANIFEST_KEY: &'static str;
    /// 16-bit limbs per coordinate.
    const NLIMB16: usize;
    /// Pack one coordinate into `out` as NLIMB16 u32 entries.
    fn pack_coord(c: &Self::Base, out: &mut Vec<u32>);
    /// Unpack one coordinate from 16-bit limbs.
    fn unpack_coord(limbs: &[u32]) -> Result<Self::Base>;
}

macro_rules! impl_engine_curve {
    ($curve:ty, $params:ty, $n:expr, $key:expr) => {
        impl EngineCurve for $curve {
            const MANIFEST_KEY: &'static str = $key;
            const NLIMB16: usize = 4 * $n;

            fn pack_coord(c: &Self::Base, out: &mut Vec<u32>) {
                out.extend(limbs16::u64_to_u16_limbs(c.mont_limbs()));
            }

            fn unpack_coord(limbs: &[u32]) -> Result<Self::Base> {
                let u64s = limbs16::u16_limbs_to_u64(limbs).map_err(|e| anyhow!(e))?;
                let arr: [u64; $n] =
                    u64s.try_into().map_err(|_| anyhow!("bad limb count"))?;
                Fp::<$params, $n>::from_mont_limbs(arr)
                    .ok_or_else(|| anyhow!("engine returned non-canonical value"))
            }
        }
    };
}

impl_engine_curve!(Bn254G1, crate::ff::params::Bn254FpParams, 4, "bn254");
impl_engine_curve!(Bls12381G1, crate::ff::params::Bls12381FpParams, 6, "bls12_381");

/// A loaded, compiled UDA executable for one curve.
pub struct UdaEngine<C: EngineCurve> {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
    /// Engine invocations so far (metrics).
    calls: std::cell::Cell<u64>,
    /// Point-ops processed (metrics).
    ops: std::cell::Cell<u64>,
    _c: std::marker::PhantomData<C>,
}

impl<C: EngineCurve> UdaEngine<C> {
    /// Load the curve's artifact from the manifest and compile it.
    pub fn load(ctx: &PjrtContext, manifest: &ArtifactManifest) -> Result<Self> {
        let meta = manifest.for_curve(C::MANIFEST_KEY)?.clone();
        if meta.nlimb16 != C::NLIMB16 {
            return Err(anyhow!(
                "artifact limb count {} != curve limb count {}",
                meta.nlimb16,
                C::NLIMB16
            ));
        }
        let exe = ctx
            .compile_hlo_text(&manifest.path_of(&meta))
            .with_context(|| format!("loading UDA engine for {}", C::MANIFEST_KEY))?;
        Ok(UdaEngine {
            exe,
            meta,
            calls: std::cell::Cell::new(0),
            ops: std::cell::Cell::new(0),
            _c: std::marker::PhantomData,
        })
    }

    /// Engine batch width.
    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    /// (calls, point-ops) processed so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.calls.get(), self.ops.get())
    }

    /// Execute one batch of unified double-adds: `out[i] = a[i] + b[i]`
    /// (with the full UDA semantics: doubling / infinity / cancellation).
    /// `pairs.len()` must be ≤ batch; short batches are padded with
    /// (∞, ∞) lanes.
    pub fn uda_batch(
        &self,
        pairs: &[(Jacobian<C>, Jacobian<C>)],
    ) -> Result<Vec<Jacobian<C>>> {
        let b = self.meta.batch;
        let nl = C::NLIMB16;
        if pairs.is_empty() || pairs.len() > b {
            return Err(anyhow!("batch size {} out of range 1..={b}", pairs.len()));
        }
        // Pack the six coordinate planes.
        let mut planes: [Vec<u32>; 6] = Default::default();
        for plane in planes.iter_mut() {
            plane.reserve(b * nl);
        }
        let inf = Jacobian::<C>::infinity();
        for i in 0..b {
            let (p, q) = if i < pairs.len() { pairs[i] } else { (inf, inf) };
            C::pack_coord(&p.x, &mut planes[0]);
            C::pack_coord(&p.y, &mut planes[1]);
            C::pack_coord(&p.z, &mut planes[2]);
            C::pack_coord(&q.x, &mut planes[3]);
            C::pack_coord(&q.y, &mut planes[4]);
            C::pack_coord(&q.z, &mut planes[5]);
        }
        let lits: Vec<xla::Literal> = planes
            .iter()
            .map(|p| {
                xla::Literal::vec1(p)
                    .reshape(&[b as i64, nl as i64])
                    .context("reshaping input literal")
            })
            .collect::<Result<_>>()?;

        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let (xs, ys, zs) = tuple.to_tuple3()?;
        let (xs, ys, zs) =
            (xs.to_vec::<u32>()?, ys.to_vec::<u32>()?, zs.to_vec::<u32>()?);

        self.calls.set(self.calls.get() + 1);
        self.ops.set(self.ops.get() + pairs.len() as u64);

        let mut out = Vec::with_capacity(pairs.len());
        for i in 0..pairs.len() {
            let sl = i * nl..(i + 1) * nl;
            let z = C::unpack_coord(&zs[sl.clone()])?;
            if z.is_zero() {
                out.push(Jacobian::infinity());
            } else {
                out.push(Jacobian {
                    x: C::unpack_coord(&xs[sl.clone()])?,
                    y: C::unpack_coord(&ys[sl.clone()])?,
                    z,
                });
            }
        }
        Ok(out)
    }
}
