//! Artifact manifest: what `python/compile/aot.py` produced, as consumed by
//! the rust runtime (name → file, batch size, limb count, io arity).

use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Metadata for one compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// HLO text file name (relative to the manifest directory).
    pub file: String,
    /// Artifact kind (e.g. "uda").
    pub kind: String,
    /// Curve key ("bn254" / "bls12_381").
    pub curve: String,
    /// Batch width the kernel was compiled for.
    pub batch: usize,
    /// 16-bit limbs per field coordinate.
    pub nlimb16: usize,
    /// Input tensor arity.
    pub inputs: usize,
    /// Output tensor arity.
    pub outputs: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Batch width shared by all entries.
    pub batch: usize,
    /// One entry per compiled curve kernel.
    pub entries: Vec<ArtifactMeta>,
}

/// Default artifact directory: `$IFZKP_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("IFZKP_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

impl ArtifactManifest {
    /// Load and validate `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let batch = j
            .get("batch")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest missing batch"))? as usize;
        let arts = match j.get("artifacts") {
            Some(Json::Obj(m)) => m,
            _ => return Err(anyhow!("manifest missing artifacts object")),
        };
        let mut entries = Vec::new();
        for (curve, meta) in arts {
            let get_num = |k: &str| -> Result<usize> {
                meta.get(k)
                    .and_then(Json::as_f64)
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow!("artifact {curve}: missing {k}"))
            };
            let entry = ArtifactMeta {
                file: meta
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {curve}: missing file"))?
                    .to_string(),
                kind: meta.get("kind").and_then(Json::as_str).unwrap_or("uda").to_string(),
                curve: curve.clone(),
                batch: get_num("batch")?,
                nlimb16: get_num("nlimb16")?,
                inputs: get_num("inputs")?,
                outputs: get_num("outputs")?,
            };
            let fpath = dir.join(&entry.file);
            if !fpath.exists() {
                return Err(anyhow!("artifact file missing: {fpath:?}"));
            }
            entries.push(entry);
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), batch, entries })
    }

    /// Find the artifact for a curve (by manifest key, e.g. "bn254").
    pub fn for_curve(&self, curve: &str) -> Result<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|e| e.curve == curve)
            .ok_or_else(|| anyhow!("no artifact for curve {curve}"))
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_present() {
        // Runs against the checked-out artifacts dir when it exists (CI
        // builds it first); skips silently otherwise so unit tests don't
        // depend on `make artifacts`.
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts dir");
            return;
        }
        let m = ArtifactManifest::load(&dir).expect("manifest loads");
        assert!(m.batch > 0);
        let bn = m.for_curve("bn254").expect("bn254 artifact");
        assert_eq!(bn.nlimb16, 16);
        assert_eq!(bn.inputs, 6);
        assert_eq!(bn.outputs, 3);
        let bls = m.for_curve("bls12_381").expect("bls artifact");
        assert_eq!(bls.nlimb16, 24);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactManifest::load(Path::new("/no/such/dir")).is_err());
    }

    #[test]
    fn synthetic_manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ifzkp_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("uda_x_b8.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch":8,"block":4,"artifacts":{"x":{"file":"uda_x_b8.hlo.txt","kind":"uda","curve":"x","batch":8,"nlimb16":16,"inputs":6,"outputs":3}}}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.for_curve("x").unwrap().batch, 8);
        assert!(m.for_curve("y").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
