//! Deterministic open-loop traffic generator for the admission tier.
//!
//! Drives configurable tenant mixes against a fresh [`Coordinator`] fleet
//! and reports the three serving curves the admission tier exists to
//! shape: latency percentiles per lane, achieved-vs-offered throughput,
//! and shed rate as offered load sweeps past fleet capacity.
//!
//! Open-loop means arrivals do **not** wait for completions: each tenant
//! submits on a pre-drawn Poisson schedule regardless of how backed up
//! the fleet is, which is what exposes overload behavior (a closed loop
//! self-throttles and can never overrun capacity). Determinism comes
//! from drawing every arrival schedule and scalar payload from a seeded
//! [`Rng`] before the clock starts — two runs at the same seed offer an
//! identical job sequence; only the measured timings differ.
//!
//! Rates are expressed relative to *calibrated* fleet capacity (one
//! timed MSM per run, see [`calibrate`]), so a mix means the same thing
//! on a laptop and in CI: `share = 0.8` at `multiplier = 3.0` is 2.4×
//! whatever this host can actually drain.
//!
//! ```no_run
//! use ifzkp::coordinator::loadgen::{self, LoadgenConfig};
//!
//! let report = loadgen::run(&LoadgenConfig::default(), &loadgen::default_mixes());
//! println!("{}", report.to_json()); // the BENCH_serving.json payload
//! ```
//!
//! The JSON schema is documented in the repo-root `BENCHMARKS.md`.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::admission::{AdmissionConfig, AdmissionSnapshot, Lane, Quota, TenantId, LANES};
use super::devices::{DeviceDesc, PointSetRegistry};
use super::server::{Coordinator, CoordinatorConfig, ServedJob};
use crate::ec::{points, Bn254G1, CurveParams};
use crate::msm::{self, MsmConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Distinct scalar payloads cycled across submissions (pre-generated so
/// the submit loop never pays scalar-sampling cost on the clock).
const SCALAR_POOL: usize = 8;

/// One tenant's contribution to a traffic mix.
#[derive(Clone, Debug)]
pub struct TenantLoad {
    /// Display name carried into the report.
    pub name: String,
    /// Tenant identity — the token-bucket quota key.
    pub tenant: TenantId,
    /// Lane this tenant submits into.
    pub lane: Lane,
    /// Offered arrival rate at multiplier 1.0, as a fraction of the
    /// calibrated fleet capacity (shares across a mix may sum past 1.0 —
    /// that *is* the overload scenario).
    pub share: f64,
    /// Per-job deadline as a multiple of the calibrated per-job service
    /// time (`None` = no deadline, never shed as infeasible).
    pub deadline_service_mult: Option<f64>,
    /// Token-bucket quota rate as a fraction of fleet capacity
    /// (`None` = unmetered).
    pub quota_capacity_share: Option<f64>,
}

/// A named set of tenants driven together against one coordinator.
#[derive(Clone, Debug)]
pub struct TenantMix {
    /// Mix name carried into the report.
    pub name: String,
    /// The tenants generating load.
    pub tenants: Vec<TenantLoad>,
}

impl TenantMix {
    /// A balanced production-shaped mix: deadline-bound interactive
    /// traffic over a batch backbone with a best-effort trickle. Shares
    /// sum to 1.0, so `multiplier` is the fleet-relative offered load.
    pub fn steady_mixed() -> TenantMix {
        TenantMix {
            name: "steady-mixed".into(),
            tenants: vec![
                TenantLoad {
                    name: "wallet".into(),
                    tenant: TenantId(1),
                    lane: Lane::Interactive,
                    share: 0.3,
                    deadline_service_mult: Some(40.0),
                    quota_capacity_share: None,
                },
                TenantLoad {
                    name: "rollup".into(),
                    tenant: TenantId(2),
                    lane: Lane::Batch,
                    share: 0.5,
                    deadline_service_mult: None,
                    quota_capacity_share: None,
                },
                TenantLoad {
                    name: "indexer".into(),
                    tenant: TenantId(3),
                    lane: Lane::BestEffort,
                    share: 0.2,
                    deadline_service_mult: None,
                    quota_capacity_share: None,
                },
            ],
        }
    }

    /// An adversarial mix: a quota-capped best-effort tenant flooding at
    /// 4× its entitlement while a deadline-bound interactive tenant
    /// rides alongside. The acceptance shape: best-effort sheds (quota
    /// plus lane bounds), interactive p99 stays near its deadline.
    pub fn besteffort_flood() -> TenantMix {
        TenantMix {
            name: "besteffort-flood".into(),
            tenants: vec![
                TenantLoad {
                    name: "wallet".into(),
                    tenant: TenantId(11),
                    lane: Lane::Interactive,
                    share: 0.2,
                    deadline_service_mult: Some(30.0),
                    quota_capacity_share: None,
                },
                TenantLoad {
                    name: "crawler".into(),
                    tenant: TenantId(12),
                    lane: Lane::BestEffort,
                    share: 0.8,
                    deadline_service_mult: None,
                    quota_capacity_share: Some(0.4),
                },
            ],
        }
    }
}

/// The two built-in mixes every `serve --load` run sweeps.
pub fn default_mixes() -> Vec<TenantMix> {
    vec![TenantMix::steady_mixed(), TenantMix::besteffort_flood()]
}

/// Generator configuration (one sweep = every mix × every multiplier).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Points per MSM job.
    pub msm_size: usize,
    /// Fleet width: this many single-threaded native CPU devices.
    pub devices: usize,
    /// Open-loop generation window per run, in seconds (completions are
    /// still drained to the end after the window closes).
    pub duration_s: f64,
    /// Offered-load multipliers swept per mix; 1.0 ≡ calibrated fleet
    /// capacity.
    pub multipliers: Vec<f64>,
    /// Root seed for arrival schedules and scalar payloads.
    pub seed: u64,
    /// Admission tier configuration applied to each run's coordinator.
    pub admission: AdmissionConfig,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            msm_size: 512,
            devices: 2,
            duration_s: 1.0,
            multipliers: vec![0.5, 1.0, 2.0, 4.0],
            seed: 0x1f2e_3d4c,
            admission: AdmissionConfig::default(),
        }
    }
}

/// Per-lane outcome of one run: admission counters plus exact latency
/// percentiles over the successful completions.
#[derive(Clone, Debug)]
pub struct LaneStats {
    /// Which lane.
    pub lane: Lane,
    /// Jobs offered into this lane.
    pub offered: u64,
    /// Jobs admitted.
    pub admitted: u64,
    /// Jobs shed at admit time.
    pub shed: u64,
    /// Admitted jobs that completed successfully.
    pub completed: u64,
    /// Admitted jobs that finished with a delivered error.
    pub failed: u64,
    /// `shed / offered` (0 when nothing was offered).
    pub shed_rate: f64,
    /// Mean submit→reply latency over completions, seconds.
    pub mean_s: f64,
    /// Median latency, seconds.
    pub p50_s: f64,
    /// 95th-percentile latency, seconds.
    pub p95_s: f64,
    /// 99th-percentile latency, seconds.
    pub p99_s: f64,
}

/// One (mix, multiplier) run against a fresh coordinator.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Offered-load multiplier this run was driven at.
    pub multiplier: f64,
    /// Offered arrival rate actually realized, jobs/s.
    pub offered_jobs_per_s: f64,
    /// Completions per second of generation window (the drain tail after
    /// the window counts toward the numerator, so this saturates at
    /// slightly above fleet capacity rather than below it).
    pub achieved_jobs_per_s: f64,
    /// Overall `shed / offered` across lanes.
    pub shed_rate: f64,
    /// Per-lane counters and latency percentiles, [`Lane::ALL`] order.
    pub lanes: Vec<LaneStats>,
    /// Raw admission counters (includes per-reason shed counts).
    pub snapshot: AdmissionSnapshot,
    /// Busy fraction per device over the run.
    pub device_utilization: Vec<f64>,
}

/// All runs of one mix across the multiplier sweep.
#[derive(Clone, Debug)]
pub struct MixStats {
    /// Mix name.
    pub mix: String,
    /// One entry per multiplier, in sweep order.
    pub runs: Vec<RunStats>,
}

/// A full sweep: the `BENCH_serving.json` payload in struct form.
#[derive(Clone, Debug)]
pub struct ServingReport {
    /// Points per MSM job.
    pub msm_size: usize,
    /// Fleet width the sweep ran with.
    pub devices: usize,
    /// Generation window per run, seconds.
    pub duration_s: f64,
    /// Root seed the sweep ran with.
    pub seed: u64,
    /// Calibrated single-device per-job service time, seconds.
    pub calibrated_job_s: f64,
    /// Calibrated aggregate fleet capacity, jobs/s (`devices / job_s`).
    pub capacity_jobs_per_s: f64,
    /// One entry per mix.
    pub mixes: Vec<MixStats>,
}

impl ServingReport {
    /// Render the report in the `BENCH_serving.json` schema
    /// (see BENCHMARKS.md).
    pub fn to_json(&self) -> Json {
        let mut config = Json::obj();
        config
            .set("msm_size", self.msm_size)
            .set("devices", self.devices)
            .set("duration_s", self.duration_s)
            .set("seed", self.seed)
            .set("calibrated_job_s", self.calibrated_job_s)
            .set("capacity_jobs_per_s", self.capacity_jobs_per_s);
        let mut mixes = Vec::with_capacity(self.mixes.len());
        for mix in &self.mixes {
            let mut runs = Vec::with_capacity(mix.runs.len());
            for run in &mix.runs {
                let mut lanes = Vec::with_capacity(run.lanes.len());
                for l in &run.lanes {
                    let mut lj = Json::obj();
                    lj.set("lane", l.lane.name())
                        .set("offered", l.offered)
                        .set("admitted", l.admitted)
                        .set("shed", l.shed)
                        .set("completed", l.completed)
                        .set("failed", l.failed)
                        .set("shed_rate", l.shed_rate)
                        .set("mean_s", l.mean_s)
                        .set("p50_s", l.p50_s)
                        .set("p95_s", l.p95_s)
                        .set("p99_s", l.p99_s);
                    lanes.push(lj);
                }
                let mut rj = Json::obj();
                rj.set("offered_multiplier", run.multiplier)
                    .set("offered_jobs_per_s", run.offered_jobs_per_s)
                    .set("achieved_jobs_per_s", run.achieved_jobs_per_s)
                    .set("shed_rate", run.shed_rate)
                    .set("lanes", lanes)
                    .set("admission", run.snapshot.to_json())
                    .set("device_utilization", run.device_utilization.clone());
                runs.push(rj);
            }
            let mut mj = Json::obj();
            mj.set("mix", mix.name.as_str()).set("runs", runs);
            mixes.push(mj);
        }
        let mut j = Json::obj();
        j.set("bench", "serving").set("config", config).set("mixes", mixes);
        j
    }
}

/// Estimate the per-job service time (best-of-3 timed MSMs on one
/// thread — the same plan a `DeviceDesc::native(1)` worker runs) and
/// from it the fleet's aggregate capacity in jobs/s.
pub fn calibrate(msm_size: usize, devices: usize) -> (f64, f64) {
    let w = points::workload::<Bn254G1>(msm_size, 7);
    let cfg = MsmConfig::default();
    std::hint::black_box(msm::parallel::msm(&w.points, &w.scalars, &cfg, 1)); // warm-up
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(msm::parallel::msm(&w.points, &w.scalars, &cfg, 1));
        best = best.min(t0.elapsed().as_secs_f64());
    }
    let per_job = best.max(1e-6);
    (per_job, devices as f64 / per_job)
}

/// Draw a Poisson arrival schedule: exponential inter-arrival gaps at
/// `rate` jobs/s until `duration_s` is exhausted. `rng.f64()` is in
/// `[0, 1)`, so `1 - u` never hits the log singularity.
fn arrival_times(rng: &mut Rng, rate: f64, duration_s: f64) -> Vec<f64> {
    let mut out = Vec::new();
    if rate <= 0.0 {
        return out;
    }
    let mut t = 0.0;
    loop {
        let u = rng.f64();
        t += -(1.0 - u).ln() / rate;
        if t >= duration_s {
            break;
        }
        out.push(t);
    }
    out
}

/// Exact percentile of a sorted sample (nearest-rank; 0 when empty).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Drive one (mix, multiplier) run against a fresh fleet. A new
/// coordinator per run means no queue state or service-time EMA leaks
/// across sweep points.
fn run_one(
    cfg: &LoadgenConfig,
    mix: &TenantMix,
    multiplier: f64,
    per_job_s: f64,
    capacity: f64,
) -> RunStats {
    let mut registry = PointSetRegistry::<Bn254G1>::new();
    let ps = registry.register(points::generate_points_walk::<Bn254G1>(cfg.msm_size, 11));
    let fleet: Vec<DeviceDesc<Bn254G1>> =
        (0..cfg.devices.max(1)).map(|_| DeviceDesc::<Bn254G1>::native(1)).collect();
    let coord = Coordinator::start(
        CoordinatorConfig { admission: cfg.admission, ..Default::default() },
        fleet,
        registry,
    );
    for t in &mix.tenants {
        if let Some(share) = t.quota_capacity_share {
            coord.set_tenant_quota(t.tenant, Quota::per_second(share * capacity));
        }
    }

    // Pre-draw the whole arrival schedule: one forked stream per tenant
    // (keyed by tenant id, so adding a tenant never perturbs another's
    // schedule), merged into one time-ordered event list.
    let mut root = Rng::new(cfg.seed);
    let mut events: Vec<(f64, usize)> = Vec::new();
    for (ti, t) in mix.tenants.iter().enumerate() {
        let rate = t.share * capacity * multiplier;
        let mut stream = root.fork(t.tenant.0.wrapping_add(1));
        for at in arrival_times(&mut stream, rate, cfg.duration_s) {
            events.push((at, ti));
        }
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut payloads = Vec::with_capacity(SCALAR_POOL);
    for i in 0..SCALAR_POOL {
        let bits = Bn254G1::SCALAR_BITS.min(256);
        payloads.push(Arc::new(points::generate_scalars(
            cfg.msm_size,
            bits,
            cfg.seed.wrapping_add(0x5ca1a5 + i as u64),
        )));
    }

    // Completions are collected off-thread so the submit loop stays
    // open-loop AND the admission tier's service-time estimator (fed by
    // `ServedJob::recv`) updates live — that estimator is what paces
    // the pump and lets backlogs form in the lanes under overload.
    let (job_tx, job_rx) = mpsc::channel::<ServedJob<Bn254G1>>();
    let collector = thread::spawn(move || {
        let mut lat: [Vec<f64>; LANES] = std::array::from_fn(|_| Vec::new());
        while let Ok(job) = job_rx.recv() {
            let lane = job.lane();
            if let Ok(res) = job.recv() {
                if res.error.is_none() {
                    lat[lane.index()].push(res.service_s);
                }
            }
        }
        lat
    });

    let start = Instant::now();
    for (i, &(at, ti)) in events.iter().enumerate() {
        let target = Duration::from_secs_f64(at);
        let elapsed = start.elapsed();
        if target > elapsed {
            thread::sleep(target - elapsed);
        }
        let t = &mix.tenants[ti];
        let deadline = t.deadline_service_mult.map(|m| Duration::from_secs_f64(m * per_job_s));
        let scalars = payloads[i % payloads.len()].clone();
        // Sheds are booked by the admission tier itself; only admitted
        // jobs travel to the collector.
        if let Ok(job) = coord.submit_admitted(t.tenant, t.lane, deadline, ps, scalars) {
            let _ = job_tx.send(job);
        }
    }
    drop(job_tx);
    let mut lat = collector.join().expect("loadgen collector panicked");

    let snapshot = coord.admission_snapshot();
    let device_utilization = coord.device_metrics.utilization();
    coord.shutdown();

    let mut lanes = Vec::with_capacity(LANES);
    for lane in Lane::ALL {
        let i = lane.index();
        let mut v = std::mem::take(&mut lat[i]);
        v.sort_by(f64::total_cmp);
        let mean = if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
        lanes.push(LaneStats {
            lane,
            offered: snapshot.offered[i],
            admitted: snapshot.admitted[i],
            shed: snapshot.shed[i],
            completed: snapshot.completed[i],
            failed: snapshot.failed[i],
            shed_rate: snapshot.shed_rate(lane),
            mean_s: mean,
            p50_s: percentile(&v, 0.50),
            p95_s: percentile(&v, 0.95),
            p99_s: percentile(&v, 0.99),
        });
    }
    let offered_total = snapshot.offered_total();
    RunStats {
        multiplier,
        offered_jobs_per_s: offered_total as f64 / cfg.duration_s,
        achieved_jobs_per_s: snapshot.completed_total() as f64 / cfg.duration_s,
        shed_rate: if offered_total == 0 {
            0.0
        } else {
            snapshot.shed_total() as f64 / offered_total as f64
        },
        lanes,
        snapshot,
        device_utilization,
    }
}

/// Run the full sweep: calibrate once, then every mix × multiplier on a
/// fresh coordinator each, collecting the [`ServingReport`].
pub fn run(cfg: &LoadgenConfig, mixes: &[TenantMix]) -> ServingReport {
    let (per_job_s, capacity) = calibrate(cfg.msm_size, cfg.devices.max(1));
    let mut out = Vec::with_capacity(mixes.len());
    for mix in mixes {
        let mut runs = Vec::with_capacity(cfg.multipliers.len());
        for &m in &cfg.multipliers {
            runs.push(run_one(cfg, mix, m, per_job_s, capacity));
        }
        out.push(MixStats { mix: mix.name.clone(), runs });
    }
    ServingReport {
        msm_size: cfg.msm_size,
        devices: cfg.devices.max(1),
        duration_s: cfg.duration_s,
        seed: cfg.seed,
        calibrated_job_s: per_job_s,
        capacity_jobs_per_s: capacity,
        mixes: out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A short two-point sweep over both built-in mixes: counters must
    /// reconcile exactly, and under 3× overload the flood mix must shed
    /// best-effort work while interactive jobs still complete.
    #[test]
    fn sweep_reconciles_and_sheds_besteffort_under_overload() {
        let cfg = LoadgenConfig {
            msm_size: 256,
            devices: 1,
            duration_s: 0.25,
            multipliers: vec![0.5, 3.0],
            seed: 42,
            admission: AdmissionConfig::default(),
        };
        let report = run(&cfg, &default_mixes());
        assert_eq!(report.mixes.len(), 2);
        for mix in &report.mixes {
            assert_eq!(mix.runs.len(), 2);
            for r in &mix.runs {
                let s = &r.snapshot;
                assert_eq!(s.offered_total(), s.admitted_total() + s.shed_total());
                assert_eq!(s.admitted_total(), s.completed_total() + s.failed_total());
            }
        }
        let flood = &report.mixes[1];
        assert_eq!(flood.mix, "besteffort-flood");
        let over = &flood.runs[1];
        let be = &over.lanes[Lane::BestEffort.index()];
        let ia = &over.lanes[Lane::Interactive.index()];
        assert!(be.shed > 0, "best-effort must shed under 3x overload: {over:?}");
        assert!(ia.completed > 0, "interactive must still complete: {over:?}");

        let j = report.to_json();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("serving"));
        assert_eq!(j.get("mixes").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
    }

    /// The percentile helper is nearest-rank exact.
    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    /// Arrival schedules are deterministic in the seed and scale with
    /// the rate.
    #[test]
    fn arrivals_deterministic_and_rate_scaled() {
        let mut a = Rng::new(9).fork(1);
        let mut b = Rng::new(9).fork(1);
        let xs = arrival_times(&mut a, 1000.0, 1.0);
        let ys = arrival_times(&mut b, 1000.0, 1.0);
        assert_eq!(xs, ys);
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "arrivals must be monotone");
        // ~1000 expected; Poisson stddev ~32 — 5 sigma bounds.
        assert!((840..1160).contains(&xs.len()), "got {} arrivals", xs.len());
        let mut c = Rng::new(9).fork(2);
        let slow = arrival_times(&mut c, 10.0, 1.0);
        assert!(slow.len() < xs.len() / 10);
    }
}
