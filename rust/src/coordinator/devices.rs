//! Device backends: where an MSM job actually runs.
//!
//! * [`DeviceBackend::Native`] — this crate's chunk-parallel MSM runtime
//!   (point-partitioned threads, `msm::chunked` — the CPU of Table IX);
//! * [`DeviceBackend::SimFpga`] — bit-exact native compute **plus** the
//!   SAB model's virtual latency: results are real, reported timing is the
//!   modeled accelerator's (how every Table IX FPGA row is produced);
//! * [`DeviceBackend::Engine`] — the PJRT UDA engine (real offloaded
//!   compute through the AOT artifact). PJRT handles are thread-pinned
//!   (`!Send` — Rc/raw pointers inside the xla crate), so the backend
//!   carries a **factory** and each worker thread constructs its engine
//!   locally at startup — mirroring the one-bitstream-per-board reality.

use super::request::PointSetId;
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::fpga::{SabConfig, SabModel};
use crate::msm::partial::{self, ShardSpec};
use crate::msm::{self, MsmConfig, PrecompTable};
use anyhow::anyhow;
use crate::runtime::{msm_engine, EngineCurve, UdaEngine};
use crate::util::Stopwatch;
use std::collections::HashMap;
use std::sync::Arc;

/// Thread-local MSM executor built from an [`DeviceBackend::Engine`]
/// factory (deliberately not `Send`: PJRT state stays on its thread).
pub trait EngineHolder<C: CurveParams> {
    /// Run one MSM on the engine.
    fn msm(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        cfg: &MsmConfig,
    ) -> anyhow::Result<Jacobian<C>>;
}

impl<C: EngineCurve> EngineHolder<C> for UdaEngine<C> {
    fn msm(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        cfg: &MsmConfig,
    ) -> anyhow::Result<Jacobian<C>> {
        msm_engine::msm_engine(self, points, scalars, cfg).map(|(p, _)| p)
    }
}

/// Constructor for a thread-local engine.
pub type EngineFactory<C> =
    Box<dyn FnOnce() -> anyhow::Result<Box<dyn EngineHolder<C>>> + Send>;

/// Execution backend of one device slot (the movable description).
pub enum DeviceBackend<C: CurveParams> {
    /// Host CPU, `threads`-way parallel Pippenger.
    Native {
        /// OS threads per MSM.
        threads: usize,
    },
    /// Modeled FPGA: native compute, virtual (modeled) device time.
    SimFpga {
        /// The accelerator build whose timing is reported.
        model: SabModel,
    },
    /// PJRT UDA engine, constructed on the worker thread.
    Engine {
        /// Deferred constructor (PJRT state is thread-pinned).
        factory: EngineFactory<C>,
    },
}

/// Descriptor of one device (moved into its worker thread).
pub struct DeviceDesc<C: CurveParams> {
    /// Display name for logs and metrics.
    pub name: String,
    /// Where this device's MSMs execute.
    pub backend: DeviceBackend<C>,
    /// DDR byte budget for resident point sets.
    pub ddr_capacity: u64,
    /// The plan config single (unsharded) jobs run with on this device.
    pub msm_cfg: MsmConfig,
}

impl<C: CurveParams> DeviceDesc<C> {
    /// A host-CPU device with `threads`-way window parallelism.
    pub fn native(threads: usize) -> Self {
        DeviceDesc {
            name: format!("cpu{threads}"),
            backend: DeviceBackend::Native { threads },
            ddr_capacity: u64::MAX, // host memory: effectively unbounded here
            msm_cfg: MsmConfig::default(),
        }
    }

    /// A modeled-FPGA device (bit-exact native compute, modeled timing).
    pub fn sim_fpga(cfg: SabConfig, ddr_capacity: u64) -> Self {
        DeviceDesc {
            name: format!("fpga-{}-s{}", cfg.curve.name(), cfg.scaling),
            backend: DeviceBackend::SimFpga { model: SabModel::new(cfg) },
            ddr_capacity,
            msm_cfg: MsmConfig::default(),
        }
    }

    /// A PJRT-engine device loading the curve's artifact from the default
    /// manifest (construction deferred to the worker thread).
    pub fn engine_default<E: EngineCurve>(ddr_capacity: u64) -> DeviceDesc<E> {
        DeviceDesc {
            name: format!("engine-{}", E::MANIFEST_KEY),
            backend: DeviceBackend::Engine {
                factory: Box::new(|| {
                    let ctx = crate::runtime::PjrtContext::cpu()?;
                    let manifest = crate::runtime::ArtifactManifest::load(
                        &crate::runtime::artifact::default_dir(),
                    )?;
                    let engine = UdaEngine::<E>::load(&ctx, &manifest)?;
                    Ok(Box::new(engine) as Box<dyn EngineHolder<E>>)
                }),
            },
            ddr_capacity,
            msm_cfg: MsmConfig::new(8, Default::default()),
        }
    }

    /// Materialize into a runnable device (constructs engine state on the
    /// *calling* thread — do this from the owning worker).
    pub fn into_runtime(self) -> anyhow::Result<RunningDevice<C>> {
        let backend = match self.backend {
            DeviceBackend::Native { threads } => RunningBackend::Native { threads },
            DeviceBackend::SimFpga { model } => RunningBackend::SimFpga { model },
            DeviceBackend::Engine { factory } => RunningBackend::Engine { engine: factory()? },
        };
        Ok(RunningDevice { name: self.name, backend, msm_cfg: self.msm_cfg })
    }
}

/// The thread-local runnable form.
pub struct RunningDevice<C: CurveParams> {
    /// Display name (copied from the descriptor).
    pub name: String,
    backend: RunningBackend<C>,
    /// The plan config single jobs run with.
    pub msm_cfg: MsmConfig,
}

enum RunningBackend<C: CurveParams> {
    Native { threads: usize },
    SimFpga { model: SabModel },
    Engine { engine: Box<dyn EngineHolder<C>> },
}

impl<C: CurveParams> RunningDevice<C> {
    /// Execute an MSM; returns (result, wall seconds, modeled device
    /// seconds).
    pub fn execute(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
    ) -> anyhow::Result<(Jacobian<C>, f64, f64)> {
        let sw = Stopwatch::start();
        match &self.backend {
            RunningBackend::Native { threads } => {
                let out = msm::execute(
                    msm::Backend::Chunked { threads: *threads },
                    points,
                    scalars,
                    &self.msm_cfg,
                );
                let wall = sw.secs();
                Ok((out, wall, wall))
            }
            RunningBackend::SimFpga { model } => {
                let out = msm::execute(
                    msm::Backend::Chunked { threads: msm::parallel::default_threads() },
                    points,
                    scalars,
                    &self.msm_cfg,
                );
                let wall = sw.secs();
                let device = model.time_msm(points.len() as u64).total_s();
                Ok((out, wall, device))
            }
            RunningBackend::Engine { engine } => {
                let out = engine.msm(points, scalars, &self.msm_cfg)?;
                let wall = sw.secs();
                Ok((out, wall, wall))
            }
        }
    }

    /// Execute one shard of a sharded MSM under the group's uniform `cfg`
    /// (window-range shards need identical window boundaries on every
    /// device, so the device's own `msm_cfg` is deliberately ignored).
    /// Returns (partial, wall seconds, modeled device seconds).
    pub fn execute_shard(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        spec: &ShardSpec,
        cfg: &MsmConfig,
    ) -> anyhow::Result<(Jacobian<C>, f64, f64)> {
        let sw = Stopwatch::start();
        match &self.backend {
            RunningBackend::Native { threads } => {
                let out = partial::execute_shard(
                    msm::Backend::Chunked { threads: *threads },
                    points,
                    scalars,
                    cfg,
                    spec,
                );
                let wall = sw.secs();
                Ok((out, wall, wall))
            }
            RunningBackend::SimFpga { model } => {
                let out = partial::execute_shard(
                    msm::Backend::Chunked { threads: msm::parallel::default_threads() },
                    points,
                    scalars,
                    cfg,
                    spec,
                );
                let wall = sw.secs();
                // window indices in `spec` live in the *group's* plan, so
                // the fraction must use its window count, not the model's
                let plan_windows = msm::MsmPlan::for_curve::<C>(cfg).windows;
                let device = model.time_shard(points.len() as u64, spec, plan_windows);
                Ok((out, wall, device))
            }
            RunningBackend::Engine { engine } => match *spec {
                ShardSpec::PointChunk { lo, hi } => {
                    let out = engine.msm(&points[lo..hi], &scalars[lo..hi], cfg)?;
                    let wall = sw.secs();
                    Ok((out, wall, wall))
                }
                ShardSpec::WindowRange { .. } => Err(anyhow!(
                    "window-range shards are not supported on the engine backend \
                     (it owns the whole window loop)"
                )),
            },
        }
    }
}

/// Registry of base-point sets shared across devices (host-side master
/// copy; device DDR residency is tracked in the point cache). Also the
/// home of **fixed-base precompute tables** ([`PrecompTable`]): built once
/// per (set, config) with [`Self::build_tables`], served to executors via
/// [`Self::tables_for`], and evictable mid-run ([`Self::evict_tables`]) —
/// after which selection falls back to a live-point backend with
/// bit-identical results.
pub struct PointSetRegistry<C: CurveParams> {
    sets: HashMap<PointSetId, Arc<Vec<Affine<C>>>>,
    tables: HashMap<PointSetId, Arc<PrecompTable<C>>>,
    next: u64,
}

impl<C: CurveParams> Default for PointSetRegistry<C> {
    fn default() -> Self {
        Self::new()
    }
}

impl<C: CurveParams> PointSetRegistry<C> {
    /// Empty registry.
    pub fn new() -> Self {
        PointSetRegistry { sets: HashMap::new(), tables: HashMap::new(), next: 1 }
    }

    /// Register a point set; returns its id.
    pub fn register(&mut self, points: Vec<Affine<C>>) -> PointSetId {
        let id = PointSetId(self.next);
        self.next += 1;
        self.sets.insert(id, Arc::new(points));
        id
    }

    /// Shared handle to a registered set.
    pub fn get(&self, id: PointSetId) -> Option<Arc<Vec<Affine<C>>>> {
        self.sets.get(&id).cloned()
    }

    /// DDR footprint of a set (paper layout: affine coordinates).
    pub fn bytes_of(&self, id: PointSetId) -> u64 {
        self.sets.get(&id).map(|s| s.len() as u64 * C::AFFINE_BYTES).unwrap_or(0)
    }

    /// DDR footprint of a set under an MSM config: a GLV config on a curve
    /// with endomorphism parameters keeps the endo-expanded `(P, φ(P))`
    /// set resident — double the bytes (the residency budget the router
    /// and point cache must admit against).
    pub fn bytes_for(&self, id: PointSetId, cfg: &MsmConfig) -> u64 {
        let active = match cfg.decomposition {
            crate::msm::Decomposition::Glv if C::glv().is_some() => {
                crate::msm::Decomposition::Glv
            }
            _ => crate::msm::Decomposition::Full,
        };
        super::pointcache::resident_bytes(self.bytes_of(id), active)
    }

    /// Build (or rebuild) the fixed-base tables for a registered set
    /// under `cfg` — the one-time doubling-chain cost a proving service
    /// amortizes over every later MSM. Returns the table footprint in
    /// bytes (0 for an unknown id). Tables are keyed per set; rebuilding
    /// under a different config replaces the old table.
    pub fn build_tables(&mut self, id: PointSetId, cfg: &MsmConfig) -> u64 {
        let Some(points) = self.sets.get(&id).cloned() else {
            return 0;
        };
        let table = Arc::new(PrecompTable::build(points.as_slice(), cfg));
        let bytes = table.bytes();
        self.tables.insert(id, table);
        bytes
    }

    /// The resident tables for a set **iff** they can serve `cfg` (window
    /// width, slicing, reduction, and decomposition all match the build
    /// config) — `None` otherwise, and the caller falls back to a
    /// live-point backend (`msm::Backend::pick_with_tables` keys its
    /// selection on exactly this `is_some()`).
    pub fn tables_for(&self, id: PointSetId, cfg: &MsmConfig) -> Option<Arc<PrecompTable<C>>> {
        self.tables.get(&id).filter(|t| t.compatible_with(cfg)).cloned()
    }

    /// Drop a set's tables (mid-run eviction under memory pressure);
    /// returns the bytes released. Later MSMs over the set fall back to
    /// live-point backends bit-identically.
    pub fn evict_tables(&mut self, id: PointSetId) -> u64 {
        self.tables.remove(&id).map(|t| t.bytes()).unwrap_or(0)
    }

    /// Footprint of a set's resident tables (0 when none are built).
    pub fn table_bytes_of(&self, id: PointSetId) -> u64 {
        self.tables.get(&id).map(|t| t.bytes()).unwrap_or(0)
    }

    /// The DDR residency a device must admit to serve `cfg` from this
    /// registry: the table footprint when compatible tables are resident
    /// (the expanded set × window count), else the live-point footprint
    /// of [`Self::bytes_for`].
    pub fn residency_for(&self, id: PointSetId, cfg: &MsmConfig) -> u64 {
        match self.tables_for(id, cfg) {
            Some(t) => t.bytes(),
            None => self.bytes_for(id, cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bn254G1};
    use crate::fpga::CurveId;

    #[test]
    fn native_device_executes() {
        let d = DeviceDesc::<Bn254G1>::native(2).into_runtime().unwrap();
        let w = points::workload::<Bn254G1>(64, 201);
        let (out, wall, dev) = d.execute(&w.points, &w.scalars).unwrap();
        assert!(out.eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        assert_eq!(wall, dev);
    }

    #[test]
    fn sim_fpga_reports_model_time() {
        let d = DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 34)
            .into_runtime()
            .unwrap();
        let w = points::workload::<Bn254G1>(128, 202);
        let (out, _wall, dev) = d.execute(&w.points, &w.scalars).unwrap();
        assert!(out.eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        // modeled time for 128 points ≈ call overhead ≈ 9–20 ms
        assert!(dev > 0.005 && dev < 0.05, "modeled {dev}");
    }

    #[test]
    fn device_shards_merge_bit_exact() {
        let d = DeviceDesc::<Bn254G1>::native(2).into_runtime().unwrap();
        let w = points::workload::<Bn254G1>(96, 204);
        let cfg = MsmConfig::default();
        let want = msm::naive::msm(&w.points, &w.scalars);
        let windows = crate::msm::MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        for specs in [partial::chunk_specs(96, 3), partial::window_specs(windows, 3)] {
            let mut parts: Vec<crate::msm::PartialMsm<Bn254G1>> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let (out, wall, dev) = d.execute_shard(&w.points, &w.scalars, s, &cfg).unwrap();
                    assert!(wall >= 0.0 && dev >= 0.0);
                    crate::msm::PartialMsm { index: i, spec: *s, output: out }
                })
                .collect();
            assert!(partial::merge(&mut parts).eq_point(&want), "{specs:?}");
        }
    }

    #[test]
    fn sim_fpga_shard_time_scales_with_shape() {
        let d = DeviceDesc::<Bn254G1>::sim_fpga(SabConfig::paper(CurveId::Bn254, 2), 1 << 34)
            .into_runtime()
            .unwrap();
        let w = points::workload::<Bn254G1>(128, 205);
        let cfg = MsmConfig::default();
        let windows = crate::msm::MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        let (_, _, full) = d.execute(&w.points, &w.scalars).unwrap();
        let half_spec = ShardSpec::WindowRange { lo: 0, hi: windows / 2 };
        let (_, _, half) = d.execute_shard(&w.points, &w.scalars, &half_spec, &cfg).unwrap();
        assert!(half < full, "half the windows must model faster: {half} vs {full}");
        let chunk_spec = ShardSpec::PointChunk { lo: 0, hi: 64 };
        let (_, _, chunk) = d.execute_shard(&w.points, &w.scalars, &chunk_spec, &cfg).unwrap();
        assert!(chunk > 0.0 && chunk <= full);
    }

    #[test]
    fn registry_roundtrip() {
        let mut r = PointSetRegistry::<Bn254G1>::new();
        let pts = points::generate_points_walk::<Bn254G1>(10, 203);
        let id = r.register(pts);
        assert_eq!(r.get(id).unwrap().len(), 10);
        assert_eq!(r.bytes_of(id), 640);
        assert!(r.get(PointSetId(999)).is_none());
    }

    #[test]
    fn registry_glv_footprint_doubles() {
        let mut r = PointSetRegistry::<Bn254G1>::new();
        let id = r.register(points::generate_points_walk::<Bn254G1>(10, 206));
        let cfg = MsmConfig::default();
        assert_eq!(r.bytes_for(id, &cfg), 640);
        assert_eq!(r.bytes_for(id, &cfg.glv()), 1280);
        assert_eq!(r.bytes_for(PointSetId(999), &cfg.glv()), 0);
    }

    #[test]
    fn registry_tables_roundtrip_and_evict() {
        let mut r = PointSetRegistry::<Bn254G1>::new();
        let id = r.register(points::generate_points_walk::<Bn254G1>(16, 207));
        let cfg = MsmConfig::new(8, Default::default());
        assert!(r.tables_for(id, &cfg).is_none());
        assert_eq!(r.table_bytes_of(id), 0);
        assert_eq!(r.residency_for(id, &cfg), r.bytes_for(id, &cfg));
        let bytes = r.build_tables(id, &cfg);
        let t = r.tables_for(id, &cfg).expect("tables resident");
        assert_eq!(t.bytes(), bytes);
        assert_eq!(r.table_bytes_of(id), bytes);
        assert_eq!(r.residency_for(id, &cfg), bytes);
        // footprint = expanded set × windows — the pointcache accounting
        assert_eq!(
            bytes,
            super::super::pointcache::table_resident_bytes(
                r.bytes_of(id),
                crate::msm::Decomposition::Full,
                t.windows(),
            )
        );
        // an incompatible config (or unknown set) is never served
        assert!(r.tables_for(id, &cfg.glv()).is_none());
        assert!(r.tables_for(PointSetId(999), &cfg).is_none());
        assert_eq!(r.build_tables(PointSetId(999), &cfg), 0);
        assert_eq!(r.evict_tables(id), bytes);
        assert!(r.tables_for(id, &cfg).is_none());
        assert_eq!(r.evict_tables(id), 0);
    }

    #[test]
    fn mid_run_table_eviction_falls_back_bit_identical() {
        // satellite regression: the precomputed backend wins while tables
        // are resident; when the registry evicts them mid-run, selection
        // falls back and the same inputs still produce the same point
        let mut r = PointSetRegistry::<Bn254G1>::new();
        let w = points::workload::<Bn254G1>(200, 208);
        let id = r.register(w.points.clone());
        let cfg = MsmConfig::new(8, Default::default()).glv();
        r.build_tables(id, &cfg);
        let windows = crate::msm::MsmPlan::for_curve::<Bn254G1>(&cfg).windows;
        let resident = r.tables_for(id, &cfg);
        let backend = msm::Backend::pick_with_tables(200, windows, 8, resident.is_some());
        assert_eq!(backend, msm::Backend::Precomputed);
        let first = resident.expect("resident").msm(&w.scalars);
        // eviction lands between this MSM and the next over the same set
        r.evict_tables(id);
        assert!(r.tables_for(id, &cfg).is_none());
        let fallback = msm::Backend::pick_with_tables(200, windows, 8, false);
        assert_ne!(fallback, msm::Backend::Precomputed);
        let live = r.get(id).expect("set still registered");
        let second = msm::execute(fallback, live.as_slice(), &w.scalars, &cfg);
        assert!(first.eq_point(&second));
    }
}
