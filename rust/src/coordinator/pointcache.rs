//! Point-set residency manager.
//!
//! The paper moves the base points to FPGA DDR once per proof lifetime
//! (§IV-A: storage "can be in the range of tens of GBs") and then sends
//! only scalars per call. A proving service juggles many circuits whose
//! point sets compete for device DDR; this cache tracks residency per
//! device with LRU eviction under a byte budget — the L3 analogue of a
//! KV-cache manager in an LLM server.

use super::request::PointSetId;
use crate::msm::Decomposition;
use std::collections::HashMap;

/// DDR bytes a point set occupies under a scalar decomposition mode: the
/// GLV fast path keeps both `P` and the endomorphism image `φ(P)` resident
/// (the device streams the expanded set every window pass), doubling the
/// footprint. Routing and admission must budget with this, not the raw
/// set size — see `devices::PointSetRegistry::bytes_for`. The factor is
/// [`Decomposition::expansion_factor`], shared with the FPGA model.
pub fn resident_bytes(base_bytes: u64, decomposition: Decomposition) -> u64 {
    base_bytes.saturating_mul(decomposition.expansion_factor())
}

/// DDR bytes a point set occupies with **fixed-base tables** resident:
/// the table keeps `windows` shifted copies (`2^(j·k)·B` per window `j`)
/// of the decomposition-expanded set, so the footprint is
/// [`resident_bytes`] × window count. `msm::precomp::PrecompTable::bytes`
/// reports the same number from the built table, and the FPGA what-if
/// (`fpga::sab`) charges DDR with it when its table knob is on.
pub fn table_resident_bytes(base_bytes: u64, decomposition: Decomposition, windows: u32) -> u64 {
    resident_bytes(base_bytes, decomposition).saturating_mul(u64::from(windows))
}

/// DDR bytes a **streamed** point set occupies: only the chunk working set
/// is ever resident, so the footprint is the chunk's share of the full set
/// under the same decomposition expansion as [`resident_bytes`] — capped at
/// the fully-resident footprint (a chunk larger than the set degenerates to
/// the resident case). This is what admission should budget when the host
/// feeds a device through `msm::stream` instead of uploading the whole set:
/// a set that is [`Admission::TooLarge`] resident can still be served
/// streamed at `chunk_bytes` of the full `base_bytes`.
pub fn streamed_resident_bytes(
    base_bytes: u64,
    chunk_bytes: u64,
    decomposition: Decomposition,
) -> u64 {
    resident_bytes(chunk_bytes.min(base_bytes), decomposition)
}

/// Residency state for one device's DDR.
#[derive(Debug)]
pub struct DeviceDdr {
    capacity_bytes: u64,
    used_bytes: u64,
    /// point set → (bytes, last-use tick)
    resident: HashMap<PointSetId, (u64, u64)>,
    tick: u64,
}

/// Result of a residency request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Already resident — zero upload cost.
    Hit,
    /// Admitted after uploading `upload_bytes` (and evicting `evicted`
    /// sets).
    Miss {
        /// Bytes uploaded to admit the set.
        upload_bytes: u64,
        /// Resident sets evicted to make room.
        evicted: usize,
    },
    /// Cannot fit even after evicting everything.
    TooLarge,
}

impl DeviceDdr {
    /// Empty DDR with a byte budget.
    pub fn new(capacity_bytes: u64) -> Self {
        DeviceDdr { capacity_bytes, used_bytes: 0, resident: HashMap::new(), tick: 0 }
    }

    /// Is the set currently resident?
    pub fn is_resident(&self, id: PointSetId) -> bool {
        self.resident.contains_key(&id)
    }

    /// Bytes currently occupied.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Number of resident sets.
    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Touch-or-admit a point set of `bytes`; LRU-evicts as needed.
    ///
    /// A set can be re-admitted at a **different size** than it was booked
    /// at — mixed-config fleets do this when one path budgets the plain
    /// set, another the GLV endo-expanded (doubled) one, and a third the
    /// table-expanded footprint ([`table_resident_bytes`] — the same set
    /// grown by the window count when fixed-base tables move on device).
    /// A booking that
    /// already covers `bytes` is a plain [`Admission::Hit`] (the larger
    /// footprint stays resident); a larger request *grows* the booking in
    /// place, evicting other sets as needed and reporting only the delta
    /// as upload (the missing φ(P) half); a growth that can never fit
    /// returns [`Admission::TooLarge`] and leaves the existing booking
    /// untouched — routers fall through to another device.
    pub fn admit(&mut self, id: PointSetId, bytes: u64) -> Admission {
        self.tick += 1;
        if let Some(&(booked, _)) = self.resident.get(&id) {
            if booked >= bytes {
                self.resident.get_mut(&id).expect("just read").1 = self.tick;
                return Admission::Hit;
            }
            // grow the booking to the larger footprint
            if bytes > self.capacity_bytes {
                return Admission::TooLarge;
            }
            let delta = bytes - booked;
            // refresh the tick first so the eviction loop never picks `id`
            self.resident.get_mut(&id).expect("just read").1 = self.tick;
            let evicted = self.evict_until_fits(delta);
            let entry = self.resident.get_mut(&id).expect("still resident");
            entry.0 = bytes;
            self.used_bytes += delta;
            return Admission::Miss { upload_bytes: delta, evicted };
        }
        if bytes > self.capacity_bytes {
            return Admission::TooLarge;
        }
        let evicted = self.evict_until_fits(bytes);
        self.resident.insert(id, (bytes, self.tick));
        self.used_bytes += bytes;
        Admission::Miss { upload_bytes: bytes, evicted }
    }

    /// Evict least-recently-used sets until `incoming` more bytes fit.
    /// The caller guarantees feasibility (incoming ≤ capacity, minus any
    /// booking it is about to keep).
    fn evict_until_fits(&mut self, incoming: u64) -> usize {
        let mut evicted = 0;
        while self.used_bytes + incoming > self.capacity_bytes {
            let lru = self
                .resident
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k)
                .expect("used>0 implies nonempty");
            let (b, _) = self.resident.remove(&lru).unwrap();
            self.used_bytes -= b;
            evicted += 1;
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_admit() {
        let mut d = DeviceDdr::new(1000);
        assert_eq!(d.admit(PointSetId(1), 400), Admission::Miss { upload_bytes: 400, evicted: 0 });
        assert_eq!(d.admit(PointSetId(1), 400), Admission::Hit);
        assert_eq!(d.used_bytes(), 400);
    }

    #[test]
    fn lru_eviction_order() {
        let mut d = DeviceDdr::new(1000);
        d.admit(PointSetId(1), 400);
        d.admit(PointSetId(2), 400);
        d.admit(PointSetId(1), 400); // touch 1 → 2 becomes LRU
        let adm = d.admit(PointSetId(3), 400);
        assert_eq!(adm, Admission::Miss { upload_bytes: 400, evicted: 1 });
        assert!(d.is_resident(PointSetId(1)));
        assert!(!d.is_resident(PointSetId(2)));
        assert!(d.is_resident(PointSetId(3)));
    }

    #[test]
    fn too_large_rejected() {
        let mut d = DeviceDdr::new(100);
        assert_eq!(d.admit(PointSetId(1), 101), Admission::TooLarge);
        assert_eq!(d.resident_count(), 0);
    }

    #[test]
    fn rebooking_grows_shrinks_and_refuses_correctly() {
        let mut d = DeviceDdr::new(1000);
        assert_eq!(d.admit(PointSetId(1), 400), Admission::Miss { upload_bytes: 400, evicted: 0 });
        // a smaller request is a plain hit — the larger footprint stays
        assert_eq!(d.admit(PointSetId(1), 200), Admission::Hit);
        assert_eq!(d.used_bytes(), 400);
        // a larger request (e.g. the GLV-expanded set) grows the booking
        // in place, uploading only the delta
        assert_eq!(d.admit(PointSetId(1), 800), Admission::Miss { upload_bytes: 400, evicted: 0 });
        assert_eq!(d.used_bytes(), 800);
        assert_eq!(d.admit(PointSetId(1), 800), Admission::Hit);
        // growth evicts OTHER sets, never the growing one
        let mut d = DeviceDdr::new(1000);
        d.admit(PointSetId(1), 400);
        d.admit(PointSetId(2), 500);
        assert_eq!(d.admit(PointSetId(1), 800), Admission::Miss { upload_bytes: 400, evicted: 1 });
        assert!(d.is_resident(PointSetId(1)));
        assert!(!d.is_resident(PointSetId(2)));
        assert_eq!(d.used_bytes(), 800);
        // an impossible growth refuses and leaves the booking untouched
        assert_eq!(d.admit(PointSetId(1), 1001), Admission::TooLarge);
        assert!(d.is_resident(PointSetId(1)));
        assert_eq!(d.used_bytes(), 800);
    }

    #[test]
    fn resident_bytes_doubles_under_glv() {
        assert_eq!(resident_bytes(640, Decomposition::Full), 640);
        assert_eq!(resident_bytes(640, Decomposition::Glv), 1280);
        assert_eq!(resident_bytes(u64::MAX, Decomposition::Glv), u64::MAX); // saturates
        // an endo-expanded set that no longer fits must be rejected
        let mut d = DeviceDdr::new(1000);
        let glv_bytes = resident_bytes(640, Decomposition::Glv);
        assert_eq!(d.admit(PointSetId(1), glv_bytes), Admission::TooLarge);
        assert_eq!(
            d.admit(PointSetId(1), resident_bytes(400, Decomposition::Glv)),
            Admission::Miss { upload_bytes: 800, evicted: 0 }
        );
    }

    #[test]
    fn table_footprint_grow_reconciles_like_glv() {
        // the satellite fix under test: base → GLV 2× → tables ×windows
        // is one grow chain through `admit` — each step uploads only the
        // delta, growth evicts other sets LRU-first (never the growing
        // one), and an impossible step falls through with the booking
        // untouched
        let base = 100u64;
        let glv = resident_bytes(base, Decomposition::Glv);
        let tables = table_resident_bytes(base, Decomposition::Glv, 11);
        assert_eq!(tables, 2200);
        // GLV halves the windows but doubles the set: same product as a
        // full-width table at double the window count
        assert_eq!(table_resident_bytes(base, Decomposition::Full, 22), tables);
        assert_eq!(table_resident_bytes(u64::MAX, Decomposition::Glv, 11), u64::MAX);
        let mut d = DeviceDdr::new(2500);
        d.admit(PointSetId(9), 600); // bystander — the eventual LRU victim
        assert_eq!(d.admit(PointSetId(1), base), Admission::Miss { upload_bytes: 100, evicted: 0 });
        assert_eq!(d.admit(PointSetId(1), glv), Admission::Miss { upload_bytes: 100, evicted: 0 });
        // the table-expanded re-admission grows in place: delta upload
        // (the 10 missing columns), bystander evicted, grower kept
        assert_eq!(
            d.admit(PointSetId(1), tables),
            Admission::Miss { upload_bytes: 2000, evicted: 1 }
        );
        assert!(d.is_resident(PointSetId(1)));
        assert!(!d.is_resident(PointSetId(9)));
        assert_eq!(d.used_bytes(), 2200);
        // the larger booking serves every smaller view of the same set
        assert_eq!(d.admit(PointSetId(1), tables), Admission::Hit);
        assert_eq!(d.admit(PointSetId(1), glv), Admission::Hit);
        assert_eq!(d.admit(PointSetId(1), base), Admission::Hit);
        // a wider table that can never fit refuses, booking untouched —
        // the router falls through to another device
        let huge = table_resident_bytes(base, Decomposition::Glv, 22);
        assert!(huge > 2500);
        assert_eq!(d.admit(PointSetId(1), huge), Admission::TooLarge);
        assert!(d.is_resident(PointSetId(1)));
        assert_eq!(d.used_bytes(), 2200);
    }

    #[test]
    fn streamed_footprint_is_the_chunk_working_set() {
        // streaming budgets only the chunk's share of the set, under the
        // same decomposition expansion as the resident path
        assert_eq!(streamed_resident_bytes(10_000, 640, Decomposition::Full), 640);
        assert_eq!(streamed_resident_bytes(10_000, 640, Decomposition::Glv), 1280);
        // a chunk larger than the set degenerates to the resident footprint
        assert_eq!(
            streamed_resident_bytes(10_000, 20_000, Decomposition::Glv),
            resident_bytes(10_000, Decomposition::Glv)
        );
        assert_eq!(streamed_resident_bytes(u64::MAX, u64::MAX, Decomposition::Glv), u64::MAX);
        // a set too large to sit resident still admits streamed
        let mut d = DeviceDdr::new(1000);
        let full = resident_bytes(2000, Decomposition::Full);
        assert_eq!(d.admit(PointSetId(1), full), Admission::TooLarge);
        let streamed = streamed_resident_bytes(2000, 400, Decomposition::Full);
        assert_eq!(
            d.admit(PointSetId(1), streamed),
            Admission::Miss { upload_bytes: 400, evicted: 0 }
        );
        assert!(d.is_resident(PointSetId(1)));
    }

    #[test]
    fn multi_eviction() {
        let mut d = DeviceDdr::new(1000);
        d.admit(PointSetId(1), 300);
        d.admit(PointSetId(2), 300);
        d.admit(PointSetId(3), 300);
        let adm = d.admit(PointSetId(4), 900);
        assert_eq!(adm, Admission::Miss { upload_bytes: 900, evicted: 3 });
        assert_eq!(d.used_bytes(), 900);
    }
}
