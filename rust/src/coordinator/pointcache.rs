//! Point-set residency manager.
//!
//! The paper moves the base points to FPGA DDR once per proof lifetime
//! (§IV-A: storage "can be in the range of tens of GBs") and then sends
//! only scalars per call. A proving service juggles many circuits whose
//! point sets compete for device DDR; this cache tracks residency per
//! device with LRU eviction under a byte budget — the L3 analogue of a
//! KV-cache manager in an LLM server.

use super::request::PointSetId;
use std::collections::HashMap;

/// Residency state for one device's DDR.
#[derive(Debug)]
pub struct DeviceDdr {
    capacity_bytes: u64,
    used_bytes: u64,
    /// point set → (bytes, last-use tick)
    resident: HashMap<PointSetId, (u64, u64)>,
    tick: u64,
}

/// Result of a residency request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Already resident — zero upload cost.
    Hit,
    /// Admitted after uploading `upload_bytes` (and evicting `evicted`
    /// sets).
    Miss { upload_bytes: u64, evicted: usize },
    /// Cannot fit even after evicting everything.
    TooLarge,
}

impl DeviceDdr {
    pub fn new(capacity_bytes: u64) -> Self {
        DeviceDdr { capacity_bytes, used_bytes: 0, resident: HashMap::new(), tick: 0 }
    }

    pub fn is_resident(&self, id: PointSetId) -> bool {
        self.resident.contains_key(&id)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Touch-or-admit a point set of `bytes`; LRU-evicts as needed.
    pub fn admit(&mut self, id: PointSetId, bytes: u64) -> Admission {
        self.tick += 1;
        if let Some(entry) = self.resident.get_mut(&id) {
            entry.1 = self.tick;
            return Admission::Hit;
        }
        if bytes > self.capacity_bytes {
            return Admission::TooLarge;
        }
        let mut evicted = 0;
        while self.used_bytes + bytes > self.capacity_bytes {
            // evict the least-recently-used set
            let lru = self
                .resident
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k)
                .expect("used>0 implies nonempty");
            let (b, _) = self.resident.remove(&lru).unwrap();
            self.used_bytes -= b;
            evicted += 1;
        }
        self.resident.insert(id, (bytes, self.tick));
        self.used_bytes += bytes;
        Admission::Miss { upload_bytes: bytes, evicted }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_admit() {
        let mut d = DeviceDdr::new(1000);
        assert_eq!(d.admit(PointSetId(1), 400), Admission::Miss { upload_bytes: 400, evicted: 0 });
        assert_eq!(d.admit(PointSetId(1), 400), Admission::Hit);
        assert_eq!(d.used_bytes(), 400);
    }

    #[test]
    fn lru_eviction_order() {
        let mut d = DeviceDdr::new(1000);
        d.admit(PointSetId(1), 400);
        d.admit(PointSetId(2), 400);
        d.admit(PointSetId(1), 400); // touch 1 → 2 becomes LRU
        let adm = d.admit(PointSetId(3), 400);
        assert_eq!(adm, Admission::Miss { upload_bytes: 400, evicted: 1 });
        assert!(d.is_resident(PointSetId(1)));
        assert!(!d.is_resident(PointSetId(2)));
        assert!(d.is_resident(PointSetId(3)));
    }

    #[test]
    fn too_large_rejected() {
        let mut d = DeviceDdr::new(100);
        assert_eq!(d.admit(PointSetId(1), 101), Admission::TooLarge);
        assert_eq!(d.resident_count(), 0);
    }

    #[test]
    fn multi_eviction() {
        let mut d = DeviceDdr::new(1000);
        d.admit(PointSetId(1), 300);
        d.admit(PointSetId(2), 300);
        d.admit(PointSetId(3), 300);
        let adm = d.admit(PointSetId(4), 900);
        assert_eq!(adm, Admission::Miss { upload_bytes: 900, evicted: 3 });
        assert_eq!(d.used_bytes(), 900);
    }
}
