//! Job batcher: groups same-point-set jobs so a device runs them
//! back-to-back (the point set streams from DDR while the scalars change —
//! §IV-A's cheap path). A batch flushes when it reaches `max_batch` or its
//! oldest job has waited `max_wait`.

use super::request::{MsmJob, PointSetId};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates jobs per point set.
pub struct Batcher {
    policy: BatchPolicy,
    pending: HashMap<PointSetId, Vec<MsmJob>>,
    oldest: HashMap<PointSetId, Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: HashMap::new(), oldest: HashMap::new() }
    }

    /// Add a job; returns a full batch if this push filled one.
    pub fn push(&mut self, job: MsmJob) -> Option<(PointSetId, Vec<MsmJob>)> {
        let ps = job.point_set;
        let entry = self.pending.entry(ps).or_default();
        self.oldest.entry(ps).or_insert_with(Instant::now);
        entry.push(job);
        if entry.len() >= self.policy.max_batch {
            return self.take(ps);
        }
        None
    }

    /// Pop every batch whose oldest job exceeded the wait budget.
    pub fn expired(&mut self, now: Instant) -> Vec<(PointSetId, Vec<MsmJob>)> {
        let ready: Vec<PointSetId> = self
            .oldest
            .iter()
            .filter(|(_, &t)| now.duration_since(t) >= self.policy.max_wait)
            .map(|(&ps, _)| ps)
            .collect();
        ready.into_iter().filter_map(|ps| self.take(ps)).collect()
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<(PointSetId, Vec<MsmJob>)> {
        let keys: Vec<PointSetId> = self.pending.keys().copied().collect();
        keys.into_iter().filter_map(|ps| self.take(ps)).collect()
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    fn take(&mut self, ps: PointSetId) -> Option<(PointSetId, Vec<MsmJob>)> {
        self.oldest.remove(&ps);
        let jobs = self.pending.remove(&ps)?;
        if jobs.is_empty() {
            None
        } else {
            Some((ps, jobs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::JobId;
    use std::sync::Arc;

    fn job(id: u64, ps: u64) -> MsmJob {
        MsmJob {
            id: JobId(id),
            point_set: PointSetId(ps),
            scalars: Arc::new(vec![[id, 0, 0, 0]]),
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(9) });
        assert!(b.push(job(1, 5)).is_none());
        assert!(b.push(job(2, 5)).is_none());
        let (ps, jobs) = b.push(job(3, 5)).expect("full batch");
        assert_eq!(ps, PointSetId(5));
        assert_eq!(jobs.len(), 3);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn separate_point_sets_dont_mix() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(9) });
        assert!(b.push(job(1, 1)).is_none());
        assert!(b.push(job(2, 2)).is_none());
        assert_eq!(b.pending_jobs(), 2);
        let full = b.push(job(3, 1)).expect("set 1 fills");
        assert_eq!(full.1.len(), 2);
        assert_eq!(b.pending_jobs(), 1);
    }

    #[test]
    fn expiry_flushes_old_batches() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(job(1, 3));
        std::thread::sleep(Duration::from_millis(3));
        b.push(job(2, 4)); // fresh — wait, also >1ms by flush time? use now
        let now = Instant::now() + Duration::from_millis(2);
        let expired = b.expired(now);
        assert_eq!(expired.len(), 2);
    }

    #[test]
    fn drain_empties() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.push(job(1, 1));
        b.push(job(2, 2));
        let drained = b.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(b.pending_jobs(), 0);
        assert!(b.drain().is_empty());
    }
}
