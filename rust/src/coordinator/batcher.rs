//! Job batcher: groups same-point-set jobs so a device runs them
//! back-to-back (the point set streams from DDR while the scalars change —
//! §IV-A's cheap path). A batch flushes when it reaches `max_batch` or its
//! oldest job has waited `max_wait`.
//!
//! **Shard awareness**: sub-jobs of one shard group (see
//! [`super::shard::ShardGroup`]) batch under their own key, separate from
//! plain jobs of the same point set, and a group flushes in **exactly one
//! batch** — it is released the moment its last member arrives, `max_batch`
//! never cuts it mid-group, and `expired`/`drain` only ever emit it whole.
//! Splitting a group across two flushes would let the router place its
//! halves independently and break the group's atomic complete-or-fail
//! contract downstream.

use super::request::{MsmJob, PointSetId};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush a plain batch at this many jobs.
    pub max_batch: usize,
    /// Flush once the oldest member has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pending-batch key: plain jobs batch per point set; shard sub-jobs batch
/// per (point set, group), so groups never mix with singles.
type Key = (PointSetId, Option<u64>);

fn key_of(job: &MsmJob) -> Key {
    (job.point_set, job.shard.map(|s| s.group))
}

/// Accumulates jobs per point set (and per shard group).
pub struct Batcher {
    policy: BatchPolicy,
    pending: HashMap<Key, Vec<MsmJob>>,
    oldest: HashMap<Key, Instant>,
}

impl Batcher {
    /// Empty batcher under a policy.
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: HashMap::new(), oldest: HashMap::new() }
    }

    /// Add a job; returns a full batch if this push released one. A plain
    /// batch fills at `max_batch`; a shard group fills exactly when its
    /// last member arrives (its size wins over `max_batch` — atomicity
    /// beats batch shaping).
    pub fn push(&mut self, job: MsmJob) -> Option<(PointSetId, Vec<MsmJob>)> {
        let key = key_of(&job);
        let group_total = job.shard.map(|s| s.total as usize);
        let entry = self.pending.entry(key).or_default();
        self.oldest.entry(key).or_insert_with(Instant::now);
        entry.push(job);
        let ready = match group_total {
            Some(total) => entry.len() >= total.max(1),
            None => entry.len() >= self.policy.max_batch,
        };
        if ready {
            return self.take(key);
        }
        None
    }

    /// Pop every batch whose oldest job exceeded the wait budget. An
    /// incomplete shard group is *not* popped (it would split across this
    /// flush and a later one); it stays pending until its last member
    /// arrives or `drain` runs.
    pub fn expired(&mut self, now: Instant) -> Vec<(PointSetId, Vec<MsmJob>)> {
        let ready: Vec<Key> = self
            .oldest
            .iter()
            .filter(|(key, t)| {
                now.duration_since(**t) >= self.policy.max_wait && self.complete(**key)
            })
            .map(|(&k, _)| k)
            .collect();
        ready.into_iter().filter_map(|key| self.take(key)).collect()
    }

    /// Drain everything (shutdown path). Each key — shard groups included —
    /// comes out as one batch.
    pub fn drain(&mut self) -> Vec<(PointSetId, Vec<MsmJob>)> {
        let keys: Vec<Key> = self.pending.keys().copied().collect();
        keys.into_iter().filter_map(|key| self.take(key)).collect()
    }

    /// Jobs currently held across all pending batches.
    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    /// Is the batch under `key` safe to flush? Plain batches always are; a
    /// shard group only once every member is present.
    fn complete(&self, key: Key) -> bool {
        if key.1.is_none() {
            return true;
        }
        match self.pending.get(&key) {
            Some(jobs) => jobs
                .last()
                .and_then(|j| j.shard)
                .map_or(true, |s| jobs.len() >= s.total as usize),
            None => true,
        }
    }

    fn take(&mut self, key: Key) -> Option<(PointSetId, Vec<MsmJob>)> {
        self.oldest.remove(&key);
        let jobs = self.pending.remove(&key)?;
        if jobs.is_empty() {
            None
        } else {
            Some((key.0, jobs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{JobId, ShardAssignment};
    use std::sync::Arc;

    fn job(id: u64, ps: u64) -> MsmJob {
        MsmJob {
            id: JobId(id),
            point_set: PointSetId(ps),
            scalars: Arc::new(vec![[id, 0, 0, 0]]),
            submitted_at: Instant::now(),
            shard: None,
        }
    }

    fn shard_job(id: u64, ps: u64, group: u64, index: u32, total: u32) -> MsmJob {
        MsmJob { shard: Some(ShardAssignment { group, index, total }), ..job(id, ps) }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(9) });
        assert!(b.push(job(1, 5)).is_none());
        assert!(b.push(job(2, 5)).is_none());
        let (ps, jobs) = b.push(job(3, 5)).expect("full batch");
        assert_eq!(ps, PointSetId(5));
        assert_eq!(jobs.len(), 3);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn separate_point_sets_dont_mix() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(9) });
        assert!(b.push(job(1, 1)).is_none());
        assert!(b.push(job(2, 2)).is_none());
        assert_eq!(b.pending_jobs(), 2);
        let full = b.push(job(3, 1)).expect("set 1 fills");
        assert_eq!(full.1.len(), 2);
        assert_eq!(b.pending_jobs(), 1);
    }

    #[test]
    fn expiry_flushes_old_batches() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(job(1, 3));
        std::thread::sleep(Duration::from_millis(3));
        b.push(job(2, 4)); // fresh — wait, also >1ms by flush time? use now
        let now = Instant::now() + Duration::from_millis(2);
        let expired = b.expired(now);
        assert_eq!(expired.len(), 2);
    }

    #[test]
    fn drain_empties() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.push(job(1, 1));
        b.push(job(2, 2));
        let drained = b.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(b.pending_jobs(), 0);
        assert!(b.drain().is_empty());
    }

    #[test]
    fn shard_group_ignores_max_batch_and_flushes_whole() {
        // group of 5 under max_batch = 2: the old size rule would cut the
        // group at 2 — it must instead flush once, complete, at member 5
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(9) });
        for i in 0..4 {
            assert!(b.push(shard_job(100 + i, 7, 42, i as u32, 5)).is_none(), "shard {i}");
        }
        let (ps, jobs) = b.push(shard_job(104, 7, 42, 4, 5)).expect("complete group flushes");
        assert_eq!(ps, PointSetId(7));
        assert_eq!(jobs.len(), 5);
        assert!(jobs.iter().all(|j| j.shard.unwrap().group == 42));
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn shard_group_does_not_mix_with_plain_jobs_of_same_set() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(9) });
        assert!(b.push(job(1, 7)).is_none());
        assert!(b.push(shard_job(2, 7, 9, 0, 2)).is_none());
        // plain batch of set 7 fills on its own, without the shard job
        let (_, plain) = b.push(job(3, 7)).expect("plain batch fills");
        assert_eq!(plain.len(), 2);
        assert!(plain.iter().all(|j| j.shard.is_none()));
        // the group still completes independently
        let (_, grp) = b.push(shard_job(4, 7, 9, 1, 2)).expect("group completes");
        assert_eq!(grp.len(), 2);
        assert!(grp.iter().all(|j| j.shard.is_some()));
    }

    #[test]
    fn expired_never_splits_incomplete_group() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(shard_job(1, 3, 11, 0, 3));
        b.push(shard_job(2, 3, 11, 1, 3));
        // well past the wait budget, but the group is incomplete: hold it
        let late = Instant::now() + Duration::from_secs(1);
        assert!(b.expired(late).is_empty(), "incomplete group must not flush on expiry");
        assert_eq!(b.pending_jobs(), 2);
        // last member arrives → one flush with all three
        let (_, jobs) = b.push(shard_job(3, 3, 11, 2, 3)).expect("now complete");
        assert_eq!(jobs.len(), 3);
    }

    #[test]
    fn drain_emits_group_as_single_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(9) });
        b.push(shard_job(1, 5, 13, 0, 3));
        b.push(shard_job(2, 5, 13, 1, 3));
        b.push(shard_job(3, 5, 13, 2, 3)); // completes → flushed by push
        b.push(shard_job(4, 5, 14, 0, 2));
        let drained = b.drain();
        assert_eq!(drained.len(), 1, "group 14 comes out whole in one batch");
        assert_eq!(drained[0].1.len(), 1);
        assert_eq!(drained[0].1[0].shard.unwrap().group, 14);
    }
}
