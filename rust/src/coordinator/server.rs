//! The coordinator server: bounded ingress, batching dispatcher, per-device
//! worker threads — the process topology of a proving-farm MSM tier.
//!
//! ```text
//!  submit() ──bounded──► dispatcher ──route──► device queue ──► worker 0
//!   (backpressure)        (batcher)                        └──► worker 1 …
//!                                                            reply channels
//! ```
//!
//! Everything is std-thread + mpsc (no async runtime exists in the offline
//! dependency set — and none is needed: the workload is compute-bound with
//! small fan-out).

use super::batcher::{BatchPolicy, Batcher};
use super::devices::{DeviceDesc, PointSetRegistry};
use super::metrics::{Counters, LatencyHistogram};
use super::pointcache::{Admission, DeviceDdr};
use super::request::{JobId, JobResult, MsmJob, PointSetId};
use super::router;
use crate::ec::{CurveParams, Jacobian, ScalarLimbs};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Ingress queue bound (jobs) — the backpressure knob.
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { queue_capacity: 256, batch: BatchPolicy::default() }
    }
}

struct Dispatch<C: CurveParams> {
    job: MsmJob,
    reply: mpsc::Sender<JobResult<Jacobian<C>>>,
}

enum WorkerMsg<C: CurveParams> {
    Batch { point_set: PointSetId, jobs: Vec<Dispatch<C>>, upload_miss: bool },
    Stop,
}

/// A running coordinator for one curve.
pub struct Coordinator<C: CurveParams> {
    /// `None` after shutdown (dropping the sender stops the dispatcher).
    ingress: Option<mpsc::SyncSender<Dispatch<C>>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub counters: Arc<Counters>,
    pub latency: Arc<LatencyHistogram>,
    next_job: AtomicU64,
    registry: Arc<PointSetRegistry<C>>,
}

impl<C: CurveParams> Coordinator<C> {
    /// Start the server over a set of devices and a pre-registered point
    /// registry (points move to devices lazily, once, on first use — the
    /// paper's "moved once and consumed on every call" lifecycle).
    pub fn start(
        cfg: CoordinatorConfig,
        devices: Vec<DeviceDesc<C>>,
        registry: PointSetRegistry<C>,
    ) -> Coordinator<C> {
        assert!(!devices.is_empty(), "need at least one device");
        let registry = Arc::new(registry);
        let counters = Arc::new(Counters::default());
        let latency = Arc::new(LatencyHistogram::new());
        let loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..devices.len()).map(|_| AtomicUsize::new(0)).collect());
        let ddrs: Arc<Mutex<Vec<DeviceDdr>>> = Arc::new(Mutex::new(
            devices.iter().map(|d| DeviceDdr::new(d.ddr_capacity)).collect(),
        ));

        // per-device worker threads
        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for (idx, dev) in devices.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<WorkerMsg<C>>();
            worker_txs.push(tx);
            let registry = registry.clone();
            let counters = counters.clone();
            let latency = latency.clone();
            let loads = loads.clone();
            workers.push(std::thread::spawn(move || {
                // PJRT engines must be constructed on their owning thread.
                let dev = match dev.into_runtime() {
                    Ok(d) => d,
                    Err(e) => {
                        eprintln!("[ERROR] device worker {idx} failed to start: {e:#}");
                        return; // replies drop ⇒ callers observe RecvError
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Stop => break,
                        WorkerMsg::Batch { point_set, jobs, upload_miss } => {
                            let points = match registry.get(point_set) {
                                Some(p) => p,
                                None => continue, // validated at submit; defensive
                            };
                            for (pos, d) in jobs.into_iter().enumerate() {
                                let res = dev.execute(&points, &d.job.scalars);
                                loads[idx].fetch_sub(1, Ordering::Relaxed);
                                let service_s = d.job.submitted_at.elapsed().as_secs_f64();
                                match res {
                                    Ok((output, _wall, device_s)) => {
                                        latency.record_secs(service_s);
                                        counters.completed.fetch_add(1, Ordering::Relaxed);
                                        let _ = d.reply.send(JobResult {
                                            id: d.job.id,
                                            output,
                                            service_s,
                                            device_s,
                                            device: idx,
                                            upload_miss: upload_miss && pos == 0,
                                            error: None,
                                        });
                                    }
                                    Err(e) => {
                                        // Deliver the failure: callers must be
                                        // able to tell "device failed" apart
                                        // from "coordinator shut down" (which
                                        // drops the channel instead).
                                        counters.failed.fetch_add(1, Ordering::Relaxed);
                                        let _ = d.reply.send(JobResult {
                                            id: d.job.id,
                                            output: Jacobian::<C>::infinity(),
                                            service_s,
                                            device_s: 0.0,
                                            device: idx,
                                            upload_miss: upload_miss && pos == 0,
                                            error: Some(format!("{e:#}")),
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }));
        }

        // dispatcher thread
        let (ingress, ingress_rx) = mpsc::sync_channel::<Dispatch<C>>(cfg.queue_capacity);
        let dispatcher = {
            let registry = registry.clone();
            let counters = counters.clone();
            let loads = loads.clone();
            let worker_txs = worker_txs.clone();
            std::thread::spawn(move || {
                let mut batcher = Batcher::new(cfg.batch);
                let flush = |ps: PointSetId, jobs: Vec<MsmJob>, replies: &mut JobReplies<C>| {
                    let bytes = registry.bytes_of(ps);
                    let load_now: Vec<usize> =
                        loads.iter().map(|l| l.load(Ordering::Relaxed)).collect();
                    let mut ddrs = ddrs.lock().unwrap();
                    let route = router::route(&mut ddrs, &load_now, ps, bytes);
                    drop(ddrs);
                    if let Some(r) = route {
                        let miss = matches!(r.admission, Admission::Miss { .. });
                        if miss {
                            counters.affinity_misses.fetch_add(1, Ordering::Relaxed);
                            counters.uploads_bytes.fetch_add(bytes, Ordering::Relaxed);
                        } else {
                            counters.affinity_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        let dispatches: Vec<Dispatch<C>> = jobs
                            .into_iter()
                            .filter_map(|j| {
                                replies.take(j.id).map(|reply| Dispatch { job: j, reply })
                            })
                            .collect();
                        loads[r.device].fetch_add(dispatches.len(), Ordering::Relaxed);
                        let _ = worker_txs[r.device].send(WorkerMsg::Batch {
                            point_set: ps,
                            jobs: dispatches,
                            upload_miss: miss,
                        });
                    } else {
                        counters.rejected.fetch_add(jobs.len() as u64, Ordering::Relaxed);
                    }
                };

                let mut replies = JobReplies::<C>::default();
                loop {
                    match ingress_rx.recv_timeout(cfg.batch.max_wait) {
                        Ok(d) => {
                            replies.put(d.job.id, d.reply);
                            if let Some((ps, jobs)) = batcher.push(d.job) {
                                flush(ps, jobs, &mut replies);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    for (ps, jobs) in batcher.expired(Instant::now()) {
                        flush(ps, jobs, &mut replies);
                    }
                }
                for (ps, jobs) in batcher.drain() {
                    flush(ps, jobs, &mut replies);
                }
                for tx in &worker_txs {
                    let _ = tx.send(WorkerMsg::Stop);
                }
            })
        };

        Coordinator {
            ingress: Some(ingress),
            dispatcher: Some(dispatcher),
            workers,
            counters,
            latency,
            next_job: AtomicU64::new(1),
            registry,
        }
    }

    /// Submit an MSM; returns the job id and the reply channel.
    /// `Err` when the ingress queue is full (backpressure) or the point
    /// set is unknown.
    pub fn submit(
        &self,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
    ) -> Result<(JobId, mpsc::Receiver<JobResult<Jacobian<C>>>)> {
        let set_len = match self.registry.get(point_set) {
            Some(s) => s.len(),
            None => return Err(anyhow!("unknown point set {point_set:?}")),
        };
        if scalars.len() != set_len {
            return Err(anyhow!(
                "scalar count {} != point set size {set_len}",
                scalars.len()
            ));
        }
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = mpsc::channel();
        let d = Dispatch {
            job: MsmJob { id, point_set, scalars, submitted_at: Instant::now() },
            reply: reply_tx,
        };
        let ingress = self.ingress.as_ref().ok_or_else(|| anyhow!("coordinator stopped"))?;
        ingress.try_send(d).map_err(|e| match e {
            mpsc::TrySendError::Full(_) => {
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow!("ingress queue full (backpressure)")
            }
            mpsc::TrySendError::Disconnected(_) => anyhow!("coordinator stopped"),
        })?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok((id, reply_rx))
    }

    /// Stop accepting work, drain in-flight batches, join all threads.
    pub fn shutdown(mut self) {
        drop(self.ingress.take()); // dispatcher's recv disconnects → drain
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }
}

/// Reply-channel stash keyed by job id (the batcher only carries jobs).
struct JobReplies<C: CurveParams> {
    map: std::collections::HashMap<JobId, mpsc::Sender<JobResult<Jacobian<C>>>>,
}

impl<C: CurveParams> Default for JobReplies<C> {
    fn default() -> Self {
        JobReplies { map: Default::default() }
    }
}

impl<C: CurveParams> JobReplies<C> {
    fn put(&mut self, id: JobId, tx: mpsc::Sender<JobResult<Jacobian<C>>>) {
        self.map.insert(id, tx);
    }

    fn take(&mut self, id: JobId) -> Option<mpsc::Sender<JobResult<Jacobian<C>>>> {
        self.map.remove(&id)
    }
}
