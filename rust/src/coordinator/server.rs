//! The coordinator server: bounded ingress, batching dispatcher, per-device
//! worker threads — the process topology of a proving-farm MSM tier.
//!
//! ```text
//!  submit_admitted() ──► [lanes: quota/deadline/bounds] ──► pump ─┐
//!                          (admission — see super::admission)     │
//!  submit() ─────bounded──► dispatcher ──route───► device queue ──► worker 0
//!   (backpressure)           (batcher)                          └──► worker 1 …
//!  submit_sharded() ──────►  split ► spread ──► shard per device ──► merge
//!                               ▲                                      │
//!                               └────────── retry (failed shard) ◄─────┘
//! ```
//!
//! Everything is std-thread + mpsc (no async runtime exists in the offline
//! dependency set — and none is needed: the workload is compute-bound with
//! small fan-out).
//!
//! A sharded job ([`Coordinator::submit_sharded`]) splits into one shard
//! per device under a [`ShardPolicy`], travels the batcher as an atomic
//! group, spreads across distinct devices via `router::route_spread`, and
//! merges deterministically in the last-finishing worker. A failed shard
//! bounces back to the dispatcher and is re-routed to a device it has not
//! tried; when a shard runs out of devices the whole group fails
//! atomically through [`JobResult::error`].

use super::admission::{
    AdmissionConfig, AdmissionController, AdmissionCounters, AdmissionSnapshot, Lane, Quota,
    RejectReason, TenantId,
};
use super::batcher::{BatchPolicy, Batcher};
use super::devices::{DeviceDesc, PointSetRegistry};
use super::metrics::{Counters, DeviceMetrics, LatencyHistogram};
use super::pointcache::{Admission, DeviceDdr};
use super::request::{JobError, JobId, JobResult, MsmJob, PointSetId, ShardAssignment};
use super::router;
use super::shard::{ShardGroup, ShardPolicy, ShardRetry};
use crate::ec::{CurveParams, Jacobian, ScalarLimbs};
use crate::msm::MsmConfig;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Ingress queue bound (jobs) — the backpressure knob. `0` (the
    /// default) means *auto*: [`Coordinator::start`] derives the bound
    /// from the registered fleet as `devices × 32` — one device keeps a
    /// 32-deep runway, not the former fleet-blind 256. Set a nonzero
    /// value to override (it is taken verbatim); the resolved bound is
    /// readable via [`Coordinator::queue_capacity`].
    pub queue_capacity: usize,
    /// Same-point-set batching policy.
    pub batch: BatchPolicy,
    /// The uniform MSM plan config sharded jobs run with (window-range
    /// shards need identical window boundaries on every device). Shard
    /// groups also budget DDR residency against it: a GLV config books
    /// the endo-expanded (doubled) point footprint when routing. Plain
    /// (unsharded) batches instead budget per device, against each
    /// device's own `msm_cfg`.
    pub shard_cfg: MsmConfig,
    /// Admission policy for the [`Coordinator::submit_admitted`] path:
    /// per-lane queue bounds, drain weights, default tenant quota. Lane
    /// capacities left at `0` auto-derive from the device count too.
    pub admission: AdmissionConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            queue_capacity: 0,
            batch: BatchPolicy::default(),
            shard_cfg: MsmConfig::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

struct SingleDispatch<C: CurveParams> {
    job: MsmJob,
    reply: mpsc::Sender<JobResult<Jacobian<C>>>,
}

enum Dispatch<C: CurveParams> {
    Single(SingleDispatch<C>),
    Group(Arc<ShardGroup<C>>),
}

enum WorkerMsg<C: CurveParams> {
    Batch { point_set: PointSetId, jobs: Vec<SingleDispatch<C>>, upload_miss: bool },
    Shard { group: Arc<ShardGroup<C>>, shard_index: usize },
    Stop,
}

/// A running coordinator for one curve.
pub struct Coordinator<C: CurveParams> {
    /// `None` after shutdown (dropping the sender stops the dispatcher).
    ingress: Option<mpsc::SyncSender<Dispatch<C>>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The admission tier in front of the ingress (lanes, quotas,
    /// deadline shedding); drained into `ingress` by the pump thread.
    admission: Arc<AdmissionController<Dispatch<C>>>,
    pump: Option<std::thread::JoinHandle<()>>,
    /// The resolved ingress bound (auto-derived when the config said 0).
    queue_capacity: usize,
    /// Coordinator-wide counters (submits, completions, shard stats).
    pub counters: Arc<Counters>,
    /// End-to-end job latency histogram.
    pub latency: Arc<LatencyHistogram>,
    /// Per-device lanes: jobs/shards executed, busy device-time,
    /// utilization.
    pub device_metrics: Arc<DeviceMetrics>,
    next_job: AtomicU64,
    registry: Arc<PointSetRegistry<C>>,
    retry_tx: mpsc::Sender<ShardRetry<C>>,
    n_devices: usize,
    shard_cfg: MsmConfig,
}

/// Dispatcher-side state shared by the flush paths.
struct DispatchCtx<C: CurveParams> {
    registry: Arc<PointSetRegistry<C>>,
    counters: Arc<Counters>,
    loads: Arc<Vec<AtomicUsize>>,
    ddrs: Arc<Mutex<Vec<DeviceDdr>>>,
    worker_txs: Vec<mpsc::Sender<WorkerMsg<C>>>,
    groups: HashMap<u64, Arc<ShardGroup<C>>>,
    replies: JobReplies<C>,
    /// The uniform config sharded jobs run (`shard_cfg`); shard-group
    /// routing budgets DDR against it (GLV doubles the footprint).
    group_cfg: MsmConfig,
    /// Each device's own single-job config — plain batches execute with
    /// these, so plain-batch routing budgets DDR per device (a GLV device
    /// keeps the endo-expanded set resident; a full-width one does not).
    device_cfgs: Vec<MsmConfig>,
}

impl<C: CurveParams> DispatchCtx<C> {
    fn loads_now(&self) -> Vec<usize> {
        self.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    /// DDR bytes of a point set for shard-group routing (every shard runs
    /// the uniform `shard_cfg`, so one figure fits all devices).
    fn group_bytes(&self, ps: PointSetId) -> u64 {
        self.registry.bytes_for(ps, &self.group_cfg)
    }

    /// Per-device DDR bytes of a point set for plain-batch routing.
    fn batch_bytes(&self, ps: PointSetId) -> Vec<u64> {
        self.device_cfgs.iter().map(|cfg| self.registry.bytes_for(ps, cfg)).collect()
    }

    fn flush(&mut self, ps: PointSetId, jobs: Vec<MsmJob>) {
        if jobs.first().and_then(|j| j.shard).is_some() {
            self.flush_group(ps, jobs);
        } else {
            self.flush_batch(ps, jobs);
        }
    }

    /// Route one same-point-set batch to a single device (affinity path).
    fn flush_batch(&mut self, ps: PointSetId, jobs: Vec<MsmJob>) {
        let bytes = self.batch_bytes(ps);
        let load_now = self.loads_now();
        let mut ddrs = self.ddrs.lock().unwrap();
        let route = router::route_weighted(&mut ddrs, &load_now, ps, &bytes);
        drop(ddrs);
        if let Some(r) = route {
            let miss = matches!(r.admission, Admission::Miss { .. });
            if let Admission::Miss { upload_bytes, .. } = r.admission {
                self.counters.affinity_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.uploads_bytes.fetch_add(upload_bytes, Ordering::Relaxed);
            } else {
                self.counters.affinity_hits.fetch_add(1, Ordering::Relaxed);
            }
            let dispatches: Vec<SingleDispatch<C>> = jobs
                .into_iter()
                .filter_map(|j| {
                    self.replies.take(j.id).map(|reply| SingleDispatch { job: j, reply })
                })
                .collect();
            self.loads[r.device].fetch_add(dispatches.len(), Ordering::Relaxed);
            let _ = self.worker_txs[r.device].send(WorkerMsg::Batch {
                point_set: ps,
                jobs: dispatches,
                upload_miss: miss,
            });
        } else {
            // unroutable: no device DDR can hold the point set. Deliver a
            // typed failure to every caller — before the typed-error
            // redesign these replies were silently dropped and callers
            // hung until shutdown.
            self.counters.rejected.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            for j in jobs {
                if let Some(reply) = self.replies.take(j.id) {
                    let _ = reply.send(JobResult {
                        id: j.id,
                        output: Jacobian::<C>::infinity(),
                        service_s: j.submitted_at.elapsed().as_secs_f64(),
                        device_s: 0.0,
                        device: 0,
                        upload_miss: false,
                        error: Some(JobError::TooLarge),
                    });
                }
            }
        }
    }

    /// Spread one shard group across the device set (one shard per
    /// distinct device while they last) and hand each shard to its worker.
    fn flush_group(&mut self, ps: PointSetId, mut jobs: Vec<MsmJob>) {
        jobs.sort_by_key(|j| j.shard.map_or(0, |s| s.index));
        let gid = match jobs[0].shard {
            Some(s) => s.group,
            None => return, // unreachable: flush() checked
        };
        let group = match self.groups.remove(&gid) {
            Some(g) => g,
            None => return, // group already failed/settled
        };
        // counted before any failure path, so shard_group_failures can
        // never exceed shard_groups (ShardPool counts in the same order)
        self.counters.shard_groups.fetch_add(1, Ordering::Relaxed);
        if jobs.len() != group.shard_count() {
            group.fail_group("shard group arrived incomplete at flush", &self.counters);
            return;
        }
        let bytes = self.group_bytes(ps);
        let load_now = self.loads_now();
        let mut ddrs = self.ddrs.lock().unwrap();
        let routes = router::route_spread(&mut ddrs, &load_now, ps, bytes, jobs.len());
        drop(ddrs);
        let routes = match routes {
            Some(r) => r,
            None => {
                group.fail_group("no device can hold the point set", &self.counters);
                return;
            }
        };
        // upload accounting: once per distinct device the group touches
        // (a re-admission at a grown footprint reports only its delta)
        let mut seen: Vec<usize> = Vec::new();
        for r in &routes {
            if seen.contains(&r.device) {
                continue;
            }
            seen.push(r.device);
            if let Admission::Miss { upload_bytes, .. } = r.admission {
                self.counters.affinity_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.uploads_bytes.fetch_add(upload_bytes, Ordering::Relaxed);
            } else {
                self.counters.affinity_hits.fetch_add(1, Ordering::Relaxed);
            }
        }
        for (job, route) in jobs.iter().zip(&routes) {
            let shard_index = job.shard.expect("group job").index as usize;
            group.note_dispatch(shard_index, route.device);
            self.loads[route.device].fetch_add(1, Ordering::Relaxed);
            let _ = self.worker_txs[route.device]
                .send(WorkerMsg::Shard { group: group.clone(), shard_index });
        }
    }

    /// Re-route one failed shard to the least-loaded device it has not
    /// tried yet; fail the group atomically when none is left.
    fn handle_retry(&mut self, r: ShardRetry<C>) {
        if r.group.is_settled() {
            return; // another shard already failed the group — drop the retry
        }
        let tried = r.group.tried_devices(r.shard_index);
        let bytes = self.group_bytes(r.group.point_set);
        let load_now = self.loads_now();
        let mut order: Vec<usize> =
            (0..self.worker_txs.len()).filter(|d| !tried.contains(d)).collect();
        order.sort_by_key(|&d| load_now[d]);
        let mut dest = None;
        let mut ddrs = self.ddrs.lock().unwrap();
        for d in order {
            match ddrs[d].admit(r.group.point_set, bytes) {
                Admission::TooLarge => continue,
                adm => {
                    dest = Some((d, adm));
                    break;
                }
            }
        }
        drop(ddrs);
        match dest {
            Some((d, adm)) => {
                // the retry's admission is a real upload/hit like any other
                if let Admission::Miss { upload_bytes, .. } = adm {
                    self.counters.affinity_misses.fetch_add(1, Ordering::Relaxed);
                    self.counters.uploads_bytes.fetch_add(upload_bytes, Ordering::Relaxed);
                } else {
                    self.counters.affinity_hits.fetch_add(1, Ordering::Relaxed);
                }
                r.group.note_dispatch(r.shard_index, d);
                self.loads[d].fetch_add(1, Ordering::Relaxed);
                let _ = self.worker_txs[d]
                    .send(WorkerMsg::Shard { group: r.group, shard_index: r.shard_index });
            }
            None => r.group.fail_group(
                &format!("shard {} has no untried device left", r.shard_index),
                &self.counters,
            ),
        }
    }
}

impl<C: CurveParams> Coordinator<C> {
    /// Start the server over a set of devices and a pre-registered point
    /// registry (points move to devices lazily, once, on first use — the
    /// paper's "moved once and consumed on every call" lifecycle).
    pub fn start(
        cfg: CoordinatorConfig,
        devices: Vec<DeviceDesc<C>>,
        registry: PointSetRegistry<C>,
    ) -> Coordinator<C> {
        assert!(!devices.is_empty(), "need at least one device");
        let n_devices = devices.len();
        // captured before the descriptors move into their workers: plain
        // batches route with each device's own config's DDR footprint
        let device_cfgs: Vec<MsmConfig> = devices.iter().map(|d| d.msm_cfg).collect();
        let registry = Arc::new(registry);
        let counters = Arc::new(Counters::default());
        let latency = Arc::new(LatencyHistogram::new());
        let device_metrics = Arc::new(DeviceMetrics::new(n_devices));
        let loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n_devices).map(|_| AtomicUsize::new(0)).collect());
        let ddrs: Arc<Mutex<Vec<DeviceDdr>>> = Arc::new(Mutex::new(
            devices.iter().map(|d| DeviceDdr::new(d.ddr_capacity)).collect(),
        ));
        let (retry_tx, retry_rx) = mpsc::channel::<ShardRetry<C>>();

        // per-device worker threads
        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for (idx, dev) in devices.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<WorkerMsg<C>>();
            worker_txs.push(tx);
            let registry = registry.clone();
            let counters = counters.clone();
            let latency = latency.clone();
            let device_metrics = device_metrics.clone();
            let loads = loads.clone();
            workers.push(std::thread::spawn(move || {
                // PJRT engines must be constructed on their owning thread.
                let dev = match dev.into_runtime() {
                    Ok(d) => d,
                    Err(e) => {
                        eprintln!("[ERROR] device worker {idx} failed to start: {e:#}");
                        return; // replies drop ⇒ callers observe RecvError
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WorkerMsg::Stop => break,
                        WorkerMsg::Batch { point_set, jobs, upload_miss } => {
                            let points = match registry.get(point_set) {
                                Some(p) => p,
                                None => continue, // validated at submit; defensive
                            };
                            for (pos, d) in jobs.into_iter().enumerate() {
                                let res = dev.execute(&points, &d.job.scalars);
                                loads[idx].fetch_sub(1, Ordering::Relaxed);
                                let service_s = d.job.submitted_at.elapsed().as_secs_f64();
                                match res {
                                    Ok((output, _wall, device_s)) => {
                                        latency.record_secs(service_s);
                                        counters.completed.fetch_add(1, Ordering::Relaxed);
                                        device_metrics.lane(idx).record(device_s, false);
                                        let _ = d.reply.send(JobResult {
                                            id: d.job.id,
                                            output,
                                            service_s,
                                            device_s,
                                            device: idx,
                                            upload_miss: upload_miss && pos == 0,
                                            error: None,
                                        });
                                    }
                                    Err(e) => {
                                        // Deliver the failure: callers must be
                                        // able to tell "device failed" apart
                                        // from "coordinator shut down" (which
                                        // drops the channel instead).
                                        counters.failed.fetch_add(1, Ordering::Relaxed);
                                        device_metrics.lane(idx).record_failure();
                                        let _ = d.reply.send(JobResult {
                                            id: d.job.id,
                                            output: Jacobian::<C>::infinity(),
                                            service_s,
                                            device_s: 0.0,
                                            device: idx,
                                            upload_miss: upload_miss && pos == 0,
                                            error: Some(JobError::DeviceFailed(format!("{e:#}"))),
                                        });
                                    }
                                }
                            }
                        }
                        WorkerMsg::Shard { group, shard_index } => {
                            if group.is_settled() {
                                // group already failed atomically — the
                                // result would be discarded, skip the work
                                loads[idx].fetch_sub(1, Ordering::Relaxed);
                                continue;
                            }
                            let spec = group.specs[shard_index];
                            let res = match registry.get(group.point_set) {
                                Some(points) => dev.execute_shard(
                                    &points,
                                    &group.scalars,
                                    &spec,
                                    &group.cfg,
                                ),
                                None => Err(anyhow!("point set disappeared")),
                            };
                            loads[idx].fetch_sub(1, Ordering::Relaxed);
                            match res {
                                Ok((output, _wall, device_s)) => {
                                    device_metrics.lane(idx).record(device_s, true);
                                    group.complete(
                                        shard_index,
                                        output,
                                        device_s,
                                        idx,
                                        &counters,
                                        &latency,
                                    );
                                }
                                Err(e) => {
                                    device_metrics.lane(idx).record_failure();
                                    ShardGroup::fail(
                                        &group,
                                        shard_index,
                                        idx,
                                        &format!("{e:#}"),
                                        &counters,
                                    );
                                }
                            }
                        }
                    }
                }
            }));
        }

        // dispatcher thread. 0 = auto: derive the ingress bound from the
        // fleet size (a 1-device pool keeps a 32-deep runway, not the
        // former fleet-blind 256).
        let queue_capacity =
            if cfg.queue_capacity == 0 { n_devices * 32 } else { cfg.queue_capacity };
        let (ingress, ingress_rx) = mpsc::sync_channel::<Dispatch<C>>(queue_capacity);
        let dispatcher = {
            let mut ctx = DispatchCtx {
                registry: registry.clone(),
                counters: counters.clone(),
                loads: loads.clone(),
                ddrs,
                worker_txs,
                groups: HashMap::new(),
                replies: JobReplies::default(),
                group_cfg: cfg.shard_cfg,
                device_cfgs,
            };
            std::thread::spawn(move || {
                let mut batcher = Batcher::new(cfg.batch);
                loop {
                    match ingress_rx.recv_timeout(cfg.batch.max_wait) {
                        Ok(Dispatch::Single(d)) => {
                            ctx.replies.put(d.job.id, d.reply);
                            if let Some((ps, jobs)) = batcher.push(d.job) {
                                ctx.flush(ps, jobs);
                            }
                        }
                        Ok(Dispatch::Group(group)) => {
                            ctx.groups.insert(group.id.0, group.clone());
                            // all members enter the batcher back-to-back;
                            // the group-completing push releases them as
                            // one atomic batch
                            let total = group.shard_count() as u32;
                            let mut flushed = None;
                            for index in 0..total {
                                let job = MsmJob {
                                    id: group.id,
                                    point_set: group.point_set,
                                    scalars: group.scalars.clone(),
                                    submitted_at: group.submitted_at,
                                    shard: Some(ShardAssignment {
                                        group: group.id.0,
                                        index,
                                        total,
                                    }),
                                };
                                if let Some(f) = batcher.push(job) {
                                    flushed = Some(f);
                                }
                            }
                            if let Some((ps, jobs)) = flushed {
                                ctx.flush(ps, jobs);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    while let Ok(r) = retry_rx.try_recv() {
                        ctx.handle_retry(r);
                    }
                    for (ps, jobs) in batcher.expired(Instant::now()) {
                        ctx.flush(ps, jobs);
                    }
                }
                for (ps, jobs) in batcher.drain() {
                    ctx.flush(ps, jobs);
                }
                // best-effort: re-route retries that raced the shutdown
                while let Ok(r) = retry_rx.try_recv() {
                    ctx.handle_retry(r);
                }
                for tx in &ctx.worker_txs {
                    let _ = tx.send(WorkerMsg::Stop);
                }
            })
        };

        // admission tier: lanes drain weighted-fair into the bounded
        // ingress via the pump thread (the blocking send is the natural
        // backpressure between the two queues)
        let admission: Arc<AdmissionController<Dispatch<C>>> =
            Arc::new(AdmissionController::new(cfg.admission, n_devices));
        let pump = {
            let admission = admission.clone();
            let ingress_tx = ingress.clone();
            std::thread::spawn(move || {
                while let Some(d) = admission.drain_next() {
                    if ingress_tx.send(d).is_err() {
                        break; // dispatcher gone — nothing left to feed
                    }
                    // Self-clocked release: pace drains at the fleet's
                    // estimated service rate so sustained overload backs
                    // up in the lanes — where shedding and weighted-fair
                    // policy live — instead of the FIFO batcher behind
                    // the ingress (which is unbounded and lane-blind).
                    // The estimate is 0 until the first completion is
                    // booked via `ServedJob::recv`; until then drains are
                    // unpaced, which only affects the warm-up burst.
                    let est = admission.counters.est_service_secs();
                    if est > 0.0 {
                        let pace = (est / n_devices as f64).min(0.05);
                        std::thread::sleep(Duration::from_secs_f64(pace));
                    }
                }
            })
        };

        Coordinator {
            ingress: Some(ingress),
            dispatcher: Some(dispatcher),
            workers,
            admission,
            pump: Some(pump),
            queue_capacity,
            counters,
            latency,
            device_metrics,
            next_job: AtomicU64::new(1),
            registry,
            retry_tx,
            n_devices,
            shard_cfg: cfg.shard_cfg,
        }
    }

    /// Registered device count.
    pub fn device_count(&self) -> usize {
        self.n_devices
    }

    /// The resolved ingress queue bound (after the `0 = auto` derivation
    /// in [`Coordinator::start`]).
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// The resolved bound of one admission lane (after its own `0 = auto`
    /// derivation).
    pub fn lane_capacity(&self, lane: Lane) -> usize {
        self.admission.capacity(lane)
    }

    /// Install (or replace) a tenant's token-bucket quota on the
    /// admission tier. Tenants without one use
    /// [`AdmissionConfig::default_quota`] (unmetered when that is `None`).
    pub fn set_tenant_quota(&self, tenant: TenantId, quota: Quota) {
        self.admission.set_quota(tenant, quota);
    }

    /// Plain-data copy of the admission counters (offered/admitted/shed
    /// per lane and per reason, completions, failures).
    pub fn admission_snapshot(&self) -> AdmissionSnapshot {
        self.admission.counters.snapshot()
    }

    fn validate(&self, point_set: PointSetId, scalars: &[ScalarLimbs]) -> Result<usize> {
        let set_len = match self.registry.get(point_set) {
            Some(s) => s.len(),
            None => return Err(anyhow!("unknown point set {point_set:?}")),
        };
        if scalars.len() != set_len {
            return Err(anyhow!("scalar count {} != point set size {set_len}", scalars.len()));
        }
        Ok(set_len)
    }

    fn enqueue(&self, d: Dispatch<C>) -> Result<()> {
        let ingress = self.ingress.as_ref().ok_or_else(|| anyhow!("coordinator stopped"))?;
        ingress.try_send(d).map_err(|e| match e {
            mpsc::TrySendError::Full(_) => {
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow!("ingress queue full (backpressure)")
            }
            mpsc::TrySendError::Disconnected(_) => anyhow!("coordinator stopped"),
        })?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Submit an MSM; returns the job id and the reply channel.
    /// `Err` when the ingress queue is full (backpressure) or the point
    /// set is unknown.
    pub fn submit(
        &self,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
    ) -> Result<(JobId, mpsc::Receiver<JobResult<Jacobian<C>>>)> {
        self.validate(point_set, &scalars)?;
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = mpsc::channel();
        self.enqueue(Dispatch::Single(SingleDispatch {
            job: MsmJob { id, point_set, scalars, submitted_at: Instant::now(), shard: None },
            reply: reply_tx,
        }))?;
        Ok((id, reply_rx))
    }

    /// Submit an MSM to shard across every registered device under
    /// `policy`. With one device this degrades to [`Self::submit`]. The
    /// reply channel delivers exactly one [`JobResult`]: the
    /// deterministically merged point, or — after per-shard retries
    /// exhaust the device set — an atomic failure via
    /// [`JobResult::error`].
    pub fn submit_sharded(
        &self,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
        policy: ShardPolicy,
    ) -> Result<(JobId, mpsc::Receiver<JobResult<Jacobian<C>>>)> {
        if self.n_devices == 1 {
            return self.submit(point_set, scalars);
        }
        let set_len = self.validate(point_set, &scalars)?;
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = mpsc::channel();
        let specs = policy.plan::<C>(set_len, &self.shard_cfg, self.n_devices);
        let group = Arc::new(ShardGroup::new(
            id,
            point_set,
            scalars,
            specs,
            self.shard_cfg,
            self.n_devices as u32, // dispatch budget: one try per device
            reply_tx,
            self.retry_tx.clone(),
        ));
        self.enqueue(Dispatch::Group(group))?;
        Ok((id, reply_rx))
    }

    /// Submit an MSM through the admission tier: the job is checked
    /// against `lane`'s queue bound, `tenant`'s token bucket and (when
    /// `deadline` is given) the backlog-based wait estimate **now**, and
    /// either queued — [`ServedJob`] resolves to exactly one
    /// [`JobResult`] — or refused with a typed
    /// [`JobError::Rejected`]. A refused job never occupies queue space:
    /// doomed work is shed at the door, not after it rotted in line.
    pub fn submit_admitted(
        &self,
        tenant: TenantId,
        lane: Lane,
        deadline: Option<Duration>,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
    ) -> std::result::Result<ServedJob<C>, JobError> {
        if self.validate(point_set, &scalars).is_err() {
            self.admission.counters.note_shed_offer(lane, RejectReason::Invalid);
            return Err(JobError::Rejected { lane, reason: RejectReason::Invalid });
        }
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = mpsc::channel();
        let dispatch = Dispatch::Single(SingleDispatch {
            job: MsmJob { id, point_set, scalars, submitted_at: Instant::now(), shard: None },
            reply: reply_tx,
        });
        self.admission
            .offer(tenant, lane, deadline, dispatch)
            .map_err(|reason| JobError::Rejected { lane, reason })?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(ServedJob { id, lane, rx: reply_rx, counters: self.admission.counters.clone() })
    }

    /// [`Self::submit_admitted`] for the sharded path. The whole shard
    /// group is **one** admission unit (one lane-queue entry, one token):
    /// it is admitted or shed atomically, so admission control can never
    /// split a group — the batcher/spread/merge machinery downstream
    /// keeps its complete-or-fail guarantee untouched. With one device
    /// this degrades to the plain admitted path, like
    /// [`Self::submit_sharded`] does.
    pub fn submit_sharded_admitted(
        &self,
        tenant: TenantId,
        lane: Lane,
        deadline: Option<Duration>,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
        policy: ShardPolicy,
    ) -> std::result::Result<ServedJob<C>, JobError> {
        if self.n_devices == 1 {
            return self.submit_admitted(tenant, lane, deadline, point_set, scalars);
        }
        let set_len = match self.validate(point_set, &scalars) {
            Ok(n) => n,
            Err(_) => {
                self.admission.counters.note_shed_offer(lane, RejectReason::Invalid);
                return Err(JobError::Rejected { lane, reason: RejectReason::Invalid });
            }
        };
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = mpsc::channel();
        let specs = policy.plan::<C>(set_len, &self.shard_cfg, self.n_devices);
        let group = Arc::new(ShardGroup::new(
            id,
            point_set,
            scalars,
            specs,
            self.shard_cfg,
            self.n_devices as u32,
            reply_tx,
            self.retry_tx.clone(),
        ));
        self.admission
            .offer(tenant, lane, deadline, Dispatch::Group(group))
            .map_err(|reason| JobError::Rejected { lane, reason })?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(ServedJob { id, lane, rx: reply_rx, counters: self.admission.counters.clone() })
    }

    /// Stop accepting work, drain in-flight batches, join all threads.
    /// Order matters: close admission (queued lane work still drains),
    /// join the pump (it exits once the lanes are dry and drops its
    /// ingress handle), then drop ours so the dispatcher disconnects.
    pub fn shutdown(mut self) {
        self.admission.close();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        drop(self.ingress.take()); // dispatcher's recv disconnects → drain
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }
}

/// A job accepted by the admission tier: resolves to exactly one
/// [`JobResult`] via [`ServedJob::recv`], which also books the completion
/// into the per-lane admission counters (so `admitted == completed +
/// failed` reconciles once every admitted job has been received).
pub struct ServedJob<C: CurveParams> {
    id: JobId,
    lane: Lane,
    rx: mpsc::Receiver<JobResult<Jacobian<C>>>,
    counters: Arc<AdmissionCounters>,
}

impl<C: CurveParams> ServedJob<C> {
    /// The job's coordinator-wide id.
    pub fn id(&self) -> JobId {
        self.id
    }

    /// The lane the job was admitted on.
    pub fn lane(&self) -> Lane {
        self.lane
    }

    /// Wait for the job's one result, booking it into the lane counters
    /// and folding its service time into the deadline estimator.
    /// Consumes the handle — one job, one result, one booking. `Err`
    /// means the coordinator shut down before serving the job.
    ///
    /// The estimator is fed `device_s` (pure execution time), not
    /// `service_s` (submit→reply): end-to-end latency includes lane and
    /// queue wait, and feeding that back into the pump's pacing and the
    /// deadline feasibility check would make backlog inflate the very
    /// estimate that throttles drainage — a positive feedback loop.
    pub fn recv(self) -> std::result::Result<JobResult<Jacobian<C>>, mpsc::RecvError> {
        let res = self.rx.recv()?;
        if res.is_ok() {
            self.counters.note_completed(self.lane);
            let est = if res.device_s > 0.0 { res.device_s } else { res.service_s };
            self.counters.note_service_secs(est);
        } else {
            self.counters.note_failed(self.lane);
        }
        Ok(res)
    }
}

/// Reply-channel stash keyed by job id (the batcher only carries jobs).
struct JobReplies<C: CurveParams> {
    map: std::collections::HashMap<JobId, mpsc::Sender<JobResult<Jacobian<C>>>>,
}

impl<C: CurveParams> Default for JobReplies<C> {
    fn default() -> Self {
        JobReplies { map: Default::default() }
    }
}

impl<C: CurveParams> JobReplies<C> {
    fn put(&mut self, id: JobId, tx: mpsc::Sender<JobResult<Jacobian<C>>>) {
        self.map.insert(id, tx);
    }

    fn take(&mut self, id: JobId) -> Option<mpsc::Sender<JobResult<Jacobian<C>>>> {
        self.map.remove(&id)
    }
}
