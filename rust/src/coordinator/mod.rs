//! L3 coordinator: a prover-serving layer around the MSM accelerators.
//!
//! The paper's host/device split (§IV-A, §V-C) generalized into the
//! serving system a proving farm actually deploys:
//!
//! * [`request`] — MSM jobs and their lifecycle;
//! * [`pointcache`] — the paper's key observation operationalized: *"the
//!   set of elliptic curve points remains constant throughout the lifetime
//!   of a given proof … moved to FPGA DDR once"* — a residency manager
//!   that tracks which device DDR holds which named point set, with
//!   capacity-aware LRU eviction;
//! * [`router`] — affinity routing: a job goes to a device that already
//!   holds its point set; uploads are charged otherwise;
//! * [`batcher`] — groups same-point-set jobs so consecutive calls
//!   amortize DDR residency (the serving analogue of the paper's
//!   scalars-only per-call transfer);
//! * [`devices`] — backend abstraction: native CPU executor, modeled-FPGA
//!   executor (bit-exact native compute + SAB-model virtual latency), and
//!   the PJRT UDA engine;
//! * [`shard`] — the multi-device path: one large MSM splits into
//!   per-device shards (point chunks or window ranges, selected by a
//!   [`shard::ShardPolicy`]), fans out across every device, and merges
//!   back deterministically; shard groups complete or fail atomically,
//!   with per-shard retry on device failure;
//! * [`server`] — bounded-queue thread server with backpressure and
//!   latency metrics ([`metrics`] — including per-device utilization
//!   lanes and shard-skew counters);
//! * [`admission`] — the serving tier's front door: bounded priority
//!   lanes (interactive / batch / best-effort), per-tenant token-bucket
//!   quotas and deadline-aware shedding, drained weighted-fair into the
//!   dispatcher ([`Coordinator::submit_admitted`]);
//! * [`loadgen`] — a deterministic open-loop traffic generator that
//!   drives tenant mixes against a coordinator and reports
//!   latency-percentile / throughput / shed-rate curves
//!   (`BENCH_serving.json` — schema in the repo-root BENCHMARKS.md).
//!
//! The coordinator is generic over the curve (one instance per curve —
//! matching the hardware reality of one bitstream per curve).

pub mod request;
pub mod pointcache;
pub mod router;
pub mod batcher;
pub mod devices;
pub mod shard;
pub mod server;
pub mod metrics;
pub mod admission;
pub mod loadgen;

pub use admission::{AdmissionConfig, AdmissionSnapshot, Lane, Quota, RejectReason, TenantId};
pub use devices::{DeviceBackend, DeviceDesc, PointSetRegistry, RunningDevice};
pub use metrics::{CounterSnapshot, Counters, DeviceMetrics};
pub use request::{JobError, JobId, JobResult, MsmJob, PointSetId, ShardAssignment};
pub use server::{Coordinator, CoordinatorConfig, ServedJob};
pub use shard::{PoolDevice, ShardGroup, ShardPolicy, ShardPool};
