//! Sharded multi-device MSM execution — the device half of the sharding
//! layer (`msm::partial` owns the kernel half: specs, window-range
//! execution, deterministic merge).
//!
//! One large MSM splits into per-device shards under a
//! [`ShardPolicy`] (point chunks or window ranges), fans out to every
//! registered device, and merges back with a deterministic reduce (shard-
//! index order), so the served point never depends on completion order.
//!
//! Two embeddings share this module:
//!
//! * **Serving path** — [`ShardGroup`]: the server-side state of one
//!   sharded job flowing through `Coordinator::submit_sharded`. Shards
//!   travel the normal batcher → router → device-worker pipeline; the
//!   group settles exactly once — a merged success, or an **atomic
//!   failure** after per-shard retries exhaust the device set. A failed
//!   shard bounces back to the dispatcher as a [`ShardRetry`] and is
//!   re-routed to a device it has not tried yet; the caller observes
//!   failures only through [`JobResult::error`], never a dropped channel.
//! * **In-process path** — [`ShardPool`]: a synchronous multi-device
//!   executor for callers that hold their inputs as slices
//!   (`snark::prover`, `baseline::cpu`). Same planning, retry, and merge
//!   semantics, scoped threads instead of server workers.
//!
//! Shutdown caveat (serving path): a retry requested after the dispatcher
//! drained its queue cannot be re-routed; the group's channel then closes,
//! which callers already treat as "coordinator shut down".

use super::metrics::{Counters, DeviceMetrics, LatencyHistogram};
use super::request::{JobError, JobId, JobResult, PointSetId};
use crate::ec::{Affine, CurveParams, Jacobian, ScalarLimbs};
use crate::fpga::{SabConfig, SabModel};
use crate::msm::partial::{self, PartialMsm, ShardSpec};
use crate::msm::{self, Backend, MsmConfig};
use crate::util::Stopwatch;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

pub use crate::msm::partial::ShardPolicy;

/// A failed shard bounced back to the dispatcher for re-routing onto a
/// device it has not tried yet.
pub struct ShardRetry<C: CurveParams> {
    /// The group the failed shard belongs to.
    pub group: Arc<ShardGroup<C>>,
    /// Index of the shard to re-dispatch.
    pub shard_index: usize,
}

struct PartialShard<C: CurveParams> {
    output: Jacobian<C>,
    device_s: f64,
}

struct GroupState<C: CurveParams> {
    partials: Vec<Option<PartialShard<C>>>,
    remaining: usize,
    /// Dispatch count per shard (first dispatch included).
    attempts: Vec<u32>,
    /// Devices each shard has been dispatched to (retries exclude these).
    tried: Vec<Vec<usize>>,
    settled: bool,
}

/// Server-side state of one sharded job: specs, partials, retry
/// bookkeeping, and the caller's reply channel. Settles exactly once.
pub struct ShardGroup<C: CurveParams> {
    /// The client-visible job id.
    pub id: JobId,
    /// The point set every shard reads.
    pub point_set: PointSetId,
    /// The job's scalars (shared across shard executions).
    pub scalars: Arc<Vec<ScalarLimbs>>,
    /// One spec per shard, index-aligned with the merge order.
    pub specs: Vec<ShardSpec>,
    /// The uniform plan config every shard runs (window-range shards
    /// require identical window boundaries across devices).
    pub cfg: MsmConfig,
    /// Submission timestamp (latency accounting).
    pub submitted_at: Instant,
    /// Dispatch budget per shard (one try per registered device).
    pub max_attempts: u32,
    reply: mpsc::Sender<JobResult<Jacobian<C>>>,
    retry_tx: mpsc::Sender<ShardRetry<C>>,
    state: Mutex<GroupState<C>>,
}

impl<C: CurveParams> ShardGroup<C> {
    /// Assemble the group state for one sharded job.
    #[allow(clippy::too_many_arguments)] // constructor mirrors the wire format
    pub fn new(
        id: JobId,
        point_set: PointSetId,
        scalars: Arc<Vec<ScalarLimbs>>,
        specs: Vec<ShardSpec>,
        cfg: MsmConfig,
        max_attempts: u32,
        reply: mpsc::Sender<JobResult<Jacobian<C>>>,
        retry_tx: mpsc::Sender<ShardRetry<C>>,
    ) -> ShardGroup<C> {
        let n = specs.len();
        ShardGroup {
            id,
            point_set,
            scalars,
            specs,
            cfg,
            submitted_at: Instant::now(),
            max_attempts: max_attempts.max(1),
            reply,
            retry_tx,
            state: Mutex::new(GroupState {
                partials: (0..n).map(|_| None).collect(),
                remaining: n,
                attempts: vec![0; n],
                tried: vec![Vec::new(); n],
                settled: false,
            }),
        }
    }

    /// Number of shards in the plan.
    pub fn shard_count(&self) -> usize {
        self.specs.len()
    }

    /// Record a dispatch decision (router side), so a retry never lands on
    /// a device that already ran this shard.
    pub fn note_dispatch(&self, shard_index: usize, device: usize) {
        let mut st = self.state.lock().unwrap();
        st.attempts[shard_index] += 1;
        if !st.tried[shard_index].contains(&device) {
            st.tried[shard_index].push(device);
        }
    }

    /// Devices this shard has already been dispatched to.
    pub fn tried_devices(&self, shard_index: usize) -> Vec<usize> {
        self.state.lock().unwrap().tried[shard_index].clone()
    }

    /// Has the group already settled (merged or failed atomically)?
    /// Dispatch paths use this to drop work whose result would be
    /// discarded anyway.
    pub fn is_settled(&self) -> bool {
        self.state.lock().unwrap().settled
    }

    /// Deliver one shard's partial result. When it is the last one, merge
    /// deterministically and reply; returns true iff this call settled the
    /// group.
    pub fn complete(
        &self,
        shard_index: usize,
        output: Jacobian<C>,
        device_s: f64,
        device: usize,
        counters: &Counters,
        latency: &LatencyHistogram,
    ) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.settled {
            return false;
        }
        if st.partials[shard_index].is_none() {
            st.remaining -= 1;
        }
        st.partials[shard_index] = Some(PartialShard { output, device_s });
        if st.remaining > 0 {
            return false;
        }
        st.settled = true;
        let mut parts: Vec<PartialMsm<C>> = Vec::with_capacity(self.specs.len());
        let mut max_s = 0.0f64;
        let mut min_s = f64::INFINITY;
        for (i, p) in st.partials.iter().enumerate() {
            let p = p.as_ref().expect("remaining == 0 implies all partials present");
            max_s = max_s.max(p.device_s);
            min_s = min_s.min(p.device_s);
            parts.push(PartialMsm { index: i, spec: self.specs[i], output: p.output });
        }
        drop(st);
        let output = partial::merge(&mut parts);
        let skew = if max_s > 0.0 { (max_s - min_s) / max_s } else { 0.0 };
        counters.record_shard_skew(skew);
        counters.completed.fetch_add(1, Ordering::Relaxed);
        let service_s = self.submitted_at.elapsed().as_secs_f64();
        latency.record_secs(service_s);
        let _ = self.reply.send(JobResult {
            id: self.id,
            output,
            service_s,
            // the group's modeled device time is its makespan: the slowest
            // shard (they run concurrently on distinct devices)
            device_s: max_s,
            device,
            upload_miss: false,
            error: None,
        });
        true
    }

    /// A shard failed on `device`: request a retry while the dispatch
    /// budget lasts, otherwise fail the whole group atomically.
    pub fn fail(
        group: &Arc<ShardGroup<C>>,
        shard_index: usize,
        device: usize,
        err: &str,
        counters: &Counters,
    ) {
        let retry = {
            let mut st = group.state.lock().unwrap();
            if st.settled {
                return;
            }
            if !st.tried[shard_index].contains(&device) {
                st.tried[shard_index].push(device);
            }
            st.attempts[shard_index] < group.max_attempts
        };
        if retry {
            counters.shard_retries.fetch_add(1, Ordering::Relaxed);
            let sent = group
                .retry_tx
                .send(ShardRetry { group: group.clone(), shard_index })
                .is_ok();
            if sent {
                return;
            }
            // dispatcher is gone (shutdown) — fall through to atomic failure
        }
        group.fail_group(
            &format!(
                "shard {shard_index} ({}) failed on device {device}: {err}",
                group.specs[shard_index].describe()
            ),
            counters,
        );
    }

    /// Fail the group atomically: one error [`JobResult`] carrying
    /// [`JobError::ShardExhausted`] is delivered, every not-yet-merged
    /// partial is discarded.
    pub fn fail_group(&self, err: &str, counters: &Counters) {
        {
            let mut st = self.state.lock().unwrap();
            if st.settled {
                return;
            }
            st.settled = true;
        }
        counters.shard_group_failures.fetch_add(1, Ordering::Relaxed);
        counters.failed.fetch_add(1, Ordering::Relaxed);
        let _ = self.reply.send(JobResult {
            id: self.id,
            output: Jacobian::<C>::infinity(),
            service_s: self.submitted_at.elapsed().as_secs_f64(),
            device_s: 0.0,
            device: 0,
            upload_miss: false,
            error: Some(JobError::ShardExhausted(err.to_string())),
        });
    }
}

/// A device slot of an in-process [`ShardPool`]. Cloneable descriptions —
/// workers materialize nothing; shards execute on scoped threads.
#[derive(Clone, Debug)]
pub enum PoolDevice {
    /// Host CPU, `threads`-way chunk-parallel fills (point-level
    /// parallelism — not capped by the plan's window count).
    Native {
        /// OS threads per shard.
        threads: usize,
    },
    /// Bit-exact native compute; per-shard device time comes from the SAB
    /// model (chunk shards: an (hi−lo)-point MSM; window shards: the
    /// window fraction of the full MSM).
    SimFpga {
        /// The modeled accelerator build.
        cfg: SabConfig,
    },
    /// Chaos slot for exercising the retry path: fails the next
    /// `failures` shards handed to it, then behaves like `Native`.
    Flaky {
        /// Remaining injected failures (shared, decremented per shard).
        failures: Arc<AtomicUsize>,
        /// OS threads per shard once healthy.
        threads: usize,
    },
}

impl PoolDevice {
    /// Execute one shard; returns (partial, device seconds).
    fn run_shard<C: CurveParams>(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        cfg: &MsmConfig,
        spec: &ShardSpec,
    ) -> anyhow::Result<(Jacobian<C>, f64)> {
        let threads = match self {
            PoolDevice::Native { threads } | PoolDevice::Flaky { threads, .. } => {
                (*threads).max(1)
            }
            PoolDevice::SimFpga { .. } => msm::parallel::default_threads(),
        };
        if let PoolDevice::Flaky { failures, .. } = self {
            let armed = failures
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                .is_ok();
            if armed {
                anyhow::bail!("injected flaky-device fault");
            }
        }
        let sw = Stopwatch::start();
        // Chunk-parallel execution: a point-chunk shard's thread count is
        // then independent of the plan's window count (window-range
        // shards thread across their windows either way).
        let out = partial::execute_shard(
            Backend::Chunked { threads },
            points,
            scalars,
            cfg,
            spec,
        );
        let wall = sw.secs();
        let device_s = match self {
            PoolDevice::SimFpga { cfg: sab } => {
                // spec window indices live in the job's plan, not the
                // model's hardware plan — time_shard needs its window count
                let plan_windows = crate::msm::MsmPlan::for_curve::<C>(cfg).windows;
                SabModel::new(*sab).time_shard(points.len() as u64, spec, plan_windows)
            }
            _ => wall,
        };
        Ok((out, device_s))
    }
}

/// In-process multi-device MSM executor: shard across every device, retry
/// failed shards on untried devices, merge deterministically. This is the
/// sharded path `snark::prover` and `baseline::cpu` submit through when
/// more than one device is registered.
///
/// # Examples
///
/// ```
/// use ifzkp::coordinator::shard::{ShardPolicy, ShardPool};
/// use ifzkp::ec::{points, Bn254G1};
/// use ifzkp::msm::{self, Backend, MsmConfig};
///
/// let w = points::workload::<Bn254G1>(96, 5);
/// let cfg = MsmConfig::default();
/// // three simulated devices, point-chunk sharding
/// let pool = ShardPool::<Bn254G1>::native(3, 1).with_policy(ShardPolicy::ChunkPoints);
/// let merged = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
/// // the merge is invisible: identical to the unsharded dispatch
/// let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
/// assert!(merged.eq_point(&want));
/// assert_eq!(pool.counters.snapshot().shard_groups, 1);
/// ```
pub struct ShardPool<C: CurveParams> {
    devices: Vec<PoolDevice>,
    /// How jobs split across the device set.
    pub policy: ShardPolicy,
    /// Per-device lanes (shards executed, busy seconds, failures).
    pub metrics: DeviceMetrics,
    /// Pool-wide shard counters (groups, retries, atomic failures, skew).
    pub counters: Counters,
    _curve: PhantomData<C>,
}

impl<C: CurveParams> ShardPool<C> {
    /// A pool over an explicit device list.
    pub fn new(devices: Vec<PoolDevice>, policy: ShardPolicy) -> ShardPool<C> {
        assert!(!devices.is_empty(), "need at least one device");
        let n = devices.len();
        ShardPool {
            devices,
            policy,
            metrics: DeviceMetrics::new(n),
            counters: Counters::default(),
            _curve: PhantomData,
        }
    }

    /// `n` identical native devices (the multi-socket / multi-board CPU
    /// stand-in), default policy.
    pub fn native(n: usize, threads_per_device: usize) -> ShardPool<C> {
        ShardPool::new(
            (0..n.max(1)).map(|_| PoolDevice::Native { threads: threads_per_device }).collect(),
            ShardPolicy::default(),
        )
    }

    /// `n` identical modeled-FPGA devices.
    pub fn sim_fpga(n: usize, cfg: SabConfig, policy: ShardPolicy) -> ShardPool<C> {
        ShardPool::new((0..n.max(1)).map(|_| PoolDevice::SimFpga { cfg }).collect(), policy)
    }

    /// Same pool, different shard policy.
    pub fn with_policy(mut self, policy: ShardPolicy) -> ShardPool<C> {
        self.policy = policy;
        self
    }

    /// Registered device count.
    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Execute one MSM across the pool. Single-device pools run directly;
    /// otherwise the job shards per the policy, failed shards retry on
    /// devices they have not tried, and the group fails atomically (Err)
    /// when any shard exhausts the device set.
    pub fn execute(
        &self,
        points: &[Affine<C>],
        scalars: &[ScalarLimbs],
        cfg: &MsmConfig,
    ) -> anyhow::Result<Jacobian<C>> {
        assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
        let m = points.len();
        if self.devices.len() == 1 || m < 2 {
            let spec = ShardSpec::PointChunk { lo: 0, hi: m };
            let (out, secs) = self.devices[0].run_shard(points, scalars, cfg, &spec)?;
            self.metrics.lane(0).record(secs, false);
            return Ok(out);
        }
        let specs = self.policy.plan::<C>(m, cfg, self.devices.len());
        let n = specs.len();
        self.counters.shard_groups.fetch_add(1, Ordering::Relaxed);

        let mut assignment: Vec<usize> = (0..n).map(|i| i % self.devices.len()).collect();
        let mut tried: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut results: Vec<Option<PartialMsm<C>>> = (0..n).map(|_| None).collect();
        let mut shard_secs = vec![0.0f64; n];

        loop {
            let pending: Vec<usize> =
                (0..n).filter(|&i| results[i].is_none()).collect();
            if pending.is_empty() {
                break;
            }
            let wave: Mutex<Vec<(usize, anyhow::Result<(Jacobian<C>, f64)>, usize)>> =
                Mutex::new(Vec::with_capacity(pending.len()));
            std::thread::scope(|scope| {
                for &i in &pending {
                    let dev_idx = assignment[i];
                    let dev = &self.devices[dev_idx];
                    let spec = specs[i];
                    let wave = &wave;
                    scope.spawn(move || {
                        let r = dev.run_shard::<C>(points, scalars, cfg, &spec);
                        wave.lock().unwrap().push((i, r, dev_idx));
                    });
                }
            });
            for (i, r, dev_idx) in wave.into_inner().unwrap() {
                if !tried[i].contains(&dev_idx) {
                    tried[i].push(dev_idx);
                }
                match r {
                    Ok((out, secs)) => {
                        self.metrics.lane(dev_idx).record(secs, true);
                        shard_secs[i] = secs;
                        results[i] = Some(PartialMsm { index: i, spec: specs[i], output: out });
                    }
                    Err(e) => {
                        self.metrics.lane(dev_idx).record_failure();
                        match (0..self.devices.len()).find(|d| !tried[i].contains(d)) {
                            Some(d) => {
                                self.counters.shard_retries.fetch_add(1, Ordering::Relaxed);
                                assignment[i] = d;
                            }
                            None => {
                                self.counters
                                    .shard_group_failures
                                    .fetch_add(1, Ordering::Relaxed);
                                anyhow::bail!(
                                    "shard group failed atomically: shard {i} ({}) failed on \
                                     every device (last: {e:#})",
                                    specs[i].describe()
                                );
                            }
                        }
                    }
                }
            }
        }

        let max_s = shard_secs.iter().copied().fold(0.0f64, f64::max);
        let min_s = shard_secs.iter().copied().fold(f64::INFINITY, f64::min);
        self.counters.record_shard_skew(if max_s > 0.0 { (max_s - min_s) / max_s } else { 0.0 });
        let mut parts: Vec<PartialMsm<C>> = results.into_iter().flatten().collect();
        Ok(partial::merge(&mut parts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{points, Bn254G1};
    use crate::fpga::CurveId;

    fn workload(m: usize, seed: u64) -> points::MsmWorkload<Bn254G1> {
        points::workload::<Bn254G1>(m, seed)
    }

    #[test]
    fn pool_matches_single_device_both_policies() {
        let w = workload(257, 7001);
        let cfg = MsmConfig::default();
        let want = msm::execute(Backend::Pippenger, &w.points, &w.scalars, &cfg);
        for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
            let pool = ShardPool::<Bn254G1>::native(3, 1).with_policy(policy);
            let got = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
            assert!(got.eq_point(&want), "{policy:?}");
            assert_eq!(pool.counters.snapshot().shard_groups, 1);
            // every device lane saw at least one shard
            assert!(pool.metrics.lanes().iter().all(|l| l.shards.load(Ordering::Relaxed) > 0));
        }
    }

    #[test]
    fn pool_single_device_runs_direct() {
        let w = workload(64, 7002);
        let cfg = MsmConfig::default();
        let pool = ShardPool::<Bn254G1>::native(1, 2);
        let got = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
        assert!(got.eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        assert_eq!(pool.counters.snapshot().shard_groups, 0);
        assert_eq!(pool.metrics.lane(0).jobs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_retries_flaky_device_and_still_merges() {
        let w = workload(120, 7003);
        let cfg = MsmConfig::default();
        let want = msm::naive::msm(&w.points, &w.scalars);
        let pool = ShardPool::<Bn254G1>::new(
            vec![
                PoolDevice::Flaky { failures: Arc::new(AtomicUsize::new(1)), threads: 1 },
                PoolDevice::Native { threads: 1 },
            ],
            ShardPolicy::ChunkPoints,
        );
        let got = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
        assert!(got.eq_point(&want));
        let snap = pool.counters.snapshot();
        assert_eq!(snap.shard_retries, 1, "{snap:?}");
        assert_eq!(snap.shard_group_failures, 0);
        assert_eq!(pool.metrics.lane(0).failures.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_fails_atomically_when_all_devices_fail() {
        let w = workload(60, 7004);
        let cfg = MsmConfig::default();
        let pool = ShardPool::<Bn254G1>::new(
            vec![
                PoolDevice::Flaky { failures: Arc::new(AtomicUsize::new(99)), threads: 1 },
                PoolDevice::Flaky { failures: Arc::new(AtomicUsize::new(99)), threads: 1 },
            ],
            ShardPolicy::ChunkPoints,
        );
        let err = pool.execute(&w.points, &w.scalars, &cfg).unwrap_err();
        assert!(format!("{err}").contains("failed atomically"), "{err}");
        assert_eq!(pool.counters.snapshot().shard_group_failures, 1);
    }

    #[test]
    fn sim_fpga_pool_reports_modeled_shard_time() {
        let w = workload(256, 7005);
        let cfg = MsmConfig::default();
        let pool = ShardPool::<Bn254G1>::sim_fpga(
            2,
            SabConfig::paper(CurveId::Bn254, 2),
            ShardPolicy::ChunkPoints,
        );
        let got = pool.execute(&w.points, &w.scalars, &cfg).unwrap();
        assert!(got.eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        // modeled device time per shard ≈ call overhead ≥ 5 ms each
        assert!(pool.metrics.lane(0).busy_secs() > 0.004);
        assert!(pool.metrics.lane(1).busy_secs() > 0.004);
    }
}
