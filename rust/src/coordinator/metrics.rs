//! Serving metrics: counters, a fixed-bucket latency histogram, and
//! per-device utilization lanes (lock-free enough for the worker threads
//! via atomics). The shard counters (groups, retries, atomic failures,
//! skew) instrument the multi-device sharded path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Log-spaced latency histogram from 1 µs to ~1000 s.
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record_secs(&self, s: f64) {
        let us = (s * 1e6).max(1.0) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency over all samples (0 when empty).
    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }

    /// Approximate quantile (upper edge of the containing bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 / 1e6;
            }
        }
        (1u64 << self.buckets.len()) as f64 / 1e6
    }
}

/// One device's serving lane: how much work (and modeled device time) the
/// slot has absorbed. `busy_us` uses the *device* clock — for sim-FPGA
/// slots that is the modeled accelerator time, so utilization reads as
/// "how loaded the modeled hardware would be".
#[derive(Default)]
pub struct DeviceLane {
    /// Whole (unsharded) jobs executed.
    pub jobs: AtomicU64,
    /// Shard executions (pieces of sharded jobs).
    pub shards: AtomicU64,
    /// Executions that returned a device error.
    pub failures: AtomicU64,
    /// Device-seconds consumed, in microseconds.
    pub busy_us: AtomicU64,
}

impl DeviceLane {
    /// Record one successful execution and its device time.
    pub fn record(&self, device_secs: f64, is_shard: bool) {
        if is_shard {
            self.shards.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_us.fetch_add((device_secs.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    /// Record one failed execution.
    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Total device-busy seconds absorbed by the lane.
    pub fn busy_secs(&self) -> f64 {
        self.busy_us.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Per-device metrics for a fixed device set (one lane per slot).
pub struct DeviceMetrics {
    lanes: Vec<DeviceLane>,
    started: Instant,
}

impl DeviceMetrics {
    /// Fresh lanes for a fixed device count.
    pub fn new(devices: usize) -> DeviceMetrics {
        DeviceMetrics {
            lanes: (0..devices).map(|_| DeviceLane::default()).collect(),
            started: Instant::now(),
        }
    }

    /// Number of lanes.
    pub fn device_count(&self) -> usize {
        self.lanes.len()
    }

    /// One device's lane.
    pub fn lane(&self, device: usize) -> &DeviceLane {
        &self.lanes[device]
    }

    /// All lanes, device-index order.
    pub fn lanes(&self) -> &[DeviceLane] {
        &self.lanes
    }

    /// Per-device utilization: device-busy seconds over wall seconds since
    /// construction. Sim-FPGA lanes can exceed 1.0 (the modeled hardware
    /// would be oversubscribed) — that is the signal, so it is not clamped.
    pub fn utilization(&self) -> Vec<f64> {
        let wall = self.started.elapsed().as_secs_f64().max(1e-9);
        self.lanes.iter().map(|l| l.busy_secs() / wall).collect()
    }

    /// JSON rendering for the CLI/metrics endpoint.
    pub fn to_json(&self) -> crate::util::json::Json {
        let util = self.utilization();
        let mut arr = Vec::with_capacity(self.lanes.len());
        for (i, lane) in self.lanes.iter().enumerate() {
            let mut j = crate::util::json::Json::obj();
            j.set("device", i)
                .set("jobs", lane.jobs.load(Ordering::Relaxed))
                .set("shards", lane.shards.load(Ordering::Relaxed))
                .set("failures", lane.failures.load(Ordering::Relaxed))
                .set("busy_s", lane.busy_secs())
                .set("utilization", util[i]);
            arr.push(j);
        }
        crate::util::json::Json::Arr(arr)
    }
}

/// Coordinator-wide counters.
#[derive(Default)]
pub struct Counters {
    /// Jobs accepted at the ingress.
    pub submitted: AtomicU64,
    /// Jobs (and merged shard groups) completed successfully.
    pub completed: AtomicU64,
    /// Jobs whose device `execute` returned an error (the error result is
    /// still delivered to the caller — see `request::JobResult::error`).
    pub failed: AtomicU64,
    /// Jobs refused at the ingress (backpressure) or unroutable batches.
    pub rejected: AtomicU64,
    /// Batches routed to a device already holding the point set.
    pub affinity_hits: AtomicU64,
    /// Batches that forced a point-set upload first.
    pub affinity_misses: AtomicU64,
    /// Total bytes uploaded to device DDR.
    pub uploads_bytes: AtomicU64,
    /// Shard groups dispatched (one per sharded job reaching the devices).
    pub shard_groups: AtomicU64,
    /// Individual shards re-dispatched after a device failure.
    pub shard_retries: AtomicU64,
    /// Shard groups that failed atomically (a shard ran out of devices).
    pub shard_group_failures: AtomicU64,
    /// Shard-skew accumulator: per group, (max − min)/max of the shard
    /// device times, in permille (0 = perfectly balanced shards).
    skew_permille_sum: AtomicU64,
    skew_samples: AtomicU64,
}

impl Counters {
    /// Record one completed group's shard skew (0.0 balanced … 1.0 one
    /// shard did all the waiting).
    pub fn record_shard_skew(&self, skew: f64) {
        let pm = (skew.clamp(0.0, 1.0) * 1000.0).round() as u64;
        self.skew_permille_sum.fetch_add(pm, Ordering::Relaxed);
        self.skew_samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough plain-data copy of all counters.
    pub fn snapshot(&self) -> CounterSnapshot {
        let samples = self.skew_samples.load(Ordering::Relaxed);
        CounterSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            affinity_hits: self.affinity_hits.load(Ordering::Relaxed),
            affinity_misses: self.affinity_misses.load(Ordering::Relaxed),
            uploads_bytes: self.uploads_bytes.load(Ordering::Relaxed),
            shard_groups: self.shard_groups.load(Ordering::Relaxed),
            shard_retries: self.shard_retries.load(Ordering::Relaxed),
            shard_group_failures: self.shard_group_failures.load(Ordering::Relaxed),
            mean_shard_skew_permille: if samples == 0 {
                0
            } else {
                self.skew_permille_sum.load(Ordering::Relaxed) / samples
            },
        }
    }
}

/// Plain-data snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// Jobs accepted at the ingress.
    pub submitted: u64,
    /// Jobs (and merged shard groups) completed successfully.
    pub completed: u64,
    /// Jobs delivered with a device-failure error.
    pub failed: u64,
    /// Jobs refused at the ingress or unroutable.
    pub rejected: u64,
    /// Affinity-routing hits.
    pub affinity_hits: u64,
    /// Affinity-routing misses (uploads).
    pub affinity_misses: u64,
    /// Total bytes uploaded to device DDR.
    pub uploads_bytes: u64,
    /// Shard groups dispatched.
    pub shard_groups: u64,
    /// Shard re-dispatches after device failures.
    pub shard_retries: u64,
    /// Atomically failed shard groups.
    pub shard_group_failures: u64,
    /// Mean shard skew across completed groups, in permille.
    pub mean_shard_skew_permille: u64,
}

impl CounterSnapshot {
    /// Affinity hit rate over all routed batches (0 when none routed).
    pub fn hit_rate(&self) -> f64 {
        let total = self.affinity_hits + self.affinity_misses;
        if total == 0 {
            0.0
        } else {
            self.affinity_hits as f64 / total as f64
        }
    }

    /// Mean shard skew across completed groups as a ratio in [0, 1].
    pub fn mean_shard_skew(&self) -> f64 {
        self.mean_shard_skew_permille as f64 / 1000.0
    }

    /// JSON rendering for the CLI/metrics endpoint.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("failed", self.failed)
            .set("rejected", self.rejected)
            .set("affinity_hits", self.affinity_hits)
            .set("affinity_misses", self.affinity_misses)
            .set("uploads_bytes", self.uploads_bytes)
            .set("hit_rate", self.hit_rate())
            .set("shard_groups", self.shard_groups)
            .set("shard_retries", self.shard_retries)
            .set("shard_group_failures", self.shard_group_failures)
            .set("mean_shard_skew", self.mean_shard_skew());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_secs(i as f64 * 0.001); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        let mean = h.mean_secs();
        assert!((mean - 0.0505).abs() < 0.002, "{mean}");
        let p50 = h.quantile_secs(0.5);
        assert!(p50 >= 0.03 && p50 <= 0.07, "{p50}");
        let p99 = h.quantile_secs(0.99);
        assert!(p99 >= p50);
    }

    #[test]
    fn snapshot_hit_rate() {
        let c = Counters::default();
        c.affinity_hits.store(3, Ordering::Relaxed);
        c.affinity_misses.store(1, Ordering::Relaxed);
        assert!((c.snapshot().hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(Counters::default().snapshot().hit_rate(), 0.0);
    }

    #[test]
    fn json_snapshot() {
        let c = Counters::default();
        c.submitted.store(5, Ordering::Relaxed);
        let j = c.snapshot().to_json();
        assert_eq!(j.get("submitted").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("shard_groups").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn shard_skew_mean() {
        let c = Counters::default();
        c.record_shard_skew(0.2);
        c.record_shard_skew(0.4);
        let snap = c.snapshot();
        assert_eq!(snap.mean_shard_skew_permille, 300);
        assert!((snap.mean_shard_skew() - 0.3).abs() < 1e-9);
        // out-of-range input is clamped, not wrapped
        c.record_shard_skew(7.0);
        assert!(c.snapshot().mean_shard_skew() <= 1.0);
    }

    #[test]
    fn device_lanes_track_busy_time_and_kind() {
        let m = DeviceMetrics::new(3);
        m.lane(0).record(0.5, false);
        m.lane(1).record(0.25, true);
        m.lane(1).record(0.25, true);
        m.lane(2).record_failure();
        assert_eq!(m.device_count(), 3);
        assert_eq!(m.lane(0).jobs.load(Ordering::Relaxed), 1);
        assert_eq!(m.lane(1).shards.load(Ordering::Relaxed), 2);
        assert_eq!(m.lane(2).failures.load(Ordering::Relaxed), 1);
        assert!((m.lane(1).busy_secs() - 0.5).abs() < 1e-6);
        let util = m.utilization();
        assert_eq!(util.len(), 3);
        assert!(util[0] > 0.0 && util[2] == 0.0);
        let j = m.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }
}
