//! Serving metrics: counters and a fixed-bucket latency histogram
//! (lock-free enough for the worker threads via atomics).

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram from 1 µs to ~1000 s.
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record_secs(&self, s: f64) {
        let us = (s * 1e6).max(1.0) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
    }

    /// Approximate quantile (upper edge of the containing bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 / 1e6;
            }
        }
        (1u64 << self.buckets.len()) as f64 / 1e6
    }
}

/// Coordinator-wide counters.
#[derive(Default)]
pub struct Counters {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    /// Jobs whose device `execute` returned an error (the error result is
    /// still delivered to the caller — see `request::JobResult::error`).
    pub failed: AtomicU64,
    pub rejected: AtomicU64,
    pub affinity_hits: AtomicU64,
    pub affinity_misses: AtomicU64,
    pub uploads_bytes: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            affinity_hits: self.affinity_hits.load(Ordering::Relaxed),
            affinity_misses: self.affinity_misses.load(Ordering::Relaxed),
            uploads_bytes: self.uploads_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    pub uploads_bytes: u64,
}

impl CounterSnapshot {
    pub fn hit_rate(&self) -> f64 {
        let total = self.affinity_hits + self.affinity_misses;
        if total == 0 {
            0.0
        } else {
            self.affinity_hits as f64 / total as f64
        }
    }

    /// JSON rendering for the CLI/metrics endpoint.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("failed", self.failed)
            .set("rejected", self.rejected)
            .set("affinity_hits", self.affinity_hits)
            .set("affinity_misses", self.affinity_misses)
            .set("uploads_bytes", self.uploads_bytes)
            .set("hit_rate", self.hit_rate());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_secs(i as f64 * 0.001); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        let mean = h.mean_secs();
        assert!((mean - 0.0505).abs() < 0.002, "{mean}");
        let p50 = h.quantile_secs(0.5);
        assert!(p50 >= 0.03 && p50 <= 0.07, "{p50}");
        let p99 = h.quantile_secs(0.99);
        assert!(p99 >= p50);
    }

    #[test]
    fn snapshot_hit_rate() {
        let c = Counters::default();
        c.affinity_hits.store(3, Ordering::Relaxed);
        c.affinity_misses.store(1, Ordering::Relaxed);
        assert!((c.snapshot().hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(Counters::default().snapshot().hit_rate(), 0.0);
    }

    #[test]
    fn json_snapshot() {
        let c = Counters::default();
        c.submitted.store(5, Ordering::Relaxed);
        let j = c.snapshot().to_json();
        assert_eq!(j.get("submitted").unwrap().as_f64(), Some(5.0));
    }
}
