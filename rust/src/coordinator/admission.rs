//! Admission control for the serving tier: bounded priority lanes,
//! per-tenant token-bucket quotas, and deadline-aware shedding, drained
//! weighted-fair into the dispatcher.
//!
//! ```text
//!  offer() ──quota?──deadline?──► [Interactive] ─┐
//!  offer() ─────────────────────► [Batch]        ├─ weighted-fair ─► pump
//!  offer() ─────────────────────► [Best-effort] ─┘   (DRR drain)
//!     │
//!     └── Err(RejectReason) — typed, at admit time, never a queued job
//!         that was doomed to miss its deadline
//! ```
//!
//! The controller is deliberately **generic over the queued payload**: the
//! coordinator queues its internal dispatch envelopes, unit tests queue
//! plain integers. All policy lives here — the dispatcher downstream never
//! sees a lane, which is what keeps the batcher/router/shard semantics
//! (atomic groups, deterministic merge) untouched by admission decisions.
//!
//! Shed points, in check order (first hit wins, no side effects before the
//! token is taken):
//!
//! 1. [`RejectReason::Closed`] — the controller is shutting down;
//! 2. [`RejectReason::LaneFull`] — the lane's bounded queue is at capacity;
//! 3. [`RejectReason::DeadlineInfeasible`] — the backlog ahead of the job
//!    (same and higher lanes, divided across devices) multiplied by the
//!    observed service-time EMA already exceeds the caller's deadline;
//! 4. [`RejectReason::QuotaExhausted`] — the tenant's token bucket is
//!    empty (checked last so a rejected job never burns a token).

use crate::util::json::Json;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Priority lane of a served job, highest first. The drain order is
/// weighted-fair ([`AdmissionConfig::lane_weight`]): under saturation the
/// Interactive lane takes most drain slots per round, but lower lanes
/// still progress (no starvation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Lane {
    /// Latency-sensitive traffic (wallet-style single proofs).
    Interactive,
    /// Throughput traffic (rollup-style proof batches).
    Batch,
    /// Background traffic: first to shed under overload.
    BestEffort,
}

/// Number of lanes (array dimension for per-lane state).
pub const LANES: usize = 3;

impl Lane {
    /// All lanes, priority order (index order of the per-lane arrays).
    pub const ALL: [Lane; LANES] = [Lane::Interactive, Lane::Batch, Lane::BestEffort];

    /// The lane's index into per-lane arrays (priority order, 0 highest).
    pub fn index(self) -> usize {
        match self {
            Lane::Interactive => 0,
            Lane::Batch => 1,
            Lane::BestEffort => 2,
        }
    }

    /// Stable lowercase name (metrics keys, JSON artifacts).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Interactive => "interactive",
            Lane::Batch => "batch",
            Lane::BestEffort => "best-effort",
        }
    }
}

impl fmt::Display for Lane {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A tenant of the proving service (quota-accounting identity).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u64);

/// Why admission refused a job. Delivered typed (through
/// [`super::request::JobError::Rejected`]) at admit time — a shed job
/// never occupies queue space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The lane's bounded submission queue is at capacity.
    LaneFull,
    /// The tenant's token bucket is empty.
    QuotaExhausted,
    /// The estimated queueing delay already exceeds the job's deadline.
    DeadlineInfeasible,
    /// The controller is closed (coordinator shutting down).
    Closed,
    /// The request itself is malformed (unknown point set, length
    /// mismatch). Emitted by the server wrapper, never by the controller.
    Invalid,
}

/// Number of reject reasons (array dimension for shed accounting).
pub const REASONS: usize = 5;

impl RejectReason {
    /// The reason's index into per-reason shed counters.
    pub fn index(self) -> usize {
        match self {
            RejectReason::LaneFull => 0,
            RejectReason::QuotaExhausted => 1,
            RejectReason::DeadlineInfeasible => 2,
            RejectReason::Closed => 3,
            RejectReason::Invalid => 4,
        }
    }

    /// Stable lowercase name (metrics keys, JSON artifacts).
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::LaneFull => "lane-full",
            RejectReason::QuotaExhausted => "quota-exhausted",
            RejectReason::DeadlineInfeasible => "deadline-infeasible",
            RejectReason::Closed => "closed",
            RejectReason::Invalid => "invalid",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msg = match self {
            RejectReason::LaneFull => "lane queue full",
            RejectReason::QuotaExhausted => "tenant quota exhausted",
            RejectReason::DeadlineInfeasible => "deadline infeasible at current backlog",
            RejectReason::Closed => "admission closed (shutdown)",
            RejectReason::Invalid => "invalid request",
        };
        f.write_str(msg)
    }
}

/// A tenant's token-bucket quota: sustained `rate_per_s` jobs per second
/// with bursts up to `burst` jobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quota {
    /// Sustained refill rate, jobs per second.
    pub rate_per_s: f64,
    /// Bucket capacity: how many jobs may arrive back-to-back.
    pub burst: f64,
}

impl Quota {
    /// A quota of `rate_per_s` with a burst of the same size (the common
    /// "N jobs per second" shape).
    pub fn per_second(rate_per_s: f64) -> Quota {
        Quota { rate_per_s, burst: rate_per_s.max(1.0) }
    }
}

/// One tenant's token bucket. Time is passed in explicitly so refill is
/// deterministic under test (construct instants, no sleeping).
#[derive(Clone, Debug)]
pub struct TokenBucket {
    quota: Quota,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A full bucket as of `now`.
    pub fn new(quota: Quota, now: Instant) -> TokenBucket {
        TokenBucket { quota, tokens: quota.burst.max(1.0), last: now }
    }

    /// Refill for the elapsed time, then try to take one token.
    pub fn try_take(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.quota.rate_per_s).min(self.quota.burst.max(1.0));
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Admission policy knobs. `Copy`, so it rides inside
/// [`super::CoordinatorConfig`] like the other server knobs.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Per-lane queue bounds, [`Lane`] index order. `0` = auto: derived
    /// from the device count at startup (`devices × 8` — roughly one
    /// device-queue depth of headroom per lane).
    pub lane_capacity: [usize; LANES],
    /// Deficit-round-robin drain weights, [`Lane`] index order: how many
    /// jobs each lane may drain per round when all lanes are backlogged.
    pub lane_weight: [u32; LANES],
    /// Quota applied to tenants without an explicit
    /// [`AdmissionController::set_quota`] override; `None` = unmetered.
    pub default_quota: Option<Quota>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            lane_capacity: [0; LANES],
            lane_weight: [8, 3, 1],
            default_quota: None,
        }
    }
}

/// Per-lane admission counters. The accounting identities the serving
/// tier maintains (and tests assert):
///
/// * `offered == admitted + shed` — enforced here, per lane;
/// * `admitted == completed + failed` — holds once every admitted job's
///   [`super::server::ServedJob::recv`] has returned.
#[derive(Default)]
pub struct AdmissionCounters {
    offered: [AtomicU64; LANES],
    admitted: [AtomicU64; LANES],
    shed: [AtomicU64; LANES],
    shed_by_reason: [AtomicU64; REASONS],
    completed: [AtomicU64; LANES],
    failed: [AtomicU64; LANES],
    /// EMA of observed service time, microseconds (0 = no samples yet —
    /// deadline checks admit everything until the first completion).
    est_service_us: AtomicU64,
}

impl AdmissionCounters {
    fn note_offered(&self, lane: Lane) {
        self.offered[lane.index()].fetch_add(1, Ordering::Relaxed);
    }

    fn note_admitted(&self, lane: Lane) {
        self.admitted[lane.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one shed decision (also usable by the server wrapper for
    /// rejections it raises itself, e.g. [`RejectReason::Invalid`]).
    pub fn note_shed(&self, lane: Lane, reason: RejectReason) {
        self.shed[lane.index()].fetch_add(1, Ordering::Relaxed);
        self.shed_by_reason[reason.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record an offer the server wrapper refused before it reached the
    /// controller (e.g. [`RejectReason::Invalid`]): counts both the offer
    /// and the shed, so `offered == admitted + shed` still holds.
    pub fn note_shed_offer(&self, lane: Lane, reason: RejectReason) {
        self.note_offered(lane);
        self.note_shed(lane, reason);
    }

    /// Record one admitted job finishing successfully.
    pub fn note_completed(&self, lane: Lane) {
        self.completed[lane.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one admitted job finishing with a delivered error.
    pub fn note_failed(&self, lane: Lane) {
        self.failed[lane.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one completed job's service time into the EMA the deadline
    /// check estimates queueing delay with.
    pub fn note_service_secs(&self, s: f64) {
        let us = (s.max(0.0) * 1e6) as u64;
        let old = self.est_service_us.load(Ordering::Relaxed);
        let new = if old == 0 { us.max(1) } else { (old * 4 + us) / 5 };
        self.est_service_us.store(new.max(1), Ordering::Relaxed);
    }

    /// Current service-time estimate in seconds (0 before any sample).
    pub fn est_service_secs(&self) -> f64 {
        self.est_service_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Plain-data copy of every counter.
    pub fn snapshot(&self) -> AdmissionSnapshot {
        let load = |a: &[AtomicU64]| -> [u64; LANES] {
            std::array::from_fn(|i| a[i].load(Ordering::Relaxed))
        };
        AdmissionSnapshot {
            offered: load(&self.offered),
            admitted: load(&self.admitted),
            shed: load(&self.shed),
            shed_by_reason: std::array::from_fn(|i| self.shed_by_reason[i].load(Ordering::Relaxed)),
            completed: load(&self.completed),
            failed: load(&self.failed),
        }
    }
}

/// Plain-data snapshot of [`AdmissionCounters`], [`Lane`] index order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionSnapshot {
    /// Jobs offered per lane (every `offer` call).
    pub offered: [u64; LANES],
    /// Jobs admitted per lane.
    pub admitted: [u64; LANES],
    /// Jobs shed per lane.
    pub shed: [u64; LANES],
    /// Jobs shed per [`RejectReason`] (reason index order).
    pub shed_by_reason: [u64; REASONS],
    /// Admitted jobs that completed successfully, per lane.
    pub completed: [u64; LANES],
    /// Admitted jobs that finished with a delivered error, per lane.
    pub failed: [u64; LANES],
}

impl AdmissionSnapshot {
    /// Total offered across lanes.
    pub fn offered_total(&self) -> u64 {
        self.offered.iter().sum()
    }

    /// Total admitted across lanes.
    pub fn admitted_total(&self) -> u64 {
        self.admitted.iter().sum()
    }

    /// Total shed across lanes.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    /// Total successful completions across lanes.
    pub fn completed_total(&self) -> u64 {
        self.completed.iter().sum()
    }

    /// Total delivered failures across lanes.
    pub fn failed_total(&self) -> u64 {
        self.failed.iter().sum()
    }

    /// Shed fraction of offered load for one lane (0 when none offered).
    pub fn shed_rate(&self, lane: Lane) -> f64 {
        let i = lane.index();
        if self.offered[i] == 0 {
            0.0
        } else {
            self.shed[i] as f64 / self.offered[i] as f64
        }
    }

    /// JSON rendering (per-lane objects plus per-reason shed counts).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let mut lanes = Vec::with_capacity(LANES);
        for lane in Lane::ALL {
            let i = lane.index();
            let mut l = Json::obj();
            l.set("lane", lane.name())
                .set("offered", self.offered[i])
                .set("admitted", self.admitted[i])
                .set("shed", self.shed[i])
                .set("completed", self.completed[i])
                .set("failed", self.failed[i])
                .set("shed_rate", self.shed_rate(lane));
            lanes.push(l);
        }
        let mut reasons = Json::obj();
        for (r, count) in [
            RejectReason::LaneFull,
            RejectReason::QuotaExhausted,
            RejectReason::DeadlineInfeasible,
            RejectReason::Closed,
            RejectReason::Invalid,
        ]
        .into_iter()
        .zip(self.shed_by_reason)
        {
            reasons.set(r.name(), count);
        }
        j.set("lanes", Json::Arr(lanes)).set("shed_by_reason", reasons);
        j
    }
}

struct AdmissionState<T> {
    queues: [VecDeque<T>; LANES],
    /// Deficit-round-robin credits; a lane drains while it has credit,
    /// a new round replenishes every lane to its weight.
    credits: [u32; LANES],
    /// Explicit per-tenant quota overrides (else the config default).
    quotas: HashMap<u64, Quota>,
    buckets: HashMap<u64, TokenBucket>,
    closed: bool,
}

/// The admission controller: bounded per-lane queues in front of the
/// dispatcher, drained weighted-fair. Generic over the queued payload so
/// policy is unit-testable without a device in sight.
///
/// # Examples
///
/// ```
/// use ifzkp::coordinator::admission::{
///     AdmissionConfig, AdmissionController, Lane, TenantId,
/// };
///
/// let ctl: AdmissionController<u64> =
///     AdmissionController::new(AdmissionConfig::default(), 2);
/// ctl.offer(TenantId(1), Lane::Interactive, None, 7).unwrap();
/// assert_eq!(ctl.try_drain(), Some(7));
/// assert_eq!(ctl.try_drain(), None);
/// ```
pub struct AdmissionController<T> {
    state: Mutex<AdmissionState<T>>,
    available: Condvar,
    caps: [usize; LANES],
    weights: [u32; LANES],
    default_quota: Option<Quota>,
    n_devices: usize,
    /// Shared per-lane counters (offered/admitted/shed/completed/failed).
    pub counters: Arc<AdmissionCounters>,
}

impl<T> AdmissionController<T> {
    /// Build a controller for a fleet of `n_devices`, resolving `0`
    /// (auto) lane capacities to `n_devices × 8`.
    pub fn new(cfg: AdmissionConfig, n_devices: usize) -> AdmissionController<T> {
        let n = n_devices.max(1);
        let resolve = |cap: usize| if cap == 0 { n * 8 } else { cap };
        AdmissionController {
            state: Mutex::new(AdmissionState {
                queues: std::array::from_fn(|_| VecDeque::new()),
                credits: [0; LANES],
                quotas: HashMap::new(),
                buckets: HashMap::new(),
                closed: false,
            }),
            available: Condvar::new(),
            caps: std::array::from_fn(|i| resolve(cfg.lane_capacity[i])),
            weights: std::array::from_fn(|i| cfg.lane_weight[i].max(1)),
            default_quota: cfg.default_quota,
            n_devices: n,
            counters: Arc::new(AdmissionCounters::default()),
        }
    }

    /// The resolved queue bound of one lane.
    pub fn capacity(&self, lane: Lane) -> usize {
        self.caps[lane.index()]
    }

    /// Jobs currently queued in one lane.
    pub fn queued(&self, lane: Lane) -> usize {
        self.state.lock().unwrap().queues[lane.index()].len()
    }

    /// Install (or replace) a tenant's quota. Resets the tenant's bucket
    /// to a full burst of the new quota.
    pub fn set_quota(&self, tenant: TenantId, quota: Quota) {
        let mut st = self.state.lock().unwrap();
        st.quotas.insert(tenant.0, quota);
        st.buckets.insert(tenant.0, TokenBucket::new(quota, Instant::now()));
    }

    /// Offer one job for admission. `Ok` queues it; `Err` is the typed
    /// shed decision (the payload is dropped — with a reply-channel
    /// payload the caller's receiver sees the rejection it already got
    /// synchronously). See the module docs for the check order.
    pub fn offer(
        &self,
        tenant: TenantId,
        lane: Lane,
        deadline: Option<Duration>,
        item: T,
    ) -> Result<(), RejectReason> {
        let li = lane.index();
        self.counters.note_offered(lane);
        let mut st = self.state.lock().unwrap();
        let verdict = self.check(&mut st, tenant, li, deadline);
        if let Err(reason) = verdict {
            self.counters.note_shed(lane, reason);
            return Err(reason);
        }
        st.queues[li].push_back(item);
        self.counters.note_admitted(lane);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// The side-effect-ordered admission checks (token taken last).
    fn check(
        &self,
        st: &mut AdmissionState<T>,
        tenant: TenantId,
        li: usize,
        deadline: Option<Duration>,
    ) -> Result<(), RejectReason> {
        if st.closed {
            return Err(RejectReason::Closed);
        }
        if st.queues[li].len() >= self.caps[li] {
            return Err(RejectReason::LaneFull);
        }
        if let Some(d) = deadline {
            let est = self.counters.est_service_secs();
            if est > 0.0 {
                // backlog the job waits behind: same and higher lanes,
                // spread across the fleet, plus its own service time
                let ahead: usize = st.queues[..=li].iter().map(VecDeque::len).sum();
                let est_wait = ((ahead / self.n_devices) + 1) as f64 * est;
                if est_wait > d.as_secs_f64() {
                    return Err(RejectReason::DeadlineInfeasible);
                }
            }
        }
        let quota = st.quotas.get(&tenant.0).copied().or(self.default_quota);
        if let Some(q) = quota {
            let now = Instant::now();
            let bucket = st.buckets.entry(tenant.0).or_insert_with(|| TokenBucket::new(q, now));
            if !bucket.try_take(now) {
                return Err(RejectReason::QuotaExhausted);
            }
        }
        Ok(())
    }

    /// Weighted-fair pick: scan lanes in priority order, drain where
    /// credit remains; when every backlogged lane is out of credit,
    /// replenish all credits to the lane weights (a new DRR round).
    fn pick(st: &mut AdmissionState<T>, weights: [u32; LANES]) -> Option<T> {
        if st.queues.iter().all(VecDeque::is_empty) {
            return None;
        }
        loop {
            for i in 0..LANES {
                if st.credits[i] > 0 && !st.queues[i].is_empty() {
                    st.credits[i] -= 1;
                    return st.queues[i].pop_front();
                }
            }
            st.credits = weights;
        }
    }

    /// Blocking drain: the next job in weighted-fair order, or `None`
    /// once the controller is closed **and** every lane is empty (close
    /// drains, it does not discard).
    pub fn drain_next(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = Self::pick(&mut st, self.weights) {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Non-blocking drain (tests, opportunistic pulls).
    pub fn try_drain(&self) -> Option<T> {
        Self::pick(&mut self.state.lock().unwrap(), self.weights)
    }

    /// Stop admitting; queued jobs still drain. Wakes all drainers so
    /// they can observe the close.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(cfg: AdmissionConfig, devices: usize) -> AdmissionController<u64> {
        AdmissionController::new(cfg, devices)
    }

    #[test]
    fn lane_full_sheds_typed() {
        let c = ctl(AdmissionConfig { lane_capacity: [2, 2, 2], ..Default::default() }, 1);
        assert!(c.offer(TenantId(1), Lane::Batch, None, 1).is_ok());
        assert!(c.offer(TenantId(1), Lane::Batch, None, 2).is_ok());
        assert_eq!(c.offer(TenantId(1), Lane::Batch, None, 3), Err(RejectReason::LaneFull));
        // other lanes are unaffected by one lane's backlog
        assert!(c.offer(TenantId(1), Lane::Interactive, None, 4).is_ok());
        let snap = c.counters.snapshot();
        assert_eq!(snap.offered_total(), 4);
        assert_eq!(snap.admitted_total(), 3);
        assert_eq!(snap.shed, [0, 1, 0]);
        assert_eq!(snap.shed_by_reason[RejectReason::LaneFull.index()], 1);
        assert_eq!(snap.offered_total(), snap.admitted_total() + snap.shed_total());
    }

    #[test]
    fn auto_capacity_scales_with_devices() {
        let c1 = ctl(AdmissionConfig::default(), 1);
        let c4 = ctl(AdmissionConfig::default(), 4);
        assert_eq!(c1.capacity(Lane::Interactive), 8);
        assert_eq!(c4.capacity(Lane::Interactive), 32);
        // explicit capacities are taken verbatim
        let c = ctl(AdmissionConfig { lane_capacity: [5, 6, 7], ..Default::default() }, 4);
        assert_eq!(c.capacity(Lane::BestEffort), 7);
    }

    #[test]
    fn token_bucket_refills_deterministically() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(Quota { rate_per_s: 1.0, burst: 2.0 }, t0);
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0), "burst of 2 is spent");
        // one simulated second refills one token — no sleeping needed
        assert!(b.try_take(t0 + Duration::from_secs(1)));
        assert!(!b.try_take(t0 + Duration::from_secs(1)));
    }

    #[test]
    fn quota_exhaustion_sheds_per_tenant() {
        let c = ctl(
            AdmissionConfig {
                default_quota: Some(Quota { rate_per_s: 0.0, burst: 2.0 }),
                ..Default::default()
            },
            2,
        );
        assert!(c.offer(TenantId(7), Lane::Batch, None, 1).is_ok());
        assert!(c.offer(TenantId(7), Lane::Batch, None, 2).is_ok());
        assert_eq!(c.offer(TenantId(7), Lane::Batch, None, 3), Err(RejectReason::QuotaExhausted));
        // buckets are per tenant: tenant 8 still has its own burst
        assert!(c.offer(TenantId(8), Lane::Batch, None, 4).is_ok());
        // an explicit override replaces the default (and refills)
        c.set_quota(TenantId(7), Quota { rate_per_s: 0.0, burst: 1.0 });
        assert!(c.offer(TenantId(7), Lane::Batch, None, 5).is_ok());
        assert_eq!(c.offer(TenantId(7), Lane::Batch, None, 6), Err(RejectReason::QuotaExhausted));
        let shed = c.counters.snapshot().shed_by_reason;
        assert_eq!(shed[RejectReason::QuotaExhausted.index()], 2);
    }

    #[test]
    fn weighted_fair_drain_prefers_higher_lanes() {
        let c = ctl(AdmissionConfig { lane_weight: [2, 1, 1], ..Default::default() }, 4);
        for i in 0..4u64 {
            c.offer(TenantId(1), Lane::Interactive, None, 100 + i).unwrap();
            c.offer(TenantId(1), Lane::Batch, None, 200 + i).unwrap();
            c.offer(TenantId(1), Lane::BestEffort, None, 300 + i).unwrap();
        }
        let drained: Vec<u64> = std::iter::from_fn(|| c.try_drain()).collect();
        assert_eq!(drained.len(), 12, "no job starves");
        // each DRR round under saturation: 2 interactive, 1 batch, 1 b.e.
        assert_eq!(&drained[..4], &[100, 101, 200, 300]);
        assert_eq!(&drained[4..8], &[102, 103, 201, 301]);
        // within a lane, FIFO order is preserved
        let batch: Vec<u64> = drained.iter().copied().filter(|v| (200..300).contains(v)).collect();
        assert_eq!(batch, vec![200, 201, 202, 203]);
    }

    #[test]
    fn deadline_infeasible_sheds_against_backlog_estimate() {
        let c = ctl(AdmissionConfig::default(), 1);
        let ms = |n: u64| Some(Duration::from_millis(n));
        // with no service samples yet, deadlines admit everything
        assert!(c.offer(TenantId(1), Lane::Interactive, ms(1), 0).is_ok());
        assert_eq!(c.try_drain(), Some(0));
        c.counters.note_service_secs(0.1);
        // empty queue: est wait = 1 × 100ms — a 50ms deadline is doomed
        let rejected = c.offer(TenantId(1), Lane::Interactive, ms(50), 1);
        assert_eq!(rejected, Err(RejectReason::DeadlineInfeasible));
        assert!(c.offer(TenantId(1), Lane::Interactive, ms(1000), 2).is_ok());
        // backlog in the same-and-higher lanes inflates the estimate
        for i in 3..11u64 {
            assert!(c.offer(TenantId(1), Lane::Batch, None, i).is_ok());
        }
        let rejected = c.offer(TenantId(1), Lane::Batch, ms(300), 99);
        assert_eq!(rejected, Err(RejectReason::DeadlineInfeasible));
        // a higher lane ignores lower-lane backlog in its estimate
        assert!(c.offer(TenantId(1), Lane::Interactive, ms(250), 98).is_ok());
    }

    #[test]
    fn close_drains_then_rejects() {
        let c = ctl(AdmissionConfig::default(), 1);
        c.offer(TenantId(1), Lane::Batch, None, 1).unwrap();
        c.offer(TenantId(1), Lane::Interactive, None, 2).unwrap();
        c.close();
        assert_eq!(c.offer(TenantId(1), Lane::Batch, None, 3), Err(RejectReason::Closed));
        // queued work still drains (higher lane first), then None
        assert_eq!(c.drain_next(), Some(2));
        assert_eq!(c.drain_next(), Some(1));
        assert_eq!(c.drain_next(), None);
    }

    #[test]
    fn blocking_drain_wakes_on_offer() {
        let c = Arc::new(ctl(AdmissionConfig::default(), 1));
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.drain_next());
        std::thread::sleep(Duration::from_millis(20));
        c.offer(TenantId(1), Lane::Interactive, None, 42).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }
}
