//! Affinity router: pick a device for each job.
//!
//! Policy (in priority order):
//! 1. a device whose DDR already holds the job's point set (affinity hit —
//!    the scalars-only fast path of §IV-A);
//! 2. otherwise the least-loaded device (queued jobs as the load proxy),
//!    charging the upload.
//!
//! Load is tracked by the server; the router is a pure decision function so
//! the property tests can drive it directly.

use super::pointcache::{Admission, DeviceDdr};
use super::request::PointSetId;

/// Routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    pub device: usize,
    pub admission: Admission,
}

/// Decide a device for a point set of `bytes`, given per-device DDR states
/// and load estimates. Mutates the chosen device's DDR (admission).
pub fn route(
    ddrs: &mut [DeviceDdr],
    loads: &[usize],
    point_set: PointSetId,
    bytes: u64,
) -> Option<Route> {
    assert_eq!(ddrs.len(), loads.len());
    if ddrs.is_empty() {
        return None;
    }
    // 1. affinity hit on the least-loaded holder
    let holder = (0..ddrs.len())
        .filter(|&i| ddrs[i].is_resident(point_set))
        .min_by_key(|&i| loads[i]);
    if let Some(i) = holder {
        let adm = ddrs[i].admit(point_set, bytes); // touch (refresh LRU)
        debug_assert_eq!(adm, Admission::Hit);
        return Some(Route { device: i, admission: adm });
    }
    // 2. least-loaded device that can take the set
    let mut order: Vec<usize> = (0..ddrs.len()).collect();
    order.sort_by_key(|&i| loads[i]);
    for i in order {
        match ddrs[i].admit(point_set, bytes) {
            Admission::TooLarge => continue,
            adm => return Some(Route { device: i, admission: adm }),
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ddrs(n: usize, cap: u64) -> Vec<DeviceDdr> {
        (0..n).map(|_| DeviceDdr::new(cap)).collect()
    }

    #[test]
    fn prefers_resident_device() {
        let mut d = ddrs(2, 1000);
        d[1].admit(PointSetId(7), 500);
        // device 1 holds set 7 but is more loaded — affinity still wins
        let r = route(&mut d, &[0, 10], PointSetId(7), 500).unwrap();
        assert_eq!(r.device, 1);
        assert_eq!(r.admission, Admission::Hit);
    }

    #[test]
    fn least_loaded_on_miss() {
        let mut d = ddrs(3, 1000);
        let r = route(&mut d, &[5, 2, 9], PointSetId(1), 100).unwrap();
        assert_eq!(r.device, 1);
        assert!(matches!(r.admission, Admission::Miss { .. }));
    }

    #[test]
    fn skips_too_small_devices() {
        let mut d = vec![DeviceDdr::new(50), DeviceDdr::new(5000)];
        let r = route(&mut d, &[0, 10], PointSetId(1), 100).unwrap();
        assert_eq!(r.device, 1);
    }

    #[test]
    fn none_when_nothing_fits() {
        let mut d = ddrs(2, 10);
        assert_eq!(route(&mut d, &[0, 0], PointSetId(1), 100), None);
    }

    #[test]
    fn ties_break_to_holder_with_lowest_load() {
        let mut d = ddrs(3, 1000);
        d[0].admit(PointSetId(3), 100);
        d[2].admit(PointSetId(3), 100);
        let r = route(&mut d, &[7, 0, 4], PointSetId(3), 100).unwrap();
        assert_eq!(r.device, 2);
    }
}
