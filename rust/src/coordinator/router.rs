//! Affinity router: pick a device for each job.
//!
//! Policy (in priority order):
//! 1. a device whose DDR already holds the job's point set (affinity hit —
//!    the scalars-only fast path of §IV-A);
//! 2. otherwise the least-loaded device (queued jobs as the load proxy),
//!    charging the upload.
//!
//! Load is tracked by the server; the router is a pure decision function so
//! the property tests can drive it directly.
//!
//! Sharded jobs use [`route_spread`] instead: one route per shard, distinct
//! devices while they last (holders first, then least-loaded), every chosen
//! device charged an admission for the point set.

use super::pointcache::{Admission, DeviceDdr};
use super::request::PointSetId;

/// Routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    /// Chosen device index.
    pub device: usize,
    /// The DDR admission outcome the choice incurred.
    pub admission: Admission,
}

/// Decide a device for a point set of `bytes`, given per-device DDR states
/// and load estimates. Mutates the chosen device's DDR (admission).
pub fn route(
    ddrs: &mut [DeviceDdr],
    loads: &[usize],
    point_set: PointSetId,
    bytes: u64,
) -> Option<Route> {
    let uniform = vec![bytes; ddrs.len()];
    route_weighted(ddrs, loads, point_set, &uniform)
}

/// [`route`] with a *per-device* byte budget: device `i` is charged
/// `bytes_by_device[i]` on admission. This is how plain (unsharded)
/// batches route correctly when devices run different MSM configs — a
/// device whose config uses the GLV split keeps the endo-expanded
/// (doubled) point set resident, while a full-width device holds the
/// plain set; one uniform byte figure would over- or under-book one of
/// them.
pub fn route_weighted(
    ddrs: &mut [DeviceDdr],
    loads: &[usize],
    point_set: PointSetId,
    bytes_by_device: &[u64],
) -> Option<Route> {
    assert_eq!(ddrs.len(), loads.len());
    assert_eq!(ddrs.len(), bytes_by_device.len());
    if ddrs.is_empty() {
        return None;
    }
    // 1. affinity preference: the least-loaded holder. With per-device
    // budgets the holder may need to *grow* its booking (it held the
    // plain set, this config needs the endo-expanded one) — that is a
    // Miss charging only the delta; a growth that cannot fit falls
    // through to the general placement below.
    let holder = (0..ddrs.len())
        .filter(|&i| ddrs[i].is_resident(point_set))
        .min_by_key(|&i| loads[i]);
    if let Some(i) = holder {
        match ddrs[i].admit(point_set, bytes_by_device[i]) {
            Admission::TooLarge => {}
            adm => return Some(Route { device: i, admission: adm }),
        }
    }
    // 2. least-loaded device that can take the set
    let mut order: Vec<usize> = (0..ddrs.len()).collect();
    order.sort_by_key(|&i| loads[i]);
    for i in order {
        match ddrs[i].admit(point_set, bytes_by_device[i]) {
            Admission::TooLarge => continue,
            adm => return Some(Route { device: i, admission: adm }),
        }
    }
    None
}

/// Route the `shards` shards of one group across the device set. Every
/// executing device needs the point set resident, so each chosen device
/// is charged an admission. Preference order: devices already holding the
/// set first, then by load. Distinct devices are used while they last;
/// when fewer devices can admit the set than there are shards, the
/// admitting devices are reused round-robin (degraded but correct).
/// Returns `None` when no device can hold the set at all.
pub fn route_spread(
    ddrs: &mut [DeviceDdr],
    loads: &[usize],
    point_set: PointSetId,
    bytes: u64,
    shards: usize,
) -> Option<Vec<Route>> {
    assert_eq!(ddrs.len(), loads.len());
    if ddrs.is_empty() || shards == 0 {
        return None;
    }
    let mut order: Vec<usize> = (0..ddrs.len()).collect();
    order.sort_by_key(|&i| (!ddrs[i].is_resident(point_set), loads[i], i));
    let mut admitted: Vec<Route> = Vec::new();
    for i in order {
        if admitted.len() >= shards {
            break;
        }
        match ddrs[i].admit(point_set, bytes) {
            Admission::TooLarge => continue,
            adm => admitted.push(Route { device: i, admission: adm }),
        }
    }
    if admitted.is_empty() {
        return None;
    }
    Some((0..shards).map(|s| admitted[s % admitted.len()]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ddrs(n: usize, cap: u64) -> Vec<DeviceDdr> {
        (0..n).map(|_| DeviceDdr::new(cap)).collect()
    }

    #[test]
    fn prefers_resident_device() {
        let mut d = ddrs(2, 1000);
        d[1].admit(PointSetId(7), 500);
        // device 1 holds set 7 but is more loaded — affinity still wins
        let r = route(&mut d, &[0, 10], PointSetId(7), 500).unwrap();
        assert_eq!(r.device, 1);
        assert_eq!(r.admission, Admission::Hit);
    }

    #[test]
    fn least_loaded_on_miss() {
        let mut d = ddrs(3, 1000);
        let r = route(&mut d, &[5, 2, 9], PointSetId(1), 100).unwrap();
        assert_eq!(r.device, 1);
        assert!(matches!(r.admission, Admission::Miss { .. }));
    }

    #[test]
    fn skips_too_small_devices() {
        let mut d = vec![DeviceDdr::new(50), DeviceDdr::new(5000)];
        let r = route(&mut d, &[0, 10], PointSetId(1), 100).unwrap();
        assert_eq!(r.device, 1);
    }

    #[test]
    fn none_when_nothing_fits() {
        let mut d = ddrs(2, 10);
        assert_eq!(route(&mut d, &[0, 0], PointSetId(1), 100), None);
    }

    #[test]
    fn ties_break_to_holder_with_lowest_load() {
        let mut d = ddrs(3, 1000);
        d[0].admit(PointSetId(3), 100);
        d[2].admit(PointSetId(3), 100);
        let r = route(&mut d, &[7, 0, 4], PointSetId(3), 100).unwrap();
        assert_eq!(r.device, 2);
    }

    #[test]
    fn spread_uses_distinct_devices() {
        let mut d = ddrs(4, 1000);
        let routes = route_spread(&mut d, &[0, 0, 0, 0], PointSetId(1), 100, 4).unwrap();
        let mut devs: Vec<usize> = routes.iter().map(|r| r.device).collect();
        devs.sort_unstable();
        devs.dedup();
        assert_eq!(devs.len(), 4, "4 shards over 4 devices must not share");
        // every chosen device now holds the set
        for r in &routes {
            assert!(d[r.device].is_resident(PointSetId(1)));
        }
    }

    #[test]
    fn spread_prefers_resident_then_least_loaded() {
        let mut d = ddrs(3, 1000);
        d[2].admit(PointSetId(5), 100);
        let routes = route_spread(&mut d, &[1, 0, 9], PointSetId(5), 100, 2).unwrap();
        // holder (2) first despite its load, then the least-loaded (1)
        assert_eq!(routes[0].device, 2);
        assert_eq!(routes[0].admission, Admission::Hit);
        assert_eq!(routes[1].device, 1);
    }

    #[test]
    fn weighted_route_skips_devices_whose_budget_overflows() {
        // device 0 would hold the endo-expanded (2x) set — too large for
        // its DDR; device 1 runs full-width and fits. The weighted router
        // must charge each device its own figure.
        let mut d = vec![DeviceDdr::new(1000), DeviceDdr::new(1000)];
        let loads = vec![0usize, 5]; // device 0 preferred by load
        let r = route_weighted(&mut d, &loads, PointSetId(1), &[1200, 600]).expect("routes");
        assert_eq!(r.device, 1);
        assert_eq!(r.admission, Admission::Miss { upload_bytes: 600, evicted: 0 });
        assert!(!d[0].is_resident(PointSetId(1)));
        assert!(d[1].is_resident(PointSetId(1)));
        // nobody fits → None
        assert!(route_weighted(&mut d, &loads, PointSetId(2), &[1200, 1200]).is_none());
    }

    #[test]
    fn spread_wraps_when_fewer_devices_admit() {
        // only device 1 can hold the set: all 3 shards land there
        let mut d = vec![DeviceDdr::new(50), DeviceDdr::new(5000)];
        let routes = route_spread(&mut d, &[0, 0], PointSetId(1), 100, 3).unwrap();
        assert_eq!(routes.len(), 3);
        assert!(routes.iter().all(|r| r.device == 1));
    }

    #[test]
    fn spread_none_when_nothing_fits() {
        let mut d = ddrs(2, 10);
        assert!(route_spread(&mut d, &[0, 0], PointSetId(1), 100, 2).is_none());
    }
}
