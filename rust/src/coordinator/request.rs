//! Job types flowing through the coordinator.

use super::admission::{Lane, RejectReason};
use crate::ec::ScalarLimbs;
use std::fmt;
use std::sync::Arc;

/// Identifies a registered base-point set (the MSM's constant input — one
/// per circuit/CRS in a proving farm).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PointSetId(pub u64);

/// Job identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobId(pub u64);

/// Membership of a shard sub-job in its shard group. The batcher uses
/// this to keep a group together (a group flushes in exactly one batch —
/// it completes or fails atomically downstream); the dispatcher uses it to
/// look up the group state and spread shards across devices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardAssignment {
    /// Group key (the client-visible sharded job's id).
    pub group: u64,
    /// Shard position within the group's spec list.
    pub index: u32,
    /// Total shards in the group.
    pub total: u32,
}

/// One MSM request: scalars against a resident point set.
#[derive(Clone, Debug)]
pub struct MsmJob {
    /// The job's id (allocated at submit).
    pub id: JobId,
    /// The registered point set the scalars pair with.
    pub point_set: PointSetId,
    /// Scalars (shared — jobs are fanned out to worker threads).
    pub scalars: Arc<Vec<ScalarLimbs>>,
    /// Submission timestamp (for latency accounting).
    pub submitted_at: std::time::Instant,
    /// `Some` when this job is one shard of a sharded job.
    pub shard: Option<ShardAssignment>,
}

/// Typed failure of a served job — every way the coordinator can fail a
/// job without dropping its reply channel. The `Display` impl preserves
/// the legacy string messages (pre-typed-error logs and tests matched on
/// substrings like `"failed atomically"`), so it is the only place error
/// text is rendered.
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// The executing device returned an error (message as formatted by
    /// the device backend, e.g. an injected fault or an engine error).
    DeviceFailed(String),
    /// A shard group failed atomically: some shard exhausted its retry
    /// budget (or the group could not be routed/assembled). The payload
    /// is the detail; `Display` adds the historical
    /// `"shard group failed atomically: "` prefix.
    ShardExhausted(String),
    /// Admission control refused the job at submit time.
    Rejected {
        /// The lane the job was offered to.
        lane: Lane,
        /// Why admission shed it.
        reason: RejectReason,
    },
    /// No registered device's DDR can hold the job's point set.
    TooLarge,
    /// A streaming chunk source failed mid-prove (read failure, short
    /// chunk, malformed chunk file, or a budget that cannot hold one
    /// element). The prover surfaces this instead of a wrong proof or
    /// partial state; retrying with a fresh stream is bit-identical.
    StreamFailed(String),
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::DeviceFailed(msg) => f.write_str(msg),
            JobError::ShardExhausted(detail) => {
                write!(f, "shard group failed atomically: {detail}")
            }
            JobError::Rejected { lane, reason } => {
                write!(f, "admission rejected ({lane} lane): {reason}")
            }
            JobError::TooLarge => f.write_str("no device can hold the point set"),
            JobError::StreamFailed(detail) => {
                write!(f, "streaming chunk source failed: {detail}")
            }
        }
    }
}

impl std::error::Error for JobError {}

impl From<crate::msm::stream::StreamError> for JobError {
    fn from(e: crate::msm::stream::StreamError) -> Self {
        JobError::StreamFailed(e.to_string())
    }
}

/// Result of a completed job. Device failures are **delivered**, not
/// dropped: a worker whose `execute` errors sends a result with
/// [`JobResult::error`] set (and `output` at the identity), so callers can
/// distinguish "the device failed on this job" from "the coordinator shut
/// down" (reply channel disconnect → `RecvError`).
#[derive(Clone, Debug)]
pub struct JobResult<P> {
    /// The id the result answers.
    pub id: JobId,
    /// The MSM output point (the group identity when `error` is set).
    pub output: P,
    /// Wall-clock service time (host side).
    pub service_s: f64,
    /// Modeled device time (for sim-FPGA backends; equals wall time for
    /// native backends; 0 on failure).
    pub device_s: f64,
    /// Which device executed it.
    pub device: usize,
    /// Whether the point set had to be uploaded first (affinity miss).
    pub upload_miss: bool,
    /// The typed failure, `None` on success.
    pub error: Option<JobError>,
}

impl<P> JobResult<P> {
    /// Did the device produce a valid output?
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// The rendered error message, if the job failed (legacy string view;
    /// matches what `error.to_string()` produces).
    pub fn error_message(&self) -> Option<String> {
        self.error.as_ref().map(JobError::to_string)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_error_display_preserves_legacy_messages() {
        let e = JobError::ShardExhausted("shard 1 has no untried device left".into());
        assert!(e.to_string().contains("failed atomically"), "{e}");
        assert_eq!(JobError::TooLarge.to_string(), "no device can hold the point set");
        let e = JobError::DeviceFailed("injected device fault".into());
        assert_eq!(e.to_string(), "injected device fault");
        let e = JobError::Rejected { lane: Lane::BestEffort, reason: RejectReason::QuotaExhausted };
        assert!(e.to_string().contains("best-effort"), "{e}");
        assert!(e.to_string().contains("quota"), "{e}");
    }

    #[test]
    fn stream_errors_convert_to_typed_job_errors() {
        use crate::msm::stream::StreamError;
        let e: JobError =
            StreamError::ShortChunk { chunk: 3, expected: 64, got: 63 }.into();
        assert!(matches!(e, JobError::StreamFailed(_)));
        assert!(e.to_string().contains("streaming chunk source failed"), "{e}");
        assert!(e.to_string().contains("short chunk 3"), "{e}");
    }

    #[test]
    fn ids_are_ordered_and_hashable() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(PointSetId(1));
        s.insert(PointSetId(1));
        s.insert(PointSetId(2));
        assert_eq!(s.len(), 2);
        assert!(PointSetId(1) < PointSetId(2));
    }
}
