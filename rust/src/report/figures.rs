//! Figure series generators (CSV, plot-ready).

use super::csv_block;
use crate::baseline::{CpuBaseline, GpuModel};
use crate::fpga::{power, CurveId, DesignVariant, NumberForm, SabConfig, SabModel};

/// Sizes swept by the paper's figures (log-spaced 1K → 64M).
pub fn sweep_sizes() -> Vec<u64> {
    let mut v = Vec::new();
    let mut m = 1_000u64;
    while m <= 64_000_000 {
        v.push(m);
        v.push(m * 2);
        v.push(m * 5);
        m *= 10;
    }
    v.retain(|&x| x <= 64_000_000);
    v
}

/// Figure 4 — CPU throughput (M-MSM-PPS) vs MSM size, both curves
/// (libsnark-calibrated model; the measured series is produced by the
/// bench, which appends locally-timed rows).
pub fn fig4_cpu_throughput() -> String {
    let bn = CpuBaseline::for_curve(CurveId::Bn254);
    let bls = CpuBaseline::for_curve(CurveId::Bls12381);
    let rows: Vec<Vec<String>> = sweep_sizes()
        .iter()
        .map(|&m| {
            vec![
                m.to_string(),
                format!("{:.4}", bn.throughput_mpps(m, true)),
                format!("{:.4}", bls.throughput_mpps(m, true)),
            ]
        })
        .collect();
    csv_block(
        "Figure 4: CPU MSM throughput (M-MSM-PPS), single-thread libsnark model",
        &["msm_size", "bn128_mpps", "bls12_381_mpps"],
        &rows,
    )
}

/// Figure 6 — FPGA throughput vs size, curve × scaling.
pub fn fig6_fpga_throughput() -> String {
    let models: Vec<(String, SabModel)> = [
        (CurveId::Bn254, 1u32),
        (CurveId::Bn254, 2),
        (CurveId::Bls12381, 1),
        (CurveId::Bls12381, 2),
    ]
    .into_iter()
    .map(|(c, s)| (format!("{}_s{}", c.name(), s), SabModel::new(SabConfig::paper(c, s))))
    .collect();

    let mut rows = Vec::new();
    for m in sweep_sizes() {
        let mut row = vec![m.to_string()];
        for (_, model) in &models {
            row.push(format!("{:.4}", model.time_msm(m).m_msm_pps(m)));
        }
        rows.push(row);
    }
    let headers: Vec<String> =
        std::iter::once("msm_size".to_string()).chain(models.iter().map(|(n, _)| n.clone())).collect();
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    csv_block("Figure 6: FPGA MSM throughput (M-MSM-PPS) across curve and scaling", &hdr_refs, &rows)
}

/// Figures 5 and 7 — power-normalized FPGA throughput (M-MSM-PPS/W),
/// S=1 vs S=2, one figure per curve.
pub fn fig5_7_power_normalized(curve: CurveId) -> String {
    let variant = DesignVariant {
        bits: curve.field_bits(),
        form: NumberForm::Standard,
        unified: true,
    };
    let mut rows = Vec::new();
    for m in sweep_sizes() {
        let mut row = vec![m.to_string()];
        for s in [1u32, 2] {
            let model = SabModel::new(SabConfig::paper(curve, s));
            let tp = model.time_msm(m).m_msm_pps(m);
            let w = power::estimate(variant, s).active_w;
            row.push(format!("{:.5}", tp / w));
        }
        rows.push(row);
    }
    let fig = if curve == CurveId::Bn254 { 5 } else { 7 };
    csv_block(
        &format!(
            "Figure {fig}: FPGA power-normalized throughput (M-MSM-PPS/W), {}",
            curve.name()
        ),
        &["msm_size", "s1_mpps_per_w", "s2_mpps_per_w"],
        &rows,
    )
}

/// Figure 8 — FPGA vs GPU normalized throughput (and per-watt), BLS12-381.
pub fn fig8_fpga_vs_gpu() -> String {
    let curve = CurveId::Bls12381;
    let fpga = SabModel::new(SabConfig::paper(curve, 2));
    let gpu = GpuModel::t4_bellperson(curve).unwrap();
    let variant =
        DesignVariant { bits: curve.field_bits(), form: NumberForm::Standard, unified: true };
    let w_fpga = power::estimate(variant, 2).active_w;
    let mut rows = Vec::new();
    for m in sweep_sizes() {
        let t_f = fpga.time_msm(m).m_msm_pps(m);
        let t_g = gpu.throughput_mpps(m);
        rows.push(vec![
            m.to_string(),
            format!("{t_f:.4}"),
            format!("{t_g:.4}"),
            format!("{:.5}", t_f / w_fpga),
            format!("{:.5}", gpu.throughput_per_watt(m)),
        ]);
    }
    csv_block(
        "Figure 8: FPGA vs GPU throughput and per-watt, BLS12-381",
        &["msm_size", "fpga_mpps", "gpu_mpps", "fpga_mpps_per_w", "gpu_mpps_per_w"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_sorted_and_bounded() {
        let s = sweep_sizes();
        assert_eq!(s.first(), Some(&1_000));
        assert_eq!(s.last(), Some(&50_000_000).or(s.last())); // contains 64M? check max
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(*s.last().unwrap() <= 64_000_000);
        assert!(s.contains(&64_000_000) || *s.last().unwrap() == 50_000_000);
    }

    #[test]
    fn fig4_has_both_curves_flat_tail() {
        let f = fig4_cpu_throughput();
        assert!(f.contains("bn128_mpps"));
        let lines: Vec<&str> = f.lines().collect();
        let last = lines.last().unwrap().split(',').nth(1).unwrap();
        let v: f64 = last.parse().unwrap();
        assert!((v - 0.06).abs() < 0.01, "BN plateau {v}");
    }

    #[test]
    fn fig6_scaling_ratio_near_2() {
        let f = fig6_fpga_throughput();
        let last = f.lines().last().unwrap();
        let cells: Vec<f64> = last.split(',').skip(1).map(|c| c.parse().unwrap()).collect();
        // columns: bn_s1, bn_s2, bls_s1, bls_s2
        assert!((cells[1] / cells[0] - 2.0).abs() < 0.3, "bn scaling {}", cells[1] / cells[0]);
        assert!((cells[3] / cells[2] - 2.0).abs() < 0.3, "bls scaling {}", cells[3] / cells[2]);
    }

    #[test]
    fn fig5_7_power_efficiency_improves_with_s() {
        for curve in [CurveId::Bn254, CurveId::Bls12381] {
            let f = fig5_7_power_normalized(curve);
            let last = f.lines().last().unwrap();
            let cells: Vec<f64> = last.split(',').skip(1).map(|c| c.parse().unwrap()).collect();
            assert!(cells[1] > 1.5 * cells[0], "{curve:?}: {cells:?}");
        }
    }

    #[test]
    fn fig8_fpga_wins_at_large_sizes() {
        let f = fig8_fpga_vs_gpu();
        let last = f.lines().last().unwrap();
        let cells: Vec<f64> = last.split(',').skip(1).map(|c| c.parse().unwrap()).collect();
        let (fpga, gpu, fpga_w, gpu_w) = (cells[0], cells[1], cells[2], cells[3]);
        // paper: FPGA ≈1.14x GPU at 64M, and 16–51% better per watt
        assert!(fpga / gpu > 1.0 && fpga / gpu < 1.6, "throughput ratio {}", fpga / gpu);
        assert!(fpga_w / gpu_w > 1.1, "per-watt ratio {}", fpga_w / gpu_w);
    }
}
