//! Table generators (paper values printed beside measured/modeled ones).

use super::{ascii_table, f2};
use crate::baseline::{CpuBaseline, GpuModel};
use crate::ec::{Bls12381G1, Bls12381G2, Bn254G1, Bn254G2};
use crate::ff::params::{Bls12381FrParams, Bn254FrParams};
use crate::fpga::rbam::ReductionKind;
use crate::fpga::{
    power, resources::TABLE_V_VARIANTS, CurveId, DesignVariant, NttKernelConfig, NttModel,
    NumberForm, ResourceModel, SabConfig, SabModel,
};
use crate::msm::{
    self, pippenger, Decomposition, MsmConfig, MsmPlan, Reduction, ShardPolicy, Slicing,
};
use crate::snark::{circuits, prover::Prover, setup::Crs};

/// Table I — prover profiling (measured on this host vs paper).
pub fn table1(n_constraints: usize, seed: u64) -> String {
    let mut rows = Vec::new();

    // BN254 family
    {
        let cs = circuits::mul_chain::<Bn254FrParams, 4>(n_constraints, seed);
        let n = cs.num_constraints().max(2).next_power_of_two();
        let crs = Crs::<Bn254G1, Bn254G2>::synthesize(cs.num_variables(), n, seed ^ 1);
        let (_, prof) = Prover::new(crs).prove(&cs);
        rows.push(vec![
            "BN128 (ours)".into(),
            f2(prof.msm_g1_pct),
            f2(prof.msm_g2_pct),
            f2(prof.ntt_pct),
            f2(prof.other_pct),
        ]);
        rows.push(vec![
            "BN128 (paper)".into(),
            "37".into(),
            "51".into(),
            "11".into(),
            "1".into(),
        ]);
    }
    // BLS12-381 family
    {
        let cs = circuits::mul_chain::<Bls12381FrParams, 4>(n_constraints, seed);
        let n = cs.num_constraints().max(2).next_power_of_two();
        let crs = Crs::<Bls12381G1, Bls12381G2>::synthesize(cs.num_variables(), n, seed ^ 2);
        let (_, prof) = Prover::new(crs).prove(&cs);
        rows.push(vec![
            "BLS12-381 (ours)".into(),
            f2(prof.msm_g1_pct),
            f2(prof.msm_g2_pct),
            f2(prof.ntt_pct),
            f2(prof.other_pct),
        ]);
        rows.push(vec![
            "BLS12-381 (paper)".into(),
            "33".into(),
            "59".into(),
            "7".into(),
            "1".into(),
        ]);
    }
    ascii_table(
        &format!("Table I: prover profiling, {} constraints (%)", n_constraints),
        &["curve", "MSM-G1", "MSM-G2", "NTT", "other"],
        &rows,
    )
}

/// Tables II + III — modular-multiplication counts, double-and-add vs
/// bucket method, *measured* by the op counters.
pub fn table2_3(m: usize, seed: u64) -> String {
    let mut rows = Vec::new();
    // Work on BN254 G1 and BLS12-381 G1 with paper-width scalars.
    fn measure<C: crate::ec::CurveParams>(
        m: usize,
        seed: u64,
        label: &str,
        rows: &mut Vec<Vec<String>>,
        paper_naive_per_point: u64,
        paper_bucket_point_ops: u64,
    ) {
        let w = crate::ec::points::workload::<C>(m, seed);
        // naive double-and-add
        let before = crate::ff::opcount::snapshot();
        let a = msm::naive::msm(&w.points, &w.scalars);
        let naive_ops = crate::ff::opcount::snapshot() - before;

        // bucket method, hardware window k=12, unsigned buckets (the
        // published hardware's accounting; the signed variant is compared
        // in `ablation_signed`)
        let cfg = MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 });
        let before = crate::ff::opcount::snapshot();
        let (b, cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
        let bucket_ops = crate::ff::opcount::snapshot() - before;
        assert!(a.eq_point(&b), "algorithms disagree");

        let naive_mm = naive_ops.modmuls();
        let bucket_mm = bucket_ops.modmuls();
        rows.push(vec![
            label.to_string(),
            format!("m x {} (paper m x {})", naive_mm / m as u64, paper_naive_per_point),
            format!("{bucket_mm}"),
            format!("{:.1}x", naive_mm as f64 / bucket_mm as f64),
            // Table III counts the BAM's *fill* ops (reduce is recursive/
            // amortized in hardware): ours per point vs paper's m×22/32
            format!(
                "m x {:.1} (paper m x {})",
                cost.fill_ops as f64 / m as f64,
                paper_bucket_point_ops
            ),
            format!("m x {:.1}", cost.total_point_ops() as f64 / m as f64),
        ]);
    }
    // paper: BN m×(2·254·16) modmuls naive; bucket m×22 fill point-ops
    measure::<Bn254G1>(m, seed, "BN128", &mut rows, 2 * 254 * 16, 22);
    measure::<Bls12381G1>(m, seed, "BLS12-381", &mut rows, 2 * 381 * 16, 32);
    ascii_table(
        &format!("Tables II+III: measured op counts, m = {m} (reduce-phase cost amortizes as m grows)"),
        &[
            "curve",
            "naive modmuls/pt",
            "bucket modmuls",
            "reduction",
            "fill ops/pt",
            "total ops/pt",
        ],
        &rows,
    )
}

/// Tables IV + V — point-processor resources (model vs paper).
pub fn table4_5() -> String {
    let model = ResourceModel;
    let paper = [
        (372_700.0, 5005.0, 742.0),
        (290_400.0, 5400.0, 647.0),
        (207_000.0, 1975.0, 3367.0),
        (419_000.0, 4425.0, 6770.0),
    ];
    let mut rows = Vec::new();
    for (v, (pa, pd, pm)) in TABLE_V_VARIANTS.iter().zip(paper) {
        let r = model.point_processor(*v);
        rows.push(vec![
            v.label(),
            format!("{:.0} / {pa:.0}", r.alms),
            format!("{:.0} / {pd:.0}", r.dsps),
            format!("{:.0} / {pm:.0}", r.m20ks),
        ]);
    }
    ascii_table(
        "Tables IV+V: EC adder resources (model / paper)",
        &["variant", "ALMs", "DSPs", "M20K"],
        &rows,
    )
}

/// Table VII — system-level resources.
pub fn table7() -> String {
    let model = ResourceModel;
    let cases: [(DesignVariant, u32, [f64; 3]); 5] = [
        (
            DesignVariant { bits: 254, form: NumberForm::Montgomery, unified: false },
            2,
            [715_603.0, 5005.0, 4642.0],
        ),
        (
            DesignVariant { bits: 254, form: NumberForm::Standard, unified: true },
            2,
            [571_408.0, 1975.0, 6501.0],
        ),
        (
            DesignVariant { bits: 254, form: NumberForm::Standard, unified: true },
            1,
            [537_348.0, 1975.0, 5616.0],
        ),
        (
            DesignVariant { bits: 381, form: NumberForm::Standard, unified: true },
            2,
            [831_972.0, 4425.0, 10_973.0],
        ),
        (
            DesignVariant { bits: 381, form: NumberForm::Standard, unified: true },
            1,
            [770_561.0, 4425.0, 9_662.0],
        ),
    ];
    let mut rows = Vec::new();
    for (v, s, p) in cases {
        let r = model.system(v, s);
        let fmax = model.system_fmax(v, s) / 1e6;
        rows.push(vec![
            format!("{} (S={s})", v.label()),
            format!("{:.0} / {:.0}", r.alms, p[0]),
            format!("{:.0} / {:.0}", r.dsps, p[1]),
            format!("{:.0} / {:.0}", r.m20ks, p[2]),
            format!("{fmax:.0} MHz"),
        ]);
    }
    ascii_table(
        "Table VII: system resources (model / paper)",
        &["variant", "ALMs", "DSPs", "M20K", "fmax (model)"],
        &rows,
    )
}

/// Table VIII — power (model vs paper).
pub fn table8() -> String {
    let cases: [(&str, Option<(DesignVariant, u32)>, f64, f64); 6] = [
        ("oneAPI BSP only", None, 17.25, f64::NAN),
        (
            "BN128 PAPD (S=1)",
            Some((DesignVariant { bits: 254, form: NumberForm::Montgomery, unified: false }, 1)),
            44.6,
            72.7,
        ),
        (
            "BN128 UDA (S=1)",
            Some((DesignVariant { bits: 254, form: NumberForm::Standard, unified: true }, 1)),
            42.6,
            58.0,
        ),
        (
            "BN128 UDA (S=2)",
            Some((DesignVariant { bits: 254, form: NumberForm::Standard, unified: true }, 2)),
            44.7,
            63.5,
        ),
        (
            "BLS12-381 UDA (S=1)",
            Some((DesignVariant { bits: 381, form: NumberForm::Standard, unified: true }, 1)),
            48.8,
            63.1,
        ),
        (
            "BLS12-381 UDA (S=2)",
            Some((DesignVariant { bits: 381, form: NumberForm::Standard, unified: true }, 2)),
            50.4,
            68.6,
        ),
    ];
    let mut rows = Vec::new();
    for (label, build, p_standby, p_active) in cases {
        match build {
            None => rows.push(vec![
                label.into(),
                format!("{:.2} / {:.2}", crate::fpga::calib::POWER_BSP_W, p_standby),
                "N/A".into(),
            ]),
            Some((v, s)) => {
                let e = power::estimate(v, s);
                rows.push(vec![
                    label.into(),
                    format!("{:.1} / {:.1}", e.standby_w, p_standby),
                    format!("{:.1} / {:.1}", e.active_w, p_active),
                ]);
            }
        }
    }
    ascii_table(
        "Table VIII: power, 64M-point MSM (model / paper, W)",
        &["design variant", "standby", "active"],
        &rows,
    )
}

/// Table IX — execution-time comparison for BLS12-381 (CPU model+measured,
/// GPU model, FPGA model). `measure_cpu_up_to` caps the locally-executed
/// sizes.
pub fn table9(measure_cpu_up_to: usize) -> String {
    let sizes: [u64; 10] = [
        1_000, 10_000, 100_000, 1_000_000, 2_000_000, 4_000_000, 8_000_000, 16_000_000,
        32_000_000, 64_000_000,
    ];
    let cpu = CpuBaseline::for_curve(CurveId::Bls12381);
    let gpu = GpuModel::t4_bellperson(CurveId::Bls12381).unwrap();
    let fpga = SabModel::new(SabConfig::paper(CurveId::Bls12381, 2));
    let paper_fpga = [0.01, 0.02, 0.03, 0.24, 0.47, 0.94, 1.88, 3.76, 7.51, 15.03];

    let mut rows = Vec::new();
    for (i, &m) in sizes.iter().enumerate() {
        let t_cpu = cpu.model_seconds(m);
        let cpu_meas = if (m as usize) <= measure_cpu_up_to {
            let meas =
                crate::baseline::cpu::measure_parallel::<Bls12381G1>(m as usize, 0xC0FE + m, 0);
            format!("{:.2}", meas.seconds)
        } else {
            "-".into()
        };
        let t_gpu = gpu.seconds(m);
        let t_fpga = fpga.time_msm(m).total_s();
        rows.push(vec![
            crate::util::human_count(m),
            format!("{t_cpu:.2}"),
            cpu_meas,
            format!("{t_gpu:.2}"),
            format!("{t_fpga:.2} / {:.2}", paper_fpga[i]),
            format!("{:.0}x", t_cpu / t_fpga),
            format!("{:.2}x", t_gpu / t_fpga),
        ]);
    }
    ascii_table(
        "Table IX: BLS12-381 execution time (s); FPGA column: model / paper",
        &["MSM size", "CPU(model)", "CPU(measured)", "GPU(model)", "FPGA", "xCPU", "xGPU"],
        &rows,
    )
}

/// Table X — 64M summary: time + power for the three devices.
pub fn table10() -> String {
    let m = 64_000_000u64;
    let mut rows = Vec::new();
    for curve in [CurveId::Bn254, CurveId::Bls12381] {
        let cpu = CpuBaseline::for_curve(curve).model_seconds(m);
        let fpga_model = SabModel::new(SabConfig::paper(curve, 2));
        let t_fpga = fpga_model.time_msm(m).total_s();
        let p_fpga = power::estimate(
            DesignVariant { bits: curve.field_bits(), form: NumberForm::Standard, unified: true },
            2,
        )
        .active_w;
        let (t_gpu, p_gpu) = match GpuModel::t4_bellperson(curve) {
            Some(g) => (format!("{:.1}", g.seconds(m)), format!("{:.0}", g.power_w)),
            None => ("NA".into(), "NA".into()),
        };
        rows.push(vec![
            curve.name().into(),
            format!("{cpu:.0}"),
            t_gpu,
            format!("{t_fpga:.1}"),
            "NA".into(),
            p_gpu,
            format!("{p_fpga:.0}"),
        ]);
    }
    ascii_table(
        "Table X: 64M-point MSM — exec time (s) and power (W) [CPU, GPU, FPGA]",
        &["curve", "t CPU", "t GPU", "t FPGA", "P CPU", "P GPU", "P FPGA"],
        &rows,
    )
}

/// Ablation (beyond the paper's tables, motivated by §IV-A): IS-RBAM vs
/// running-sum reduction at system level.
pub fn ablation_reduction() -> String {
    let mut rows = Vec::new();
    for curve in [CurveId::Bn254, CurveId::Bls12381] {
        for m in [10_000u64, 1_000_000, 64_000_000] {
            let mut cfg = SabConfig::paper(curve, 2);
            let rec = SabModel::new(cfg).time_msm(m).total_s();
            cfg.reduction = ReductionKind::RunningSum;
            let rs = SabModel::new(cfg).time_msm(m).total_s();
            rows.push(vec![
                curve.name().into(),
                crate::util::human_count(m),
                format!("{rs:.4}"),
                format!("{rec:.4}"),
                format!("{:.2}x", rs / rec),
            ]);
        }
    }
    ascii_table(
        "Ablation: bucket-reduction strategy (total MSM seconds)",
        &["curve", "size", "running-sum", "IS-RBAM", "speedup"],
        &rows,
    )
}

/// Ablation (beyond the paper, motivated by SZKP's signed buckets): at
/// equal window width k, signed-digit slicing halves the live bucket count
/// — and with it both the serial reduce chain (the thing IS-RBAM exists to
/// shorten) and the bucket memory — at the cost of one extra carry window.
/// Measured software reduce ops (running sum, dense windows) sit next to
/// the plan's analytic chain length, bit-exactness asserted against naive.
pub fn ablation_signed(m: usize, seed: u64) -> String {
    let k = 8u32; // dense at test sizes: every live bucket is occupied
    let mut rows = Vec::new();
    let w = crate::ec::points::workload::<Bn254G1>(m, seed);
    let want = msm::naive::msm(&w.points, &w.scalars);
    for slicing in [Slicing::Unsigned, Slicing::Signed] {
        let cfg = MsmConfig {
            window_bits: k,
            reduction: Reduction::RunningSum,
            slicing,
            ..Default::default()
        };
        let plan = MsmPlan::for_curve::<Bn254G1>(&cfg);
        let (got, cost) = pippenger::msm_with_cost(&w.points, &w.scalars, &cfg);
        assert!(got.eq_point(&want), "signed ablation diverged from naive");
        rows.push(vec![
            format!("{slicing:?}"),
            format!("{}", plan.live_buckets()),
            format!("{}", plan.windows),
            format!("{}", plan.serial_reduce_ops_per_window()),
            format!("{}", cost.reduce_ops / plan.windows as u64),
            format!("{}", cost.fill_ops),
        ]);
    }
    ascii_table(
        &format!("Ablation: signed-digit buckets, BN254, k={k}, m={m} (bit-exact vs naive)"),
        &[
            "slicing",
            "buckets/window",
            "windows",
            "serial ops/window (plan)",
            "reduce ops/window (measured)",
            "fill ops",
        ],
        &rows,
    )
}

/// Ablation (beyond the paper, motivated by the GLV endomorphism on the
/// a = 0 curves): splitting every scalar `k ≡ k1 + k2·λ (mod r)` against
/// the doubled (P, φ(P)) point set halves the k-bit window passes, so the
/// serially-dependent reduce chain and the DNA combine drop ~2x *on top
/// of* signed digits, while bucket memory stays put and DDR point
/// residency doubles. Total fill/stream work is unchanged when the window
/// count halves exactly (BN128: 22 → 11); BLS12-381's half-width slices
/// keep a carry window (32 → 17), costing ~6% extra streaming in the
/// stream-bound regime — the table reports that honestly. Bit-exactness
/// of the software fast path is asserted against the plain path before
/// the model rows print.
pub fn ablation_glv(m: usize, seed: u64) -> String {
    // software cross-check: GLV on vs off through the shared dispatch
    let w = crate::ec::points::workload::<Bn254G1>(m, seed);
    let cfg = MsmConfig::new(12, Reduction::default());
    let want = msm::execute(msm::Backend::Pippenger, &w.points, &w.scalars, &cfg);
    let got = msm::execute(msm::Backend::Pippenger, &w.points, &w.scalars, &cfg.glv());
    assert!(got.eq_point(&want), "GLV path diverged from the plain path");

    let mut rows = Vec::new();
    for curve in [CurveId::Bn254, CurveId::Bls12381] {
        for m in [10_000u64, 1_000_000, 64_000_000] {
            let signed = SabConfig::paper_signed(curve, 2);
            let glv = SabConfig::paper_glv(curve, 2);
            let t_signed = SabModel::new(signed).time_msm(m).total_s();
            let t_glv = SabModel::new(glv).time_msm(m).total_s();
            rows.push(vec![
                curve.name().into(),
                crate::util::human_count(m),
                format!("{}", signed.plan().windows),
                format!("{}", glv.plan().windows),
                format!("{}", signed.plan().serial_reduce_ops()),
                format!("{}", glv.plan().serial_reduce_ops()),
                format!("{t_signed:.4}"),
                format!("{t_glv:.4}"),
                format!("{:.2}x", t_signed / t_glv),
            ]);
        }
    }
    ascii_table(
        &format!(
            "Ablation: GLV endomorphism split, S=2 (modeled s; software bit-exact at m = {m})"
        ),
        &[
            "curve",
            "size",
            "win signed",
            "win glv",
            "serial ops signed",
            "serial ops glv",
            "t signed",
            "t glv",
            "speedup",
        ],
        &rows,
    )
}

/// Ablation (beyond the paper, the SRS point-cache what-if): fixed-base
/// precompute tables vs live Pippenger, speedup against table size as the
/// window width sweeps. Each row builds a [`msm::PrecompTable`] on the
/// signed+GLV plan at width `k` (BN254 G1), asserts bit-exactness against
/// the shared Pippenger on the same config, then reports the table's DDR
/// footprint next to measured seconds for both paths — the table build
/// itself stays off the timed path ([`crate::baseline::cpu::measure_precomputed_with`]'s
/// amortization convention). The modeled column is the SAB what-if
/// ([`SabConfig::paper_tables`] vs [`SabConfig::paper_glv`] at 1M points)
/// and only exists at the hardware window width — the FPGA build pins
/// `k`, the software sweep does not.
pub fn ablation_pointcache(m: usize, seed: u64) -> String {
    let w = crate::ec::points::workload::<Bn254G1>(m, seed);
    let hw_k = crate::fpga::calib::HW_WINDOW_BITS;
    let modeled = {
        let glv = SabModel::new(SabConfig::paper_glv(CurveId::Bn254, 2));
        let tab = SabModel::new(SabConfig::paper_tables(CurveId::Bn254, 2));
        glv.time_msm(1_000_000).total_s() / tab.time_msm(1_000_000).total_s()
    };
    let mut rows = Vec::new();
    for k in [8u32, 10, hw_k] {
        let cfg = MsmConfig {
            window_bits: k,
            reduction: Reduction::default(),
            slicing: Slicing::Signed,
            decomposition: Decomposition::Glv,
        };
        let table = msm::PrecompTable::<Bn254G1>::build(&w.points, &cfg);
        let want = msm::execute(msm::Backend::Pippenger, &w.points, &w.scalars, &cfg);
        assert!(
            table.msm(&w.scalars).eq_point(&want),
            "table-fed path diverged from Pippenger at k={k}"
        );
        let live = crate::baseline::cpu::measure_backend_with::<Bn254G1>(
            m,
            seed,
            msm::Backend::Pippenger,
            &cfg,
        );
        let fed = crate::baseline::cpu::measure_precomputed_with::<Bn254G1>(m, seed, &cfg);
        rows.push(vec![
            format!("{k}"),
            format!("{}", table.windows()),
            format!("{}", table.bytes()),
            format!("{:.4}", live.seconds),
            format!("{:.4}", fed.seconds),
            format!("{:.2}x", live.seconds / fed.seconds),
            if k == hw_k { format!("{modeled:.2}x") } else { "-".into() },
        ]);
    }
    ascii_table(
        &format!(
            "Ablation: fixed-base precompute tables, BN254 signed+GLV, m = {m} (bit-exact vs \
             Pippenger; modeled column at the hardware k only)"
        ),
        &[
            "k",
            "windows",
            "table bytes",
            "t pippenger",
            "t table-fed",
            "measured speedup",
            "modeled speedup",
        ],
        &rows,
    )
}

/// What-if (beyond the paper, the coordinator's multi-device path
/// modeled): one m-point MSM sharded across replicated kernels. Chunk
/// sharding splits the point/scalar stream per kernel; window sharding
/// broadcasts the scalars and splits the k-bit window ranges. Speedups
/// are against the single-kernel build of the same curve.
pub fn whatif_multi_kernel(m: u64) -> String {
    let mut rows = Vec::new();
    for curve in [CurveId::Bn254, CurveId::Bls12381] {
        let model = SabModel::new(SabConfig::paper(curve, 2));
        let base = model.time_msm(m).total_s();
        for d in [1u32, 2, 4, 8] {
            let tc = model.time_msm_sharded(m, d, ShardPolicy::ChunkPoints).total_s();
            let tw = model.time_msm_sharded(m, d, ShardPolicy::WindowRange).total_s();
            rows.push(vec![
                curve.name().into(),
                format!("{d}"),
                format!("{tc:.3}"),
                format!("{:.2}x", base / tc),
                format!("{tw:.3}"),
                format!("{:.2}x", base / tw),
            ]);
        }
    }
    ascii_table(
        &format!(
            "What-if: multi-kernel sharded MSM, m = {} (modeled seconds; speedup vs 1 kernel)",
            crate::util::human_count(m)
        ),
        &["curve", "kernels", "chunk t", "chunk speedup", "window t", "window speedup"],
        &rows,
    )
}

/// What-if (the paper's explicit future work): an FPGA NTT kernel next to
/// the SAB MSM accelerator. The CPU NTT column is *measured* on this host
/// through the crate's cached-plan serial path up to `measure_cpu_up_to`
/// elements and extrapolated by n·log n beyond it (marked `~`); the FPGA
/// column is the [`NttModel`] what-if. The last two columns apply
/// Amdahl's law with the paper's own Table I prover shares: accelerating
/// MSM alone caps the prover at roughly `1/(ntt% + other%)`, which is
/// exactly why the NTT is the next ceiling once the MSM hot path is
/// accelerated — and what pairing both kernels buys back. At small n the
/// table honestly shows offload *losing*: per-call PCIe transfer and
/// launch overhead dwarf a 2¹² transform, the same reason zkSpeed keeps
/// intermediate data device-resident.
pub fn whatif_ntt(measure_cpu_up_to: usize) -> String {
    let sizes: [u64; 4] = [1 << 12, 1 << 16, 1 << 20, 1 << 24];
    let cap = measure_cpu_up_to.clamp(1 << 8, 1 << 22).next_power_of_two();
    let mut rows = Vec::new();
    for curve in [CurveId::Bn254, CurveId::Bls12381] {
        // Table I prover shares (paper rows): msm / ntt / other
        let (msm_share, ntt_share, other_share) = match curve {
            CurveId::Bn254 => (0.88, 0.11, 0.01),
            CurveId::Bls12381 => (0.92, 0.07, 0.01),
        };
        let ntt_model = NttModel::new(NttKernelConfig::whatif(curve, 16));
        let msm_model = SabModel::new(SabConfig::paper(curve, 2));
        let cpu_msm = CpuBaseline::for_curve(curve);
        let measure = |n: usize| match curve {
            CurveId::Bn254 => crate::baseline::cpu::measure_ntt::<Bn254FrParams>(n, 0xA11CE, 1),
            CurveId::Bls12381 => {
                crate::baseline::cpu::measure_ntt::<Bls12381FrParams>(n, 0xA11CE, 1)
            }
        };
        let anchor = measure(cap);
        let nlogn = |n: u64| n as f64 * (n as f64).log2();
        for &n in &sizes {
            let (cpu_ntt_s, extrapolated) = if n as usize == cap {
                (anchor.seconds, false) // the anchor measurement, reused
            } else if (n as usize) < cap {
                (measure(n as usize).seconds, false)
            } else {
                (anchor.seconds * nlogn(n) / nlogn(cap as u64), true)
            };
            let t_fpga = ntt_model.time_ntt(n).total_s();
            let s_ntt = cpu_ntt_s / t_fpga;
            let s_msm = cpu_msm.model_seconds(n) / msm_model.time_msm(n).total_s();
            let amdahl =
                |s_m: f64, s_n: f64| 1.0 / (other_share + msm_share / s_m + ntt_share / s_n);
            rows.push(vec![
                curve.name().into(),
                crate::util::human_count(n),
                format!("{cpu_ntt_s:.4}{}", if extrapolated { "~" } else { "" }),
                format!("{t_fpga:.4}"),
                format!("{s_ntt:.1}x"),
                format!("{:.1}x", amdahl(s_msm, 1.0)),
                format!("{:.1}x", amdahl(s_msm, s_ntt)),
            ]);
        }
    }
    ascii_table(
        &format!(
            "What-if: FPGA NTT kernel (paper future work) — CPU measured to {}, ~ = n·log n \
             extrapolated; prover columns apply Table I shares",
            crate::util::human_count(cap as u64)
        ),
        &["curve", "size", "CPU NTT s", "FPGA NTT s", "xNTT", "prover xMSM", "prover xMSM+NTT"],
        &rows,
    )
}

/// Short executor label for table cells.
fn backend_label(b: msm::Backend) -> String {
    match b {
        msm::Backend::Naive => "naive".into(),
        msm::Backend::Pippenger => "pippenger".into(),
        msm::Backend::Parallel { threads } => format!("parallel({threads})"),
        msm::Backend::BatchAffine => "batch-affine".into(),
        msm::Backend::BatchAffineParallel { threads } => format!("batch-affine({threads})"),
        msm::Backend::Chunked { threads } => format!("chunked({threads})"),
        msm::Backend::Precomputed => "precomputed".into(),
    }
}

/// Per-scenario prover profiles across the circuit library, both curve
/// families. For every [`Scenario`](crate::snark::Scenario): build an
/// instance sized to ~`size` constraints, synthesize a CRS, run the
/// resident Table-I rig, verify the transcript, then re-prove with the
/// streaming prover under a 1 MiB chunk budget and assert bit-identity.
/// Returns the rendered table and the `BENCH_scenarios.json` payload
/// (schema in BENCHMARKS.md).
pub fn table_scenarios(size: usize, seed: u64) -> (String, crate::util::json::Json) {
    use crate::util::json::Json;

    fn profile<G1, G2, P>(
        curve: &str,
        size: usize,
        seed: u64,
        rows: &mut Vec<Vec<String>>,
        results: &mut Vec<Json>,
    ) where
        G1: crate::ec::CurveParams,
        G2: crate::ec::CurveParams,
        P: crate::ff::FieldParams<4>,
        G1::Base: crate::ff::WordCodec,
        G2::Base: crate::ff::WordCodec,
    {
        use crate::snark::{prove_streaming, ProverConfig, Scenario, StreamingSrs, VerifyingKey};
        use crate::util::mem::MemoryBudget;
        for sc in Scenario::ALL {
            let inst = sc.build::<P, 4>(size, seed);
            let cs = &inst.cs;
            let domain_n = cs.num_constraints().max(2).next_power_of_two();
            let nv = cs.num_variables();
            let crs_seed = seed ^ 0x5ce2_a210;
            let crs = Crs::<G1, G2>::synthesize(nv, domain_n, crs_seed);
            let vk = VerifyingKey::from_crs(&crs, cs.num_public);
            let auto = msm::Backend::auto_for::<G1>(nv, &MsmConfig::default());
            let prover = Prover::<G1, G2, P>::new(crs);
            let (proof, prof) = prover.prove(cs);
            let verified = crate::snark::verify::verify(&vk, &proof, &inst.public_inputs).is_ok();
            // streaming replay over the generator-backed SRS view of the
            // same CRS seed: must be bit-identical to the resident proof
            let srs = StreamingSrs::<G1, G2>::generated(nv, domain_n, crs_seed);
            let budget = MemoryBudget::mib(1);
            let (sproof, report) = prove_streaming(cs, &srs, budget, &ProverConfig::default())
                .expect("1 MiB budget admits whole chunks");
            let identical = sproof.a.eq_point(&proof.a)
                && sproof.b.eq_point(&proof.b)
                && sproof.c.eq_point(&proof.c)
                && sproof.pi.eq_point(&proof.pi);
            rows.push(vec![
                curve.into(),
                sc.name().into(),
                inst.shape.clone(),
                cs.num_constraints().to_string(),
                nv.to_string(),
                cs.num_public.to_string(),
                backend_label(auto),
                f2(prof.msm_g1_pct),
                f2(prof.msm_g2_pct),
                f2(prof.ntt_pct),
                f2(prof.other_pct),
                report.peak_chunk_bytes.to_string(),
                if verified && identical { "ok".into() } else { "FAIL".into() },
            ]);
            let mut r = Json::obj();
            r.set("curve", curve)
                .set("scenario", sc.name())
                .set("shape", inst.shape.clone())
                .set("constraints", cs.num_constraints())
                .set("variables", nv)
                .set("publics", cs.num_public)
                .set("auto_backend", backend_label(auto))
                .set("msm_g1_pct", prof.msm_g1_pct)
                .set("msm_g2_pct", prof.msm_g2_pct)
                .set("ntt_pct", prof.ntt_pct)
                .set("other_pct", prof.other_pct)
                .set("total_s", prof.total_s)
                .set("stream_peak_bytes", report.peak_chunk_bytes)
                .set("stream_budget_bytes", report.budget_bytes)
                .set("verified", verified)
                .set("stream_identical", identical);
            results.push(r);
        }
    }

    let mut rows = Vec::new();
    let mut results = Vec::new();
    profile::<Bn254G1, Bn254G2, Bn254FrParams>("BN254", size, seed, &mut rows, &mut results);
    profile::<Bls12381G1, Bls12381G2, Bls12381FrParams>(
        "BLS12-381",
        size,
        seed,
        &mut rows,
        &mut results,
    );
    let table = ascii_table(
        &format!("Scenario profiles: circuit library at ~{size} constraints (%)"),
        &[
            "curve", "scenario", "shape", "constr", "vars", "pub", "auto backend", "G1%", "G2%",
            "NTT%", "other%", "stream peak B", "check",
        ],
        &rows,
    );
    let mut json = Json::obj();
    json.set("bench", "scenarios").set("size", size).set("seed", seed).set("results", results);
    (table, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_scenarios_round_trips_every_workload() {
        let (t, json) = table_scenarios(250, 21);
        assert!(t.contains("rollup") && t.contains("poseidon2"));
        assert!(!t.contains("FAIL"), "a scenario failed verify/bit-identity:\n{t}");
        let results = json.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 12, "6 scenarios x 2 curves");
        for r in results {
            assert_eq!(r.get("verified"), Some(&crate::util::json::Json::Bool(true)));
            assert_eq!(r.get("stream_identical"), Some(&crate::util::json::Json::Bool(true)));
        }
    }

    #[test]
    fn table4_5_renders() {
        let t = table4_5();
        assert!(t.contains("UDA-254-Standard"));
        assert!(t.contains("1975"));
    }

    #[test]
    fn table7_renders_with_fmax() {
        let t = table7();
        assert!(t.contains("MHz"));
        assert!(t.contains("S=2"));
    }

    #[test]
    fn table8_renders() {
        let t = table8();
        assert!(t.contains("BSP"));
        assert!(t.contains("BLS12-381 UDA (S=2)"));
    }

    #[test]
    fn table9_speedups_exceed_100x_at_large_sizes() {
        let t = table9(0); // no local measurement in unit tests
        // paper: ≥110x for the largest sizes; our modeled CPU/FPGA ratio
        // should be in the same regime — spot check text content
        assert!(t.contains("64M"));
        let lines: Vec<&str> = t.lines().collect();
        let last = lines.last().unwrap();
        let x: f64 = last
            .split('|')
            .nth(6)
            .unwrap()
            .trim()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(x > 90.0 && x < 160.0, "CPU speedup at 64M: {x}");
    }

    #[test]
    fn table2_3_small_runs() {
        let t = table2_3(64, 5);
        assert!(t.contains("BN128"));
        assert!(t.contains("BLS12-381"));
    }

    #[test]
    fn table2_3_source_counts_squares_without_drift() {
        // The table's modmul source is opcount's mul + square. The
        // dedicated SOS squaring must keep feeding the square lane (one
        // count per call, never silently re-routed through mul), so the
        // regenerated Tables II/III pick the new squarings up with zero
        // accounting drift.
        let w = crate::ec::points::workload::<Bn254G1>(256, 6);
        let cfg = MsmConfig::unsigned(12, Reduction::Recursive { k2: 6 });
        let ((out, cost), ops) =
            crate::ff::opcount::measure(|| pippenger::msm_with_cost(&w.points, &w.scalars, &cfg));
        assert!(out.eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        // squarings are a large, separately-tracked share of the fill
        // path (madd-2007-bl is 7M + 4S per mixed add)
        assert!(ops.square > 0 && ops.mul > 0);
        assert!(ops.square * 3 > ops.mul, "squares underrepresented: {ops:?}");
        // the cost path's modmul figure is exactly the counter sum
        assert_eq!(cost.modmuls, ops.modmuls());
        assert_eq!(ops.modmuls(), ops.mul + ops.square);
    }

    #[test]
    fn ablation_signed_halves_serial_chain() {
        let t = ablation_signed(1024, 31);
        assert!(t.contains("Unsigned") && t.contains("Signed"));
        // pull the plan's serial ops column for both rows and check ~2×
        let mut serial = Vec::new();
        for line in t.lines() {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            if cells.len() > 4 && (cells[1] == "Unsigned" || cells[1] == "Signed") {
                serial.push(cells[4].parse::<f64>().unwrap());
            }
        }
        assert_eq!(serial.len(), 2, "{t}");
        let ratio = serial[0] / serial[1];
        assert!((1.9..=2.0).contains(&ratio), "serial chain ratio {ratio}\n{t}");
    }

    #[test]
    fn ablation_glv_halves_windows_and_serial_chain() {
        let t = ablation_glv(256, 41);
        assert!(t.contains("speedup"), "{t}");
        // per row: window count ~halves (exact for BN254's 22 → 11; BLS's
        // 32 → 17 keeps a carry window), the serial chain follows the
        // window count, and the modeled build is never slower
        let mut checked = 0;
        for line in t.lines() {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            if cells.len() > 9 && (cells[1] == "BN128" || cells[1] == "BLS12-381") {
                let ws: f64 = cells[3].parse().unwrap();
                let wg: f64 = cells[4].parse().unwrap();
                let ratio = ws / wg;
                assert!((1.8..=2.05).contains(&ratio), "window ratio {ratio}\n{t}");
                let ss: f64 = cells[5].parse().unwrap();
                let sg: f64 = cells[6].parse().unwrap();
                let sratio = ss / sg;
                assert!((1.8..=2.05).contains(&sratio), "serial ratio {sratio}\n{t}");
                let speedup: f64 = cells[9].trim_end_matches('x').parse().unwrap();
                // BN128 windows halve exactly → never slower. BLS keeps a
                // carry window (32 → 17), so stream-bound sizes can pay up
                // to 17·2/32 ≈ 6% extra streaming — the table is allowed
                // to show that honestly.
                let floor = if cells[1] == "BN128" { 0.999 } else { 0.9 };
                assert!(speedup >= floor, "glv speedup {speedup} < {floor}\n{t}");
                checked += 1;
            }
        }
        assert_eq!(checked, 6, "{t}");
    }

    #[test]
    fn ablation_pointcache_sweeps_table_size_and_reports_speedups() {
        // small m keeps the unit test fast; bit-exactness is asserted
        // inside the generator before any row prints
        let t = ablation_pointcache(512, 77);
        assert!(t.contains("table bytes"), "{t}");
        let mut windows = Vec::new();
        let mut bytes = Vec::new();
        let mut modeled = Vec::new();
        for line in t.lines() {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            if cells.len() > 7 && cells[1].parse::<u32>().is_ok() {
                windows.push(cells[2].parse::<u64>().unwrap());
                bytes.push(cells[3].parse::<u64>().unwrap());
                // measured speedup is timing-noisy at this size: only
                // require a well-formed positive cell
                let x: f64 = cells[6].trim_end_matches('x').parse().unwrap();
                assert!(x > 0.0, "{t}");
                modeled.push(cells[7].to_string());
            }
        }
        assert_eq!(windows.len(), 3, "{t}");
        // wider windows → fewer of them → smaller tables: both columns
        // fall monotonically down the sweep
        for w in windows.windows(2) {
            assert!(w[1] < w[0], "windows not shrinking: {windows:?}\n{t}");
        }
        for b in bytes.windows(2) {
            assert!(b[1] < b[0], "table bytes not shrinking: {bytes:?}\n{t}");
        }
        // the modeled SAB point exists only at the hardware window width
        assert_eq!(modeled[0], "-");
        assert_eq!(modeled[1], "-");
        let m: f64 = modeled[2].trim_end_matches('x').parse().unwrap();
        assert!(m >= 1.0, "modeled table build slower than glv: {m}\n{t}");
    }

    #[test]
    fn whatif_multi_kernel_speedup_scales_with_devices() {
        let t = whatif_multi_kernel(16_000_000);
        assert!(t.contains("kernels"));
        // pull the chunk-speedup column per curve: must increase with the
        // kernel count and exceed 2x by 4 kernels
        let mut per_curve: Vec<Vec<f64>> = Vec::new();
        for line in t.lines() {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            if cells.len() > 6 && (cells[1] == "BN128" || cells[1] == "BLS12-381") {
                if cells[2] == "1" {
                    per_curve.push(Vec::new());
                }
                let x: f64 = cells[4].trim_end_matches('x').parse().unwrap();
                per_curve.last_mut().unwrap().push(x);
            }
        }
        assert_eq!(per_curve.len(), 2, "{t}");
        for speedups in &per_curve {
            assert_eq!(speedups.len(), 4, "{t}");
            for w in speedups.windows(2) {
                assert!(w[1] > w[0], "speedup not scaling: {speedups:?}");
            }
            assert!(speedups[2] > 2.0, "4-kernel speedup too low: {speedups:?}");
        }
    }

    #[test]
    fn whatif_ntt_shows_the_amdahl_ceiling() {
        // small measurement cap keeps the unit test fast; the shape is
        // what matters: MSM-only acceleration hits the Table I Amdahl
        // ceiling (≈1/(ntt+other)), adding the NTT kernel lifts it
        let t = whatif_ntt(1 << 10);
        assert!(t.contains("xMSM+NTT"), "{t}");
        let mut checked = 0;
        for line in t.lines() {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            if cells.len() > 7 && (cells[1] == "BN128" || cells[1] == "BLS12-381") {
                let msm_only: f64 = cells[6].trim_end_matches('x').parse().unwrap();
                let both: f64 = cells[7].trim_end_matches('x').parse().unwrap();
                // the MSM-only column can never beat the share ceiling
                assert!(msm_only < 1.0 / 0.08, "msm-only {msm_only} above ceiling\n{t}");
                // at the largest size the combined kernel must clear the
                // MSM-only ceiling decisively; at small n the per-call
                // PCIe + launch overhead can honestly make NTT offload a
                // net loss, so no direction is asserted there
                if cells[2] == crate::util::human_count(1 << 24) {
                    assert!(both > msm_only * 1.5, "{msm_only} vs {both}\n{t}");
                }
                checked += 1;
            }
        }
        assert_eq!(checked, 8, "{t}");
    }

    #[test]
    fn ablation_shows_isrbam_wins() {
        let t = ablation_reduction();
        assert!(t.contains("IS-RBAM"));
        // every speedup cell should be ≥ 1.0
        for line in t.lines().skip(3) {
            if let Some(cell) = line.split('|').nth(5) {
                if let Ok(x) = cell.trim().trim_end_matches('x').parse::<f64>() {
                    assert!(x >= 0.99, "IS-RBAM slower? {x}");
                }
            }
        }
    }
}
