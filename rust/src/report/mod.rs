//! Report rendering: regenerates every table and figure of the paper's
//! evaluation section from this repo's measurements and models.
//!
//! * [`tables`] — Tables I–X (paper values printed beside ours);
//! * [`figures`] — Figures 4–8 as CSV series (plot-ready).
//!
//! The benches under `rust/benches/` are thin wrappers that call these and
//! print; integration tests assert the claims (speedup bands, scaling
//! linearity, who-wins ordering) rather than exact numbers.

pub mod tables;
pub mod figures;

/// Render an aligned ASCII table.
pub fn ascii_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(&sep, &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Render a CSV block with a `# title` comment head.
pub fn csv_block(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = format!("# {title}\n{}\n", headers.join(","));
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Format with 2 decimal places (shared by tables/figures).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
/// Format with 3 decimal places.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
/// Format with 4 decimal places.
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_table_aligns() {
        let t = ascii_table(
            "T",
            &["a", "bbbb"],
            &[vec!["x".into(), "y".into()], vec!["long".into(), "z".into()]],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("| a    | bbbb |"));
        assert!(t.contains("| long | z    |"));
    }

    #[test]
    fn csv_block_format() {
        let c = csv_block("F", &["m", "t"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "# F\nm,t\n1,2\n");
    }
}
