//! Evaluation domains: the 2-adic multiplicative subgroups of Fr plus coset
//! shifts — the QAP prover evaluates over a coset to divide by the domain's
//! vanishing polynomial safely.
//!
//! A domain lazily builds (and caches) one [`NttPlan`] — the twiddle
//! tables are computed on the first transform and shared by every
//! subsequent one, including by clones taken *after* the first build
//! (the cache is an `Arc` inside a `OnceLock`; a clone taken before any
//! transform starts with an empty cache and would build its own). The
//! QAP prover's seven transforms per proof all hit the same tables.

use std::sync::{Arc, OnceLock};

use super::plan::NttPlan;
use crate::ff::bigint;
use crate::ff::{Field, FieldParams, Fp};

/// A power-of-two evaluation domain in Fr.
///
/// # Examples
///
/// ```
/// use ifzkp::ff::params::Bn254FrParams;
/// use ifzkp::ntt::domain::Domain;
///
/// let d = Domain::<Bn254FrParams, 4>::new(1024).unwrap();
/// assert_eq!(d.n, 1024);
/// // the transform plan (twiddle tables, coset ladders) is built once
/// // and cached — repeated calls return the same Arc
/// let p1 = d.plan();
/// let p2 = d.plan();
/// assert!(std::sync::Arc::ptr_eq(&p1, &p2));
/// ```
#[derive(Clone, Debug)]
pub struct Domain<P: FieldParams<N>, const N: usize> {
    /// Domain size n (power of two).
    pub n: usize,
    /// Primitive n-th root of unity.
    pub omega: Fp<P, N>,
    /// Coset generator g (the field's multiplicative generator).
    pub coset_gen: Fp<P, N>,
    /// Lazily-built transform plan, shared across clones once built.
    plan: OnceLock<Arc<NttPlan<P, N>>>,
}

impl<P: FieldParams<N>, const N: usize> Domain<P, N> {
    /// Build a domain of size `n`; None if n isn't a power of two or
    /// exceeds the field's 2-adicity.
    pub fn new(n: usize) -> Option<Self> {
        if !n.is_power_of_two() || n == 0 {
            return None;
        }
        let log_n = n.trailing_zeros();
        if log_n > P::TWO_ADICITY {
            return None;
        }
        // omega = g^((p−1) / n)
        let g = Fp::<P, N>::from_u64(P::GENERATOR);
        let mut exp = P::MODULUS.to_vec();
        exp[0] -= 1; // p odd
        let exp = bigint::shr_slices(&exp, log_n as usize);
        let omega = g.pow_limbs(&exp);
        debug_assert!(super::is_primitive_root(&omega, n));
        Some(Domain { n, omega, coset_gen: g, plan: OnceLock::new() })
    }

    /// The domain's cached [`NttPlan`] — built on first use, then shared
    /// (the twiddle tables amortize across every transform over this
    /// domain, which is what makes the prover's repeated transforms
    /// cheap).
    pub fn plan(&self) -> Arc<NttPlan<P, N>> {
        self.plan.get_or_init(|| Arc::new(NttPlan::for_domain(self))).clone()
    }

    /// Evaluate the vanishing polynomial Z(x) = xⁿ − 1 at a point.
    pub fn vanishing_at(&self, x: &Fp<P, N>) -> Fp<P, N> {
        x.pow_u64(self.n as u64).sub(&Fp::<P, N>::one())
    }

    /// Forward NTT over the coset g·⟨ω⟩. Runs through the cached plan:
    /// the coset shift reads the precomputed gⁱ ladder instead of
    /// walking a serial `scale·g` chain per call.
    pub fn coset_ntt(&self, values: &mut [Fp<P, N>]) {
        self.plan().coset_ntt(values, 1);
    }

    /// Inverse of [`Self::coset_ntt`] (cached plan; the n⁻¹ scale is
    /// folded into the inverse coset ladder).
    pub fn coset_intt(&self, values: &mut [Fp<P, N>]) {
        self.plan().coset_intt(values, 1);
    }

    /// All n domain elements ωⁱ.
    pub fn elements(&self) -> Vec<Fp<P, N>> {
        let mut out = Vec::with_capacity(self.n);
        let mut x = Fp::<P, N>::one();
        for _ in 0..self.n {
            out.push(x);
            x = x.mul(&self.omega);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    use crate::ff::FrBn254;
    use crate::util::rng::Rng;

    type D = Domain<Bn254FrParams, 4>;

    #[test]
    fn domain_sizes() {
        assert!(D::new(1 << 10).is_some());
        assert!(D::new(1 << 28).is_some()); // exactly the 2-adicity
        assert!(D::new(1 << 29).is_none()); // beyond it
        assert!(D::new(3).is_none());
    }

    #[test]
    fn vanishing_zero_on_domain_nonzero_on_coset() {
        let d = D::new(16).unwrap();
        for x in d.elements() {
            assert!(d.vanishing_at(&x).is_zero());
        }
        let on_coset = d.coset_gen.mul(&d.omega);
        assert!(!d.vanishing_at(&on_coset).is_zero());
    }

    #[test]
    fn coset_ntt_roundtrip() {
        let mut rng = Rng::new(95);
        let d = D::new(32).unwrap();
        let orig: Vec<FrBn254> = (0..32).map(|_| FrBn254::random(&mut rng)).collect();
        let mut v = orig.clone();
        d.coset_ntt(&mut v);
        d.coset_intt(&mut v);
        assert_eq!(v, orig);
    }

    #[test]
    fn coset_ntt_evaluates_on_coset() {
        // degree-1 poly a + b·x evaluated at g·ωⁱ
        let mut rng = Rng::new(96);
        let d = D::new(8).unwrap();
        let a = FrBn254::random(&mut rng);
        let b = FrBn254::random(&mut rng);
        let mut v = vec![FrBn254::zero(); 8];
        v[0] = a;
        v[1] = b;
        d.coset_ntt(&mut v);
        for i in 0..8 {
            let x = d.coset_gen.mul(&d.omega.pow_u64(i as u64));
            assert_eq!(v[i as usize], a.add(&b.mul(&x)));
        }
    }

    #[test]
    fn cached_plan_is_built_once_and_travels_with_clones() {
        let d = D::new(64).unwrap();
        let p1 = d.plan();
        assert!(std::sync::Arc::ptr_eq(&p1, &d.plan()));
        // a clone taken after the first build shares the same tables
        let d2 = d.clone();
        assert!(std::sync::Arc::ptr_eq(&p1, &d2.plan()));
        assert_eq!(p1.n, 64);
        assert_eq!(p1.omega, d.omega);
    }

    #[test]
    fn elements_are_distinct_roots() {
        let d = D::new(16).unwrap();
        let els = d.elements();
        assert_eq!(els.len(), 16);
        for (i, x) in els.iter().enumerate() {
            assert_eq!(*x, d.omega.pow_u64(i as u64));
        }
    }
}
