//! Number-Theoretic Transform over the scalar fields.
//!
//! The third compute pillar of the prover (Table I's NTT column, 7–11% of
//! runtime; the paper defers its FPGA acceleration to future work but the
//! profiling reproduction needs a real implementation), organised like
//! the MSM pillar:
//!
//! * **plan** ([`plan::NttPlan`]) — cached, stage-major twiddle tables
//!   and coset ladders built once per size, plus the exact field-mul
//!   budget each transform must hit (`n/2·log₂ n` butterflies — pinned
//!   in `tests/perf_smoke.rs` like the MSM SOS word-mul constants);
//! * **executors** ([`parallel`]) — a stage/chunk-parallel radix-2
//!   schedule and a transpose-based four-step path for large n, both
//!   bit-identical to the serial reference at every thread count;
//! * **domains** ([`domain::Domain`]) — the 2-adic subgroups plus coset
//!   shifts, caching one shared plan per domain so the QAP prover's
//!   seven transforms amortize a single table build.
//!
//! [`ntt_in_place`]/[`intt_in_place`] remain as the **serial
//! reference**: the simplest correct implementation (per-stage
//! `ω^(n/len)` derivation, serial twiddle walk), which the property
//! matrix in `tests/prop_ntt.rs` holds every executor against.

pub mod domain;
pub mod parallel;
pub mod plan;

pub use plan::NttPlan;

use crate::ff::{Field, FieldParams, Fp};

/// Bit-reversal permutation (in place).
pub(crate) fn bit_reverse<T>(v: &mut [T]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        if (j as usize) > i {
            v.swap(i, j as usize);
        }
    }
}

/// In-place forward NTT: values ← evaluations of the polynomial (given in
/// coefficient order) at the powers of `omega` (a primitive n-th root).
///
/// This is the **serial reference**: it re-derives `ω^(n/len)` per stage
/// and walks the twiddle chain inside every butterfly loop (two muls per
/// butterfly). Production callers should go through a cached
/// [`NttPlan`], which halves the mul count and parallelizes.
pub fn ntt_in_place<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    omega: &Fp<P, N>,
) {
    let n = values.len();
    assert!(n.is_power_of_two(), "NTT size must be a power of two");
    debug_assert!(is_primitive_root(omega, n));
    bit_reverse(values);
    let mut len = 2usize;
    while len <= n {
        // w_len = omega^(n/len)
        let w_len = omega.pow_u64((n / len) as u64);
        for start in (0..n).step_by(len) {
            let mut w = Fp::<P, N>::one();
            for i in 0..len / 2 {
                let u = values[start + i];
                let v = values[start + i + len / 2].mul(&w);
                values[start + i] = u.add(&v);
                values[start + i + len / 2] = u.sub(&v);
                w = w.mul(&w_len);
            }
        }
        len <<= 1;
    }
}

/// Inverse NTT (scales by n⁻¹) — the serial reference for
/// [`NttPlan::intt`].
pub fn intt_in_place<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    omega: &Fp<P, N>,
) {
    let n = values.len();
    let omega_inv = omega.inv().expect("omega nonzero");
    ntt_in_place(values, &omega_inv);
    let n_inv = Fp::<P, N>::from_u64(n as u64).inv().expect("n invertible");
    for v in values.iter_mut() {
        *v = v.mul(&n_inv);
    }
}

/// Check ω is a primitive n-th root of unity (debug validation).
pub fn is_primitive_root<F: Field>(omega: &F, n: usize) -> bool {
    if n == 0 || !n.is_power_of_two() {
        return false;
    }
    if n == 1 {
        return *omega == F::one(); // the trivial group's only root
    }
    omega.pow_u64(n as u64) == F::one() && omega.pow_u64((n / 2) as u64) != F::one()
}

/// Schoolbook polynomial multiplication (reference for the NTT tests).
pub fn poly_mul_schoolbook<F: Field>(a: &[F], b: &[F]) -> Vec<F> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![F::zero(); a.len() + b.len() - 1];
    for (i, ai) in a.iter().enumerate() {
        for (j, bj) in b.iter().enumerate() {
            out[i + j] = out[i + j].add(&ai.mul(bj));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::domain::Domain;
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};
    use crate::ff::FrBn254;
    use crate::util::rng::Rng;

    #[test]
    fn ntt_intt_roundtrip() {
        let mut rng = Rng::new(91);
        let dom = Domain::<Bn254FrParams, 4>::new(64).unwrap();
        let orig: Vec<FrBn254> = (0..64).map(|_| FrBn254::random(&mut rng)).collect();
        let mut v = orig.clone();
        ntt_in_place(&mut v, &dom.omega);
        assert_ne!(v, orig);
        intt_in_place(&mut v, &dom.omega);
        assert_eq!(v, orig);
    }

    #[test]
    fn ntt_of_constant_poly() {
        // constant c evaluates to c everywhere
        let dom = Domain::<Bn254FrParams, 4>::new(8).unwrap();
        let c = FrBn254::from_u64(42);
        let mut v = vec![FrBn254::zero(); 8];
        v[0] = c;
        ntt_in_place(&mut v, &dom.omega);
        assert!(v.iter().all(|x| *x == c));
    }

    #[test]
    fn ntt_matches_naive_evaluation() {
        let mut rng = Rng::new(92);
        let n = 16usize;
        let dom = Domain::<Bls12381FrParams, 4>::new(n).unwrap();
        let coeffs: Vec<_> =
            (0..n).map(|_| crate::ff::FrBls12381::random(&mut rng)).collect();
        let mut v = coeffs.clone();
        ntt_in_place(&mut v, &dom.omega);
        // naive evaluation at omega^i
        for i in 0..n {
            let x = dom.omega.pow_u64(i as u64);
            let mut acc = crate::ff::FrBls12381::zero();
            let mut xp = crate::ff::FrBls12381::one();
            for c in &coeffs {
                acc = acc.add(&c.mul(&xp));
                xp = xp.mul(&x);
            }
            assert_eq!(v[i], acc, "eval mismatch at {i}");
        }
    }

    #[test]
    fn convolution_theorem() {
        // poly mult via NTT == schoolbook
        let mut rng = Rng::new(93);
        let (da, db) = (10usize, 13usize);
        let a: Vec<FrBn254> = (0..da).map(|_| FrBn254::random(&mut rng)).collect();
        let b: Vec<FrBn254> = (0..db).map(|_| FrBn254::random(&mut rng)).collect();
        let want = poly_mul_schoolbook(&a, &b);
        let n = (da + db - 1).next_power_of_two();
        let dom = Domain::<Bn254FrParams, 4>::new(n).unwrap();
        let mut fa = a.clone();
        fa.resize(n, FrBn254::zero());
        let mut fb = b.clone();
        fb.resize(n, FrBn254::zero());
        ntt_in_place(&mut fa, &dom.omega);
        ntt_in_place(&mut fb, &dom.omega);
        for (x, y) in fa.iter_mut().zip(&fb) {
            *x = x.mul(y);
        }
        intt_in_place(&mut fa, &dom.omega);
        assert_eq!(&fa[..want.len()], &want[..]);
        assert!(fa[want.len()..].iter().all(|x| x.is_zero()));
    }

    #[test]
    fn rejects_non_power_of_two() {
        assert!(Domain::<Bn254FrParams, 4>::new(12).is_none());
        assert!(!is_primitive_root(&FrBn254::one(), 4));
    }

    #[test]
    fn size_one_domain_is_identity() {
        // n = 1: ω = g^(p−1) = 1 is the trivial group's primitive root and
        // the transform is the identity (bit_reverse guards the 0-bit shift)
        let dom = Domain::<Bn254FrParams, 4>::new(1).unwrap();
        assert_eq!(dom.omega, FrBn254::one());
        assert!(is_primitive_root(&dom.omega, 1));
        let mut v = vec![FrBn254::from_u64(9)];
        ntt_in_place(&mut v, &dom.omega);
        assert_eq!(v[0], FrBn254::from_u64(9));
        intt_in_place(&mut v, &dom.omega);
        assert_eq!(v[0], FrBn254::from_u64(9));
    }

    #[test]
    fn bit_reverse_involution() {
        let mut v: Vec<u32> = (0..32).collect();
        let orig = v.clone();
        bit_reverse(&mut v);
        assert_ne!(v, orig);
        bit_reverse(&mut v);
        assert_eq!(v, orig);
    }
}
