//! The NTT execution plan: cached twiddle tables, coset ladders, and
//! field-mul budgets — the transform-side analogue of `msm::plan`.
//!
//! The serial reference ([`super::ntt_in_place`]) re-derives
//! `ω^(n/len)` with a modular exponentiation per stage and walks a
//! serially dependent `w = w·w_len` chain inside every butterfly loop —
//! two field muls per butterfly, every call. A [`NttPlan`] pays that cost
//! **once per size**: all `n − 1` stage twiddles land in one flat,
//! stage-major table, the coset ladder `gⁱ` (and its inverse, with
//! `n⁻¹` folded in) is cached next to them, and every subsequent
//! transform runs exactly `n/2·log₂ n` butterfly muls — half the
//! reference's count, pinned in `tests/perf_smoke.rs` the same way the
//! SOS word-mul constants pin `Fp::square`.
//!
//! Execution (serial, stage/chunk-parallel, and the transpose-based
//! four-step path for large `n`) lives in [`super::parallel`]; the plan
//! methods ([`NttPlan::ntt`], [`NttPlan::intt`], [`NttPlan::coset_ntt`],
//! [`NttPlan::coset_intt`]) are thin dispatchers over it. The QAP prover
//! builds one plan per domain (cached inside
//! [`Domain`](super::domain::Domain)) and reuses it across all seven
//! transforms of the h-polynomial computation.

use super::domain::Domain;
use crate::ff::{Field, FieldParams, Fp};

/// Flat, stage-major twiddle table for an `n`-point radix-2 NTT with
/// root `omega`: stage `s` (butterfly half-length `2^s`) occupies
/// `table[2^s − 1 .. 2^(s+1) − 1]`, holding `(ω^(n/2^(s+1)))^i` for
/// `i in 0..2^s`. Total `n − 1` entries.
pub(crate) fn build_stage_tables<P: FieldParams<N>, const N: usize>(
    omega: &Fp<P, N>,
    n: usize,
) -> Vec<Fp<P, N>> {
    debug_assert!(n.is_power_of_two());
    let log_n = n.trailing_zeros();
    let mut out = Vec::with_capacity(n.saturating_sub(1));
    for s in 0..log_n {
        let half = 1usize << s;
        let w_len = omega.pow_u64((n / (2 * half)) as u64);
        let mut w = Fp::<P, N>::one();
        for _ in 0..half {
            out.push(w);
            w = w.mul(&w_len);
        }
    }
    out
}

/// Stage `s`'s slice of a flat stage-major table (see
/// [`build_stage_tables`] for the layout).
#[inline]
pub(crate) fn stage_slice<T>(table: &[T], s: u32) -> &[T] {
    let half = 1usize << s;
    &table[half - 1..2 * half - 1]
}

/// A cached execution plan for every transform over one power-of-two
/// domain: precomputed forward/inverse twiddle tables, the coset ladder,
/// and the analytic field-mul budget each transform must hit.
///
/// # Examples
///
/// ```
/// use ifzkp::ff::{params::Bn254FrParams, Field, FrBn254};
/// use ifzkp::ntt::NttPlan;
///
/// let plan = NttPlan::<Bn254FrParams, 4>::new(8).unwrap();
/// let coeffs: Vec<FrBn254> = (0u64..8).map(FrBn254::from_u64).collect();
/// let mut v = coeffs.clone();
/// plan.ntt(&mut v, 4); // parallel forward transform (4 threads)
/// plan.intt(&mut v, 4); // inverse undoes it exactly
/// assert_eq!(v, coeffs);
///
/// // the cached tables make the butterfly mul count exact: n/2 · log2 n
/// assert_eq!(plan.mul_budget(false, false), 4 * 3);
/// ```
#[derive(Clone, Debug)]
pub struct NttPlan<P: FieldParams<N>, const N: usize> {
    /// Domain size n (power of two).
    pub n: usize,
    /// log₂ n (the stage count).
    pub log_n: u32,
    /// Primitive n-th root of unity the forward tables are built on.
    pub omega: Fp<P, N>,
    /// ω⁻¹ (the inverse tables' root).
    pub omega_inv: Fp<P, N>,
    /// n⁻¹ — the inverse transform's output scale (folded into
    /// the cached inverse-coset ladder, see [`NttPlan::coset_intt`]).
    pub n_inv: Fp<P, N>,
    /// Coset generator g (the field's multiplicative generator).
    pub coset_gen: Fp<P, N>,
    /// Forward stage twiddles, flat stage-major (n − 1 entries).
    fwd: Vec<Fp<P, N>>,
    /// Inverse stage twiddles (same layout, root ω⁻¹).
    inv: Vec<Fp<P, N>>,
    /// Coset ladder gⁱ for i in 0..n.
    coset: Vec<Fp<P, N>>,
    /// Inverse coset ladder with the iNTT scale folded in: n⁻¹·g⁻ⁱ.
    coset_inv: Vec<Fp<P, N>>,
}

impl<P: FieldParams<N>, const N: usize> NttPlan<P, N> {
    /// Build the plan for an `n`-point domain; `None` under the same
    /// conditions as [`Domain::new`] (not a power of two, or past the
    /// field's 2-adicity).
    pub fn new(n: usize) -> Option<Self> {
        Domain::<P, N>::new(n).map(|d| Self::for_domain(&d))
    }

    /// Build the plan for an existing domain. Prefer
    /// [`Domain::plan`](super::domain::Domain::plan), which builds once
    /// and caches the result inside the domain.
    pub fn for_domain(domain: &Domain<P, N>) -> Self {
        let n = domain.n;
        let log_n = n.trailing_zeros();
        let omega = domain.omega;
        let omega_inv = omega.inv().expect("omega nonzero");
        let n_inv = Fp::<P, N>::from_u64(n as u64).inv().expect("n invertible (p odd, n = 2^s)");
        let coset_gen = domain.coset_gen;
        let g_inv = coset_gen.inv().expect("generator nonzero");
        let mut coset = Vec::with_capacity(n);
        let mut x = Fp::<P, N>::one();
        for _ in 0..n {
            coset.push(x);
            x = x.mul(&coset_gen);
        }
        // the iNTT's n⁻¹ scale rides the inverse ladder for free: one
        // cached pointwise pass instead of two
        let mut coset_inv = Vec::with_capacity(n);
        let mut x = n_inv;
        for _ in 0..n {
            coset_inv.push(x);
            x = x.mul(&g_inv);
        }
        NttPlan {
            n,
            log_n,
            omega,
            omega_inv,
            n_inv,
            coset_gen,
            fwd: build_stage_tables(&omega, n),
            inv: build_stage_tables(&omega_inv, n),
            coset,
            coset_inv,
        }
    }

    /// The flat forward stage-twiddle table.
    pub(crate) fn fwd_table(&self) -> &[Fp<P, N>] {
        &self.fwd
    }

    /// The flat inverse stage-twiddle table.
    pub(crate) fn inv_table(&self) -> &[Fp<P, N>] {
        &self.inv
    }

    /// The coset ladder gⁱ.
    pub(crate) fn coset_table(&self) -> &[Fp<P, N>] {
        &self.coset
    }

    /// The inverse coset ladder n⁻¹·g⁻ⁱ.
    pub(crate) fn coset_inv_table(&self) -> &[Fp<P, N>] {
        &self.coset_inv
    }

    /// Exact field-multiplication budget of one transform through this
    /// plan: `n/2·log₂ n` butterfly muls, plus one pointwise pass
    /// (`n` muls) when the transform is inverse (the n⁻¹ scale) or
    /// coset-shifted (the cached ladder) — the two never stack, because
    /// [`Self::coset_intt`] reads the fused `n⁻¹·g⁻ⁱ` table. Pinned in
    /// `tests/perf_smoke.rs` like the MSM plan's serial-chain counts.
    pub fn mul_budget(&self, inverse: bool, coset: bool) -> u64 {
        let butterflies = (self.n as u64 / 2) * u64::from(self.log_n);
        butterflies + if inverse || coset { self.n as u64 } else { 0 }
    }

    /// In-place forward NTT (coefficients → evaluations at ωⁱ) over
    /// `threads` OS threads. `threads == 1` runs inline on the calling
    /// thread (so the `ff::opcount` counters see the work — the same
    /// convention as `msm::chunked`); larger n automatically takes the
    /// transpose-based four-step path (see
    /// [`super::parallel::FOUR_STEP_MIN`]). Output is bit-identical to
    /// [`super::ntt_in_place`] for every thread count.
    ///
    /// # Examples
    ///
    /// ```
    /// use ifzkp::ff::{params::Bn254FrParams, Field, FrBn254};
    /// use ifzkp::ntt::{self, NttPlan};
    /// use ifzkp::util::rng::Rng;
    ///
    /// let plan = NttPlan::<Bn254FrParams, 4>::new(16).unwrap();
    /// let mut rng = Rng::new(1);
    /// let coeffs: Vec<FrBn254> = (0..16).map(|_| FrBn254::random(&mut rng)).collect();
    ///
    /// let mut serial = coeffs.clone();
    /// ntt::ntt_in_place(&mut serial, &plan.omega); // the serial reference
    ///
    /// let mut parallel = coeffs.clone();
    /// plan.ntt(&mut parallel, 4); // bit-identical at any thread count
    /// assert_eq!(parallel, serial);
    /// ```
    pub fn ntt(&self, values: &mut [Fp<P, N>], threads: usize) {
        super::parallel::ntt(self, values, threads);
    }

    /// In-place inverse NTT (evaluations → coefficients, scaled by n⁻¹)
    /// over `threads` OS threads. Bit-identical to
    /// [`super::intt_in_place`].
    pub fn intt(&self, values: &mut [Fp<P, N>], threads: usize) {
        super::parallel::intt(self, values, threads);
    }

    /// Forward NTT over the coset g·⟨ω⟩. The coset shift is a pointwise
    /// pass over the cached gⁱ ladder — no serial generator walk.
    pub fn coset_ntt(&self, values: &mut [Fp<P, N>], threads: usize) {
        super::parallel::coset_ntt(self, values, threads);
    }

    /// Inverse of [`Self::coset_ntt`]. The n⁻¹ scale is folded into the
    /// cached n⁻¹·g⁻ⁱ ladder, so the whole un-shift is one pointwise
    /// pass.
    pub fn coset_intt(&self, values: &mut [Fp<P, N>], threads: usize) {
        super::parallel::coset_intt(self, values, threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::{Bls12381FrParams, Bn254FrParams};
    use crate::ff::FrBn254;
    use crate::util::rng::Rng;

    type Plan = NttPlan<Bn254FrParams, 4>;

    #[test]
    fn table_layout_covers_every_stage() {
        let plan = Plan::new(32).unwrap();
        assert_eq!(plan.log_n, 5);
        let mut total = 0usize;
        for s in 0..plan.log_n {
            let tw = stage_slice(plan.fwd_table(), s);
            assert_eq!(tw.len(), 1 << s);
            // entry i is (ω^(n/2^(s+1)))^i
            let w_len = plan.omega.pow_u64((32 >> (s + 1)) as u64);
            for (i, w) in tw.iter().enumerate() {
                assert_eq!(*w, w_len.pow_u64(i as u64), "stage {s} entry {i}");
            }
            total += tw.len();
        }
        assert_eq!(total, 31); // n − 1
        assert_eq!(plan.fwd_table().len(), 31);
        assert_eq!(plan.inv_table().len(), 31);
    }

    #[test]
    fn coset_ladders_fold_the_scale() {
        let plan = Plan::new(16).unwrap();
        let g = plan.coset_gen;
        let g_inv = g.inv().unwrap();
        for i in 0..16u64 {
            assert_eq!(plan.coset_table()[i as usize], g.pow_u64(i));
            // inverse ladder carries n⁻¹: applying both is a pure n⁻¹
            let prod = plan.coset_table()[i as usize].mul(&plan.coset_inv_table()[i as usize]);
            assert_eq!(prod, plan.n_inv);
            assert_eq!(plan.coset_inv_table()[i as usize], plan.n_inv.mul(&g_inv.pow_u64(i)));
        }
    }

    #[test]
    fn budgets_are_the_analytic_counts() {
        let plan = Plan::new(1 << 10).unwrap();
        let nb = (1u64 << 9) * 10;
        assert_eq!(plan.mul_budget(false, false), nb);
        assert_eq!(plan.mul_budget(true, false), nb + (1 << 10));
        assert_eq!(plan.mul_budget(false, true), nb + (1 << 10));
        // the fused inverse-coset ladder keeps this at one pass, not two
        assert_eq!(plan.mul_budget(true, true), nb + (1 << 10));
    }

    #[test]
    fn rejects_bad_sizes_like_domain() {
        assert!(Plan::new(12).is_none());
        assert!(NttPlan::<Bls12381FrParams, 4>::new(1 << 33).is_none());
    }

    #[test]
    fn plan_path_matches_serial_reference_roundtrip() {
        let plan = Plan::new(64).unwrap();
        let mut rng = Rng::new(551);
        let orig: Vec<FrBn254> = (0..64).map(|_| FrBn254::random(&mut rng)).collect();
        let mut v = orig.clone();
        plan.ntt(&mut v, 1);
        let mut want = orig.clone();
        super::super::ntt_in_place(&mut want, &plan.omega);
        assert_eq!(v, want);
        plan.intt(&mut v, 1);
        assert_eq!(v, orig);
    }
}
