//! Chunk-parallel NTT executors over a cached [`NttPlan`].
//!
//! Two schedules, both bit-identical to the serial reference (field
//! arithmetic is exact, so every correct evaluation order produces the
//! same canonical `Fp` limbs — there is no floating-point reassociation
//! to worry about):
//!
//! * **Stage-parallel radix-2** — the array splits into `T` contiguous
//!   bands; one thread per band runs every stage whose butterfly span
//!   fits inside its band (no synchronization at all), then each
//!   remaining cross-band stage splits its blocks' half-ranges into
//!   contiguous per-thread butterfly chunks. Twiddles come from the
//!   plan's flat stage table — no per-call `w = w·w_len` serial walk.
//! * **Four-step transpose** ([`ntt_four_step`], taken automatically at
//!   `n ≥` [`FOUR_STEP_MIN`]) — the classic √n × √n decomposition:
//!   transpose, √n-point row NTTs, twiddle by ω^(j·k), transpose,
//!   row NTTs, transpose. Rows are cache-resident and embarrassingly
//!   parallel, which is what the late radix-2 stages (stride ≈ n) are
//!   not.
//!
//! Thread budget conventions follow `msm::chunked`: `threads == 1` runs
//! inline on the caller (the `ff::opcount` counters see every mul — the
//! perf-smoke budget pins measure through this path), and the band count
//! is clamped so no band shrinks below `MIN_BAND` (256) elements.

use super::plan::{build_stage_tables, stage_slice, NttPlan};
use crate::ff::lanes::{FpLanes, LANES};
use crate::ff::{Field, FieldParams, Fp};

/// Sizes at or above this take the four-step path when `threads > 1`:
/// below it the whole transform is cache-resident and the transposes
/// cost more than they save.
pub const FOUR_STEP_MIN: usize = 1 << 16;

/// Minimum elements per stage-parallel band: below this the per-stage
/// spawn overhead dwarfs the butterfly work a band contributes (the NTT
/// analogue of `msm::chunked::MIN_CHUNK`).
const MIN_BAND: usize = 1 << 8;

/// In-place forward NTT through the plan's cached tables. Dispatches to
/// the stage-parallel schedule, or the four-step path at
/// `n ≥ FOUR_STEP_MIN` when `threads > 1`. Bit-identical to
/// [`super::ntt_in_place`] for every thread count.
pub fn ntt<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    if threads > 1 && plan.n >= FOUR_STEP_MIN {
        four_step_core(plan, values, false, threads);
    } else {
        radix2(values, plan.fwd_table(), threads);
    }
}

/// In-place inverse NTT (scales by n⁻¹). Bit-identical to
/// [`super::intt_in_place`].
pub fn intt<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    backward(plan, values, threads);
    scale_by(values, &plan.n_inv, threads);
}

/// Forward NTT over the coset g·⟨ω⟩: one pointwise pass over the cached
/// gⁱ ladder (parallel, no serial generator walk), then [`ntt`].
pub fn coset_ntt<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    pointwise(values, plan.coset_table(), threads);
    ntt(plan, values, threads);
}

/// Inverse of [`coset_ntt`]: the unscaled inverse transform followed by
/// one pointwise pass over the fused n⁻¹·g⁻ⁱ ladder — the iNTT scale and
/// the coset un-shift cost a single pass together.
pub fn coset_intt<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    backward(plan, values, threads);
    pointwise(values, plan.coset_inv_table(), threads);
}

/// Forced stage-parallel forward NTT (no four-step dispatch) — the
/// hotpath bench compares this against [`ntt_four_step`] at the 2¹⁶
/// operating point. Same output as [`ntt`].
pub fn ntt_stage_parallel<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    radix2(values, plan.fwd_table(), threads);
}

/// Forced four-step forward NTT (usable below [`FOUR_STEP_MIN`], where
/// the auto path would pick the stage-parallel schedule). Same output as
/// [`ntt`]; sizes below 4 fall back to radix-2.
pub fn ntt_four_step<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    four_step_core(plan, values, false, threads);
}

/// Forced four-step inverse NTT (scales by n⁻¹). Same output as
/// [`intt`].
pub fn intt_four_step<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    four_step_core(plan, values, true, threads);
    scale_by(values, &plan.n_inv, threads);
}

/// The unscaled inverse transform (shared by [`intt`] and
/// [`coset_intt`], which apply different output scales).
fn backward<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    threads: usize,
) {
    assert_eq!(values.len(), plan.n, "value length != domain size");
    if threads > 1 && plan.n >= FOUR_STEP_MIN {
        four_step_core(plan, values, true, threads);
    } else {
        radix2(values, plan.inv_table(), threads);
    }
}

/// Largest power-of-two band count ≤ `threads` whose bands hold at
/// least [`MIN_BAND`] elements each; 1 means "run serial inline".
fn band_count(n: usize, threads: usize) -> usize {
    if threads <= 1 || n < 2 * MIN_BAND {
        return 1;
    }
    let mut bands = 1usize;
    while bands * 2 <= threads && n / (bands * 2) >= MIN_BAND {
        bands *= 2;
    }
    bands
}

/// One contiguous run of butterflies: `lo[i], hi[i] ← lo[i] ± tw[i]·hi[i]`.
///
/// Four butterflies per step through the limb-interleaved lane core —
/// the per-lane algorithm is the scalar one verbatim, so results and op
/// counts (1 mul + 2 adds per butterfly, zero squares) are identical;
/// the ragged tail (and the half ∈ {1, 2} early stages) runs scalar.
#[inline]
fn butterflies<P: FieldParams<N>, const N: usize>(
    lo: &mut [Fp<P, N>],
    hi: &mut [Fp<P, N>],
    tw: &[Fp<P, N>],
) {
    let len = lo.len();
    let mut i = 0;
    while i + LANES <= len {
        let u = FpLanes::load(&lo[i..]);
        let v = FpLanes::load(&hi[i..]);
        let t = v.mul4(&FpLanes::load(&tw[i..]));
        u.sub4(&t).store(&mut hi[i..]);
        u.add4(&t).store(&mut lo[i..]);
        i += LANES;
    }
    for ((u, v), w) in lo[i..].iter_mut().zip(hi[i..].iter_mut()).zip(&tw[i..]) {
        let t = v.mul(w);
        *v = u.sub(&t);
        *u = u.add(&t);
    }
}

/// All of stage `s`'s butterflies inside one contiguous part of the
/// array (the part's length must be a multiple of the stage's block
/// length `2^(s+1)`).
fn stage_serial<P: FieldParams<N>, const N: usize>(
    part: &mut [Fp<P, N>],
    table: &[Fp<P, N>],
    s: u32,
) {
    let half = 1usize << s;
    let tw = stage_slice(table, s);
    for block in part.chunks_mut(2 * half) {
        let (lo, hi) = block.split_at_mut(half);
        butterflies(lo, hi, tw);
    }
}

/// In-place radix-2 NTT over a flat stage table: bit-reverse, then a
/// band-local phase (one spawn per thread, zero synchronization) and a
/// cross-band phase (per stage, blocks' half-ranges split into
/// contiguous per-thread chunks).
fn radix2<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    table: &[Fp<P, N>],
    threads: usize,
) {
    let n = values.len();
    super::bit_reverse(values);
    if n <= 1 {
        return;
    }
    let log_n = n.trailing_zeros();
    let bands = band_count(n, threads);
    if bands == 1 {
        for s in 0..log_n {
            stage_serial(values, table, s);
        }
        return;
    }
    let band_len = n / bands;
    let local_stages = band_len.trailing_zeros();
    std::thread::scope(|scope| {
        for band in values.chunks_mut(band_len) {
            scope.spawn(move || {
                for s in 0..local_stages {
                    stage_serial(band, table, s);
                }
            });
        }
    });
    for s in local_stages..log_n {
        cross_stage(values, table, s, bands);
    }
}

/// One cross-band stage: every block spans multiple bands, so each
/// block's lower/upper halves split into contiguous chunk pairs — all
/// `lanes` threads stay busy even on the final single-block stage.
fn cross_stage<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    table: &[Fp<P, N>],
    s: u32,
    lanes: usize,
) {
    let half = 1usize << s;
    let blocks = values.len() >> (s + 1);
    let tw = stage_slice(table, s);
    let per = (lanes / blocks.max(1)).max(1);
    let chunk = half.div_ceil(per).max(1);
    std::thread::scope(|scope| {
        for block in values.chunks_mut(2 * half) {
            let (lo, hi) = block.split_at_mut(half);
            for ((lo_c, hi_c), tw_c) in
                lo.chunks_mut(chunk).zip(hi.chunks_mut(chunk)).zip(tw.chunks(chunk))
            {
                scope.spawn(move || butterflies(lo_c, hi_c, tw_c));
            }
        }
    });
}

/// Elementwise `vs[i] ← vs[i] · cs[i]`, four lanes per step with a
/// scalar tail — the shared kernel under [`pointwise`]'s serial and
/// banded branches.
#[inline]
fn mul_elementwise<P: FieldParams<N>, const N: usize>(vs: &mut [Fp<P, N>], cs: &[Fp<P, N>]) {
    let mut i = 0;
    while i + LANES <= vs.len() {
        FpLanes::load(&vs[i..]).mul4(&FpLanes::load(&cs[i..])).store(&mut vs[i..]);
        i += LANES;
    }
    for (v, c) in vs[i..].iter_mut().zip(&cs[i..]) {
        *v = v.mul(c);
    }
}

/// Uniform `vs[i] ← vs[i] · k`, four lanes per step against a splatted
/// constant — the shared kernel under [`scale_by`].
#[inline]
fn mul_uniform<P: FieldParams<N>, const N: usize>(vs: &mut [Fp<P, N>], k: &Fp<P, N>) {
    let kk = FpLanes::splat(k);
    let mut i = 0;
    while i + LANES <= vs.len() {
        FpLanes::load(&vs[i..]).mul4(&kk).store(&mut vs[i..]);
        i += LANES;
    }
    for v in vs[i..].iter_mut() {
        *v = v.mul(k);
    }
}

/// Pointwise `values[i] ← values[i] · table[i]` (the coset ladders).
fn pointwise<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    table: &[Fp<P, N>],
    threads: usize,
) {
    debug_assert_eq!(values.len(), table.len());
    let bands = band_count(values.len(), threads);
    if bands == 1 {
        mul_elementwise(values, table);
        return;
    }
    let chunk = values.len().div_ceil(bands);
    std::thread::scope(|scope| {
        for (vc, tc) in values.chunks_mut(chunk).zip(table.chunks(chunk)) {
            scope.spawn(move || mul_elementwise(vc, tc));
        }
    });
}

/// Pointwise scale by one constant (the plain iNTT's n⁻¹).
fn scale_by<P: FieldParams<N>, const N: usize>(
    values: &mut [Fp<P, N>],
    k: &Fp<P, N>,
    threads: usize,
) {
    let bands = band_count(values.len(), threads);
    if bands == 1 {
        mul_uniform(values, k);
        return;
    }
    let chunk = values.len().div_ceil(bands);
    std::thread::scope(|scope| {
        for vc in values.chunks_mut(chunk) {
            scope.spawn(move || mul_uniform(vc, k));
        }
    });
}

/// Transpose a `rows × cols` row-major matrix in `src` into `dst`
/// (which becomes `cols × rows` row-major). Destination rows partition
/// across threads; the source is read-shared.
fn transpose_into<P: FieldParams<N>, const N: usize>(
    dst: &mut [Fp<P, N>],
    src: &[Fp<P, N>],
    rows: usize,
    cols: usize,
    threads: usize,
) {
    debug_assert_eq!(dst.len(), rows * cols);
    debug_assert_eq!(src.len(), rows * cols);
    let bands = threads.clamp(1, cols);
    if bands == 1 {
        for (c, drow) in dst.chunks_mut(rows).enumerate() {
            for (j, slot) in drow.iter_mut().enumerate() {
                *slot = src[j * cols + c];
            }
        }
        return;
    }
    let band_rows = cols.div_ceil(bands);
    std::thread::scope(|scope| {
        for (b, dchunk) in dst.chunks_mut(band_rows * rows).enumerate() {
            let first = b * band_rows;
            scope.spawn(move || {
                for (r, drow) in dchunk.chunks_mut(rows).enumerate() {
                    let c = first + r;
                    for (j, slot) in drow.iter_mut().enumerate() {
                        *slot = src[j * cols + c];
                    }
                }
            });
        }
    });
}

/// Serial radix-2 NTT of one (small) row through a flat stage table.
fn radix2_row<P: FieldParams<N>, const N: usize>(row: &mut [Fp<P, N>], table: &[Fp<P, N>]) {
    super::bit_reverse(row);
    if row.len() <= 1 {
        return;
    }
    for s in 0..row.len().trailing_zeros() {
        stage_serial(row, table, s);
    }
}

/// NTT every `row_len`-sized row of `data` (rows partition across
/// threads; each row runs the serial kernel over `table`).
fn row_ntts<P: FieldParams<N>, const N: usize>(
    data: &mut [Fp<P, N>],
    row_len: usize,
    table: &[Fp<P, N>],
    threads: usize,
) {
    let rows = data.len() / row_len;
    let bands = threads.clamp(1, rows);
    if bands == 1 {
        for row in data.chunks_mut(row_len) {
            radix2_row(row, table);
        }
        return;
    }
    let rows_per = rows.div_ceil(bands);
    std::thread::scope(|scope| {
        for band in data.chunks_mut(rows_per * row_len) {
            scope.spawn(move || {
                for row in band.chunks_mut(row_len) {
                    radix2_row(row, table);
                }
            });
        }
    });
}

/// One twiddled row: `row[k] ← row[k] · wj^k` for `k ≥ 1` (column 0's
/// twiddle is 1). Four elements per step: the lane vector starts at
/// `[wj, wj², wj³, wj⁴]` and advances by a splat of `wj⁴`, replacing
/// two serial muls per element with two lane muls per group. Every
/// power is a product of exact, canonically-reduced Montgomery ops, so
/// the results are bit-identical to the serial ladder's.
fn twiddle_row<P: FieldParams<N>, const N: usize>(row: &mut [Fp<P, N>], wj: &Fp<P, N>) {
    let tail = &mut row[1..];
    if tail.len() < LANES {
        let mut w = *wj;
        for v in tail.iter_mut() {
            *v = v.mul(&w);
            w = w.mul(wj);
        }
        return;
    }
    let wj2 = wj.square();
    let wj4 = wj2.square();
    let mut w = FpLanes::from_elems(&[*wj, wj2, wj.mul(&wj2), wj4]);
    let step = FpLanes::splat(&wj4);
    let mut i = 0;
    while i + LANES <= tail.len() {
        FpLanes::load(&tail[i..]).mul4(&w).store(&mut tail[i..]);
        w = w.mul4(&step);
        i += LANES;
    }
    for (v, wl) in tail[i..].iter_mut().zip(&w.to_elems()) {
        *v = v.mul(wl);
    }
}

/// The four-step twiddle pass: row `j` of the `rows × row_len` matrix
/// multiplies elementwise by `root^(j·k)` for `k in 0..row_len` (row 0
/// and column 0 are untouched — their twiddle is 1).
fn twiddle_rows<P: FieldParams<N>, const N: usize>(
    data: &mut [Fp<P, N>],
    row_len: usize,
    root: &Fp<P, N>,
    threads: usize,
) {
    let rows = data.len() / row_len;
    let bands = threads.clamp(1, rows);
    let rows_per = rows.div_ceil(bands);
    let twiddle_band = |band: &mut [Fp<P, N>], first: usize| {
        for (r, row) in band.chunks_mut(row_len).enumerate() {
            let j = first + r;
            if j == 0 {
                continue;
            }
            twiddle_row(row, &root.pow_u64(j as u64));
        }
    };
    if bands == 1 {
        twiddle_band(data, 0);
        return;
    }
    std::thread::scope(|scope| {
        for (b, band) in data.chunks_mut(rows_per * row_len).enumerate() {
            let twiddle_band = &twiddle_band;
            scope.spawn(move || twiddle_band(band, b * rows_per));
        }
    });
}

/// The four-step (transpose) NTT: n = n₁·n₂ with n₁ = 2^⌊log n / 2⌋.
///
/// Writing input index `j = j₁ + n₁·j₂` and output index
/// `k = n₂·k₁ + k₂`, the transform factors as n₂-point NTTs over j₂
/// (root ω^n₁), a twiddle by ω^(j₁·k₂), and n₁-point NTTs over j₁
/// (root ω^n₂) — three transposes keep every row contiguous. The two
/// sub-size stage tables cost O(√n) to build per call (negligible next
/// to the n/2·log n butterflies); the full-size tables stay in the
/// plan.
fn four_step_core<P: FieldParams<N>, const N: usize>(
    plan: &NttPlan<P, N>,
    values: &mut [Fp<P, N>],
    inverse: bool,
    threads: usize,
) {
    let n = plan.n;
    if n < 4 {
        let table = if inverse { plan.inv_table() } else { plan.fwd_table() };
        radix2(values, table, threads);
        return;
    }
    let n1 = 1usize << (plan.log_n / 2);
    let n2 = n / n1;
    let root = if inverse { plan.omega_inv } else { plan.omega };
    let table_n2 = build_stage_tables(&root.pow_u64(n1 as u64), n2);
    let table_n1 = build_stage_tables(&root.pow_u64(n2 as u64), n1);
    let mut scratch = vec![Fp::<P, N>::zero(); n];
    // 1. gather T[j₁][j₂] = x[j₁ + n₁·j₂] (transpose of the n₂×n₁ view)
    transpose_into(&mut scratch, values, n2, n1, threads);
    // 2. inner transforms: n₂-point NTT along each row (root ω^n₁)
    row_ntts(&mut scratch, n2, &table_n2, threads);
    // 3. twiddle T[j₁][k₂] by ω^(j₁·k₂)
    twiddle_rows(&mut scratch, n2, &root, threads);
    // 4. transpose to U[k₂][j₁]
    transpose_into(values, &scratch, n1, n2, threads);
    // 5. outer transforms: n₁-point NTT along each row (root ω^n₂)
    row_ntts(values, n1, &table_n1, threads);
    // 6. U[k₂][k₁] = X[n₂·k₁ + k₂] — the last transpose IS the output
    transpose_into(&mut scratch, values, n2, n1, threads);
    values.copy_from_slice(&scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::params::Bn254FrParams;
    use crate::ff::FrBn254;
    use crate::ntt::{intt_in_place, ntt_in_place};
    use crate::util::rng::Rng;

    type Plan = NttPlan<Bn254FrParams, 4>;

    fn rand_vec(n: usize, seed: u64) -> Vec<FrBn254> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| FrBn254::random(&mut rng)).collect()
    }

    #[test]
    fn stage_parallel_matches_reference_across_threads() {
        for n in [2usize, 8, 64, 1024] {
            let plan = Plan::new(n).unwrap();
            let orig = rand_vec(n, 601 + n as u64);
            let mut want = orig.clone();
            ntt_in_place(&mut want, &plan.omega);
            for threads in [1usize, 2, 4, 32] {
                let mut got = orig.clone();
                ntt_stage_parallel(&plan, &mut got, threads);
                assert_eq!(got, want, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn four_step_matches_reference() {
        for n in [4usize, 16, 256, 4096] {
            let plan = Plan::new(n).unwrap();
            let orig = rand_vec(n, 611 + n as u64);
            let mut want = orig.clone();
            ntt_in_place(&mut want, &plan.omega);
            for threads in [1usize, 3, 16] {
                let mut got = orig.clone();
                ntt_four_step(&plan, &mut got, threads);
                assert_eq!(got, want, "n={n} threads={threads}");
                // inverse four-step takes it back, scale included
                intt_four_step(&plan, &mut got, threads);
                assert_eq!(got, orig, "n={n} threads={threads} inverse");
            }
        }
    }

    #[test]
    fn intt_matches_reference_and_roundtrips() {
        let n = 512;
        let plan = Plan::new(n).unwrap();
        let orig = rand_vec(n, 621);
        let mut want = orig.clone();
        intt_in_place(&mut want, &plan.omega);
        for threads in [1usize, 4] {
            let mut got = orig.clone();
            intt(&plan, &mut got, threads);
            assert_eq!(got, want, "threads={threads}");
            ntt(&plan, &mut got, threads);
            assert_eq!(got, orig, "threads={threads} roundtrip");
        }
    }

    #[test]
    fn coset_paths_match_the_pre_plan_semantics() {
        let n = 256;
        let plan = Plan::new(n).unwrap();
        let orig = rand_vec(n, 631);
        // the pre-plan reference: serial gⁱ walk, then the plain NTT
        let mut want = orig.clone();
        let mut scale = FrBn254::one();
        for v in want.iter_mut() {
            *v = v.mul(&scale);
            scale = scale.mul(&plan.coset_gen);
        }
        ntt_in_place(&mut want, &plan.omega);
        for threads in [1usize, 2, 32] {
            let mut got = orig.clone();
            coset_ntt(&plan, &mut got, threads);
            assert_eq!(got, want, "threads={threads}");
            coset_intt(&plan, &mut got, threads);
            assert_eq!(got, orig, "threads={threads} roundtrip");
        }
    }

    #[test]
    fn band_count_respects_floors() {
        assert_eq!(band_count(1 << 20, 1), 1);
        assert_eq!(band_count(64, 32), 1); // below 2·MIN_BAND: serial
        assert_eq!(band_count(1 << 12, 4), 4);
        assert_eq!(band_count(1 << 12, 5), 4); // power-of-two clamp
        // bands never shrink a band below MIN_BAND elements
        assert_eq!(band_count(1 << 10, 64), 4);
    }

    #[test]
    fn transpose_roundtrip() {
        let rows = 8;
        let cols = 4;
        let src: Vec<FrBn254> = (0..rows * cols).map(|i| FrBn254::from_u64(i as u64)).collect();
        let mut t = vec![FrBn254::zero(); rows * cols];
        transpose_into(&mut t, &src, rows, cols, 3);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(t[c * rows + r], src[r * cols + c]);
            }
        }
        let mut back = vec![FrBn254::zero(); rows * cols];
        transpose_into(&mut back, &t, cols, rows, 1);
        assert_eq!(back, src);
    }
}
