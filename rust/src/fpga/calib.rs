//! Calibration constants for the Agilex SAB model.
//!
//! Two classes of constants live here:
//!
//! 1. **Published synthesis/measurement results** quoted verbatim from the
//!    paper (Tables IV, V, VIII and §IV/§V text) — these are inputs to the
//!    model, not things a software reproduction can re-derive;
//! 2. **Fitted coefficients** derived from those tables (least squares over
//!    Table VII/VIII rows — derivations in EXPERIMENTS.md §Calibration).
//!
//! Everything downstream (Tables VII/IX/X, Figures 5–8) is *computed* from
//! these plus the architecture equations, and the bench suite checks the
//! computed values against the paper's published rows.

/// Hardware window (scalar slice) width k. Inferred from Table III:
/// ⌈254/12⌉ = 22, ⌈381/12⌉ = 32 point-ops per point.
pub const HW_WINDOW_BITS: u32 = 12;

/// UDA pipeline latency, standard-form build (§IV-B4: "latency was reduced
/// from 425 to 270 clock cycles").
pub const UDA_LATENCY_STD: u64 = 270;
/// UDA pipeline latency, Montgomery build.
pub const UDA_LATENCY_MONT: u64 = 425;

/// Point-processor fmax (§IV-B4): >700 MHz for 254-bit — the *unit*
/// closes timing well above the system clock.
pub const UNIT_FMAX_254_HZ: f64 = 700e6;
/// Point-processor fmax for the 381-bit build (>600 MHz, §IV-B4).
pub const UNIT_FMAX_381_HZ: f64 = 600e6;

/// System fmax ceiling (§V-C1: "achieved fmax was 351MHz … for other build
/// variations fmax was in the range of 334-367MHz").
pub const SYS_FMAX_CEIL_HZ: f64 = 367e6;
/// System fmax floor of the same §V-C1 range.
pub const SYS_FMAX_FLOOR_HZ: f64 = 334e6;
/// Linear congestion model: fmax = min(ceil, A − B·utilization).
pub const SYS_FMAX_A_HZ: f64 = 425e6;
/// Slope of the congestion model (Hz lost per unit ALM utilization).
pub const SYS_FMAX_B_HZ: f64 = 80e6;

/// Effective DDR bandwidth per memory-channel group feeding one BAM
/// (bytes/s). Calibrated so the BLS12-381 S=2 64M-point run lands on
/// Table IX's 15.03 s (stream-bound regime): 64e6·96·32 / (2·bw) = 15.03
/// ⇒ bw ≈ 6.54 GB/s — a realistic ~68% efficiency on a DDR4-2400 bank.
pub const DDR_BW_PER_GROUP: f64 = 6.54e9;

/// Host→device PCIe effective bandwidth (scalars move per call; points are
/// resident — §IV-A). PCIe gen3 x16 practical.
pub const PCIE_BW: f64 = 12.0e9;

/// Fixed per-MSM-call overhead (driver, kernel launch, result readback):
/// calibrated from Table IX's small-size plateau (1K and 10K points both
/// ≈ 0.01–0.02 s).
pub const CALL_OVERHEAD_S: f64 = 0.009;

/// Unsigned bucket count per window = 2^k — the published hardware's
/// reference value. The timing model no longer consumes this directly:
/// live bucket counts come from `msm::plan::MsmPlan` (signed-digit builds
/// halve them), keeping model and software consistent.
pub const HW_BUCKETS: u64 = 1 << HW_WINDOW_BITS as u64;

/// IS-RBAM sub-window width k₂ used by the hardware reduction.
pub const HW_RBAM_K2: u32 = 6;

// ---------------------------------------------------------------------------
// Power model (fit to Table VIII; see EXPERIMENTS.md §Calibration).
// standby = BSP + αA·ALM[M] + αD·DSP[k] + αM·M20K[k]   (pure surrogate fit)
// active  = standby + base(form) + γS·S
// ---------------------------------------------------------------------------

/// BSP-only board power (Table VIII row 1).
pub const POWER_BSP_W: f64 = 17.25;
/// Standby watts per million ALMs (surrogate fit over Table VIII).
pub const POWER_STANDBY_PER_MALM: f64 = 65.857;
/// Standby watts per thousand DSPs (same fit; sign is the fit's, not physics).
pub const POWER_STANDBY_PER_KDSP: f64 = -2.954;
/// Standby watts per thousand M20Ks (same fit).
pub const POWER_STANDBY_PER_KM20K: f64 = -0.714;
/// Dynamic base, standard-form datapath.
pub const POWER_DYN_BASE_STD_W: f64 = 11.0;
/// Dynamic base, Montgomery datapath (≈3 integer multipliers toggling per
/// modmul — the paper's motivation for leaving Montgomery form).
pub const POWER_DYN_BASE_MONT_W: f64 = 24.4;
/// Dynamic increment per scaling unit S.
pub const POWER_DYN_PER_S_W: f64 = 3.7;

// ---------------------------------------------------------------------------
// Resource model calibration (Tables IV, V, VII; §IV-B).
// ---------------------------------------------------------------------------

/// DSPs per full-width integer multiplier, by (bits, form). §IV-B
/// cross-check: UDA has 18 modmuls; Montgomery needs 3 integer mults per
/// modmul (Table V: 18·3·100 = 5400), standard form needs 1
/// (18·110 ≈ 1975; 18·246 ≈ 4425).
pub fn dsp_per_intmul(bits: u32, montgomery: bool) -> f64 {
    match (bits, montgomery) {
        (254, true) => 100.0,
        (254, false) => 109.7,
        (381, false) => 245.8,
        (381, true) => 218.0, // extrapolated (never built: "not possible to fit")
        _ => {
            // quadratic in width, anchored at 254
            let base = if montgomery { 100.0 } else { 109.7 };
            base * (bits as f64 / 254.0).powi(2)
        }
    }
}

/// Modular multipliers in the UDA datapath (§IV-B: "full pipelining of both
/// operations using just 18 total instances").
pub const UDA_MODMULS: u32 = 18;
/// ... and in the naive PA+PD pair (25 instances [23]).
pub const PAPD_MODMULS: u32 = 25;

/// Table IV PA block ALMs (254-bit Montgomery, the only PAPD build):
/// the separate fully-pipelined point adder, quoted verbatim.
pub const PA_BLOCK_ALM: f64 = 272_000.0;
/// Table IV PA block DSPs.
pub const PA_BLOCK_DSP: f64 = 4_800.0;
/// Table IV PA block M20Ks.
pub const PA_BLOCK_M20K: f64 = 332.0;
/// Table IV folded point-doubler ALMs.
pub const PD_BLOCK_ALM: f64 = 100_100.0;
/// Table IV folded point-doubler DSPs.
pub const PD_BLOCK_DSP: f64 = 255.0;
/// Table IV folded point-doubler M20Ks.
pub const PD_BLOCK_M20K: f64 = 410.0;

/// Practical ALM utilization ceiling for place-and-route (§V-C1: 91% is
/// described as "very close to FPGA capacity ceiling"; builds beyond this
/// fail timing/routing, which is why the paper stops at S=2).
pub const ALM_UTIL_CEILING: f64 = 0.92;

/// ALM per modmul, by (bits, form) — from Table V / UDA_MODMULS.
pub fn alm_per_modmul(bits: u32, montgomery: bool) -> f64 {
    match (bits, montgomery) {
        (254, true) => 290_400.0 / 18.0,
        (254, false) => 207_000.0 / 18.0,
        (381, false) => 419_000.0 / 18.0,
        _ => {
            let base = if montgomery { 290_400.0 } else { 207_000.0 } / 18.0;
            base * (bits as f64 / 254.0).powf(1.9)
        }
    }
}

/// M20K per modmul (standard form holds the Öztürk reduction tables in
/// M20K — the ALM/DSP ↔ M20K trade §IV-B4 describes).
pub fn m20k_per_modmul(bits: u32, montgomery: bool) -> f64 {
    match (bits, montgomery) {
        (254, true) => 647.0 / 18.0,
        (254, false) => 3367.0 / 18.0,
        (381, false) => 6770.0 / 18.0,
        _ => {
            let base = if montgomery { 647.0 } else { 3367.0 } / 18.0;
            base * (bits as f64 / 254.0).powf(1.9)
        }
    }
}

/// Non-adder system overhead (BSP shell + SPS + IS-RBAM + DNA + host
/// interface), ALMs. Fitted from Table VII: S=1 rows minus Table V adder.
pub const SHELL_ALM: f64 = 293_000.0;
/// Shell M20K overhead of the same fit.
pub const SHELL_M20K: f64 = 1_470.0;

/// Per-BAM-instance overhead (bucket memory control, scheduling), by curve
/// field width. Fitted from Table VII S=2 − S=1 deltas.
pub fn bam_alm(bits: u32) -> f64 {
    match bits {
        254 => 34_500.0,
        381 => 61_500.0,
        _ => 34_500.0 * (bits as f64 / 254.0).powf(1.4),
    }
}

/// Per-BAM-instance M20K (bucket memory), by curve field width.
pub fn bam_m20k(bits: u32) -> f64 {
    // Bucket storage: 2^k Jacobian points per window live in M20K.
    match bits {
        254 => 900.0,
        381 => 1_300.0,
        _ => 900.0 * (bits as f64 / 254.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_model_reproduces_table_v() {
        // Table V DSP columns are exact products of the §IV-B structure.
        assert_eq!((UDA_MODMULS as f64 * 3.0 * dsp_per_intmul(254, true)).round(), 5400.0);
        assert_eq!((UDA_MODMULS as f64 * dsp_per_intmul(254, false)).round(), 1975.0);
        assert_eq!((UDA_MODMULS as f64 * dsp_per_intmul(381, false)).round(), 4424.0);
    }

    #[test]
    fn latency_constants_match_paper() {
        assert_eq!(UDA_LATENCY_STD, 270);
        assert_eq!(UDA_LATENCY_MONT, 425);
    }

    #[test]
    fn ddr_calibration_hits_table_ix_anchor() {
        // 64M BLS12-381 S=2 stream time ≈ 15.03 s − overhead-ish terms
        let t = 64e6 * 96.0 * 32.0 / (2.0 * DDR_BW_PER_GROUP);
        assert!((t - 15.03).abs() < 0.4, "stream anchor {t}");
    }

    #[test]
    fn extrapolations_monotone_in_bits() {
        assert!(dsp_per_intmul(512, false) > dsp_per_intmul(381, false));
        assert!(alm_per_modmul(300, false) > alm_per_modmul(254, false));
        assert!(bam_alm(500) > bam_alm(381));
    }
}
