//! SAB composition: end-to-end MSM timing on the modeled accelerator
//! (Fig. 2), regenerating Table IX's FPGA column and Figures 5–8's FPGA
//! series.
//!
//! Phases per MSM call:
//!
//! 1. host→device scalar transfer (points are DDR-resident, §IV-A);
//! 2. per window: SPS stream pass ∥ BAM fill (the slower bounds);
//! 3. reduction (IS-RBAM) overlapped across windows with the next fill —
//!    modeled conservatively as additive serial tail per non-overlapped
//!    round;
//! 4. DNA combine;
//! 5. fixed call overhead (driver/launch/result readback).

use super::bam::BamModel;
use super::calib;
use super::dna::DnaModel;
use super::rbam::{RbamModel, ReductionKind};
use super::resources::{DesignVariant, NumberForm, ResourceModel};
use super::sps::SpsModel;
use super::uda::UdaPipe;
use super::CurveId;
use crate::msm::partial::{ShardPolicy, ShardSpec};
use crate::msm::plan::{Decomposition, MsmConfig, MsmPlan, Reduction, Slicing};

/// One accelerator build.
#[derive(Clone, Copy, Debug)]
pub struct SabConfig {
    /// Target curve (fixes field width, point bytes, window count).
    pub curve: CurveId,
    /// Point-processor design point (bits / number form / unified).
    pub variant: DesignVariant,
    /// Scaling factor S (replicated BAM + channel group).
    pub scaling: u32,
    /// Reduction strategy (the paper ships IS-RBAM; running-sum kept for
    /// the ablation).
    pub reduction: ReductionKind,
    /// IS-RBAM instances.
    pub rbam_units: u32,
    /// Digit encoding: window count and bucket count derive from it via
    /// the shared `msm::plan` (signed halves bucket memory and the serial
    /// reduce chain; a carry window is added only when the top slice can
    /// carry — never at the paper's k = 12 scalar widths).
    pub slicing: Slicing,
    /// Scalar decomposition: [`Decomposition::Glv`] models the
    /// endomorphism split — half the window passes over a doubled
    /// (P, φ(P)) point set, so total fill/stream work is unchanged while
    /// the serial reduce chain and DNA combine halve again; DDR point
    /// residency doubles (see `coordinator::pointcache::resident_bytes`).
    pub decomposition: Decomposition,
    /// Fixed-base precompute tables resident in DDR (the SRS point-cache
    /// what-if, `msm::precomp`): per-window shifted multiples replace the
    /// live point set, multiplying DDR residency ([`Self::ddr_points`])
    /// by the window count while each window pass still streams one
    /// expanded-set column — fill/stream/reduce are unchanged and the DNA
    /// combine collapses to windows − 1 serial adds (the Horner doubling
    /// chain is pre-paid in the tables).
    pub precomp_tables: bool,
}

impl SabConfig {
    /// The paper's shipping configuration for a curve and scaling factor
    /// (unsigned 2^k buckets, as published).
    pub fn paper(curve: CurveId, scaling: u32) -> SabConfig {
        SabConfig {
            curve,
            variant: DesignVariant {
                bits: curve.field_bits(),
                form: NumberForm::Standard,
                unified: true,
            },
            scaling,
            reduction: ReductionKind::Recursive { k2: calib::HW_RBAM_K2 },
            rbam_units: 1,
            slicing: Slicing::Unsigned,
            decomposition: Decomposition::Full,
            precomp_tables: false,
        }
    }

    /// The paper design with signed-digit buckets (half the bucket M20K,
    /// half the serial reduce chain — the SZKP-style what-if).
    pub fn paper_signed(curve: CurveId, scaling: u32) -> SabConfig {
        SabConfig { slicing: Slicing::Signed, ..SabConfig::paper(curve, scaling) }
    }

    /// The signed-digit design with the GLV endomorphism split layered on
    /// top (the what-if motivated by SZKP/ZK-Flex scalar decomposition):
    /// half-width scalars against the doubled (P, φ(P)) point set. Window
    /// passes halve, so the serial reduce chain and the DNA combine drop
    /// another ~2x beyond signed digits; DDR residency doubles
    /// ([`Self::ddr_points`]).
    pub fn paper_glv(curve: CurveId, scaling: u32) -> SabConfig {
        SabConfig { decomposition: Decomposition::Glv, ..SabConfig::paper_signed(curve, scaling) }
    }

    /// The GLV build with fixed-base precompute tables resident in DDR
    /// (the `msm::precomp` point-cache what-if): window passes read
    /// pre-shifted multiples, so the DNA combine collapses to a plain
    /// windows − 1 add chain while DDR residency multiplies by the window
    /// count. Only worth it for SRS-style fixed bases reused across calls
    /// — the table build itself is amortized off the modeled path.
    pub fn paper_tables(curve: CurveId, scaling: u32) -> SabConfig {
        SabConfig { precomp_tables: true, ..SabConfig::paper_glv(curve, scaling) }
    }

    /// Points resident in device DDR for an m-point MSM under this build
    /// (GLV keeps the endo-expanded set resident: 2m; fixed-base tables
    /// keep one shifted copy per window on top of that). The expansion
    /// factor is [`Decomposition::expansion_factor`] and the table factor
    /// is the plan's window count — the same rule the coordinator budgets
    /// with (`coordinator::pointcache::table_resident_bytes`).
    pub fn ddr_points(&self, m: u64) -> u64 {
        let expanded = m.saturating_mul(self.decomposition.expansion_factor());
        if self.precomp_tables {
            expanded.saturating_mul(u64::from(self.plan().windows))
        } else {
            expanded
        }
    }

    /// Points one window pass streams from DDR: the decomposition-expanded
    /// set. Tables change *residency* ([`Self::ddr_points`]), not the
    /// per-pass working set — each window reads exactly its own
    /// pre-shifted column, the same volume as a live-point pass.
    pub fn streamed_points(&self, m: u64) -> u64 {
        m.saturating_mul(self.decomposition.expansion_factor())
    }

    /// The pre-UDA Montgomery build (Table VII row 1, BN128 only).
    pub fn papd_montgomery(scaling: u32) -> SabConfig {
        SabConfig {
            curve: CurveId::Bn254,
            variant: DesignVariant { bits: 254, form: NumberForm::Montgomery, unified: false },
            scaling,
            reduction: ReductionKind::RunningSum,
            rbam_units: 1,
            slicing: Slicing::Unsigned,
            decomposition: Decomposition::Full,
            precomp_tables: false,
        }
    }

    /// The software-plan view of this build: window count, bucket count,
    /// and serial-chain accounting all come from here.
    pub fn plan(&self) -> MsmPlan {
        let reduction = match self.reduction {
            ReductionKind::RunningSum => Reduction::RunningSum,
            ReductionKind::Recursive { k2 } => Reduction::Recursive { k2 },
        };
        MsmPlan::new(
            self.curve.field_bits(),
            &MsmConfig {
                window_bits: calib::HW_WINDOW_BITS,
                reduction,
                slicing: self.slicing,
                decomposition: self.decomposition,
            },
        )
    }
}

/// Timing breakdown of one MSM call (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct MsmTiming {
    /// Host→device scalar transfer (PCIe).
    pub transfer_s: f64,
    /// BAM bucket-fill compute across all windows.
    pub fill_s: f64,
    /// DDR point streaming across all window passes.
    pub stream_s: f64,
    /// Non-overlapped reduction tail (IS-RBAM or running sum).
    pub reduce_s: f64,
    /// DNA Horner combine.
    pub combine_s: f64,
    /// Fixed per-call overhead (driver/launch/readback).
    pub overhead_s: f64,
    /// Which of fill/stream bounds the steady-state phase.
    pub stream_bound: bool,
}

impl MsmTiming {
    /// End-to-end seconds: transfer + max(fill, stream) + tails + overhead.
    pub fn total_s(&self) -> f64 {
        self.transfer_s
            + self.fill_s.max(self.stream_s)
            + self.reduce_s
            + self.combine_s
            + self.overhead_s
    }

    /// Throughput in the paper's unit: millions of MSM points per second.
    pub fn m_msm_pps(&self, m: u64) -> f64 {
        m as f64 / self.total_s() / 1e6
    }
}

/// The composed model.
#[derive(Clone, Copy, Debug)]
pub struct SabModel {
    /// The accelerator build being timed.
    pub cfg: SabConfig,
    /// Modeled system clock (Hz) of that build.
    pub fmax_hz: f64,
    pipe: UdaPipe,
}

impl SabModel {
    /// Compose the per-stage models for one build.
    pub fn new(cfg: SabConfig) -> SabModel {
        let rm = ResourceModel;
        let fmax_hz = rm.system_fmax(cfg.variant, cfg.scaling);
        let pipe = if cfg.variant.unified {
            UdaPipe::unified(cfg.variant.form)
        } else {
            UdaPipe::papd()
        };
        SabModel { cfg, fmax_hz, pipe }
    }

    /// Time one MSM of `m` points. Window and bucket counts come from the
    /// shared software plan ([`SabConfig::plan`]), never from hard-coded
    /// `2^k` — signed-digit builds automatically see half the buckets
    /// (and a carry window only for scalar widths whose top slice can
    /// carry; not at the paper's operating points).
    pub fn time_msm(&self, m: u64) -> MsmTiming {
        let curve = self.cfg.curve;
        let k = calib::HW_WINDOW_BITS;
        let plan = self.cfg.plan();
        let windows = plan.windows;
        let live_buckets = plan.live_buckets();
        let s = self.cfg.scaling.max(1);
        // GLV builds stream/fill the endo-expanded set: 2m ops per window
        // over half the windows — total fill and stream work is unchanged;
        // the win is the halved serial chain and combine below. Fixed-base
        // tables multiply DDR *residency*, not the per-pass volume: each
        // window streams exactly its own pre-shifted column.
        let m_eff = self.cfg.streamed_points(m);

        // 1. scalar transfer (PCIe) — m full-width scalars either way (the
        // half-width split is a device-side integer computation).
        let transfer_s = m as f64 * curve.scalar_bytes() as f64 / calib::PCIE_BW;

        // 2. fills: windows are processed sequentially; within a window the
        // m_eff ops are split across S BAM instances. PA+PD builds also pay
        // the folded-PD penalty on the doubling-class ops mixed in.
        let bam = BamModel { buckets: live_buckets, pipe: self.pipe };
        let per_window_ops = m_eff.div_ceil(s as u64);
        let fill_cycles = bam.fill_cycles(per_window_ops) * windows as u64;
        let fill_s = fill_cycles as f64 / self.fmax_hz;

        // concurrent stream passes over the (possibly expanded) point set
        let sps = SpsModel::new(s);
        let stream_s = sps.msm_stream_seconds(curve, m_eff, windows);

        // 3. reduction: in steady state a window's reduction overlaps the
        // next window's fill; only the non-overlapped remainder is exposed.
        let rbam = RbamModel { pipe: self.pipe, rbam_units: self.cfg.rbam_units };
        let reduce_total = rbam.total_cycles(k, live_buckets, windows, self.cfg.reduction)
            as f64
            / self.fmax_hz;
        let per_window_fill_s = bam.fill_cycles(per_window_ops) as f64 / self.fmax_hz;
        let hidden = per_window_fill_s * (windows as f64 - 1.0);
        let reduce_s = (reduce_total - hidden).max(reduce_total / windows as f64);

        // 4. combine: the Horner chain (k doublings + 1 add per window),
        // unless precompute tables pre-paid the doublings — then window
        // results sit at their final weight and the combine is a plain
        // windows − 1 serially dependent add chain (the same shape as a
        // host-side shard merge).
        let dna = DnaModel { pipe: self.pipe };
        let combine_s = if self.cfg.precomp_tables {
            self.merge_seconds(windows)
        } else {
            dna.combine_cycles(k, windows) as f64 / self.fmax_hz
        };

        MsmTiming {
            transfer_s,
            fill_s,
            stream_s,
            reduce_s,
            combine_s,
            overhead_s: calib::CALL_OVERHEAD_S,
            stream_bound: stream_s > fill_s,
        }
    }

    /// Sweep of sizes → (m, timing), for the figures.
    pub fn sweep(&self, sizes: &[u64]) -> Vec<(u64, MsmTiming)> {
        sizes.iter().map(|&m| (m, self.time_msm(m))).collect()
    }

    /// Host-side merge tail of a `d`-kernel sharded MSM: d − 1 serially
    /// dependent point additions, each paying a full pipeline latency.
    fn merge_seconds(&self, d: u32) -> f64 {
        self.pipe.serial_cycles(u64::from(d.saturating_sub(1))) as f64 / self.fmax_hz
    }

    /// Modeled device seconds for **one shard** of an m-point sharded MSM.
    /// `plan_windows` is the window count of the *job's* plan — the plan
    /// the spec's window indices live in, which need not match this
    /// model's own hardware plan — so the window fraction stays in [0, 1].
    /// Window shards scale only the window-dependent phases (fill/stream,
    /// reduce, combine); the scalar broadcast and call overhead are paid
    /// whole — the same decomposition [`Self::time_msm_sharded`] uses, so
    /// the served metrics and the what-if table agree. The single source
    /// of truth for per-shard device time: both the coordinator's
    /// sim-FPGA devices and the in-process pool call this.
    pub fn time_shard(&self, m: u64, spec: &ShardSpec, plan_windows: u32) -> f64 {
        match *spec {
            ShardSpec::PointChunk { lo, hi } => self.time_msm((hi - lo) as u64).total_s(),
            ShardSpec::WindowRange { lo, hi } => {
                let full = self.time_msm(m);
                let frac = f64::from(hi - lo) / f64::from(plan_windows.max(1));
                full.transfer_s
                    + (full.fill_s.max(full.stream_s) + full.reduce_s + full.combine_s) * frac
                    + full.overhead_s
            }
        }
    }

    /// End-to-end seconds for one m-point MSM sharded across `devices`
    /// replicated kernels — the coordinator's multi-device path, modeled
    /// (§V's scaling argument taken past one board).
    ///
    /// * [`ShardPolicy::ChunkPoints`]: each kernel runs an ⌈m/d⌉-point MSM
    ///   over all windows — scalar transfer, fills *and* DDR streaming all
    ///   shrink by d; the partials merge with d − 1 serial adds.
    /// * [`ShardPolicy::WindowRange`]: each kernel sees all m scalars
    ///   (broadcast transfer, unscaled) but fills/streams/reduces only its
    ///   ⌈windows/d⌉ window slice.
    ///
    /// Chunk sharding therefore scales the stream-bound large-m regime;
    /// window sharding stops helping once the shared scalar broadcast
    /// dominates — exactly the trade-off the what-if table shows.
    pub fn time_msm_sharded(&self, m: u64, devices: u32, policy: ShardPolicy) -> MsmTiming {
        let d = devices.max(1);
        if d == 1 {
            return self.time_msm(m);
        }
        match policy {
            ShardPolicy::ChunkPoints => {
                let mut t = self.time_msm(m.div_ceil(u64::from(d)));
                t.combine_s += self.merge_seconds(d);
                t
            }
            ShardPolicy::WindowRange => {
                let full = self.time_msm(m);
                let windows = self.cfg.plan().windows.max(1);
                let shard_windows = windows.div_ceil(d).min(windows);
                let frac = f64::from(shard_windows) / f64::from(windows);
                MsmTiming {
                    transfer_s: full.transfer_s, // scalars broadcast whole
                    fill_s: full.fill_s * frac,
                    stream_s: full.stream_s * frac,
                    reduce_s: full.reduce_s * frac,
                    combine_s: full.combine_s * frac + self.merge_seconds(d),
                    overhead_s: full.overhead_s,
                    stream_bound: full.stream_bound,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bls_s2() -> SabModel {
        SabModel::new(SabConfig::paper(CurveId::Bls12381, 2))
    }

    #[test]
    fn table_ix_fpga_column_shape() {
        // paper: 1K→0.01s, 1M→0.24s, 64M→15.03s
        let m = bls_s2();
        let t1k = m.time_msm(1_000).total_s();
        let t1m = m.time_msm(1_000_000).total_s();
        let t64m = m.time_msm(64_000_000).total_s();
        assert!((0.005..0.02).contains(&t1k), "1K: {t1k}");
        assert!((0.15..0.35).contains(&t1m), "1M: {t1m}");
        assert!((13.5..16.5).contains(&t64m), "64M: {t64m}");
    }

    #[test]
    fn bn128_faster_than_bls() {
        // §V-C2: "performance of BN128 is almost 2x compared to BLS12-381"
        let bn = SabModel::new(SabConfig::paper(CurveId::Bn254, 2));
        let bls = bls_s2();
        let m = 16_000_000;
        let ratio =
            bls.time_msm(m).total_s() / bn.time_msm(m).total_s();
        assert!((1.6..2.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn scaling_near_linear_at_large_sizes() {
        // Fig. 6: throughput(S=2) ≈ 2× throughput(S=1)
        let s1 = SabModel::new(SabConfig::paper(CurveId::Bls12381, 1));
        let s2 = bls_s2();
        let m = 32_000_000;
        let speedup = s1.time_msm(m).total_s() / s2.time_msm(m).total_s();
        assert!((1.7..2.1).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn throughput_peaks_early_then_flat() {
        // Fig. 6: "MSM sizes with tens of thousands of points will execute
        // at maximum throughput"
        let m = bls_s2();
        let t10k = m.time_msm(10_000).m_msm_pps(10_000);
        let t1m = m.time_msm(1_000_000).m_msm_pps(1_000_000);
        let t64m = m.time_msm(64_000_000).m_msm_pps(64_000_000);
        assert!(t10k < t1m, "ramp: {t10k} < {t1m}");
        assert!((t1m / t64m - 1.0).abs() < 0.25, "plateau: {t1m} vs {t64m}");
    }

    #[test]
    fn signed_build_halves_buckets_and_serial_chain() {
        let u = SabConfig::paper(CurveId::Bn254, 2);
        let s = SabConfig::paper_signed(CurveId::Bn254, 2);
        // bucket memory: 4095 live → 2048 live; and at k=12 the 254-bit
        // top slice (2 bits) can never carry, so no extra window either
        assert_eq!(u.plan().live_buckets(), 4095);
        assert_eq!(s.plan().live_buckets(), 2048);
        assert_eq!(s.plan().windows, u.plan().windows);
        // in the reduce-exposed (running-sum) regime the halved chain wins
        // end to end despite the extra window
        let ur = SabConfig { reduction: ReductionKind::RunningSum, ..u };
        let sr = SabConfig { reduction: ReductionKind::RunningSum, ..s };
        let t_u = SabModel::new(ur).time_msm(100_000).total_s();
        let t_s = SabModel::new(sr).time_msm(100_000).total_s();
        assert!(t_s < t_u, "signed {t_s} vs unsigned {t_u}");
    }

    #[test]
    fn glv_build_halves_windows_chain_and_doubles_residency() {
        let signed = SabConfig::paper_signed(CurveId::Bn254, 2);
        let glv = SabConfig::paper_glv(CurveId::Bn254, 2);
        let ps = signed.plan();
        let pg = glv.plan();
        // 254-bit scalars → 128-bit halves → 11 windows instead of 22
        assert_eq!(ps.windows, 22);
        assert_eq!(pg.windows, 11);
        // bucket memory is per-window: unchanged; the serial chain halves
        // with the window count
        assert_eq!(pg.live_buckets(), ps.live_buckets());
        assert_eq!(2 * pg.serial_reduce_ops(), ps.serial_reduce_ops());
        // DDR residency doubles (the pointcache budget must account for it)
        assert_eq!(glv.ddr_points(1_000), 2_000);
        assert_eq!(signed.ddr_points(1_000), 1_000);
        // in the reduce-exposed (running-sum) regime the halved chain wins
        // end to end
        let sr = SabConfig { reduction: ReductionKind::RunningSum, ..signed };
        let gr = SabConfig { reduction: ReductionKind::RunningSum, ..glv };
        let t_s = SabModel::new(sr).time_msm(100_000).total_s();
        let t_g = SabModel::new(gr).time_msm(100_000).total_s();
        assert!(t_g < t_s, "glv {t_g} vs signed {t_s}");
    }

    #[test]
    fn glv_leaves_stream_and_fill_work_unchanged() {
        // BN254: 2m ops over exactly half the windows — steady-state
        // stream/fill totals are unchanged (to within per-window fixed
        // costs), while the combine halves with the window count.
        let signed = SabModel::new(SabConfig::paper_signed(CurveId::Bn254, 2));
        let glv = SabModel::new(SabConfig::paper_glv(CurveId::Bn254, 2));
        let m = 4_000_000;
        let ts = signed.time_msm(m);
        let tg = glv.time_msm(m);
        let stream_ratio = tg.stream_s / ts.stream_s;
        assert!((stream_ratio - 1.0).abs() < 0.05, "stream ratio {stream_ratio}");
        assert!(tg.fill_s <= ts.fill_s * 1.02, "{} vs {}", tg.fill_s, ts.fill_s);
        assert!(tg.combine_s < ts.combine_s * 0.7, "{} vs {}", tg.combine_s, ts.combine_s);
        assert_eq!(ts.transfer_s, tg.transfer_s); // scalars transfer whole
        assert!(tg.total_s() <= ts.total_s());
        // BLS12-381: 381-bit accounting → 32 windows drop to 17 (the
        // half-width top slice picks up a carry window at k = 12)
        assert_eq!(SabConfig::paper_glv(CurveId::Bls12381, 2).plan().windows, 17);
    }

    #[test]
    fn tables_collapse_combine_and_multiply_ddr() {
        let glv = SabConfig::paper_glv(CurveId::Bn254, 2);
        let tab = SabConfig::paper_tables(CurveId::Bn254, 2);
        // same plan: tables change where points come from, not the digit
        // encoding — 11 GLV windows on BN254
        assert_eq!(tab.plan().windows, glv.plan().windows);
        assert_eq!(tab.plan().windows, 11);
        // DDR residency: 2× (endo pair) × 11 (one shifted copy per window);
        // the per-pass streamed volume stays at the endo-expanded 2m
        assert_eq!(glv.ddr_points(1_000), 2_000);
        assert_eq!(tab.ddr_points(1_000), 22_000);
        assert_eq!(tab.streamed_points(1_000), 2_000);
        assert_eq!(tab.streamed_points(1_000), glv.streamed_points(1_000));
        let mg = SabModel::new(glv);
        let mt = SabModel::new(tab);
        let m = 4_000_000;
        let tg = mg.time_msm(m);
        let tt = mt.time_msm(m);
        // transfer/fill/stream/reduce untouched; only the combine collapses
        // from the Horner chain to windows − 1 serial adds
        assert_eq!(tg.transfer_s, tt.transfer_s);
        assert_eq!(tg.fill_s, tt.fill_s);
        assert_eq!(tg.stream_s, tt.stream_s);
        assert_eq!(tg.reduce_s, tt.reduce_s);
        assert!(tt.combine_s < tg.combine_s, "{} vs {}", tt.combine_s, tg.combine_s);
        assert!(tt.total_s() <= tg.total_s());
    }

    #[test]
    fn is_rbam_beats_running_sum_system_level() {
        // the §IV-A claim behind IS-RBAM
        let mut cfg = SabConfig::paper(CurveId::Bn254, 1);
        let rec = SabModel::new(cfg).time_msm(100_000).total_s();
        cfg.reduction = ReductionKind::RunningSum;
        let rs = SabModel::new(cfg).time_msm(100_000).total_s();
        assert!(rec < rs, "IS-RBAM {rec} vs running-sum {rs}");
    }

    #[test]
    fn large_msm_is_stream_bound() {
        let t = bls_s2().time_msm(64_000_000);
        assert!(t.stream_bound);
        // compute has headroom — the UDA is not the bottleneck (§V text:
        // scaling limited by resources, not the point processor)
        assert!(t.fill_s < t.stream_s);
    }

    #[test]
    fn sharded_speedup_scales_with_device_count() {
        // the multi-kernel what-if: more devices, more speedup, for both
        // policies, at a stream-bound large size
        let model = bls_s2();
        let m = 16_000_000;
        let base = model.time_msm(m).total_s();
        for policy in [ShardPolicy::ChunkPoints, ShardPolicy::WindowRange] {
            let mut prev = base;
            for d in [2u32, 4, 8] {
                let t = model.time_msm_sharded(m, d, policy).total_s();
                assert!(t < prev, "{policy:?} d={d}: {t} !< {prev}");
                prev = t;
            }
        }
        // chunk sharding also scales the scalar transfer; at large m it
        // must beat window sharding
        let tc = model.time_msm_sharded(m, 4, ShardPolicy::ChunkPoints).total_s();
        let tw = model.time_msm_sharded(m, 4, ShardPolicy::WindowRange).total_s();
        assert!(tc <= tw, "chunk {tc} vs window {tw}");
        // and 4 devices buy a >2x end-to-end speedup at this size
        assert!(base / tc > 2.0, "4-device chunk speedup {}", base / tc);
    }

    #[test]
    fn time_shard_uses_job_plan_windows() {
        let model = bls_s2();
        let m = 100_000;
        let t = model.time_msm(m);
        let full = t.total_s();
        let fixed = t.transfer_s + t.overhead_s;
        let phases = t.fill_s.max(t.stream_s) + t.reduce_s + t.combine_s;
        // the job's plan has 32 windows: a 16-window shard pays the whole
        // broadcast + overhead but half the window-dependent phases — the
        // same decomposition time_msm_sharded uses
        let half = model.time_shard(m, &ShardSpec::WindowRange { lo: 0, hi: 16 }, 32);
        assert!((half - (fixed + 0.5 * phases)).abs() < full * 1e-9, "{half} vs {full}");
        // the whole range equals the full MSM whatever plan produced it —
        // the fraction can never exceed 1 (the old bug divided by the
        // model's own window count instead)
        let whole = model.time_shard(m, &ShardSpec::WindowRange { lo: 0, hi: 22 }, 22);
        assert!((whole - full).abs() < full * 1e-9);
        let chunk = model.time_shard(m, &ShardSpec::PointChunk { lo: 0, hi: 50_000 }, 22);
        assert!(chunk > 0.0 && chunk < full);
    }

    #[test]
    fn sharded_single_device_is_identity() {
        let model = bls_s2();
        let a = model.time_msm(100_000).total_s();
        let b = model.time_msm_sharded(100_000, 1, ShardPolicy::ChunkPoints).total_s();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn uda_build_beats_papd_by_about_30_percent() {
        // §IV-B3: "a 30% improvement in performance was observed on the MSM"
        let uda = SabModel::new(SabConfig {
            reduction: ReductionKind::RunningSum,
            ..SabConfig::paper(CurveId::Bn254, 2)
        });
        let papd = SabModel::new(SabConfig::papd_montgomery(2));
        let m = 1 << 20;
        // compare the compute-side (fill+reduce), where the architectures
        // differ; PA+PD pays folded-PD replays on doubling-class ops
        let tu = uda.time_msm(m);
        let tp = papd.time_msm(m);
        assert!(
            tp.total_s() > tu.total_s(),
            "papd {} should be slower than uda {}",
            tp.total_s(),
            tu.total_s()
        );
    }
}
