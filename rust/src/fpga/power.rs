//! Power model (Table VIII, Figures 5/7/8).
//!
//! Fitted surrogate (EXPERIMENTS.md §Calibration):
//!
//! * standby = BSP + αA·ALM + αD·DSP + αM·M20K — least squares over the
//!   five Table VIII builds (max residual 0.8 W). The coefficients are a
//!   *fit*, not physics: αD/αM come out slightly negative because ALM
//!   count dominates and correlates with everything; the model is only
//!   used inside the envelope of builds it was fitted on.
//! * active = standby + dyn_base(form) + γS·S — the Montgomery datapath's
//!   dynamic base is ≈2.2× the standard form's (three integer multipliers
//!   toggling per modmul), which is the §IV-B4 power story.

use super::calib;
use super::resources::{DesignVariant, NumberForm, ResourceModel};

/// Power model output (watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerEstimate {
    /// Board power with the build loaded but idle.
    pub standby_w: f64,
    /// Board power during an MSM run.
    pub active_w: f64,
}

/// Compute the power estimate of a build.
pub fn estimate(variant: DesignVariant, scaling: u32) -> PowerEstimate {
    let r = ResourceModel.system(variant, scaling);
    let standby_w = (calib::POWER_BSP_W
        + calib::POWER_STANDBY_PER_MALM * r.alms / 1e6
        + calib::POWER_STANDBY_PER_KDSP * r.dsps / 1e3
        + calib::POWER_STANDBY_PER_KM20K * r.m20ks / 1e3)
        .max(calib::POWER_BSP_W);
    let dyn_base = match variant.form {
        NumberForm::Standard => calib::POWER_DYN_BASE_STD_W,
        NumberForm::Montgomery => calib::POWER_DYN_BASE_MONT_W,
    };
    let active_w = standby_w + dyn_base + calib::POWER_DYN_PER_S_W * scaling as f64;
    PowerEstimate { standby_w, active_w }
}

/// Power-normalized throughput (the y-axis of Figs 5/7/8):
/// millions of MSM points per second per watt.
pub fn throughput_per_watt(m_msm_pps: f64, active_w: f64) -> f64 {
    m_msm_pps / active_w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variant(bits: u32, form: NumberForm, unified: bool) -> DesignVariant {
        DesignVariant { bits, form, unified }
    }

    #[test]
    fn table_viii_standby_within_one_watt() {
        let cases = [
            (variant(254, NumberForm::Standard, true), 1, 42.6),
            (variant(254, NumberForm::Standard, true), 2, 44.7),
            (variant(381, NumberForm::Standard, true), 1, 48.8),
            (variant(381, NumberForm::Standard, true), 2, 50.4),
        ];
        for (v, s, want) in cases {
            let got = estimate(v, s).standby_w;
            assert!((got - want).abs() < 1.2, "{} S={s}: {got} vs {want}", v.label());
        }
    }

    #[test]
    fn table_viii_active_within_two_watts() {
        let cases = [
            (variant(254, NumberForm::Standard, true), 1, 58.0),
            (variant(254, NumberForm::Standard, true), 2, 63.5),
            (variant(381, NumberForm::Standard, true), 1, 63.1),
            (variant(381, NumberForm::Standard, true), 2, 68.6),
        ];
        for (v, s, want) in cases {
            let got = estimate(v, s).active_w;
            assert!((got - want).abs() < 2.5, "{} S={s}: {got} vs {want}", v.label());
        }
    }

    #[test]
    fn montgomery_burns_more_dynamic_power() {
        let papd = estimate(variant(254, NumberForm::Montgomery, false), 1);
        let uda = estimate(variant(254, NumberForm::Standard, true), 1);
        let dyn_papd = papd.active_w - papd.standby_w;
        let dyn_uda = uda.active_w - uda.standby_w;
        assert!(dyn_papd > 1.8 * dyn_uda, "{dyn_papd} vs {dyn_uda}");
    }

    #[test]
    fn power_sublinear_in_scaling() {
        // §V-C3: "power consumption doesn't go up linearly with scaling"
        let s1 = estimate(variant(381, NumberForm::Standard, true), 1);
        let s2 = estimate(variant(381, NumberForm::Standard, true), 2);
        assert!(s2.active_w < 1.2 * s1.active_w, "{} vs {}", s2.active_w, s1.active_w);
    }

    #[test]
    fn scaling_improves_perf_per_watt_near_2x() {
        // Fig. 5/7: "higher scaling factor of 2 is almost giving a power
        // efficiency that is 2x better"
        use super::super::{CurveId, SabConfig, SabModel};
        let m = 32_000_000u64;
        let v = variant(381, NumberForm::Standard, true);
        let tp = |s: u32| {
            let t = SabModel::new(SabConfig::paper(CurveId::Bls12381, s)).time_msm(m);
            throughput_per_watt(t.m_msm_pps(m), estimate(v, s).active_w)
        };
        let ratio = tp(2) / tp(1);
        assert!((1.6..2.1).contains(&ratio), "perf/W ratio {ratio}");
    }
}
