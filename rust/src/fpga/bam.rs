//! Bucket-Array-Manager fill-phase model (§IV-A).
//!
//! The BAM streams (slice, point) pairs into the UDA at II=1. The hazard:
//! an update to a bucket whose previous update is still in the pipeline
//! (within `latency` cycles) must be replayed — the hardware holds it in a
//! conflict FIFO. With B buckets and uniformly distributed slices the
//! per-op conflict probability is ≈ L/B (L in-flight slots over B
//! buckets), giving an effective slowdown factor 1/(1−L/B) in steady
//! state; the model exposes both the analytic factor and a (seeded)
//! discrete simulation that validates it.

use super::uda::UdaPipe;
use crate::util::rng::Rng;

/// Fill-phase model for one window pass over m points.
#[derive(Clone, Copy, Debug)]
pub struct BamModel {
    /// Live bucket count per window, taken from the software plan
    /// (`msm::plan::MsmPlan::live_buckets`): 2^k − 1 unsigned, 2^(k−1)
    /// signed. Fewer buckets ⇒ more pipeline conflicts, which this model
    /// prices — the flip side of signed slicing's halved reduce chain.
    pub buckets: u64,
    /// The UDA pipe this BAM feeds.
    pub pipe: UdaPipe,
}

impl BamModel {
    /// Analytic expected cycles to fill one window with `m` ops.
    ///
    /// Uniform slices: an op conflicts iff one of the previous L ops hit
    /// its bucket: p ≈ 1 − (1 − 1/B)^L ≈ L/B for L ≪ B. Each conflict
    /// replays the op after the blocking result retires, consuming one
    /// extra issue slot, so throughput ≈ (1 − p_eff)⁻¹ issue slots per op.
    /// Small m (< L) can't fill the pipe: floor at m + latency drain.
    pub fn fill_cycles(&self, m: u64) -> u64 {
        let l = self.pipe.latency as f64;
        let b = self.buckets as f64;
        let p = 1.0 - (1.0 - 1.0 / b).powf(l.min(m as f64));
        let slowdown = 1.0 / (1.0 - p.min(0.95));
        let issue = (m as f64 * self.pipe.ii as f64 * slowdown).ceil() as u64;
        issue + self.pipe.latency // drain
    }

    /// Seeded discrete simulation of the conflict FIFO (validation +
    /// ablation: what if the hardware *stalled* instead of replaying?).
    pub fn simulate_fill(&self, m: u64, seed: u64, stall_on_conflict: bool) -> u64 {
        let mut rng = Rng::new(seed);
        // busy_until[bucket] = cycle when the in-flight update retires
        let mut busy_until = vec![0u64; self.buckets as usize];
        let mut cycle = 0u64;
        let mut replay: std::collections::VecDeque<u64> = Default::default();
        let mut drawn = 0u64;
        let mut issued = 0u64;
        while issued < m {
            // a ready replayed op has priority (the paper's join priority
            // rule that avoids deadlock), else draw a fresh op, else bubble
            let bucket = if let Some(pos) =
                replay.iter().position(|&b| busy_until[b as usize] <= cycle)
            {
                replay.remove(pos).unwrap()
            } else if drawn < m {
                drawn += 1;
                rng.below(self.buckets)
            } else {
                cycle += 1; // everything pending is blocked
                continue;
            };
            if busy_until[bucket as usize] > cycle {
                if stall_on_conflict {
                    cycle = busy_until[bucket as usize];
                } else {
                    replay.push_back(bucket); // conflict FIFO, slot wasted
                    cycle += 1;
                    continue;
                }
            }
            busy_until[bucket as usize] = cycle + self.pipe.latency;
            issued += 1;
            cycle += self.pipe.ii;
        }
        cycle + self.pipe.latency
    }
}

#[cfg(test)]
mod tests {
    use super::super::resources::NumberForm;
    use super::*;

    fn model() -> BamModel {
        BamModel { buckets: 4096, pipe: UdaPipe::unified(NumberForm::Standard) }
    }

    #[test]
    fn large_fill_near_ii_one() {
        let m = 1_000_000;
        let c = model().fill_cycles(m);
        // conflicts with L=270, B=4096: ~6.8% slowdown
        assert!(c > m && c < m + m / 10, "cycles {c}");
    }

    #[test]
    fn small_fill_dominated_by_drain() {
        let c = model().fill_cycles(10);
        assert!(c >= 270 && c < 300, "cycles {c}");
    }

    #[test]
    fn simulation_close_to_analytic() {
        let m = 20_000;
        let bam = model();
        let sim = bam.simulate_fill(m, 7, false);
        let ana = bam.fill_cycles(m);
        let rel = (sim as f64 - ana as f64).abs() / ana as f64;
        assert!(rel < 0.08, "sim {sim} vs analytic {ana} ({rel:.3})");
    }

    #[test]
    fn replay_beats_stalling() {
        // ablation: the conflict FIFO should outperform naive stalls
        let bam = model();
        let m = 5_000;
        let replay = bam.simulate_fill(m, 9, false);
        let stall = bam.simulate_fill(m, 9, true);
        assert!(replay <= stall, "replay {replay} stall {stall}");
    }

    #[test]
    fn fewer_buckets_more_conflicts() {
        let small = BamModel { buckets: 256, pipe: UdaPipe::unified(NumberForm::Standard) };
        let big = model();
        assert!(small.fill_cycles(100_000) > big.fill_cycles(100_000));
    }
}
