//! DNA (Double-aNd-Add) combine-phase model (§IV-A): folds the per-window
//! MSM results into the final point via Horner — k doublings + 1 add per
//! window, inherently serial (each step consumes the previous result).

use super::uda::UdaPipe;

/// Combine-phase model.
#[derive(Clone, Copy, Debug)]
pub struct DnaModel {
    /// The UDA pipe the combine chain runs on.
    pub pipe: UdaPipe,
}

impl DnaModel {
    /// Cycles to combine `windows` window results at slice width k.
    pub fn combine_cycles(&self, k: u32, windows: u32) -> u64 {
        // (k doublings + 1 add) per window, all on one dependency chain
        self.pipe.serial_cycles(windows as u64 * (k as u64 + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::super::resources::NumberForm;
    use super::*;

    #[test]
    fn combine_is_small_vs_fill() {
        // BLS12-381: 32 windows × 13 ops × 270 cycles ≈ 112k cycles —
        // microseconds at 351 MHz; negligible next to 64M-point fills,
        // exactly why the paper keeps DNA simple.
        let d = DnaModel { pipe: UdaPipe::unified(NumberForm::Standard) };
        let c = d.combine_cycles(12, 32);
        assert_eq!(c, 32 * 13 * 270);
        let seconds = c as f64 / 351e6;
        assert!(seconds < 0.001);
    }
}
