//! Scalar-Point-Streamer model (§IV-A, Fig. 2 "layered memory channels").
//!
//! Base points live in FPGA DDR (moved once per proof lifetime); every MSM
//! call streams them back through the BAM once per scalar window. Each BAM
//! instance is fed by its own DDR channel group, so stream bandwidth scales
//! with S — this is what makes Fig. 6's throughput scale linearly with S
//! even in the stream-bound regime.

use super::calib;
use super::CurveId;

/// Streaming model.
#[derive(Clone, Copy, Debug)]
pub struct SpsModel {
    /// Effective bytes/s per channel group (one BAM's feed).
    pub bw_per_group: f64,
    /// Number of groups in use (= scaling factor S, capped by the card).
    pub groups: u32,
}

impl SpsModel {
    /// Streamer model for scaling factor S (capped at the card's banks).
    pub fn new(s: u32) -> SpsModel {
        SpsModel {
            bw_per_group: calib::DDR_BW_PER_GROUP,
            groups: s.min(super::device::IA840F.ddr_groups),
        }
    }

    /// Seconds to stream the point set once (one window pass), split
    /// across groups.
    pub fn pass_seconds(&self, curve: CurveId, m: u64) -> f64 {
        let bytes = m as f64 * curve.affine_bytes() as f64;
        bytes / (self.bw_per_group * self.groups as f64)
    }

    /// Seconds of DDR streaming for a full MSM: one pass per window. The
    /// window count comes from the caller's `msm::plan::MsmPlan` (signed
    /// slicing adds a carry window; unsigned reproduces Table III's 22/32).
    pub fn msm_stream_seconds(&self, curve: CurveId, m: u64, windows: u32) -> f64 {
        self.pass_seconds(curve, m) * windows as f64
    }

    /// One-time point upload over PCIe (per point-set, not per call).
    pub fn upload_seconds(&self, curve: CurveId, m: u64) -> f64 {
        m as f64 * curve.affine_bytes() as f64 / calib::PCIE_BW
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_time_scales_with_windows_and_size() {
        let s = SpsModel::new(1);
        let w = CurveId::Bn254.hw_windows();
        let t1 = s.msm_stream_seconds(CurveId::Bn254, 1 << 20, w);
        let t2 = s.msm_stream_seconds(CurveId::Bn254, 1 << 21, w);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // BLS streams more bytes over more windows
        assert!(
            s.msm_stream_seconds(CurveId::Bls12381, 1 << 20, CurveId::Bls12381.hw_windows())
                > s.msm_stream_seconds(CurveId::Bn254, 1 << 20, w)
        );
        // one extra (signed carry) window costs exactly one extra pass
        let t3 = s.msm_stream_seconds(CurveId::Bn254, 1 << 20, w + 1);
        assert!((t3 / t1 - (w + 1) as f64 / w as f64).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_scales_with_s() {
        let w = CurveId::Bls12381.hw_windows();
        let t1 = SpsModel::new(1).msm_stream_seconds(CurveId::Bls12381, 64_000_000, w);
        let t2 = SpsModel::new(2).msm_stream_seconds(CurveId::Bls12381, 64_000_000, w);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_ix_anchor_64m_bls_s2() {
        // the calibration anchor: ≈ 15.0 s stream-bound
        let t = SpsModel::new(2).msm_stream_seconds(
            CurveId::Bls12381,
            64_000_000,
            CurveId::Bls12381.hw_windows(),
        );
        assert!((t - 15.03).abs() < 0.5, "stream {t}");
    }

    #[test]
    fn groups_capped_by_card() {
        assert_eq!(SpsModel::new(64).groups, super::super::device::IA840F.ddr_groups);
    }
}
