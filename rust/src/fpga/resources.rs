//! Resource model: ALM / DSP / M20K for every design variant the paper
//! builds (Tables IV, V, VII).
//!
//! Structure (§IV-B): a point processor is `modmuls × modmul(bits, form)`
//! plus wiring; the system adds the shell/SPS/IS-RBAM/DNA overhead and S
//! BAM instances. The per-modmul and overhead coefficients are calibrated
//! in [`super::calib`]; this module is the composition.

use super::calib;

/// Number representation of the datapath (§IV-B4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NumberForm {
    /// Montgomery multipliers: 3 integer multipliers per modmul.
    Montgomery,
    /// "Standard" (non-Montgomery) with LUT-based reduction: 1 integer
    /// multiplier per modmul + M20K tables.
    Standard,
}

/// A point-processor design point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DesignVariant {
    /// Field width (254 = BN128, 381 = BLS12-381).
    pub bits: u32,
    /// Datapath number representation.
    pub form: NumberForm,
    /// Unified double-add pipeline (true) vs separate PA + folded PD.
    pub unified: bool,
}

impl DesignVariant {
    /// Display label in the paper's table style (e.g. `UDA-254-Standard`).
    pub fn label(&self) -> String {
        let arch = if self.unified { "UDA" } else { "PA+PD" };
        let form = match self.form {
            NumberForm::Montgomery => "Montgomery",
            NumberForm::Standard => "Standard",
        };
        format!("{arch}-{}-{form}", self.bits)
    }
}

/// A resource vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    /// Adaptive logic modules.
    pub alms: f64,
    /// DSP blocks.
    pub dsps: f64,
    /// M20K memory blocks.
    pub m20ks: f64,
}

impl Resources {
    fn add(&self, o: &Resources) -> Resources {
        Resources {
            alms: self.alms + o.alms,
            dsps: self.dsps + o.dsps,
            m20ks: self.m20ks + o.m20ks,
        }
    }

    fn scale(&self, k: f64) -> Resources {
        Resources { alms: self.alms * k, dsps: self.dsps * k, m20ks: self.m20ks * k }
    }
}

/// The resource model (stateless; a struct so alternative calibrations can
/// be injected in ablation benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceModel;

impl ResourceModel {
    /// One modular multiplier instance.
    pub fn modmul(&self, bits: u32, form: NumberForm) -> Resources {
        let mont = form == NumberForm::Montgomery;
        let int_muls = if mont { 3.0 } else { 1.0 };
        Resources {
            alms: calib::alm_per_modmul(bits, mont),
            dsps: int_muls * calib::dsp_per_intmul(bits, mont),
            m20ks: calib::m20k_per_modmul(bits, mont),
        }
    }

    /// A complete point processor (Table V rows).
    pub fn point_processor(&self, v: DesignVariant) -> Resources {
        let mm = self.modmul(v.bits, v.form);
        if v.unified {
            mm.scale(calib::UDA_MODMULS as f64)
        } else {
            // Separate fully-pipelined PA + folded PD — Table IV blocks
            // verbatim (built only at 254-bit Montgomery; other widths
            // scale by the modmul area ratio).
            let scale_vs_254mont = mm.alms / self.modmul(254, NumberForm::Montgomery).alms;
            Resources {
                alms: (calib::PA_BLOCK_ALM + calib::PD_BLOCK_ALM) * scale_vs_254mont,
                dsps: (calib::PA_BLOCK_DSP + calib::PD_BLOCK_DSP) * scale_vs_254mont,
                m20ks: (calib::PA_BLOCK_M20K + calib::PD_BLOCK_M20K) * scale_vs_254mont,
            }
        }
    }

    /// Full system build (Table VII rows): processor + shell + S × BAM.
    pub fn system(&self, v: DesignVariant, s: u32) -> Resources {
        let proc = self.point_processor(v);
        let shell = Resources {
            alms: calib::SHELL_ALM,
            dsps: 0.0,
            m20ks: calib::SHELL_M20K,
        };
        let bam = Resources {
            alms: calib::bam_alm(v.bits),
            dsps: 0.0,
            m20ks: calib::bam_m20k(v.bits),
        };
        proc.add(&shell).add(&bam.scale(s as f64))
    }

    /// System fmax (Hz) under the congestion model, clamped to the paper's
    /// observed 334–367 MHz range.
    pub fn system_fmax(&self, v: DesignVariant, s: u32) -> f64 {
        let r = self.system(v, s);
        let util = r.alms / super::device::IA840F.alms as f64;
        (calib::SYS_FMAX_A_HZ - calib::SYS_FMAX_B_HZ * util)
            .min(calib::SYS_FMAX_CEIL_HZ)
            .max(calib::SYS_FMAX_FLOOR_HZ)
    }
}

/// The four Table V variants in paper order.
pub const TABLE_V_VARIANTS: [DesignVariant; 4] = [
    DesignVariant { bits: 254, form: NumberForm::Montgomery, unified: false },
    DesignVariant { bits: 254, form: NumberForm::Montgomery, unified: true },
    DesignVariant { bits: 254, form: NumberForm::Standard, unified: true },
    DesignVariant { bits: 381, form: NumberForm::Standard, unified: true },
];

#[cfg(test)]
mod tests {
    use super::*;

    fn close(got: f64, want: f64, tol: f64) -> bool {
        (got - want).abs() / want <= tol
    }

    #[test]
    fn table_v_alm_within_tolerance() {
        let m = ResourceModel;
        let want = [372_700.0, 290_400.0, 207_000.0, 419_000.0];
        for (v, w) in TABLE_V_VARIANTS.iter().zip(want) {
            let r = m.point_processor(*v);
            assert!(close(r.alms, w, 0.06), "{}: alm {} vs {w}", v.label(), r.alms);
        }
    }

    #[test]
    fn table_v_dsp_matches() {
        let m = ResourceModel;
        let want = [5005.0, 5400.0, 1975.0, 4425.0];
        for (v, w) in TABLE_V_VARIANTS.iter().zip(want) {
            let r = m.point_processor(*v);
            assert!(close(r.dsps, w, 0.05), "{}: dsp {} vs {w}", v.label(), r.dsps);
        }
    }

    #[test]
    fn table_vii_system_alm_within_tolerance() {
        let m = ResourceModel;
        let cases = [
            (DesignVariant { bits: 254, form: NumberForm::Standard, unified: true }, 2, 571_408.0),
            (DesignVariant { bits: 254, form: NumberForm::Standard, unified: true }, 1, 537_348.0),
            (DesignVariant { bits: 381, form: NumberForm::Standard, unified: true }, 2, 831_972.0),
            (DesignVariant { bits: 381, form: NumberForm::Standard, unified: true }, 1, 770_561.0),
        ];
        for (v, s, want) in cases {
            let r = m.system(v, s);
            assert!(close(r.alms, want, 0.03), "{} S={s}: {} vs {want}", v.label(), r.alms);
        }
    }

    #[test]
    fn uda_standard_saves_dsps_63_percent() {
        // §IV-B4: "63% reduction of DSP resources" Montgomery → standard.
        let m = ResourceModel;
        let mont = m.point_processor(DesignVariant {
            bits: 254,
            form: NumberForm::Montgomery,
            unified: true,
        });
        let std = m.point_processor(DesignVariant {
            bits: 254,
            form: NumberForm::Standard,
            unified: true,
        });
        let saving = 1.0 - std.dsps / mont.dsps;
        assert!((saving - 0.63).abs() < 0.03, "saving {saving}");
    }

    #[test]
    fn uda_saves_alms_vs_papd() {
        // §IV-B3: "ALM utilization was also improved by roughly 22%".
        let m = ResourceModel;
        let papd = m.point_processor(TABLE_V_VARIANTS[0]);
        let uda = m.point_processor(TABLE_V_VARIANTS[1]);
        let saving = 1.0 - uda.alms / papd.alms;
        assert!((saving - 0.22).abs() < 0.04, "saving {saving}");
    }

    #[test]
    fn fmax_in_paper_range() {
        let m = ResourceModel;
        for v in TABLE_V_VARIANTS {
            for s in [1, 2] {
                let f = m.system_fmax(v, s);
                assert!((334e6..=367e6).contains(&f), "{} S={s}: {f}", v.label());
            }
        }
        // BLS S=2 specifically ≈ 351 MHz (§V-C1)
        let f = m.system_fmax(
            DesignVariant { bits: 381, form: NumberForm::Standard, unified: true },
            2,
        );
        assert!((f - 351e6).abs() < 8e6, "{f}");
    }
}
