//! Cycle-level model of the paper's SAB accelerator on Intel Agilex.
//!
//! The physical artifact (bitstream on a BittWare IA-840f) cannot be
//! rebuilt here, so per the substitution rule (DESIGN.md §0) this module
//! models the architecture the paper describes, calibrated against every
//! number the paper publishes:
//!
//! * [`device`] — Agilex AGFB027R25A2E2V capacities, DDR banks, PCIe;
//! * [`uda`] — the Unified-Double-Add pipeline unit (§IV-B3): II=1,
//!   latency 270 (standard form) / 425 (Montgomery) cycles, fmax model;
//! * [`bam`] — Bucket-Array-Manager fill phase: pipelined mixed adds with
//!   the bucket-conflict hazard (in-flight bucket ⇒ replay);
//! * [`sps`] — Scalar-Point-Streamer: DDR channel bandwidth, one point
//!   stream pass per scalar window;
//! * [`rbam`] — IS-RBAM recursive reduction vs serial running sum;
//! * [`dna`] — the final Double-aNd-Add combine;
//! * [`sab`] — composition into an end-to-end [`sab::MsmTiming`];
//! * [`nttmodel`] — a clearly-labeled what-if model of the NTT kernel
//!   the paper defers to future work, in the same calibration
//!   vocabulary;
//! * [`resources`] — ALM/DSP/M20K model (Tables IV, V, VII);
//! * [`power`] — standby/active power model (Table VIII, Figs 5/7);
//! * [`calib`] — every calibration constant, with provenance notes.

pub mod calib;
pub mod device;
pub mod uda;
pub mod bam;
pub mod sps;
pub mod rbam;
pub mod dna;
pub mod sab;
pub mod nttmodel;
pub mod resources;
pub mod power;

pub use nttmodel::{NttKernelConfig, NttModel, NttTiming};
pub use resources::{DesignVariant, NumberForm, ResourceModel, Resources};
pub use sab::{MsmTiming, SabConfig, SabModel};

/// The two curves as the model keys them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CurveId {
    /// BN254 (the paper's "BN128"), 254-bit base field.
    Bn254,
    /// BLS12-381, 381-bit base field.
    Bls12381,
}

impl CurveId {
    /// Display name in the paper's spelling ("BN128" / "BLS12-381").
    pub fn name(&self) -> &'static str {
        match self {
            CurveId::Bn254 => "BN128",
            CurveId::Bls12381 => "BLS12-381",
        }
    }

    /// Base-field bit width (the paper's MSM accounting width).
    pub fn field_bits(&self) -> u32 {
        match self {
            CurveId::Bn254 => 254,
            CurveId::Bls12381 => 381,
        }
    }

    /// Affine point bytes in DDR (2 coordinates, word-padded).
    pub fn affine_bytes(&self) -> u64 {
        match self {
            CurveId::Bn254 => 64,
            CurveId::Bls12381 => 96,
        }
    }

    /// Scalar bytes as transferred from the host per MSM call.
    pub fn scalar_bytes(&self) -> u64 {
        match self {
            CurveId::Bn254 => 32,
            CurveId::Bls12381 => 48,
        }
    }

    /// Windows at the hardware slice width k=12 (Table III: 22 / 32).
    pub fn hw_windows(&self) -> u32 {
        self.field_bits().div_ceil(calib::HW_WINDOW_BITS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_counts_match_table_iii() {
        assert_eq!(CurveId::Bn254.hw_windows(), 22);
        assert_eq!(CurveId::Bls12381.hw_windows(), 32);
    }

    #[test]
    fn point_sizes() {
        assert_eq!(CurveId::Bn254.affine_bytes(), 64);
        assert_eq!(CurveId::Bls12381.affine_bytes(), 96);
    }
}
