//! UDA pipeline unit model (§IV-B3, Fig. 3).
//!
//! Fully pipelined: initiation interval 1 (one point operation per system
//! clock), latency L cycles (270 standard-form, 425 Montgomery). The
//! PA/PD distinction costs nothing — the join-mux absorbs it — which is
//! exactly why the paper moved off the separate folded-PD design whose
//! 1/650 throughput occasionally throttled the whole system (§IV-B2/B3).

use super::resources::NumberForm;

/// Pipeline description of one point-processor configuration.
#[derive(Clone, Copy, Debug)]
pub struct UdaPipe {
    /// Initiation interval in cycles (1 for the pipelined designs).
    pub ii: u64,
    /// Result latency in cycles.
    pub latency: u64,
    /// Folded-PD throughput penalty: II for doubles (PA+PD design only).
    pub pd_ii: u64,
}

impl UdaPipe {
    /// The unified pipeline of a given number form.
    pub fn unified(form: NumberForm) -> UdaPipe {
        UdaPipe {
            ii: 1,
            latency: match form {
                NumberForm::Standard => super::calib::UDA_LATENCY_STD,
                NumberForm::Montgomery => super::calib::UDA_LATENCY_MONT,
            },
            pd_ii: 1,
        }
    }

    /// The initial separate PA + folded PD architecture (§IV-B2): adds are
    /// pipelined; doubles recirculate through a single multiplier for ~650
    /// cycles (Table IV: "approx 1/650").
    pub fn papd() -> UdaPipe {
        UdaPipe { ii: 1, latency: super::calib::UDA_LATENCY_MONT, pd_ii: 650 }
    }

    /// Cycles to issue a stream of `adds` independent additions and
    /// `doubles` doublings, fully overlapped (throughput view).
    pub fn stream_cycles(&self, adds: u64, doubles: u64) -> u64 {
        adds * self.ii + doubles * self.pd_ii
    }

    /// Cycles for a *serial dependency chain* of `n` operations (each must
    /// wait for the previous result): n × latency. This is what makes the
    /// classic bucket running-sum expensive in hardware and motivates
    /// IS-RBAM.
    pub fn serial_cycles(&self, n: u64) -> u64 {
        n * self.latency.max(self.ii)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unified_ii_one() {
        let p = UdaPipe::unified(NumberForm::Standard);
        assert_eq!(p.stream_cycles(1000, 500), 1500);
        assert_eq!(p.latency, 270);
    }

    #[test]
    fn montgomery_longer_latency() {
        let m = UdaPipe::unified(NumberForm::Montgomery);
        let s = UdaPipe::unified(NumberForm::Standard);
        assert!(m.latency > s.latency);
        assert_eq!(m.latency, 425);
    }

    #[test]
    fn papd_doubles_throttle() {
        // the §IV-B2 bottleneck: doubles at 1/650
        let p = UdaPipe::papd();
        assert_eq!(p.stream_cycles(0, 10), 6500);
        assert_eq!(p.stream_cycles(10, 0), 10);
    }

    #[test]
    fn serial_chain_costs_latency_per_op() {
        let p = UdaPipe::unified(NumberForm::Standard);
        assert_eq!(p.serial_cycles(100), 27_000);
        // serial is 270× worse than streamed at II=1
        assert_eq!(p.serial_cycles(100) / p.stream_cycles(100, 0), 270);
    }
}
