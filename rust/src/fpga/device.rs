//! Target device: Intel Agilex AGFB027R25A2E2V on the BittWare IA-840f
//! ([30] in the paper). Capacities from the public device tables; the
//! paper's §V-C1 "912,800 ALMs … 91% utilization" confirms the ALM figure.

/// Static FPGA device description.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// Marketing name of the card + FPGA.
    pub name: &'static str,
    /// Adaptive logic modules available.
    pub alms: u64,
    /// DSP blocks available.
    pub dsps: u64,
    /// M20K memory blocks available.
    pub m20ks: u64,
    /// DDR4 channel groups usable by BAM instances (IA-840f: 4 banks).
    pub ddr_groups: u32,
}

/// The paper's target card.
pub const IA840F: Device = Device {
    name: "BittWare IA-840f (Agilex AGFB027R25A2E2V)",
    alms: 912_800,
    dsps: 8_528,
    m20ks: 13_272,
    ddr_groups: 4,
};

impl Device {
    /// Does a resource vector fit — with the practical place-and-route
    /// ceiling on ALM utilization (§V-C1: 91% was already "very close to
    /// FPGA capacity ceiling")?
    pub fn fits(&self, r: &super::Resources) -> bool {
        r.alms <= self.alms as f64 * super::calib::ALM_UTIL_CEILING
            && (r.dsps as u64) <= self.dsps
            && (r.m20ks as u64) <= self.m20ks
    }

    /// ALM utilization fraction of a build.
    pub fn alm_utilization(&self, r: &super::Resources) -> f64 {
        r.alms as f64 / self.alms as f64
    }

    /// Largest scaling factor S of a variant that fits this device (the
    /// paper: "scaling is currently limited only by the availability of
    /// resources").
    pub fn max_scaling(&self, model: &super::ResourceModel, variant: super::DesignVariant) -> u32 {
        let mut s = 1;
        while s < self.ddr_groups {
            let r = model.system(variant, s + 1);
            if !self.fits(&r) {
                break;
            }
            s += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::super::{DesignVariant, NumberForm, ResourceModel};
    use super::*;

    #[test]
    fn paper_alm_count() {
        assert_eq!(IA840F.alms, 912_800);
    }

    #[test]
    fn bls_s2_utilization_matches_91_percent() {
        // §V-C1: "for BLS12-381 curve with scaling=2 the ALM utilization
        // peaks at 91%"
        let model = ResourceModel::default();
        let r = model.system(
            DesignVariant { bits: 381, form: NumberForm::Standard, unified: true },
            2,
        );
        let u = IA840F.alm_utilization(&r);
        assert!((u - 0.91).abs() < 0.02, "utilization {u}");
    }

    #[test]
    fn max_scaling_bls_is_two() {
        // The paper could only fit S=2 ("evaluation is possible for only
        // two scaling factors because of the resources available").
        let model = ResourceModel::default();
        let v = DesignVariant { bits: 381, form: NumberForm::Standard, unified: true };
        assert_eq!(IA840F.max_scaling(&model, v), 2);
    }
}
