//! IS-RBAM: the Independently-Scalable Recursive Bucket-Array-Manager
//! (§IV-A) — reduction-phase timing.
//!
//! The classic Algorithm-2 running sum is a chain of 2·live_buckets point
//! adds in which *every add depends on the previous one*: on a pipelined
//! UDA it pays full latency per add. IS-RBAM re-expresses Σ b·B[b] as a
//! second, tiny bucket MSM over k₂-bit sub-slices of the bucket index: the
//! fills are independent (II=1), and only (k/k₂) running sums of 2^k₂
//! buckets each remain serial. Its instance count (`rbam_units`) scales
//! independently of the BAM — the "Independently Scalable" in the name.
//!
//! The **bucket count is a parameter**, taken from the software's
//! `msm::plan::MsmPlan` rather than hard-coded `2^k`: signed-digit slicing
//! halves it (2^k − 1 → 2^(k−1)), which halves the running-sum chain and
//! the recursive variant's fill traffic — the model and the software stay
//! consistent by construction.

use super::uda::UdaPipe;

/// Reduction strategies the model can time (mirrors `msm::Reduction`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReductionKind {
    /// Algorithm 2's fully serial running sum.
    RunningSum,
    /// The paper's IS-RBAM recursive reduction.
    Recursive {
        /// Sub-window width k₂ of the second-level bucket MSM.
        k2: u32,
    },
}

/// Reduction-phase model for one window of buckets.
#[derive(Clone, Copy, Debug)]
pub struct RbamModel {
    /// The UDA pipe reductions run on.
    pub pipe: UdaPipe,
    /// Parallel IS-RBAM instances (reduces the serial sections of distinct
    /// windows concurrently).
    pub rbam_units: u32,
}

impl RbamModel {
    /// Cycles to reduce one window of `live_buckets` coefficient-carrying
    /// buckets whose indices are `k` bits wide.
    pub fn window_cycles(&self, k: u32, live_buckets: u64, kind: ReductionKind) -> u64 {
        match kind {
            ReductionKind::RunningSum => {
                // 2·live fully serial adds
                self.pipe.serial_cycles(2 * live_buckets)
            }
            ReductionKind::Recursive { k2 } => {
                let k2 = k2.clamp(1, k);
                let sub_windows = k.div_ceil(k2) as u64;
                // fills: each live bucket feeds `sub_windows` second-level
                // buckets, pipelined at II=1
                let fills = self.pipe.stream_cycles(live_buckets * sub_windows, 0);
                // serial tails: one short running sum per sub-window plus k
                // Horner doublings
                let serial = self
                    .pipe
                    .serial_cycles(sub_windows * 2 * ((1u64 << k2) - 1) + k as u64);
                fills + serial
            }
        }
    }

    /// Cycles to reduce all `windows` windows, with `rbam_units` working
    /// window-parallel.
    pub fn total_cycles(
        &self,
        k: u32,
        live_buckets: u64,
        windows: u32,
        kind: ReductionKind,
    ) -> u64 {
        let per = self.window_cycles(k, live_buckets, kind);
        let rounds = windows.div_ceil(self.rbam_units.max(1)) as u64;
        per * rounds
    }
}

#[cfg(test)]
mod tests {
    use super::super::resources::NumberForm;
    use super::*;

    fn model(units: u32) -> RbamModel {
        RbamModel { pipe: UdaPipe::unified(NumberForm::Standard), rbam_units: units }
    }

    const UNSIGNED_K12: u64 = (1 << 12) - 1;
    const SIGNED_K12: u64 = 1 << 11;

    #[test]
    fn recursive_crushes_running_sum() {
        // k=12: running sum = 2·4095·270 ≈ 2.2M cycles/window;
        // IS-RBAM(k2=6) ≈ 8190 fills + short serial ≈ 0.05M
        let m = model(1);
        let rs = m.window_cycles(12, UNSIGNED_K12, ReductionKind::RunningSum);
        let rec = m.window_cycles(12, UNSIGNED_K12, ReductionKind::Recursive { k2: 6 });
        assert!(rs > 2_000_000);
        assert!(rec < rs / 10, "recursive {rec} vs running-sum {rs}");
    }

    #[test]
    fn signed_buckets_halve_the_running_sum() {
        let m = model(1);
        let rs_u = m.window_cycles(12, UNSIGNED_K12, ReductionKind::RunningSum);
        let rs_s = m.window_cycles(12, SIGNED_K12, ReductionKind::RunningSum);
        let ratio = rs_u as f64 / rs_s as f64;
        assert!((1.9..=2.0).contains(&ratio), "ratio {ratio}");
        // and the recursive variant's fill traffic halves too
        let rec_u = m.window_cycles(12, UNSIGNED_K12, ReductionKind::Recursive { k2: 6 });
        let rec_s = m.window_cycles(12, SIGNED_K12, ReductionKind::Recursive { k2: 6 });
        assert!(rec_s < rec_u);
    }

    #[test]
    fn k2_tradeoff_has_interior_optimum() {
        // tiny k2 → many sub-windows (fill-heavy); k2=k → degenerate
        // running sum. Some interior k2 must beat both ends.
        let m = model(1);
        let ends = m
            .window_cycles(12, UNSIGNED_K12, ReductionKind::Recursive { k2: 1 })
            .min(m.window_cycles(12, UNSIGNED_K12, ReductionKind::Recursive { k2: 12 }));
        let best = (2..12)
            .map(|k2| m.window_cycles(12, UNSIGNED_K12, ReductionKind::Recursive { k2 }))
            .min()
            .unwrap();
        assert!(best < ends);
    }

    #[test]
    fn units_scale_reduction() {
        let one = model(1).total_cycles(12, UNSIGNED_K12, 32, ReductionKind::Recursive { k2: 6 });
        let four = model(4).total_cycles(12, UNSIGNED_K12, 32, ReductionKind::Recursive { k2: 6 });
        assert_eq!(one / four, 4);
    }
}
