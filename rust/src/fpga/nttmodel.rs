//! What-if model of an FPGA NTT kernel — the acceleration the paper
//! explicitly defers to future work (§VI: MSM first, NTT later).
//!
//! There is no published hardware to calibrate against, so this model
//! reuses the **same vocabulary and constants** as the SAB MSM model
//! ([`super::sab`]) and labels itself a what-if: the UDA-style pipelined
//! modular multiplier (II = 1, standard-form latency), the system-fmax
//! congestion model, the measured PCIe and DDR bandwidths, and the fixed
//! per-call overhead all come from [`super::calib`]. The architecture is
//! the one SZKP and zkSpeed describe for their NTT engines:
//!
//! * `units` radix-2 **butterfly lanes**, each one pipelined modmul plus
//!   an add/sub pair — a stage's n/2 butterflies stream through the
//!   lanes at one butterfly per lane per cycle;
//! * **ping-pong stage memory** in M20K: stages are serially dependent,
//!   so each of the log₂ n stage boundaries exposes one pipeline drain;
//! * transforms that outgrow on-chip memory run the **four-step
//!   decomposition** (the same √n×√n factorization the software
//!   executor uses): three transpose passes stream the array through
//!   DDR at the SPS channel-group bandwidth;
//! * coefficients cross **PCIe twice** (in and out) — unlike MSM base
//!   points, NTT inputs change every call, which is why the modeled
//!   speedup is transfer-bound at small n. The report's `tables --id
//!   ntt` pairs this model with the SAB MSM model to show the combined
//!   prover-level (Amdahl) picture.

use super::calib;
use super::device::IA840F;
use super::resources::{DesignVariant, NumberForm, ResourceModel};
use super::uda::UdaPipe;
use super::CurveId;

/// One modeled NTT kernel build.
#[derive(Clone, Copy, Debug)]
pub struct NttKernelConfig {
    /// Target curve — fixes the scalar-field width the butterflies run
    /// at (the NTT operates in Fr, moved as [`CurveId::scalar_bytes`]).
    pub curve: CurveId,
    /// Parallel butterfly lanes (each one pipelined modular multiplier —
    /// the resource-cost unit of the UDA datapath).
    pub units: u32,
    /// DDR channel groups feeding the out-of-core four-step path (the
    /// SPS scaling knob, capped by the card's banks).
    pub scaling: u32,
}

impl NttKernelConfig {
    /// The default what-if build: 16 butterfly lanes (≈ the UDA's 18
    /// modmuls worth of multiplier area), the paper's S = 2 channel
    /// groups.
    pub fn whatif(curve: CurveId, units: u32) -> NttKernelConfig {
        NttKernelConfig { curve, units: units.max(1), scaling: 2 }
    }
}

/// Timing breakdown of one modeled n-point NTT call (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct NttTiming {
    /// Host→device coefficients in + results out (PCIe, both ways).
    pub transfer_s: f64,
    /// Butterfly compute across all log₂ n stages.
    pub butterfly_s: f64,
    /// Pipeline drains at the stage boundaries (serial dependency).
    pub drain_s: f64,
    /// DDR streaming of the four-step transpose passes (0 when the
    /// transform fits on chip).
    pub stream_s: f64,
    /// Fixed per-call overhead (driver/launch/readback).
    pub overhead_s: f64,
}

impl NttTiming {
    /// End-to-end seconds: transfer + max(compute, stream) + drains +
    /// overhead (streaming overlaps the butterfly pipeline the same way
    /// the SAB model overlaps fills and point streaming).
    pub fn total_s(&self) -> f64 {
        self.transfer_s + self.butterfly_s.max(self.stream_s) + self.drain_s + self.overhead_s
    }

    /// Throughput in millions of field elements per second.
    pub fn melems_per_s(&self, n: u64) -> f64 {
        n as f64 / self.total_s() / 1e6
    }
}

/// The composed what-if NTT model.
#[derive(Clone, Copy, Debug)]
pub struct NttModel {
    /// The kernel build being timed.
    pub cfg: NttKernelConfig,
    /// Modeled system clock (Hz) — same congestion model as the MSM
    /// builds of this curve.
    pub fmax_hz: f64,
    pipe: UdaPipe,
}

impl NttModel {
    /// Compose the model for one build.
    pub fn new(cfg: NttKernelConfig) -> NttModel {
        let variant = DesignVariant {
            bits: cfg.curve.field_bits(),
            form: NumberForm::Standard,
            unified: true,
        };
        // an NTT butterfly array is far smaller than the SAB point
        // processor, so the MSM build's congested fmax is conservative
        let fmax_hz = ResourceModel.system_fmax(variant, cfg.scaling);
        NttModel { cfg, fmax_hz, pipe: UdaPipe::unified(NumberForm::Standard) }
    }

    /// Largest transform resident in on-chip stage memory: half the
    /// card's M20K blocks (the other half stays with the shell/BSP),
    /// ping-pong double-buffered, one Fr element per slot.
    pub fn onchip_elems(&self) -> u64 {
        let bits_total = IA840F.m20ks / 2 * 20 * 1024;
        bits_total / (2 * self.cfg.curve.scalar_bytes() * 8)
    }

    /// Time one n-point NTT (n a power of two).
    pub fn time_ntt(&self, n: u64) -> NttTiming {
        assert!(n.is_power_of_two(), "NTT size must be a power of two");
        let stages = n.trailing_zeros() as u64;
        let lanes = u64::from(self.cfg.units.max(1));
        // one butterfly per lane per cycle, stages in sequence
        let butterfly_cycles = stages * (n / 2).div_ceil(lanes);
        let butterfly_s = butterfly_cycles as f64 / self.fmax_hz;
        // each stage boundary pays one pipeline drain
        let drain_s = self.pipe.serial_cycles(stages) as f64 / self.fmax_hz;
        // coefficients cross PCIe both ways — NTT inputs are per-call
        // data, not resident like MSM base points
        let bytes = n as f64 * self.cfg.curve.scalar_bytes() as f64;
        let transfer_s = 2.0 * bytes / calib::PCIE_BW;
        // out of core: the four-step path's three transpose passes each
        // read and write the whole array through the DDR channel groups
        let stream_s = if n > self.onchip_elems() {
            let groups = self.cfg.scaling.clamp(1, IA840F.ddr_groups) as f64;
            3.0 * 2.0 * bytes / (calib::DDR_BW_PER_GROUP * groups)
        } else {
            0.0
        };
        NttTiming {
            transfer_s,
            butterfly_s,
            drain_s,
            stream_s,
            overhead_s: calib::CALL_OVERHEAD_S,
        }
    }

    /// Sweep of sizes → (n, timing), for the report tables.
    pub fn sweep(&self, sizes: &[u64]) -> Vec<(u64, NttTiming)> {
        sizes.iter().map(|&n| (n, self.time_ntt(n))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bn_16() -> NttModel {
        NttModel::new(NttKernelConfig::whatif(CurveId::Bn254, 16))
    }

    #[test]
    fn compute_scales_as_n_log_n() {
        let m = bn_16();
        let a = m.time_ntt(1 << 16).butterfly_s;
        let b = m.time_ntt(1 << 18).butterfly_s;
        // 4x the points, 18/16 the stages
        let want = 4.0 * 18.0 / 16.0;
        assert!((b / a - want).abs() < 0.01, "{}", b / a);
    }

    #[test]
    fn more_lanes_cut_compute_not_transfer() {
        let narrow = NttModel::new(NttKernelConfig::whatif(CurveId::Bn254, 8));
        let wide = NttModel::new(NttKernelConfig::whatif(CurveId::Bn254, 32));
        let n = 1 << 18;
        let tn = narrow.time_ntt(n);
        let tw = wide.time_ntt(n);
        assert!((tn.butterfly_s / tw.butterfly_s - 4.0).abs() < 0.05);
        assert_eq!(tn.transfer_s, tw.transfer_s);
        assert!(tw.total_s() <= tn.total_s());
    }

    #[test]
    fn small_transforms_are_transfer_and_overhead_bound() {
        // the honest headline: per-call NTT offload pays PCIe both ways,
        // so small transforms see little benefit — the reason zkSpeed
        // keeps intermediate data resident
        let t = bn_16().time_ntt(1 << 12);
        assert!(t.transfer_s + t.overhead_s > t.butterfly_s + t.drain_s, "{t:?}");
    }

    #[test]
    fn out_of_core_sizes_stream_through_ddr() {
        let m = bn_16();
        let small = m.time_ntt(1 << 16);
        assert_eq!(small.stream_s, 0.0, "2^16 fits on chip: {small:?}");
        let cap = m.onchip_elems();
        assert!(cap > 1 << 16 && cap < 1 << 20, "capacity {cap}");
        let big = m.time_ntt(1 << 22);
        assert!(big.stream_s > 0.0, "{big:?}");
        // BLS elements are wider: less fits on chip
        let bls = NttModel::new(NttKernelConfig::whatif(CurveId::Bls12381, 16));
        assert!(bls.onchip_elems() < cap);
    }

    #[test]
    fn modeled_device_beats_a_serial_cpu_at_large_n() {
        // crate-measured serial NTTs run ~1-5 M elem/s on commodity
        // hosts; the modeled kernel should sit an order of magnitude
        // above that at 2^20 (DDR-streamed regime) while staying
        // physically plausible — transfer and streaming, not compute,
        // bound it
        let t = bn_16().time_ntt(1 << 20);
        let melems = t.melems_per_s(1 << 20);
        assert!(melems > 10.0, "modeled throughput too low: {melems}");
        assert!(melems < 2000.0, "modeled throughput implausible: {melems}");
        assert!(t.stream_s > t.butterfly_s, "large n should be stream-bound: {t:?}");
    }

    #[test]
    fn timing_fields_sum_into_total() {
        let t = bn_16().time_ntt(1 << 18);
        assert!(t.total_s() >= t.transfer_s + t.butterfly_s.max(t.stream_s));
        assert!(t.total_s() <= t.transfer_s + t.butterfly_s + t.stream_s + t.drain_s + t.overhead_s + 1e-12);
    }
}
