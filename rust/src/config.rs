//! Configuration system: a TOML-subset parser plus the typed system config.
//!
//! The offline dependency set has no serde/toml, so the needed subset is
//! implemented here: `[section]` headers, `key = value` with strings,
//! integers, floats, booleans and flat arrays, `#` comments. That covers
//! launcher configs like:
//!
//! ```toml
//! [serve]
//! curve = "bls12_381"        # or "bn254"
//! devices = ["sim_fpga", "cpu"]
//! scaling = 2
//! queue_capacity = 256
//! batch_max = 8
//! batch_wait_ms = 2.0
//!
//! [msm]
//! window_bits = 12
//! reduction = "recursive"
//! k2 = 6
//! ```

use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer (underscore separators allowed).
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The float (integers coerce), if numeric.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// section → key → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// All parsed sections (keys before the first `[section]` live in "").
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// String lookup with a default.
    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Integer lookup with a default.
    pub fn get_int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    /// Float lookup with a default (integers coerce).
    pub fn get_float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }

    /// Boolean lookup with a default.
    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

/// Parse a config document.
pub fn parse(src: &str) -> Result<Config, String> {
    let mut cfg = Config::default();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
            section = name.trim().to_string();
            cfg.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let v = parse_value(value.trim()).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        cfg.sections
            .entry(section.clone())
            .or_default()
            .insert(key.trim().to_string(), v);
    }
    Ok(cfg)
}

/// Load from a file path.
pub fn load(path: &std::path::Path) -> Result<Config, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
    parse(&src)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, String> =
            split_items(inner)?.into_iter().map(|it| parse_value(&it)).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_items(s: &str) -> Result<Vec<String>, String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
[serve]
curve = "bls12_381"
devices = ["sim_fpga", "cpu"]   # device list
scaling = 2
batch_wait_ms = 2.5
verbose = true

[msm]
window_bits = 12
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("serve", "curve", ""), "bls12_381");
        assert_eq!(c.get_int("serve", "scaling", 0), 2);
        assert!((c.get_float("serve", "batch_wait_ms", 0.0) - 2.5).abs() < 1e-12);
        assert!(c.get_bool("serve", "verbose", false));
        assert_eq!(c.get_int("msm", "window_bits", 0), 12);
        let devs = c.get("serve", "devices").unwrap().as_array().unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].as_str(), Some("sim_fpga"));
    }

    #[test]
    fn defaults_apply() {
        let c = parse("").unwrap();
        assert_eq!(c.get_int("nope", "x", 42), 42);
        assert_eq!(c.get_str("nope", "y", "d"), "d");
    }

    #[test]
    fn comments_inside_strings_kept() {
        let c = parse("[s]\nk = \"a#b\"").unwrap();
        assert_eq!(c.get_str("s", "k", ""), "a#b");
    }

    #[test]
    fn int_with_underscores() {
        let c = parse("[s]\nn = 64_000_000").unwrap();
        assert_eq!(c.get_int("s", "n", 0), 64_000_000);
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[s\n").is_err());
        assert!(parse("[s]\ngarbage").is_err());
        assert!(parse("[s]\nk = [1, \"x]").is_err());
    }

    #[test]
    fn float_and_int_coercion() {
        let c = parse("[s]\na = 2\nb = 2.5").unwrap();
        assert_eq!(c.get_float("s", "a", 0.0), 2.0);
        assert_eq!(c.get_float("s", "b", 0.0), 2.5);
        assert_eq!(c.get_int("s", "b", 7), 7); // floats don't silently truncate
    }
}
