//! Deterministic point-set and scalar-set generation for MSM workloads.
//!
//! The paper generates test vectors with libsnark (§V-A); in this repo every
//! workload derives from an explicit seed. Two generators are provided:
//!
//! * [`generate_points_walk`] — fast additive walk (1 point-add per point):
//!   `P_i = Q_i + T[i mod 2^t]` with `Q_{i+1} = Q_i + D`. Points are
//!   distinct subgroup elements; this is what the benches use to build
//!   multi-million-point MSM inputs in reasonable time.
//! * [`hash_to_curve`] — independent try-and-increment points via
//!   Tonelli–Shanks (no linear relation between outputs); used by the
//!   correctness tests where algebraic independence matters.

use super::point::{Affine, CurveParams, Jacobian};
use super::scalar;
use super::ScalarLimbs;
use crate::ff::{sqrt, Field};
use crate::util::rng::Rng;

/// Resumable state of the additive point walk behind
/// [`generate_points_walk`]: `P_i = Q_i + T[i mod 16]`, `Q_{i+1} = Q_i + D`.
///
/// The streaming SRS (`snark/stream.rs`) emits the walk chunk by chunk, so
/// the walk state is a first-class value: [`PointWalk::next_chunk`] produces
/// the next `n` points and [`PointWalk::skip`] advances past points a query
/// slice does not need (1 point-add per skipped point, no affine
/// normalization). Chunked emission is bit-identical to one-shot emission:
/// the walk itself visits the same `(Q_i, T)` sequence regardless of chunk
/// boundaries, and `batch_to_affine`'s Montgomery batch inversion computes
/// the exact per-element `z⁻¹`, so grouping does not change any output
/// coordinate.
pub struct PointWalk<C: CurveParams> {
    table: Vec<Jacobian<C>>,
    step: Jacobian<C>,
    q: Jacobian<C>,
    index: usize,
}

impl<C: CurveParams> PointWalk<C> {
    /// Start the walk for `seed` at index 0 (same derivation as
    /// [`generate_points_walk`]).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let g = Jacobian::<C>::generator();
        // Small table of random multiples breaks the pure arithmetic
        // progression.
        let t = 16usize;
        let table: Vec<Jacobian<C>> = (0..t)
            .map(|_| {
                let k = [rng.next_u64() | 1, rng.next_u64(), 0, 0];
                scalar::mul::<C>(&g, &k)
            })
            .collect();
        let step = {
            let k = [rng.next_u64() | 1, rng.next_u64(), rng.next_u64(), 0];
            scalar::mul::<C>(&g, &k)
        };
        let q = {
            let k = [rng.next_u64() | 1, 0, 0, 0];
            scalar::mul::<C>(&g, &k)
        };
        PointWalk { table, step, q, index: 0 }
    }

    /// Index of the next point the walk will emit.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Emit the next `n` points of the walk.
    pub fn next_chunk(&mut self, n: usize) -> Vec<Affine<C>> {
        let t = self.table.len();
        let mut jac = Vec::with_capacity(n);
        for _ in 0..n {
            jac.push(self.q.add(&self.table[self.index % t]));
            self.q = self.q.add(&self.step);
            self.index += 1;
        }
        Jacobian::batch_to_affine(&jac)
    }

    /// Advance past `n` points without materializing them.
    pub fn skip(&mut self, n: usize) {
        for _ in 0..n {
            self.q = self.q.add(&self.step);
            self.index += 1;
        }
    }
}

/// Fast deterministic point set: distinct points in the generator subgroup.
pub fn generate_points_walk<C: CurveParams>(n: usize, seed: u64) -> Vec<Affine<C>> {
    PointWalk::new(seed).next_chunk(n)
}

/// Independent points by try-and-increment: x ← random, bump until
/// x³ + b is a square, y ← sqrt (sign from one more random bit).
pub fn hash_to_curve<C: CurveParams>(n: usize, seed: u64) -> Vec<Affine<C>> {
    let mut rng = Rng::new(seed ^ 0x4861_7368_3243_7276); // "Hash2Crv"
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let mut x = C::Base::random(&mut rng);
        loop {
            let rhs = x.square().mul(&x).add(&C::b());
            if let Some(y) = sqrt::sqrt(&rhs) {
                let y = if rng.bool() { y } else { y.neg() };
                out.push(Affine::new(x, y));
                break;
            }
            x = x.add(&C::Base::one());
        }
    }
    out
}

/// Uniform random scalars below `2^bits` (canonical limbs). The paper's MSM
/// inputs are field scalars; `bits` defaults to the curve's scalar width.
pub fn generate_scalars(n: usize, bits: u32, seed: u64) -> Vec<ScalarLimbs> {
    assert!(bits >= 1 && bits <= 256);
    let mut rng = Rng::new(seed ^ 0x5343_414c_4152_5321); // "SCALARS!"
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = [
            rng.next_u64(),
            rng.next_u64(),
            rng.next_u64(),
            rng.next_u64(),
        ];
        // mask to `bits`
        for (i, limb) in s.iter_mut().enumerate() {
            let lo = 64 * i as u32;
            if lo >= bits {
                *limb = 0;
            } else if bits - lo < 64 {
                *limb &= (1u64 << (bits - lo)) - 1;
            }
        }
        out.push(s);
    }
    out
}

/// A complete deterministic MSM workload.
pub struct MsmWorkload<C: CurveParams> {
    /// The base points (walk-generated, distinct, on-curve).
    pub points: Vec<Affine<C>>,
    /// Uniform scalars at the curve's MSM width.
    pub scalars: Vec<ScalarLimbs>,
}

/// Standard workload: walk points + uniform scalars of the curve's MSM width
/// (the paper's Table IX setup for a given size).
pub fn workload<C: CurveParams>(n: usize, seed: u64) -> MsmWorkload<C> {
    MsmWorkload {
        points: generate_points_walk::<C>(n, seed),
        scalars: generate_scalars(n, C::SCALAR_BITS.min(256), seed.wrapping_add(1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bls12381G1, Bn254G1, Bn254G2};
    use std::collections::HashSet;

    #[test]
    fn walk_points_on_curve_and_distinct() {
        let pts = generate_points_walk::<Bn254G1>(64, 7);
        assert_eq!(pts.len(), 64);
        let mut seen = HashSet::new();
        for p in &pts {
            assert!(p.is_on_curve());
            assert!(!p.infinity);
            assert!(seen.insert(p.x.to_hex()), "duplicate point");
        }
    }

    #[test]
    fn walk_deterministic() {
        let a = generate_points_walk::<Bls12381G1>(8, 42);
        let b = generate_points_walk::<Bls12381G1>(8, 42);
        for (p, q) in a.iter().zip(&b) {
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
        let c = generate_points_walk::<Bls12381G1>(8, 43);
        assert_ne!(a[0].x, c[0].x);
    }

    #[test]
    fn walk_chunked_emission_is_bit_identical() {
        let whole = generate_points_walk::<Bn254G1>(21, 42);
        let mut walk = PointWalk::<Bn254G1>::new(42);
        let mut chunked = Vec::new();
        for n in [3usize, 5, 1, 12] {
            chunked.extend(walk.next_chunk(n));
        }
        assert_eq!(walk.index(), 21);
        assert_eq!(chunked.len(), whole.len());
        for (p, q) in chunked.iter().zip(&whole) {
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
    }

    #[test]
    fn walk_skip_matches_dense_emission() {
        let whole = generate_points_walk::<Bls12381G1>(20, 7);
        let mut walk = PointWalk::<Bls12381G1>::new(7);
        walk.skip(13);
        let tail = walk.next_chunk(7);
        for (p, q) in tail.iter().zip(&whole[13..]) {
            assert_eq!(p.x, q.x);
            assert_eq!(p.y, q.y);
        }
    }

    #[test]
    fn hash_to_curve_on_curve() {
        let pts = hash_to_curve::<Bn254G1>(8, 3);
        for p in &pts {
            assert!(p.is_on_curve());
        }
        // works over Fp2 as well
        let pts2 = hash_to_curve::<Bn254G2>(2, 3);
        for p in &pts2 {
            assert!(p.is_on_curve());
        }
    }

    #[test]
    fn scalars_respect_bit_width() {
        let ss = generate_scalars(100, 254, 9);
        for s in &ss {
            assert_eq!(s[3] >> (254 - 192), 0);
        }
        let narrow = generate_scalars(100, 16, 9);
        for s in &narrow {
            assert!(s[0] < (1 << 16));
            assert_eq!(s[1] | s[2] | s[3], 0);
        }
    }

    #[test]
    fn workload_sizes_match() {
        let w = workload::<Bn254G1>(33, 5);
        assert_eq!(w.points.len(), 33);
        assert_eq!(w.scalars.len(), 33);
    }
}
