//! Point-operation counters (thread-local, like [`crate::ff::opcount`]).
//!
//! Tables II/III of the paper account MSM cost in point operations × the
//! per-operation modmul budget (16 for PA, 9 for PD in their hardware).
//! These counters record what the algorithms *actually* execute so the
//! benches can report both measured point-ops and measured modmuls.

use std::cell::Cell;

thread_local! {
    static ADD: Cell<u64> = const { Cell::new(0) };
    static DOUBLE: Cell<u64> = const { Cell::new(0) };
    static MIXED: Cell<u64> = const { Cell::new(0) };
}

/// Count one full Jacobian addition.
#[inline(always)]
pub fn count_add() {
    ADD.with(|c| c.set(c.get() + 1));
}
/// Retract an add (the unified-add PD branch re-counts as a double).
#[inline(always)]
pub fn uncount_add() {
    ADD.with(|c| c.set(c.get() - 1));
}
/// Count one doubling.
#[inline(always)]
pub fn count_double() {
    DOUBLE.with(|c| c.set(c.get() + 1));
}
/// Count `n` doublings with a single thread-local access — the
/// `Jacobian::double_n` shift chains record their whole run at once, so
/// measured totals stay identical to n calls of [`count_double`].
#[inline(always)]
pub fn count_doubles(n: u64) {
    DOUBLE.with(|c| c.set(c.get() + n));
}
/// Count one mixed (Jacobian + affine) addition.
#[inline(always)]
pub fn count_mixed() {
    MIXED.with(|c| c.set(c.get() + 1));
}
/// Retract a mixed add (same PD-branch correction as [`uncount_add`]).
#[inline(always)]
pub fn uncount_mixed() {
    MIXED.with(|c| c.set(c.get() - 1));
}

/// Snapshot of point-op counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PointOps {
    /// Full Jacobian + Jacobian additions.
    pub add: u64,
    /// Doublings.
    pub double: u64,
    /// Mixed (Jacobian + affine) additions.
    pub mixed: u64,
}

impl PointOps {
    /// Total point operations (the unit of Table III).
    pub fn total(&self) -> u64 {
        self.add + self.double + self.mixed
    }

    /// Modmul budget under the paper's hardware accounting
    /// (16 per full/mixed add — the UDA always runs the full datapath —
    /// and 9 per double).
    pub fn hardware_modmuls(&self) -> u64 {
        16 * (self.add + self.mixed) + 9 * self.double
    }
}

impl std::ops::Sub for PointOps {
    type Output = PointOps;
    fn sub(self, rhs: PointOps) -> PointOps {
        PointOps {
            add: self.add - rhs.add,
            double: self.double - rhs.double,
            mixed: self.mixed - rhs.mixed,
        }
    }
}

/// Current counter values for this thread.
pub fn snapshot() -> PointOps {
    PointOps {
        add: ADD.with(Cell::get),
        double: DOUBLE.with(Cell::get),
        mixed: MIXED.with(Cell::get),
    }
}

/// Run `f`, returning its output and the point-ops it consumed.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, PointOps) {
    let before = snapshot();
    let out = f();
    (out, snapshot() - before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bn254G1, Jacobian};

    #[test]
    fn counts_adds_and_doubles() {
        let g = Jacobian::<Bn254G1>::generator();
        let g2 = g.double();
        let (_, ops) = measure(|| {
            let mut p = g; // odd multiples of g: always distinct from g2
            for _ in 0..5 {
                p = p.add(&g2);
            }
            p.double()
        });
        assert_eq!(ops.double, 1);
        assert_eq!(ops.add, 5);
    }

    #[test]
    fn unified_add_counts_as_double_when_equal() {
        let g = Jacobian::<Bn254G1>::generator();
        let (_, ops) = measure(|| g.add(&g));
        assert_eq!(ops, PointOps { add: 0, double: 1, mixed: 0 });
    }

    #[test]
    fn hardware_modmul_budget() {
        let ops = PointOps { add: 2, double: 3, mixed: 1 };
        assert_eq!(ops.hardware_modmuls(), 16 * 3 + 9 * 3);
        assert_eq!(ops.total(), 6);
    }
}
