//! G2 groups over Fp² — the second MSM of the Groth16 prover (Table I's
//! dominant MSM-𝔾₂ column). The paper leaves G2 MSM hardware as future
//! work but its *profiling motivation* (Table I) requires real G2 compute,
//! so the groups are implemented in full.
//!
//! Twists: BN254 G2 is `y² = x³ + 3/(9+u)`; BLS12-381 G2 is
//! `y² = x³ + 4(1+u)`; both over Fp² with u² = −1.

use super::point::CurveParams;
use crate::ff::params::curve_constants as cc;
use crate::ff::{Field, Fp2Bls12381, Fp2Bn254, FpBls12381, FpBn254};
use std::sync::LazyLock as Lazy;

/// BN254 G2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bn254G2;

static BN254_B2: Lazy<Fp2Bn254> = Lazy::new(|| {
    // b2 = 3 / (9 + u)
    let three = Fp2Bn254::from_base(FpBn254::from_u64(3));
    let nine_u = Fp2Bn254::new(FpBn254::from_u64(9), FpBn254::from_u64(1));
    three.mul(&nine_u.inv().expect("9+u invertible"))
});

impl CurveParams for Bn254G2 {
    type Base = Fp2Bn254;

    fn b() -> Fp2Bn254 {
        *BN254_B2
    }

    fn generator_xy() -> (Fp2Bn254, Fp2Bn254) {
        let x = Fp2Bn254::new(
            FpBn254::from_canonical(cc::BN254_G2_X_C0).unwrap(),
            FpBn254::from_canonical(cc::BN254_G2_X_C1).unwrap(),
        );
        let y = Fp2Bn254::new(
            FpBn254::from_canonical(cc::BN254_G2_Y_C0).unwrap(),
            FpBn254::from_canonical(cc::BN254_G2_Y_C1).unwrap(),
        );
        (x, y)
    }

    const SCALAR_BITS: u32 = 254;
    const MSM_SCALAR_BITS: u32 = 254;
    const NAME: &'static str = "bn254_g2";
    // 4 × 32-byte field elements.
    const AFFINE_BYTES: u64 = 128;

    fn glv() -> Option<&'static super::endo::GlvParams<Self>> {
        super::endo::bn254_g2()
    }
}

/// BLS12-381 G2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bls12381G2;

impl CurveParams for Bls12381G2 {
    type Base = Fp2Bls12381;

    fn b() -> Fp2Bls12381 {
        // b2 = 4·(1 + u)
        Fp2Bls12381::new(FpBls12381::from_u64(4), FpBls12381::from_u64(4))
    }

    fn generator_xy() -> (Fp2Bls12381, Fp2Bls12381) {
        let x = Fp2Bls12381::new(
            FpBls12381::from_canonical(cc::BLS12_381_G2_X_C0).unwrap(),
            FpBls12381::from_canonical(cc::BLS12_381_G2_X_C1).unwrap(),
        );
        let y = Fp2Bls12381::new(
            FpBls12381::from_canonical(cc::BLS12_381_G2_Y_C0).unwrap(),
            FpBls12381::from_canonical(cc::BLS12_381_G2_Y_C1).unwrap(),
        );
        (x, y)
    }

    const SCALAR_BITS: u32 = 255;
    const MSM_SCALAR_BITS: u32 = 381;
    const NAME: &'static str = "bls12_381_g2";
    // 4 × 48-byte field elements.
    const AFFINE_BYTES: u64 = 192;

    fn glv() -> Option<&'static super::endo::GlvParams<Self>> {
        super::endo::bls12_381_g2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::point::Jacobian;
    use crate::ec::scalar;

    #[test]
    fn g2_generators_on_twist() {
        assert!(Jacobian::<Bn254G2>::generator().is_on_curve());
        assert!(Jacobian::<Bls12381G2>::generator().is_on_curve());
    }

    #[test]
    fn g2_group_law() {
        let g = Jacobian::<Bls12381G2>::generator();
        let five_g = scalar::mul::<Bls12381G2>(&g, &[5, 0, 0, 0]);
        let check = g.double().double().add(&g);
        assert!(five_g.eq_point(&check));
        assert!(five_g.is_on_curve());
    }

    #[test]
    fn g2_add_commutes() {
        let g = Jacobian::<Bn254G2>::generator();
        let a = scalar::mul::<Bn254G2>(&g, &[1234, 0, 0, 0]);
        let b = scalar::mul::<Bn254G2>(&g, &[9876, 0, 0, 0]);
        assert!(a.add(&b).eq_point(&b.add(&a)));
    }
}
