//! Elliptic-curve groups in Weierstrass form, Jacobian coordinates.
//!
//! The paper deliberately targets the *general* Weierstrass form (§I, §III):
//! BN128 and BLS12-381 cannot be put in Twisted Edwards shape, so unlike the
//! ZPrize/CycloneMSM designs the point processor must implement the full
//! Jacobian add/double formulas (16 and 9 modmuls respectively, §IV).
//!
//! * [`point`] — generic affine/Jacobian points over any [`crate::ff::Field`]
//!   with the explicit-formulas-database `add-2007-bl` / `madd-2007-bl` /
//!   `dbl-2009-l` (a=0) formulas and **unified add semantics** (the UDA
//!   join-mux behaviour: add that transparently handles P=Q, ±infinity);
//! * [`g1`], [`g2`] — the four concrete groups;
//! * [`endo`] — the GLV cube-root endomorphism (ζ, λ, half-width lattice
//!   decomposition) behind the MSM plan's `Decomposition::Glv` fast path;
//! * [`scalar`] — Algorithm 1 (double-and-add) and windowed variants;
//! * [`points`] — deterministic workload generators (additive-walk fast
//!   path, hash-to-curve via Tonelli–Shanks for independence-critical
//!   tests);
//! * [`counters`] — point-operation counters (Tables II/III are reported in
//!   point-op and modmul units).

pub mod point;
pub mod g1;
pub mod g2;
pub mod endo;
pub mod scalar;
pub mod points;
pub mod counters;

pub use endo::{GlvParams, GlvSplit};
pub use g1::{Bls12381G1, Bn254G1};
pub use g2::{Bls12381G2, Bn254G2};
pub use point::{Affine, CurveParams, Jacobian};

/// Scalars are canonical little-endian limbs; both supported scalar fields
/// (254/255 bits) fit in four words.
pub type ScalarLimbs = [u64; 4];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_on_curve() {
        assert!(Affine::<Bn254G1>::from_generator().is_on_curve());
        assert!(Affine::<Bls12381G1>::from_generator().is_on_curve());
        assert!(Affine::<Bn254G2>::from_generator().is_on_curve());
        assert!(Affine::<Bls12381G2>::from_generator().is_on_curve());
    }

    #[test]
    fn generator_has_scalar_order() {
        // r·G = O for all four groups (validates generator + group law end
        // to end).
        fn check<C: CurveParams>(r: [u64; 4]) {
            let g = Jacobian::<C>::generator();
            let rg = scalar::mul::<C>(&g, &r);
            assert!(rg.is_infinity(), "{}: r*G != O", C::NAME);
        }
        use crate::ff::fp::FieldParams;
        check::<Bn254G1>(crate::ff::params::Bn254FrParams::MODULUS);
        check::<Bn254G2>(crate::ff::params::Bn254FrParams::MODULUS);
        check::<Bls12381G1>(crate::ff::params::Bls12381FrParams::MODULUS);
        check::<Bls12381G2>(crate::ff::params::Bls12381FrParams::MODULUS);
    }

    #[test]
    fn curve_names() {
        assert_eq!(Bn254G1::NAME, "bn254_g1");
        assert_eq!(Bls12381G1::NAME, "bls12_381_g1");
    }
}
