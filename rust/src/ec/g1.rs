//! The two G1 groups targeted by the paper (§II-C, §V): BN254's
//! `y² = x³ + 3` over a 254-bit field and BLS12-381's `y² = x³ + 4` over a
//! 381-bit field.

use super::point::CurveParams;
use crate::ff::params::curve_constants as cc;
use crate::ff::{Field, FpBls12381, FpBn254};

/// BN254 (alt_bn128 / "BN128") G1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bn254G1;

impl CurveParams for Bn254G1 {
    type Base = FpBn254;

    fn b() -> FpBn254 {
        FpBn254::from_u64(cc::BN254_B)
    }

    fn generator_xy() -> (FpBn254, FpBn254) {
        (
            FpBn254::from_canonical(cc::BN254_G1_X).expect("generator x < p"),
            FpBn254::from_canonical(cc::BN254_G1_Y).expect("generator y < p"),
        )
    }

    const SCALAR_BITS: u32 = 254;
    const MSM_SCALAR_BITS: u32 = 254;
    const NAME: &'static str = "bn254_g1";
    // 2 × 32-byte coordinates in the DDR layout.
    const AFFINE_BYTES: u64 = 64;

    fn glv() -> Option<&'static super::endo::GlvParams<Self>> {
        super::endo::bn254_g1()
    }
}

/// BLS12-381 G1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bls12381G1;

impl CurveParams for Bls12381G1 {
    type Base = FpBls12381;

    fn b() -> FpBls12381 {
        FpBls12381::from_u64(cc::BLS12_381_B)
    }

    fn generator_xy() -> (FpBls12381, FpBls12381) {
        (
            FpBls12381::from_canonical(cc::BLS12_381_G1_X).expect("generator x < p"),
            FpBls12381::from_canonical(cc::BLS12_381_G1_Y).expect("generator y < p"),
        )
    }

    const SCALAR_BITS: u32 = 255;
    // The paper accounts BLS12-381 MSM slicing over the 381-bit base-field
    // width (Table II: "2 × 381 × 16"); we keep their accounting for the
    // model comparisons while the real scalars are 255 bits.
    const MSM_SCALAR_BITS: u32 = 381;
    const NAME: &'static str = "bls12_381_g1";
    // 2 × 48-byte coordinates.
    const AFFINE_BYTES: u64 = 96;

    fn glv() -> Option<&'static super::endo::GlvParams<Self>> {
        super::endo::bls12_381_g1()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::point::{Affine, Jacobian};

    #[test]
    fn bn254_generator_is_one_two() {
        let (x, y) = Bn254G1::generator_xy();
        assert_eq!(x, FpBn254::from_u64(1));
        assert_eq!(y, FpBn254::from_u64(2));
    }

    #[test]
    fn small_multiples_on_curve() {
        let g = Jacobian::<Bls12381G1>::generator();
        let mut p = g;
        for _ in 0..10 {
            p = p.add(&g);
            assert!(p.is_on_curve());
        }
    }

    #[test]
    fn five_g_consistency() {
        // 5G computed two ways
        let g = Jacobian::<Bn254G1>::generator();
        let a = g.double().double().add(&g); // 4G + G
        let b = g.double().add(&g).add(&g).add(&g); // 2G+G+G+G
        assert!(a.eq_point(&b));
    }

    #[test]
    fn affine_constants_roundtrip() {
        let a = Affine::<Bls12381G1>::from_generator();
        assert!(a.is_on_curve());
        assert!(!a.infinity);
    }
}
