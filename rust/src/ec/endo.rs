//! GLV endomorphism layer: cube-root-of-unity scalar decomposition for the
//! a = 0 curves (Gallant–Lambert–Vanstone, the SZKP/ZK-Flex-style
//! structural reduction layered *on top of* signed-digit buckets).
//!
//! Both paper curves (and their G2 twists) have j-invariant 0, so the map
//!
//! ```text
//!   φ(x, y) = (ζ·x, y),   ζ³ = 1, ζ ≠ 1 in the coordinate field
//! ```
//!
//! is an efficiently computable endomorphism (one field multiplication)
//! acting on the prime-order subgroup as multiplication by a scalar λ with
//! λ² + λ + 1 ≡ 0 (mod r). Writing `k ≡ k1 + k2·λ (mod r)` with half-width
//! `k1`, `k2` turns one full-width MSM term `k·P` into two half-width terms
//! `k1·P + k2·φ(P)` — the MSM plan then covers the scalars with **half the
//! k-bit windows** over a doubled point set: total bucket fills are
//! unchanged, but the serially-dependent reduction chain and the DNA
//! combine (the latency-bound phases the hardware cannot pipeline away)
//! halve again on top of the signed-digit halving.
//!
//! Following the crate's no-magic-numbers rule (see `ff::bigint`), nothing
//! here is hand-transcribed: ζ and λ are derived at first use from the
//! field parameters (`g^((q−1)/3)`), matched to each other against the
//! curve group (`φ(G) = λ·G`), and the half-width lattice basis comes from
//! the classic extended-Euclidean construction on (r, λ). The derivation
//! self-checks every property — `ζ³ = 1`, the decomposition congruence,
//! the magnitude bound, the endomorphism action — and yields `None` (GLV
//! stays off for that curve, results stay correct) rather than ever
//! exposing unverified parameters.

use super::point::{Affine, CurveParams, Jacobian};
use super::{scalar, ScalarLimbs};
use crate::ff::{bigint, Field, FieldParams, Fp};
use std::sync::LazyLock as Lazy;

// ---------------------------------------------------------------------------
// Sign-magnitude helpers (512-bit headroom, covers every intermediate)
// ---------------------------------------------------------------------------

/// Sign-magnitude integer over 8 little-endian limbs. The decomposition's
/// worst intermediates are products of two < 2^255 magnitudes plus small
/// sums — comfortably inside 512 bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SWide {
    neg: bool,
    mag: [u64; 8],
}

impl SWide {
    const ZERO: SWide = SWide { neg: false, mag: [0; 8] };

    fn from_limbs4(v: ScalarLimbs) -> SWide {
        let mut mag = [0u64; 8];
        mag[..4].copy_from_slice(&v);
        SWide { neg: false, mag }
    }

    fn is_zero(&self) -> bool {
        bigint::is_zero(&self.mag)
    }

    fn negate(mut self) -> SWide {
        if !self.is_zero() {
            self.neg = !self.neg;
        }
        self
    }

    fn add(&self, other: &SWide) -> SWide {
        if self.neg == other.neg {
            let (mag, carry) = bigint::add(&self.mag, &other.mag);
            debug_assert_eq!(carry, 0, "SWide overflow");
            SWide { neg: self.neg && !bigint::is_zero(&mag), mag }
        } else if bigint::gte(&self.mag, &other.mag) {
            let (mag, _) = bigint::sub(&self.mag, &other.mag);
            SWide { neg: self.neg && !bigint::is_zero(&mag), mag }
        } else {
            let (mag, _) = bigint::sub(&other.mag, &self.mag);
            SWide { neg: other.neg, mag }
        }
    }

    fn sub(&self, other: &SWide) -> SWide {
        self.add(&other.negate())
    }

    /// Signed product of two 4-limb magnitudes.
    fn mul4(a: &ScalarLimbs, a_neg: bool, b: &ScalarLimbs, b_neg: bool) -> SWide {
        let (lo, hi) = bigint::mul_wide(a, b);
        let mut mag = [0u64; 8];
        mag[..4].copy_from_slice(&lo);
        mag[4..].copy_from_slice(&hi);
        SWide { neg: (a_neg != b_neg) && !bigint::is_zero(&mag), mag }
    }

    /// The low 4 limbs, or `None` if the value does not fit.
    fn to_limbs4(&self) -> Option<ScalarLimbs> {
        if self.mag[4..].iter().any(|&w| w != 0) {
            return None;
        }
        let mut out = [0u64; 4];
        out.copy_from_slice(&self.mag[..4]);
        Some(out)
    }
}

/// Bit length of a 4-limb magnitude (0 for zero).
fn bit_len4(v: &ScalarLimbs) -> u32 {
    match bigint::msb(v) {
        Some(b) => b as u32 + 1,
        None => 0,
    }
}

// ---------------------------------------------------------------------------
// Resolved parameters
// ---------------------------------------------------------------------------

/// One half of a GLV split: sign plus half-width magnitude. Folding the
/// sign into the point (negation is free on Weierstrass curves) leaves the
/// MSM plan an ordinary non-negative scalar below `2^half_bits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GlvSplit {
    /// `k1` contributes `−|k1|·P` when set.
    pub k1_neg: bool,
    /// |k1| — the λ⁰ half.
    pub k1: ScalarLimbs,
    /// `k2` contributes `−|k2|·φ(P)` when set.
    pub k2_neg: bool,
    /// |k2| — the λ¹ half.
    pub k2: ScalarLimbs,
}

/// Fully derived and self-checked GLV data for one curve (see the module
/// docs for how each constant is obtained). Access through
/// [`CurveParams::glv`]; construction is lazy and happens once per curve.
pub struct GlvParams<C: CurveParams> {
    /// ζ — the cube root of unity in the coordinate field, matched to
    /// [`Self::lambda`] so that `φ(P) = (ζ·x, y) = λ·P` on the subgroup.
    pub zeta: C::Base,
    /// λ — the matching cube root of unity mod r (canonical limbs, < r).
    pub lambda: ScalarLimbs,
    /// The scalar-field modulus r.
    pub modulus: ScalarLimbs,
    /// Upper bound on the bit width of either decomposition half
    /// (`⌈log₂ max(|a1|+|a2|, |b1|+|b2|)⌉` — just over half the scalar
    /// width for a balanced lattice basis). Sizes the GLV MSM plan.
    pub half_bits: u32,
    /// Lattice basis v1 = (a1, b1), v2 = (a2, b2) with a + b·λ ≡ 0 (mod r)
    /// and det(v1, v2) = +r, stored sign-magnitude.
    a1: (bool, ScalarLimbs),
    b1: (bool, ScalarLimbs),
    a2: (bool, ScalarLimbs),
    b2: (bool, ScalarLimbs),
    /// round(2^256·|b2| / r) — Babai coefficient c1 by multiply-high.
    g1: ScalarLimbs,
    /// round(2^256·|b1| / r) — Babai coefficient c2 by multiply-high.
    g2: ScalarLimbs,
}

impl<C: CurveParams> GlvParams<C> {
    /// Split `k` (canonical limbs, reduced mod r internally) into two
    /// signed half-width parts with `k1 + k2·λ ≡ k (mod r)` and both
    /// magnitudes below `2^half_bits`.
    pub fn decompose(&self, k: &ScalarLimbs) -> GlvSplit {
        self.try_decompose(k).expect("validated lattice bounds every split")
    }

    /// [`Self::decompose`] returning `None` instead of panicking when a
    /// half overflows its bound — only reachable with unvalidated
    /// parameters, which the derivation never exposes.
    fn try_decompose(&self, k: &ScalarLimbs) -> Option<GlvSplit> {
        // reduce k mod r (MSM callers hand canonical-but-unreduced limbs)
        let mut kr = *k;
        while bigint::gte(&kr, &self.modulus) {
            let (d, _) = bigint::sub(&kr, &self.modulus);
            kr = d;
        }
        // Babai rounding: c1 = round(k·b2/r), c2 = round(−k·b1/r); the
        // congruence holds for ANY integers c1, c2 (each basis vector is in
        // the lattice), rounding only controls the magnitude of the halves.
        let c1 = (self.b2.0, babai_c(&kr, &self.g1));
        let c2 = (!self.b1.0, babai_c(&kr, &self.g2));
        // (k1, k2) = (k, 0) − c1·v1 − c2·v2
        let k1 = SWide::from_limbs4(kr)
            .sub(&SWide::mul4(&c1.1, c1.0, &self.a1.1, self.a1.0))
            .sub(&SWide::mul4(&c2.1, c2.0, &self.a2.1, self.a2.0));
        let k2 = SWide::mul4(&c1.1, c1.0, &self.b1.1, self.b1.0)
            .add(&SWide::mul4(&c2.1, c2.0, &self.b2.1, self.b2.0))
            .negate();
        Some(GlvSplit {
            k1_neg: k1.neg,
            k1: k1.to_limbs4()?,
            k2_neg: k2.neg,
            k2: k2.to_limbs4()?,
        })
    }
}

/// `floor((k·g + 2^255) / 2^256)` — the multiply-high rounding step shared
/// by both Babai coefficients (total error vs the exact rational < 1, which
/// the `half_bits` bound already absorbs).
fn babai_c(k: &ScalarLimbs, g: &ScalarLimbs) -> ScalarLimbs {
    let (lo, hi) = bigint::mul_wide(k, g);
    let mut prod = [0u64; 8];
    prod[..4].copy_from_slice(&lo);
    prod[4..].copy_from_slice(&hi);
    let mut half = [0u64; 8];
    half[3] = 1 << 63;
    let (sum, carry) = bigint::add(&prod, &half);
    debug_assert_eq!(carry, 0, "k·g bounded well below 2^512");
    let mut c = [0u64; 4];
    c.copy_from_slice(&sum[4..]);
    c
}

/// round(2^256·|b| / r) for a basis coordinate (one-time setup).
fn mulhigh_const(b_mag: &ScalarLimbs, r: &ScalarLimbs) -> Option<ScalarLimbs> {
    let mut num = [0u64; 8];
    num[4..].copy_from_slice(b_mag);
    let (mut q, rem) = bigint::div_rem_wide::<8, 4>(&num, r);
    let (rem2, carry) = bigint::add(&rem, &rem);
    if carry == 1 || bigint::gte(&rem2, r) {
        let mut one = [0u64; 8];
        one[0] = 1;
        let (s, c) = bigint::add(&q, &one);
        debug_assert_eq!(c, 0);
        q = s;
    }
    if q[4..].iter().any(|&w| w != 0) {
        return None; // basis coordinate implausibly large
    }
    let mut out = [0u64; 4];
    out.copy_from_slice(&q[..4]);
    Some(out)
}

// ---------------------------------------------------------------------------
// The endomorphism map and MSM-input expansion
// ---------------------------------------------------------------------------

/// φ on an affine point: `(x, y) ↦ (ζ·x, y)` — one field multiplication.
pub fn endo_affine<C: CurveParams>(params: &GlvParams<C>, p: &Affine<C>) -> Affine<C> {
    Affine { x: p.x.mul(&params.zeta), y: p.y, infinity: p.infinity }
}

/// φ on a Jacobian point: `(X, Y, Z) ↦ (ζ·X, Y, Z)` (affine x = X/Z²
/// scales by ζ exactly as required; infinity (Z = 0) maps to itself).
pub fn endo_jacobian<C: CurveParams>(params: &GlvParams<C>, p: &Jacobian<C>) -> Jacobian<C> {
    Jacobian { x: p.x.mul(&params.zeta), y: p.y, z: p.z }
}

/// Expand an m-term MSM into the 2m-term GLV form: entry `2i` is
/// `(±Pᵢ, |k1|)`, entry `2i+1` is `(±φ(Pᵢ), |k2|)` (signs folded into the
/// points). Per-point and deterministic, so point-chunk shards that expand
/// their own slice compose linearly with the whole, and every device
/// expanding the full set for a window-range shard produces identical
/// inputs — merges stay bit-identical.
pub fn expand<C: CurveParams>(
    params: &GlvParams<C>,
    points: &[Affine<C>],
    scalars: &[ScalarLimbs],
) -> (Vec<Affine<C>>, Vec<ScalarLimbs>) {
    assert_eq!(points.len(), scalars.len(), "MSM input length mismatch");
    let mut out_points = Vec::with_capacity(2 * points.len());
    let mut out_scalars = Vec::with_capacity(2 * points.len());
    for (p, s) in points.iter().zip(scalars) {
        let split = params.decompose(s);
        out_points.push(if split.k1_neg { p.neg() } else { *p });
        out_scalars.push(split.k1);
        let phi = endo_affine(params, p);
        out_points.push(if split.k2_neg { phi.neg() } else { phi });
        out_scalars.push(split.k2);
    }
    (out_points, out_scalars)
}

// ---------------------------------------------------------------------------
// Derivation (lazy, once per curve)
// ---------------------------------------------------------------------------

/// A primitive cube root of unity in `F` (`t^((q−1)/3)` for the first
/// small `t` that is not a cube), or `None` if 3 ∤ q − 1.
fn cube_root_of_unity<F: Field>() -> Option<F> {
    let q_minus_1 = F::order_minus_one();
    let (exp, rem) = bigint::div_rem_small(&q_minus_1, 3);
    if rem != 0 {
        return None;
    }
    for t in 2u64..40 {
        let z = F::from_u64(t).pow_limbs(&exp);
        if z != F::one() {
            if z.square().mul(&z) != F::one() {
                return None; // q not what we assumed — refuse
            }
            return Some(z);
        }
    }
    None
}

/// Derive and self-check the full GLV parameter set for curve `C` with
/// scalar field `P`. Every failure path returns `None` (the curve simply
/// runs without the fast path) — no partially-checked constants escape.
fn derive<C: CurveParams, P: FieldParams<4>>() -> Option<GlvParams<C>> {
    let r = P::MODULUS;

    // λ = g^((r−1)/3) in Fr, a primitive cube root of unity mod r.
    let mut r_minus_1 = r.to_vec();
    r_minus_1[0] -= 1; // r odd
    let (exp, rem) = bigint::div_rem_small(&r_minus_1, 3);
    if rem != 0 {
        return None;
    }
    let lambda_f = Fp::<P, 4>::from_u64(P::GENERATOR).pow_limbs(&exp);
    if lambda_f == Fp::<P, 4>::one()
        || lambda_f.square().mul(&lambda_f) != Fp::<P, 4>::one()
    {
        return None;
    }
    let lambda = lambda_f.to_canonical();

    // ζ in the coordinate field, matched to λ: φ(G) must equal λ·G —
    // otherwise the other root (ζ²) is the partner.
    let zeta_any = cube_root_of_unity::<C::Base>()?;
    let g = Jacobian::<C>::generator();
    let lambda_g = scalar::mul::<C>(&g, &lambda);
    let phi_g = |z: &C::Base| {
        let (x, y) = C::generator_xy();
        Jacobian::<C> { x: x.mul(z), y, z: C::Base::one() }
    };
    let zeta = if phi_g(&zeta_any).eq_point(&lambda_g) {
        zeta_any
    } else {
        let z2 = zeta_any.square();
        if !phi_g(&z2).eq_point(&lambda_g) {
            return None;
        }
        z2
    };

    // Half-width lattice basis by the extended Euclidean algorithm on
    // (r, λ): every EEA row satisfies r_i − t_i·λ ≡ 0 (mod r), so
    // (r_i, −t_i) lies in the lattice {(a, b) : a + b·λ ≡ 0 (mod r)}.
    // Stop at the first remainder below √r; that row and the shorter of
    // its neighbours form the (near-)shortest basis.
    let sq_ge_r = |v: &ScalarLimbs| -> bool {
        let (lo, hi) = bigint::mul_wide(v, v);
        if !bigint::is_zero(&hi) {
            return true;
        }
        bigint::gte(&lo, &r)
    };
    let mut r_prev = r;
    let mut r_cur = lambda;
    let mut t_prev = SWide::ZERO;
    let mut t_cur = SWide::from_limbs4([1, 0, 0, 0]);
    while sq_ge_r(&r_cur) {
        if bigint::is_zero(&r_cur) {
            return None; // gcd reached without a short vector — degenerate
        }
        let (q, rem) = bigint::div_rem(&r_prev, &r_cur);
        let t4 = t_cur.to_limbs4()?;
        let t_next = t_prev.sub(&SWide::mul4(&q, false, &t4, t_cur.neg));
        r_prev = r_cur;
        r_cur = rem;
        t_prev = t_cur;
        t_cur = t_next;
    }
    // v1 = (r_cur, −t_cur); v2 = the shorter (∞-norm) of the neighbours
    // (r_prev, −t_prev) and one EEA step further.
    let a1 = r_cur;
    let b1 = t_cur.negate();
    let b1_mag = b1.to_limbs4()?;
    if bigint::is_zero(&a1) && bigint::is_zero(&b1_mag) {
        return None;
    }
    let (cand_b_r, cand_b_t) = {
        if bigint::is_zero(&r_cur) {
            return None;
        }
        let (q, rem) = bigint::div_rem(&r_prev, &r_cur);
        let t4 = t_cur.to_limbs4()?;
        (rem, t_prev.sub(&SWide::mul4(&q, false, &t4, t_cur.neg)))
    };
    let norm_inf = |a: &ScalarLimbs, b: &ScalarLimbs| -> ScalarLimbs {
        if bigint::gte(a, b) {
            *a
        } else {
            *b
        }
    };
    let cand_a_t4 = t_prev.to_limbs4()?;
    let cand_b_t4 = cand_b_t.to_limbs4()?;
    let norm_a = norm_inf(&r_prev, &cand_a_t4);
    let norm_b = norm_inf(&cand_b_r, &cand_b_t4);
    let (mut a2, mut b2) = if bigint::lt(&norm_a, &norm_b) {
        ((false, r_prev), (!t_prev.neg && !bigint::is_zero(&cand_a_t4), cand_a_t4))
    } else {
        ((false, cand_b_r), (!cand_b_t.neg && !bigint::is_zero(&cand_b_t4), cand_b_t4))
    };
    let a1 = (false, a1);
    let b1 = (b1.neg, b1_mag);

    // det(v1, v2) = a1·b2 − a2·b1 must be ±r; flip v2 so it is +r, which
    // is what the Babai sign conventions below assume.
    let det = SWide::mul4(&a1.1, a1.0, &b2.1, b2.0)
        .sub(&SWide::mul4(&a2.1, a2.0, &b1.1, b1.0));
    let det_mag = det.to_limbs4()?;
    if det_mag != r {
        return None;
    }
    if det.neg {
        a2.0 = !a2.0 && !bigint::is_zero(&a2.1);
        b2.0 = !b2.0 && !bigint::is_zero(&b2.1);
    }

    // Babai multiply-high constants and the magnitude bound:
    // |k1| ≤ |a1| + |a2|, |k2| ≤ |b1| + |b2| (rounding error < 1 per
    // coefficient), so half_bits = ⌈log₂ max(...)⌉ covers every split.
    let g1 = mulhigh_const(&b2.1, &r)?;
    let g2 = mulhigh_const(&b1.1, &r)?;
    let (sum_a, ca) = bigint::add(&a1.1, &a2.1);
    let (sum_b, cb) = bigint::add(&b1.1, &b2.1);
    if ca != 0 || cb != 0 {
        return None;
    }
    let half_bits = bit_len4(&norm_inf(&sum_a, &sum_b));
    if half_bits == 0 || half_bits > 160 {
        return None; // not a half-width basis — refuse the fast path
    }

    let params = GlvParams::<C> {
        zeta,
        lambda,
        modulus: r,
        half_bits,
        a1,
        b1,
        a2,
        b2,
        g1,
        g2,
    };

    // Final self-check: sampled decompositions must satisfy the exact
    // congruence and the magnitude bound, and φ must act as λ on a
    // non-generator point.
    let mut rng = crate::util::rng::Rng::new(0x614C_5653); // "aLVS"
    for i in 0..24u32 {
        let k: ScalarLimbs = match i {
            0 => [0; 4],
            1 => [1, 0, 0, 0],
            2 => {
                let mut v = r;
                v[0] -= 1;
                v
            }
            _ => [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 1],
        };
        let split = params.try_decompose(&k)?;
        if bit_len4(&split.k1) > params.half_bits || bit_len4(&split.k2) > params.half_bits {
            return None;
        }
        let signed_f = |neg: bool, mag: &ScalarLimbs| {
            let v = Fp::<P, 4>::from_limbs_reduce(*mag);
            if neg {
                v.neg()
            } else {
                v
            }
        };
        let lhs = signed_f(split.k1_neg, &split.k1)
            .add(&signed_f(split.k2_neg, &split.k2).mul(&lambda_f));
        if lhs != Fp::<P, 4>::from_limbs_reduce(k) {
            return None;
        }
    }
    let q5 = scalar::mul::<C>(&Jacobian::<C>::generator(), &[5, 0, 0, 0]);
    if !endo_jacobian(&params, &q5).eq_point(&scalar::mul::<C>(&q5, &params.lambda)) {
        return None;
    }
    Some(params)
}

// ---------------------------------------------------------------------------
// Per-curve lazily derived statics (the targets of `CurveParams::glv`)
// ---------------------------------------------------------------------------

use super::g1::{Bls12381G1, Bn254G1};
use super::g2::{Bls12381G2, Bn254G2};
use crate::ff::params::{Bls12381FrParams, Bn254FrParams};

static BN254_G1_GLV: Lazy<Option<GlvParams<Bn254G1>>> =
    Lazy::new(derive::<Bn254G1, Bn254FrParams>);
static BN254_G2_GLV: Lazy<Option<GlvParams<Bn254G2>>> =
    Lazy::new(derive::<Bn254G2, Bn254FrParams>);
static BLS12_381_G1_GLV: Lazy<Option<GlvParams<Bls12381G1>>> =
    Lazy::new(derive::<Bls12381G1, Bls12381FrParams>);
static BLS12_381_G2_GLV: Lazy<Option<GlvParams<Bls12381G2>>> =
    Lazy::new(derive::<Bls12381G2, Bls12381FrParams>);

/// BN254 G1 parameters (the `CurveParams::glv` impl target).
pub(crate) fn bn254_g1() -> Option<&'static GlvParams<Bn254G1>> {
    BN254_G1_GLV.as_ref()
}

/// BN254 G2 parameters.
pub(crate) fn bn254_g2() -> Option<&'static GlvParams<Bn254G2>> {
    BN254_G2_GLV.as_ref()
}

/// BLS12-381 G1 parameters.
pub(crate) fn bls12_381_g1() -> Option<&'static GlvParams<Bls12381G1>> {
    BLS12_381_G1_GLV.as_ref()
}

/// BLS12-381 G2 parameters.
pub(crate) fn bls12_381_g2() -> Option<&'static GlvParams<Bls12381G2>> {
    BLS12_381_G2_GLV.as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::points;
    use crate::ff::{FrBls12381, FrBn254};
    use crate::msm;
    use crate::util::rng::Rng;

    #[test]
    fn all_four_groups_have_params() {
        // every a = 0 group in the crate admits the endomorphism; a
        // regression to None would silently disable the fast path
        assert!(Bn254G1::glv().is_some(), "bn254 g1");
        assert!(Bls12381G1::glv().is_some(), "bls12-381 g1");
        assert!(Bn254G2::glv().is_some(), "bn254 g2");
        assert!(Bls12381G2::glv().is_some(), "bls12-381 g2");
    }

    #[test]
    fn zeta_cubes_to_one_and_is_nontrivial() {
        fn check<C: CurveParams>() {
            let p = C::glv().expect("params");
            assert_ne!(p.zeta, C::Base::one(), "{}: zeta must be primitive", C::NAME);
            let cube = p.zeta.square().mul(&p.zeta);
            assert_eq!(cube, C::Base::one(), "{}: zeta^3 != 1", C::NAME);
            // primitive also means zeta² ≠ 1
            assert_ne!(p.zeta.square(), C::Base::one(), "{}", C::NAME);
        }
        check::<Bn254G1>();
        check::<Bls12381G1>();
        check::<Bn254G2>();
        check::<Bls12381G2>();
    }

    #[test]
    fn lambda_cubes_to_one_mod_r() {
        let p = Bn254G1::glv().unwrap();
        let l = FrBn254::from_canonical(p.lambda).unwrap();
        assert_eq!(l.square().mul(&l), FrBn254::one());
        assert_ne!(l, FrBn254::one());
        // λ² + λ + 1 ≡ 0 — the minimal polynomial of a primitive cube root
        assert!(l.square().add(&l).add(&FrBn254::one()).is_zero());
        let p = Bls12381G1::glv().unwrap();
        let l = FrBls12381::from_canonical(p.lambda).unwrap();
        assert!(l.square().add(&l).add(&FrBls12381::one()).is_zero());
    }

    #[test]
    fn endo_map_is_multiplication_by_lambda() {
        fn check<C: CurveParams>() {
            let p = C::glv().expect("params");
            let q = scalar::mul::<C>(&Jacobian::<C>::generator(), &[0xABCDE, 0, 0, 0]);
            let want = scalar::mul::<C>(&q, &p.lambda);
            assert!(endo_jacobian(p, &q).eq_point(&want), "{} jacobian", C::NAME);
            let qa = q.to_affine();
            assert!(endo_affine(p, &qa).to_jacobian().eq_point(&want), "{} affine", C::NAME);
        }
        check::<Bn254G1>();
        check::<Bls12381G1>();
        check::<Bn254G2>();
        check::<Bls12381G2>();
    }

    #[test]
    fn endo_preserves_infinity_and_curve_membership() {
        let p = Bn254G1::glv().unwrap();
        assert!(endo_affine(p, &Affine::<Bn254G1>::infinity()).infinity);
        assert!(endo_jacobian(p, &Jacobian::<Bn254G1>::infinity()).is_infinity());
        let pts = points::generate_points_walk::<Bn254G1>(8, 991);
        for q in &pts {
            assert!(endo_affine(p, q).is_on_curve());
        }
    }

    #[test]
    fn decompose_edge_scalars() {
        let p = Bn254G1::glv().unwrap();
        // zero splits to zero halves
        let z = p.decompose(&[0; 4]);
        assert_eq!(z.k1, [0; 4]);
        assert_eq!(z.k2, [0; 4]);
        assert!(!z.k1_neg && !z.k2_neg);
        // one splits to (1, 0) — the rounding terms all vanish
        let o = p.decompose(&[1, 0, 0, 0]);
        assert_eq!(o.k1, [1, 0, 0, 0]);
        assert!(!o.k1_neg);
        assert_eq!(o.k2, [0; 4]);
        // scalars ≥ r reduce first: r itself behaves as zero
        let r = p.decompose(&p.modulus);
        assert_eq!(r.k1, [0; 4]);
        assert_eq!(r.k2, [0; 4]);
    }

    #[test]
    fn decompose_halves_are_half_width() {
        let p = Bn254G1::glv().unwrap();
        // the lattice bound must really be (just over) half the scalar
        // width — the whole point of the fast path
        assert!(p.half_bits <= 130, "half_bits {}", p.half_bits);
        let p381 = Bls12381G1::glv().unwrap();
        assert!(p381.half_bits <= 130, "half_bits {}", p381.half_bits);
        let mut rng = Rng::new(8181);
        for _ in 0..50 {
            let k = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 2];
            let s = p.decompose(&k);
            assert!(bit_len4(&s.k1) <= p.half_bits, "{:?}", s);
            assert!(bit_len4(&s.k2) <= p.half_bits, "{:?}", s);
        }
    }

    #[test]
    fn expand_preserves_the_msm_sum() {
        // the linearity identity the whole fast path rests on:
        // Σ kᵢ·Pᵢ == Σ (k1ᵢ·(±Pᵢ) + k2ᵢ·(±φ(Pᵢ)))
        let p = Bn254G1::glv().unwrap();
        let w = points::workload::<Bn254G1>(24, 771);
        let (xp, xs) = expand(p, &w.points, &w.scalars);
        assert_eq!(xp.len(), 48);
        assert_eq!(xs.len(), 48);
        for q in &xp {
            assert!(q.is_on_curve());
        }
        let want = msm::naive::msm(&w.points, &w.scalars);
        let got = msm::naive::msm(&xp, &xs);
        assert!(got.eq_point(&want));
    }

    #[test]
    fn expand_preserves_the_msm_sum_bls_and_g2() {
        let w = points::workload::<Bls12381G1>(12, 772);
        let p = Bls12381G1::glv().unwrap();
        let (xp, xs) = expand(p, &w.points, &w.scalars);
        assert!(msm::naive::msm(&xp, &xs).eq_point(&msm::naive::msm(&w.points, &w.scalars)));
        let w2 = points::workload::<Bn254G2>(8, 773);
        let p2 = Bn254G2::glv().unwrap();
        let (xp2, xs2) = expand(p2, &w2.points, &w2.scalars);
        assert!(
            msm::naive::msm(&xp2, &xs2).eq_point(&msm::naive::msm(&w2.points, &w2.scalars))
        );
    }

    #[test]
    fn swide_arithmetic_basics() {
        let a = SWide::from_limbs4([5, 0, 0, 0]);
        let b = SWide::from_limbs4([7, 0, 0, 0]);
        assert_eq!(a.sub(&b), SWide::from_limbs4([2, 0, 0, 0]).negate());
        assert_eq!(b.sub(&a), SWide::from_limbs4([2, 0, 0, 0]));
        assert!(a.sub(&a).is_zero());
        assert!(!a.sub(&a).neg, "no negative zero");
        let p = SWide::mul4(&[3, 0, 0, 0], true, &[4, 0, 0, 0], false);
        assert_eq!(p, SWide::from_limbs4([12, 0, 0, 0]).negate());
        // products of full-width magnitudes land in the high limbs
        let big = SWide::mul4(&[0, 0, 0, 1 << 62], false, &[0, 0, 0, 1 << 62], false);
        assert!(big.to_limbs4().is_none());
    }
}
