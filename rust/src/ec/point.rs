//! Generic short-Weierstrass points (`y² = x³ + b`, a = 0) in affine and
//! Jacobian coordinates.
//!
//! Formulas follow the Explicit-Formulas Database entries the paper cites
//! ([23]): `add-2007-bl` (11M + 5S — the paper's "16 modulo multiplications"
//! for point addition), `madd-2007-bl` (7M + 4S mixed addition — what the
//! BAM issues for bucket ← bucket + base-point), and `dbl-2009-l`
//! (2M + 5S, valid for a = 0; the paper's resource model budgets the generic
//! 9-modmul doubling and `fpga::resources` keeps that accounting).
//!
//! [`Jacobian::add`] is **unified**: it detects the P = Q case and falls
//! through to doubling, and handles both infinities — exactly the semantics
//! of the paper's Unified-Double-Add pipeline where a "PD check" join-mux
//! selects between the PA and PD datapaths (§IV-B3, Fig. 3).

use super::counters;
use crate::ff::Field;
use std::fmt;

/// Static curve description. `a` is fixed to 0 (true for both paper curves).
pub trait CurveParams:
    'static + Copy + Clone + Send + Sync + fmt::Debug + PartialEq + Eq
{
    /// Coordinate field (Fp for G1, Fp² for G2).
    type Base: Field;
    /// Curve constant b.
    fn b() -> Self::Base;
    /// Subgroup generator, affine.
    fn generator_xy() -> (Self::Base, Self::Base);
    /// Scalar bit width (254 for BN254, 255→381-bit MSM slicing for BLS).
    const SCALAR_BITS: u32;
    /// The paper's headline scalar width for MSM accounting (254 / 381).
    const MSM_SCALAR_BITS: u32;
    /// Display name.
    const NAME: &'static str;
    /// Bytes of an affine point in the paper's DDR layout (2 coords).
    const AFFINE_BYTES: u64;

    /// GLV endomorphism parameters (ζ, λ, half-width lattice basis) when
    /// the curve admits the cube-root endomorphism — derived lazily and
    /// self-checked once per curve (see [`crate::ec::endo`]). `None`
    /// disables the `Decomposition::Glv` fast path for the curve; the MSM
    /// plan then falls back to full-width scalars, so results stay correct
    /// either way.
    fn glv() -> Option<&'static crate::ec::endo::GlvParams<Self>> {
        None
    }
}

/// Affine point (with explicit infinity flag).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Affine<C: CurveParams> {
    /// x-coordinate (unspecified when `infinity`).
    pub x: C::Base,
    /// y-coordinate (unspecified when `infinity`).
    pub y: C::Base,
    /// Point-at-infinity marker.
    pub infinity: bool,
}

impl<C: CurveParams> fmt::Debug for Affine<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.infinity {
            write!(f, "{}(inf)", C::NAME)
        } else {
            write!(f, "{}({:?}, {:?})", C::NAME, self.x, self.y)
        }
    }
}

impl<C: CurveParams> Affine<C> {
    /// A finite point from coordinates (membership is not checked — use
    /// [`Self::is_on_curve`]).
    pub fn new(x: C::Base, y: C::Base) -> Self {
        Affine { x, y, infinity: false }
    }

    /// The point at infinity.
    pub fn infinity() -> Self {
        Affine { x: C::Base::zero(), y: C::Base::zero(), infinity: true }
    }

    /// The subgroup generator in affine form.
    pub fn from_generator() -> Self {
        let (x, y) = C::generator_xy();
        Affine::new(x, y)
    }

    /// y² == x³ + b (infinity counts as on-curve).
    pub fn is_on_curve(&self) -> bool {
        if self.infinity {
            return true;
        }
        let lhs = self.y.square();
        let rhs = self.x.square().mul(&self.x).add(&C::b());
        lhs == rhs
    }

    /// −P (free on Weierstrass curves: y ↦ −y).
    pub fn neg(&self) -> Self {
        Affine { x: self.x, y: self.y.neg(), infinity: self.infinity }
    }

    /// Lift to Jacobian coordinates (Z = 1).
    pub fn to_jacobian(&self) -> Jacobian<C> {
        if self.infinity {
            Jacobian::infinity()
        } else {
            Jacobian { x: self.x, y: self.y, z: C::Base::one() }
        }
    }
}

/// Jacobian point: (X, Y, Z) ↦ affine (X/Z², Y/Z³); infinity encoded Z = 0.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Jacobian<C: CurveParams> {
    /// X coordinate.
    pub x: C::Base,
    /// Y coordinate.
    pub y: C::Base,
    /// Z coordinate (zero encodes the point at infinity).
    pub z: C::Base,
}

impl<C: CurveParams> fmt::Debug for Jacobian<C> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_infinity() {
            write!(f, "{}_jac(inf)", C::NAME)
        } else {
            write!(f, "{}_jac({:?})", C::NAME, self.to_affine())
        }
    }
}

impl<C: CurveParams> Jacobian<C> {
    /// The point at infinity (Z = 0).
    pub fn infinity() -> Self {
        Jacobian { x: C::Base::one(), y: C::Base::one(), z: C::Base::zero() }
    }

    /// The subgroup generator.
    pub fn generator() -> Self {
        let (x, y) = C::generator_xy();
        Jacobian { x, y, z: C::Base::one() }
    }

    /// Is this the point at infinity?
    #[inline]
    pub fn is_infinity(&self) -> bool {
        self.z.is_zero()
    }

    /// Projective equality (compares the underlying affine points).
    pub fn eq_point(&self, other: &Self) -> bool {
        match (self.is_infinity(), other.is_infinity()) {
            (true, true) => true,
            (true, false) | (false, true) => false,
            _ => {
                // X1·Z2² == X2·Z1² and Y1·Z2³ == Y2·Z1³
                let z1z1 = self.z.square();
                let z2z2 = other.z.square();
                if self.x.mul(&z2z2) != other.x.mul(&z1z1) {
                    return false;
                }
                let z1c = z1z1.mul(&self.z);
                let z2c = z2z2.mul(&other.z);
                self.y.mul(&z2c) == other.y.mul(&z1c)
            }
        }
    }

    /// Unified point addition (`add-2007-bl`, 11M + 5S) with the UDA
    /// join-mux semantics: handles infinities, falls through to [`Self::double`]
    /// when the operands are equal, returns infinity for P + (−P).
    pub fn add(&self, other: &Self) -> Self {
        if self.is_infinity() {
            return *other;
        }
        if other.is_infinity() {
            return *self;
        }
        counters::count_add();

        let z1z1 = self.z.square();
        let z2z2 = other.z.square();
        let u1 = self.x.mul(&z2z2);
        let u2 = other.x.mul(&z1z1);
        let s1 = self.y.mul(&other.z).mul(&z2z2);
        let s2 = other.y.mul(&self.z).mul(&z1z1);

        if u1 == u2 {
            return if s1 == s2 {
                // PD check fired: same point — the unified pipeline's
                // double branch (count the add back out; double counts
                // itself).
                counters::uncount_add();
                self.double()
            } else {
                // P + (−P)
                Jacobian::infinity()
            };
        }

        let h = u2.sub(&u1);
        let i = h.double().square();
        let j = h.mul(&i);
        let r = s2.sub(&s1).double();
        let v = u1.mul(&i);
        let x3 = r.square().sub(&j).sub(&v.double());
        let y3 = r.mul(&v.sub(&x3)).sub(&s1.mul(&j).double());
        let z3 = self.z.add(&other.z).square().sub(&z1z1).sub(&z2z2).mul(&h);
        Jacobian { x: x3, y: y3, z: z3 }
    }

    /// Mixed addition with an affine operand (`madd-2007-bl`, 7M + 4S) —
    /// the bucket-accumulation workhorse (base points live in DDR in
    /// affine form; the paper's SPS streams them straight into the UDA).
    pub fn add_mixed(&self, other: &Affine<C>) -> Self {
        if other.infinity {
            return *self;
        }
        if self.is_infinity() {
            return other.to_jacobian();
        }
        counters::count_mixed();

        let z1z1 = self.z.square();
        let u2 = other.x.mul(&z1z1);
        let s2 = other.y.mul(&self.z).mul(&z1z1);

        if u2 == self.x {
            return if s2 == self.y {
                counters::uncount_mixed();
                self.double()
            } else {
                Jacobian::infinity()
            };
        }

        let h = u2.sub(&self.x);
        let hh = h.square();
        let i = hh.double().double();
        let j = h.mul(&i);
        let r = s2.sub(&self.y).double();
        let v = self.x.mul(&i);
        let x3 = r.square().sub(&j).sub(&v.double());
        let y3 = r.mul(&v.sub(&x3)).sub(&self.y.mul(&j).double());
        let z3 = self.z.add(&h).square().sub(&z1z1).sub(&hh);
        Jacobian { x: x3, y: y3, z: z3 }
    }

    /// Doubling (`dbl-2009-l`, 2M + 5S, valid for a = 0).
    pub fn double(&self) -> Self {
        if self.is_infinity() {
            return *self;
        }
        counters::count_double();

        let a = self.x.square();
        let b = self.y.square();
        let c = b.square();
        let d = self.x.add(&b).square().sub(&a).sub(&c).double();
        let e = a.double().add(&a);
        let f = e.square();
        let x3 = f.sub(&d.double());
        let eight_c = c.double().double().double();
        let y3 = e.mul(&d.sub(&x3)).sub(&eight_c);
        let z3 = self.y.mul(&self.z).double();
        Jacobian { x: x3, y: y3, z: z3 }
    }

    /// `n` successive doublings — the Horner shift chain of the window
    /// combine (`k` doublings per window in the DNA pass, `k·lo` for a
    /// window-range shard's global shift). Same `dbl-2009-l` bodies as
    /// [`Self::double`] (2M + 5S each; a = 0 means there is no cross-step
    /// state worth caching, which is exactly why the per-step formula is
    /// already minimal), but the infinity check is hoisted out of the
    /// loop and the doubling counter is bumped once for the whole run.
    /// Safe without per-step checks: Z₃ = 2·Y·Z keeps Z at zero once it
    /// reaches zero, so an infinity can never silently un-flag itself.
    pub fn double_n(&self, n: u32) -> Self {
        if n == 0 || self.is_infinity() {
            return *self;
        }
        counters::count_doubles(n as u64);
        let (mut x, mut y, mut z) = (self.x, self.y, self.z);
        for _ in 0..n {
            let a = x.square();
            let b = y.square();
            let c = b.square();
            let d = x.add(&b).square().sub(&a).sub(&c).double();
            let e = a.double().add(&a);
            let f = e.square();
            let x3 = f.sub(&d.double());
            let eight_c = c.double().double().double();
            let y3 = e.mul(&d.sub(&x3)).sub(&eight_c);
            let z3 = y.mul(&z).double();
            x = x3;
            y = y3;
            z = z3;
        }
        Jacobian { x, y, z }
    }

    /// −P (y ↦ −y).
    pub fn neg(&self) -> Self {
        Jacobian { x: self.x, y: self.y.neg(), z: self.z }
    }

    /// P − Q.
    pub fn sub(&self, other: &Self) -> Self {
        self.add(&other.neg())
    }

    /// Convert to affine (one field inversion).
    pub fn to_affine(&self) -> Affine<C> {
        if self.is_infinity() {
            return Affine::infinity();
        }
        let zinv = self.z.inv().expect("nonzero z");
        let zinv2 = zinv.square();
        let zinv3 = zinv2.mul(&zinv);
        Affine::new(self.x.mul(&zinv2), self.y.mul(&zinv3))
    }

    /// Batch affine conversion using Montgomery's simultaneous-inversion
    /// trick (1 inversion + 3(n−1) multiplications).
    pub fn batch_to_affine(points: &[Jacobian<C>]) -> Vec<Affine<C>> {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        // prefix products of the nonzero z's
        let mut prefix = Vec::with_capacity(n);
        let mut acc = C::Base::one();
        for p in points {
            prefix.push(acc);
            if !p.is_infinity() {
                acc = acc.mul(&p.z);
            }
        }
        let mut inv = acc.inv().unwrap_or_else(C::Base::one);
        let mut out = vec![Affine::infinity(); n];
        for i in (0..n).rev() {
            let p = &points[i];
            if p.is_infinity() {
                continue;
            }
            let zinv = inv.mul(&prefix[i]);
            inv = inv.mul(&p.z);
            let zinv2 = zinv.square();
            out[i] = Affine::new(p.x.mul(&zinv2), p.y.mul(&zinv2.mul(&zinv)));
        }
        out
    }

    /// Is the corresponding affine point on the curve?
    pub fn is_on_curve(&self) -> bool {
        if self.is_infinity() {
            return true;
        }
        // Y² = X³ + b·Z⁶
        let z2 = self.z.square();
        let z6 = z2.square().mul(&z2);
        self.y.square() == self.x.square().mul(&self.x).add(&C::b().mul(&z6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::{Bls12381G1, Bn254G1};
    use crate::util::rng::Rng;

    fn rand_point<C: CurveParams>(rng: &mut Rng) -> Jacobian<C> {
        // random small multiple of the generator
        let k = rng.range(1, 1 << 30);
        crate::ec::scalar::mul::<C>(&Jacobian::generator(), &[k, 0, 0, 0])
    }

    #[test]
    fn add_commutative() {
        let mut rng = Rng::new(51);
        for _ in 0..10 {
            let p = rand_point::<Bn254G1>(&mut rng);
            let q = rand_point::<Bn254G1>(&mut rng);
            assert!(p.add(&q).eq_point(&q.add(&p)));
        }
    }

    #[test]
    fn add_associative() {
        let mut rng = Rng::new(52);
        let p = rand_point::<Bls12381G1>(&mut rng);
        let q = rand_point::<Bls12381G1>(&mut rng);
        let r = rand_point::<Bls12381G1>(&mut rng);
        assert!(p.add(&q).add(&r).eq_point(&p.add(&q.add(&r))));
    }

    #[test]
    fn unified_add_handles_doubling() {
        let g = Jacobian::<Bn254G1>::generator();
        assert!(g.add(&g).eq_point(&g.double()));
        // and through distinct Jacobian representations of the same point
        let g3 = g.double().add(&g); // 3G with z != 1
        let doubled = g3.add(&g3);
        assert!(doubled.eq_point(&g3.double()));
    }

    #[test]
    fn add_inverse_gives_infinity() {
        let mut rng = Rng::new(53);
        let p = rand_point::<Bn254G1>(&mut rng);
        assert!(p.add(&p.neg()).is_infinity());
        assert!(p.sub(&p).is_infinity());
    }

    #[test]
    fn infinity_is_identity() {
        let mut rng = Rng::new(54);
        let p = rand_point::<Bls12381G1>(&mut rng);
        let o = Jacobian::<Bls12381G1>::infinity();
        assert!(p.add(&o).eq_point(&p));
        assert!(o.add(&p).eq_point(&p));
        assert!(o.add(&o).is_infinity());
        assert!(o.double().is_infinity());
    }

    #[test]
    fn mixed_add_matches_full_add() {
        let mut rng = Rng::new(55);
        for _ in 0..10 {
            let p = rand_point::<Bn254G1>(&mut rng);
            let q = rand_point::<Bn254G1>(&mut rng);
            let qa = q.to_affine();
            assert!(p.add_mixed(&qa).eq_point(&p.add(&q)));
        }
        // degenerate cases
        let p = rand_point::<Bn254G1>(&mut rng);
        let pa = p.to_affine();
        assert!(p.add_mixed(&pa).eq_point(&p.double()));
        assert!(p.add_mixed(&pa.neg()).is_infinity());
        assert!(Jacobian::<Bn254G1>::infinity().add_mixed(&pa).eq_point(&p));
        assert!(p.add_mixed(&Affine::infinity()).eq_point(&p));
    }

    #[test]
    fn double_n_matches_repeated_double() {
        let mut rng = Rng::new(59);
        for _ in 0..5 {
            let p = rand_point::<Bn254G1>(&mut rng);
            let mut want = p;
            for n in 0..=13u32 {
                // exact coordinate equality, not just eq_point: the shift
                // chain must be bit-identical to the double() loop
                let got = p.double_n(n);
                assert_eq!(got.x, want.x, "n={n}");
                assert_eq!(got.y, want.y, "n={n}");
                assert_eq!(got.z, want.z, "n={n}");
                want = want.double();
            }
        }
        // infinity shifts to infinity, and the counter stays untouched
        let o = Jacobian::<Bn254G1>::infinity();
        let (r, ops) = crate::ec::counters::measure(|| o.double_n(12));
        assert!(r.is_infinity());
        assert_eq!(ops.double, 0);
        // a finite run counts exactly n doublings
        let g = Jacobian::<Bn254G1>::generator();
        let (_, ops) = crate::ec::counters::measure(|| g.double_n(12));
        assert_eq!(ops.double, 12);
    }

    #[test]
    fn double_stays_on_curve() {
        let mut p = Jacobian::<Bls12381G1>::generator();
        for _ in 0..20 {
            p = p.double();
            assert!(p.is_on_curve());
        }
    }

    #[test]
    fn add_results_on_curve() {
        let mut rng = Rng::new(56);
        let p = rand_point::<Bls12381G1>(&mut rng);
        let q = rand_point::<Bls12381G1>(&mut rng);
        assert!(p.add(&q).is_on_curve());
    }

    #[test]
    fn to_affine_roundtrip() {
        let mut rng = Rng::new(57);
        let p = rand_point::<Bn254G1>(&mut rng);
        let a = p.to_affine();
        assert!(a.is_on_curve());
        assert!(a.to_jacobian().eq_point(&p));
    }

    #[test]
    fn batch_to_affine_matches_individual() {
        let mut rng = Rng::new(58);
        let mut pts: Vec<Jacobian<Bn254G1>> =
            (0..17).map(|_| rand_point::<Bn254G1>(&mut rng)).collect();
        pts.push(Jacobian::infinity());
        pts.insert(5, Jacobian::infinity());
        let batch = Jacobian::batch_to_affine(&pts);
        for (p, b) in pts.iter().zip(&batch) {
            assert_eq!(p.to_affine().infinity, b.infinity);
            if !b.infinity {
                assert_eq!(p.to_affine().x, b.x);
                assert_eq!(p.to_affine().y, b.y);
            }
        }
    }

    #[test]
    fn eq_point_across_representations() {
        let g = Jacobian::<Bn254G1>::generator();
        let g2a = g.double().add(&g);
        let g2b = g.add(&g.double());
        assert!(g2a.eq_point(&g2b));
        assert!(!g2a.eq_point(&g));
    }
}
