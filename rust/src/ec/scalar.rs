//! Scalar multiplication and scalar window slicing.
//!
//! [`mul`] is Algorithm 1 of the paper (MSB-first double-and-add) — the
//! baseline whose O(N) point-op cost motivates the bucket method (Table II).
//! [`mul_window`] is a fixed-window variant used where the walk generator
//! and the prover need many multiplications of the *same* base.
//!
//! [`slice_bits`]/[`window_count`] are the §II-F scalar-slicing primitives.
//! They live here — at the field-ops layer — because every consumer above
//! (the windowed multiplier below, `msm::plan`'s bucket pipeline, and
//! through it the FPGA timing model) slices scalars the same way; the MSM
//! plan layer builds signed-digit decomposition on top of them.

use super::point::{CurveParams, Jacobian};
use super::ScalarLimbs;
use crate::ff::bigint;

/// Extract the k-bit slice of `scalar` starting at bit `lo` (k ≤ 32).
/// Bits beyond the 256-bit limb range read as zero.
#[inline]
pub fn slice_bits(scalar: &ScalarLimbs, lo: u32, k: u32) -> u64 {
    debug_assert!(k <= 32);
    let limb = (lo / 64) as usize;
    let shift = lo % 64;
    if limb >= 4 {
        return 0;
    }
    let mut v = scalar[limb] >> shift;
    if shift + k > 64 && limb + 1 < 4 {
        v |= scalar[limb + 1] << (64 - shift);
    }
    v & ((1u64 << k) - 1)
}

/// Number of k-bit windows covering an N-bit scalar.
pub fn window_count(scalar_bits: u32, k: u32) -> u32 {
    scalar_bits.div_ceil(k)
}

/// Algorithm 1: MSB-first double-and-add. `scalar` is canonical little-
/// endian limbs (not reduced — the loop runs from the scalar's MSB).
pub fn mul<C: CurveParams>(p: &Jacobian<C>, scalar: &ScalarLimbs) -> Jacobian<C> {
    let msb = match bigint::msb(scalar) {
        None => return Jacobian::infinity(), // s = 0
        Some(b) => b,
    };
    let mut q = Jacobian::<C>::infinity();
    for i in (0..=msb).rev() {
        q = q.double();
        if bigint::bit(scalar, i) {
            q = q.add(p);
        }
    }
    q
}

/// Fixed-window (2^w) scalar multiplication: precomputes the small-multiple
/// table of `p` once; ~N/w adds instead of ~N/2.
pub fn mul_window<C: CurveParams>(
    p: &Jacobian<C>,
    scalar: &ScalarLimbs,
    w: usize,
) -> Jacobian<C> {
    assert!((1..=8).contains(&w), "window width out of range");
    let msb = match bigint::msb(scalar) {
        None => return Jacobian::infinity(),
        Some(b) => b,
    };
    // table[i] = i·P for i in 0..2^w
    let mut table = Vec::with_capacity(1 << w);
    table.push(Jacobian::<C>::infinity());
    table.push(*p);
    for i in 2..(1 << w) {
        table.push(table[i - 1].add(p));
    }
    let windows = msb / w + 1;
    let mut q = Jacobian::<C>::infinity();
    for win in (0..windows).rev() {
        for _ in 0..w {
            q = q.double();
        }
        let digit = slice_bits(scalar, (win * w) as u32, w as u32) as usize;
        if digit != 0 {
            q = q.add(&table[digit]);
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ec::counters;
    use crate::ec::{Bls12381G1, Bn254G1};
    use crate::util::rng::Rng;

    #[test]
    fn slice_bits_extracts_correctly() {
        let s: ScalarLimbs = [0xABCD_EF01_2345_6789, 0x1122_3344_5566_7788, 0, 0];
        assert_eq!(slice_bits(&s, 0, 8), 0x89);
        assert_eq!(slice_bits(&s, 4, 8), 0x78);
        // straddles the limb boundary: bits 60..72 = low 4 of limb1 (0x8) ++ top nibble of limb0 (0xA)
        assert_eq!(slice_bits(&s, 60, 12), 0x88A);
        assert_eq!(slice_bits(&s, 192, 16), 0);
        assert_eq!(slice_bits(&s, 300, 8), 0); // beyond the limbs: zero
    }

    #[test]
    fn window_count_matches_paper_table_iii() {
        // k=12: BN254 → 22 windows, BLS12-381 → 32 windows (Table III's
        // m×22 / m×32 point-op accounting).
        assert_eq!(window_count(254, 12), 22);
        assert_eq!(window_count(381, 12), 32);
    }

    #[test]
    fn small_scalars_match_repeated_add() {
        let g = Jacobian::<Bn254G1>::generator();
        let mut acc = Jacobian::<Bn254G1>::infinity();
        for k in 1u64..=16 {
            acc = acc.add(&g);
            let viamul = mul::<Bn254G1>(&g, &[k, 0, 0, 0]);
            assert!(viamul.eq_point(&acc), "k={k}");
        }
    }

    #[test]
    fn zero_scalar_gives_infinity() {
        let g = Jacobian::<Bls12381G1>::generator();
        assert!(mul::<Bls12381G1>(&g, &[0; 4]).is_infinity());
        assert!(mul_window::<Bls12381G1>(&g, &[0; 4], 4).is_infinity());
    }

    #[test]
    fn window_matches_double_and_add() {
        let mut rng = Rng::new(61);
        let g = Jacobian::<Bn254G1>::generator();
        for w in [2usize, 4, 5] {
            let s = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64() >> 2];
            let a = mul::<Bn254G1>(&g, &s);
            let b = mul_window::<Bn254G1>(&g, &s, w);
            assert!(a.eq_point(&b), "w={w}");
        }
    }

    #[test]
    fn distributes_over_scalar_addition() {
        // (a+b)·G = a·G + b·G for small scalars without carries
        let g = Jacobian::<Bls12381G1>::generator();
        let a = 0x1234_5678u64;
        let b = 0x0fed_cba9u64;
        let lhs = mul::<Bls12381G1>(&g, &[a + b, 0, 0, 0]);
        let rhs = mul::<Bls12381G1>(&g, &[a, 0, 0, 0]).add(&mul::<Bls12381G1>(&g, &[b, 0, 0, 0]));
        assert!(lhs.eq_point(&rhs));
    }

    #[test]
    fn double_and_add_cost_matches_table_ii_accounting() {
        // Algorithm 1 on an N-bit scalar costs ≈N doubles + (ones) adds;
        // the paper's Table II budgets 2N point-ops (N doubles + N adds
        // upper bound). Check we're within it.
        let g = Jacobian::<Bn254G1>::generator();
        let s: [u64; 4] = [u64::MAX, u64::MAX, u64::MAX, u64::MAX >> 10]; // 246-bit
        let (_, ops) = counters::measure(|| mul::<Bn254G1>(&g, &s));
        let n = 246u64;
        assert!(ops.double <= n && ops.double >= n - 1, "doubles {}", ops.double);
        assert!(ops.add <= n, "adds {}", ops.add);
        assert!(ops.total() <= 2 * n);
    }
}
