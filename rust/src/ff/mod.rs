//! Finite-field arithmetic substrate.
//!
//! The paper's entire compute reduces to modular arithmetic over the base
//! fields of BN254 ("BN128") and BLS12-381 (§II-C, §IV-B1). This module
//! provides:
//!
//! * [`bigint`] — fixed-width multi-precision primitives (compile-time
//!   Montgomery constant derivation included);
//! * [`fp`] — the generic Montgomery-form prime field [`fp::Fp`];
//! * [`lanes`] — the 4-lane limb-interleaved (SoA) vectorized core
//!   feeding NTT butterflies and batch-affine MSM fill;
//! * [`barrett`] — the paper's "standard form" (non-Montgomery) backend
//!   (§IV-B4), used for cross-checking and by the hardware resource models;
//! * [`fp2`] — the quadratic extension for G2;
//! * [`sqrt`] — generic Tonelli–Shanks (deterministic point generation);
//! * [`limbs16`] — repacking to the PJRT engine's 16-bit limb domain;
//! * [`opcount`] — the modmul counters behind Tables II/III;
//! * [`codec`] — canonical `u64`-word (de)serialization for the
//!   streaming SRS's on-disk chunk files.

pub mod bigint;
pub mod fp;
pub mod lanes;
pub mod opcount;
pub mod barrett;
pub mod fp2;
pub mod sqrt;
pub mod limbs16;
pub mod params;
pub mod codec;

pub use codec::WordCodec;
pub use fp::{Field, FieldParams, Fp};
pub use fp2::Fp2;
pub use lanes::{FpLanes, LANES};
pub use opcount::OpCounts;

/// BN254 base field (4 × 64-bit limbs, 254 bits).
pub type FpBn254 = Fp<params::Bn254FpParams, 4>;
/// BN254 scalar field.
pub type FrBn254 = Fp<params::Bn254FrParams, 4>;
/// BLS12-381 base field (6 × 64-bit limbs, 381 bits).
pub type FpBls12381 = Fp<params::Bls12381FpParams, 6>;
/// BLS12-381 scalar field.
pub type FrBls12381 = Fp<params::Bls12381FrParams, 4>;
/// BN254 quadratic extension (G2 coordinates).
pub type Fp2Bn254 = Fp2<params::Bn254FpParams, 4>;
/// BLS12-381 quadratic extension (G2 coordinates).
pub type Fp2Bls12381 = Fp2<params::Bls12381FpParams, 6>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_bit_widths() {
        use fp::FieldParams;
        assert_eq!(params::Bn254FpParams::BITS, 254);
        assert_eq!(params::Bn254FrParams::BITS, 254);
        assert_eq!(params::Bls12381FpParams::BITS, 381);
        assert_eq!(params::Bls12381FrParams::BITS, 255);
    }

    #[test]
    fn two_adicity_matches_known() {
        use fp::FieldParams;
        assert_eq!(params::Bn254FrParams::TWO_ADICITY, 28);
        assert_eq!(params::Bls12381FrParams::TWO_ADICITY, 32);
    }
}
