//! Canonical word-level (de)serialization for field elements.
//!
//! The streaming SRS (`snark/stream.rs`) stores points on disk as their
//! canonical (non-Montgomery) little-endian `u64` words, so chunk files
//! are byte-stable across runs and hosts of the same endianness-agnostic
//! format. [`WordCodec`] is the one trait both coordinate types implement:
//!
//! * `Fp<P, N>` — `N` words via `to_canonical`/`from_canonical`;
//! * `Fp2<P, N>` — `2N` words, `c0`'s words first, then `c1`'s.
//!
//! Decoding is validating: a word vector encoding a value ≥ p is rejected
//! (`None`), so a corrupted chunk file surfaces as a typed stream error
//! instead of a garbage point.

use super::fp::{FieldParams, Fp};
use super::fp2::Fp2;

/// Fixed-width canonical `u64`-word encoding for a coordinate type.
pub trait WordCodec: Sized {
    /// Number of `u64` words one element occupies.
    const WORDS: usize;

    /// Append exactly [`Self::WORDS`] canonical words to `out`.
    fn write_words(&self, out: &mut Vec<u64>);

    /// Decode from exactly [`Self::WORDS`] leading words of `words`;
    /// `None` if too short or non-canonical (≥ p).
    fn read_words(words: &[u64]) -> Option<Self>;
}

impl<P: FieldParams<N>, const N: usize> WordCodec for Fp<P, N> {
    const WORDS: usize = N;

    fn write_words(&self, out: &mut Vec<u64>) {
        out.extend_from_slice(&self.to_canonical());
    }

    fn read_words(words: &[u64]) -> Option<Self> {
        if words.len() < N {
            return None;
        }
        let mut limbs = [0u64; N];
        limbs.copy_from_slice(&words[..N]);
        Fp::from_canonical(limbs)
    }
}

impl<P: FieldParams<N>, const N: usize> WordCodec for Fp2<P, N> {
    const WORDS: usize = 2 * N;

    fn write_words(&self, out: &mut Vec<u64>) {
        self.c0.write_words(out);
        self.c1.write_words(out);
    }

    fn read_words(words: &[u64]) -> Option<Self> {
        if words.len() < 2 * N {
            return None;
        }
        let c0 = Fp::read_words(&words[..N])?;
        let c1 = Fp::read_words(&words[N..2 * N])?;
        Some(Fp2 { c0, c1 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::fp::Field;
    use crate::ff::{Fp2Bls12381, Fp2Bn254, FpBls12381, FpBn254};

    fn roundtrip<T: WordCodec + PartialEq + std::fmt::Debug>(v: &T) {
        let mut words = Vec::new();
        v.write_words(&mut words);
        assert_eq!(words.len(), T::WORDS);
        let back = T::read_words(&words).expect("canonical words decode");
        assert_eq!(&back, v);
    }

    #[test]
    fn fp_roundtrips_both_curves() {
        roundtrip(&FpBn254::from_u64(0));
        roundtrip(&FpBn254::from_u64(12345));
        roundtrip(&FpBn254::from_u64(1).neg()); // p - 1: the largest canonical value
        roundtrip(&FpBls12381::from_u64(0));
        roundtrip(&FpBls12381::from_u64(987654321));
        roundtrip(&FpBls12381::from_u64(1).neg());
    }

    #[test]
    fn fp2_roundtrips_both_curves_c0_first() {
        let v = Fp2Bn254 {
            c0: FpBn254::from_u64(7),
            c1: FpBn254::from_u64(11),
        };
        roundtrip(&v);
        let mut words = Vec::new();
        v.write_words(&mut words);
        // layout contract: c0's words precede c1's
        assert_eq!(FpBn254::read_words(&words[..4]).unwrap(), v.c0);
        assert_eq!(FpBn254::read_words(&words[4..]).unwrap(), v.c1);
        roundtrip(&Fp2Bls12381 {
            c0: FpBls12381::from_u64(3),
            c1: FpBls12381::from_u64(1).neg(),
        });
    }

    #[test]
    fn non_canonical_words_are_rejected() {
        // all-ones words are ≥ p for every supported field
        assert!(FpBn254::read_words(&[u64::MAX; 4]).is_none());
        assert!(FpBls12381::read_words(&[u64::MAX; 6]).is_none());
        let mut words = vec![u64::MAX; 8];
        // valid c0, corrupt c1 — still rejected
        words[..4].copy_from_slice(&FpBn254::from_u64(5).to_canonical());
        assert!(Fp2Bn254::read_words(&words).is_none());
    }

    #[test]
    fn short_input_is_rejected() {
        assert!(FpBn254::read_words(&[0u64; 3]).is_none());
        assert!(Fp2Bn254::read_words(&[0u64; 7]).is_none());
    }
}
