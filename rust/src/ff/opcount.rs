//! Modular-arithmetic operation counters.
//!
//! The paper quantifies MSM algorithms in *modular multiplications*
//! (Tables II and III). Rather than trusting formulas, every `Fp` multiply,
//! square, add/sub and inversion increments a thread-local counter; the
//! Table II/III benches snapshot these around real MSM executions.
//!
//! Thread-local `Cell` increments cost ≈1ns next to a ≈20–60ns field
//! multiply, so the hot path keeps them enabled unconditionally.

use std::cell::Cell;

thread_local! {
    static MUL: Cell<u64> = const { Cell::new(0) };
    static SQUARE: Cell<u64> = const { Cell::new(0) };
    static ADD: Cell<u64> = const { Cell::new(0) };
    static INV: Cell<u64> = const { Cell::new(0) };
}

/// Count one modular multiplication (called by the field cores).
#[inline(always)]
pub fn count_mul() {
    MUL.with(|c| c.set(c.get() + 1));
}
/// Count one modular squaring.
#[inline(always)]
pub fn count_square() {
    SQUARE.with(|c| c.set(c.get() + 1));
}
/// Count one modular addition/subtraction/doubling.
#[inline(always)]
pub fn count_add() {
    ADD.with(|c| c.set(c.get() + 1));
}
/// Count one modular inversion.
#[inline(always)]
pub fn count_inv() {
    INV.with(|c| c.set(c.get() + 1));
}

/// Count `n` modular multiplications at once — the 4-lane field core
/// charges its batched ops here so lane and scalar paths stay
/// indistinguishable to every pinned budget.
#[inline(always)]
pub fn count_muls(n: u64) {
    MUL.with(|c| c.set(c.get() + n));
}
/// Count `n` modular squarings at once (see [`count_muls`]).
#[inline(always)]
pub fn count_squares(n: u64) {
    SQUARE.with(|c| c.set(c.get() + n));
}
/// Count `n` modular additions/subtractions/doublings at once (see
/// [`count_muls`]).
#[inline(always)]
pub fn count_adds(n: u64) {
    ADD.with(|c| c.set(c.get() + n));
}

/// A snapshot of the per-thread counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// General modular multiplications.
    pub mul: u64,
    /// Modular squarings (the FPGA treats them as multiplications too).
    pub square: u64,
    /// Modular additions/subtractions/doublings.
    pub add: u64,
    /// Modular inversions.
    pub inv: u64,
}

impl OpCounts {
    /// Total multiplications in the paper's accounting (mul + square —
    /// the UDA datapath runs squarings through the same multipliers).
    pub fn modmuls(&self) -> u64 {
        self.mul + self.square
    }
}

impl std::ops::Sub for OpCounts {
    type Output = OpCounts;
    fn sub(self, rhs: OpCounts) -> OpCounts {
        OpCounts {
            mul: self.mul - rhs.mul,
            square: self.square - rhs.square,
            add: self.add - rhs.add,
            inv: self.inv - rhs.inv,
        }
    }
}

impl std::ops::Add for OpCounts {
    type Output = OpCounts;
    fn add(self, rhs: OpCounts) -> OpCounts {
        OpCounts {
            mul: self.mul + rhs.mul,
            square: self.square + rhs.square,
            add: self.add + rhs.add,
            inv: self.inv + rhs.inv,
        }
    }
}

impl std::ops::AddAssign for OpCounts {
    fn add_assign(&mut self, rhs: OpCounts) {
        *self = *self + rhs;
    }
}

/// Current counter values for this thread.
pub fn snapshot() -> OpCounts {
    OpCounts {
        mul: MUL.with(Cell::get),
        square: SQUARE.with(Cell::get),
        add: ADD.with(Cell::get),
        inv: INV.with(Cell::get),
    }
}

/// Run `f` and return (result, ops consumed by f) on this thread.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, OpCounts) {
    let before = snapshot();
    let out = f();
    (out, snapshot() - before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::fp::Field;
    use crate::ff::params::Bn254FpParams;
    type F = crate::ff::fp::Fp<Bn254FpParams, 4>;

    #[test]
    fn measures_muls_and_squares() {
        let a = F::from_u64(3);
        let (_, ops) = measure(|| {
            let mut x = a;
            for _ in 0..10 {
                x = x.mul(&a); // 10 muls
            }
            x.square() // 1 square
        });
        assert_eq!(ops.mul, 10);
        assert_eq!(ops.square, 1);
        assert_eq!(ops.modmuls(), 11);
    }

    #[test]
    fn counts_aggregate_across_phases() {
        // multi-phase budget pins (e.g. the NTT transform sequence in
        // tests/perf_smoke.rs) sum per-phase snapshots
        let a = OpCounts { mul: 3, square: 1, add: 5, inv: 0 };
        let b = OpCounts { mul: 7, square: 0, add: 1, inv: 2 };
        let mut acc = OpCounts::default();
        acc += a;
        acc += b;
        assert_eq!(acc, a + b);
        assert_eq!(acc.modmuls(), 11);
        assert_eq!((acc - a), b);
    }

    #[test]
    fn measures_adds_and_inv() {
        let a = F::from_u64(7);
        let (_, ops) = measure(|| {
            let _ = a.add(&a);
            let _ = a.sub(&a);
            a.inv()
        });
        assert_eq!(ops.add, 2);
        assert_eq!(ops.inv, 1);
        // Fermat inversion burns ~BITS squarings/muls
        assert!(ops.modmuls() > 200, "inversion should cost many modmuls");
    }
}
