//! "Standard form" (non-Montgomery) modular multiplication.
//!
//! §IV-B4 of the paper: for BLS12-381 the design moved off Montgomery form
//! to a LUT-based reduction (Öztürk [27]) so each modular multiply needs one
//! integer multiplier instead of three. In software the natural analogue of
//! a precomputed-table reduction is **Barrett reduction** with a precomputed
//! μ = ⌊2^(2·64·N) / p⌋: one wide multiply plus two truncated multiplies and
//! a couple of subtractions — no per-step division, exactly one full-width
//! integer product on the critical path.
//!
//! This backend operates on **canonical** (standard-form) limbs and is used
//! (a) to cross-check the Montgomery core, (b) by the resource/power models
//! which distinguish the two hardware variants, and (c) as the reference
//! semantics of the L1 kernel's final-compare path.

use super::bigint::{self, mac};
use std::sync::LazyLock as Lazy;

/// Precomputed Barrett context for one modulus.
#[derive(Debug)]
pub struct BarrettCtx {
    /// Modulus limbs, little-endian.
    pub p: Vec<u64>,
    /// μ = ⌊2^(2·64·n) / p⌋ (n = p limb count) — n+1 limbs.
    pub mu: Vec<u64>,
    /// limb count of p.
    pub n: usize,
}

impl BarrettCtx {
    /// Build a context (one-time cost: a 2·64·n-bit long division).
    pub fn new(p: &[u64]) -> BarrettCtx {
        let mut p = p.to_vec();
        bigint::normalize(&mut p);
        let n = p.len();
        let mu = bigint::div_pow2(2 * 64 * n, &p);
        BarrettCtx { p, mu, n }
    }

    /// Multiply canonical a·b mod p. `a`, `b` must be < p.
    pub fn mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        super::opcount::count_mul();
        let n = self.n;
        // x = a*b, 2n limbs
        let x = mul_slices(a, b, 2 * n);
        self.reduce(&x)
    }

    /// Barrett-reduce a 2n-limb value x < p² to x mod p.
    pub fn reduce(&self, x: &[u64]) -> Vec<u64> {
        let n = self.n;
        // q1 = x >> 64(n-1)
        let q1 = &x[(n - 1).min(x.len())..];
        // q2 = q1 * mu ; q3 = q2 >> 64(n+1)
        let q2 = mul_slices(q1, &self.mu, q1.len() + self.mu.len());
        let q3 = if q2.len() > n + 1 { q2[n + 1..].to_vec() } else { vec![0] };
        // r = x mod 2^(64(n+1)) − (q3·p mod 2^(64(n+1)))
        let r1 = &x[..x.len().min(n + 1)];
        let q3p = mul_slices(&q3, &self.p, n + 1); // truncated product
        let mut r = sub_mod_pow(r1, &q3p, n + 1);
        // At most two corrective subtractions (Barrett bound).
        let mut guard = 0;
        while bigint::cmp_slices(&r, &self.p) != std::cmp::Ordering::Less {
            r = bigint::sub_slices(&r, &self.p);
            guard += 1;
            assert!(guard <= 3, "Barrett correction out of bounds");
        }
        bigint::normalize(&mut r);
        r
    }

    /// a + b mod p (canonical operands).
    pub fn add(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        super::opcount::count_add();
        let mut s = add_slices(a, b);
        if bigint::cmp_slices(&s, &self.p) != std::cmp::Ordering::Less {
            s = bigint::sub_slices(&s, &self.p);
        }
        s
    }

    /// a − b mod p.
    pub fn sub(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        super::opcount::count_add();
        if bigint::cmp_slices(a, b) != std::cmp::Ordering::Less {
            bigint::sub_slices(a, b)
        } else {
            let t = add_slices(a, &self.p);
            bigint::sub_slices(&t, b)
        }
    }
}

/// Truncated schoolbook multiply: low `out_limbs` limbs of a·b.
fn mul_slices(a: &[u64], b: &[u64], out_limbs: usize) -> Vec<u64> {
    let mut t = vec![0u64; out_limbs + 1];
    for (i, &ai) in a.iter().enumerate() {
        if i >= out_limbs {
            break;
        }
        let mut carry = 0u64;
        for (j, &bj) in b.iter().enumerate() {
            if i + j >= out_limbs {
                break;
            }
            let (lo, hi) = mac(t[i + j], ai, bj, carry);
            t[i + j] = lo;
            carry = hi;
        }
        if i + b.len() < out_limbs {
            t[i + b.len()] = carry;
        }
    }
    t.truncate(out_limbs);
    bigint::normalize(&mut t);
    t
}

fn add_slices(a: &[u64], b: &[u64]) -> Vec<u64> {
    let n = a.len().max(b.len());
    let mut out = vec![0u64; n + 1];
    let mut carry = 0u64;
    for i in 0..n {
        let av = a.get(i).copied().unwrap_or(0);
        let bv = b.get(i).copied().unwrap_or(0);
        let (s, c) = bigint::adc(av, bv, carry);
        out[i] = s;
        carry = c;
    }
    out[n] = carry;
    bigint::normalize(&mut out);
    out
}

/// (a − b) mod 2^(64·k), assuming the true difference taken mod 2^(64k).
fn sub_mod_pow(a: &[u64], b: &[u64], k: usize) -> Vec<u64> {
    let mut out = vec![0u64; k];
    let mut borrow = 0u64;
    for i in 0..k {
        let av = a.get(i).copied().unwrap_or(0);
        let bv = b.get(i).copied().unwrap_or(0);
        let (d, bo) = bigint::sbb(av, bv, borrow);
        out[i] = d;
        borrow = bo;
    }
    // wraparound ignored: Barrett guarantees the true r ≥ 0 and < 2^(64k)
    bigint::normalize(&mut out);
    out
}

/// Shared BN254 base-field context (built once).
pub static BN254_FP_BARRETT: Lazy<BarrettCtx> = Lazy::new(|| {
    use crate::ff::fp::FieldParams;
    BarrettCtx::new(&crate::ff::params::Bn254FpParams::MODULUS)
});
/// Shared BLS12-381 base-field context (built once).
pub static BLS12_381_FP_BARRETT: Lazy<BarrettCtx> = Lazy::new(|| {
    use crate::ff::fp::FieldParams;
    BarrettCtx::new(&crate::ff::params::Bls12381FpParams::MODULUS)
});

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::fp::{Field, Fp};
    use crate::ff::params::{Bls12381FpParams, Bn254FpParams};
    use crate::util::rng::Rng;

    type FpBn = Fp<Bn254FpParams, 4>;
    type FpBls = Fp<Bls12381FpParams, 6>;

    #[test]
    fn small_modulus_mul() {
        let ctx = BarrettCtx::new(&[97]);
        assert_eq!(ctx.mul(&[13], &[15]), vec![13 * 15 % 97]);
        assert_eq!(ctx.mul(&[96], &[96]), vec![1]); // (-1)^2
    }

    #[test]
    fn agrees_with_montgomery_bn254() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let a = FpBn::random(&mut rng);
            let b = FpBn::random(&mut rng);
            let want = a.mul(&b).to_canonical().to_vec();
            let got = BN254_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
            let mut want = want;
            crate::ff::bigint::normalize(&mut want);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn agrees_with_montgomery_bls() {
        let mut rng = Rng::new(12);
        for _ in 0..50 {
            let a = FpBls::random(&mut rng);
            let b = FpBls::random(&mut rng);
            let want = a.mul(&b).to_canonical().to_vec();
            let got = BLS12_381_FP_BARRETT.mul(&a.to_canonical(), &b.to_canonical());
            let mut want = want;
            crate::ff::bigint::normalize(&mut want);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn add_sub_agree_with_montgomery() {
        let mut rng = Rng::new(13);
        let a = FpBls::random(&mut rng);
        let b = FpBls::random(&mut rng);
        let ctx = &BLS12_381_FP_BARRETT;
        let mut want_add = a.add(&b).to_canonical().to_vec();
        crate::ff::bigint::normalize(&mut want_add);
        assert_eq!(ctx.add(&a.to_canonical(), &b.to_canonical()), want_add);
        let mut want_sub = a.sub(&b).to_canonical().to_vec();
        crate::ff::bigint::normalize(&mut want_sub);
        assert_eq!(ctx.sub(&a.to_canonical(), &b.to_canonical()), want_sub);
    }

    #[test]
    fn edge_values() {
        let ctx = &BN254_FP_BARRETT;
        let zero = vec![0u64];
        let one = vec![1u64];
        let pm1 = {
            let mut p = ctx.p.clone();
            p[0] -= 1;
            p
        };
        assert_eq!(ctx.mul(&zero, &pm1), vec![0]);
        assert_eq!(ctx.mul(&one, &pm1), pm1);
        assert_eq!(ctx.mul(&pm1, &pm1), vec![1]);
    }
}
