//! Generic Tonelli–Shanks square root over any [`Field`].
//!
//! Used by the deterministic point generators (`ec::points`) to build large
//! MSM test workloads without a trusted setup: sample x, solve
//! y² = x³ + b. Works for both Fp (G1) and Fp² (G2) through the `Field`
//! abstraction — the Fp² case needs a randomized nonresidue search because
//! every base-subfield element is a square in Fp².

use super::fp::Field;
use crate::util::rng::Rng;
use crate::ff::bigint;

/// Legendre-style symbol via Euler's criterion: returns 1, 0, or −1 encoded
/// as `Some(true)` (square), `None` (zero), `Some(false)` (nonsquare).
pub fn euler_criterion<F: Field>(a: &F) -> Option<bool> {
    if a.is_zero() {
        return None;
    }
    let e = bigint::shr_slices(&F::order_minus_one(), 1);
    let l = a.pow_limbs(&e);
    Some(l == F::one())
}

/// Find a quadratic nonresidue: try small integers first (fast path for
/// prime fields), then deterministic pseudo-random elements (needed for
/// Fp², where all base-subfield elements are squares).
fn find_nonresidue<F: Field>() -> F {
    for k in 2u64..32 {
        let c = F::from_u64(k);
        if euler_criterion(&c) == Some(false) {
            return c;
        }
    }
    // Fixed seed: the search is deterministic so repeated sqrt calls agree.
    let mut rng = Rng::new(NONRESIDUE_SEARCH_SEED);
    loop {
        let c = F::random(&mut rng);
        if euler_criterion(&c) == Some(false) {
            return c;
        }
    }
}

/// Seed for the randomized nonresidue search (recorded for reproducibility).
const NONRESIDUE_SEARCH_SEED: u64 = 0x5eed_0f05_0a12_e000;

/// sqrt(a) if it exists. Returns the "positive" root (either root works for
/// point construction; callers that care pick a sign).
pub fn sqrt<F: Field>(a: &F) -> Option<F> {
    if a.is_zero() {
        return Some(F::zero());
    }
    if euler_criterion(a) != Some(true) {
        return None;
    }
    // q − 1 = 2^s · t with t odd
    let q1 = F::order_minus_one();
    let s = bigint::trailing_zeros(&q1).expect("q-1 nonzero");
    let t = bigint::shr_slices(&q1, s as usize);

    // R = a^((t+1)/2), b = a^t, c = z^t
    let t_plus_1 = {
        let mut v = t.clone();
        let mut i = 0;
        loop {
            let (s_, c) = bigint::adc(v[i], if i == 0 { 1 } else { 0 }, 0);
            v[i] = s_;
            if c == 0 {
                break;
            }
            i += 1;
            if i == v.len() {
                v.push(0);
            }
        }
        v
    };
    let half_t1 = bigint::shr_slices(&t_plus_1, 1);
    let mut r = a.pow_limbs(&half_t1);
    let mut b = a.pow_limbs(&t);
    let z: F = find_nonresidue();
    let mut c = z.pow_limbs(&t);
    let mut m = s;

    while b != F::one() {
        // least i in (0, m): b^(2^i) = 1
        let mut i = 0u32;
        let mut t2 = b;
        while t2 != F::one() {
            t2 = t2.square();
            i += 1;
            if i == m {
                return None; // not a residue (shouldn't happen post-Euler)
            }
        }
        // c^(2^(m-i-1))
        let mut cexp = c;
        for _ in 0..(m - i - 1) {
            cexp = cexp.square();
        }
        r = r.mul(&cexp);
        c = cexp.square();
        b = b.mul(&c);
        m = i;
    }
    debug_assert_eq!(r.square(), *a);
    Some(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::fp::Fp;
    use crate::ff::fp2::Fp2;
    use crate::ff::params::{Bls12381FpParams, Bn254FpParams};

    type FpBn = Fp<Bn254FpParams, 4>;
    type FpBls = Fp<Bls12381FpParams, 6>;
    type F2Bls = Fp2<Bls12381FpParams, 6>;

    #[test]
    fn sqrt_of_squares_roundtrips() {
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let a = FpBn::random(&mut rng);
            let sq = a.square();
            let r = sqrt(&sq).expect("square must have a root");
            assert!(r == a || r == a.neg());
        }
    }

    #[test]
    fn sqrt_rejects_nonsquares() {
        let mut rng = Rng::new(32);
        let mut rejected = 0;
        for _ in 0..20 {
            let a = FpBls::random(&mut rng);
            if euler_criterion(&a) == Some(false) {
                assert!(sqrt(&a).is_none());
                rejected += 1;
            }
        }
        assert!(rejected > 0, "should have seen some nonsquares");
    }

    #[test]
    fn sqrt_zero_and_one() {
        assert_eq!(sqrt(&FpBn::zero()), Some(FpBn::zero()));
        let r = sqrt(&FpBn::one()).unwrap();
        assert!(r == FpBn::one() || r == FpBn::one().neg());
    }

    #[test]
    fn sqrt_in_fp2() {
        let mut rng = Rng::new(33);
        for _ in 0..5 {
            let a = F2Bls::random(&mut rng);
            let sq = a.square();
            let r = sqrt(&sq).expect("square in Fp2 must have a root");
            assert!(r == a || r == a.neg());
            assert_eq!(r.square(), sq);
        }
    }

    #[test]
    fn euler_on_known_values() {
        // 4 is always a square; generator is configured to be a nonresidue.
        assert_eq!(euler_criterion(&FpBn::from_u64(4)), Some(true));
        assert_eq!(euler_criterion(&FpBn::zero()), None);
    }
}
