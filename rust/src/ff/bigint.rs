//! Fixed-size little-endian multi-precision integer helpers.
//!
//! All routines are `const fn` where the Montgomery-constant derivation
//! needs them (R, R², −p⁻¹ mod 2⁶⁴ are computed at compile time from the
//! modulus alone — no hand-transcribed magic numbers anywhere in the crate).

/// carry-propagating add: returns (sum, carry_out).
#[inline(always)]
pub const fn adc(a: u64, b: u64, carry: u64) -> (u64, u64) {
    let t = a as u128 + b as u128 + carry as u128;
    (t as u64, (t >> 64) as u64)
}

/// borrow-propagating sub: returns (diff, borrow_out ∈ {0,1}).
#[inline(always)]
pub const fn sbb(a: u64, b: u64, borrow: u64) -> (u64, u64) {
    let t = (a as u128).wrapping_sub(b as u128 + borrow as u128);
    (t as u64, ((t >> 64) as u64) & 1)
}

/// multiply-accumulate: acc + a*b + carry → (lo, hi).
#[inline(always)]
pub const fn mac(acc: u64, a: u64, b: u64, carry: u64) -> (u64, u64) {
    let t = acc as u128 + (a as u128) * (b as u128) + carry as u128;
    (t as u64, (t >> 64) as u64)
}

/// a < b over equal-length little-endian limbs.
#[inline]
pub const fn lt<const N: usize>(a: &[u64; N], b: &[u64; N]) -> bool {
    let mut i = N;
    while i > 0 {
        i -= 1;
        if a[i] < b[i] {
            return true;
        }
        if a[i] > b[i] {
            return false;
        }
    }
    false
}

/// a >= b.
#[inline]
pub const fn gte<const N: usize>(a: &[u64; N], b: &[u64; N]) -> bool {
    !lt(a, b)
}

/// a + b with carry-out.
#[inline]
pub const fn add<const N: usize>(a: &[u64; N], b: &[u64; N]) -> ([u64; N], u64) {
    let mut out = [0u64; N];
    let mut carry = 0u64;
    let mut i = 0;
    while i < N {
        let (s, c) = adc(a[i], b[i], carry);
        out[i] = s;
        carry = c;
        i += 1;
    }
    (out, carry)
}

/// a - b with borrow-out.
#[inline]
pub const fn sub<const N: usize>(a: &[u64; N], b: &[u64; N]) -> ([u64; N], u64) {
    let mut out = [0u64; N];
    let mut borrow = 0u64;
    let mut i = 0;
    while i < N {
        let (d, bo) = sbb(a[i], b[i], borrow);
        out[i] = d;
        borrow = bo;
        i += 1;
    }
    (out, borrow)
}

/// Double in place, returning carry-out.
#[inline]
pub const fn double<const N: usize>(a: &[u64; N]) -> ([u64; N], u64) {
    let mut out = [0u64; N];
    let mut carry = 0u64;
    let mut i = 0;
    while i < N {
        out[i] = (a[i] << 1) | carry;
        carry = a[i] >> 63;
        i += 1;
    }
    (out, carry)
}

/// Is zero?
#[inline]
pub const fn is_zero<const N: usize>(a: &[u64; N]) -> bool {
    let mut i = 0;
    while i < N {
        if a[i] != 0 {
            return false;
        }
        i += 1;
    }
    true
}

/// Bit `i` (little-endian).
#[inline]
pub fn bit<const N: usize>(a: &[u64; N], i: usize) -> bool {
    debug_assert!(i < 64 * N);
    (a[i / 64] >> (i % 64)) & 1 == 1
}

/// Index of the highest set bit, or None for zero.
pub fn msb<const N: usize>(a: &[u64; N]) -> Option<usize> {
    for i in (0..N).rev() {
        if a[i] != 0 {
            return Some(64 * i + 63 - a[i].leading_zeros() as usize);
        }
    }
    None
}

/// −p⁻¹ mod 2⁶⁴ via Newton/Hensel lifting; p must be odd.
pub const fn mont_inv64(p0: u64) -> u64 {
    // Each iteration doubles the number of correct low bits (start: 1 bit
    // because p0 odd ⇒ p0·p0 ≡ 1 mod 2... use standard 63-step-safe loop).
    let mut inv = 1u64;
    let mut i = 0;
    while i < 6 {
        inv = inv.wrapping_mul(2u64.wrapping_sub(p0.wrapping_mul(inv)));
        i += 1;
    }
    inv.wrapping_neg()
}

/// 2^(64·N) mod p (the Montgomery radix), computed by 64·N modular doublings.
pub const fn compute_r<const N: usize>(p: &[u64; N]) -> [u64; N] {
    // start from 1, double 64*N times, reducing mod p each step.
    let mut x = [0u64; N];
    x[0] = 1;
    let mut i = 0;
    while i < 64 * N {
        let (d, carry) = double(&x);
        // reduce: if carry or d >= p, subtract p
        if carry == 1 || gte(&d, p) {
            let (r, _) = sub(&d, p);
            x = r;
        } else {
            x = d;
        }
        i += 1;
    }
    x
}

/// R² = 2^(128·N) mod p.
pub const fn compute_r2<const N: usize>(p: &[u64; N]) -> [u64; N] {
    let mut x = compute_r(p);
    let mut i = 0;
    while i < 64 * N {
        let (d, carry) = double(&x);
        if carry == 1 || gte(&d, p) {
            let (r, _) = sub(&d, p);
            x = r;
        } else {
            x = d;
        }
        i += 1;
    }
    x
}

/// Restoring long division of an M-limb numerator by an N-limb denominator:
/// returns (quotient, remainder). The denominator must be nonzero and below
/// `2^(64·N − 1)` (one spare bit so the shifted remainder never overflows its
/// N limbs) — true for every modulus in the crate. Used by the GLV lattice
/// setup (`ec::endo`), which needs exact quotients the Montgomery/Barrett
/// fast paths cannot provide.
pub fn div_rem_wide<const M: usize, const N: usize>(
    num: &[u64; M],
    den: &[u64; N],
) -> ([u64; M], [u64; N]) {
    assert!(!is_zero(den), "division by zero");
    // hard assert: a violated precondition would silently corrupt the
    // quotient in release builds (the shifted remainder drops its carry)
    assert!(den[N - 1] >> 63 == 0, "denominator needs a spare top bit");
    let mut q = [0u64; M];
    let mut r = [0u64; N];
    let mut i = 64 * M;
    while i > 0 {
        i -= 1;
        // r = (r << 1) | numerator bit i
        let mut carry = (num[i / 64] >> (i % 64)) & 1;
        for limb in r.iter_mut() {
            let hi = *limb >> 63;
            *limb = (*limb << 1) | carry;
            carry = hi;
        }
        debug_assert_eq!(carry, 0);
        if gte(&r, den) {
            let (d, _) = sub(&r, den);
            r = d;
            q[i / 64] |= 1 << (i % 64);
        }
    }
    (q, r)
}

/// [`div_rem_wide`] for equal widths (the EEA quotient step).
pub fn div_rem<const N: usize>(a: &[u64; N], b: &[u64; N]) -> ([u64; N], [u64; N]) {
    div_rem_wide::<N, N>(a, b)
}

/// Divide a little-endian slice by a small (64-bit) divisor: returns
/// (quotient, remainder). Exact-exponent manipulation for the cube-root
/// derivations in `ec::endo` ((q − 1)/3 with a 3-divisibility check).
pub fn div_rem_small(a: &[u64], d: u64) -> (Vec<u64>, u64) {
    assert!(d != 0, "division by zero");
    let mut q = vec![0u64; a.len()];
    let mut rem: u128 = 0;
    for i in (0..a.len()).rev() {
        let cur = (rem << 64) | a[i] as u128;
        q[i] = (cur / d as u128) as u64;
        rem = cur % d as u128;
    }
    normalize(&mut q);
    (q, rem as u64)
}

/// Schoolbook widening multiply into hi/lo halves (runtime use: Barrett path
/// and tests; the Montgomery hot path uses fused CIOS instead).
pub fn mul_wide<const N: usize>(a: &[u64; N], b: &[u64; N]) -> ([u64; N], [u64; N]) {
    let mut t = vec![0u64; 2 * N];
    for i in 0..N {
        let mut carry = 0u64;
        for j in 0..N {
            let (lo, c) = mac(t[i + j], a[i], b[j], carry);
            t[i + j] = lo;
            carry = c;
        }
        t[i + N] = carry;
    }
    let mut lo = [0u64; N];
    let mut hi = [0u64; N];
    lo.copy_from_slice(&t[..N]);
    hi.copy_from_slice(&t[N..]);
    (lo, hi)
}

// ---------------------------------------------------------------------------
// Slice-based helpers for the variable-width paths (Barrett μ, exponent
// manipulation for Tonelli–Shanks). Little-endian, arbitrary length.
// ---------------------------------------------------------------------------

/// Strip high zero limbs.
pub fn normalize(a: &mut Vec<u64>) {
    while a.len() > 1 && *a.last().unwrap() == 0 {
        a.pop();
    }
}

/// Compare variable-length little-endian numbers.
pub fn cmp_slices(a: &[u64], b: &[u64]) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    let n = a.len().max(b.len());
    for i in (0..n).rev() {
        let av = a.get(i).copied().unwrap_or(0);
        let bv = b.get(i).copied().unwrap_or(0);
        match av.cmp(&bv) {
            Ordering::Equal => continue,
            o => return o,
        }
    }
    Ordering::Equal
}

/// a - b for slices (a >= b required).
pub fn sub_slices(a: &[u64], b: &[u64]) -> Vec<u64> {
    debug_assert!(cmp_slices(a, b) != std::cmp::Ordering::Less);
    let mut out = vec![0u64; a.len()];
    let mut borrow = 0u64;
    for i in 0..a.len() {
        let bv = b.get(i).copied().unwrap_or(0);
        let (d, bo) = sbb(a[i], bv, borrow);
        out[i] = d;
        borrow = bo;
    }
    debug_assert_eq!(borrow, 0);
    normalize(&mut out);
    out
}

/// Shift left by `k` bits.
pub fn shl_slices(a: &[u64], k: usize) -> Vec<u64> {
    let limb_shift = k / 64;
    let bit_shift = k % 64;
    let mut out = vec![0u64; a.len() + limb_shift + 1];
    for (i, &w) in a.iter().enumerate() {
        out[i + limb_shift] |= w << bit_shift;
        if bit_shift > 0 {
            out[i + limb_shift + 1] |= w >> (64 - bit_shift);
        }
    }
    normalize(&mut out);
    out
}

/// Shift right by `k` bits.
pub fn shr_slices(a: &[u64], k: usize) -> Vec<u64> {
    let limb_shift = k / 64;
    let bit_shift = k % 64;
    if limb_shift >= a.len() {
        return vec![0];
    }
    let mut out = vec![0u64; a.len() - limb_shift];
    for i in 0..out.len() {
        let lo = a[i + limb_shift] >> bit_shift;
        let hi = if bit_shift > 0 {
            a.get(i + limb_shift + 1).copied().unwrap_or(0) << (64 - bit_shift)
        } else {
            0
        };
        out[i] = lo | hi;
    }
    normalize(&mut out);
    out
}

/// Number of trailing zero bits (None for zero value).
pub fn trailing_zeros(a: &[u64]) -> Option<u32> {
    for (i, &w) in a.iter().enumerate() {
        if w != 0 {
            return Some(64 * i as u32 + w.trailing_zeros());
        }
    }
    None
}

/// floor(2^k / d) via restoring long division (one-time Barrett μ setup).
pub fn div_pow2(k: usize, d: &[u64]) -> Vec<u64> {
    assert!(!d.iter().all(|&w| w == 0), "division by zero");
    let mut quotient = vec![0u64; k / 64 + 1];
    let mut rem: Vec<u64> = vec![0];
    // Process bits of 2^k from MSB (bit k) to LSB. Numerator bits: bit k is
    // 1, the rest 0.
    for bitpos in (0..=k).rev() {
        // rem <<= 1; rem |= numerator bit
        rem = shl_slices(&rem, 1);
        if bitpos == k {
            rem[0] |= 1;
        }
        if cmp_slices(&rem, d) != std::cmp::Ordering::Less {
            rem = sub_slices(&rem, d);
            quotient[bitpos / 64] |= 1 << (bitpos % 64);
        }
    }
    normalize(&mut quotient);
    quotient
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_sbb_roundtrip() {
        let (s, c) = adc(u64::MAX, 1, 0);
        assert_eq!((s, c), (0, 1));
        let (d, b) = sbb(0, 1, 0);
        assert_eq!((d, b), (u64::MAX, 1));
    }

    #[test]
    fn add_sub_inverse() {
        let a = [1u64, 2, 3, 4];
        let b = [5u64, 6, 7, 8];
        let (s, c) = add(&a, &b);
        assert_eq!(c, 0);
        let (d, bo) = sub(&s, &b);
        assert_eq!(bo, 0);
        assert_eq!(d, a);
    }

    #[test]
    fn lt_works() {
        assert!(lt(&[0, 1], &[0, 2]));
        assert!(lt(&[5, 1], &[0, 2]));
        assert!(!lt(&[0, 2], &[0, 2]));
        assert!(!lt(&[1, 2], &[0, 2]));
    }

    #[test]
    fn mont_inv64_property() {
        for p0 in [0x43e1f593f0000001u64, 0xb9feffffffffaaab, 3, 0xffffffffffffffff] {
            let inv = mont_inv64(p0);
            assert_eq!(p0.wrapping_mul(inv.wrapping_neg()), 1, "p0={p0:#x}");
        }
    }

    #[test]
    fn compute_r_small_modulus() {
        // p = 2^64 - 59 (prime); R = 2^64 mod p = 59.
        let p = [u64::MAX - 58];
        assert_eq!(compute_r(&p), [59]);
        // R2 = 59^2 mod p = 3481.
        assert_eq!(compute_r2(&p), [3481]);
    }

    #[test]
    fn mul_wide_small() {
        let (lo, hi) = mul_wide(&[u64::MAX], &[u64::MAX]);
        // (2^64-1)^2 = 2^128 - 2^65 + 1
        assert_eq!(lo, [1]);
        assert_eq!(hi, [u64::MAX - 1]);
    }

    #[test]
    fn msb_and_bit() {
        let a = [0u64, 0b1000];
        assert_eq!(msb(&a), Some(67));
        assert!(bit(&a, 67));
        assert!(!bit(&a, 66));
        assert_eq!(msb(&[0u64, 0]), None);
    }

    #[test]
    fn slice_shifts() {
        let a = vec![0x8000_0000_0000_0000u64];
        assert_eq!(shl_slices(&a, 1), vec![0, 1]);
        assert_eq!(shr_slices(&shl_slices(&a, 5), 5), a);
        assert_eq!(shr_slices(&a, 64), vec![0]);
    }

    #[test]
    fn div_pow2_exact() {
        // 2^10 / 8 = 128
        assert_eq!(div_pow2(10, &[8]), vec![128]);
        // 2^64 / 3 = 6148914691236517205
        assert_eq!(div_pow2(64, &[3]), vec![6148914691236517205]);
    }

    #[test]
    fn div_rem_matches_known_quotients() {
        // 4-limb / 4-limb with a known split: a = q·b + r
        let a = [0u64, 0, 0, 1 << 60]; // 2^252
        let b = [3u64, 0, 0, 0];
        let (q, r) = div_rem(&a, &b);
        // 2^252 = 3·q + r with r < 3: q = (2^252 - 1)/3, r = 1 (2^252 ≡ 1 mod 3)
        assert_eq!(r, [1, 0, 0, 0]);
        let (lo, hi) = mul_wide(&q, &b);
        let (sum, carry) = add(&lo, &r);
        assert_eq!(carry, 0);
        assert_eq!(sum, a);
        assert_eq!(hi, [0; 4]);
        // identity and zero numerators
        assert_eq!(div_rem(&[7, 0, 0, 0], &[7, 0, 0, 0]), ([1, 0, 0, 0], [0, 0, 0, 0]));
        assert_eq!(div_rem(&[0; 4], &[5, 0, 0, 0]), ([0; 4], [0; 4]));
    }

    #[test]
    fn div_rem_wide_eight_by_four() {
        // (2^256·x) / d for small x, d: exercises the wide numerator path
        let mut num = [0u64; 8];
        num[4] = 1_000_003; // 2^256 · 1000003
        let den = [97u64, 0, 0, 0];
        let (q, r) = div_rem_wide::<8, 4>(&num, &den);
        // spot-check via reconstruction: q·97 + r == num
        let mut q4 = [0u64; 4];
        q4.copy_from_slice(&q[..4]);
        let (lo, hi) = mul_wide(&q4, &den);
        let (sum, carry) = add(&lo, &r);
        assert_eq!(carry, 0);
        assert_eq!(&sum[..], &num[..4]);
        assert_eq!(hi[0], num[4]); // high half carries the 2^256 part
        assert!(r[0] < 97 && r[1] | r[2] | r[3] == 0);
        assert_eq!(&q[4..], &[0u64; 4]);
    }

    #[test]
    fn div_rem_small_matches_long_division() {
        let (q, r) = div_rem_small(&[10, 0, 7], 3);
        // value = 7·2^128 + 10; q·3 + r must reconstruct it
        assert!(r < 3);
        let back_lo = q[0].wrapping_mul(3).wrapping_add(r);
        assert_eq!(back_lo, 10);
        let (q2, r2) = div_rem_small(&[9], 3);
        assert_eq!((q2, r2), (vec![3], 0));
        let (q3, r3) = div_rem_small(&[u64::MAX, u64::MAX], 1);
        assert_eq!((q3, r3), (vec![u64::MAX, u64::MAX], 0));
    }

    #[test]
    fn trailing_zeros_works() {
        assert_eq!(trailing_zeros(&[0, 0b100]), Some(66));
        assert_eq!(trailing_zeros(&[0, 0]), None);
        assert_eq!(trailing_zeros(&[1]), Some(0));
    }

    #[test]
    fn cmp_handles_unequal_lengths() {
        use std::cmp::Ordering::*;
        assert_eq!(cmp_slices(&[1, 0, 0], &[1]), Equal);
        assert_eq!(cmp_slices(&[0, 1], &[5]), Greater);
        assert_eq!(cmp_slices(&[5], &[0, 1]), Less);
    }
}
